#!/usr/bin/env python3
"""Validate BENCH_table7.json (schema + stage-mapping-sweep gate).

Usage: check_bench_table7.py

Run after `cargo bench --bench table7_stage_mapping`. Every gated value
is cycle-model or resource-model derived, so the gate is
machine-independent:

* schema: workload / mappings / summary sections, 16 mapping rows;
* rows follow Table 7 order: all-DSP first, all-LUT last, all 16
  stage-map names distinct;
* every mapping has positive cycles/interval and interval <= cycles;
* binding choice only perturbs pipeline fill depth, never throughput:
  the cycle spread across the sweep stays under 1.15x;
* the all-DSP row spends the most DSPs and the all-LUT row none;
* the summary block is self-consistent with the rows.
"""
import json

d = json.load(open("BENCH_table7.json"))

# --- schema ---
for key in ("bench", "workload", "mappings", "summary", "rows"):
    assert key in d, f"missing key: {key}"
assert d["bench"] == "table7"
for k in ("base_config", "mappings", "device"):
    assert k in d["workload"], f"missing workload.{k}"
assert d["workload"]["base_config"] == "concurrent"

rows = d["mappings"]
assert len(rows) == d["workload"]["mappings"] == 16, "Table 7 is the 2^4 sweep"
for r in rows:
    for k in ("config", "cycles", "interval", "lut", "ff", "dsp", "bram18",
              "worst_stage_ii", "fits_pynq"):
        assert k in r, f"{r.get('config', '?')}: missing {k}"
    assert r["cycles"] > 0 and r["interval"] > 0, f"{r['config']}: empty model"
    assert r["interval"] <= r["cycles"], f"{r['config']}: interval > cycles"
    assert r["worst_stage_ii"] >= 1

# --- Table 7 row order and naming ---
names = [r["config"] for r in rows]
assert len(set(names)) == 16, "stage-map names must be distinct"
assert names[0] == "s1D_s2D_s3D_s4D", f"row 0 must be all-DSP, got {names[0]}"
assert names[15] == "s1L_s2L_s3L_s4L", f"row 15 must be all-LUT, got {names[15]}"

# --- binding moves resources, not throughput ---
best = min(r["cycles"] for r in rows)
worst = max(r["cycles"] for r in rows)
spread = worst / best
assert spread < 1.15, f"binding changed throughput: cycle spread {spread:.3f}x"
assert rows[15]["dsp"] == 0, "all-LUT mapping must spend no DSP48s"
assert rows[0]["dsp"] == max(r["dsp"] for r in rows), \
    "all-DSP mapping must be the DSP-heaviest row"
assert rows[15]["lut"] > rows[0]["lut"], \
    "all-LUT mapping must pay for its MACs in fabric LUTs"
fitting = sum(1 for r in rows if r["fits_pynq"])
assert fitting >= 1, "at least one mapping must fit the PYNQ-Z2"

# --- summary self-consistency ---
s = d["summary"]
for k in ("best_cycles", "worst_cycles", "cycle_spread", "fitting"):
    assert k in s, f"missing summary.{k}"
assert s["best_cycles"] == best and s["worst_cycles"] == worst
assert abs(s["cycle_spread"] - spread) < 1e-9
assert s["fitting"] == fitting

print(f"BENCH_table7.json OK: 16 mappings, {fitting} fit, "
      f"cycle spread {spread:.3f}x ({best:.0f}..{worst:.0f} cycles)")

#!/usr/bin/env python3
"""Validate BENCH_stream.json (schema + deterministic throughput floor).

Usage: check_bench_stream.py <expected-backend> [tuned] [chaos]

Run after `merinda soak` with MERINDA_SOAK_TENANTS / MERINDA_SOAK_SAMPLES
set; every gated value below is window-count or cycle-model based, so the
gate is machine-independent (wall-clock numbers live in the ungated
"wall" section). Pass `tuned` when the soak ran with `--tuned`, and
`chaos` when it ran with `--chaos`, so CI notices if either path
silently stops being exercised.

In chaos mode the completion gate is *stronger in spirit*: the fixed
smoke plan injects a crash, a stall and a bit-flip, and every window
must still complete (failover + retry absorb the faults), every injected
flip must be caught by the fidelity check, and every crashed instance
must be reported down. Wall-clock-dependent counters (timeouts,
duplicates) are not gated — only their ledger consistency is.
"""
import json
import os
import sys

expected_backend = sys.argv[1] if len(sys.argv) > 1 else "native"
flags = set(sys.argv[2:])
unknown = flags - {"tuned", "chaos"}
assert not unknown, f"unknown flags: {sorted(unknown)}"
expected_tuned = "tuned" in flags
expected_chaos = "chaos" in flags
tenants = int(os.environ.get("MERINDA_SOAK_TENANTS", "6"))
samples = int(os.environ.get("MERINDA_SOAK_SAMPLES", "400"))

d = json.load(open("BENCH_stream.json"))

# --- schema ---
for key in ("bench", "workload", "totals", "fairness", "queue",
            "cycle_model", "verify", "placement", "warm_start", "faults",
            "wall", "rows", "speedups"):
    assert key in d, f"missing key: {key}"
assert d["bench"] == "stream"
for k in ("tenants", "samples_per_tenant", "window", "stride", "backend",
          "workers", "scenarios", "tuned"):
    assert k in d["workload"], f"missing workload.{k}"
for k in ("windows_emitted", "windows_completed", "windows_shed",
          "windows_failed"):
    assert k in d["totals"], f"missing totals.{k}"
for k in ("min_tenant_completed", "max_tenant_completed"):
    assert k in d["fairness"], f"missing fairness.{k}"
for k in ("service_queue_depth_max", "tenant_queue_max", "in_flight_max",
          "burst_backoffs", "burst_final"):
    assert k in d["queue"], f"missing queue.{k}"
for k in ("window_cycles", "interval", "modeled_cycles_streamed",
          "windows_per_mcycle"):
    assert k in d["cycle_model"], f"missing cycle_model.{k}"
for k in ("checked", "compared", "max_abs_delta"):
    assert k in d["verify"], f"missing verify.{k}"
for k in ("instances", "instances_used", "per_instance"):
    assert k in d["placement"], f"missing placement.{k}"
for k in ("enabled", "paired_windows", "warm_iters", "cold_iters",
          "iter_ratio", "warm_cycles", "cold_cycles", "cycle_ratio",
          "scenarios_measured", "scenarios_warm_below_cold",
          "per_scenario"):
    assert k in d["warm_start"], f"missing warm_start.{k}"
for k in ("chaos", "plan", "deadline_ms", "injected_crash",
          "injected_stall", "injected_link", "injected_flip",
          "detected_timeouts", "detected_disconnects",
          "detected_corruptions", "detected_submit_down", "failed_over",
          "retries", "duplicates_dropped", "exhausted",
          "degraded_entries", "degraded_exits", "standby_windows",
          "instances_down", "instances_recovered",
          "recovery_rounds_total", "accounting_closed"):
    assert k in d["faults"], f"missing faults.{k}"

# --- workload matches the env knobs ---
w = d["workload"]
assert w["backend"] == expected_backend, \
    f"backend {w['backend']!r} != expected {expected_backend!r}"
assert w["tenants"] == tenants and w["samples_per_tenant"] == samples
assert w["tuned"] is expected_tuned, \
    f"tuned {w['tuned']} != expected {expected_tuned}"

# --- deterministic completion gate: every planned window recovered ---
t = d["totals"]
window, stride = w["window"], w["stride"]
per_tenant = (samples - window) // stride + 1 if samples >= window else 0
# +1 tail window when the strided walk leaves trailing samples uncovered.
if samples >= window and (per_tenant - 1) * stride + window < samples:
    per_tenant += 1
expected_windows = tenants * per_tenant
assert t["windows_emitted"] == expected_windows, \
    f"emitted {t['windows_emitted']} != planned {expected_windows}"
assert t["windows_completed"] == t["windows_emitted"], \
    "smoke workload must complete every window (no shed/fail) — " \
    "under chaos, failover and retry must absorb the injected faults"
assert t["windows_shed"] == 0 and t["windows_failed"] == 0

# --- fairness: identical-length streams must complete identically ---
f = d["fairness"]
assert f["min_tenant_completed"] == f["max_tenant_completed"] == per_tenant

# --- sustained-throughput floor from the accelerator cycle model ---
wpm = d["cycle_model"]["windows_per_mcycle"]
assert wpm >= 5.0, f"sustained throughput regressed: {wpm} windows/Mcycle"

# --- streaming must equal the one-shot path bitwise ---
v = d["verify"]
assert v["checked"], "soak smoke must run with verification on"
assert v["compared"] == expected_windows
assert v["max_abs_delta"] == 0.0, \
    f"streaming diverged from one-shot recovery: {v['max_abs_delta']}"

# --- resource-aware placement: budget-respecting, fully accounted ---
p = d["placement"]
per_inst = p["per_instance"]
assert len(per_inst) == p["instances"] >= 1
if expected_chaos:
    # Failed-over windows are placed more than once, so the placed sum
    # exceeds the window count by exactly the observable failovers.
    assert sum(i["placed"] for i in per_inst) >= expected_windows
else:
    assert sum(i["placed"] for i in per_inst) == expected_windows, \
        "every completed window must be attributed to an instance"
assert sum(i["completed"] for i in per_inst) == expected_windows
for i in per_inst:
    assert i["completed"] <= i["placed"]
    assert i["window_cycles"] > 0, f"{i['name']}: cycle model must be wired in"
    assert i["modeled_cycles"] == i["completed"] * i["window_cycles"]
    assert i["health"] in ("healthy", "degraded", "down", "recovering"), \
        f"{i['name']}: unknown health {i['health']!r}"
assert p["instances_used"] == sum(1 for i in per_inst if i["placed"] > 0)
if p["instances"] > 1 and expected_windows >= 2 * tenants:
    assert p["instances_used"] >= 2, \
        "a loaded multi-instance fleet must spread windows across siblings"

# --- warm-start recovery: fewer iterations than cold, per scenario ---
# Under chaos, corruption retries invalidate the warm cache, so the
# paired-window count is workload-dependent; the iteration gates apply
# only to the healthy-fleet smoke.
ws = d["warm_start"]
assert ws["enabled"], "soak smoke must run with warm-start on"
if expected_chaos:
    assert ws["paired_windows"] <= tenants * max(per_tenant - 1, 0)
else:
    assert ws["paired_windows"] == tenants * max(per_tenant - 1, 0), \
        "every non-first window must be measured warm AND cold"
if not expected_chaos and ws["paired_windows"] > 0:
    assert ws["warm_iters"] < ws["cold_iters"], \
        f"warm-start must save iterations: {ws['warm_iters']} vs {ws['cold_iters']}"
    assert 0.0 < ws["iter_ratio"] < 1.0 or ws["warm_iters"] == 0
    assert ws["cycle_ratio"] < 1.0, \
        f"modeled recovery cycles must shrink: ratio {ws['cycle_ratio']}"
    assert ws["warm_cycles"] < ws["cold_cycles"]
    # The acceptance bar: warm strictly below cold on all but at most
    # one scenario (>= 5 of 6 on the full roster).
    assert ws["scenarios_measured"] >= 1
    assert ws["scenarios_warm_below_cold"] >= ws["scenarios_measured"] - 1, \
        (f"warm-start regressed on too many scenarios: "
         f"{ws['scenarios_warm_below_cold']}/{ws['scenarios_measured']} "
         f"({ws['per_scenario']})")

# --- fault layer: ledger always closed; injection observable in chaos ---
fa = d["faults"]
assert fa["chaos"] is expected_chaos, \
    f"chaos {fa['chaos']} != expected {expected_chaos}"
assert fa["accounting_closed"], \
    "per-tenant accounting must close: completed + shed + failed == emitted"
injected = (fa["injected_crash"] + fa["injected_stall"]
            + fa["injected_link"] + fa["injected_flip"])
if expected_chaos:
    assert fa["plan"], "a chaos run must record its plan spec"
    assert injected >= 1, "the chaos plan must actually fire"
    assert fa["detected_corruptions"] == fa["injected_flip"], \
        (f"{fa['injected_flip']} flips injected but "
         f"{fa['detected_corruptions']} caught by the fidelity check")
    if fa["injected_crash"] > 0:
        assert fa["instances_down"] >= fa["injected_crash"], \
            "every crashed instance must be taken down by the health machine"
        downs = sum(1 for i in per_inst if i["health"] == "down")
        assert downs >= fa["injected_crash"], \
            f"crashed instances must report down at exit: {per_inst}"
    if fa["failed_over"] > 0:
        assert fa["retries"] >= 1, \
            "failover without retries would mean windows were dropped"
else:
    assert fa["plan"] == "", "no plan may be armed outside chaos mode"
    assert injected == 0, f"faults injected without chaos: {fa}"
    for k in ("detected_timeouts", "detected_disconnects",
              "detected_corruptions", "detected_submit_down",
              "failed_over", "retries", "duplicates_dropped", "exhausted",
              "standby_windows", "instances_down"):
        assert fa[k] == 0, \
            f"healthy-fleet smoke observed faults.{k} = {fa[k]}"

mode = " +chaos" if expected_chaos else ""
print(f"BENCH_stream.json OK: {expected_windows} windows on "
      f"{w['backend']}{mode}, {wpm:.1f} windows/Mcycle, "
      f"{p['instances_used']}/{p['instances']} instances used, "
      f"warm/cold iters {ws['warm_iters']}/{ws['cold_iters']}, "
      f"bitwise-verified")

#!/usr/bin/env python3
"""Validate BENCH_stream.json (schema + deterministic throughput floor).

Usage: check_bench_stream.py <expected-backend>

Run after `merinda soak` with MERINDA_SOAK_TENANTS / MERINDA_SOAK_SAMPLES
set; every gated value below is window-count or cycle-model based, so the
gate is machine-independent (wall-clock numbers live in the ungated
"wall" section).
"""
import json
import os
import sys

expected_backend = sys.argv[1] if len(sys.argv) > 1 else "native"
tenants = int(os.environ.get("MERINDA_SOAK_TENANTS", "6"))
samples = int(os.environ.get("MERINDA_SOAK_SAMPLES", "400"))

d = json.load(open("BENCH_stream.json"))

# --- schema ---
for key in ("bench", "workload", "totals", "fairness", "queue",
            "cycle_model", "verify", "wall", "rows", "speedups"):
    assert key in d, f"missing key: {key}"
assert d["bench"] == "stream"
for k in ("tenants", "samples_per_tenant", "window", "stride", "backend",
          "workers", "scenarios"):
    assert k in d["workload"], f"missing workload.{k}"
for k in ("windows_emitted", "windows_completed", "windows_shed",
          "windows_failed"):
    assert k in d["totals"], f"missing totals.{k}"
for k in ("min_tenant_completed", "max_tenant_completed"):
    assert k in d["fairness"], f"missing fairness.{k}"
for k in ("service_queue_depth_max", "tenant_queue_max", "in_flight_max",
          "burst_backoffs", "burst_final"):
    assert k in d["queue"], f"missing queue.{k}"
for k in ("window_cycles", "interval", "modeled_cycles_streamed",
          "windows_per_mcycle"):
    assert k in d["cycle_model"], f"missing cycle_model.{k}"
for k in ("checked", "compared", "max_abs_delta"):
    assert k in d["verify"], f"missing verify.{k}"

# --- workload matches the env knobs ---
w = d["workload"]
assert w["backend"] == expected_backend, \
    f"backend {w['backend']!r} != expected {expected_backend!r}"
assert w["tenants"] == tenants and w["samples_per_tenant"] == samples

# --- deterministic completion gate: every planned window recovered ---
t = d["totals"]
window, stride = w["window"], w["stride"]
per_tenant = (samples - window) // stride + 1 if samples >= window else 0
# +1 tail window when the strided walk leaves trailing samples uncovered.
if samples >= window and (per_tenant - 1) * stride + window < samples:
    per_tenant += 1
expected_windows = tenants * per_tenant
assert t["windows_emitted"] == expected_windows, \
    f"emitted {t['windows_emitted']} != planned {expected_windows}"
assert t["windows_completed"] == t["windows_emitted"], \
    "smoke workload must complete every window (no shed/fail)"
assert t["windows_shed"] == 0 and t["windows_failed"] == 0

# --- fairness: identical-length streams must complete identically ---
f = d["fairness"]
assert f["min_tenant_completed"] == f["max_tenant_completed"] == per_tenant

# --- sustained-throughput floor from the accelerator cycle model ---
wpm = d["cycle_model"]["windows_per_mcycle"]
assert wpm >= 5.0, f"sustained throughput regressed: {wpm} windows/Mcycle"

# --- streaming must equal the one-shot path bitwise ---
v = d["verify"]
assert v["checked"], "soak smoke must run with verification on"
assert v["compared"] == expected_windows
assert v["max_abs_delta"] == 0.0, \
    f"streaming diverged from one-shot recovery: {v['max_abs_delta']}"

print(f"BENCH_stream.json OK: {expected_windows} windows on "
      f"{w['backend']}, {wpm:.1f} windows/Mcycle, bitwise-verified")

#!/usr/bin/env python3
"""Validate BENCH_stream.json (schema + deterministic throughput floor).

Usage: check_bench_stream.py <expected-backend> [tuned] [chaos] [open-loop]

Run after `merinda soak` with MERINDA_SOAK_TENANTS / MERINDA_SOAK_SAMPLES
set; every gated value below is window-count or cycle-model based, so the
gate is machine-independent (wall-clock numbers live in the ungated
"wall" section). Pass `tuned` when the soak ran with `--tuned`, `chaos`
when it ran with `--chaos`, and `open-loop` when it ran with
`--open-loop`, so CI notices if any path silently stops being exercised.

In chaos mode the completion gate is *stronger in spirit*: the fixed
smoke plan injects a crash, a stall and a bit-flip, and every window
must still complete (failover + retry absorb the faults), every injected
flip must be caught by the fidelity check, and every crashed instance
must be reported down. Wall-clock-dependent counters (timeouts,
duplicates) are not gated — only their ledger consistency is.

In open-loop mode the fixed smoke spec drives a drifting realtime burst
through the QoS traffic tier, so the gates shift from "every planned
window completes" to the tier ledgers: offered == admitted + rejected
and admitted == completed + shed + failed per tier, the realtime p99
must meet its SLO with completed realtime windows to show for it, the
drift episode must have fired the online retune, and every completed
window must still verify bitwise against one-shot recovery. Chaos and
open-loop are separate smokes — combining the flags is rejected here
(the chaos completion gate is meaningless under deliberate overload).
"""
import json
import os
import sys

expected_backend = sys.argv[1] if len(sys.argv) > 1 else "native"
flags = set(sys.argv[2:])
unknown = flags - {"tuned", "chaos", "open-loop"}
assert not unknown, f"unknown flags: {sorted(unknown)}"
expected_tuned = "tuned" in flags
expected_chaos = "chaos" in flags
expected_open = "open-loop" in flags
assert not (expected_chaos and expected_open), \
    "chaos and open-loop are separate smokes — gate them separately"
tenants = int(os.environ.get("MERINDA_SOAK_TENANTS", "6"))
samples = int(os.environ.get("MERINDA_SOAK_SAMPLES", "400"))

TIERS = ("realtime", "standard", "batch")

d = json.load(open("BENCH_stream.json"))

# --- schema ---
for key in ("bench", "workload", "totals", "fairness", "queue",
            "cycle_model", "verify", "placement", "warm_start", "faults",
            "traffic", "qos", "admission", "retune", "wall", "rows",
            "speedups"):
    assert key in d, f"missing key: {key}"
assert d["bench"] == "stream"
for k in ("tenants", "samples_per_tenant", "window", "stride", "backend",
          "workers", "scenarios", "tuned"):
    assert k in d["workload"], f"missing workload.{k}"
for k in ("windows_emitted", "windows_completed", "windows_shed",
          "windows_failed"):
    assert k in d["totals"], f"missing totals.{k}"
for k in ("min_tenant_completed", "max_tenant_completed"):
    assert k in d["fairness"], f"missing fairness.{k}"
for k in ("service_queue_depth_max", "tenant_queue_max", "in_flight_max",
          "burst_backoffs", "burst_final"):
    assert k in d["queue"], f"missing queue.{k}"
for k in ("window_cycles", "interval", "modeled_cycles_streamed",
          "windows_per_mcycle"):
    assert k in d["cycle_model"], f"missing cycle_model.{k}"
for k in ("checked", "compared", "max_abs_delta"):
    assert k in d["verify"], f"missing verify.{k}"
for k in ("instances", "instances_used", "per_instance"):
    assert k in d["placement"], f"missing placement.{k}"
for k in ("enabled", "paired_windows", "warm_iters", "cold_iters",
          "iter_ratio", "warm_cycles", "cold_cycles", "cycle_ratio",
          "scenarios_measured", "scenarios_warm_below_cold",
          "per_scenario"):
    assert k in d["warm_start"], f"missing warm_start.{k}"
for k in ("chaos", "plan", "deadline_ms", "injected_crash",
          "injected_stall", "injected_link", "injected_flip",
          "detected_timeouts", "detected_disconnects",
          "detected_corruptions", "detected_submit_down", "failed_over",
          "retries", "duplicates_dropped", "exhausted",
          "degraded_entries", "degraded_exits", "standby_windows",
          "instances_down", "instances_recovered",
          "recovery_rounds_total", "accounting_closed"):
    assert k in d["faults"], f"missing faults.{k}"
for k in ("open_loop", "spec", "ticks", "offered_total", "backlog_budget",
          "max_drift", "per_tier"):
    assert k in d["traffic"], f"missing traffic.{k}"
for tier in TIERS:
    assert tier in d["traffic"]["per_tier"], f"missing traffic.per_tier.{tier}"
    for k in ("offered", "admitted", "rejected", "shed_budget"):
        assert k in d["traffic"]["per_tier"][tier], \
            f"missing traffic.per_tier.{tier}.{k}"
    assert tier in d["qos"], f"missing qos.{tier}"
    for k in ("offered", "admitted", "rejected", "placed", "completed",
              "shed", "failed", "latency_count", "p50_ms", "p99_ms",
              "p999_ms", "max_ms", "slo_ms", "slo_met"):
        assert k in d["qos"][tier], f"missing qos.{tier}.{k}"
for k in ("enabled", "slo_realtime_ms", "slo_standard_ms", "slo_batch_ms",
          "rejected_total", "closes"):
    assert k in d["admission"], f"missing admission.{k}"
for k in ("enabled", "drift_threshold", "count", "max_drift", "events"):
    assert k in d["retune"], f"missing retune.{k}"

# --- workload matches the env knobs ---
w = d["workload"]
assert w["backend"] == expected_backend, \
    f"backend {w['backend']!r} != expected {expected_backend!r}"
# Open-loop tenant population comes from the arrival spec's `tenants:`/
# `mix:` fields, not the env knob; the ring trajectories still honor it.
if not expected_open:
    assert w["tenants"] == tenants
assert w["samples_per_tenant"] == samples
assert w["tuned"] is expected_tuned, \
    f"tuned {w['tuned']} != expected {expected_tuned}"

t = d["totals"]
window, stride = w["window"], w["stride"]
per_tenant = (samples - window) // stride + 1 if samples >= window else 0
# +1 tail window when the strided walk leaves trailing samples uncovered.
if samples >= window and (per_tenant - 1) * stride + window < samples:
    per_tenant += 1
expected_windows = t["windows_emitted"] if expected_open \
    else w["tenants"] * per_tenant

if expected_open:
    # --- open-loop: emission is driven by the arrival plan, and the
    # gate is ledger closure, not full completion (overload may shed).
    assert t["windows_completed"] + t["windows_shed"] == t["windows_emitted"], \
        "open-loop disposition must close: completed + shed == emitted"
    assert t["windows_failed"] == 0, \
        "a healthy open-loop fleet must not fail windows (shed, never lose)"
    assert t["windows_completed"] > 0, "open-loop smoke completed nothing"
else:
    # --- deterministic completion gate: every planned window recovered ---
    assert t["windows_emitted"] == expected_windows, \
        f"emitted {t['windows_emitted']} != planned {expected_windows}"
    assert t["windows_completed"] == t["windows_emitted"], \
        "smoke workload must complete every window (no shed/fail) — " \
        "under chaos, failover and retry must absorb the injected faults"
    assert t["windows_shed"] == 0 and t["windows_failed"] == 0

# --- fairness: identical-length streams must complete identically
# (closed loop only — open-loop arrivals are Poisson-split by design) ---
f = d["fairness"]
if not expected_open:
    assert f["min_tenant_completed"] == f["max_tenant_completed"] == per_tenant

# --- sustained-throughput floor from the accelerator cycle model ---
wpm = d["cycle_model"]["windows_per_mcycle"]
assert wpm >= 5.0, f"sustained throughput regressed: {wpm} windows/Mcycle"

# --- streaming must equal the one-shot path bitwise ---
v = d["verify"]
assert v["checked"], "soak smoke must run with verification on"
assert v["compared"] == (t["windows_completed"] if expected_open
                         else expected_windows)
assert v["max_abs_delta"] == 0.0, \
    f"streaming diverged from one-shot recovery: {v['max_abs_delta']}"

# --- resource-aware placement: budget-respecting, fully accounted ---
p = d["placement"]
per_inst = p["per_instance"]
assert len(per_inst) == p["instances"] >= 1
placed_total = sum(q["placed"] for q in d["qos"].values())
if expected_chaos:
    # Failed-over windows are placed more than once, so the placed sum
    # exceeds the window count by exactly the observable failovers.
    assert sum(i["placed"] for i in per_inst) >= expected_windows
elif expected_open:
    # Shed windows never reach placement; everything placed completes.
    assert sum(i["placed"] for i in per_inst) == placed_total
    assert sum(i["completed"] for i in per_inst) == t["windows_completed"]
else:
    assert sum(i["placed"] for i in per_inst) == expected_windows, \
        "every completed window must be attributed to an instance"
if not expected_open:
    assert sum(i["completed"] for i in per_inst) == expected_windows
for i in per_inst:
    assert i["completed"] <= i["placed"]
    assert i["window_cycles"] > 0, f"{i['name']}: cycle model must be wired in"
    assert i["modeled_cycles"] == i["completed"] * i["window_cycles"]
    assert i["health"] in ("healthy", "degraded", "down", "recovering"), \
        f"{i['name']}: unknown health {i['health']!r}"
assert p["instances_used"] == sum(1 for i in per_inst if i["placed"] > 0)
if p["instances"] > 1 and expected_windows >= 2 * tenants:
    assert p["instances_used"] >= 2, \
        "a loaded multi-instance fleet must spread windows across siblings"

# --- warm-start recovery: fewer iterations than cold, per scenario ---
# Under chaos, corruption retries invalidate the warm cache, so the
# paired-window count is workload-dependent; the iteration gates apply
# only to the healthy-fleet smoke. The open-loop smoke runs --no-warm
# (ring arrivals repeat windows, which would double-count pairs), so its
# warm-start section is reported but not gated.
ws = d["warm_start"]
if not expected_open:
    assert ws["enabled"], "soak smoke must run with warm-start on"
if expected_chaos:
    assert ws["paired_windows"] <= tenants * max(per_tenant - 1, 0)
elif not expected_open:
    assert ws["paired_windows"] == tenants * max(per_tenant - 1, 0), \
        "every non-first window must be measured warm AND cold"
if not expected_chaos and not expected_open and ws["paired_windows"] > 0:
    assert ws["warm_iters"] < ws["cold_iters"], \
        f"warm-start must save iterations: {ws['warm_iters']} vs {ws['cold_iters']}"
    assert 0.0 < ws["iter_ratio"] < 1.0 or ws["warm_iters"] == 0
    assert ws["cycle_ratio"] < 1.0, \
        f"modeled recovery cycles must shrink: ratio {ws['cycle_ratio']}"
    assert ws["warm_cycles"] < ws["cold_cycles"]
    # The acceptance bar: warm strictly below cold on all but at most
    # one scenario (>= 5 of 6 on the full roster).
    assert ws["scenarios_measured"] >= 1
    assert ws["scenarios_warm_below_cold"] >= ws["scenarios_measured"] - 1, \
        (f"warm-start regressed on too many scenarios: "
         f"{ws['scenarios_warm_below_cold']}/{ws['scenarios_measured']} "
         f"({ws['per_scenario']})")

# --- fault layer: ledger always closed; injection observable in chaos ---
fa = d["faults"]
assert fa["chaos"] is expected_chaos, \
    f"chaos {fa['chaos']} != expected {expected_chaos}"
assert fa["accounting_closed"], \
    "per-tenant accounting must close: completed + shed + failed == emitted"
injected = (fa["injected_crash"] + fa["injected_stall"]
            + fa["injected_link"] + fa["injected_flip"])
if expected_chaos:
    assert fa["plan"], "a chaos run must record its plan spec"
    assert injected >= 1, "the chaos plan must actually fire"
    assert fa["detected_corruptions"] == fa["injected_flip"], \
        (f"{fa['injected_flip']} flips injected but "
         f"{fa['detected_corruptions']} caught by the fidelity check")
    if fa["injected_crash"] > 0:
        assert fa["instances_down"] >= fa["injected_crash"], \
            "every crashed instance must be taken down by the health machine"
        downs = sum(1 for i in per_inst if i["health"] == "down")
        assert downs >= fa["injected_crash"], \
            f"crashed instances must report down at exit: {per_inst}"
    if fa["failed_over"] > 0:
        assert fa["retries"] >= 1, \
            "failover without retries would mean windows were dropped"
else:
    assert fa["plan"] == "", "no plan may be armed outside chaos mode"
    assert injected == 0, f"faults injected without chaos: {fa}"
    for k in ("detected_timeouts", "detected_disconnects",
              "detected_corruptions", "detected_submit_down",
              "failed_over", "retries", "duplicates_dropped", "exhausted",
              "standby_windows", "instances_down"):
        assert fa[k] == 0, \
            f"healthy-fleet smoke observed faults.{k} = {fa[k]}"

# --- traffic tier: ledgers closed in both modes, live gates when open ---
tr, qos, adm, rt = d["traffic"], d["qos"], d["admission"], d["retune"]
assert tr["open_loop"] is expected_open
assert adm["enabled"] is expected_open and rt["enabled"] is expected_open
assert adm["closes"], "admission ledger must close (vacuously when closed-loop)"
for tier in TIERS:
    tt, q = tr["per_tier"][tier], qos[tier]
    assert tt["offered"] == tt["admitted"] + tt["rejected"], \
        f"{tier}: traffic admission ledger must close"
    # The driver's report and the metrics sink count the same events.
    for k in ("offered", "admitted", "rejected"):
        assert tt[k] == q[k], f"{tier}: traffic.{k} != qos.{k}"
    if q["latency_count"] > 0:
        assert q["p50_ms"] <= q["p99_ms"] <= q["p999_ms"] <= q["max_ms"], \
            f"{tier}: latency percentiles must be ordered"
assert tr["offered_total"] == sum(tr["per_tier"][x]["offered"] for x in TIERS)
assert adm["rejected_total"] == sum(qos[x]["rejected"] for x in TIERS)
# Per-tier completions partition the totals in both modes (closed-loop
# tenants all ride the default standard tier).
assert sum(qos[x]["completed"] for x in TIERS) == t["windows_completed"]
assert sum(qos[x]["shed"] for x in TIERS) == t["windows_shed"]
assert sum(qos[x]["failed"] for x in TIERS) == t["windows_failed"]
assert qos["batch"]["slo_ms"] is None and qos["batch"]["rejected"] == 0, \
    "batch has no SLO and must never be rejected"
if expected_open:
    assert tr["spec"], "an open-loop run must record its arrival spec"
    assert tr["ticks"] >= 1 and tr["offered_total"] >= 1
    for tier in TIERS:
        q = qos[tier]
        assert q["admitted"] == q["completed"] + q["shed"] + q["failed"], \
            f"{tier}: disposition ledger must close under open loop"
    # The acceptance bar: the realtime tier actually served load AND met
    # its SLO — admission control is what makes this hold under a burst.
    assert qos["realtime"]["completed"] > 0, \
        "open-loop smoke must complete realtime windows"
    assert qos["realtime"]["slo_ms"] is not None
    assert qos["realtime"]["slo_met"], \
        (f"realtime p99 {qos['realtime']['p99_ms']:.1f}ms breached its "
         f"{qos['realtime']['slo_ms']}ms SLO")
    # The fixed smoke spec drifts past the threshold by construction, so
    # the online retune must fire and refresh the placement models.
    assert rt["count"] >= 1 and len(rt["events"]) == rt["count"], \
        "the drifting smoke spec must trigger at least one retune"
    assert rt["max_drift"] > rt["drift_threshold"]
    for ev in rt["events"]:
        assert 0 <= ev["tick"] < tr["ticks"]
        assert ev["drift"] > rt["drift_threshold"]
        assert ev["models_refreshed"], \
            "the soak retune hook must re-derive models via the tuner"
else:
    assert tr["spec"] == "" and tr["offered_total"] == 0
    assert adm["rejected_total"] == 0
    assert rt["count"] == 0 and rt["events"] == []

mode = "".join((" +chaos" if expected_chaos else "",
                " +open-loop" if expected_open else ""))
extra = ""
if expected_open:
    extra = (f", rt p99 {qos['realtime']['p99_ms']:.1f}ms"
             f"/{qos['realtime']['slo_ms']}ms SLO, "
             f"{adm['rejected_total']} rejected, {rt['count']} retune(s)")
print(f"BENCH_stream.json OK: {expected_windows} windows on "
      f"{w['backend']}{mode}, {wpm:.1f} windows/Mcycle, "
      f"{p['instances_used']}/{p['instances']} instances used, "
      f"warm/cold iters {ws['warm_iters']}/{ws['cold_iters']}, "
      f"bitwise-verified{extra}")

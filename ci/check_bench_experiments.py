#!/usr/bin/env python3
"""Validate BENCH_experiments.json (paper-reproduction harness gate).

Usage: check_bench_experiments.py [--require-parsed]

Run after `merinda experiments` (or the bench wrappers). Gates:

* schema: bench == "experiments", experiments + summary sections;
* every registry entry present: table1..table8, fig8, cycles — all
  Tables 1-8 and Fig. 8 of the paper are reproduced;
* each experiment: schema_version, source in {parsed, executed}, title,
  non-empty headers/rows, comparisons with ours/paper/ratio/band fields;
* every gated comparison's ours/paper ratio sits inside its declared
  tolerance band (within_band recomputed here, not trusted);
* the summary envelope is self-consistent with the per-experiment data;
* with --require-parsed: zero executions — the committed logs alone
  regenerated everything (the parse-or-execute second-run contract).
"""
import json
import sys

REQUIRED_IDS = [
    "table1", "table2", "table3", "table4", "table5",
    "table6", "table7", "table8", "fig8", "cycles",
]

require_parsed = "--require-parsed" in sys.argv[1:]

d = json.load(open("BENCH_experiments.json"))

# --- schema ---
for key in ("bench", "rows", "speedups", "experiments", "summary"):
    assert key in d, f"missing key: {key}"
assert d["bench"] == "experiments"

exps = d["experiments"]
missing = [i for i in REQUIRED_IDS if i not in exps]
assert not missing, f"missing experiments: {missing}"

total_comparisons = 0
gated = 0
gated_within = 0
executed = 0
for eid, e in sorted(exps.items()):
    for k in ("id", "schema_version", "source", "title", "headers", "rows",
              "comparisons", "notes"):
        assert k in e, f"{eid}: missing {k}"
    assert e["id"] == eid, f"{eid}: id mismatch ({e['id']})"
    assert e["source"] in ("parsed", "executed"), f"{eid}: bad source"
    if e["source"] == "executed":
        executed += 1
    assert e["headers"], f"{eid}: empty headers"
    assert e["rows"], f"{eid}: empty rows"
    for row in e["rows"]:
        assert len(row) == len(e["headers"]), \
            f"{eid}: row arity {len(row)} != headers {len(e['headers'])}"
    for c in e["comparisons"]:
        for k in ("metric", "ours", "paper", "ratio", "band_lo", "band_hi",
                  "gated", "within_band"):
            assert k in c, f"{eid}.{c.get('metric', '?')}: missing {k}"
        assert c["paper"] > 0, f"{eid}.{c['metric']}: paper value must be > 0"
        ratio = c["ours"] / c["paper"]
        assert abs(ratio - c["ratio"]) < 1e-6 * max(1.0, abs(ratio)), \
            f"{eid}.{c['metric']}: recorded ratio {c['ratio']} != {ratio}"
        total_comparisons += 1
        if c["gated"]:
            gated += 1
            inside = c["band_lo"] - 1e-12 <= ratio <= c["band_hi"] + 1e-12
            assert inside == c["within_band"], \
                f"{eid}.{c['metric']}: within_band flag inconsistent"
            assert inside, (
                f"{eid}.{c['metric']}: ratio {ratio:.4f} outside band "
                f"[{c['band_lo']}, {c['band_hi']}] "
                f"(ours {c['ours']}, paper {c['paper']})"
            )
            gated_within += 1

# Fig. 8 must carry its rendered chart.
assert exps["fig8"].get("chart"), "fig8: missing ASCII chart"

# --- summary self-consistency ---
s = d["summary"]
for k in ("experiments", "executed", "parsed", "comparisons",
          "gated_comparisons", "gated_within_band", "all_within_band"):
    assert k in s, f"missing summary.{k}"
assert s["experiments"] == len(exps)
assert s["executed"] + s["parsed"] == s["experiments"]
assert s["executed"] == executed
assert s["comparisons"] == total_comparisons
assert s["gated_comparisons"] == gated == gated_within
assert s["gated_within_band"] == s["gated_comparisons"], \
    "summary reports a gated comparison outside its band"
assert s["all_within_band"] is True

if require_parsed:
    assert s["executed"] == 0, (
        f"--require-parsed: {s['executed']} entries executed; committed "
        "logs must regenerate everything"
    )

print(f"BENCH_experiments.json OK: {len(exps)} experiments "
      f"({s['parsed']} parsed, {s['executed']} executed), "
      f"{gated}/{total_comparisons} comparisons gated, all within band")

#!/usr/bin/env python3
"""Validate BENCH_partition.json (schema + multi-board partitioning gate).

Usage: check_bench_partition.py

Run after `merinda partition`. Every gated value is cycle-model based,
so the gate is machine-independent:

* schema: workload / designs / summary sections with per-design whole,
  split, sweep-counter and chosen entries;
* every design whose whole-graph plan does NOT fit one board must
  become feasible split — more than one part, every part fitting and
  closing timing (splitting is the point of the subsystem);
* the composed end-to-end window never undershoots its slowest member
  board (max-plus composition cannot beat a member pipeline);
* for designs that DO fit one board whole, the chosen plan never
  models more cycles than the whole-graph plan (never-worse gate);
* hops carry real payloads with positive serialization cost, and the
  sweep counters are coherent (evaluated >= feasible >= 1).
"""
import json

d = json.load(open("BENCH_partition.json"))

# --- schema ---
for key in ("bench", "workload", "designs", "summary", "rows", "speedups"):
    assert key in d, f"missing key: {key}"
assert d["bench"] == "partition"
for k in ("window", "slots", "board", "link"):
    assert k in d["workload"], f"missing workload.{k}"
for k in ("designs", "whole_feasible", "split_feasible", "rescued_by_split"):
    assert k in d["summary"], f"missing summary.{k}"

designs = d["designs"]
assert len(designs) == d["summary"]["designs"] >= 1

rescued = 0
whole_feasible = 0
for name, b in designs.items():
    for k in ("whole", "split", "evaluated", "feasible_candidates", "chosen",
              "chosen_window_cycles", "chosen_window_s"):
        assert k in b, f"{name}: missing {k}"
    for k in ("fits", "feasible", "window_cycles", "window_s", "bram18"):
        assert k in b["whole"], f"{name}: missing whole.{k}"
    sp = b["split"]
    for k in ("n_parts", "feasible", "parts", "hops", "end_to_end"):
        assert k in sp, f"{name}: missing split.{k}"
    e2e = sp["end_to_end"]
    for k in ("window_cycles", "interval_cycles", "fill_s", "interval_s",
              "window_s", "reference_clock_mhz"):
        assert k in e2e, f"{name}: missing end_to_end.{k}"
    assert len(sp["parts"]) == sp["n_parts"] >= 1
    assert 1 <= b["feasible_candidates"] <= b["evaluated"]

    # --- the winning plan must actually deploy ---
    assert sp["feasible"] is True, f"{name}: chosen plan must be feasible"
    for p in sp["parts"]:
        assert p["fits"] is True, f"{name}: part {p['board']} must fit"
        assert p["clock_ok"] is True, f"{name}: part {p['board']} timing"
        assert p["window_cycles"] > 0

    # --- oversized designs must be rescued by splitting ---
    if b["whole"]["fits"]:
        whole_feasible += 1
    else:
        rescued += 1
        assert sp["n_parts"] > 1, \
            f"{name}: does not fit one board, so it must split"
        assert len(sp["hops"]) >= 1, f"{name}: a real split has cut traffic"

    # --- composition law: end to end dominates the slowest member ---
    member_max = max(p["window_cycles"] for p in sp["parts"])
    assert e2e["window_cycles"] + 2 >= member_max, \
        f"{name}: end-to-end {e2e['window_cycles']} beats a member {member_max}"
    assert e2e["window_s"] >= e2e["fill_s"] > 0
    assert e2e["interval_s"] > 0 and e2e["reference_clock_mhz"] > 0

    # --- hops carry real link traffic ---
    for h in sp["hops"]:
        assert h["bytes_per_item"] > 0 and h["elems"] > 0
        assert h["serialize_s"] > 0 and h["latency_s"] > 0
        assert h["from_part"] < h["to_part"], f"{name}: hop must point forward"

    # --- never worse than the whole-graph plan where it exists ---
    if b["whole"]["feasible"]:
        assert b["chosen_window_cycles"] <= b["whole"]["window_cycles"], \
            f"{name}: chose {b['chosen_window_cycles']} cycles over whole " \
            f"{b['whole']['window_cycles']}"
        assert b["chosen_window_s"] <= b["whole"]["window_s"] + 1e-12
    assert b["chosen"] in ("whole", "split")
    if b["chosen"] == "whole":
        assert sp["n_parts"] == 1

s = d["summary"]
assert s["whole_feasible"] == whole_feasible
assert s["rescued_by_split"] == rescued
assert rescued >= 1, "the report must include at least one rescued design"
assert whole_feasible >= 1, "the report must include a never-worse row"
assert s["split_feasible"] == len(designs), \
    "every report design must end up deployable after the sweep"

print(f"BENCH_partition.json OK: {len(designs)} designs, "
      f"{rescued} rescued by splitting, {whole_feasible} fit whole")

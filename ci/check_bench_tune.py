#!/usr/bin/env python3
"""Validate BENCH_tune.json (schema + tuning-actually-helps gate).

Usage: check_bench_tune.py

Run after `merinda tune`. Every gated value is cycle-model or
resource-model based, so the gate is machine-independent:

* schema: workload / boards / summary sections with per-board default,
  tuned, ratio and Pareto entries;
* every board gets a *fitting* tuned config with a BRAM
  double-buffering budget of at least one window;
* tuned-vs-default cycle ratio >= 1.0 on every board (tuning never
  regresses the shipped design) and > 1.0 on at least one (the search
  finds a real win — the sequential PYNQ gains DATAFLOW);
* each Pareto front is non-empty, fastest-first, and strictly
  power-decreasing along the front.
"""
import json

d = json.load(open("BENCH_tune.json"))

# --- schema ---
for key in ("bench", "workload", "boards", "summary", "rows", "speedups"):
    assert key in d, f"missing key: {key}"
assert d["bench"] == "tune"
for k in ("window", "input", "hidden", "xdim", "udim", "theta_len", "boards"):
    assert k in d["workload"], f"missing workload.{k}"
for k in ("boards", "boards_fitting", "boards_improved", "min_ratio_cycles",
          "max_ratio_cycles"):
    assert k in d["summary"], f"missing summary.{k}"

boards = d["boards"]
assert len(boards) == d["workload"]["boards"] >= 1

improved = 0
for name, b in boards.items():
    for k in ("default", "tuned", "ratio_cycles", "pareto_size", "evaluated",
              "feasible", "pareto"):
        assert k in b, f"{name}: missing {k}"
    for k in ("window_cycles", "window_s", "power_w"):
        assert k in b["default"], f"{name}: missing default.{k}"
    t = b["tuned"]
    for k in ("window_cycles", "window_s", "power_w", "energy_per_window_j",
              "clock_mhz", "unroll", "banks", "reshape", "dataflow",
              "stage_map", "format", "max_outstanding", "fits"):
        assert k in t, f"{name}: missing tuned.{k}"

    # --- every board must get a config that actually deploys ---
    assert t["fits"] is True, f"{name}: tuned design must fit the device"
    assert t["max_outstanding"] >= 1, \
        f"{name}: tuned design must leave BRAM double-buffer headroom"
    assert t["window_cycles"] > 0 and t["window_s"] > 0

    # --- tuning never regresses, and the ratio is self-consistent ---
    ratio = b["ratio_cycles"]
    assert ratio >= 1.0, f"{name}: tuned slower than default ({ratio})"
    expect = b["default"]["window_cycles"] / t["window_cycles"]
    assert abs(ratio - expect) < 1e-6, \
        f"{name}: ratio {ratio} != cycles ratio {expect}"
    if ratio > 1.0:
        improved += 1

    # --- Pareto front: non-empty, fastest first, power strictly falls ---
    front = b["pareto"]
    assert len(front) == b["pareto_size"] >= 1
    assert 1 <= b["feasible"] <= b["evaluated"]
    for i in range(1, len(front)):
        assert front[i - 1]["window_s"] <= front[i]["window_s"], \
            f"{name}: Pareto front not fastest-first at {i}"
        assert front[i - 1]["power_w"] > front[i]["power_w"], \
            f"{name}: Pareto point {i} does not buy power back"

assert improved >= 1, "tuning must strictly improve at least one board"
s = d["summary"]
assert s["boards"] == len(boards)
assert s["boards_fitting"] == len(boards), "every board must get a fitting config"
assert s["boards_improved"] == improved
assert s["min_ratio_cycles"] >= 1.0 and s["max_ratio_cycles"] > 1.0

print(f"BENCH_tune.json OK: {len(boards)} boards tuned, {improved} improved, "
      f"cycle ratio {s['min_ratio_cycles']:.2f}x..{s['max_ratio_cycles']:.2f}x")

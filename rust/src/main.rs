//! MERINDA command-line interface (L3 leader entrypoint).
//!
//! Subcommands:
//!   info                       — artifact + device summary
//!   recover  --system S --method M   — run one recovery end to end
//!   train    --system S --steps N    — train the neural flow via PJRT
//!   simulate --config C        — FPGA accelerator report (table-8 configs)
//!   serve    --requests N      — run the streaming service demo
//!   soak     --tenants N --fleet M — multi-tenant streaming workload on a fleet
//!       (--open-loop --arrivals <spec> drives the QoS traffic tier open-loop)
//!   tune     [--window N]      — design-space autotuner, writes BENCH_tune.json
//!   partition [--window N]     — multi-board graph partitioner, writes
//!       BENCH_partition.json
//!   table <1|2|3|4|5|6|7|8|fig8> — regenerate a paper table/figure
//!   experiments [--only ids] [--parse-only|--force] — parse-or-execute
//!       runner over every paper table/figure, writes BENCH_experiments.json
//!
//! `cargo run --release -- <subcommand> [flags]`

use merinda::util::cli;

mod commands {
    pub mod experiments;
    pub mod partition;
    pub mod recover;
    pub mod serve;
    pub mod simulate;
    pub mod soak;
    pub mod tables;
    pub mod train;
    pub mod tune;
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(
        &argv,
        &[
            "system", "method", "steps", "config", "requests", "seed", "samples", "dt", "lr",
            "artifacts", "out", "workers", "backend", "fmt", "tenants", "window", "stride",
            "queue", "shed", "fleet", "chaos", "deadline-ms", "only", "logdir", "arrivals",
            "backlog", "slo-rt-ms", "slo-std-ms", "drift-threshold",
        ],
    );
    let result = match args.subcommand() {
        Some("info") => commands::tables::info(&args),
        Some("experiments") => commands::experiments::run(&args),
        Some("recover") => commands::recover::run(&args),
        Some("train") => commands::train::run(&args),
        Some("simulate") => commands::simulate::run(&args),
        Some("serve") => commands::serve::run(&args),
        Some("soak") => commands::soak::run(&args),
        Some("tune") => commands::tune::run(&args),
        Some("partition") => commands::partition::run(&args),
        Some("table") => commands::tables::run(&args),
        _ => {
            eprintln!(
                "usage: merinda <info|recover|train|simulate|serve|soak|tune|partition|table|experiments> [--flags]\n\
                 examples:\n\
                 \x20 merinda recover --system lotka --method merinda\n\
                 \x20 merinda train --system aid --steps 300\n\
                 \x20 merinda simulate --config concurrent\n\
                 \x20 merinda serve --requests 256 --backend fixed --fmt q8.8\n\
                 \x20 merinda soak --tenants 6 --samples 400 --backend native --fleet 3\n\
                 \x20 merinda soak --fleet 3 --tuned\n\
                 \x20 merinda soak --fleet 3 --chaos crash:2@6,flip:1@2 --deadline-ms 250\n\
                 \x20 merinda soak --open-loop --arrivals poisson:3,tenants:6,mix:1/2/1,ticks:120,seed:7,burst:40+40*4@rt\n\
                 \x20 merinda tune --window 64\n\
                 \x20 merinda partition --window 64\n\
                 \x20 merinda table 8\n\
                 \x20 merinda experiments --only table8,fig8\n\
                 \x20 merinda experiments --parse-only"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

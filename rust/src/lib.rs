//! MERINDA: Model Recovery in Dynamic Architecture.
//!
//! Reproduction of "Hardware Software Optimizations for Fast Model Recovery
//! on Reconfigurable Architectures" (Xu, Banerjee, Gupta — 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas GRU-cell / fixed-point
//!   kernels, the compute hot-spot, validated against a pure-jnp oracle.
//! * **L2** (`python/compile/model.py`) — the MERINDA model (GRU → dense →
//!   coefficient head → RK4 ODE loss) and the LTC baseline, AOT-lowered to
//!   HLO text once at build time (`make artifacts`).
//! * **L3** (this crate) — the Rust coordinator: PJRT runtime that loads the
//!   artifacts, a streaming training/serving coordinator, the cycle-level
//!   FPGA dataflow simulator that reproduces the paper's hardware study, the
//!   model-recovery algorithm suite (SINDy, ridge/STLSQ, ODE solvers) and
//!   the dynamical-system case studies.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.

pub mod coordinator;
pub mod fpga;
pub mod mr;
pub mod platform;
pub mod report;
pub mod runtime;
pub mod systems;
pub mod util;

pub use util::error::{Error, Result};

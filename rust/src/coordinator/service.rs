//! The recovery service: request router → shared queue → N sharded
//! executor workers.
//!
//! Each worker thread owns its own inference backend instance (the PJRT
//! client is not Send, so backends are constructed *inside* the worker
//! threads by a shared factory); clients submit into one bounded queue and
//! workers drain it into per-worker dynamic batches. Throughput scales
//! with `ServiceConfig::workers` while FIFO pop order keeps per-stream
//! latency fair. `MockBackend` lets the full pipeline be tested without
//! artifacts; `NativeBackend` (see `coordinator::native`) serves real
//! recovery traffic with no artifacts at all.

use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::{Error, Result};

use super::batcher::{pad_rows, BatcherConfig, PendingBatch};
use super::metrics::Metrics;

/// Lock that survives a poisoned mutex: a worker panicking mid-batch must
/// read as *that instance died*, not take the whole coordinator down with
/// a cascading panic. The queue state is a plain FIFO + flag, so the
/// inner value is always coherent even after a panic.
fn lock_queue(shared: &Shared) -> std::sync::MutexGuard<'_, QueueState> {
    shared
        .state
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// One inference request: a (seq, xdim) window + (seq, udim) inputs.
#[derive(Clone, Debug)]
pub struct RecoveryRequest {
    pub id: u64,
    pub y: Vec<f32>,
    pub u: Vec<f32>,
}

/// The response: estimated (xdim × plib) coefficients for the window.
#[derive(Clone, Debug)]
pub struct RecoveryResponse {
    pub id: u64,
    pub theta: Vec<f32>,
    pub latency: Duration,
}

/// Anything that can run a fixed-size forward batch.
///
/// `y`: (B, K, X) flattened; `u`: (B, K, U) flattened. Returns (B, X*P)
/// per-window coefficient estimates, flattened.
pub trait InferenceBackend {
    fn batch(&self) -> usize;
    fn theta_len(&self) -> usize;
    fn window_y_len(&self) -> usize;
    fn window_u_len(&self) -> usize;
    fn forward_batch(&self, y: &[f32], u: &[f32]) -> Result<Vec<f32>>;
}

/// PJRT-backed backend using the `merinda_forward` artifact.
pub struct PjrtBackend {
    rt: crate::runtime::Runtime,
    exe: Arc<crate::runtime::Executable>,
    params: Vec<Vec<f32>>,
}

impl PjrtBackend {
    /// Load artifacts from `dir` with parameters (e.g. a trained
    /// `TrainState`'s params); random params if `None`.
    pub fn new(
        dir: impl AsRef<std::path::Path>,
        params: Option<Vec<Vec<f32>>>,
        seed: u64,
    ) -> Result<PjrtBackend> {
        let rt = crate::runtime::Runtime::new(dir)?;
        let exe = rt.load("merinda_forward")?;
        let params = match params {
            Some(p) => p,
            None => {
                let dims = rt.manifest.dims.clone();
                let mut rng = crate::util::Prng::new(seed);
                crate::mr::train::TrainState::init(&dims, &mut rng).params
            }
        };
        Ok(PjrtBackend { rt, exe, params })
    }
}

impl InferenceBackend for PjrtBackend {
    fn batch(&self) -> usize {
        self.rt.manifest.dims.batch
    }

    fn theta_len(&self) -> usize {
        let d = &self.rt.manifest.dims;
        d.xdim * d.plib
    }

    fn window_y_len(&self) -> usize {
        let d = &self.rt.manifest.dims;
        d.seq * d.xdim
    }

    fn window_u_len(&self) -> usize {
        let d = &self.rt.manifest.dims;
        d.seq * d.udim
    }

    fn forward_batch(&self, y: &[f32], u: &[f32]) -> Result<Vec<f32>> {
        let mut args: Vec<&[f32]> = self.params.iter().map(|p| p.as_slice()).collect();
        args.push(y);
        args.push(u);
        let out = self.exe.run_f32(&args)?;
        Ok(out.into_iter().next().unwrap())
    }
}

/// Deterministic mock: theta[i] = mean(y) + i (tests the routing fabric).
pub struct MockBackend {
    pub batch: usize,
    pub theta_len: usize,
    pub window_y_len: usize,
    pub window_u_len: usize,
    /// Artificial per-batch service time.
    pub delay: Duration,
}

impl Default for MockBackend {
    fn default() -> Self {
        MockBackend {
            batch: 8,
            theta_len: 45,
            window_y_len: 64 * 3,
            window_u_len: 64,
            delay: Duration::ZERO,
        }
    }
}

impl InferenceBackend for MockBackend {
    fn batch(&self) -> usize {
        self.batch
    }
    fn theta_len(&self) -> usize {
        self.theta_len
    }
    fn window_y_len(&self) -> usize {
        self.window_y_len
    }
    fn window_u_len(&self) -> usize {
        self.window_u_len
    }

    fn forward_batch(&self, y: &[f32], _u: &[f32]) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = vec![0.0f32; self.batch * self.theta_len];
        for b in 0..self.batch {
            let win = &y[b * self.window_y_len..(b + 1) * self.window_y_len];
            let mean: f32 = win.iter().sum::<f32>() / win.len() as f32;
            for i in 0..self.theta_len {
                out[b * self.theta_len + i] = mean + i as f32;
            }
        }
        Ok(out)
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    /// Bounded submission queue depth (backpressure).
    pub queue_depth: usize,
    /// Number of sharded executor workers, each owning one backend
    /// instance. Throughput scales with workers as long as the backend is
    /// the bottleneck.
    pub workers: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batcher: BatcherConfig::default(),
            queue_depth: 256,
            workers: 1,
        }
    }
}

struct InFlight {
    req: RecoveryRequest,
    t0: Instant,
    resp: SyncSender<RecoveryResponse>,
}

/// Shared submission queue: bounded FIFO + shutdown flag.
struct QueueState {
    items: VecDeque<InFlight>,
    open: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// A running recovery service.
pub struct Service {
    shared: Arc<Shared>,
    queue_depth: usize,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start the service with a backend factory. The factory runs on each
    /// executor thread, so non-Send backends (PJRT) are fine; it must be
    /// callable once per worker.
    ///
    /// # Example
    ///
    /// ```
    /// use merinda::coordinator::{MockBackend, RecoveryRequest, Service, ServiceConfig};
    ///
    /// let svc = Service::start(ServiceConfig::default(), MockBackend::default);
    /// let resp = svc
    ///     .recover(RecoveryRequest {
    ///         id: 7,
    ///         y: vec![1.5; 64 * 3],
    ///         u: vec![0.0; 64],
    ///     })
    ///     .unwrap();
    /// assert_eq!(resp.id, 7);
    /// assert_eq!(resp.theta.len(), 45);
    /// ```
    pub fn start<B, F>(cfg: ServiceConfig, make_backend: F) -> Service
    where
        B: InferenceBackend + 'static,
        F: Fn() -> B + Send + Sync + 'static,
    {
        Service::start_with_metrics(cfg, make_backend, Arc::new(Metrics::new()))
    }

    /// Like [`Service::start`], but recording into a caller-provided
    /// [`Metrics`] sink. A multi-instance fleet passes one shared sink to
    /// every instance's service so latency, batching and per-instance
    /// placement counters aggregate into a single snapshot.
    pub fn start_with_metrics<B, F>(
        cfg: ServiceConfig,
        make_backend: F,
        metrics: Arc<Metrics>,
    ) -> Service
    where
        B: InferenceBackend + 'static,
        F: Fn() -> B + Send + Sync + 'static,
    {
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                open: true,
            }),
            cv: Condvar::new(),
        });
        let factory = Arc::new(make_backend);
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let sh = shared.clone();
            let m = metrics.clone();
            let f = factory.clone();
            workers.push(std::thread::spawn(move || worker_loop(sh, cfg, f(), m)));
        }
        Service {
            shared,
            queue_depth: cfg.queue_depth,
            metrics,
            workers,
        }
    }

    /// Submit a request; returns a receiver for the response. Fails fast
    /// with a typed [`Error::Overloaded`] if the queue is full, so
    /// callers (the streaming layer in particular) can tell transient
    /// backpressure apart from permanent failures and make an explicit
    /// shed-vs-retry decision. A shut-down or killed service reports
    /// [`Error::ServiceDown`] instead — retrying *here* would never
    /// succeed, but the work can fail over to a healthy sibling.
    pub fn submit(&self, req: RecoveryRequest) -> Result<Receiver<RecoveryResponse>> {
        self.try_submit(req).map_err(|(e, _)| e)
    }

    /// Like [`Service::submit`], but hands the request back on rejection
    /// so retrying callers keep the payload without cloning it per
    /// attempt (the streaming pump holds rejected windows this way).
    #[allow(clippy::result_large_err)]
    pub fn try_submit(
        &self,
        req: RecoveryRequest,
    ) -> std::result::Result<Receiver<RecoveryResponse>, (Error, RecoveryRequest)> {
        let (rtx, rrx) = sync_channel(1);
        self.metrics.on_submit();
        let depth = {
            let mut q = lock_queue(&self.shared);
            if !q.open {
                drop(q);
                self.metrics.on_reject();
                return Err((Error::service_down("service is shut down"), req));
            }
            if q.items.len() >= self.queue_depth {
                let depth = q.items.len();
                drop(q);
                self.metrics.on_reject();
                return Err((Error::Overloaded { depth }, req));
            }
            q.items.push_back(InFlight {
                req,
                t0: Instant::now(),
                resp: rtx,
            });
            q.items.len()
        };
        self.metrics.on_queue_depth(depth);
        self.shared.cv.notify_one();
        Ok(rrx)
    }

    /// Submit and wait.
    pub fn recover(&self, req: RecoveryRequest) -> Result<RecoveryResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| Error::service_down("service shut down mid-request"))
    }

    /// Hard-kill the instance: close the queue AND drop every queued
    /// request without serving it, simulating an accelerator crash.
    ///
    /// Unlike `Drop` (graceful shutdown — workers drain the remaining
    /// queue first), callers holding response receivers for queued work
    /// observe a disconnected channel, exactly what a host sees when a
    /// board dies mid-window. In-flight batches already popped by a
    /// worker may still complete; that race is faithful to real crashes
    /// and the coordinator's dedupe handles late arrivals.
    pub fn kill(&self) {
        {
            let mut q = lock_queue(&self.shared);
            q.open = false;
            q.items.clear();
        }
        self.shared.cv.notify_all();
    }

    /// Submit many requests up front (so batches fill) and wait for all
    /// accepted ones, preserving submission order. Requests rejected by
    /// backpressure — or dropped by a failing backend — are simply absent
    /// from the result; callers needing per-request rejection handling
    /// use [`Service::submit`].
    pub fn recover_many(&self, reqs: Vec<RecoveryRequest>) -> Vec<RecoveryResponse> {
        let rxs: Vec<_> = reqs
            .into_iter()
            .filter_map(|req| self.submit(req).ok())
            .collect();
        rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        {
            let mut q = lock_queue(&self.shared);
            q.open = false;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop<B: InferenceBackend>(
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    backend: B,
    metrics: Arc<Metrics>,
) {
    let cap = backend.batch().max(1);
    let mut pending: PendingBatch<InFlight> = PendingBatch::new(BatcherConfig {
        batch: cap,
        ..cfg.batcher
    });
    loop {
        let mut flush_now = false;
        let mut exit = false;
        {
            let mut q = lock_queue(&shared);
            loop {
                // Drain queued requests into the local batch.
                while pending.len() < cap {
                    match q.items.pop_front() {
                        Some(it) => {
                            pending.push(it);
                        }
                        None => break,
                    }
                }
                if pending.len() >= cap {
                    flush_now = true;
                    break;
                }
                if !q.open {
                    // Shutting down: flush what we hold, exit once drained.
                    exit = q.items.is_empty();
                    flush_now = !pending.is_empty();
                    if exit || flush_now {
                        break;
                    }
                }
                let now = Instant::now();
                if pending.is_empty() {
                    q = shared
                        .cv
                        .wait(q)
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                } else if pending.should_flush(now) {
                    flush_now = true;
                    break;
                } else {
                    let timeout = pending
                        .time_to_deadline(now)
                        .unwrap_or(Duration::from_millis(50));
                    let (guard, _) = shared
                        .cv
                        .wait_timeout(q, timeout)
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                    q = guard;
                }
            }
        }
        if flush_now {
            flush(&backend, &mut pending, &metrics);
        }
        if exit && pending.is_empty() {
            return;
        }
    }
}

fn flush<B: InferenceBackend>(
    backend: &B,
    pending: &mut PendingBatch<InFlight>,
    metrics: &Metrics,
) {
    let items = pending.take();
    if items.is_empty() {
        return;
    }
    let ylen = backend.window_y_len();
    let ulen = backend.window_u_len();
    let mut y = Vec::with_capacity(items.len() * ylen);
    let mut u = Vec::with_capacity(items.len() * ulen);
    for it in &items {
        // Shape guard: malformed requests answered with zeros rather than
        // poisoning the whole batch.
        if it.req.y.len() == ylen && it.req.u.len() == ulen {
            y.extend_from_slice(&it.req.y);
            u.extend_from_slice(&it.req.u);
        } else {
            y.extend(std::iter::repeat(0.0).take(ylen));
            u.extend(std::iter::repeat(0.0).take(ulen));
        }
    }
    let (y, real) = pad_rows(y, ylen, backend.batch());
    let (u, _) = pad_rows(u, ulen, backend.batch());
    metrics.on_batch(real as u64);

    match backend.forward_batch(&y, &u) {
        Ok(thetas) => {
            let tl = backend.theta_len();
            for (b, it) in items.into_iter().enumerate() {
                let theta = thetas[b * tl..(b + 1) * tl].to_vec();
                let latency = it.t0.elapsed();
                metrics.on_complete(latency);
                let _ = it.resp.send(RecoveryResponse {
                    id: it.req.id,
                    theta,
                    latency,
                });
            }
        }
        Err(_) => {
            // Drop responders; callers observe a closed channel.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_req(id: u64, fill: f32) -> RecoveryRequest {
        RecoveryRequest {
            id,
            y: vec![fill; 64 * 3],
            u: vec![0.0; 64],
        }
    }

    #[test]
    fn single_request_round_trip() {
        let svc = Service::start(ServiceConfig::default(), MockBackend::default);
        let resp = svc.recover(mk_req(7, 1.5)).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.theta.len(), 45);
        // Mock: theta[i] = mean + i = 1.5 + i.
        assert!((resp.theta[0] - 1.5).abs() < 1e-6);
        assert!((resp.theta[44] - 45.5).abs() < 1e-6);
    }

    #[test]
    fn batch_of_eight_single_flush() {
        let svc = Service::start(ServiceConfig::default(), MockBackend::default);
        let rxs: Vec<_> = (0..8)
            .map(|i| svc.submit(mk_req(i, i as f32)).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, i as u64);
            assert!((r.theta[0] - i as f32).abs() < 1e-6, "demux mismatch");
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.completed, 8);
        assert_eq!(s.batches, 1, "should have been one full batch");
        assert!((s.mean_batch_occupancy - 8.0).abs() < 1e-12);
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let cfg = ServiceConfig {
            batcher: BatcherConfig {
                batch: 8,
                max_wait: Duration::from_millis(2),
            },
            ..Default::default()
        };
        let svc = Service::start(cfg, MockBackend::default);
        let resp = svc.recover(mk_req(1, 0.5)).unwrap();
        assert_eq!(resp.id, 1);
        let s = svc.metrics.snapshot();
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_occupancy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Slow backend + tiny queue: the second/third submits must fail.
        let cfg = ServiceConfig {
            queue_depth: 1,
            batcher: BatcherConfig {
                batch: 1,
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
        };
        let svc = Service::start(cfg, || MockBackend {
            batch: 1,
            delay: Duration::from_millis(50),
            ..Default::default()
        });
        let mut rejected = 0;
        let mut kept = Vec::new();
        for i in 0..6 {
            match svc.submit(mk_req(i, 0.0)) {
                Ok(rx) => kept.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in kept {
            let _ = rx.recv();
        }
    }

    #[test]
    fn overload_error_is_typed_with_depth() {
        // Regression: a full queue must surface as `Error::Overloaded`
        // (shed-vs-fail decisions key on it), not a stringly config error.
        let cfg = ServiceConfig {
            queue_depth: 2,
            batcher: BatcherConfig {
                batch: 1,
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
        };
        let svc = Service::start(cfg, || MockBackend {
            batch: 1,
            delay: Duration::from_millis(50),
            ..Default::default()
        });
        let mut kept = Vec::new();
        let mut saw_overload = false;
        for i in 0..12 {
            match svc.submit(mk_req(i, 0.0)) {
                Ok(rx) => kept.push(rx),
                Err(e) => {
                    assert!(e.is_overload(), "expected Overloaded, got: {e}");
                    match e {
                        Error::Overloaded { depth } => assert!((1..=2).contains(&depth)),
                        other => panic!("expected Overloaded variant, got {other:?}"),
                    }
                    saw_overload = true;
                }
            }
        }
        assert!(saw_overload, "queue of depth 2 should have overflowed");
        for rx in kept {
            let _ = rx.recv();
        }
        let s = svc.metrics.snapshot();
        assert!(s.rejected > 0);
        assert!((1..=2).contains(&s.queue_depth_max));
    }

    #[test]
    fn try_submit_returns_payload_on_overload() {
        let cfg = ServiceConfig {
            queue_depth: 1,
            batcher: BatcherConfig {
                batch: 1,
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
        };
        let svc = Service::start(cfg, || MockBackend {
            batch: 1,
            delay: Duration::from_millis(50),
            ..Default::default()
        });
        let mut kept = Vec::new();
        let mut recovered_payload = false;
        for i in 0..12 {
            match svc.try_submit(mk_req(i, 1.25)) {
                Ok(rx) => kept.push(rx),
                Err((e, back)) => {
                    assert!(e.is_overload());
                    // The rejected request must come back intact for a
                    // clone-free retry.
                    assert_eq!(back.id, i);
                    assert_eq!(back.y.len(), 64 * 3);
                    assert!((back.y[0] - 1.25).abs() < 1e-6);
                    recovered_payload = true;
                }
            }
        }
        assert!(recovered_payload, "expected at least one rejection");
        for rx in kept {
            let _ = rx.recv();
        }
    }

    #[test]
    fn malformed_request_gets_zero_theta_not_poisoned_batch() {
        let svc = Service::start(ServiceConfig::default(), MockBackend::default);
        let bad = RecoveryRequest {
            id: 9,
            y: vec![1.0; 3], // wrong length
            u: vec![],
        };
        let good = mk_req(10, 2.0);
        let rx_bad = svc.submit(bad).unwrap();
        let rx_good = svc.submit(good).unwrap();
        let rb = rx_bad.recv().unwrap();
        let rg = rx_good.recv().unwrap();
        assert!((rb.theta[0] - 0.0).abs() < 1e-6);
        assert!((rg.theta[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn recover_many_preserves_submission_order() {
        let svc = Service::start(ServiceConfig::default(), MockBackend::default);
        let reqs: Vec<_> = (0..24).map(|i| mk_req(i, i as f32)).collect();
        let resps = svc.recover_many(reqs);
        assert_eq!(resps.len(), 24);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!((r.theta[0] - i as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn throughput_many_requests() {
        let svc = Service::start(ServiceConfig::default(), MockBackend::default);
        let rxs: Vec<_> = (0..100)
            .map(|i| svc.submit(mk_req(i, 0.1)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.completed, 100);
        assert!(s.batches >= 13); // ≥ ceil(100/8)
        assert!(s.latency.p50_ms <= s.latency.p99_ms);
    }

    #[test]
    fn fleet_services_share_one_metrics_sink() {
        let sink = Arc::new(Metrics::new());
        let a = Service::start_with_metrics(
            ServiceConfig::default(),
            MockBackend::default,
            sink.clone(),
        );
        let b = Service::start_with_metrics(
            ServiceConfig::default(),
            MockBackend::default,
            sink.clone(),
        );
        a.recover(mk_req(1, 0.5)).unwrap();
        b.recover(mk_req(2, 0.5)).unwrap();
        let s = sink.snapshot();
        assert_eq!(s.submitted, 2, "both services must record into the sink");
        assert_eq!(s.completed, 2);
        assert!(Arc::ptr_eq(&a.metrics, &sink) && Arc::ptr_eq(&b.metrics, &sink));
    }

    #[test]
    fn killed_service_rejects_with_service_down() {
        let svc = Service::start(ServiceConfig::default(), MockBackend::default);
        svc.kill();
        match svc.submit(mk_req(1, 0.0)) {
            Err(e) => assert!(e.is_service_down(), "expected ServiceDown, got: {e}"),
            Ok(_) => panic!("killed service must reject submissions"),
        }
    }

    #[test]
    fn kill_drops_queued_work_with_disconnected_channels() {
        // A crash must strand queued windows (callers see Disconnected),
        // unlike graceful Drop which drains the queue first.
        let cfg = ServiceConfig {
            queue_depth: 64,
            workers: 1,
            batcher: BatcherConfig {
                batch: 1,
                max_wait: Duration::from_millis(1),
            },
        };
        let svc = Service::start(cfg, || MockBackend {
            batch: 1,
            delay: Duration::from_millis(30),
            ..Default::default()
        });
        let rxs: Vec<_> = (0..16)
            .map(|i| svc.submit(mk_req(i, 0.0)).unwrap())
            .collect();
        svc.kill();
        let mut disconnected = 0;
        for rx in rxs {
            if rx.recv().is_err() {
                disconnected += 1;
            }
        }
        assert!(
            disconnected > 0,
            "killing a loaded service must strand queued windows"
        );
    }

    #[test]
    fn multi_worker_completes_all_requests() {
        let cfg = ServiceConfig {
            workers: 4,
            ..Default::default()
        };
        let svc = Service::start(cfg, MockBackend::default);
        let rxs: Vec<_> = (0..64)
            .map(|i| svc.submit(mk_req(i, i as f32)).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, i as u64, "response routed to wrong caller");
            assert!((r.theta[0] - i as f32).abs() < 1e-6);
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.completed, 64);
        assert!(s.batches >= 8);
    }

    #[test]
    fn multi_worker_overlaps_slow_batches() {
        // With a sleep-bound backend, 4 workers should overlap batches.
        // The assertion is deliberately weak (strictly faster, not ≥2×)
        // to stay robust on loaded CI machines; the quantitative speedup
        // is tracked by benches/hotpath.rs (`coordinator_round_trip`).
        let run = |workers: usize| -> Duration {
            let cfg = ServiceConfig {
                workers,
                batcher: BatcherConfig {
                    batch: 8,
                    max_wait: Duration::from_millis(2),
                },
                ..Default::default()
            };
            let svc = Service::start(cfg, || MockBackend {
                delay: Duration::from_millis(10),
                ..Default::default()
            });
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..32)
                .map(|i| svc.submit(mk_req(i, 0.0)).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            t0.elapsed()
        };
        let serial = run(1);
        let sharded = run(4);
        assert!(
            sharded < serial,
            "sharded {sharded:?} not faster than serial {serial:?}"
        );
    }
}

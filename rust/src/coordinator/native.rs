//! Native in-process inference backend: batched GRU + dense head.
//!
//! Serves recovery requests through the batch-major native GRU forward
//! (`mr::linalg::gru_forward_batch`) and the batched ReLU dense head —
//! the same math as the AOT `merinda_forward` artifact (L2
//! `python/compile/model.py`: GRU over `[Y | U]`, final hidden state,
//! two-layer ReLU MLP to the Θ estimates), but with **no PJRT runtime and
//! no `artifacts/` directory required**. This is the serving path for
//! environments where only the Rust binary ships.

use crate::mr::dense::DenseHead;
use crate::mr::gru::{GruCell, GruParams};
use crate::mr::linalg::{dense_head_batch, gru_forward_batch, PackedGru};
use crate::util::{Error, Prng, Result};

use super::service::InferenceBackend;

/// Canonical model dimensions (mirrors `python/compile/model.py`).
pub const NATIVE_XDIM: usize = 3;
pub const NATIVE_UDIM: usize = 1;
pub const NATIVE_PLIB: usize = 15;
pub const NATIVE_HID: usize = 32;
pub const NATIVE_DENSE: usize = 48;
pub const NATIVE_SEQ: usize = 64;

/// A self-contained native serving backend (clonable: each service worker
/// can hold its own copy).
#[derive(Clone, Debug)]
pub struct NativeBackend {
    batch: usize,
    seq: usize,
    xdim: usize,
    udim: usize,
    /// Scalar-layout GRU parameters (the reference weights).
    pub gru: GruParams,
    /// Serving-layout packed weights.
    packed: PackedGru,
    /// Θ head (hidden → dense → xdim·plib).
    pub head: DenseHead,
}

impl NativeBackend {
    /// Random-weight backend at the canonical dims (useful for serving
    /// smoke tests and benches; real deployments use `from_parts` with
    /// trained weights).
    pub fn new(batch: usize, seed: u64) -> NativeBackend {
        let mut rng = Prng::new(seed);
        let io = NATIVE_XDIM + NATIVE_UDIM;
        let gru = GruParams::random(io, NATIVE_HID, &mut rng, 0.3);
        let head = DenseHead::random(
            NATIVE_HID,
            NATIVE_DENSE,
            NATIVE_XDIM * NATIVE_PLIB,
            &mut rng,
        );
        NativeBackend::from_parts(gru, head, batch, NATIVE_SEQ, NATIVE_XDIM, NATIVE_UDIM)
            .expect("canonical dims are consistent")
    }

    /// Build from explicit weights (e.g. converted from a trained
    /// `TrainState`).
    pub fn from_parts(
        gru: GruParams,
        head: DenseHead,
        batch: usize,
        seq: usize,
        xdim: usize,
        udim: usize,
    ) -> Result<NativeBackend> {
        if gru.input != xdim + udim {
            return Err(Error::Shape {
                expected: format!("gru input {}", xdim + udim),
                got: format!("{}", gru.input),
            });
        }
        if head.input != gru.hidden {
            return Err(Error::Shape {
                expected: format!("head input {}", gru.hidden),
                got: format!("{}", head.input),
            });
        }
        if batch == 0 || seq == 0 {
            return Err(Error::config("batch and seq must be nonzero"));
        }
        let packed = PackedGru::new(&gru);
        Ok(NativeBackend {
            batch,
            seq,
            xdim,
            udim,
            gru,
            packed,
            head,
        })
    }

    /// Window length (time steps per request).
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// State dimension of each observation row.
    pub fn xdim(&self) -> usize {
        self.xdim
    }

    /// Control-input dimension.
    pub fn udim(&self) -> usize {
        self.udim
    }

    /// Scalar reference for a single window (the test oracle): one-sample
    /// GRU chain + scalar dense head on the interleaved `[y_t | u_t]` rows.
    pub fn forward_window_scalar(&self, y: &[f32], u: &[f32]) -> Vec<f32> {
        assert_eq!(y.len(), self.seq * self.xdim);
        assert_eq!(u.len(), self.seq * self.udim);
        let i_sz = self.xdim + self.udim;
        let mut yu = vec![0.0f32; self.seq * i_sz];
        for t in 0..self.seq {
            yu[t * i_sz..t * i_sz + self.xdim]
                .copy_from_slice(&y[t * self.xdim..(t + 1) * self.xdim]);
            yu[t * i_sz + self.xdim..(t + 1) * i_sz]
                .copy_from_slice(&u[t * self.udim..(t + 1) * self.udim]);
        }
        let h = GruCell::new(self.gru.clone()).run(&yu, self.seq);
        self.head.forward(&h)
    }
}

impl InferenceBackend for NativeBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn theta_len(&self) -> usize {
        self.head.output
    }

    fn window_y_len(&self) -> usize {
        self.seq * self.xdim
    }

    fn window_u_len(&self) -> usize {
        self.seq * self.udim
    }

    fn forward_batch(&self, y: &[f32], u: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch;
        if y.len() != b * self.window_y_len() {
            return Err(Error::Shape {
                expected: format!("{} y values", b * self.window_y_len()),
                got: format!("{}", y.len()),
            });
        }
        if u.len() != b * self.window_u_len() {
            return Err(Error::Shape {
                expected: format!("{} u values", b * self.window_u_len()),
                got: format!("{}", u.len()),
            });
        }
        // Interleave to batch-major (B, K, XDIM+UDIM).
        let i_sz = self.xdim + self.udim;
        let mut yu = vec![0.0f32; b * self.seq * i_sz];
        for w in 0..b {
            for t in 0..self.seq {
                let dst = (w * self.seq + t) * i_sz;
                let sy = (w * self.seq + t) * self.xdim;
                let su = (w * self.seq + t) * self.udim;
                yu[dst..dst + self.xdim].copy_from_slice(&y[sy..sy + self.xdim]);
                yu[dst + self.xdim..dst + i_sz].copy_from_slice(&u[su..su + self.udim]);
            }
        }
        let h = gru_forward_batch(&self.packed, &yu, self.seq, b);
        Ok(dense_head_batch(&self.head, &h, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_forward_matches_scalar_oracle() {
        let be = NativeBackend::new(3, 42);
        let mut rng = Prng::new(7);
        let y = rng.normal_vec_f32(3 * 64 * 3, 0.5);
        let u = rng.normal_vec_f32(3 * 64, 0.5);
        let out = be.forward_batch(&y, &u).unwrap();
        assert_eq!(out.len(), 3 * 45);
        for w in 0..3 {
            let want = be.forward_window_scalar(
                &y[w * 64 * 3..(w + 1) * 64 * 3],
                &u[w * 64..(w + 1) * 64],
            );
            for (a, b) in out[w * 45..(w + 1) * 45].iter().zip(&want) {
                assert!((a - b).abs() < 1e-6, "window {w}");
            }
        }
    }

    #[test]
    fn shape_validation() {
        let be = NativeBackend::new(2, 1);
        assert!(be.forward_batch(&[0.0; 3], &[0.0; 128]).is_err());
        assert_eq!(be.theta_len(), 45);
        assert_eq!(be.window_y_len(), 192);
        assert_eq!(be.window_u_len(), 64);
    }

    #[test]
    fn from_parts_rejects_mismatched_dims() {
        let mut rng = Prng::new(2);
        let gru = GruParams::random(4, 8, &mut rng, 0.3);
        let head = DenseHead::random(9, 4, 6, &mut rng); // wrong input
        assert!(NativeBackend::from_parts(gru, head, 2, 16, 3, 1).is_err());
    }
}

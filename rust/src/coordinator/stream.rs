//! Streaming recovery pipeline: continuous per-tenant sample streams →
//! overlapping recovery windows → the sharded executor fleet.
//!
//! MERINDA's serving claim is that model recovery should run as a
//! *streaming dataflow*, not a batch of one-shot kernel launches. This
//! module is the software half of that claim: each tenant (a deployed
//! system emitting telemetry) pushes `(y, u)` samples one at a time; a
//! per-tenant [`Windower`] slices the stream into overlapping recovery
//! windows; the [`StreamCoordinator`] holds the ready windows in bounded
//! per-tenant queues and pumps them into a [`Service`] with round-robin
//! fairness and an AIMD burst controller
//! ([`AimdBurst`](super::batcher::AimdBurst)).
//!
//! Overload handling is explicit and two-tiered:
//! * the *service* queue rejecting with a typed
//!   [`Overloaded`](crate::util::Error::Overloaded) error is treated as
//!   transient backpressure — the window is held, the burst halves, and
//!   the submit retries on a later pump;
//! * a *tenant* queue overflowing sheds a window under a configured
//!   [`ShedPolicy`] (drop the oldest for freshest-data semantics, or the
//!   newest for complete-the-backlog semantics), counted per tenant and
//!   in the shared [`Metrics`](super::metrics::Metrics) sink.
//!
//! The pipeline works against any [`InferenceBackend`]
//! (native f32 or quantized fixed-point): recovered windows are bitwise
//! identical to submitting the same windows through
//! [`Service::recover_many`], which `merinda soak` verifies by default
//! and `rust/tests/streaming.rs` asserts on both backends.
//!
//! Two layers sit on top of the original single-service pipeline:
//!
//! * **Resource-aware placement** — [`StreamCoordinator::with_fleet`]
//!   schedules windows across a heterogeneous fleet of accelerator
//!   instances via the cycle-model cost function in
//!   [`placement`](super::placement), replacing blind single-queue
//!   submission: the cheapest instance (transfer + queue wait + window
//!   latency) wins each window, a saturated instance spills to its next
//!   cheapest sibling, and only a fleet-wide refusal triggers the AIMD
//!   hold-and-retry path.
//! * **Warm-start recovery** — with [`WarmStartConfig::enabled`], each
//!   completed window's Θ is polished against the window's own data
//!   ([`refine_window_theta`](crate::mr::recover::refine_window_theta)),
//!   seeded from the *previous* overlapping window's refined Θ (cached
//!   per tenant) instead of cold-starting from the NN proposal; the
//!   saved iterations are counted per tenant and reported as the
//!   cold-vs-warm ratio in `BENCH_stream.json`. The raw service Θ in
//!   [`RecoveredWindow::theta`] is untouched, so streaming-vs-one-shot
//!   bitwise verification still holds.
//!
//! [`InferenceBackend`]: super::service::InferenceBackend

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::mr::recover::{refine_window_theta, RefineOpts};
use crate::util::{Error, Prng, Result};

use super::batcher::AimdBurst;
use super::faults::{
    corrupt_theta, fidelity_check, FaultEvent, FaultKind, FaultPlan, FaultStats,
    FaultToleranceConfig, InstanceHealth,
};
use super::metrics::Metrics;
use super::placement::{rank_with, InstanceModel, PlacementOverride};
use super::service::{RecoveryRequest, RecoveryResponse, Service};
use super::traffic::QosClass;

/// How a continuous stream is sliced into recovery windows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowConfig {
    /// Samples per recovery window (the model's `seq`).
    pub window: usize,
    /// Samples between consecutive window starts. Values above `window`
    /// would drop samples, so configs are normalized to `1..=window` —
    /// windowing is lossless by construction.
    pub stride: usize,
}

impl Default for WindowConfig {
    fn default() -> Self {
        WindowConfig {
            window: 64,
            stride: 16,
        }
    }
}

impl WindowConfig {
    /// Clamp into the lossless regime: `window ≥ 1`, `1 ≤ stride ≤ window`.
    pub fn normalized(self) -> WindowConfig {
        let window = self.window.max(1);
        WindowConfig {
            window,
            stride: self.stride.clamp(1, window),
        }
    }
}

/// Window start indices for a finite stream of `len` samples.
///
/// The pure-function mirror of [`Windower`]: starts advance by `stride`
/// (clamped into `1..=window`), and a final tail window anchored at
/// `len - window` is appended when the strided walk would leave trailing
/// samples uncovered. Guarantees, for any `len ≥ window`:
/// * every sample index in `0..len` is inside at least one window
///   (losslessness), and
/// * starts are strictly increasing.
///
/// Streams shorter than one window yield no full window and return an
/// empty plan.
pub fn window_plan(len: usize, window: usize, stride: usize) -> Vec<usize> {
    let cfg = WindowConfig { window, stride }.normalized();
    let (window, stride) = (cfg.window, cfg.stride);
    if len < window {
        return Vec::new();
    }
    let mut starts = Vec::new();
    let mut s = 0usize;
    loop {
        starts.push(s);
        if s + window >= len {
            break;
        }
        s += stride;
        if s + window > len {
            s = len - window;
        }
    }
    starts
}

/// Incremental windower for one tenant stream.
///
/// Accepts one `(y_row, u_row)` sample at a time and emits each window
/// as soon as its last sample arrives; [`Windower::finish`] flushes the
/// tail window at end-of-stream. The emitted start sequence is exactly
/// [`window_plan`] of the final stream length (asserted by the property
/// tests in `rust/tests/proptests.rs`). Memory is bounded: only the
/// samples still reachable by a future window are retained.
#[derive(Debug)]
pub struct Windower {
    window: usize,
    stride: usize,
    xdim: usize,
    udim: usize,
    /// Retained sample rows, starting at absolute index `base`.
    y: Vec<f32>,
    u: Vec<f32>,
    base: usize,
    /// Absolute start index of the next strided window.
    next_start: usize,
    /// Total samples pushed so far.
    pushed: usize,
    emitted: u64,
}

/// One emitted window: `(start_index, y_payload, u_payload)`.
pub type EmittedWindow = (usize, Vec<f32>, Vec<f32>);

impl Windower {
    pub fn new(cfg: WindowConfig, xdim: usize, udim: usize) -> Windower {
        let cfg = cfg.normalized();
        Windower {
            window: cfg.window,
            stride: cfg.stride,
            xdim,
            udim,
            y: Vec::new(),
            u: Vec::new(),
            base: 0,
            next_start: 0,
            pushed: 0,
            emitted: 0,
        }
    }

    /// Samples pushed so far.
    pub fn pushed(&self) -> usize {
        self.pushed
    }

    /// Windows emitted so far (including tail flushes).
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Push one sample; returns the window it completed, if any.
    pub fn push(&mut self, y_row: &[f32], u_row: &[f32]) -> Option<EmittedWindow> {
        assert_eq!(y_row.len(), self.xdim, "y row width");
        assert_eq!(u_row.len(), self.udim, "u row width");
        self.y.extend_from_slice(y_row);
        self.u.extend_from_slice(u_row);
        self.pushed += 1;
        let out = if self.pushed >= self.next_start + self.window {
            let s = self.next_start;
            let w = self.copy_window(s);
            self.next_start = s + self.stride;
            self.emitted += 1;
            Some(w)
        } else {
            None
        };
        self.trim();
        out
    }

    /// End-of-stream flush: emit the tail window at `len - window` when
    /// the strided walk left trailing samples uncovered. Idempotent
    /// until more samples arrive; streams shorter than one window have
    /// no full window to emit.
    pub fn finish(&mut self) -> Option<EmittedWindow> {
        if self.pushed < self.window {
            return None;
        }
        let covered = if self.emitted == 0 {
            0
        } else {
            self.next_start - self.stride + self.window
        };
        if covered >= self.pushed {
            return None;
        }
        let s = self.pushed - self.window;
        let w = self.copy_window(s);
        self.next_start = s + self.stride;
        self.emitted += 1;
        Some(w)
    }

    fn copy_window(&self, start: usize) -> EmittedWindow {
        debug_assert!(start >= self.base, "window start trimmed away");
        let off = start - self.base;
        let y = self.y[off * self.xdim..(off + self.window) * self.xdim].to_vec();
        let u = self.u[off * self.udim..(off + self.window) * self.udim].to_vec();
        (start, y, u)
    }

    /// Drop rows no future window (strided or tail) can reach: everything
    /// before `min(next_start, pushed - window)`.
    fn trim(&mut self) {
        let keep_from = self.next_start.min(self.pushed.saturating_sub(self.window));
        if keep_from > self.base {
            let rows = keep_from - self.base;
            self.y.drain(..rows * self.xdim);
            self.u.drain(..rows * self.udim);
            self.base = keep_from;
        }
    }
}

/// What to drop when a bounded tenant queue overflows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Drop the oldest queued window: the stream always serves the
    /// freshest telemetry (digital-twin semantics).
    Oldest,
    /// Drop the incoming window: finish the queued backlog first
    /// (batch-completion semantics).
    Newest,
}

impl ShedPolicy {
    /// Parse a CLI name (`merinda soak --shed oldest|newest`).
    pub fn from_name(name: &str) -> crate::util::Result<ShedPolicy> {
        match name {
            "oldest" => Ok(ShedPolicy::Oldest),
            "newest" => Ok(ShedPolicy::Newest),
            other => Err(crate::util::Error::config(format!(
                "unknown shed policy {other:?} (expected oldest or newest)"
            ))),
        }
    }
}

/// Warm-start recovery configuration.
#[derive(Clone, Copy, Debug)]
pub struct WarmStartConfig {
    /// Polish each completed window's Θ against the window's own data,
    /// seeding from the previous overlapping window's refined Θ.
    pub enabled: bool,
    /// Also run the refinement from the cold (NN-proposal) seed on every
    /// warm-seeded window, so the cold-vs-warm iteration ratio is a
    /// paired, per-window measurement (the soak/bench path; costs one
    /// extra refinement per window).
    pub measure_cold: bool,
    /// The refinement problem and stopping rule.
    pub refine: RefineOpts,
}

impl Default for WarmStartConfig {
    fn default() -> Self {
        WarmStartConfig {
            enabled: false,
            measure_cold: true,
            refine: RefineOpts::default(),
        }
    }
}

/// Streaming-pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    pub window: WindowConfig,
    /// Bounded per-tenant queue of ready-but-unsubmitted windows.
    pub tenant_queue: usize,
    /// Shed decision when a tenant queue overflows.
    pub shed: ShedPolicy,
    /// Initial AIMD burst (windows per tenant per pump round).
    pub burst_initial: usize,
    /// Maximum AIMD burst.
    pub burst_max: usize,
    /// Warm-start refinement (off by default; `merinda soak` enables it).
    pub warm_start: WarmStartConfig,
    /// Fault tolerance: deadlines, bounded retry, health thresholds,
    /// degraded-mode policy. Always active — injection is opt-in via
    /// [`StreamCoordinator::inject_faults`], but genuine instance
    /// failures take the same detection/failover paths.
    pub faults: FaultToleranceConfig,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            window: WindowConfig::default(),
            tenant_queue: 64,
            shed: ShedPolicy::Oldest,
            burst_initial: 1,
            burst_max: 8,
            warm_start: WarmStartConfig::default(),
            faults: FaultToleranceConfig::default(),
        }
    }
}

/// The outcome of warm-start refinement on one window.
#[derive(Clone, Debug)]
pub struct RefinedWindow {
    /// Polished coefficients (the warm-path output when a cache entry
    /// existed, the cold-path output otherwise).
    pub theta: Vec<f32>,
    /// CG iterations the served refinement took.
    pub iters: u64,
    /// Iterations the cold seed took on the *same* window (present only
    /// when this window was warm-seeded and
    /// [`WarmStartConfig::measure_cold`] is on).
    pub cold_iters: Option<u64>,
    /// Whether a per-tenant cache entry seeded this refinement.
    pub seeded_warm: bool,
    /// Refinement reached its residual threshold.
    pub converged: bool,
}

/// One recovered window, attributed back to its stream position.
#[derive(Clone, Debug)]
pub struct RecoveredWindow {
    pub tenant: u32,
    /// Per-tenant window sequence number (0-based emission order).
    pub seq_no: u32,
    /// Sample index of the window start within the tenant stream.
    pub start: usize,
    /// Estimated coefficients for the window — the raw service output,
    /// bitwise identical to the one-shot path.
    pub theta: Vec<f32>,
    /// Submit-to-response latency observed by the service.
    pub latency: Duration,
    /// Warm-start polish, when enabled.
    pub refined: Option<RefinedWindow>,
    /// Fleet instance that served the window.
    pub instance: usize,
}

/// Per-tenant streaming counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantStats {
    pub tenant: u32,
    pub samples: u64,
    pub emitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub failed: u64,
    /// CG iterations over warm-seeded windows (paired subset).
    pub refine_warm_iters: u64,
    /// CG iterations the cold seed took on the same paired windows.
    pub refine_cold_iters: u64,
    /// Windows measured both ways (warm cache hit + cold baseline).
    pub refine_paired: u64,
    /// Iterations spent on unpaired (first / cache-miss) windows.
    pub refine_first_iters: u64,
}

/// Per-fleet-instance streaming counters.
#[derive(Clone, Debug, Default)]
pub struct InstanceStats {
    pub name: String,
    /// Windows placed on this instance.
    pub placed: u64,
    /// Windows this instance completed.
    pub completed: u64,
    /// High-water mark of concurrently outstanding windows.
    pub outstanding_max: usize,
    /// Cycle-model cost of one window on this instance.
    pub window_cycles: u64,
    /// Modeled cycles consumed by completed windows.
    pub modeled_cycles: u64,
    /// Health-machine state at snapshot time
    /// (`healthy`/`degraded`/`down`/`recovering`).
    pub health: String,
    /// Windows stranded on this instance and re-placed elsewhere.
    pub failed_over: u64,
    /// Times the health machine took this instance down.
    pub downs: u64,
}

/// Per-QoS-tier streaming counters (window lifecycle only; admission
/// counters live with the open-loop driver in
/// [`traffic`](super::traffic)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierStats {
    pub emitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub failed: u64,
}

/// Whole-pipeline streaming counters.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub samples_pushed: u64,
    pub windows_emitted: u64,
    pub windows_completed: u64,
    pub windows_shed: u64,
    pub windows_failed: u64,
    /// High-water mark across all tenant queues.
    pub tenant_queue_max: usize,
    /// High-water mark of windows awaiting a service response.
    pub in_flight_max: usize,
    /// AIMD backoffs taken (service overload events observed).
    pub burst_backoffs: u64,
    /// Burst size the controller converged to.
    pub burst_final: usize,
    pub per_tenant: Vec<TenantStats>,
    /// Window-lifecycle breakdown per QoS tier, indexed by
    /// [`QosClass::index`].
    pub per_tier: [TierStats; 3],
    /// Placement breakdown, one entry per fleet instance.
    pub per_instance: Vec<InstanceStats>,
    /// Warm-start totals over the paired windows (see [`TenantStats`]).
    pub refine_warm_iters: u64,
    pub refine_cold_iters: u64,
    pub refine_paired: u64,
    /// Fault-layer counters: injections, detections, failovers, retries.
    pub faults: FaultStats,
    /// Whether the coordinator is currently in degraded mode (placeable
    /// capacity below the configured fraction of the full fleet).
    pub degraded: bool,
}

/// Encode a `(tenant, seq_no)` pair into a service request id.
pub fn encode_id(tenant: u32, seq_no: u32) -> u64 {
    ((tenant as u64) << 32) | seq_no as u64
}

/// Recover the `(tenant, seq_no)` pair from a service request id.
pub fn decode_id(id: u64) -> (u32, u32) {
    ((id >> 32) as u32, id as u32)
}

struct PendingWindow {
    seq_no: u32,
    start: usize,
    y: Vec<f32>,
    u: Vec<f32>,
    /// Prior submission attempts (0 for a fresh window; bumped by the
    /// fault layer on each failover retry).
    attempts: u32,
    /// Earliest pump round this window may be resubmitted (retry
    /// backoff). 0 for fresh windows.
    not_before: u64,
    /// When the window entered the pipeline. Per-tier latency is
    /// end-to-end (`born` → result), so queue wait under load counts
    /// against the tier's SLO, unlike the service's submit→response
    /// latency.
    born: Instant,
}

struct TenantState {
    windower: Windower,
    queue: VecDeque<PendingWindow>,
    queue_high: usize,
    samples: u64,
    emitted: u64,
    completed: u64,
    shed: u64,
    failed: u64,
    next_seq: u32,
    /// QoS tier: drives pump priority, shed ordering and the per-tier
    /// metrics attribution. Standard unless set via
    /// [`StreamCoordinator::set_qos`].
    qos: QosClass,
    /// Warm-start cache: the previous window's refined Θ.
    warm_theta: Option<Vec<f32>>,
    refine_warm_iters: u64,
    refine_cold_iters: u64,
    refine_paired: u64,
    refine_first_iters: u64,
}

impl TenantState {
    fn new(wcfg: WindowConfig, xdim: usize, udim: usize) -> TenantState {
        TenantState {
            windower: Windower::new(wcfg, xdim, udim),
            queue: VecDeque::new(),
            queue_high: 0,
            samples: 0,
            emitted: 0,
            completed: 0,
            shed: 0,
            failed: 0,
            next_seq: 0,
            qos: QosClass::Standard,
            warm_theta: None,
            refine_warm_iters: 0,
            refine_cold_iters: 0,
            refine_paired: 0,
            refine_first_iters: 0,
        }
    }
}

struct InFlightWindow {
    tenant: u32,
    seq_no: u32,
    start: usize,
    /// Pipeline-entry time carried from [`PendingWindow::born`].
    born: Instant,
    /// Fleet instance the window was placed on.
    instance: usize,
    /// Window payload `(y, u)` retained so a stranded window (crash,
    /// deadline timeout, corrupted result) can be re-placed on a healthy
    /// sibling, and so warm-start refinement has its inputs.
    payload: (Vec<f32>, Vec<f32>),
    /// Submission attempts so far, including this one (0-based: the
    /// first submission carries 0).
    attempts: u32,
    /// Wall-clock submission time; the fault layer fails the window over
    /// once `submitted_at.elapsed()` exceeds the deadline.
    submitted_at: Instant,
    rx: Receiver<RecoveryResponse>,
}

/// Runtime load state of one fleet instance. Only the live
/// `outstanding` count lives here (placement needs it synchronously);
/// the cumulative placed/completed/rejected/high-water counters have a
/// single source of truth in the shared [`Metrics`] sink.
struct InstanceRt {
    svc: Service,
    /// Windows submitted and not yet answered.
    outstanding: usize,
}

/// How a fleet submission attempt ended.
enum SubmitOutcome {
    /// Accepted by some instance.
    Accepted,
    /// Every instance is permanently down (or has no capacity at all):
    /// the window can never be served.
    Failed,
    /// Every eligible instance is saturated, backpressured, or
    /// transiently unhealthy: the window comes back for a
    /// hold-and-retry.
    Saturated(PendingWindow),
}

/// Bound a ready window into a tenant queue, shedding per policy on
/// overflow.
fn enqueue_window(
    t: &mut TenantState,
    w: PendingWindow,
    cap: usize,
    shed: ShedPolicy,
    metrics: &Metrics,
) {
    let cap = cap.max(1);
    if t.queue.len() >= cap {
        t.shed += 1;
        metrics.on_shed();
        metrics.on_tier_shed(t.qos);
        match shed {
            // Drop the incoming window, keep the backlog.
            ShedPolicy::Newest => return,
            // Drop the stalest queued window, keep the fresh one.
            ShedPolicy::Oldest => {
                t.queue.pop_front();
            }
        }
    }
    t.queue.push_back(w);
    t.queue_high = t.queue_high.max(t.queue.len());
}

/// The streaming recovery pipeline: per-tenant windowers and bounded
/// queues in front of one or more sharded [`Service`] instances.
///
/// Usage: [`push`](StreamCoordinator::push) samples as they arrive,
/// calling [`pump`](StreamCoordinator::pump) /
/// [`poll`](StreamCoordinator::poll) periodically to keep windows
/// flowing; at end-of-stream, [`flush_tails`](StreamCoordinator::flush_tails)
/// then [`drain`](StreamCoordinator::drain), and collect
/// [`take_results`](StreamCoordinator::take_results).
///
/// # Example
///
/// ```
/// use merinda::coordinator::{
///     MockBackend, Service, ServiceConfig, StreamConfig, StreamCoordinator,
/// };
///
/// let svc = Service::start(ServiceConfig::default(), MockBackend::default);
/// let mut coord = StreamCoordinator::new(svc, StreamConfig::default(), 3, 1);
/// // One tenant pushing 64 samples completes exactly one 64-step window.
/// for i in 0..64 {
///     coord.push(0, &[i as f32; 3], &[0.0]);
/// }
/// coord.flush_tails();
/// coord.drain();
/// let results = coord.take_results();
/// assert_eq!(results.len(), 1);
/// assert_eq!(results[0].start, 0);
/// ```
pub struct StreamCoordinator {
    /// Static placement cost inputs, parallel to `instances`.
    models: Vec<InstanceModel>,
    instances: Vec<InstanceRt>,
    /// Shared metrics sink (instance 0's service sink; a fleet built via
    /// [`Service::start_with_metrics`] shares one sink across instances).
    metrics: Arc<Metrics>,
    cfg: StreamConfig,
    xdim: usize,
    udim: usize,
    tenants: BTreeMap<u32, TenantState>,
    in_flight: VecDeque<InFlightWindow>,
    burst: AimdBurst,
    results: Vec<RecoveredWindow>,
    in_flight_max: usize,
    /// Tenant id the next pump sweep starts from — set to the tenant the
    /// service refused, so a freed slot goes to the starved tenant first
    /// instead of restarting at the lowest id every time.
    rr_resume: u32,

    // --- fault layer ---
    /// Per-instance health machines, parallel to `instances`.
    health: Vec<InstanceHealth>,
    /// Scheduled fault events not yet fired (see [`FaultPlan`]).
    plan: Vec<FaultEvent>,
    /// Fleet-wide accepted-submission counter (Crash/Stall/LinkDegrade
    /// trigger clock).
    submit_clock: u64,
    /// Pump rounds elapsed (retry-backoff and health-probe clock).
    rounds: u64,
    /// Per-instance count of responses received (BitFlip trigger clock).
    responses_from: Vec<u64>,
    /// Per-instance stall window: masked from placement and left
    /// unread by `poll` until the instant passes.
    stall_until: Vec<Option<Instant>>,
    /// Per-instance link-degradation factor and the `submit_clock` value
    /// at which it expires.
    link_factor: Vec<f64>,
    link_expire: Vec<u64>,
    /// Request ids that were deadline-hedged: their original submission
    /// may still answer after the retry, so completions dedupe via
    /// `done`.
    hedged: BTreeSet<u64>,
    /// Hedged ids already accounted (completed or exhausted).
    done: BTreeSet<u64>,
    /// Hedged originals: moved out of `in_flight` (slot already
    /// released) but kept so a late response is drained as a duplicate
    /// instead of leaking the channel.
    late: Vec<InFlightWindow>,
    /// Standby instance index (masked from placement until the fleet
    /// degrades), if one was registered via
    /// [`add_standby`](Self::add_standby).
    standby: Option<usize>,
    /// Per-instance member-board lists, parallel to `instances`. Empty
    /// for ordinary whole-window instances; a *partitioned* instance
    /// ([`add_partitioned`](Self::add_partitioned)) lists the fleet
    /// indices of the boards its plan spans — each placed window
    /// occupies a slot on every member, and any member going down
    /// invalidates the whole plan.
    members: Vec<Vec<usize>>,
    /// Degraded mode: placeable capacity below the configured fraction.
    degraded: bool,
    fault_stats: FaultStats,
    /// Deterministic jitter source for retry backoff.
    jitter: Prng,
}

/// Cost model for a coordinator wrapping a single anonymous service: no
/// transfer/queue modelling, effectively unbounded concurrency budget —
/// placement degenerates to the original single-queue behaviour.
fn uniform_model() -> InstanceModel {
    InstanceModel {
        name: "service".to_string(),
        window_cycles: 0,
        service_cycles: 0,
        window_s: 0.0,
        service_s: 0.0,
        transfer_s: 0.0,
        payload_bytes: 0,
        max_outstanding: usize::MAX,
        resources: crate::fpga::resources::Resources::ZERO,
        fits: true,
    }
}

impl StreamCoordinator {
    /// Wrap a running service. `xdim`/`udim` are the per-sample row
    /// widths the backend expects (padded dims, e.g. 3/1 for the
    /// canonical serving model).
    pub fn new(svc: Service, cfg: StreamConfig, xdim: usize, udim: usize) -> StreamCoordinator {
        StreamCoordinator::build(vec![(uniform_model(), svc)], cfg, xdim, udim)
    }

    /// Wrap a heterogeneous fleet: each entry pairs the instance's static
    /// placement model (derived from its board via
    /// [`InstanceSpec::model`](super::placement::InstanceSpec::model))
    /// with its running service. Windows are placed on the instance with
    /// the lowest estimated completion time; a saturated instance spills
    /// to the next cheapest sibling. For aggregated metrics, start every
    /// instance's service with one shared sink
    /// ([`Service::start_with_metrics`]); shed/queue counters are
    /// recorded into instance 0's sink either way.
    pub fn with_fleet(
        fleet: Vec<(InstanceModel, Service)>,
        cfg: StreamConfig,
        xdim: usize,
        udim: usize,
    ) -> Result<StreamCoordinator> {
        if fleet.is_empty() {
            return Err(Error::config(
                "fleet must have at least one instance (placement needs a roster)",
            ));
        }
        Ok(StreamCoordinator::build(fleet, cfg, xdim, udim))
    }

    fn build(
        fleet: Vec<(InstanceModel, Service)>,
        cfg: StreamConfig,
        xdim: usize,
        udim: usize,
    ) -> StreamCoordinator {
        debug_assert!(!fleet.is_empty());
        let cfg = StreamConfig {
            window: cfg.window.normalized(),
            ..cfg
        };
        let burst = AimdBurst::new(cfg.burst_initial, cfg.burst_max);
        let mut models = Vec::with_capacity(fleet.len());
        let mut instances = Vec::with_capacity(fleet.len());
        for (model, svc) in fleet {
            models.push(model);
            instances.push(InstanceRt {
                svc,
                outstanding: 0,
            });
        }
        let metrics = instances[0].svc.metrics.clone();
        let n = instances.len();
        StreamCoordinator {
            health: (0..n).map(|_| InstanceHealth::new(&cfg.faults.health)).collect(),
            plan: Vec::new(),
            submit_clock: 0,
            rounds: 0,
            responses_from: vec![0; n],
            stall_until: vec![None; n],
            link_factor: vec![1.0; n],
            link_expire: vec![0; n],
            hedged: BTreeSet::new(),
            done: BTreeSet::new(),
            late: Vec::new(),
            standby: None,
            members: vec![Vec::new(); n],
            degraded: false,
            fault_stats: FaultStats::default(),
            jitter: Prng::new(0xC0FF_EE00_D15EA5E5),
            models,
            instances,
            metrics,
            cfg,
            xdim,
            udim,
            tenants: BTreeMap::new(),
            in_flight: VecDeque::new(),
            burst,
            results: Vec::new(),
            in_flight_max: 0,
            rr_resume: 0,
        }
    }

    /// Arm a deterministic fault schedule (see [`FaultPlan`]). Events
    /// fire as their trigger clocks pass; calling again replaces any
    /// unfired events. Fails if an event names an instance outside the
    /// fleet.
    pub fn inject_faults(&mut self, plan: FaultPlan) -> Result<()> {
        if let Some(ev) = plan.events.iter().find(|e| e.instance >= self.instances.len()) {
            return Err(Error::config(format!(
                "fault plan names instance {} but the fleet has {}",
                ev.instance,
                self.instances.len()
            )));
        }
        self.plan = plan.events;
        Ok(())
    }

    /// Register a standby instance (e.g. a host-native backend). It is
    /// masked out of placement while the fleet is healthy and becomes
    /// placeable only in degraded mode, when primary capacity has
    /// shrunk below [`FaultToleranceConfig::degraded_capacity_frac`].
    /// Returns the standby's fleet index.
    pub fn add_standby(&mut self, model: InstanceModel, svc: Service) -> usize {
        self.models.push(model);
        self.instances.push(InstanceRt {
            svc,
            outstanding: 0,
        });
        self.health.push(InstanceHealth::new(&self.cfg.faults.health));
        self.responses_from.push(0);
        self.stall_until.push(None);
        self.link_factor.push(1.0);
        self.link_expire.push(0);
        self.members.push(Vec::new());
        let idx = self.instances.len() - 1;
        self.standby = Some(idx);
        idx
    }

    /// Register a *partitioned* instance: one design split across the
    /// member boards named by `member_of` (fleet indices), entering
    /// placement as a single instance whose cost model is the plan's
    /// composition (see
    /// [`PartitionedInstanceSpec`](super::placement::PartitionedInstanceSpec)).
    /// Every window placed here also occupies one concurrency slot on
    /// *each* member board (the pipeline runs on all of them at once),
    /// and a member going permanently down invalidates the plan: its
    /// in-flight windows fail over to whole-window siblings and the
    /// instance leaves the roster. Returns the new fleet index.
    pub fn add_partitioned(
        &mut self,
        model: InstanceModel,
        member_of: Vec<usize>,
        svc: Service,
    ) -> Result<usize> {
        if member_of.is_empty() {
            return Err(Error::config(
                "a partitioned instance needs at least one member board",
            ));
        }
        for &m in &member_of {
            if m >= self.instances.len() {
                return Err(Error::config(format!(
                    "partitioned member {m} is out of range for a fleet of {}",
                    self.instances.len()
                )));
            }
            if !self.members[m].is_empty() {
                return Err(Error::config(format!(
                    "partitioned member {m} is itself a partitioned instance"
                )));
            }
            if self.standby == Some(m) {
                return Err(Error::config(format!(
                    "partitioned member {m} is the standby instance"
                )));
            }
        }
        self.models.push(model);
        self.instances.push(InstanceRt {
            svc,
            outstanding: 0,
        });
        self.health.push(InstanceHealth::new(&self.cfg.faults.health));
        self.responses_from.push(0);
        self.stall_until.push(None);
        self.link_factor.push(1.0);
        self.link_expire.push(0);
        self.members.push(member_of);
        Ok(self.instances.len() - 1)
    }

    /// A partitioned instance is transiently unplaceable while any
    /// member board is (down, recovering-without-probe or stalled).
    /// Always false for ordinary instances.
    fn members_blocked(&self, i: usize) -> bool {
        self.members[i]
            .iter()
            .any(|&m| !self.health[m].placeable() || self.stall_active(m))
    }

    /// A partitioned instance is *dead* once any member board is
    /// permanently down: the pipeline spans that board, so the plan can
    /// never serve again. Always false for ordinary instances.
    fn members_dead(&self, i: usize) -> bool {
        self.members[i]
            .iter()
            .any(|&m| self.health[m].is_permanently_down())
    }

    /// Free member slots a partitioned instance may still claim: the
    /// minimum over members of (member cap − member outstanding).
    /// `None` for ordinary instances (no member constraint).
    fn member_headroom(&self, i: usize) -> Option<usize> {
        if self.members[i].is_empty() {
            return None;
        }
        let mut free = usize::MAX;
        for &m in &self.members[i] {
            let budget = self.models[m].max_outstanding;
            let cap = match self.health[m].probe_cap() {
                Some(c) => c.min(budget),
                None => budget,
            };
            free = free.min(cap.saturating_sub(self.instances[m].outstanding));
        }
        Some(free)
    }

    /// Release one occupancy slot on instance `i` — and, for a
    /// partitioned instance, on every member board it spans.
    fn release_slot(&mut self, i: usize) {
        let rt = &mut self.instances[i];
        rt.outstanding = rt.outstanding.saturating_sub(1);
        for k in 0..self.members[i].len() {
            let m = self.members[i][k];
            let rt = &mut self.instances[m];
            rt.outstanding = rt.outstanding.saturating_sub(1);
        }
    }

    /// Fault-layer counters (injections, detections, failovers), with
    /// per-instance health tallies folded in.
    pub fn fault_stats(&self) -> FaultStats {
        let mut fs = self.fault_stats;
        for h in &self.health {
            fs.instances_down += h.downs;
            fs.instances_recovered += h.recoveries;
            fs.recovery_rounds_total += h.recovery_rounds;
        }
        fs
    }

    /// The shared metrics sink (latency, batches, sheds, per-instance
    /// placement counters).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Push one sample for `tenant`. If the sample completes a window it
    /// is enqueued (possibly shedding per policy). Cheap; call `pump`
    /// periodically to move enqueued windows into the service.
    pub fn push(&mut self, tenant: u32, y_row: &[f32], u_row: &[f32]) {
        let (wcfg, xdim, udim) = (self.cfg.window, self.xdim, self.udim);
        let t = self
            .tenants
            .entry(tenant)
            .or_insert_with(|| TenantState::new(wcfg, xdim, udim));
        t.samples += 1;
        if let Some((start, y, u)) = t.windower.push(y_row, u_row) {
            let w = PendingWindow {
                seq_no: t.next_seq,
                start,
                y,
                u,
                attempts: 0,
                not_before: 0,
                born: Instant::now(),
            };
            t.next_seq += 1;
            t.emitted += 1;
            enqueue_window(t, w, self.cfg.tenant_queue, self.cfg.shed, &self.metrics);
        }
    }

    /// Assign `tenant` to a QoS tier (creating its state if needed).
    /// Tiers drive pump priority (realtime first), shed ordering
    /// ([`shed_to_budget`](Self::shed_to_budget) drops batch before
    /// standard before realtime) and the per-tier metrics attribution.
    /// Tenants default to [`QosClass::Standard`].
    pub fn set_qos(&mut self, tenant: u32, qos: QosClass) {
        let (wcfg, xdim, udim) = (self.cfg.window, self.xdim, self.udim);
        let t = self
            .tenants
            .entry(tenant)
            .or_insert_with(|| TenantState::new(wcfg, xdim, udim));
        t.qos = qos;
    }

    /// QoS tier of `tenant` (Standard for unknown tenants).
    pub fn qos_of(&self, tenant: u32) -> QosClass {
        self.tenants.get(&tenant).map(|t| t.qos).unwrap_or_default()
    }

    /// Offer one pre-sliced window directly (the open-loop arrival path:
    /// traffic fires on a logical clock, bypassing the per-sample
    /// [`Windower`]). The window is enqueued like a windower emission —
    /// bounded queue, shed policy and per-tier accounting all apply.
    /// Payload lengths must match the configured window geometry.
    pub fn offer_window(
        &mut self,
        tenant: u32,
        start: usize,
        y: Vec<f32>,
        u: Vec<f32>,
    ) -> Result<()> {
        let rows = self.cfg.window.window;
        if y.len() != rows * self.xdim || u.len() != rows * self.udim {
            return Err(Error::config(format!(
                "offered window payload {}x{} does not match window {} (xdim {}, udim {})",
                y.len(),
                u.len(),
                rows,
                self.xdim,
                self.udim
            )));
        }
        let (wcfg, xdim, udim) = (self.cfg.window, self.xdim, self.udim);
        let t = self
            .tenants
            .entry(tenant)
            .or_insert_with(|| TenantState::new(wcfg, xdim, udim));
        let w = PendingWindow {
            seq_no: t.next_seq,
            start,
            y,
            u,
            attempts: 0,
            not_before: 0,
            born: Instant::now(),
        };
        t.next_seq += 1;
        t.emitted += 1;
        enqueue_window(t, w, self.cfg.tenant_queue, self.cfg.shed, &self.metrics);
        Ok(())
    }

    /// End-of-stream: flush every tenant's tail window into its queue.
    pub fn flush_tails(&mut self) {
        for t in self.tenants.values_mut() {
            if let Some((start, y, u)) = t.windower.finish() {
                let w = PendingWindow {
                    seq_no: t.next_seq,
                    start,
                    y,
                    u,
                    attempts: 0,
                    not_before: 0,
                    born: Instant::now(),
                };
                t.next_seq += 1;
                t.emitted += 1;
                enqueue_window(t, w, self.cfg.tenant_queue, self.cfg.shed, &self.metrics);
            }
        }
    }

    /// Shed queued windows until at most `budget` remain, strictly in
    /// reverse priority order: every batch window sheds before any
    /// standard window, and every standard window before any realtime
    /// window (within a tier, the longest queue loses first; ties break
    /// on the highest tenant id, so the sweep is deterministic). The
    /// configured [`ShedPolicy`] picks which end of the victim queue
    /// drops. Returns windows shed per tier, indexed by
    /// [`QosClass::index`].
    pub fn shed_to_budget(&mut self, budget: usize) -> [u64; 3] {
        let mut shed = [0u64; 3];
        while self.queued_windows() > budget {
            let victim = self
                .tenants
                .iter()
                .filter(|(_, t)| !t.queue.is_empty())
                .max_by_key(|(id, t)| (t.qos.index(), t.queue.len(), **id))
                .map(|(id, _)| *id);
            let Some(tid) = victim else { break };
            let policy = self.cfg.shed;
            let Some(t) = self.tenants.get_mut(&tid) else { break };
            let dropped = match policy {
                ShedPolicy::Oldest => t.queue.pop_front(),
                ShedPolicy::Newest => t.queue.pop_back(),
            };
            if dropped.is_none() {
                break;
            }
            t.shed += 1;
            shed[t.qos.index()] += 1;
            self.metrics.on_shed();
            self.metrics.on_tier_shed(t.qos);
        }
        shed
    }

    /// Windows queued at `qos` priority or higher (the admission
    /// controller's view of how much work drains ahead of a new arrival
    /// at that tier).
    pub fn queued_at_or_above(&self, qos: QosClass) -> usize {
        self.tenants
            .values()
            .filter(|t| t.qos.index() <= qos.index())
            .map(|t| t.queue.len())
            .sum()
    }

    /// Total concurrency slots currently placeable across the fleet
    /// (masked/stalled/down instances excluded, health-probe and
    /// partitioned-member caps applied; the uniform single-service
    /// model's unbounded budget is clamped to keep the sum meaningful).
    pub fn placement_slots(&self) -> usize {
        let overrides = self.placement_overrides();
        self.models
            .iter()
            .enumerate()
            .filter(|(i, m)| !overrides[*i].masked && m.max_outstanding > 0)
            .map(|(i, m)| {
                let budget = m.max_outstanding.min(1 << 16);
                overrides[i].cap.map_or(budget, |c| c.min(budget))
            })
            .sum()
    }

    /// Swap the placement cost models of the primary roster mid-stream
    /// (online retuning: the traffic mix drifted, the tuner re-derived
    /// per-board configs). `models` replaces the first `models.len()`
    /// roster entries in order; instances registered later (standby,
    /// partitioned) keep their models. In-flight windows finish under
    /// the placement decision that launched them; only future
    /// placements see the new costs.
    pub fn retarget_models(&mut self, models: Vec<InstanceModel>) -> Result<()> {
        if models.is_empty() || models.len() > self.models.len() {
            return Err(Error::config(format!(
                "retarget with {} models but the fleet has {}",
                models.len(),
                self.models.len()
            )));
        }
        for (slot, m) in self.models.iter_mut().zip(models) {
            *slot = m;
        }
        Ok(())
    }

    /// Fire every armed submission-clocked fault event whose trigger has
    /// passed (Crash / Stall / LinkDegrade; BitFlip fires on the
    /// response path, see [`record`](Self::record)).
    fn fire_submission_faults(&mut self) {
        if self.plan.is_empty() {
            return;
        }
        let clock = self.submit_clock;
        let mut i = 0;
        while i < self.plan.len() {
            let due = !matches!(self.plan[i].kind, FaultKind::BitFlip) && clock >= self.plan[i].at;
            if !due {
                i += 1;
                continue;
            }
            let ev = self.plan.remove(i);
            match ev.kind {
                FaultKind::Crash => {
                    self.instances[ev.instance].svc.kill();
                    self.health[ev.instance].on_dead(self.rounds, true);
                    self.fault_stats.injected_crash += 1;
                }
                FaultKind::Stall { hold } => {
                    self.stall_until[ev.instance] = Some(Instant::now() + hold);
                    self.fault_stats.injected_stall += 1;
                }
                FaultKind::LinkDegrade { factor, windows } => {
                    self.link_factor[ev.instance] = factor.max(1.0);
                    self.link_expire[ev.instance] = clock.saturating_add(windows);
                    self.fault_stats.injected_link += 1;
                }
                FaultKind::BitFlip => unreachable!("BitFlip fires on the response path"),
            }
        }
    }

    fn stall_active(&self, i: usize) -> bool {
        self.stall_until[i].is_some_and(|t| Instant::now() < t)
    }

    /// Per-instance placement overrides derived from fault state: down
    /// and stalled instances are masked, a recovering instance is capped
    /// to one probe window, degraded links inflate their transfer cost,
    /// and the standby joins the roster only in degraded mode.
    fn placement_overrides(&self) -> Vec<PlacementOverride> {
        (0..self.models.len())
            .map(|i| {
                // A partitioned instance needs a free slot on every
                // member board: its effective cap is what it already
                // holds plus the tightest member's headroom.
                let mut cap = self.health[i].probe_cap();
                if let Some(free) = self.member_headroom(i) {
                    let combined = self.instances[i].outstanding.saturating_add(free);
                    cap = Some(cap.map_or(combined, |c| c.min(combined)));
                }
                PlacementOverride {
                    masked: !self.health[i].placeable()
                        || self.stall_active(i)
                        || self.members_blocked(i)
                        || (self.standby == Some(i) && !self.degraded),
                    transfer_factor: if self.submit_clock < self.link_expire[i] {
                        self.link_factor[i]
                    } else {
                        1.0
                    },
                    cap,
                }
            })
            .collect()
    }

    /// Whether any instance could ever serve a window again: counts
    /// transiently-full, stalled, down-but-probeable and (not yet
    /// activated) standby instances; only a fleet of permanently dead or
    /// zero-capacity instances is hopeless.
    fn any_hope(&self) -> bool {
        self.models.iter().enumerate().any(|(i, m)| {
            m.max_outstanding > 0
                && !self.health[i].is_permanently_down()
                && !self.members_dead(i)
        })
    }

    /// Recompute degraded mode: placeable primary capacity (standby
    /// excluded) below `degraded_capacity_frac` of the full primary
    /// fleet. Entering degraded mode unmasks the standby and clamps the
    /// AIMD burst; recovery exits it.
    fn update_degraded(&mut self) {
        let mut full = 0.0f64;
        let mut avail = 0.0f64;
        for (i, m) in self.models.iter().enumerate() {
            if self.standby == Some(i) || m.max_outstanding == 0 {
                continue;
            }
            // Clamp the uniform model's unbounded budget so the sum
            // stays a meaningful ratio.
            let cap = m.max_outstanding.min(1 << 20) as f64;
            full += cap;
            if self.health[i].placeable() && !self.stall_active(i) && !self.members_blocked(i) {
                avail += self.health[i].probe_cap().map_or(cap, |c| (c as f64).min(cap));
            }
        }
        let degraded = full > 0.0 && avail < self.cfg.faults.degraded_capacity_frac * full;
        if degraded && !self.degraded {
            self.fault_stats.degraded_entries += 1;
        } else if !degraded && self.degraded {
            self.fault_stats.degraded_exits += 1;
        }
        self.degraded = degraded;
    }

    /// Submit one window to the fleet, walking instances in ascending
    /// placement-cost order ([`rank_with`]): the cheapest healthy
    /// instance under its concurrency budget gets the window; a
    /// bounded-queue refusal spills to the next sibling (`try_submit`
    /// hands the payload back), and a dead instance is marked down and
    /// skipped. Only when no instance could ever serve again does the
    /// window fail; otherwise it returns for the AIMD hold-and-retry.
    fn submit_placed(&mut self, tenant: u32, w: PendingWindow) -> SubmitOutcome {
        self.fire_submission_faults();
        self.update_degraded();
        let qos = self.qos_of(tenant);
        let PendingWindow {
            seq_no,
            start,
            y,
            u,
            attempts,
            not_before,
            born,
        } = w;
        // Retained so a stranded window can be re-placed (and for
        // warm-start refinement inputs).
        let payload = (y.clone(), u.clone());
        let mut req = RecoveryRequest {
            id: encode_id(tenant, seq_no),
            y,
            u,
        };
        let outstanding: Vec<usize> = self.instances.iter().map(|r| r.outstanding).collect();
        let overrides = self.placement_overrides();
        let order = rank_with(&self.models, &outstanding, &overrides);
        let mut went_down = false;
        for &i in &order {
            match self.instances[i].svc.try_submit(req) {
                Ok(rx) => {
                    self.instances[i].outstanding += 1;
                    self.submit_clock += 1;
                    self.metrics.on_instance_placed(i);
                    self.metrics.on_tier_placed(qos);
                    self.metrics
                        .on_instance_queue_depth(i, self.instances[i].outstanding);
                    // A partitioned placement occupies one slot on
                    // every member board the plan spans.
                    for k in 0..self.members[i].len() {
                        let m = self.members[i][k];
                        self.instances[m].outstanding += 1;
                        self.metrics
                            .on_instance_queue_depth(m, self.instances[m].outstanding);
                    }
                    self.in_flight.push_back(InFlightWindow {
                        tenant,
                        seq_no,
                        start,
                        born,
                        instance: i,
                        payload,
                        attempts,
                        submitted_at: Instant::now(),
                        rx,
                    });
                    self.in_flight_max = self.in_flight_max.max(self.in_flight.len());
                    return SubmitOutcome::Accepted;
                }
                Err((e, back)) => {
                    if e.is_overload() {
                        self.metrics.on_instance_reject(i);
                    } else if e.is_service_down() {
                        // The instance died between ranking and submit
                        // (or a probe hit a corpse): mark it permanently
                        // down and spill to the next sibling.
                        self.fault_stats.detected_submit_down += 1;
                        went_down |= self.health[i].on_dead(self.rounds, true);
                    }
                    req = back;
                }
            }
        }
        if went_down {
            self.update_degraded();
        }
        if self.any_hope() {
            // Transient: budget-excluded, overloaded, stalled or
            // probeable-down instances can still free up — hold the
            // window rather than drop it.
            SubmitOutcome::Saturated(PendingWindow {
                seq_no,
                start,
                y: payload.0,
                u: payload.1,
                attempts,
                not_before,
                born,
            })
        } else {
            SubmitOutcome::Failed
        }
    }

    /// Move queued windows into the executor fleet: round-robin over
    /// tenants, up to the current AIMD burst per tenant per round,
    /// repeating until the queues drain or the fleet pushes back. Each
    /// window is placed by [`submit_placed`](Self::submit_placed)
    /// (cheapest instance first, spill to siblings). A fleet-wide
    /// refusal halves the burst and ends the pump; the refused window
    /// goes back to the front of its queue (payload moved, not cloned)
    /// and that tenant leads the next sweep, so sustained saturation
    /// rotates freed slots across tenants instead of starving high ids.
    /// A clean round with submissions grows the burst. Returns the
    /// number of windows submitted.
    pub fn pump(&mut self) -> usize {
        self.rounds += 1;
        for h in &mut self.health {
            h.tick(&self.cfg.faults.health, self.rounds);
        }
        self.update_degraded();
        // Priority-ordered sweep: realtime tenants pump before standard
        // before batch, so under saturation the freed slots reach the
        // tightest-SLO tier first. Within a tier the rotation resumes at
        // the tenant the fleet last refused (anti-starvation), exactly
        // the pre-QoS behaviour when every tenant is Standard.
        let mut by_tier: [Vec<u32>; 3] = Default::default();
        for (&id, t) in &self.tenants {
            by_tier[t.qos.index()].push(id);
        }
        let mut ids: Vec<u32> = Vec::with_capacity(self.tenants.len());
        for mut list in by_tier {
            let pivot = list.iter().position(|&id| id >= self.rr_resume).unwrap_or(0);
            list.rotate_left(pivot);
            ids.extend(list);
        }
        if ids.is_empty() {
            return 0;
        }
        let mut total = 0usize;
        loop {
            // Degraded mode caps the burst so a shrunken fleet is not
            // slammed with the healthy-fleet submission rate.
            let burst = if self.degraded {
                self.burst.current().min(self.cfg.faults.degraded_burst.max(1))
            } else {
                self.burst.current()
            };
            let mut submitted = 0usize;
            let mut overloaded = false;
            'tenants: for &tid in &ids {
                for _ in 0..burst {
                    let round = self.rounds;
                    // Tenants are never removed, but a missing entry must
                    // not panic the pump loop.
                    let Some(t) = self.tenants.get_mut(&tid) else { break };
                    // A head window still in retry backoff defers — and
                    // blocks the tenant's later windows, preserving
                    // per-tenant submission order.
                    let ready = t.queue.front().is_some_and(|w| w.not_before <= round);
                    if !ready {
                        break;
                    }
                    let Some(w) = t.queue.pop_front() else { break };
                    match self.submit_placed(tid, w) {
                        SubmitOutcome::Accepted => {
                            submitted += 1;
                        }
                        SubmitOutcome::Failed => {
                            // No instance can ever serve this window.
                            if let Some(t) = self.tenants.get_mut(&tid) {
                                t.failed += 1;
                                self.metrics.on_tier_failed(t.qos);
                            }
                        }
                        SubmitOutcome::Saturated(back) => {
                            // Transient backpressure: hold the window,
                            // back off, let this tenant lead next pump.
                            if let Some(t) = self.tenants.get_mut(&tid) {
                                t.queue.push_front(back);
                            }
                            self.rr_resume = tid;
                            overloaded = true;
                            break 'tenants;
                        }
                    }
                }
            }
            total += submitted;
            if overloaded {
                self.burst.backoff();
                break;
            }
            if submitted == 0 {
                break;
            }
            self.burst.grow();
        }
        total
    }

    /// Non-blocking: record responses that are already available. Each
    /// *tenant's* windows are recorded strictly in submission order (a
    /// pending window blocks that tenant's later ones, keeping the
    /// warm-start cache seeded from the true previous window), but
    /// tenants are reaped independently — a slow window on one instance
    /// does not hold completed windows, or their placement slots, on a
    /// faster sibling.
    ///
    /// This is a single linear pass over the in-flight deque (entries
    /// move into a kept deque rather than being removed mid-scan, so
    /// deep fleets stay O(n)). The fault layer hangs off the same pass:
    /// a window past its deadline is hedged (retried on a sibling while
    /// the original is parked in `late`), and a disconnected channel
    /// (service death) fails the window over immediately. Returns the
    /// number of responses processed.
    pub fn poll(&mut self) -> usize {
        let mut received = 0usize;
        let mut blocked: BTreeSet<u32> = BTreeSet::new();
        let deadline = self.cfg.faults.deadline;
        let mut kept: VecDeque<InFlightWindow> = VecDeque::with_capacity(self.in_flight.len());
        for inf in std::mem::take(&mut self.in_flight) {
            if blocked.contains(&inf.tenant) {
                kept.push_back(inf);
                continue;
            }
            // A partitioned plan with a permanently-down member can
            // never answer (the pipeline spans the dead board): fail
            // the window over to a whole-window sibling now. Dropping
            // `rx` here also guarantees no late duplicate.
            if self.members_dead(inf.instance) {
                self.invalidate_partitioned(inf);
                continue;
            }
            // A stalled instance's responses are deliberately left
            // unread (the stall models an unresponsive instance): the
            // window either outlives the stall or blows its deadline.
            if self.stall_active(inf.instance) {
                if inf.submitted_at.elapsed() >= deadline {
                    self.hedge_timeout(inf);
                } else {
                    blocked.insert(inf.tenant);
                    kept.push_back(inf);
                }
                continue;
            }
            match inf.rx.try_recv() {
                Ok(resp) => {
                    self.record(inf, resp, false);
                    received += 1;
                }
                Err(TryRecvError::Empty) => {
                    if inf.submitted_at.elapsed() >= deadline {
                        self.hedge_timeout(inf);
                    } else {
                        blocked.insert(inf.tenant);
                        kept.push_back(inf);
                    }
                }
                Err(TryRecvError::Disconnected) => {
                    self.handle_disconnect(inf);
                }
            }
        }
        self.in_flight = kept;
        received += self.sweep_late();
        received
    }

    /// Drain late responses from hedged originals: a completion races
    /// its retry through the `done` set (first one wins, the loser is
    /// dropped as a duplicate); a disconnect just retires the channel —
    /// the retry already owns the window.
    fn sweep_late(&mut self) -> usize {
        if self.late.is_empty() {
            return 0;
        }
        let mut received = 0usize;
        let mut kept = Vec::with_capacity(self.late.len());
        for inf in std::mem::take(&mut self.late) {
            match inf.rx.try_recv() {
                Ok(resp) => {
                    self.record(inf, resp, true);
                    received += 1;
                }
                Err(TryRecvError::Empty) => kept.push(inf),
                Err(TryRecvError::Disconnected) => {}
            }
        }
        self.late = kept;
        received
    }

    /// One member board of a partitioned plan is permanently down:
    /// take the whole plan out of the roster (it spans the dead board)
    /// and re-place its window on a surviving whole-window sibling.
    fn invalidate_partitioned(&mut self, inf: InFlightWindow) {
        self.fault_stats.failed_over += 1;
        self.metrics.on_instance_failover(inf.instance);
        self.release_slot(inf.instance);
        self.health[inf.instance].on_dead(self.rounds, true);
        self.retry_or_fail(
            inf.tenant,
            inf.seq_no,
            inf.start,
            inf.born,
            inf.payload,
            inf.attempts,
        );
    }

    /// A window blew its completion deadline: charge the instance an
    /// anomaly, release its slot, park the original submission in
    /// `late` (its response may still arrive) and hedge a retry onto a
    /// sibling.
    fn hedge_timeout(&mut self, inf: InFlightWindow) {
        self.fault_stats.detected_timeouts += 1;
        self.fault_stats.failed_over += 1;
        self.metrics.on_instance_failover(inf.instance);
        self.release_slot(inf.instance);
        self.health[inf.instance].on_anomaly(&self.cfg.faults.health, self.rounds);
        self.hedged.insert(encode_id(inf.tenant, inf.seq_no));
        let (tenant, seq_no, start, born, attempts) =
            (inf.tenant, inf.seq_no, inf.start, inf.born, inf.attempts);
        let payload = inf.payload.clone();
        self.late.push(inf);
        self.retry_or_fail(tenant, seq_no, start, born, payload, attempts);
    }

    /// A response channel died (service killed or shut down
    /// mid-request): charge the instance an anomaly — repeated
    /// disconnects take it down — and fail the window over.
    fn handle_disconnect(&mut self, inf: InFlightWindow) {
        self.fault_stats.detected_disconnects += 1;
        self.fault_stats.failed_over += 1;
        self.metrics.on_instance_failover(inf.instance);
        self.release_slot(inf.instance);
        self.health[inf.instance].on_anomaly(&self.cfg.faults.health, self.rounds);
        self.retry_or_fail(
            inf.tenant,
            inf.seq_no,
            inf.start,
            inf.born,
            inf.payload,
            inf.attempts,
        );
    }

    /// Re-enqueue a stranded window at the front of its tenant queue
    /// with exponential-backoff-with-jitter `not_before`, or fail it for
    /// good once the retry budget is spent.
    fn retry_or_fail(
        &mut self,
        tenant: u32,
        seq_no: u32,
        start: usize,
        born: Instant,
        payload: (Vec<f32>, Vec<f32>),
        attempts: u32,
    ) {
        let pol = self.cfg.faults.retry;
        if attempts >= pol.max_retries {
            self.fault_stats.exhausted += 1;
            let id = encode_id(tenant, seq_no);
            if self.hedged.contains(&id) {
                // A late original must not resurrect a window already
                // accounted as failed.
                self.done.insert(id);
            }
            if let Some(t) = self.tenants.get_mut(&tenant) {
                t.failed += 1;
                self.metrics.on_tier_failed(t.qos);
            }
            return;
        }
        let delay = pol.delay(attempts, &mut self.jitter);
        self.fault_stats.retries += 1;
        let w = PendingWindow {
            seq_no,
            start,
            y: payload.0,
            u: payload.1,
            attempts: attempts + 1,
            not_before: self.rounds + delay,
            born,
        };
        if let Some(t) = self.tenants.get_mut(&tenant) {
            // Front of the queue: the stranded window is the tenant's
            // oldest; retries may exceed the queue cap rather than shed.
            t.queue.push_front(w);
            t.queue_high = t.queue_high.max(t.queue.len());
        }
    }

    /// Blocking: pump and receive until every queued window has been
    /// submitted and every in-flight response has arrived (or been
    /// failed over and resolved by the fault layer). The loop never
    /// blocks on a single channel — it spins poll with a short sleep so
    /// deadline timeouts, health probes and retry backoffs keep firing
    /// even when the oldest outstanding window is stuck on a stalled
    /// instance. Returns the number of windows recorded.
    pub fn drain(&mut self) -> usize {
        let mut received = 0usize;
        loop {
            let submitted = self.pump();
            let polled = self.poll();
            received += polled;
            if polled > 0 {
                // Freed slots may unblock queued windows: pump again
                // before blocking.
                continue;
            }
            if !self.in_flight.is_empty() || !self.late.is_empty() {
                // Responses outstanding: wait briefly and re-poll (a
                // bounded sleep, not a blocking recv, so the fault
                // clocks keep advancing).
                std::thread::sleep(Duration::from_micros(500));
                continue;
            }
            if self.queued_windows() == 0 {
                break;
            }
            if submitted == 0 {
                if self
                    .tenants
                    .values()
                    .any(|t| t.queue.front().is_some_and(|w| w.not_before > self.rounds))
                {
                    // Head windows deferred by retry backoff: let the
                    // round clock advance rather than shed work the
                    // fault layer still owns.
                    continue;
                }
                // Nothing in flight, nothing submittable, nothing
                // deferred (pathological config, e.g. a zero-depth
                // service queue): shed the leftovers rather than spin
                // forever.
                for t in self.tenants.values_mut() {
                    let n = t.queue.len() as u64;
                    t.queue.clear();
                    t.shed += n;
                    for _ in 0..n {
                        self.metrics.on_shed();
                        self.metrics.on_tier_shed(t.qos);
                    }
                }
                break;
            }
        }
        received
    }

    /// Windows sitting in tenant queues, not yet submitted.
    pub fn queued_windows(&self) -> usize {
        self.tenants.values().map(|t| t.queue.len()).sum()
    }

    /// Windows submitted and awaiting a response.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Take the recovered windows accumulated so far (arrival order).
    pub fn take_results(&mut self) -> Vec<RecoveredWindow> {
        std::mem::take(&mut self.results)
    }

    /// Point-in-time streaming counters.
    pub fn stats(&self) -> StreamStats {
        let mut s = StreamStats {
            burst_backoffs: self.burst.backoffs(),
            burst_final: self.burst.current(),
            in_flight_max: self.in_flight_max,
            ..StreamStats::default()
        };
        for (&tid, t) in &self.tenants {
            s.samples_pushed += t.samples;
            s.windows_emitted += t.emitted;
            s.windows_completed += t.completed;
            s.windows_shed += t.shed;
            s.windows_failed += t.failed;
            let tier = &mut s.per_tier[t.qos.index()];
            tier.emitted += t.emitted;
            tier.completed += t.completed;
            tier.shed += t.shed;
            tier.failed += t.failed;
            s.tenant_queue_max = s.tenant_queue_max.max(t.queue_high);
            s.refine_warm_iters += t.refine_warm_iters;
            s.refine_cold_iters += t.refine_cold_iters;
            s.refine_paired += t.refine_paired;
            s.per_tenant.push(TenantStats {
                tenant: tid,
                samples: t.samples,
                emitted: t.emitted,
                completed: t.completed,
                shed: t.shed,
                failed: t.failed,
                refine_warm_iters: t.refine_warm_iters,
                refine_cold_iters: t.refine_cold_iters,
                refine_paired: t.refine_paired,
                refine_first_iters: t.refine_first_iters,
            });
        }
        // Per-instance counters have their single source of truth in the
        // metrics sink; stats() is just a model-labelled view of them.
        // (The sink records the outstanding depth at every submit, so its
        // high-water mark is exactly the outstanding_max.)
        let msnap = self.metrics.snapshot();
        for (idx, model) in self.models.iter().enumerate() {
            let c = msnap.per_instance.get(idx).copied().unwrap_or_default();
            s.per_instance.push(InstanceStats {
                name: model.name.clone(),
                placed: c.placed,
                completed: c.completed,
                outstanding_max: c.queue_depth_max as usize,
                window_cycles: model.window_cycles,
                modeled_cycles: c.modeled_cycles,
                health: self.health[idx].state().as_str().to_string(),
                failed_over: c.failed_over,
                downs: self.health[idx].downs,
            });
        }
        s.faults = self.fault_stats();
        s.degraded = self.degraded;
        s
    }

    /// Fire an armed bit-flip if `instance` just delivered its
    /// trigger-count-th response.
    fn due_flip(&mut self, instance: usize) -> bool {
        let count = self.responses_from[instance];
        if let Some(pos) = self.plan.iter().position(|e| {
            matches!(e.kind, FaultKind::BitFlip) && e.instance == instance && e.at <= count
        }) {
            self.plan.remove(pos);
            return true;
        }
        false
    }

    /// Account one response. `late` marks a hedged original whose
    /// instance slot was already released at hedge time. The response
    /// runs the fidelity check first: a corrupted Θ invalidates the
    /// tenant's warm-start cache (a poisoned seed must not leak into the
    /// next window), charges the instance an anomaly, and retries the
    /// window instead of recording it.
    fn record(&mut self, inf: InFlightWindow, mut resp: RecoveryResponse, late: bool) {
        let InFlightWindow {
            tenant,
            seq_no,
            start,
            born,
            instance,
            payload,
            attempts,
            submitted_at: _,
            rx: _rx,
        } = inf;
        debug_assert_eq!(resp.id, encode_id(tenant, seq_no), "response demux mismatch");
        if !late {
            self.release_slot(instance);
        }
        let id = encode_id(tenant, seq_no);
        if self.hedged.contains(&id) && self.done.contains(&id) {
            // The hedged twin already completed (or exhausted): this
            // arrival is surplus.
            self.fault_stats.duplicates_dropped += 1;
            return;
        }
        self.responses_from[instance] += 1;
        if self.due_flip(instance)
            && corrupt_theta(&mut resp.theta, self.cfg.faults.theta_bound).is_some()
        {
            self.fault_stats.injected_flip += 1;
        }
        if fidelity_check(&resp.theta, self.cfg.faults.theta_bound).is_err() {
            self.fault_stats.detected_corruptions += 1;
            self.health[instance].on_anomaly(&self.cfg.faults.health, self.rounds);
            if let Some(t) = self.tenants.get_mut(&tenant) {
                t.warm_theta = None;
            }
            self.retry_or_fail(tenant, seq_no, start, born, payload, attempts);
            return;
        }
        if self.hedged.contains(&id) {
            self.done.insert(id);
        }
        self.health[instance].on_ok(&self.cfg.faults.health, self.rounds);
        if self.standby == Some(instance) {
            self.fault_stats.standby_windows += 1;
        }
        self.metrics
            .on_instance_complete(instance, self.models[instance].window_cycles);

        let mut refined = None;
        if self.cfg.warm_start.enabled {
            refined = self.refine_completed(tenant, &payload.0, &payload.1, &resp.theta);
        }
        if let Some(t) = self.tenants.get_mut(&tenant) {
            t.completed += 1;
            // Per-tier latency is end-to-end (enqueue → result), so SLO
            // accounting charges queue wait, not just service time.
            self.metrics.on_tier_completed(t.qos, born.elapsed());
        }
        self.results.push(RecoveredWindow {
            tenant,
            seq_no,
            start,
            theta: resp.theta,
            latency: resp.latency,
            refined,
            instance,
        });
    }

    /// Warm-start polish of one completed window. The served refinement
    /// seeds from the tenant's cached previous-window Θ when present
    /// (warm), from the NN proposal otherwise (cold); with
    /// [`WarmStartConfig::measure_cold`], warm-seeded windows also run
    /// the cold seed on the same data so the iteration saving is a
    /// paired measurement. The cache always advances to the refined Θ.
    fn refine_completed(
        &mut self,
        tenant: u32,
        y: &[f32],
        u: &[f32],
        theta_nn: &[f32],
    ) -> Option<RefinedWindow> {
        let window = self.cfg.window.window;
        let (xdim, udim) = (self.xdim, self.udim);
        let opts = self.cfg.warm_start.refine;
        let measure_cold = self.cfg.warm_start.measure_cold;
        let t = self.tenants.get_mut(&tenant)?;
        let warm_seed = t.warm_theta.take();
        let (seed, seeded_warm): (&[f32], bool) = match &warm_seed {
            Some(s) => (s.as_slice(), true),
            None => (theta_nn, false),
        };
        let out = match refine_window_theta(y, xdim, u, udim, window, seed, &opts) {
            Ok(out) => out,
            Err(_) => {
                // Refinement is best-effort: put the cache back untouched.
                t.warm_theta = warm_seed;
                return None;
            }
        };
        let mut cold_iters = None;
        if seeded_warm {
            if measure_cold {
                if let Ok(cold) = refine_window_theta(y, xdim, u, udim, window, theta_nn, &opts) {
                    cold_iters = Some(cold.iters);
                    t.refine_cold_iters += cold.iters;
                    t.refine_warm_iters += out.iters;
                    t.refine_paired += 1;
                }
            } else {
                t.refine_warm_iters += out.iters;
            }
        } else {
            t.refine_first_iters += out.iters;
        }
        t.warm_theta = Some(out.theta.clone());
        Some(RefinedWindow {
            theta: out.theta,
            iters: out.iters,
            cold_iters,
            seeded_warm,
            converged: out.converged,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, MockBackend, Service, ServiceConfig};

    #[test]
    fn plan_covers_every_sample_and_is_increasing() {
        let plan = window_plan(9, 4, 2);
        assert_eq!(plan, vec![0, 2, 4, 5]);
        let plan = window_plan(8, 4, 4);
        assert_eq!(plan, vec![0, 4]);
        assert_eq!(window_plan(4, 4, 1), vec![0]);
        assert!(window_plan(3, 4, 1).is_empty());
    }

    #[test]
    fn plan_clamps_lossy_strides() {
        // stride > window would skip samples; the plan must clamp.
        let plan = window_plan(10, 3, 100);
        for i in 0..10usize {
            assert!(plan.iter().any(|&s| s <= i && i < s + 3), "sample {i} uncovered");
        }
    }

    #[test]
    fn windower_matches_plan_including_tail() {
        let cfg = WindowConfig {
            window: 5,
            stride: 3,
        };
        let len = 13usize;
        let mut w = Windower::new(cfg, 2, 1);
        let mut starts = Vec::new();
        for i in 0..len {
            let y = [i as f32, -(i as f32)];
            let u = [0.5 * i as f32];
            if let Some((s, wy, wu)) = w.push(&y, &u) {
                assert_eq!(wy.len(), 5 * 2);
                assert_eq!(wu.len(), 5);
                // Payload rows must be the original samples.
                assert_eq!(wy[0], s as f32);
                assert_eq!(wu[4], 0.5 * (s + 4) as f32);
                starts.push(s);
            }
        }
        if let Some((s, _, _)) = w.finish() {
            starts.push(s);
        }
        assert_eq!(starts, window_plan(len, 5, 3));
        assert!(w.finish().is_none(), "finish must be idempotent");
    }

    #[test]
    fn windower_tail_payload_survives_trimming() {
        // Non-overlapping stride: the tail window reaches back before
        // next_start, so trim() must have kept those rows.
        let cfg = WindowConfig {
            window: 4,
            stride: 4,
        };
        let mut w = Windower::new(cfg, 1, 1);
        for i in 0..6 {
            w.push(&[i as f32], &[0.0]);
        }
        let (s, y, _) = w.finish().expect("tail window");
        assert_eq!(s, 2);
        assert_eq!(y, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn id_roundtrip() {
        for (t, q) in [(0u32, 0u32), (3, 17), (u32::MAX, u32::MAX), (7, 0)] {
            assert_eq!(decode_id(encode_id(t, q)), (t, q));
        }
    }

    fn mock_service(workers: usize, queue_depth: usize) -> Service {
        let cfg = ServiceConfig {
            workers,
            queue_depth,
            batcher: BatcherConfig {
                batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
        };
        Service::start(cfg, MockBackend::default)
    }

    fn push_stream(coord: &mut StreamCoordinator, tenant: u32, n: usize, fill: f32) {
        for i in 0..n {
            let y = vec![fill + i as f32 * 1e-3; 3];
            let u = vec![0.0f32];
            coord.push(tenant, &y, &u);
        }
    }

    #[test]
    fn streams_complete_and_attribute_to_tenants() {
        let svc = mock_service(2, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 16,
            },
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        for t in 0..4u32 {
            push_stream(&mut coord, t, 130, t as f32);
        }
        coord.flush_tails();
        coord.drain();
        let stats = coord.stats();
        let plan = window_plan(130, 64, 16);
        assert_eq!(stats.windows_emitted, 4 * plan.len() as u64);
        assert_eq!(stats.windows_completed, stats.windows_emitted);
        assert_eq!(stats.windows_shed, 0);
        assert_eq!(stats.windows_failed, 0);
        let results = coord.take_results();
        assert_eq!(results.len(), stats.windows_completed as usize);
        for t in 0..4u32 {
            let mut starts: Vec<usize> = results
                .iter()
                .filter(|r| r.tenant == t)
                .map(|r| r.start)
                .collect();
            starts.sort_unstable();
            assert_eq!(starts, plan, "tenant {t} window starts");
        }
        // Per-tenant fairness: identical streams → identical completions.
        for pt in &stats.per_tenant {
            assert_eq!(pt.completed, plan.len() as u64, "tenant {}", pt.tenant);
        }
    }

    #[test]
    fn tenant_queue_overflow_sheds_oldest() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 1,
            },
            tenant_queue: 2,
            shed: ShedPolicy::Oldest,
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        // 64 + 9 samples → 10 windows emitted, queue cap 2, no pumping
        // in between → 8 shed, the 2 freshest survive.
        push_stream(&mut coord, 0, 73, 0.0);
        let stats = coord.stats();
        assert_eq!(stats.windows_emitted, 10);
        assert_eq!(stats.windows_shed, 8);
        assert_eq!(coord.queued_windows(), 2);
        coord.drain();
        let results = coord.take_results();
        let starts: Vec<usize> = results.iter().map(|r| r.start).collect();
        assert_eq!(starts, vec![8, 9], "oldest-shed must keep the freshest");
        assert_eq!(coord.metrics().snapshot().shed, 8);
    }

    #[test]
    fn tenant_queue_overflow_sheds_newest() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 1,
            },
            tenant_queue: 2,
            shed: ShedPolicy::Newest,
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        push_stream(&mut coord, 0, 73, 0.0);
        let stats = coord.stats();
        assert_eq!(stats.windows_emitted, 10);
        assert_eq!(stats.windows_shed, 8);
        coord.drain();
        let results = coord.take_results();
        let starts: Vec<usize> = results.iter().map(|r| r.start).collect();
        assert_eq!(starts, vec![0, 1], "newest-shed must keep the backlog");
    }

    #[test]
    fn service_overload_backs_off_and_still_completes_everything() {
        // Slow single-window backend + tiny service queue: pumping all
        // windows at once must hit typed overload, back off, and retry —
        // nothing may be shed or lost.
        let cfg = ServiceConfig {
            workers: 1,
            queue_depth: 1,
            batcher: BatcherConfig {
                batch: 1,
                max_wait: std::time::Duration::from_millis(1),
            },
        };
        let svc = Service::start(cfg, || MockBackend {
            batch: 1,
            delay: std::time::Duration::from_millis(5),
            ..Default::default()
        });
        let scfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 8,
            },
            burst_initial: 8,
            burst_max: 8,
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, scfg, 3, 1);
        push_stream(&mut coord, 0, 128, 1.0);
        push_stream(&mut coord, 1, 128, 2.0);
        coord.flush_tails();
        coord.drain();
        let stats = coord.stats();
        assert_eq!(stats.windows_completed, stats.windows_emitted);
        assert_eq!(stats.windows_shed, 0);
        assert!(stats.burst_backoffs > 0, "a depth-1 queue must trigger AIMD backoff");
    }

    #[test]
    fn placement_respects_budget_and_spills_to_sibling() {
        // A cheap instance with a budget of one outstanding window and an
        // expensive sibling: the first window goes cheap, the rest must
        // spill to the sibling rather than overfill the budget.
        let fleet = vec![
            (InstanceModel::synthetic("fast", 1e-6, 1), mock_service(1, 256)),
            (InstanceModel::synthetic("slow", 1e-3, 100), mock_service(1, 256)),
        ];
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 1,
            },
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::with_fleet(fleet, cfg, 3, 1).expect("non-empty fleet");
        push_stream(&mut coord, 0, 66, 0.0); // 3 windows, no pumping yet
        assert_eq!(coord.queued_windows(), 3);
        coord.pump();
        let stats = coord.stats();
        assert_eq!(stats.per_instance.len(), 2);
        assert_eq!(stats.per_instance[0].placed, 1, "budget of 1 must hold");
        assert_eq!(stats.per_instance[1].placed, 2, "overflow must spill");
        assert!(stats.per_instance[0].outstanding_max <= 1);
        coord.drain();
        let stats = coord.stats();
        assert_eq!(stats.windows_completed, 3);
        assert_eq!(
            stats.per_instance.iter().map(|i| i.completed).sum::<u64>(),
            3
        );
        assert_eq!(
            stats.per_instance[1].modeled_cycles,
            stats.per_instance[1].completed * 1_000
        );
        // Placement decisions are observable through the metrics sink.
        let m = coord.metrics().snapshot();
        assert_eq!(m.per_instance.len(), 2);
        assert_eq!(m.per_instance[0].placed, 1);
        assert_eq!(m.per_instance[1].placed, 2);
        assert_eq!(
            m.per_instance.iter().map(|i| i.completed).sum::<u64>(),
            3
        );
        // Results carry their serving instance.
        let results = coord.take_results();
        assert!(results.iter().any(|r| r.instance == 1));
    }

    #[test]
    fn empty_fleet_is_a_typed_config_error_not_a_panic() {
        let Err(err) = StreamCoordinator::with_fleet(Vec::new(), StreamConfig::default(), 3, 1)
        else {
            panic!("empty roster must be rejected");
        };
        assert!(format!("{err}").contains("fleet"), "error names the roster: {err}");
    }

    #[test]
    fn warm_start_pairs_windows_and_reduces_iterations() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 16,
            },
            warm_start: WarmStartConfig {
                enabled: true,
                ..WarmStartConfig::default()
            },
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        for i in 0..128 {
            let t = i as f32 * 0.05;
            let y = [(0.7 * t).sin(), 0.5 * (0.9 * t).cos(), 0.0];
            let u = [0.2 * (0.3 * t).sin()];
            coord.push(0, &y, &u);
        }
        coord.flush_tails();
        coord.drain();
        let mut results = coord.take_results();
        results.sort_by_key(|r| r.seq_no);
        assert_eq!(results.len(), window_plan(128, 64, 16).len());
        let first = results[0].refined.as_ref().expect("refinement ran");
        assert!(!first.seeded_warm, "no cache before the first window");
        assert!(first.cold_iters.is_none());
        for r in &results[1..] {
            let ref_w = r.refined.as_ref().expect("refinement ran");
            assert!(ref_w.seeded_warm, "window {} must warm-start", r.seq_no);
            assert!(ref_w.converged);
            let cold = ref_w.cold_iters.expect("paired cold measurement");
            assert!(
                ref_w.iters <= cold,
                "window {}: warm {} vs cold {}",
                r.seq_no,
                ref_w.iters,
                cold
            );
        }
        let stats = coord.stats();
        assert_eq!(stats.refine_paired as usize, results.len() - 1);
        assert!(
            stats.refine_warm_iters < stats.refine_cold_iters,
            "warm {} must beat cold {} in total",
            stats.refine_warm_iters,
            stats.refine_cold_iters
        );
        // The raw service Θ stays bitwise what the backend produced —
        // refinement is reported alongside, never in place.
        for r in &results {
            assert_eq!(r.theta.len(), 45);
            let win_mean = r.theta[0]; // mock: theta[0] = mean(y)
            assert!(win_mean.is_finite());
        }
    }

    #[test]
    fn warm_start_off_leaves_results_unrefined() {
        let svc = mock_service(1, 256);
        let mut coord = StreamCoordinator::new(svc, StreamConfig::default(), 3, 1);
        push_stream(&mut coord, 0, 64, 0.5);
        coord.drain();
        let results = coord.take_results();
        assert_eq!(results.len(), 1);
        assert!(results[0].refined.is_none());
        let stats = coord.stats();
        assert_eq!(stats.refine_paired, 0);
    }

    #[test]
    fn poll_is_nonblocking_and_partial() {
        let svc = mock_service(1, 256);
        let mut coord = StreamCoordinator::new(svc, StreamConfig::default(), 3, 1);
        push_stream(&mut coord, 0, 64, 0.5);
        coord.pump();
        // Wait until the single full window has certainly been served.
        let mut got = 0;
        for _ in 0..200 {
            got += coord.poll();
            if got > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(got, 1);
        assert_eq!(coord.in_flight(), 0);
        assert_eq!(coord.take_results().len(), 1);
    }

    #[test]
    fn offer_window_validates_geometry_and_enqueues() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 8,
                stride: 8,
            },
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        // Wrong payload geometry is a typed config error.
        assert!(coord.offer_window(0, 0, vec![0.0; 5], vec![0.0; 8]).is_err());
        coord
            .offer_window(0, 0, vec![0.5; 8 * 3], vec![0.0; 8])
            .unwrap();
        coord
            .offer_window(0, 4, vec![0.25; 8 * 3], vec![0.0; 8])
            .unwrap();
        assert_eq!(coord.queued_windows(), 2);
        coord.drain();
        let results = coord.take_results();
        assert_eq!(results.len(), 2);
        // seq_nos are assigned in offer order, like windower emissions.
        assert_eq!(results.iter().map(|r| r.seq_no).max(), Some(1));
        let stats = coord.stats();
        assert_eq!(stats.windows_emitted, 2);
        assert_eq!(stats.windows_completed, 2);
    }

    #[test]
    fn shed_to_budget_drops_batch_before_standard_before_realtime() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 8,
                stride: 8,
            },
            tenant_queue: 64,
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        coord.set_qos(0, QosClass::Realtime);
        coord.set_qos(1, QosClass::Standard);
        coord.set_qos(2, QosClass::Batch);
        for tenant in 0..3u32 {
            for k in 0..10 {
                coord
                    .offer_window(tenant, k, vec![0.1; 8 * 3], vec![0.0; 8])
                    .unwrap();
            }
        }
        assert_eq!(coord.queued_windows(), 30);
        // First sweep: only batch pays.
        let shed = coord.shed_to_budget(25);
        assert_eq!(shed, [0, 0, 5]);
        // Second sweep: batch drains fully before standard is touched.
        let shed = coord.shed_to_budget(12);
        assert_eq!(shed, [0, 8, 5]);
        // Realtime sheds only once every lower tier is empty.
        let shed = coord.shed_to_budget(0);
        assert_eq!(shed, [10, 2, 0]);
        assert_eq!(coord.queued_windows(), 0);
        let stats = coord.stats();
        assert_eq!(stats.per_tier[QosClass::Batch.index()].shed, 10);
        assert_eq!(stats.per_tier[QosClass::Standard.index()].shed, 10);
        assert_eq!(stats.per_tier[QosClass::Realtime.index()].shed, 10);
        // The metrics sink mirrors the tier attribution.
        let m = coord.metrics().snapshot();
        assert_eq!(m.per_tier[QosClass::Batch.index()].shed, 10);
        assert_eq!(m.shed, 30);
    }

    #[test]
    fn queued_at_or_above_sees_same_and_higher_priority_backlog() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 8,
                stride: 8,
            },
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        coord.set_qos(0, QosClass::Realtime);
        coord.set_qos(1, QosClass::Standard);
        coord.set_qos(2, QosClass::Batch);
        for (tenant, n) in [(0u32, 2usize), (1, 3), (2, 4)] {
            for k in 0..n {
                coord
                    .offer_window(tenant, k, vec![0.1; 8 * 3], vec![0.0; 8])
                    .unwrap();
            }
        }
        assert_eq!(coord.queued_at_or_above(QosClass::Realtime), 2);
        assert_eq!(coord.queued_at_or_above(QosClass::Standard), 5);
        assert_eq!(coord.queued_at_or_above(QosClass::Batch), 9);
        assert!(coord.placement_slots() > 0);
    }

    #[test]
    fn retarget_models_swaps_prefix_and_rejects_bad_lengths() {
        let fleet = vec![
            (InstanceModel::synthetic("a", 1e-6, 4), mock_service(1, 64)),
            (InstanceModel::synthetic("b", 1e-3, 4), mock_service(1, 64)),
        ];
        let mut coord =
            StreamCoordinator::with_fleet(fleet, StreamConfig::default(), 3, 1).unwrap();
        assert!(coord.retarget_models(Vec::new()).is_err());
        assert!(coord
            .retarget_models(vec![InstanceModel::synthetic("x", 1e-6, 4); 3])
            .is_err());
        coord
            .retarget_models(vec![InstanceModel::synthetic("a2", 2e-6, 4)])
            .unwrap();
        let stats = coord.stats();
        assert_eq!(stats.per_instance[0].name, "a2");
        assert_eq!(stats.per_instance[1].name, "b", "suffix keeps its model");
    }
}

//! Streaming recovery pipeline: continuous per-tenant sample streams →
//! overlapping recovery windows → the sharded executor fleet.
//!
//! MERINDA's serving claim is that model recovery should run as a
//! *streaming dataflow*, not a batch of one-shot kernel launches. This
//! module is the software half of that claim: each tenant (a deployed
//! system emitting telemetry) pushes `(y, u)` samples one at a time; a
//! per-tenant [`Windower`] slices the stream into overlapping recovery
//! windows; the [`StreamCoordinator`] holds the ready windows in bounded
//! per-tenant queues and pumps them into a [`Service`] with round-robin
//! fairness and an AIMD burst controller
//! ([`AimdBurst`](super::batcher::AimdBurst)).
//!
//! Overload handling is explicit and two-tiered:
//! * the *service* queue rejecting with a typed
//!   [`Overloaded`](crate::util::Error::Overloaded) error is treated as
//!   transient backpressure — the window is held, the burst halves, and
//!   the submit retries on a later pump;
//! * a *tenant* queue overflowing sheds a window under a configured
//!   [`ShedPolicy`] (drop the oldest for freshest-data semantics, or the
//!   newest for complete-the-backlog semantics), counted per tenant and
//!   in the shared [`Metrics`](super::metrics::Metrics) sink.
//!
//! The pipeline works against any [`InferenceBackend`]
//! (native f32 or quantized fixed-point): recovered windows are bitwise
//! identical to submitting the same windows through
//! [`Service::recover_many`], which `merinda soak` verifies by default
//! and `rust/tests/streaming.rs` asserts on both backends.
//!
//! [`InferenceBackend`]: super::service::InferenceBackend

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use super::batcher::AimdBurst;
use super::metrics::Metrics;
use super::service::{RecoveryRequest, RecoveryResponse, Service};

/// How a continuous stream is sliced into recovery windows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowConfig {
    /// Samples per recovery window (the model's `seq`).
    pub window: usize,
    /// Samples between consecutive window starts. Values above `window`
    /// would drop samples, so configs are normalized to `1..=window` —
    /// windowing is lossless by construction.
    pub stride: usize,
}

impl Default for WindowConfig {
    fn default() -> Self {
        WindowConfig {
            window: 64,
            stride: 16,
        }
    }
}

impl WindowConfig {
    /// Clamp into the lossless regime: `window ≥ 1`, `1 ≤ stride ≤ window`.
    pub fn normalized(self) -> WindowConfig {
        let window = self.window.max(1);
        WindowConfig {
            window,
            stride: self.stride.clamp(1, window),
        }
    }
}

/// Window start indices for a finite stream of `len` samples.
///
/// The pure-function mirror of [`Windower`]: starts advance by `stride`
/// (clamped into `1..=window`), and a final tail window anchored at
/// `len - window` is appended when the strided walk would leave trailing
/// samples uncovered. Guarantees, for any `len ≥ window`:
/// * every sample index in `0..len` is inside at least one window
///   (losslessness), and
/// * starts are strictly increasing.
///
/// Streams shorter than one window yield no full window and return an
/// empty plan.
pub fn window_plan(len: usize, window: usize, stride: usize) -> Vec<usize> {
    let cfg = WindowConfig { window, stride }.normalized();
    let (window, stride) = (cfg.window, cfg.stride);
    if len < window {
        return Vec::new();
    }
    let mut starts = Vec::new();
    let mut s = 0usize;
    loop {
        starts.push(s);
        if s + window >= len {
            break;
        }
        s += stride;
        if s + window > len {
            s = len - window;
        }
    }
    starts
}

/// Incremental windower for one tenant stream.
///
/// Accepts one `(y_row, u_row)` sample at a time and emits each window
/// as soon as its last sample arrives; [`Windower::finish`] flushes the
/// tail window at end-of-stream. The emitted start sequence is exactly
/// [`window_plan`] of the final stream length (asserted by the property
/// tests in `rust/tests/proptests.rs`). Memory is bounded: only the
/// samples still reachable by a future window are retained.
#[derive(Debug)]
pub struct Windower {
    window: usize,
    stride: usize,
    xdim: usize,
    udim: usize,
    /// Retained sample rows, starting at absolute index `base`.
    y: Vec<f32>,
    u: Vec<f32>,
    base: usize,
    /// Absolute start index of the next strided window.
    next_start: usize,
    /// Total samples pushed so far.
    pushed: usize,
    emitted: u64,
}

/// One emitted window: `(start_index, y_payload, u_payload)`.
pub type EmittedWindow = (usize, Vec<f32>, Vec<f32>);

impl Windower {
    pub fn new(cfg: WindowConfig, xdim: usize, udim: usize) -> Windower {
        let cfg = cfg.normalized();
        Windower {
            window: cfg.window,
            stride: cfg.stride,
            xdim,
            udim,
            y: Vec::new(),
            u: Vec::new(),
            base: 0,
            next_start: 0,
            pushed: 0,
            emitted: 0,
        }
    }

    /// Samples pushed so far.
    pub fn pushed(&self) -> usize {
        self.pushed
    }

    /// Windows emitted so far (including tail flushes).
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Push one sample; returns the window it completed, if any.
    pub fn push(&mut self, y_row: &[f32], u_row: &[f32]) -> Option<EmittedWindow> {
        assert_eq!(y_row.len(), self.xdim, "y row width");
        assert_eq!(u_row.len(), self.udim, "u row width");
        self.y.extend_from_slice(y_row);
        self.u.extend_from_slice(u_row);
        self.pushed += 1;
        let out = if self.pushed >= self.next_start + self.window {
            let s = self.next_start;
            let w = self.copy_window(s);
            self.next_start = s + self.stride;
            self.emitted += 1;
            Some(w)
        } else {
            None
        };
        self.trim();
        out
    }

    /// End-of-stream flush: emit the tail window at `len - window` when
    /// the strided walk left trailing samples uncovered. Idempotent
    /// until more samples arrive; streams shorter than one window have
    /// no full window to emit.
    pub fn finish(&mut self) -> Option<EmittedWindow> {
        if self.pushed < self.window {
            return None;
        }
        let covered = if self.emitted == 0 {
            0
        } else {
            self.next_start - self.stride + self.window
        };
        if covered >= self.pushed {
            return None;
        }
        let s = self.pushed - self.window;
        let w = self.copy_window(s);
        self.next_start = s + self.stride;
        self.emitted += 1;
        Some(w)
    }

    fn copy_window(&self, start: usize) -> EmittedWindow {
        debug_assert!(start >= self.base, "window start trimmed away");
        let off = start - self.base;
        let y = self.y[off * self.xdim..(off + self.window) * self.xdim].to_vec();
        let u = self.u[off * self.udim..(off + self.window) * self.udim].to_vec();
        (start, y, u)
    }

    /// Drop rows no future window (strided or tail) can reach: everything
    /// before `min(next_start, pushed - window)`.
    fn trim(&mut self) {
        let keep_from = self.next_start.min(self.pushed.saturating_sub(self.window));
        if keep_from > self.base {
            let rows = keep_from - self.base;
            self.y.drain(..rows * self.xdim);
            self.u.drain(..rows * self.udim);
            self.base = keep_from;
        }
    }
}

/// What to drop when a bounded tenant queue overflows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Drop the oldest queued window: the stream always serves the
    /// freshest telemetry (digital-twin semantics).
    Oldest,
    /// Drop the incoming window: finish the queued backlog first
    /// (batch-completion semantics).
    Newest,
}

impl ShedPolicy {
    /// Parse a CLI name (`merinda soak --shed oldest|newest`).
    pub fn from_name(name: &str) -> crate::util::Result<ShedPolicy> {
        match name {
            "oldest" => Ok(ShedPolicy::Oldest),
            "newest" => Ok(ShedPolicy::Newest),
            other => Err(crate::util::Error::config(format!(
                "unknown shed policy {other:?} (expected oldest or newest)"
            ))),
        }
    }
}

/// Streaming-pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    pub window: WindowConfig,
    /// Bounded per-tenant queue of ready-but-unsubmitted windows.
    pub tenant_queue: usize,
    /// Shed decision when a tenant queue overflows.
    pub shed: ShedPolicy,
    /// Initial AIMD burst (windows per tenant per pump round).
    pub burst_initial: usize,
    /// Maximum AIMD burst.
    pub burst_max: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            window: WindowConfig::default(),
            tenant_queue: 64,
            shed: ShedPolicy::Oldest,
            burst_initial: 1,
            burst_max: 8,
        }
    }
}

/// One recovered window, attributed back to its stream position.
#[derive(Clone, Debug)]
pub struct RecoveredWindow {
    pub tenant: u32,
    /// Per-tenant window sequence number (0-based emission order).
    pub seq_no: u32,
    /// Sample index of the window start within the tenant stream.
    pub start: usize,
    /// Estimated coefficients for the window.
    pub theta: Vec<f32>,
    /// Submit-to-response latency observed by the service.
    pub latency: Duration,
}

/// Per-tenant streaming counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantStats {
    pub tenant: u32,
    pub samples: u64,
    pub emitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub failed: u64,
}

/// Whole-pipeline streaming counters.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub samples_pushed: u64,
    pub windows_emitted: u64,
    pub windows_completed: u64,
    pub windows_shed: u64,
    pub windows_failed: u64,
    /// High-water mark across all tenant queues.
    pub tenant_queue_max: usize,
    /// High-water mark of windows awaiting a service response.
    pub in_flight_max: usize,
    /// AIMD backoffs taken (service overload events observed).
    pub burst_backoffs: u64,
    /// Burst size the controller converged to.
    pub burst_final: usize,
    pub per_tenant: Vec<TenantStats>,
}

/// Encode a `(tenant, seq_no)` pair into a service request id.
pub fn encode_id(tenant: u32, seq_no: u32) -> u64 {
    ((tenant as u64) << 32) | seq_no as u64
}

/// Recover the `(tenant, seq_no)` pair from a service request id.
pub fn decode_id(id: u64) -> (u32, u32) {
    ((id >> 32) as u32, id as u32)
}

struct PendingWindow {
    seq_no: u32,
    start: usize,
    y: Vec<f32>,
    u: Vec<f32>,
}

struct TenantState {
    windower: Windower,
    queue: VecDeque<PendingWindow>,
    queue_high: usize,
    samples: u64,
    emitted: u64,
    completed: u64,
    shed: u64,
    failed: u64,
    next_seq: u32,
}

struct InFlightWindow {
    tenant: u32,
    seq_no: u32,
    start: usize,
    rx: Receiver<RecoveryResponse>,
}

/// Bound a ready window into a tenant queue, shedding per policy on
/// overflow.
fn enqueue_window(
    t: &mut TenantState,
    w: PendingWindow,
    cap: usize,
    shed: ShedPolicy,
    metrics: &Metrics,
) {
    let cap = cap.max(1);
    if t.queue.len() >= cap {
        t.shed += 1;
        metrics.on_shed();
        match shed {
            // Drop the incoming window, keep the backlog.
            ShedPolicy::Newest => return,
            // Drop the stalest queued window, keep the fresh one.
            ShedPolicy::Oldest => {
                t.queue.pop_front();
            }
        }
    }
    t.queue.push_back(w);
    t.queue_high = t.queue_high.max(t.queue.len());
}

/// The streaming recovery pipeline: per-tenant windowers and bounded
/// queues in front of a sharded [`Service`].
///
/// Usage: [`push`](StreamCoordinator::push) samples as they arrive,
/// calling [`pump`](StreamCoordinator::pump) /
/// [`poll`](StreamCoordinator::poll) periodically to keep windows
/// flowing; at end-of-stream, [`flush_tails`](StreamCoordinator::flush_tails)
/// then [`drain`](StreamCoordinator::drain), and collect
/// [`take_results`](StreamCoordinator::take_results).
pub struct StreamCoordinator {
    svc: Service,
    cfg: StreamConfig,
    xdim: usize,
    udim: usize,
    tenants: BTreeMap<u32, TenantState>,
    in_flight: VecDeque<InFlightWindow>,
    burst: AimdBurst,
    results: Vec<RecoveredWindow>,
    in_flight_max: usize,
    /// Tenant id the next pump sweep starts from — set to the tenant the
    /// service refused, so a freed slot goes to the starved tenant first
    /// instead of restarting at the lowest id every time.
    rr_resume: u32,
}

impl StreamCoordinator {
    /// Wrap a running service. `xdim`/`udim` are the per-sample row
    /// widths the backend expects (padded dims, e.g. 3/1 for the
    /// canonical serving model).
    pub fn new(svc: Service, cfg: StreamConfig, xdim: usize, udim: usize) -> StreamCoordinator {
        let cfg = StreamConfig {
            window: cfg.window.normalized(),
            ..cfg
        };
        let burst = AimdBurst::new(cfg.burst_initial, cfg.burst_max);
        StreamCoordinator {
            svc,
            cfg,
            xdim,
            udim,
            tenants: BTreeMap::new(),
            in_flight: VecDeque::new(),
            burst,
            results: Vec::new(),
            in_flight_max: 0,
            rr_resume: 0,
        }
    }

    /// The shared service metrics sink (latency, batches, sheds).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.svc.metrics.clone()
    }

    /// Push one sample for `tenant`. If the sample completes a window it
    /// is enqueued (possibly shedding per policy). Cheap; call `pump`
    /// periodically to move enqueued windows into the service.
    pub fn push(&mut self, tenant: u32, y_row: &[f32], u_row: &[f32]) {
        let (wcfg, xdim, udim) = (self.cfg.window, self.xdim, self.udim);
        let t = self.tenants.entry(tenant).or_insert_with(|| TenantState {
            windower: Windower::new(wcfg, xdim, udim),
            queue: VecDeque::new(),
            queue_high: 0,
            samples: 0,
            emitted: 0,
            completed: 0,
            shed: 0,
            failed: 0,
            next_seq: 0,
        });
        t.samples += 1;
        if let Some((start, y, u)) = t.windower.push(y_row, u_row) {
            let w = PendingWindow {
                seq_no: t.next_seq,
                start,
                y,
                u,
            };
            t.next_seq += 1;
            t.emitted += 1;
            enqueue_window(t, w, self.cfg.tenant_queue, self.cfg.shed, &self.svc.metrics);
        }
    }

    /// End-of-stream: flush every tenant's tail window into its queue.
    pub fn flush_tails(&mut self) {
        for t in self.tenants.values_mut() {
            if let Some((start, y, u)) = t.windower.finish() {
                let w = PendingWindow {
                    seq_no: t.next_seq,
                    start,
                    y,
                    u,
                };
                t.next_seq += 1;
                t.emitted += 1;
                enqueue_window(t, w, self.cfg.tenant_queue, self.cfg.shed, &self.svc.metrics);
            }
        }
    }

    /// Move queued windows into the service: round-robin over tenants,
    /// up to the current AIMD burst per tenant per round, repeating
    /// until the queues drain or the service pushes back. A typed
    /// overload halves the burst and ends the pump; the refused window
    /// goes back to the front of its queue (payload returned by
    /// [`Service::try_submit`], no clone) and that tenant leads the next
    /// sweep, so sustained saturation rotates freed slots across tenants
    /// instead of starving high ids. A clean round with submissions
    /// grows the burst. Returns the number of windows submitted.
    pub fn pump(&mut self) -> usize {
        let ids: Vec<u32> = self.tenants.keys().copied().collect();
        if ids.is_empty() {
            return 0;
        }
        let pivot = ids.iter().position(|&id| id >= self.rr_resume).unwrap_or(0);
        let mut total = 0usize;
        loop {
            let burst = self.burst.current();
            let mut submitted = 0usize;
            let mut overloaded = false;
            'tenants: for k in 0..ids.len() {
                let tid = ids[(pivot + k) % ids.len()];
                let t = self.tenants.get_mut(&tid).expect("tenant vanished mid-pump");
                for _ in 0..burst {
                    let Some(w) = t.queue.pop_front() else { break };
                    let (seq_no, start) = (w.seq_no, w.start);
                    let req = RecoveryRequest {
                        id: encode_id(tid, seq_no),
                        y: w.y,
                        u: w.u,
                    };
                    match self.svc.try_submit(req) {
                        Ok(rx) => {
                            self.in_flight.push_back(InFlightWindow {
                                tenant: tid,
                                seq_no,
                                start,
                                rx,
                            });
                            self.in_flight_max = self.in_flight_max.max(self.in_flight.len());
                            submitted += 1;
                        }
                        Err((e, back)) if e.is_overload() => {
                            // Transient backpressure: hold the window
                            // (payload moved back, not cloned), back
                            // off, and let this tenant lead next pump.
                            t.queue.push_front(PendingWindow {
                                seq_no,
                                start,
                                y: back.y,
                                u: back.u,
                            });
                            self.rr_resume = tid;
                            overloaded = true;
                            break 'tenants;
                        }
                        Err(_) => {
                            // Permanent failure for this window.
                            t.failed += 1;
                        }
                    }
                }
            }
            total += submitted;
            if overloaded {
                self.burst.backoff();
                break;
            }
            if submitted == 0 {
                break;
            }
            self.burst.grow();
        }
        total
    }

    /// Non-blocking: record responses that are already available (in
    /// submission order, stopping at the first still-pending one).
    /// Returns the number of windows recorded.
    pub fn poll(&mut self) -> usize {
        let mut received = 0usize;
        while let Some(front) = self.in_flight.front() {
            match front.rx.try_recv() {
                Ok(resp) => {
                    let inf = self.in_flight.pop_front().expect("front in-flight vanished");
                    self.record(inf.tenant, inf.seq_no, inf.start, resp);
                    received += 1;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    let inf = self.in_flight.pop_front().expect("front in-flight vanished");
                    if let Some(t) = self.tenants.get_mut(&inf.tenant) {
                        t.failed += 1;
                    }
                }
            }
        }
        received
    }

    /// Blocking: pump and receive until every queued window has been
    /// submitted and every in-flight response has arrived. Returns the
    /// number of windows recorded.
    pub fn drain(&mut self) -> usize {
        let mut received = 0usize;
        loop {
            let submitted = self.pump();
            if let Some(inf) = self.in_flight.pop_front() {
                match inf.rx.recv() {
                    Ok(resp) => {
                        self.record(inf.tenant, inf.seq_no, inf.start, resp);
                        received += 1;
                    }
                    Err(_) => {
                        if let Some(t) = self.tenants.get_mut(&inf.tenant) {
                            t.failed += 1;
                        }
                    }
                }
            } else if self.queued_windows() == 0 {
                break;
            } else if submitted == 0 {
                // Nothing in flight, nothing submittable (pathological
                // config, e.g. a zero-depth service queue): shed the
                // leftovers rather than spin forever.
                for t in self.tenants.values_mut() {
                    let n = t.queue.len() as u64;
                    t.queue.clear();
                    t.shed += n;
                    for _ in 0..n {
                        self.svc.metrics.on_shed();
                    }
                }
                break;
            }
        }
        received
    }

    /// Windows sitting in tenant queues, not yet submitted.
    pub fn queued_windows(&self) -> usize {
        self.tenants.values().map(|t| t.queue.len()).sum()
    }

    /// Windows submitted and awaiting a response.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Take the recovered windows accumulated so far (arrival order).
    pub fn take_results(&mut self) -> Vec<RecoveredWindow> {
        std::mem::take(&mut self.results)
    }

    /// Point-in-time streaming counters.
    pub fn stats(&self) -> StreamStats {
        let mut s = StreamStats {
            burst_backoffs: self.burst.backoffs(),
            burst_final: self.burst.current(),
            in_flight_max: self.in_flight_max,
            ..StreamStats::default()
        };
        for (&tid, t) in &self.tenants {
            s.samples_pushed += t.samples;
            s.windows_emitted += t.emitted;
            s.windows_completed += t.completed;
            s.windows_shed += t.shed;
            s.windows_failed += t.failed;
            s.tenant_queue_max = s.tenant_queue_max.max(t.queue_high);
            s.per_tenant.push(TenantStats {
                tenant: tid,
                samples: t.samples,
                emitted: t.emitted,
                completed: t.completed,
                shed: t.shed,
                failed: t.failed,
            });
        }
        s
    }

    fn record(&mut self, tenant: u32, seq_no: u32, start: usize, resp: RecoveryResponse) {
        debug_assert_eq!(resp.id, encode_id(tenant, seq_no), "response demux mismatch");
        if let Some(t) = self.tenants.get_mut(&tenant) {
            t.completed += 1;
        }
        self.results.push(RecoveredWindow {
            tenant,
            seq_no,
            start,
            theta: resp.theta,
            latency: resp.latency,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, MockBackend, Service, ServiceConfig};

    #[test]
    fn plan_covers_every_sample_and_is_increasing() {
        let plan = window_plan(9, 4, 2);
        assert_eq!(plan, vec![0, 2, 4, 5]);
        let plan = window_plan(8, 4, 4);
        assert_eq!(plan, vec![0, 4]);
        assert_eq!(window_plan(4, 4, 1), vec![0]);
        assert!(window_plan(3, 4, 1).is_empty());
    }

    #[test]
    fn plan_clamps_lossy_strides() {
        // stride > window would skip samples; the plan must clamp.
        let plan = window_plan(10, 3, 100);
        for i in 0..10usize {
            assert!(plan.iter().any(|&s| s <= i && i < s + 3), "sample {i} uncovered");
        }
    }

    #[test]
    fn windower_matches_plan_including_tail() {
        let cfg = WindowConfig {
            window: 5,
            stride: 3,
        };
        let len = 13usize;
        let mut w = Windower::new(cfg, 2, 1);
        let mut starts = Vec::new();
        for i in 0..len {
            let y = [i as f32, -(i as f32)];
            let u = [0.5 * i as f32];
            if let Some((s, wy, wu)) = w.push(&y, &u) {
                assert_eq!(wy.len(), 5 * 2);
                assert_eq!(wu.len(), 5);
                // Payload rows must be the original samples.
                assert_eq!(wy[0], s as f32);
                assert_eq!(wu[4], 0.5 * (s + 4) as f32);
                starts.push(s);
            }
        }
        if let Some((s, _, _)) = w.finish() {
            starts.push(s);
        }
        assert_eq!(starts, window_plan(len, 5, 3));
        assert!(w.finish().is_none(), "finish must be idempotent");
    }

    #[test]
    fn windower_tail_payload_survives_trimming() {
        // Non-overlapping stride: the tail window reaches back before
        // next_start, so trim() must have kept those rows.
        let cfg = WindowConfig {
            window: 4,
            stride: 4,
        };
        let mut w = Windower::new(cfg, 1, 1);
        for i in 0..6 {
            w.push(&[i as f32], &[0.0]);
        }
        let (s, y, _) = w.finish().expect("tail window");
        assert_eq!(s, 2);
        assert_eq!(y, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn id_roundtrip() {
        for (t, q) in [(0u32, 0u32), (3, 17), (u32::MAX, u32::MAX), (7, 0)] {
            assert_eq!(decode_id(encode_id(t, q)), (t, q));
        }
    }

    fn mock_service(workers: usize, queue_depth: usize) -> Service {
        let cfg = ServiceConfig {
            workers,
            queue_depth,
            batcher: BatcherConfig {
                batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
        };
        Service::start(cfg, MockBackend::default)
    }

    fn push_stream(coord: &mut StreamCoordinator, tenant: u32, n: usize, fill: f32) {
        for i in 0..n {
            let y = vec![fill + i as f32 * 1e-3; 3];
            let u = vec![0.0f32];
            coord.push(tenant, &y, &u);
        }
    }

    #[test]
    fn streams_complete_and_attribute_to_tenants() {
        let svc = mock_service(2, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 16,
            },
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        for t in 0..4u32 {
            push_stream(&mut coord, t, 130, t as f32);
        }
        coord.flush_tails();
        coord.drain();
        let stats = coord.stats();
        let plan = window_plan(130, 64, 16);
        assert_eq!(stats.windows_emitted, 4 * plan.len() as u64);
        assert_eq!(stats.windows_completed, stats.windows_emitted);
        assert_eq!(stats.windows_shed, 0);
        assert_eq!(stats.windows_failed, 0);
        let results = coord.take_results();
        assert_eq!(results.len(), stats.windows_completed as usize);
        for t in 0..4u32 {
            let mut starts: Vec<usize> = results
                .iter()
                .filter(|r| r.tenant == t)
                .map(|r| r.start)
                .collect();
            starts.sort_unstable();
            assert_eq!(starts, plan, "tenant {t} window starts");
        }
        // Per-tenant fairness: identical streams → identical completions.
        for pt in &stats.per_tenant {
            assert_eq!(pt.completed, plan.len() as u64, "tenant {}", pt.tenant);
        }
    }

    #[test]
    fn tenant_queue_overflow_sheds_oldest() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 1,
            },
            tenant_queue: 2,
            shed: ShedPolicy::Oldest,
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        // 64 + 9 samples → 10 windows emitted, queue cap 2, no pumping
        // in between → 8 shed, the 2 freshest survive.
        push_stream(&mut coord, 0, 73, 0.0);
        let stats = coord.stats();
        assert_eq!(stats.windows_emitted, 10);
        assert_eq!(stats.windows_shed, 8);
        assert_eq!(coord.queued_windows(), 2);
        coord.drain();
        let results = coord.take_results();
        let starts: Vec<usize> = results.iter().map(|r| r.start).collect();
        assert_eq!(starts, vec![8, 9], "oldest-shed must keep the freshest");
        assert_eq!(coord.metrics().snapshot().shed, 8);
    }

    #[test]
    fn tenant_queue_overflow_sheds_newest() {
        let svc = mock_service(1, 256);
        let cfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 1,
            },
            tenant_queue: 2,
            shed: ShedPolicy::Newest,
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, cfg, 3, 1);
        push_stream(&mut coord, 0, 73, 0.0);
        let stats = coord.stats();
        assert_eq!(stats.windows_emitted, 10);
        assert_eq!(stats.windows_shed, 8);
        coord.drain();
        let results = coord.take_results();
        let starts: Vec<usize> = results.iter().map(|r| r.start).collect();
        assert_eq!(starts, vec![0, 1], "newest-shed must keep the backlog");
    }

    #[test]
    fn service_overload_backs_off_and_still_completes_everything() {
        // Slow single-window backend + tiny service queue: pumping all
        // windows at once must hit typed overload, back off, and retry —
        // nothing may be shed or lost.
        let cfg = ServiceConfig {
            workers: 1,
            queue_depth: 1,
            batcher: BatcherConfig {
                batch: 1,
                max_wait: std::time::Duration::from_millis(1),
            },
        };
        let svc = Service::start(cfg, || MockBackend {
            batch: 1,
            delay: std::time::Duration::from_millis(5),
            ..Default::default()
        });
        let scfg = StreamConfig {
            window: WindowConfig {
                window: 64,
                stride: 8,
            },
            burst_initial: 8,
            burst_max: 8,
            ..StreamConfig::default()
        };
        let mut coord = StreamCoordinator::new(svc, scfg, 3, 1);
        push_stream(&mut coord, 0, 128, 1.0);
        push_stream(&mut coord, 1, 128, 2.0);
        coord.flush_tails();
        coord.drain();
        let stats = coord.stats();
        assert_eq!(stats.windows_completed, stats.windows_emitted);
        assert_eq!(stats.windows_shed, 0);
        assert!(stats.burst_backoffs > 0, "a depth-1 queue must trigger AIMD backoff");
    }

    #[test]
    fn poll_is_nonblocking_and_partial() {
        let svc = mock_service(1, 256);
        let mut coord = StreamCoordinator::new(svc, StreamConfig::default(), 3, 1);
        push_stream(&mut coord, 0, 64, 0.5);
        coord.pump();
        // Wait until the single full window has certainly been served.
        let mut got = 0;
        for _ in 0..200 {
            got += coord.poll();
            if got > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(got, 1);
        assert_eq!(coord.in_flight(), 0);
        assert_eq!(coord.take_results().len(), 1);
    }
}

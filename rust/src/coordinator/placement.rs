//! Resource-aware placement over a heterogeneous accelerator fleet.
//!
//! The scheduling half of MERINDA's multi-accelerator story: instead of
//! spraying recovery windows round-robin onto anonymous, uniform
//! executors, the [`StreamCoordinator`](super::StreamCoordinator) models
//! each accelerator instance explicitly — its fabric budget
//! (`fpga::resources`), its achievable window timing (the `GruAccel`
//! stage schedule streamed through the `fpga::pipeline` cycle model) and
//! its host-link transfer cost (`fpga::cluster::Link`) — and places each
//! window on the instance with the lowest *estimated completion time*:
//!
//! ```text
//! cost(instance) = transfer_s + outstanding · service_s + window_s
//! ```
//!
//! where `service_s` is the steady-state per-window service time (queue
//! wait is outstanding windows times that) and `window_s` the
//! fill-included latency of the window itself. A saturated instance
//! (outstanding at its budget) is skipped, so load spills to the next
//! cheapest sibling instead of overloading.
//!
//! Budgets are *resource-derived*: an instance admits only as many
//! concurrent windows as its free BRAM can double-buffer after the
//! accelerator design itself is placed, and an instance whose design
//! does not fit its device admits none. The property tests in
//! `rust/tests/placement.rs` hold the placer to both invariants.
//!
//! Boards need not run their shipped defaults: the design-space tuner
//! (`fpga::tuner`) picks a per-board operating point, and
//! [`InstanceSpec::from_tuned`] derives the cost model from that tuned
//! design instead (`merinda soak --tuned`), so the fleet is scheduled
//! at the speeds the hardware can actually reach.
//!
//! Nor need instances be GRU boards at all: any model family expressed
//! in the dataflow-graph IR (`fpga::graph`) joins the fleet through
//! [`GraphInstanceSpec`], whose cost model derives from the lowered
//! graph's own cycle law — the placer sees one [`InstanceModel`]
//! vocabulary regardless of what hardware description produced it.
//!
//! # Example
//!
//! ```
//! use merinda::coordinator::placement::{choose, InstanceSpec};
//! use merinda::fpga::cluster::heterogeneous_fleet;
//!
//! // Three heterogeneous boards at the canonical serving dims.
//! let models: Vec<_> = heterogeneous_fleet(4, 32)
//!     .into_iter()
//!     .map(|b| InstanceSpec::new(b).model(64, 3, 1, 45))
//!     .collect();
//! // An idle fleet: the fastest board (zu7ev) wins the first window.
//! let idle = vec![0usize; models.len()];
//! assert_eq!(choose(&models, &idle), Some(2));
//! ```

use crate::fpga::cluster::{BoardSpec, Link};
use crate::fpga::graph::LoweredGraph;
use crate::fpga::partition::PartitionedPlan;
use crate::fpga::resources::{Device, Resources};
use crate::fpga::tuner::TunedConfig;

// The per-window link payload model is shared with the hardware layer
// (the tuner's BRAM double-buffering headroom constraint uses the same
// bytes), so the two can never disagree about what a window costs.
pub use crate::fpga::cluster::window_payload_bytes;

/// An accelerator instance offered to the placer: a concrete board plus
/// an optional explicit concurrency cap.
#[derive(Clone, Debug)]
pub struct InstanceSpec {
    pub board: BoardSpec,
    /// Hard cap on concurrently outstanding windows; `None` derives the
    /// cap from the board's free BRAM (see [`InstanceSpec::model`]).
    pub max_outstanding: Option<usize>,
}

impl InstanceSpec {
    pub fn new(board: BoardSpec) -> InstanceSpec {
        InstanceSpec {
            board,
            max_outstanding: None,
        }
    }

    /// Explicit concurrency cap (tests and deliberately tiny
    /// deployments). A cap of 0 takes the instance out of rotation —
    /// the placer treats it exactly like a non-fitting design.
    pub fn with_outstanding(board: BoardSpec, cap: usize) -> InstanceSpec {
        InstanceSpec {
            board,
            max_outstanding: Some(cap),
        }
    }

    /// An instance at its tuner-chosen operating point
    /// (`fpga::tuner::tune_board`): the cost model derives from the
    /// tuned design and clock instead of the board's shipped defaults.
    pub fn from_tuned(tc: &TunedConfig) -> InstanceSpec {
        InstanceSpec::new(tc.board.clone())
    }

    /// Derive the static placement model for `window`-step recovery
    /// windows of `(xdim, udim)` rows returning `theta_len` coefficients.
    pub fn model(
        &self,
        window: usize,
        xdim: usize,
        udim: usize,
        theta_len: usize,
    ) -> InstanceModel {
        let b = &self.board;
        let timing = b.window_timing(window as u64);
        let payload = window_payload_bytes(&b.cfg.act_fmt, window, xdim, udim, theta_len);
        let report = b.report();
        let fits = b.device.fits(&report.resources);
        let max_outstanding = match self.max_outstanding {
            // An explicit cap is honored verbatim (0 = drained), but a
            // non-fitting design never serves regardless.
            Some(cap) => {
                if fits {
                    cap
                } else {
                    0
                }
            }
            None => derived_outstanding(b, &report.resources, payload, fits),
        };
        InstanceModel {
            name: b.name.clone(),
            window_cycles: timing.total_cycles,
            service_cycles: timing.interval * window as u64,
            window_s: b.device.cycles_to_seconds(timing.total_cycles),
            service_s: b.device.cycles_to_seconds(timing.interval * window as u64),
            transfer_s: b.link.transfer_s(payload),
            payload_bytes: payload,
            max_outstanding,
            resources: report.resources,
            fits,
        }
    }
}

/// Windows the board can hold concurrently: free BRAM after the design,
/// double-buffered per window (`Device::double_buffer_windows`).
/// Non-fitting designs admit nothing; a fitting board always admits at
/// least one window (the tuner is stricter — it rejects headroom-less
/// designs outright rather than serializing on them).
fn derived_outstanding(b: &BoardSpec, used: &Resources, payload: u64, fits: bool) -> usize {
    if !fits {
        return 0;
    }
    b.device.double_buffer_windows(used, payload).clamp(1, 512)
}

/// An accelerator instance defined by a *lowered dataflow graph*
/// (`fpga::graph`) rather than a GRU `BoardSpec` — how other model
/// families (e.g. the SINDy head, `fpga::sindy_accel`) enter the fleet.
/// The cost model derives entirely from the graph's own cycle law
/// ([`LoweredGraph::window_timing`]), the named device and the host
/// link, so a heterogeneous fleet can mix families and the placer never
/// knows the difference.
#[derive(Clone, Debug)]
pub struct GraphInstanceSpec {
    pub name: String,
    pub lowered: LoweredGraph,
    pub device: Device,
    pub link: Link,
}

impl GraphInstanceSpec {
    pub fn new(
        name: impl Into<String>,
        lowered: LoweredGraph,
        device: Device,
        link: Link,
    ) -> GraphInstanceSpec {
        GraphInstanceSpec {
            name: name.into(),
            lowered,
            device,
            link,
        }
    }

    /// Derive the static placement model — same shape and semantics as
    /// [`InstanceSpec::model`], with the lowered graph standing in for
    /// the board's hand-built schedule.
    pub fn model(
        &self,
        window: usize,
        xdim: usize,
        udim: usize,
        theta_len: usize,
    ) -> InstanceModel {
        let timing = self.lowered.window_timing(window as u64);
        let payload = window_payload_bytes(&self.lowered.act_fmt, window, xdim, udim, theta_len);
        let fits = self.device.fits(&self.lowered.resources);
        let max_outstanding = if fits {
            self.device
                .double_buffer_windows(&self.lowered.resources, payload)
                .clamp(1, 512)
        } else {
            0
        };
        InstanceModel {
            name: self.name.clone(),
            window_cycles: timing.total_cycles,
            service_cycles: timing.interval * window as u64,
            window_s: self.device.cycles_to_seconds(timing.total_cycles),
            service_s: self.device.cycles_to_seconds(timing.interval * window as u64),
            transfer_s: self.link.transfer_s(payload),
            payload_bytes: payload,
            max_outstanding,
            resources: self.lowered.resources,
            fits,
        }
    }
}

/// An accelerator instance backed by a *multi-board partitioned plan*
/// (`fpga::partition`): one design cut along its FIFO edges across
/// several boards, entering the fleet as a single placement target. The
/// cost model derives from the plan's max-plus composition law
/// ([`PartitionedPlan::window_timing`]), so `rank`/`choose` price a
/// split design against whole-window siblings with no special casing —
/// a design that fits nowhere whole becomes feasible here, and one that
/// fits a single board only wins as a split if the split models
/// strictly fewer seconds.
#[derive(Clone, Debug)]
pub struct PartitionedInstanceSpec {
    pub name: String,
    pub plan: PartitionedPlan,
    /// Host ingest link feeding the plan's head board.
    pub link: Link,
}

impl PartitionedInstanceSpec {
    pub fn new(name: impl Into<String>, plan: PartitionedPlan, link: Link) -> Self {
        PartitionedInstanceSpec {
            name: name.into(),
            plan,
            link,
        }
    }

    /// Derive the static placement model — same shape and semantics as
    /// [`InstanceSpec::model`]. Cycle figures are quoted at the plan's
    /// reference clock (its slowest member); seconds come straight from
    /// the composition, so heterogeneous member clocks stay exact. The
    /// concurrency budget is the *minimum* member budget: every board
    /// must double-buffer a window's payload for the pipeline to accept
    /// it, so the tightest member bounds the whole plan.
    pub fn model(
        &self,
        window: usize,
        xdim: usize,
        udim: usize,
        theta_len: usize,
    ) -> InstanceModel {
        let plan = &self.plan;
        let timing = plan.window_timing(window as u64);
        let timing_s = plan.window_timing_s(window as u64);
        let payload = window_payload_bytes(&plan.act_fmt, window, xdim, udim, theta_len);
        let fits = plan.feasible();
        let max_outstanding = if fits {
            plan.parts
                .iter()
                .map(|p| p.device.double_buffer_windows(&p.resources(), payload))
                .min()
                .unwrap_or(0)
                .clamp(1, 512)
        } else {
            0
        };
        InstanceModel {
            name: self.name.clone(),
            window_cycles: timing.total_cycles,
            service_cycles: timing.interval * window as u64,
            window_s: timing_s.total_s,
            service_s: timing_s.interval_s * window as f64,
            transfer_s: self.link.transfer_s(payload),
            payload_bytes: payload,
            max_outstanding,
            resources: plan.resources(),
            fits,
        }
    }
}

/// The static, per-instance inputs to the placement cost function,
/// derived once from the accelerator cycle model.
#[derive(Clone, Debug)]
pub struct InstanceModel {
    pub name: String,
    /// Fill-included cycles for one window on this instance.
    pub window_cycles: u64,
    /// Steady-state cycles between window completions under load.
    pub service_cycles: u64,
    /// `window_cycles` at this instance's clock, in seconds.
    pub window_s: f64,
    /// `service_cycles` at this instance's clock, in seconds.
    pub service_s: f64,
    /// Host-link transfer seconds for one window's payload.
    pub transfer_s: f64,
    /// Payload bytes per window over the link.
    pub payload_bytes: u64,
    /// Concurrency budget (0 = unusable).
    pub max_outstanding: usize,
    /// Fabric the design consumes.
    pub resources: Resources,
    /// Whether the design fits the device.
    pub fits: bool,
}

impl InstanceModel {
    /// A hand-specified model with `window_s` doubling as the
    /// steady-state service time, a nominal 1 kcycle window and
    /// negligible transfer cost — for tests and synthetic fleets where
    /// no real board stands behind the service.
    pub fn synthetic(name: &str, window_s: f64, max_outstanding: usize) -> InstanceModel {
        InstanceModel {
            name: name.to_string(),
            window_cycles: 1_000,
            service_cycles: 800,
            window_s,
            service_s: window_s,
            transfer_s: 1e-7,
            payload_bytes: 512,
            max_outstanding,
            resources: Resources::ZERO,
            fits: true,
        }
    }
}

/// Estimated completion seconds for one more window on `m` when
/// `outstanding` windows are already queued or executing there.
pub fn placement_cost(m: &InstanceModel, outstanding: usize) -> f64 {
    m.transfer_s + outstanding as f64 * m.service_s + m.window_s
}

/// Pick the instance with the lowest estimated completion time among
/// those with spare concurrency budget. Ties break toward the lower
/// index. Returns `None` when every instance is saturated or unusable.
pub fn choose(models: &[InstanceModel], outstanding: &[usize]) -> Option<usize> {
    assert_eq!(models.len(), outstanding.len());
    let mut best: Option<(usize, f64)> = None;
    for (i, m) in models.iter().enumerate() {
        if m.max_outstanding == 0 || outstanding[i] >= m.max_outstanding {
            continue;
        }
        let c = placement_cost(m, outstanding[i]);
        let better = match best {
            None => true,
            Some((_, bc)) => c < bc,
        };
        if better {
            best = Some((i, c));
        }
    }
    best.map(|(i, _)| i)
}

/// All eligible instances in ascending cost order — the failover
/// sequence the streaming pump walks when the cheapest instance's
/// bounded queue rejects a submission mid-flight.
pub fn rank(models: &[InstanceModel], outstanding: &[usize]) -> Vec<usize> {
    rank_with(
        models,
        outstanding,
        &vec![PlacementOverride::default(); models.len()],
    )
}

/// Per-instance dynamic adjustment layered over the static
/// [`InstanceModel`] by the fault/health layer: health masking, link
/// degradation, and probing caps. The static model stays immutable so
/// recovery (an instance coming back) is just dropping the override.
#[derive(Clone, Copy, Debug)]
pub struct PlacementOverride {
    /// Instance is out of rotation entirely (health `Down`, or a warm
    /// standby held back until the fleet degrades).
    pub masked: bool,
    /// Multiplier on the modeled link transfer time (≥ 1.0 under a
    /// link-degradation fault; 1.0 = nominal).
    pub transfer_factor: f64,
    /// Tighter concurrency cap than the model's budget, if any — a
    /// `Recovering` instance probes with a cap of 1 before the health
    /// machine readmits it at full budget.
    pub cap: Option<usize>,
}

impl Default for PlacementOverride {
    fn default() -> Self {
        PlacementOverride {
            masked: false,
            transfer_factor: 1.0,
            cap: None,
        }
    }
}

/// [`rank`] with per-instance health/fault overrides applied: masked
/// instances never place, degraded links pay their inflated transfer
/// cost (so traffic drains toward healthy links), and probing caps
/// bound what a recovering instance may hold.
pub fn rank_with(
    models: &[InstanceModel],
    outstanding: &[usize],
    overrides: &[PlacementOverride],
) -> Vec<usize> {
    assert_eq!(models.len(), outstanding.len());
    assert_eq!(models.len(), overrides.len());
    let mut order: Vec<(usize, f64)> = models
        .iter()
        .enumerate()
        .filter(|(i, m)| {
            let ov = &overrides[*i];
            let cap = ov.cap.unwrap_or(m.max_outstanding).min(m.max_outstanding);
            !ov.masked && cap > 0 && outstanding[*i] < cap
        })
        .map(|(i, m)| {
            let ov = &overrides[i];
            let cost = ov.transfer_factor * m.transfer_s
                + outstanding[i] as f64 * m.service_s
                + m.window_s;
            (i, cost)
        })
        .collect();
    order.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
    order.into_iter().map(|(i, _)| i).collect()
}

/// Modeled accelerator cycles for `iters` warm-start refinement
/// iterations: each conjugate-gradient step is one (plib × plib) matvec
/// retired on `lanes` MAC lanes.
pub fn refine_cycle_model(iters: u64, plib: usize, lanes: u64) -> u64 {
    iters * ((plib * plib) as u64).div_ceil(lanes.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::cluster::heterogeneous_fleet;

    fn models() -> Vec<InstanceModel> {
        heterogeneous_fleet(4, 32)
            .into_iter()
            .map(|b| InstanceSpec::new(b).model(64, 3, 1, 45))
            .collect()
    }

    #[test]
    fn canonical_fleet_models_are_usable_and_ordered() {
        let ms = models();
        assert_eq!(ms.len(), 3);
        for m in &ms {
            assert!(m.fits, "{}", m.name);
            assert!(m.max_outstanding >= 1, "{}", m.name);
            assert!(m.window_s > 0.0 && m.service_s > 0.0 && m.transfer_s > 0.0);
        }
        // zu7ev (faster clock + aurora link) is the cheapest idle choice;
        // the sequential pynq is the dearest.
        let c: Vec<f64> = ms.iter().map(|m| placement_cost(m, 0)).collect();
        assert!(c[2] < c[0], "zu7ev {} vs pynq-dataflow {}", c[2], c[0]);
        assert!(c[0] < c[1], "dataflow {} vs sequential {}", c[0], c[1]);
    }

    #[test]
    fn cost_grows_with_queue_depth() {
        let ms = models();
        for m in &ms {
            assert!(placement_cost(m, 0) < placement_cost(m, 1));
            assert!(placement_cost(m, 1) < placement_cost(m, 8));
        }
    }

    #[test]
    fn choose_spills_to_sibling_as_load_mounts() {
        let ms = models();
        let mut outstanding = vec![0usize; 3];
        // Keep placing without completing anything: the placer must
        // eventually use every instance, never a saturated one.
        let mut used = [false; 3];
        for _ in 0..64 {
            match choose(&ms, &outstanding) {
                Some(i) => {
                    assert!(outstanding[i] < ms[i].max_outstanding, "overfilled {}", ms[i].name);
                    outstanding[i] += 1;
                    used[i] = true;
                }
                None => break,
            }
        }
        assert!(used.iter().all(|&u| u), "sustained load must reach every sibling");
    }

    #[test]
    fn choose_none_when_everything_saturated() {
        let ms = models();
        let full: Vec<usize> = ms.iter().map(|m| m.max_outstanding).collect();
        assert_eq!(choose(&ms, &full), None);
        assert!(rank(&ms, &full).is_empty());
    }

    #[test]
    fn rank_orders_by_cost_and_skips_saturated() {
        let ms = models();
        let idle = vec![0usize; 3];
        let order = rank(&ms, &idle);
        assert_eq!(order.len(), 3);
        assert_eq!(order[0], 2, "idle fleet: zu7ev first");
        for w in order.windows(2) {
            assert!(
                placement_cost(&ms[w[0]], idle[w[0]])
                    <= placement_cost(&ms[w[1]], idle[w[1]])
            );
        }
        let mut out = idle.clone();
        out[2] = ms[2].max_outstanding;
        let order = rank(&ms, &out);
        assert!(!order.contains(&2), "saturated instance must drop out");
    }

    #[test]
    fn rank_with_masks_down_instances() {
        let ms = models();
        let idle = vec![0usize; 3];
        let mut ov = vec![PlacementOverride::default(); 3];
        ov[2].masked = true; // cheapest instance is down
        let order = rank_with(&ms, &idle, &ov);
        assert_eq!(order.len(), 2);
        assert!(!order.contains(&2), "down instance must never place");
        assert_eq!(order[0], 0, "next-cheapest healthy sibling takes over");
    }

    #[test]
    fn rank_with_degraded_link_reorders_by_inflated_transfer() {
        // Two synthetic instances where transfer dominates: degrading
        // the cheaper link far enough must flip the order.
        let a = InstanceModel {
            transfer_s: 1e-3,
            ..InstanceModel::synthetic("a", 1e-4, 4)
        };
        let b = InstanceModel {
            transfer_s: 2e-3,
            ..InstanceModel::synthetic("b", 1e-4, 4)
        };
        let ms = vec![a, b];
        let idle = vec![0usize; 2];
        assert_eq!(rank(&ms, &idle)[0], 0);
        let mut ov = vec![PlacementOverride::default(); 2];
        ov[0].transfer_factor = 10.0;
        assert_eq!(
            rank_with(&ms, &idle, &ov)[0],
            1,
            "degraded link must drain traffic to the healthy sibling"
        );
    }

    #[test]
    fn rank_with_probe_cap_limits_recovering_instance() {
        let ms = models();
        let mut ov = vec![PlacementOverride::default(); 3];
        ov[2].cap = Some(1); // recovering: one probe window only
        let idle = vec![0usize; 3];
        assert!(rank_with(&ms, &idle, &ov).contains(&2), "probe slot open");
        let mut out = idle;
        out[2] = 1;
        assert!(
            !rank_with(&ms, &out, &ov).contains(&2),
            "probe cap of 1 must exclude the instance once the probe is out"
        );
    }

    #[test]
    fn explicit_cap_overrides_derived_budget() {
        let board = heterogeneous_fleet(4, 32).remove(0);
        let derived = InstanceSpec::new(board.clone()).model(64, 3, 1, 45);
        let capped = InstanceSpec::with_outstanding(board, 2).model(64, 3, 1, 45);
        assert!(derived.max_outstanding > 2);
        assert_eq!(capped.max_outstanding, 2);
    }

    #[test]
    fn zero_cap_drains_the_instance() {
        let board = heterogeneous_fleet(4, 32).remove(0);
        let drained = InstanceSpec::with_outstanding(board, 0).model(64, 3, 1, 45);
        assert_eq!(drained.max_outstanding, 0, "cap 0 must mean out of rotation");
        assert_eq!(choose(&[drained.clone()], &[0]), None);
        assert!(rank(&[drained], &[0]).is_empty());
    }

    // `window_payload_bytes` moved to `fpga::cluster` (re-exported
    // here); its unit test lives there now.

    #[test]
    fn tuned_instance_is_never_dearer_than_shipped() {
        use crate::fpga::tuner::{tune_board, TunerOptions};
        for board in heterogeneous_fleet(4, 32) {
            let shipped = InstanceSpec::new(board.clone()).model(64, 3, 1, 45);
            let out = tune_board(&board, &TunerOptions::default()).unwrap();
            let tuned = InstanceSpec::from_tuned(&out.chosen).model(64, 3, 1, 45);
            assert!(tuned.fits && tuned.max_outstanding >= 1, "{}", tuned.name);
            assert_eq!(tuned.window_cycles, out.chosen.window_cycles);
            // Same link, faster (or equal) window: an idle tuned
            // instance never costs more than its shipped counterpart.
            let c_tuned = placement_cost(&tuned, 0);
            let c_ship = placement_cost(&shipped, 0);
            assert!(c_tuned <= c_ship + 1e-12, "{}: {c_tuned} vs {c_ship}", tuned.name);
        }
    }

    #[test]
    fn graph_instance_joins_the_fleet() {
        use crate::fpga::graph::{lower, Target};
        use crate::fpga::sindy_accel::SindyAccelConfig;
        let low = lower(&SindyAccelConfig::concurrent().graph(), &Target::default()).unwrap();
        let spec = GraphInstanceSpec::new("sindy-pynq", low, Device::pynq_z2(), Link::ten_gbe());
        let m = spec.model(64, 3, 1, 45);
        assert!(m.fits, "concurrent SINDy design must fit the PYNQ-Z2");
        assert!(m.max_outstanding >= 1 && m.payload_bytes > 0);
        assert!(m.window_s > 0.0 && m.service_s > 0.0 && m.transfer_s > 0.0);
        // Mixed fleet: the graph-backed instance ranks alongside the
        // GRU boards with no special casing.
        let mut ms = models();
        ms.push(m);
        let idle = vec![0usize; ms.len()];
        let order = rank(&ms, &idle);
        assert_eq!(order.len(), ms.len());
        assert!(order.contains(&(ms.len() - 1)));
    }

    #[test]
    fn partitioned_instance_joins_the_fleet_where_whole_cannot() {
        use crate::fpga::fixedpoint::FixedFormat;
        use crate::fpga::graph::{lower, Target};
        use crate::fpga::gru_accel::{GruAccel, GruAccelConfig};
        use crate::fpga::partition::{best_partition, pynq_rack};

        // A GRU whose weight tiles exceed one PYNQ-Z2's BRAM: the
        // whole-window graph instance admits nothing...
        let fmt = FixedFormat::q8_8();
        let g = GruAccel::new(GruAccelConfig::serving(4, 384, fmt, fmt)).graph();
        let low = lower(&g, &Target::default()).unwrap();
        let whole = GraphInstanceSpec::new("gru-whole", low, Device::pynq_z2(), Link::ten_gbe())
            .model(64, 3, 1, 45);
        assert!(!whole.fits && whole.max_outstanding == 0);

        // ...but the same design split across two boards serves.
        let out = best_partition(&g, &pynq_rack(2), 64).unwrap();
        let split = PartitionedInstanceSpec::new("gru-split", out.plan, Link::ten_gbe())
            .model(64, 3, 1, 45);
        assert!(split.fits, "split plan must be feasible: {:?}", split.resources);
        assert!(split.max_outstanding >= 1);
        assert!(split.window_s > 0.0 && split.service_s > 0.0);

        // Mixed fleet: the partitioned instance ranks alongside
        // whole-window boards with no special casing.
        let mut ms = models();
        ms.push(split);
        let idle = vec![0usize; ms.len()];
        let order = rank(&ms, &idle);
        assert!(order.contains(&(ms.len() - 1)));
    }

    #[test]
    fn refine_cycles_scale_with_iterations() {
        assert_eq!(refine_cycle_model(0, 15, 32), 0);
        let one = refine_cycle_model(1, 15, 32);
        assert_eq!(one, (15u64 * 15).div_ceil(32));
        assert_eq!(refine_cycle_model(10, 15, 32), 10 * one);
    }
}

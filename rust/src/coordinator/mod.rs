//! L3 streaming coordinator.
//!
//! The serving side of MERINDA: clients submit (Y, U) windows; a dynamic
//! batcher groups them into fixed-size model batches (padding partial
//! batches), N sharded executor workers each own a backend instance
//! (PJRT runtime, the artifact-free native batched-GRU backend, or the
//! quantized fixed-point backend with its accelerator cycle model) and
//! execute, and results fan back out to callers. Backpressure is a
//! bounded submission queue. Python never runs here.
//!
//! The design is deliberately the vLLM-router shape scaled to this paper:
//! request router → batcher → executor → response demux, with metrics.

mod batcher;
mod fixed;
mod metrics;
mod native;
mod service;

pub use batcher::{BatcherConfig, PendingBatch};
pub use fixed::{FixedCycleReport, FixedPointBackend, FixedPointConfig};
pub use native::NativeBackend;

/// Re-export of the padding helper for out-of-crate property tests.
pub fn pad_rows_for_tests(data: Vec<f32>, row_len: usize, batch: usize) -> (Vec<f32>, usize) {
    batcher::pad_rows(data, row_len, batch)
}
pub use metrics::{LatencyStats, Metrics, MetricsSnapshot};
pub use service::{
    InferenceBackend, MockBackend, PjrtBackend, RecoveryRequest, RecoveryResponse, Service,
    ServiceConfig,
};

//! L3 streaming coordinator.
//!
//! The serving side of MERINDA: clients submit (Y, U) windows; a dynamic
//! batcher groups them into fixed-size model batches (padding partial
//! batches), N sharded executor workers each own a backend instance
//! (PJRT runtime, the artifact-free native batched-GRU backend, or the
//! quantized fixed-point backend with its accelerator cycle model) and
//! execute, and results fan back out to callers. Backpressure is a
//! bounded submission queue. Python never runs here.
//!
//! On top of the one-shot request path, [`stream`] turns the service
//! into a continuous pipeline: per-tenant sample streams are sliced
//! into overlapping recovery windows, held in bounded per-tenant queues
//! with explicit shed policies, and pumped into the executors through
//! an AIMD burst controller with round-robin tenant fairness
//! (`merinda soak` drives it across all six case-study scenarios).
//!
//! Scheduling across executors is resource-aware: [`placement`] models
//! each accelerator instance's fabric budget, cycle-model window timing
//! and link transfer cost, and the stream coordinator places every
//! window on the instance with the lowest estimated completion time
//! (spilling to siblings when one saturates). Consecutive overlapping
//! windows warm-start their coefficient refinement from the previous
//! window's result ([`stream::WarmStartConfig`]).
//!
//! The fleet is not assumed healthy: [`faults`] provides deterministic
//! fault injection (crash / stall / link degradation / bit-flip), a
//! per-instance health state machine, and a bounded retry policy; the
//! stream coordinator masks down instances out of placement, fails
//! stranded windows over to healthy siblings, and degrades gracefully
//! (standby capacity, lower burst) when the fleet shrinks
//! (`merinda soak --chaos`).
//!
//! Above it all sits the open-loop production traffic tier: [`traffic`]
//! generates deterministic seeded arrival processes (Poisson + diurnal +
//! burst profiles) that fire regardless of completion rate, assigns
//! tenants to `realtime`/`standard`/`batch` QoS tiers that drive shed
//! ordering and placement priority, admission-rejects work whose tier
//! SLO would be breached, and re-derives the placement cost models
//! mid-stream when the observed mix drifts
//! (`merinda soak --open-loop --arrivals <spec>`).
//!
//! The design is deliberately the vLLM-router shape scaled to this paper:
//! request router → batcher → executor → response demux, with metrics.

mod batcher;
pub mod faults;
mod fixed;
mod metrics;
mod native;
pub mod placement;
mod service;
pub mod stream;
pub mod traffic;

pub use batcher::{AimdBurst, BatcherConfig, PendingBatch};
pub use faults::{
    FaultEvent, FaultKind, FaultPlan, FaultStats, FaultToleranceConfig, HealthConfig, HealthState,
    InstanceHealth, RetryPolicy,
};
pub use fixed::{FixedCycleReport, FixedPointBackend, FixedPointConfig};
// Constant re-exports let CLI tools and out-of-crate tests reference the
// canonical serving dims without reaching into the private module.
pub use native::{
    NativeBackend, NATIVE_DENSE, NATIVE_HID, NATIVE_PLIB, NATIVE_SEQ, NATIVE_UDIM, NATIVE_XDIM,
};
pub use placement::{GraphInstanceSpec, InstanceModel, InstanceSpec, PartitionedInstanceSpec};
pub use stream::{
    window_plan, InstanceStats, RecoveredWindow, RefinedWindow, ShedPolicy, StreamConfig,
    StreamCoordinator, StreamStats, TenantStats, TierStats, WarmStartConfig, WindowConfig,
    Windower,
};
pub use traffic::{
    run_open_loop, AdmissionController, Arrival, ArrivalPlan, ArrivalSpec, DriftConfig,
    DriftDetector, OpenLoopConfig, QosClass, RetuneEvent, SloPolicy, TenantTraffic, TierTraffic,
    TrafficReport, QOS_CLASSES,
};

/// Re-export of the padding helper for out-of-crate property tests.
pub fn pad_rows_for_tests(data: Vec<f32>, row_len: usize, batch: usize) -> (Vec<f32>, usize) {
    batcher::pad_rows(data, row_len, batch)
}
pub use metrics::{InstanceSnapshot, LatencyStats, Metrics, MetricsSnapshot, TierSnapshot};
pub use service::{
    InferenceBackend, MockBackend, PjrtBackend, RecoveryRequest, RecoveryResponse, Service,
    ServiceConfig,
};

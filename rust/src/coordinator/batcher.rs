//! Dynamic batcher: groups window requests into fixed-size model batches.
//!
//! The AOT artifacts are lowered for a fixed batch B; the batcher fills a
//! batch either to capacity or until `max_wait` elapses since the first
//! queued item, then flushes (padding with replicas of the last row so the
//! executable's shape is always satisfied — padded rows are dropped on the
//! way out). Ordering within a stream is preserved: requests are drained
//! FIFO.
//!
//! Two cooperating pieces live here:
//! * [`PendingBatch`] — the executor-side accumulator (size- and
//!   deadline-triggered flush).
//! * [`AimdBurst`] — the submitter-side adaptive controller: how many
//!   windows the streaming layer pushes per tenant per pump round,
//!   grown additively while the service accepts and halved on typed
//!   overload (TCP-style AIMD), so offered load converges onto whatever
//!   the executor fleet sustains without hammering a full queue.

use std::time::{Duration, Instant};

/// Batcher policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Model batch size (from the artifact manifest).
    pub batch: usize,
    /// Flush deadline measured from the oldest queued request.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// An accumulating batch of requests with payload rows.
#[derive(Debug)]
pub struct PendingBatch<T> {
    cfg: BatcherConfig,
    items: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> PendingBatch<T> {
    pub fn new(cfg: BatcherConfig) -> PendingBatch<T> {
        PendingBatch {
            cfg,
            items: Vec::with_capacity(cfg.batch),
            oldest: None,
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Add an item; returns true if the batch is now full.
    pub fn push(&mut self, item: T) -> bool {
        if self.items.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.items.push(item);
        self.items.len() >= self.cfg.batch
    }

    /// Should we flush now (full or deadline hit)?
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.items.len() >= self.cfg.batch {
            return true;
        }
        match self.oldest {
            Some(t0) if !self.items.is_empty() => now.duration_since(t0) >= self.cfg.max_wait,
            _ => false,
        }
    }

    /// Time until the deadline (for the executor's poll timeout).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t0| {
            let elapsed = now.duration_since(t0);
            self.cfg.max_wait.saturating_sub(elapsed)
        })
    }

    /// Take the accumulated items, resetting the batch.
    pub fn take(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.items)
    }
}

/// Additive-increase / multiplicative-decrease controller for the
/// streaming submitter's per-tenant burst size.
///
/// `grow` is called after a pump round the service fully accepted,
/// `backoff` when a submit came back [`crate::util::Error::Overloaded`].
/// The burst stays in `1..=max`, so a saturated service degrades to
/// one-window-at-a-time trickle rather than a reject storm.
#[derive(Clone, Copy, Debug)]
pub struct AimdBurst {
    cur: usize,
    max: usize,
    backoffs: u64,
}

impl AimdBurst {
    /// Start at `initial` (clamped into `1..=max`).
    pub fn new(initial: usize, max: usize) -> AimdBurst {
        let max = max.max(1);
        AimdBurst {
            cur: initial.clamp(1, max),
            max,
            backoffs: 0,
        }
    }

    /// Windows the submitter may push per tenant this round.
    pub fn current(&self) -> usize {
        self.cur
    }

    /// Additive increase after a clean (fully accepted) round.
    pub fn grow(&mut self) {
        self.cur = (self.cur + 1).min(self.max);
    }

    /// Multiplicative decrease after an overload rejection.
    pub fn backoff(&mut self) {
        self.cur = (self.cur / 2).max(1);
        self.backoffs += 1;
    }

    /// How many times the controller has backed off.
    pub fn backoffs(&self) -> u64 {
        self.backoffs
    }
}

impl Default for AimdBurst {
    /// Start conservatively at 1 and allow bursts up to one model batch.
    fn default() -> Self {
        AimdBurst::new(1, 8)
    }
}

/// Pad a flat row-major payload (rows × row_len) out to `batch` rows by
/// repeating the final row. Returns the padded buffer and the real count.
pub fn pad_rows(mut data: Vec<f32>, row_len: usize, batch: usize) -> (Vec<f32>, usize) {
    assert!(row_len > 0);
    assert_eq!(data.len() % row_len, 0);
    let rows = data.len() / row_len;
    assert!(rows > 0 && rows <= batch, "rows={rows} batch={batch}");
    if rows < batch {
        let last = data[(rows - 1) * row_len..rows * row_len].to_vec();
        for _ in rows..batch {
            data.extend_from_slice(&last);
        }
    }
    (data, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_capacity() {
        let mut b = PendingBatch::new(BatcherConfig {
            batch: 3,
            max_wait: Duration::from_secs(10),
        });
        assert!(!b.push(1));
        assert!(!b.push(2));
        assert!(b.push(3));
        assert!(b.should_flush(Instant::now()));
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_flushes_partial() {
        let mut b = PendingBatch::new(BatcherConfig {
            batch: 8,
            max_wait: Duration::from_millis(1),
        });
        b.push(42);
        assert!(!b.should_flush(Instant::now()));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush(Instant::now()));
        assert_eq!(b.take(), vec![42]);
    }

    #[test]
    fn empty_batch_never_flushes() {
        let b: PendingBatch<u32> = PendingBatch::new(BatcherConfig::default());
        std::thread::sleep(Duration::from_millis(6));
        assert!(!b.should_flush(Instant::now()));
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = PendingBatch::new(BatcherConfig {
            batch: 4,
            max_wait: Duration::from_secs(1),
        });
        for i in 0..4 {
            b.push(i);
        }
        assert_eq!(b.take(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn padding_repeats_last_row() {
        let (padded, real) = pad_rows(vec![1.0, 2.0, 3.0, 4.0], 2, 4);
        assert_eq!(real, 2);
        assert_eq!(padded, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn padding_noop_when_full() {
        let (padded, real) = pad_rows(vec![1.0; 8], 2, 4);
        assert_eq!(real, 4);
        assert_eq!(padded.len(), 8);
    }

    #[test]
    #[should_panic]
    fn padding_rejects_overfull() {
        pad_rows(vec![1.0; 10], 2, 4);
    }

    #[test]
    fn aimd_grows_additively_and_caps() {
        let mut b = AimdBurst::new(1, 4);
        assert_eq!(b.current(), 1);
        for _ in 0..10 {
            b.grow();
        }
        assert_eq!(b.current(), 4, "growth must cap at max");
        assert_eq!(b.backoffs(), 0);
    }

    #[test]
    fn aimd_halves_and_floors_at_one() {
        let mut b = AimdBurst::new(8, 8);
        b.backoff();
        assert_eq!(b.current(), 4);
        b.backoff();
        b.backoff();
        b.backoff();
        assert_eq!(b.current(), 1, "burst must floor at 1, never 0");
        assert_eq!(b.backoffs(), 4);
    }

    #[test]
    fn aimd_clamps_initial() {
        assert_eq!(AimdBurst::new(0, 4).current(), 1);
        assert_eq!(AimdBurst::new(100, 4).current(), 4);
        assert_eq!(AimdBurst::default().current(), 1);
    }
}

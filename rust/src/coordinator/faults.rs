//! Deterministic fault injection, instance health, and retry policy for
//! the streaming coordinator.
//!
//! MERINDA's mission-critical framing (fast model recovery for real-time
//! digital twins) only holds if recovery *itself* survives failures: a
//! crashed board, a stalled DMA, a flapping link, or a flipped
//! accumulator bit must not strand windows or corrupt results silently.
//! This module provides the pieces the [`StreamCoordinator`] composes
//! into a failover layer:
//!
//! - [`FaultPlan`]: a deterministic, seed- or spec-driven schedule of
//!   [`FaultEvent`]s (crash, stall, link degradation, bit-flip
//!   corruption) keyed to the coordinator's logical clocks, so chaos
//!   runs replay bit-identically.
//! - [`InstanceHealth`]: a per-instance state machine
//!   (healthy → degraded → down → recovering) driven by submission
//!   outcomes and deadline timeouts. Down instances are masked out of
//!   placement; non-permanent downs are re-probed with exponential
//!   backoff and readmitted after consecutive clean completions.
//! - [`RetryPolicy`]: bounded per-window retry with exponential backoff
//!   plus deterministic jitter, layered *on top of* the AIMD
//!   hold-and-retry that already handles plain overload.
//! - [`fidelity_check`] / [`corrupt_theta`]: the detection side of the
//!   bit-flip fault. A flipped high exponent bit throws a coefficient
//!   outside any plausible magnitude for normalized inputs, so a cheap
//!   range-and-finiteness check catches it without re-running the solve.
//!
//! All timing is in *pump rounds* (one [`StreamCoordinator::pump`] call
//! advances the clock by one) except stalls, which hold wall-clock time
//! to exercise the real deadline path.
//!
//! [`StreamCoordinator`]: super::StreamCoordinator
//! [`StreamCoordinator::pump`]: super::StreamCoordinator::pump

use std::time::Duration;

use crate::util::{Error, Prng, Result};

/// What a scheduled fault does to its instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Hard crash: the instance's service is killed (queue cleared,
    /// channels dropped) and never comes back. Queued windows strand.
    Crash,
    /// Transient stall: the instance stops being offered work for
    /// `hold` of wall-clock time; windows already on it blow their
    /// deadline and fail over. The instance recovers afterwards.
    Stall { hold: Duration },
    /// Link degradation: the instance's host-link transfer cost is
    /// multiplied by `factor` for the next `windows` fleet submissions,
    /// draining placement toward healthy links (see
    /// [`Link::degraded`](crate::fpga::cluster::Link::degraded)).
    LinkDegrade { factor: f64, windows: u64 },
    /// Fixed-point bit-flip: the next response from the instance has one
    /// coefficient's high exponent bit flipped. Detected by
    /// [`fidelity_check`]; the window retries and the tenant's
    /// warm-start cache is invalidated.
    BitFlip,
}

/// One scheduled fault.
///
/// `at` is a logical trigger count: for `Crash`/`Stall`/`LinkDegrade`
/// it is the fleet-wide submission counter value at (or after) which
/// the event fires; for `BitFlip` it is the 1-based count of responses
/// received from `instance` — the `at`-th response is corrupted.
#[derive(Clone, Debug)]
pub struct FaultEvent {
    pub instance: usize,
    pub at: u64,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults for one chaos run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan (no injection; the fault layer still runs, so
    /// genuine failures are handled identically).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse a plan spec: comma-separated events, each one of
    ///
    /// ```text
    /// crash:I@N        kill instance I at fleet submission N
    /// stall:I@N+MSms   stall instance I at submission N for MS ms
    /// flip:I@K         corrupt the K-th response from instance I
    /// link:I@N*F+D     degrade I's link by factor F for D submissions
    /// ```
    ///
    /// Instance indices are validated against `n_instances`.
    ///
    /// # Example
    ///
    /// ```
    /// use merinda::coordinator::faults::FaultPlan;
    /// let plan = FaultPlan::parse("flip:2@1,crash:2@6,stall:0@10+200ms", 3).unwrap();
    /// assert_eq!(plan.events.len(), 3);
    /// ```
    pub fn parse(spec: &str, n_instances: usize) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, rest) = tok
                .split_once(':')
                .ok_or_else(|| Error::config(format!("fault `{tok}`: expected kind:I@N")))?;
            let (inst, trigger) = rest
                .split_once('@')
                .ok_or_else(|| Error::config(format!("fault `{tok}`: expected kind:I@N")))?;
            let instance: usize = inst
                .parse()
                .map_err(|_| Error::config(format!("fault `{tok}`: bad instance `{inst}`")))?;
            if instance >= n_instances {
                return Err(Error::config(format!(
                    "fault `{tok}`: instance {instance} out of range (fleet has {n_instances})"
                )));
            }
            let ev = match kind {
                "crash" => FaultEvent {
                    instance,
                    at: parse_u64(tok, trigger)?,
                    kind: FaultKind::Crash,
                },
                "flip" => {
                    let at = parse_u64(tok, trigger)?;
                    if at == 0 {
                        return Err(Error::config(format!(
                            "fault `{tok}`: flip response count is 1-based"
                        )));
                    }
                    FaultEvent {
                        instance,
                        at,
                        kind: FaultKind::BitFlip,
                    }
                }
                "stall" => {
                    let (at, hold) = trigger.split_once('+').ok_or_else(|| {
                        Error::config(format!("fault `{tok}`: expected stall:I@N+MSms"))
                    })?;
                    let ms = hold.strip_suffix("ms").ok_or_else(|| {
                        Error::config(format!("fault `{tok}`: stall hold needs `ms` suffix"))
                    })?;
                    FaultEvent {
                        instance,
                        at: parse_u64(tok, at)?,
                        kind: FaultKind::Stall {
                            hold: Duration::from_millis(parse_u64(tok, ms)?),
                        },
                    }
                }
                "link" => {
                    let (at, fd) = trigger.split_once('*').ok_or_else(|| {
                        Error::config(format!("fault `{tok}`: expected link:I@N*F+D"))
                    })?;
                    let (factor, dur) = fd.split_once('+').ok_or_else(|| {
                        Error::config(format!("fault `{tok}`: expected link:I@N*F+D"))
                    })?;
                    let f: f64 = factor.parse().map_err(|_| {
                        Error::config(format!("fault `{tok}`: bad factor `{factor}`"))
                    })?;
                    if f < 1.0 {
                        return Err(Error::config(format!(
                            "fault `{tok}`: degradation factor must be >= 1"
                        )));
                    }
                    FaultEvent {
                        instance,
                        at: parse_u64(tok, at)?,
                        kind: FaultKind::LinkDegrade {
                            factor: f,
                            windows: parse_u64(tok, dur)?,
                        },
                    }
                }
                other => {
                    return Err(Error::config(format!(
                        "fault `{tok}`: unknown kind `{other}` (crash|stall|flip|link)"
                    )))
                }
            };
            events.push(ev);
        }
        Ok(FaultPlan { events })
    }

    /// A random-but-reproducible plan: 1–3 events drawn from all four
    /// kinds, triggers within `horizon` fleet submissions. At most one
    /// crash, and never on instance 0, so a multi-fault draw cannot
    /// take the whole fleet down (losing *capacity* is the scenario
    /// under test; losing *everything* is a different one, covered by
    /// targeted tests).
    pub fn seeded(seed: u64, n_instances: usize, horizon: u64) -> FaultPlan {
        assert!(n_instances > 0);
        let mut rng = Prng::new(seed ^ 0xFA17_FA17_FA17_FA17);
        let mut events = Vec::new();
        let n = 1 + rng.below(3);
        let mut crashed = false;
        for _ in 0..n {
            let instance = rng.below(n_instances);
            let at = 1 + rng.next_u64() % horizon.max(2);
            let kind = match rng.below(4) {
                0 if !crashed && instance != 0 => {
                    crashed = true;
                    FaultKind::Crash
                }
                1 => FaultKind::Stall {
                    hold: Duration::from_millis(10 + rng.below(60) as u64),
                },
                2 => FaultKind::LinkDegrade {
                    factor: 2.0 + rng.below(14) as f64,
                    windows: 2 + rng.next_u64() % (horizon / 2 + 2),
                },
                _ => FaultKind::BitFlip,
            };
            events.push(FaultEvent { instance, at, kind });
        }
        FaultPlan { events }
    }

    /// Re-serialize to the spec grammar (recorded in bench artifacts so
    /// a chaos run is reproducible from its own report).
    pub fn spec(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                FaultKind::Crash => format!("crash:{}@{}", e.instance, e.at),
                FaultKind::Stall { hold } => {
                    format!("stall:{}@{}+{}ms", e.instance, e.at, hold.as_millis())
                }
                FaultKind::BitFlip => format!("flip:{}@{}", e.instance, e.at),
                FaultKind::LinkDegrade { factor, windows } => {
                    format!("link:{}@{}*{}+{}", e.instance, e.at, factor, windows)
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

fn parse_u64(tok: &str, s: &str) -> Result<u64> {
    s.parse()
        .map_err(|_| Error::config(format!("fault `{tok}`: bad number `{s}`")))
}

/// Per-instance health, as placement sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Full placement budget.
    Healthy,
    /// Recent anomalies; still placeable, but one more strike from Down.
    Degraded,
    /// Masked out of placement (crashed, or repeated anomalies).
    Down,
    /// Probing: one window at a time until it proves itself clean.
    Recovering,
}

impl HealthState {
    pub fn as_str(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Down => "down",
            HealthState::Recovering => "recovering",
        }
    }
}

/// Thresholds for the health state machine, in consecutive outcomes and
/// pump rounds.
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    /// Consecutive anomalies before Healthy demotes to Degraded.
    pub degraded_after: u32,
    /// Consecutive anomalies before the instance goes Down.
    pub down_after: u32,
    /// Consecutive clean completions before Degraded/Recovering
    /// readmits to Healthy.
    pub recover_after: u32,
    /// Pump rounds before the first re-probe of a Down instance.
    pub probe_after_rounds: u64,
    /// Cap on the doubling probe backoff.
    pub probe_backoff_max: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            degraded_after: 1,
            down_after: 3,
            recover_after: 2,
            probe_after_rounds: 8,
            probe_backoff_max: 256,
        }
    }
}

/// The health state machine for one fleet instance.
///
/// Driven by the coordinator: `on_anomaly` for timeouts/corruptions,
/// `on_dead` for hard evidence the service is gone, `on_ok` for clean
/// completions, and `tick` each pump round to schedule re-probes.
#[derive(Clone, Debug)]
pub struct InstanceHealth {
    state: HealthState,
    anomalies: u32,
    clean: u32,
    /// A killed service never comes back; suppress probing.
    permanent: bool,
    probe_backoff: u64,
    next_probe_at: u64,
    /// Round the instance last went Down (recovery-latency accounting).
    down_since: u64,
    /// Times this instance entered Down.
    pub downs: u64,
    /// Times this instance recovered back to Healthy from Down.
    pub recoveries: u64,
    /// Total pump rounds spent Down/Recovering before readmission.
    pub recovery_rounds: u64,
}

impl InstanceHealth {
    pub fn new(cfg: &HealthConfig) -> InstanceHealth {
        InstanceHealth {
            state: HealthState::Healthy,
            anomalies: 0,
            clean: 0,
            permanent: false,
            probe_backoff: cfg.probe_after_rounds.max(1),
            next_probe_at: 0,
            down_since: 0,
            downs: 0,
            recoveries: 0,
            recovery_rounds: 0,
        }
    }

    pub fn state(&self) -> HealthState {
        self.state
    }

    pub fn is_down(&self) -> bool {
        self.state == HealthState::Down
    }

    pub fn is_permanently_down(&self) -> bool {
        self.permanent
    }

    /// May placement offer this instance work right now?
    pub fn placeable(&self) -> bool {
        !matches!(self.state, HealthState::Down)
    }

    /// Concurrency cap while probing (`Recovering` instances get one
    /// window at a time); `None` means the model's own budget applies.
    pub fn probe_cap(&self) -> Option<usize> {
        match self.state {
            HealthState::Recovering => Some(1),
            _ => None,
        }
    }

    /// A clean completion. Enough of them readmit a Degraded or
    /// Recovering instance to Healthy. Returns `true` on readmission
    /// from Recovering (a full down→up cycle).
    pub fn on_ok(&mut self, cfg: &HealthConfig, round: u64) -> bool {
        self.anomalies = 0;
        self.clean = self.clean.saturating_add(1);
        match self.state {
            HealthState::Degraded if self.clean >= cfg.recover_after => {
                self.state = HealthState::Healthy;
                false
            }
            HealthState::Recovering if self.clean >= cfg.recover_after => {
                self.state = HealthState::Healthy;
                self.recoveries += 1;
                self.recovery_rounds += round.saturating_sub(self.down_since);
                self.probe_backoff = cfg.probe_after_rounds.max(1);
                true
            }
            _ => false,
        }
    }

    /// A soft anomaly (deadline timeout, corrupted result). Returns
    /// `true` when this strike takes the instance Down.
    pub fn on_anomaly(&mut self, cfg: &HealthConfig, round: u64) -> bool {
        self.clean = 0;
        self.anomalies = self.anomalies.saturating_add(1);
        match self.state {
            HealthState::Down => false,
            _ if self.anomalies >= cfg.down_after => {
                self.go_down(round, false);
                true
            }
            HealthState::Healthy if self.anomalies >= cfg.degraded_after => {
                self.state = HealthState::Degraded;
                false
            }
            // A Recovering probe that misbehaves goes straight back Down.
            HealthState::Recovering => {
                self.go_down(round, false);
                true
            }
            _ => false,
        }
    }

    /// Hard evidence the service is gone (disconnected channel, killed
    /// queue). `permanent` suppresses re-probing — a killed service
    /// never reopens. Returns `true` when this transitions to Down.
    pub fn on_dead(&mut self, round: u64, permanent: bool) -> bool {
        self.permanent = self.permanent || permanent;
        if self.state == HealthState::Down {
            return false;
        }
        self.go_down(round, permanent);
        true
    }

    fn go_down(&mut self, round: u64, permanent: bool) {
        self.state = HealthState::Down;
        self.permanent = self.permanent || permanent;
        self.downs += 1;
        self.down_since = round;
        self.clean = 0;
        self.next_probe_at = round + self.probe_backoff;
        self.probe_backoff = (self.probe_backoff * 2).min(self.next_backoff_cap());
    }

    fn next_backoff_cap(&self) -> u64 {
        // The cap is stored implicitly via HealthConfig at tick time;
        // keep a generous hard ceiling so a lost config can't overflow.
        1 << 20
    }

    /// Advance the probe clock: a non-permanent Down instance becomes
    /// Recovering once its backoff expires. Call once per pump round.
    pub fn tick(&mut self, cfg: &HealthConfig, round: u64) {
        self.probe_backoff = self.probe_backoff.min(cfg.probe_backoff_max.max(1));
        if self.state == HealthState::Down && !self.permanent && round >= self.next_probe_at {
            self.state = HealthState::Recovering;
            self.anomalies = 0;
            self.clean = 0;
        }
    }
}

/// Bounded retry with exponential backoff and deterministic jitter,
/// measured in pump rounds. This sits *above* the AIMD burst controller:
/// AIMD paces how fast the pump pushes into a live fleet; this policy
/// spaces out re-submissions of windows that already failed once, so a
/// flapping instance is not hammered back down.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Re-submission attempts after the first (0 = fail immediately).
    pub max_retries: u32,
    /// Backoff before retry k is `base << k` rounds, capped…
    pub base_rounds: u64,
    /// …at this many rounds, plus jitter in `[0, delay/2]`.
    pub max_rounds: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_rounds: 2,
            max_rounds: 64,
        }
    }
}

impl RetryPolicy {
    /// Rounds to wait before retry number `attempt` (0-based), jittered.
    pub fn delay(&self, attempt: u32, jitter: &mut Prng) -> u64 {
        let exp = self
            .base_rounds
            .saturating_mul(1u64 << attempt.min(32))
            .min(self.max_rounds.max(1));
        exp + jitter.next_u64() % (exp / 2 + 1)
    }
}

/// Everything the coordinator's fault layer is configured by.
#[derive(Clone, Copy, Debug)]
pub struct FaultToleranceConfig {
    /// In-flight windows older than this are presumed stranded and fail
    /// over (hedged: the original, should it still arrive, is deduped).
    pub deadline: Duration,
    /// Per-window retry budget and backoff shape.
    pub retry: RetryPolicy,
    /// Fidelity bound: any |θ_i| above this (or non-finite) is
    /// corruption. Generous vs normalized-data coefficients (≲ 10²) yet
    /// far below what a flipped exponent bit produces (≳ 10³⁸).
    pub theta_bound: f32,
    /// When placeable concurrency budget falls below this fraction of
    /// the full-fleet budget, enter degraded mode.
    pub degraded_capacity_frac: f64,
    /// AIMD burst ceiling while degraded (lower concurrency so the
    /// surviving instances keep their deadlines).
    pub degraded_burst: usize,
    /// Health state machine thresholds.
    pub health: HealthConfig,
}

impl Default for FaultToleranceConfig {
    fn default() -> Self {
        FaultToleranceConfig {
            deadline: Duration::from_secs(30),
            retry: RetryPolicy::default(),
            theta_bound: 1e6,
            degraded_capacity_frac: 0.75,
            degraded_burst: 2,
            health: HealthConfig::default(),
        }
    }
}

/// Cheap post-hoc fidelity check: every coefficient finite and within
/// `bound`. For normalized inputs the recovered Θ lives well inside
/// ±10³, while a flipped high exponent bit lands around ±10³⁸ — so the
/// check separates the two regimes with no residual recomputation.
pub fn fidelity_check(theta: &[f32], bound: f32) -> Result<()> {
    for (i, &v) in theta.iter().enumerate() {
        if !v.is_finite() || v.abs() > bound {
            return Err(Error::corrupted(format!("theta[{i}] = {v} (bound {bound})")));
        }
    }
    Ok(())
}

/// Inject a detectable bit-flip into `theta`: flip the high exponent
/// bit (bit 30) of the first coefficient where the flip lands outside
/// the fidelity bound, emulating an SEU in a result register. Returns
/// `(index, bit)` of the applied flip, or `None` for the degenerate
/// vector where no single flip is detectable (then nothing is injected
/// — an undetectable upset is outside this fault model's scope).
pub fn corrupt_theta(theta: &mut [f32], bound: f32) -> Option<(usize, u32)> {
    for bit in [30u32, 29, 28] {
        for (i, v) in theta.iter_mut().enumerate() {
            let flipped = f32::from_bits(v.to_bits() ^ (1 << bit));
            if !flipped.is_finite() || flipped.abs() > bound {
                *v = flipped;
                return Some((i, bit));
            }
        }
    }
    None
}

/// Counters for the `faults` section of `BENCH_stream.json` and the
/// chaos self-verification in `merinda soak --chaos`.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    pub injected_crash: u64,
    pub injected_stall: u64,
    pub injected_link: u64,
    pub injected_flip: u64,
    /// In-flight windows that blew the deadline and failed over.
    pub detected_timeouts: u64,
    /// Response channels observed disconnected (instance death).
    pub detected_disconnects: u64,
    /// Results rejected by the fidelity check.
    pub detected_corruptions: u64,
    /// Submissions refused because the target service was already dead.
    pub detected_submit_down: u64,
    /// Windows re-placed from a dead/stranded instance onto a sibling.
    pub failed_over: u64,
    /// Re-submissions performed by the bounded retry policy.
    pub retries: u64,
    /// Late (hedged) duplicates discarded by the dedupe filter.
    pub duplicates_dropped: u64,
    /// Windows that exhausted their retry budget and failed for real.
    pub exhausted: u64,
    /// Times the coordinator entered degraded mode.
    pub degraded_entries: u64,
    /// Times it restored full service.
    pub degraded_exits: u64,
    /// Windows served by the standby instance while degraded.
    pub standby_windows: u64,
    /// Instances that went Down at least once / recovered to Healthy.
    pub instances_down: u64,
    pub instances_recovered: u64,
    /// Total pump rounds instances spent down before readmission.
    pub recovery_rounds_total: u64,
}

impl FaultStats {
    /// Sum of injected events (plan size actually fired).
    pub fn injected_total(&self) -> u64 {
        self.injected_crash + self.injected_stall + self.injected_link + self.injected_flip
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("crash:1@6, stall:0@10+200ms, flip:2@1, link:1@4*8+20", 3)
            .unwrap();
        assert_eq!(p.events.len(), 4);
        assert_eq!(p.events[0].instance, 1);
        assert_eq!(p.events[0].at, 6);
        assert_eq!(p.events[0].kind, FaultKind::Crash);
        assert_eq!(
            p.events[1].kind,
            FaultKind::Stall {
                hold: Duration::from_millis(200)
            }
        );
        assert_eq!(p.events[2].kind, FaultKind::BitFlip);
        assert_eq!(
            p.events[3].kind,
            FaultKind::LinkDegrade {
                factor: 8.0,
                windows: 20
            }
        );
    }

    #[test]
    fn parse_round_trips_through_spec() {
        let s = "crash:1@6,stall:0@10+200ms,flip:2@1,link:1@4*8+20";
        let p = FaultPlan::parse(s, 3).unwrap();
        assert_eq!(p.spec(), s);
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "crash:9@1",        // instance out of range
            "crash:1",          // missing trigger
            "melt:0@1",         // unknown kind
            "stall:0@1+5",      // missing ms suffix
            "link:0@1*0.5+5",   // factor below 1
            "flip:0@0",         // flips are 1-based
            "crash:x@1",        // bad instance
        ] {
            assert!(FaultPlan::parse(bad, 3).is_err(), "accepted `{bad}`");
        }
        assert!(FaultPlan::parse("", 3).unwrap().is_empty());
    }

    #[test]
    fn seeded_plans_are_reproducible_and_bounded() {
        for seed in 0..64u64 {
            let a = FaultPlan::seeded(seed, 3, 20);
            let b = FaultPlan::seeded(seed, 3, 20);
            assert_eq!(a.spec(), b.spec(), "seed {seed} must replay");
            assert!(!a.is_empty() && a.events.len() <= 3);
            let crashes: Vec<_> = a
                .events
                .iter()
                .filter(|e| e.kind == FaultKind::Crash)
                .collect();
            assert!(crashes.len() <= 1, "seed {seed}: at most one crash");
            for c in crashes {
                assert_ne!(c.instance, 0, "seed {seed}: instance 0 never crashes");
            }
        }
        assert_ne!(
            FaultPlan::seeded(1, 3, 20).spec(),
            FaultPlan::seeded(2, 3, 20).spec()
        );
    }

    #[test]
    fn health_degrades_then_downs_then_recovers() {
        let cfg = HealthConfig::default();
        let mut h = InstanceHealth::new(&cfg);
        assert_eq!(h.state(), HealthState::Healthy);
        assert!(h.placeable());

        h.on_anomaly(&cfg, 0);
        assert_eq!(h.state(), HealthState::Degraded);
        assert!(h.placeable(), "degraded still serves");

        h.on_anomaly(&cfg, 1);
        let went_down = h.on_anomaly(&cfg, 2);
        assert!(went_down);
        assert_eq!(h.state(), HealthState::Down);
        assert!(!h.placeable(), "down is masked");
        assert_eq!(h.downs, 1);

        // Probe backoff: not recovering until the clock passes.
        h.tick(&cfg, 3);
        assert_eq!(h.state(), HealthState::Down);
        h.tick(&cfg, 2 + cfg.probe_after_rounds);
        assert_eq!(h.state(), HealthState::Recovering);
        assert_eq!(h.probe_cap(), Some(1), "probe one window at a time");

        // Clean probes readmit.
        assert!(!h.on_ok(&cfg, 12));
        let recovered = h.on_ok(&cfg, 13);
        assert!(recovered);
        assert_eq!(h.state(), HealthState::Healthy);
        assert_eq!(h.recoveries, 1);
        assert!(h.recovery_rounds > 0);
    }

    #[test]
    fn degraded_heals_with_clean_completions() {
        let cfg = HealthConfig::default();
        let mut h = InstanceHealth::new(&cfg);
        h.on_anomaly(&cfg, 0);
        assert_eq!(h.state(), HealthState::Degraded);
        h.on_ok(&cfg, 1);
        h.on_ok(&cfg, 2);
        assert_eq!(h.state(), HealthState::Healthy);
        assert_eq!(h.downs, 0, "never went down");
    }

    #[test]
    fn permanent_death_never_probes() {
        let cfg = HealthConfig::default();
        let mut h = InstanceHealth::new(&cfg);
        assert!(h.on_dead(5, true));
        assert!(h.is_permanently_down());
        for round in 0..10_000 {
            h.tick(&cfg, round);
        }
        assert_eq!(h.state(), HealthState::Down, "killed instances stay down");
    }

    #[test]
    fn failed_probe_goes_straight_back_down_with_longer_backoff() {
        let cfg = HealthConfig::default();
        let mut h = InstanceHealth::new(&cfg);
        h.on_dead(0, false);
        h.tick(&cfg, cfg.probe_after_rounds);
        assert_eq!(h.state(), HealthState::Recovering);
        assert!(h.on_anomaly(&cfg, cfg.probe_after_rounds + 1));
        assert_eq!(h.state(), HealthState::Down);
        assert_eq!(h.downs, 2);
        // Second probe waits roughly twice as long (doubled backoff).
        let second_wait = cfg.probe_after_rounds + 1 + 2 * cfg.probe_after_rounds;
        h.tick(&cfg, second_wait - 1);
        assert_eq!(h.state(), HealthState::Down);
        h.tick(&cfg, second_wait);
        assert_eq!(h.state(), HealthState::Recovering);
    }

    #[test]
    fn retry_backoff_grows_and_caps_with_bounded_jitter() {
        let pol = RetryPolicy::default();
        let mut rng = Prng::new(1);
        let mut prev_floor = 0u64;
        for attempt in 0..10 {
            let floor = pol
                .base_rounds
                .saturating_mul(1 << attempt)
                .min(pol.max_rounds);
            let d = pol.delay(attempt, &mut rng);
            assert!(d >= floor, "attempt {attempt}: {d} < floor {floor}");
            assert!(
                d <= floor + floor / 2,
                "attempt {attempt}: jitter above 50%: {d} vs {floor}"
            );
            assert!(floor >= prev_floor, "backoff must be monotone");
            prev_floor = floor;
        }
        // Deterministic for a fixed seed.
        let a = pol.delay(3, &mut Prng::new(9));
        let b = pol.delay(3, &mut Prng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn fidelity_passes_sane_rejects_corrupt() {
        let ok = vec![0.0f32, -3.25, 42.0, 1e3];
        assert!(fidelity_check(&ok, 1e6).is_ok());
        for bad in [f32::NAN, f32::INFINITY, -2e38, 2e7] {
            let theta = vec![1.0f32, bad];
            let err = fidelity_check(&theta, 1e6).unwrap_err();
            assert!(err.is_corrupted(), "{bad} must read as corruption");
            assert!(err.to_string().contains("theta[1]"));
        }
    }

    #[test]
    fn corrupt_theta_is_always_detected() {
        // Across magnitudes a normalized solve can produce, the injected
        // flip must violate the fidelity bound it will be checked with.
        for base in [1e-4f32, 0.5, 2.0, 45.0, -127.5, 900.0] {
            let mut theta = vec![base; 8];
            let hit = corrupt_theta(&mut theta, 1e6);
            let (i, bit) = hit.expect("flip must be injectable");
            assert!(bit >= 28);
            assert!(
                fidelity_check(&theta, 1e6).is_err(),
                "flip of {base} at bit {bit} (idx {i}) escaped detection"
            );
        }
        // The degenerate all-zero vector has no detectable single-bit
        // flip (the largest reachable value is 2.0); nothing is injected.
        let mut zeros = vec![0.0f32; 4];
        assert_eq!(corrupt_theta(&mut zeros, 1e6), None);
        assert!(zeros.iter().all(|&v| v == 0.0), "must not corrupt silently");
    }

    #[test]
    fn fault_stats_total_sums_injections() {
        let s = FaultStats {
            injected_crash: 1,
            injected_stall: 2,
            injected_link: 3,
            injected_flip: 4,
            ..Default::default()
        };
        assert_eq!(s.injected_total(), 10);
    }
}

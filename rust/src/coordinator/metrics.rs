//! Service metrics: counters, latency distribution, and a per-instance
//! breakdown so fleet placement decisions are observable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::traffic::QosClass;

/// Latency statistics over recorded samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Per-accelerator-instance counters (fleet placement observability).
#[derive(Clone, Copy, Debug, Default)]
struct InstanceCounters {
    placed: u64,
    completed: u64,
    rejected: u64,
    queue_depth_max: u64,
    modeled_cycles: u64,
    failed_over: u64,
}

/// A point-in-time copy of one instance's counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct InstanceSnapshot {
    /// Windows the placement layer routed to this instance.
    pub placed: u64,
    /// Windows this instance completed.
    pub completed: u64,
    /// Submissions this instance's bounded queue refused (spilled to a
    /// sibling or held for retry).
    pub rejected: u64,
    /// High-water mark of outstanding windows on this instance.
    pub queue_depth_max: u64,
    /// Accelerator cycles this instance's completed windows consumed
    /// under the cycle model.
    pub modeled_cycles: u64,
    /// Windows stranded on this instance (crash/timeout) and re-placed
    /// on a healthy sibling by the fault layer.
    pub failed_over: u64,
}

/// Per-QoS-tier counters and end-to-end latency samples.
///
/// Unlike the global counters (which conflate tiers), these make the
/// per-tier placed/shed/rejected story first-class: the admission
/// controller, the shed-ordering sweep, and the completion path each
/// report under the window's tier, and the open-loop gate closes the
/// books per tier (`offered == admitted + rejected`,
/// `admitted == completed + shed + failed`).
#[derive(Clone, Debug, Default)]
struct TierCounters {
    offered: u64,
    admitted: u64,
    rejected: u64,
    placed: u64,
    shed: u64,
    completed: u64,
    failed: u64,
    /// End-to-end (enqueue → result) latencies, ms.
    latencies_ms: Vec<f64>,
}

/// A point-in-time copy of one QoS tier's counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct TierSnapshot {
    /// Open-loop arrivals targeted at this tier.
    pub offered: u64,
    /// Arrivals the admission controller let through.
    pub admitted: u64,
    /// Arrivals rejected to protect the tier's SLO.
    pub rejected: u64,
    /// Windows the placement layer routed to an instance.
    pub placed: u64,
    /// Windows deliberately dropped by shed policy (queue overflow or
    /// the backlog-budget sweep).
    pub shed: u64,
    /// Windows that completed with a recovered Θ.
    pub completed: u64,
    /// Windows that exhausted retries.
    pub failed: u64,
    /// End-to-end latency distribution over completed windows.
    pub latency_count: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub max_ms: f64,
}

/// Shared metrics sink (thread-safe).
#[derive(Debug, Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    queue_depth_max: AtomicU64,
    latencies_ms: Mutex<Vec<f64>>,
    /// Indexed by fleet instance id, grown on first touch.
    instances: Mutex<Vec<InstanceCounters>>,
    /// Indexed by [`QosClass::index`].
    tiers: Mutex<[TierCounters; 3]>,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Items deliberately dropped by a load-shedding policy (as opposed
    /// to `rejected`, which counts refused submissions).
    pub shed: u64,
    pub batches: u64,
    /// Mean items per executed batch (batching efficiency).
    pub mean_batch_occupancy: f64,
    /// High-water mark of the submission queue depth.
    pub queue_depth_max: u64,
    pub latency: LatencyStats,
    /// Per-fleet-instance breakdown (empty for single-service setups
    /// that never report placement).
    pub per_instance: Vec<InstanceSnapshot>,
    /// Per-QoS-tier breakdown, indexed by [`QosClass::index`] (all-zero
    /// for drivers that never set tenant tiers).
    pub per_tier: [TierSnapshot; 3],
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a deliberate load-shed decision (streaming layer).
    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the observed submission-queue depth (keeps the maximum).
    pub fn on_queue_depth(&self, depth: usize) {
        self.queue_depth_max.fetch_max(depth as u64, Ordering::Relaxed);
    }

    fn with_instance(&self, idx: usize, f: impl FnOnce(&mut InstanceCounters)) {
        // Metrics must survive a worker panic (poisoned lock): counters
        // are plain integers, always coherent.
        let mut v = self
            .instances
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        if v.len() <= idx {
            v.resize(idx + 1, InstanceCounters::default());
        }
        f(&mut v[idx]);
    }

    /// Record a window placed onto fleet instance `idx`.
    pub fn on_instance_placed(&self, idx: usize) {
        self.with_instance(idx, |c| c.placed += 1);
    }

    /// Record a window completed by fleet instance `idx`, charging its
    /// modeled accelerator cycles.
    pub fn on_instance_complete(&self, idx: usize, cycles: u64) {
        self.with_instance(idx, |c| {
            c.completed += 1;
            c.modeled_cycles += cycles;
        });
    }

    /// Record instance `idx` refusing a submission (bounded queue full).
    pub fn on_instance_reject(&self, idx: usize) {
        self.with_instance(idx, |c| c.rejected += 1);
    }

    /// Record a window stranded on instance `idx` and re-placed on a
    /// healthy sibling (crash / deadline-timeout failover).
    pub fn on_instance_failover(&self, idx: usize) {
        self.with_instance(idx, |c| c.failed_over += 1);
    }

    /// Record instance `idx`'s outstanding-window depth (keeps the max).
    pub fn on_instance_queue_depth(&self, idx: usize, depth: usize) {
        self.with_instance(idx, |c| c.queue_depth_max = c.queue_depth_max.max(depth as u64));
    }

    fn with_tier(&self, tier: QosClass, f: impl FnOnce(&mut TierCounters)) {
        let mut tiers = self
            .tiers
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        f(&mut tiers[tier.index()]);
    }

    /// Record an open-loop arrival targeted at `tier`.
    pub fn on_tier_offered(&self, tier: QosClass) {
        self.with_tier(tier, |c| c.offered += 1);
    }

    /// Record an arrival admitted past the SLO controller.
    pub fn on_tier_admitted(&self, tier: QosClass) {
        self.with_tier(tier, |c| c.admitted += 1);
    }

    /// Record an arrival rejected to protect `tier`'s SLO.
    pub fn on_tier_rejected(&self, tier: QosClass) {
        self.with_tier(tier, |c| c.rejected += 1);
    }

    /// Record a window of `tier` placed onto a fleet instance.
    pub fn on_tier_placed(&self, tier: QosClass) {
        self.with_tier(tier, |c| c.placed += 1);
    }

    /// Record a window of `tier` deliberately shed.
    pub fn on_tier_shed(&self, tier: QosClass) {
        self.with_tier(tier, |c| c.shed += 1);
    }

    /// Record a completed window of `tier` with its end-to-end
    /// (enqueue → result) latency — queue wait included, unlike the
    /// global [`Metrics::on_complete`] service latency.
    pub fn on_tier_completed(&self, tier: QosClass, latency: Duration) {
        self.with_tier(tier, |c| {
            c.completed += 1;
            c.latencies_ms.push(latency.as_secs_f64() * 1e3);
        });
    }

    /// Record a window of `tier` that exhausted its retries.
    pub fn on_tier_failed(&self, tier: QosClass) {
        self.with_tier(tier, |c| c.failed += 1);
    }

    pub fn on_batch(&self, items: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items, Ordering::Relaxed);
    }

    pub fn on_complete(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_ms
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .push(latency.as_secs_f64() * 1e3);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lats = self
            .latencies_ms
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .clone();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        let per_instance = self
            .instances
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .iter()
            .map(|c| InstanceSnapshot {
                placed: c.placed,
                completed: c.completed,
                rejected: c.rejected,
                queue_depth_max: c.queue_depth_max,
                modeled_cycles: c.modeled_cycles,
                failed_over: c.failed_over,
            })
            .collect();
        let per_tier = {
            let tiers = self
                .tiers
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            let mut out = [TierSnapshot::default(); 3];
            for (snap, c) in out.iter_mut().zip(tiers.iter()) {
                *snap = tier_snapshot(c);
            }
            out
        };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches,
            queue_depth_max: self.queue_depth_max.load(Ordering::Relaxed),
            mean_batch_occupancy: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            latency: latency_stats(&lats),
            per_instance,
            per_tier,
        }
    }
}

fn tier_snapshot(c: &TierCounters) -> TierSnapshot {
    use crate::util::stats;
    let lats = &c.latencies_ms;
    let (p50, p99, p999, max) = if lats.is_empty() {
        (0.0, 0.0, 0.0, 0.0)
    } else {
        (
            stats::percentile(lats, 50.0),
            stats::percentile(lats, 99.0),
            stats::percentile(lats, 99.9),
            lats.iter().cloned().fold(0.0, f64::max),
        )
    };
    TierSnapshot {
        offered: c.offered,
        admitted: c.admitted,
        rejected: c.rejected,
        placed: c.placed,
        shed: c.shed,
        completed: c.completed,
        failed: c.failed,
        latency_count: lats.len() as u64,
        p50_ms: p50,
        p99_ms: p99,
        p999_ms: p999,
        max_ms: max,
    }
}

fn latency_stats(lats: &[f64]) -> LatencyStats {
    if lats.is_empty() {
        return LatencyStats::default();
    }
    use crate::util::stats;
    LatencyStats {
        count: lats.len() as u64,
        mean_ms: stats::mean(lats),
        p50_ms: stats::percentile(lats, 50.0),
        p99_ms: stats::percentile(lats, 99.0),
        max_ms: lats.iter().cloned().fold(0.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_shed();
        m.on_shed();
        m.on_batch(6);
        m.on_batch(8);
        m.on_queue_depth(3);
        m.on_queue_depth(9);
        m.on_queue_depth(5);
        m.on_complete(Duration::from_millis(10));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.queue_depth_max, 9, "gauge must keep the high-water mark");
        assert!((s.mean_batch_occupancy - 7.0).abs() < 1e-12);
        assert!(s.latency.mean_ms >= 9.0);
    }

    #[test]
    fn empty_latency_stats() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency.count, 0);
        assert_eq!(s.latency.p99_ms, 0.0);
        assert!(s.per_instance.is_empty(), "no placement → no breakdown");
    }

    #[test]
    fn per_instance_counters_grow_on_demand() {
        let m = Metrics::new();
        m.on_instance_placed(2);
        m.on_instance_placed(0);
        m.on_instance_placed(0);
        m.on_instance_reject(2);
        m.on_instance_queue_depth(0, 3);
        m.on_instance_queue_depth(0, 1);
        m.on_instance_complete(0, 500);
        m.on_instance_complete(0, 700);
        let s = m.snapshot();
        assert_eq!(s.per_instance.len(), 3, "indexing must size the vector");
        assert_eq!(s.per_instance[0].placed, 2);
        assert_eq!(s.per_instance[0].completed, 2);
        assert_eq!(s.per_instance[0].modeled_cycles, 1200);
        assert_eq!(s.per_instance[0].queue_depth_max, 3);
        assert_eq!(s.per_instance[1].placed, 0, "untouched slot stays zero");
        assert_eq!(s.per_instance[2].placed, 1);
        assert_eq!(s.per_instance[2].rejected, 1);
    }

    #[test]
    fn failover_counter_tracks_stranded_windows() {
        let m = Metrics::new();
        m.on_instance_failover(1);
        m.on_instance_failover(1);
        let s = m.snapshot();
        assert_eq!(s.per_instance[1].failed_over, 2);
        assert_eq!(s.per_instance[0].failed_over, 0);
    }

    #[test]
    fn tier_counters_are_first_class() {
        let m = Metrics::new();
        // Realtime: 3 offered, 2 admitted (1 rejected), both complete.
        for _ in 0..3 {
            m.on_tier_offered(QosClass::Realtime);
        }
        m.on_tier_admitted(QosClass::Realtime);
        m.on_tier_admitted(QosClass::Realtime);
        m.on_tier_rejected(QosClass::Realtime);
        m.on_tier_placed(QosClass::Realtime);
        m.on_tier_placed(QosClass::Realtime);
        m.on_tier_completed(QosClass::Realtime, Duration::from_millis(4));
        m.on_tier_completed(QosClass::Realtime, Duration::from_millis(8));
        // Batch: 2 offered and admitted, one shed, one failed.
        m.on_tier_offered(QosClass::Batch);
        m.on_tier_offered(QosClass::Batch);
        m.on_tier_admitted(QosClass::Batch);
        m.on_tier_admitted(QosClass::Batch);
        m.on_tier_shed(QosClass::Batch);
        m.on_tier_failed(QosClass::Batch);
        let s = m.snapshot();
        let rt = s.per_tier[QosClass::Realtime.index()];
        assert_eq!(rt.offered, 3);
        assert_eq!(rt.admitted, 2);
        assert_eq!(rt.rejected, 1);
        assert_eq!(rt.placed, 2);
        assert_eq!(rt.completed, 2);
        assert_eq!(rt.latency_count, 2);
        assert_eq!(rt.offered, rt.admitted + rt.rejected, "admission closes");
        let b = s.per_tier[QosClass::Batch.index()];
        assert_eq!(b.admitted, b.completed + b.shed + b.failed, "books close");
        let std_tier = s.per_tier[QosClass::Standard.index()];
        assert_eq!(std_tier.offered, 0, "untouched tier stays zero");
    }

    #[test]
    fn tier_latency_percentiles_ordered_with_p999() {
        let m = Metrics::new();
        for i in 1..=2000u64 {
            m.on_tier_completed(QosClass::Standard, Duration::from_micros(i * 100));
        }
        let t = m.snapshot().per_tier[QosClass::Standard.index()];
        assert_eq!(t.latency_count, 2000);
        assert!(t.p50_ms <= t.p99_ms);
        assert!(t.p99_ms <= t.p999_ms, "p999 must dominate p99");
        assert!(t.p999_ms <= t.max_ms);
        assert!(t.p999_ms > t.p50_ms, "tail must separate from the median");
    }

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.on_complete(Duration::from_millis(i));
        }
        let l = m.snapshot().latency;
        assert!(l.p50_ms <= l.p99_ms);
        assert!(l.p99_ms <= l.max_ms);
    }
}

//! Service metrics: counters and latency distribution.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Latency statistics over recorded samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Shared metrics sink (thread-safe).
#[derive(Debug, Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    queue_depth_max: AtomicU64,
    latencies_ms: Mutex<Vec<f64>>,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Items deliberately dropped by a load-shedding policy (as opposed
    /// to `rejected`, which counts refused submissions).
    pub shed: u64,
    pub batches: u64,
    /// Mean items per executed batch (batching efficiency).
    pub mean_batch_occupancy: f64,
    /// High-water mark of the submission queue depth.
    pub queue_depth_max: u64,
    pub latency: LatencyStats,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a deliberate load-shed decision (streaming layer).
    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the observed submission-queue depth (keeps the maximum).
    pub fn on_queue_depth(&self, depth: usize) {
        self.queue_depth_max.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn on_batch(&self, items: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items, Ordering::Relaxed);
    }

    pub fn on_complete(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_ms
            .lock()
            .unwrap()
            .push(latency.as_secs_f64() * 1e3);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lats = self.latencies_ms.lock().unwrap().clone();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches,
            queue_depth_max: self.queue_depth_max.load(Ordering::Relaxed),
            mean_batch_occupancy: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            latency: latency_stats(&lats),
        }
    }
}

fn latency_stats(lats: &[f64]) -> LatencyStats {
    if lats.is_empty() {
        return LatencyStats::default();
    }
    use crate::util::stats;
    LatencyStats {
        count: lats.len() as u64,
        mean_ms: stats::mean(lats),
        p50_ms: stats::percentile(lats, 50.0),
        p99_ms: stats::percentile(lats, 99.0),
        max_ms: lats.iter().cloned().fold(0.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_shed();
        m.on_shed();
        m.on_batch(6);
        m.on_batch(8);
        m.on_queue_depth(3);
        m.on_queue_depth(9);
        m.on_queue_depth(5);
        m.on_complete(Duration::from_millis(10));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.queue_depth_max, 9, "gauge must keep the high-water mark");
        assert!((s.mean_batch_occupancy - 7.0).abs() < 1e-12);
        assert!(s.latency.mean_ms >= 9.0);
    }

    #[test]
    fn empty_latency_stats() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency.count, 0);
        assert_eq!(s.latency.p99_ms, 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.on_complete(Duration::from_millis(i));
        }
        let l = m.snapshot().latency;
        assert!(l.p50_ms <= l.p99_ms);
        assert!(l.p99_ms <= l.max_ms);
    }
}

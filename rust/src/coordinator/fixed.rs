//! Fixed-point (quantized) in-process inference backend.
//!
//! The end-to-end quantized recovery path of the paper (§5, §6.4): GRU
//! weights and activations stored in 8–16-bit fixed-point formats, the
//! batched GRU forward running through the saturating-accumulator
//! datapath (`mr::linalg::gru_forward_batch_fixed`), and a per-window
//! cycle/interval report derived from the HLS stage schedule
//! (`fpga::gru_accel`) plus the DATAFLOW pipeline model
//! (`fpga::pipeline`). Plugs into [`InferenceBackend`], so the sharded
//! `Service` workers serve quantized traffic exactly like the f32
//! [`NativeBackend`] — clones share one set of cycle counters, so a
//! sharded deployment still aggregates into a single report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::fpga::fixedpoint::{DatapathFormats, FixedFormat};
use crate::fpga::gru_accel::{GruAccel, GruAccelConfig};
use crate::fpga::pipeline::Pipeline;
use crate::mr::dense::DenseHead;
use crate::mr::gru::GruParams;
use crate::mr::linalg::{dense_head_batch_fixed, gru_forward_batch_fixed, PackedGru};
use crate::util::{Error, Result};

use super::native::NativeBackend;
use super::service::InferenceBackend;

/// Quantization configuration of the fixed-point serving datapath.
#[derive(Clone, Copy, Debug)]
pub struct FixedPointConfig {
    /// Activation/state storage format.
    pub act_fmt: FixedFormat,
    /// Weight storage format (applied once at construction).
    pub weight_fmt: FixedFormat,
    /// Wide saturating accumulator (DSP48 post-adder model).
    pub acc_fmt: FixedFormat,
}

impl FixedPointConfig {
    /// Explicit activation/weight formats; the accumulator is derived via
    /// [`FixedFormat::accumulator_for`].
    pub fn with_formats(act: FixedFormat, weight: FixedFormat) -> FixedPointConfig {
        FixedPointConfig {
            act_fmt: act,
            weight_fmt: weight,
            acc_fmt: FixedFormat::accumulator_for(act, weight),
        }
    }

    /// The paper's sweet spot: Q8.8 activations and weights.
    pub fn q8_8() -> FixedPointConfig {
        FixedPointConfig::with_formats(FixedFormat::q8_8(), FixedFormat::q8_8())
    }

    /// The paper's 12-bit weight format (Q4.8) end to end.
    pub fn q4_8() -> FixedPointConfig {
        FixedPointConfig::with_formats(FixedFormat::q4_8(), FixedFormat::q4_8())
    }

    /// Aggressive 8-bit end-to-end format (4 fractional bits).
    pub fn int8() -> FixedPointConfig {
        FixedPointConfig::with_formats(FixedFormat::new(8, 4), FixedFormat::new(8, 4))
    }

    /// Parse a CLI format name (`merinda serve --backend fixed --fmt X`).
    pub fn from_name(name: &str) -> Result<FixedPointConfig> {
        match name {
            "q8.8" | "q8_8" => Ok(FixedPointConfig::q8_8()),
            "q4.8" | "q4_8" => Ok(FixedPointConfig::q4_8()),
            "8bit" | "int8" => Ok(FixedPointConfig::int8()),
            other => Err(Error::config(format!(
                "unknown fixed-point format {other:?} (expected q8.8, q4.8 or 8bit)"
            ))),
        }
    }

    /// The operand/accumulator pair handed to the batched kernels.
    pub fn datapath(&self) -> DatapathFormats {
        DatapathFormats {
            act: self.act_fmt,
            acc: self.acc_fmt,
        }
    }
}

/// Cumulative modeled-cycle counters, shared across backend clones so a
/// sharded service aggregates into one report.
#[derive(Debug, Default)]
struct CycleCounters {
    batches: AtomicU64,
    windows: AtomicU64,
    cycles: AtomicU64,
}

/// Per-window cycle/interval report of the quantized datapath.
///
/// Two clearly-scoped sub-models: the `step_*` numbers come from the
/// structural accelerator report and include the non-overlapped DDR
/// remainder, while the `window_*` pair streams the scheduled stages
/// through the DATAFLOW pipeline model *without* DDR (which overlaps
/// with compute under DATAFLOW) — so `window_cycles` vs
/// `window_cycles_sequential` isolates exactly what stage overlap buys.
/// At the canonical serving dims the streaming burst hides entirely
/// under the slowest stage, so the two models' intervals coincide.
#[derive(Clone, Copy, Debug)]
pub struct FixedCycleReport {
    /// End-to-end latency of one GRU step (pipeline fill + DDR
    /// remainder, structural report).
    pub step_cycles: u64,
    /// Steady-state cycles between GRU steps (incl. DDR remainder).
    pub step_interval: u64,
    /// One full window (`seq` steps) streamed through the stage
    /// pipeline (stage compute cycles, DATAFLOW overlap).
    pub window_cycles: u64,
    /// The same stages executed with no DATAFLOW overlap.
    pub window_cycles_sequential: u64,
    /// Windows served so far, across all clones of this backend
    /// (includes batch-padding replicas).
    pub windows_served: u64,
    /// Batches executed so far.
    pub batches: u64,
    /// Modeled accelerator cycles accumulated over all served batches.
    pub modeled_cycles: u64,
}

impl FixedCycleReport {
    /// DATAFLOW speedup of a window vs sequential stage execution.
    pub fn dataflow_speedup(&self) -> f64 {
        self.window_cycles_sequential as f64 / self.window_cycles.max(1) as f64
    }
}

/// A self-contained quantized serving backend (clonable: each service
/// worker holds its own copy; cycle counters stay shared).
#[derive(Clone, Debug)]
pub struct FixedPointBackend {
    cfg: FixedPointConfig,
    batch: usize,
    seq: usize,
    xdim: usize,
    udim: usize,
    /// Serving-layout GRU weights, quantized to `cfg.weight_fmt`.
    packed: PackedGru,
    /// Θ head, weights quantized to `cfg.weight_fmt`.
    head: DenseHead,
    /// Stage-level DATAFLOW pipeline (per-item = one GRU step).
    pipeline: Pipeline,
    /// Structural per-step numbers from the HLS schedule.
    step_cycles: u64,
    step_interval: u64,
    counters: Arc<CycleCounters>,
}

impl FixedPointBackend {
    /// Random-weight backend at the canonical serving dims, bit-matched
    /// to [`NativeBackend::new`] with the same seed (useful for accuracy
    /// comparisons, smoke tests and benches).
    pub fn new(batch: usize, seed: u64, cfg: FixedPointConfig) -> FixedPointBackend {
        FixedPointBackend::from_native(&NativeBackend::new(batch, seed), cfg)
            .expect("canonical dims are consistent")
    }

    /// Quantize an existing f32 native backend's weights.
    pub fn from_native(native: &NativeBackend, cfg: FixedPointConfig) -> Result<FixedPointBackend> {
        FixedPointBackend::from_parts(
            native.gru.clone(),
            native.head.clone(),
            cfg,
            native.batch(),
            native.seq(),
            native.xdim(),
            native.udim(),
        )
    }

    /// Build from explicit f32 weights; quantizes them once to
    /// `cfg.weight_fmt` (weights live in BRAM at that width).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        gru: GruParams,
        head: DenseHead,
        cfg: FixedPointConfig,
        batch: usize,
        seq: usize,
        xdim: usize,
        udim: usize,
    ) -> Result<FixedPointBackend> {
        if gru.input != xdim + udim {
            return Err(Error::Shape {
                expected: format!("gru input {}", xdim + udim),
                got: format!("{}", gru.input),
            });
        }
        if head.input != gru.hidden {
            return Err(Error::Shape {
                expected: format!("head input {}", gru.hidden),
                got: format!("{}", head.input),
            });
        }
        if batch == 0 || seq == 0 {
            return Err(Error::config("batch and seq must be nonzero"));
        }
        let mut qgru = gru;
        cfg.weight_fmt.quantize_slice(&mut qgru.w);
        cfg.weight_fmt.quantize_slice(&mut qgru.u);
        cfg.weight_fmt.quantize_slice(&mut qgru.b);
        let mut qhead = head;
        cfg.weight_fmt.quantize_slice(&mut qhead.w1);
        cfg.weight_fmt.quantize_slice(&mut qhead.b1);
        cfg.weight_fmt.quantize_slice(&mut qhead.w2);
        cfg.weight_fmt.quantize_slice(&mut qhead.b2);
        let packed = PackedGru::new(&qgru);

        // Cycle model: the concurrent DATAFLOW accelerator at serving
        // dims and the configured formats. Each pipeline item is one GRU
        // step whose per-stage service time comes from the HLS schedule.
        let accel = GruAccel::new(GruAccelConfig::serving(
            xdim + udim,
            qgru.hidden,
            cfg.act_fmt,
            cfg.weight_fmt,
        ));
        let report = accel.report();
        let pipeline = accel.stage_pipeline();

        Ok(FixedPointBackend {
            cfg,
            batch,
            seq,
            xdim,
            udim,
            packed,
            head: qhead,
            pipeline,
            step_cycles: report.cycles,
            step_interval: report.interval,
            counters: Arc::new(CycleCounters::default()),
        })
    }

    /// The quantization configuration this backend serves with.
    pub fn config(&self) -> FixedPointConfig {
        self.cfg
    }

    /// Per-window cycle/interval report plus cumulative served-traffic
    /// counters (shared across clones).
    pub fn cycle_report(&self) -> FixedCycleReport {
        let seq = self.seq as u64;
        let window = self.pipeline.analyze(seq);
        let sequential = self.pipeline.analyze_sequential(seq);
        FixedCycleReport {
            step_cycles: self.step_cycles,
            step_interval: self.step_interval,
            window_cycles: window.total_cycles,
            window_cycles_sequential: sequential.total_cycles,
            windows_served: self.counters.windows.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            modeled_cycles: self.counters.cycles.load(Ordering::Relaxed),
        }
    }
}

impl InferenceBackend for FixedPointBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn theta_len(&self) -> usize {
        self.head.output
    }

    fn window_y_len(&self) -> usize {
        self.seq * self.xdim
    }

    fn window_u_len(&self) -> usize {
        self.seq * self.udim
    }

    fn forward_batch(&self, y: &[f32], u: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch;
        if y.len() != b * self.window_y_len() {
            return Err(Error::Shape {
                expected: format!("{} y values", b * self.window_y_len()),
                got: format!("{}", y.len()),
            });
        }
        if u.len() != b * self.window_u_len() {
            return Err(Error::Shape {
                expected: format!("{} u values", b * self.window_u_len()),
                got: format!("{}", u.len()),
            });
        }
        // Interleave to batch-major (B, K, XDIM+UDIM) and quantize the
        // stream to the activation format (the DMA word width).
        let i_sz = self.xdim + self.udim;
        let mut yu = vec![0.0f32; b * self.seq * i_sz];
        for w in 0..b {
            for t in 0..self.seq {
                let dst = (w * self.seq + t) * i_sz;
                let sy = (w * self.seq + t) * self.xdim;
                let su = (w * self.seq + t) * self.udim;
                yu[dst..dst + self.xdim].copy_from_slice(&y[sy..sy + self.xdim]);
                yu[dst + self.xdim..dst + i_sz].copy_from_slice(&u[su..su + self.udim]);
            }
        }
        self.cfg.act_fmt.quantize_slice(&mut yu);
        let fmts = self.cfg.datapath();
        let h = gru_forward_batch_fixed(&self.packed, &yu, self.seq, b, fmts);
        let theta = dense_head_batch_fixed(&self.head, &h, b, fmts);

        self.counters.batches.fetch_add(1, Ordering::Relaxed);
        self.counters.windows.fetch_add(b as u64, Ordering::Relaxed);
        let streamed = self.pipeline.analyze((b * self.seq) as u64).total_cycles;
        self.counters.cycles.fetch_add(streamed, Ordering::Relaxed);
        Ok(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn q8_8_tracks_native_backend() {
        let native = NativeBackend::new(3, 42);
        let fixed = FixedPointBackend::from_native(&native, FixedPointConfig::q8_8()).unwrap();
        let mut rng = Prng::new(7);
        let y = rng.normal_vec_f32(3 * 64 * 3, 0.5);
        let u = rng.normal_vec_f32(3 * 64, 0.5);
        let want = native.forward_batch(&y, &u).unwrap();
        let got = fixed.forward_batch(&y, &u).unwrap();
        assert_eq!(got.len(), want.len());
        let worst = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst < 0.05, "Q8.8 drift vs native: {worst}");
    }

    #[test]
    fn clones_share_cycle_counters() {
        let be = FixedPointBackend::new(2, 1, FixedPointConfig::q8_8());
        let clone = be.clone();
        let y = vec![0.1f32; 2 * clone.window_y_len()];
        let u = vec![0.0f32; 2 * clone.window_u_len()];
        clone.forward_batch(&y, &u).unwrap();
        let rep = be.cycle_report();
        assert_eq!(rep.batches, 1);
        assert_eq!(rep.windows_served, 2);
        assert!(rep.modeled_cycles > 0);
    }

    #[test]
    fn cycle_report_dataflow_beats_sequential() {
        let be = FixedPointBackend::new(2, 3, FixedPointConfig::q8_8());
        let rep = be.cycle_report();
        assert!(rep.window_cycles < rep.window_cycles_sequential);
        assert!(rep.dataflow_speedup() > 1.0);
        assert!(rep.step_interval > 0 && rep.step_cycles >= rep.step_interval);
    }

    #[test]
    fn from_parts_rejects_mismatched_dims() {
        let mut rng = Prng::new(2);
        let gru = GruParams::random(4, 8, &mut rng, 0.3);
        let head = DenseHead::random(9, 4, 6, &mut rng); // wrong input
        assert!(
            FixedPointBackend::from_parts(gru, head, FixedPointConfig::q8_8(), 2, 16, 3, 1)
                .is_err()
        );
    }

    #[test]
    fn format_names_parse() {
        assert!(FixedPointConfig::from_name("q8.8").is_ok());
        assert!(FixedPointConfig::from_name("q4_8").is_ok());
        assert!(FixedPointConfig::from_name("8bit").is_ok());
        assert!(FixedPointConfig::from_name("fp32").is_err());
    }

    #[test]
    fn shape_validation() {
        let be = FixedPointBackend::new(2, 1, FixedPointConfig::q8_8());
        assert!(be.forward_batch(&[0.0; 3], &[0.0; 128]).is_err());
        assert_eq!(be.theta_len(), 45);
        assert_eq!(be.window_y_len(), 192);
        assert_eq!(be.window_u_len(), 64);
    }
}

//! Open-loop production traffic tier.
//!
//! Closed-loop drivers (`merinda soak` without `--open-loop`) only offer
//! the next window once the previous one completes, so the fleet never
//! sees more load than it can absorb. Real serving is open-loop: arrivals
//! fire on a clock regardless of completion rate, and the serving stack
//! has to shed, reject, and re-tune to survive. This module provides that
//! tier:
//!
//! - [`ArrivalSpec`] / [`ArrivalPlan`]: a deterministic arrival-process
//!   generator — seeded Poisson arrivals per logical tick with diurnal
//!   and burst modulation profiles, multiplexed over synthetic tenants.
//!   Like [`super::faults::FaultPlan`], a plan is a pure function of its
//!   spec string and seed: same spec ⇒ bit-identical schedule, so every
//!   soak run is replayable.
//! - [`QosClass`]: per-tenant SLO tiers (`realtime` / `standard` /
//!   `batch`) that drive shed ordering (batch sheds before standard
//!   before realtime), placement priority, and admission.
//! - [`AdmissionController`]: rejects new work with a typed
//!   [`Error::Admission`] once the projected p99 for a tier would breach
//!   its SLO — policy-level backpressure in front of the queues.
//! - [`DriftDetector`] + online retuning: when the observed traffic mix
//!   drifts past a threshold, [`run_open_loop`] invokes a retune
//!   callback that may re-derive the placement cost models (re-running
//!   the `fpga::tuner`) mid-stream instead of only at startup.
//!
//! Determinism contract: the arrival *plan* is bit-identical for a given
//! spec. Admission and shed decisions additionally depend on runtime
//! backlog (thread timing), but per-tier accounting always closes:
//! `offered == admitted + rejected` and
//! `admitted == completed + shed + failed`.

use std::collections::VecDeque;
use std::sync::Arc;

use super::metrics::Metrics;
use super::placement::InstanceModel;
use super::stream::StreamCoordinator;
use crate::util::error::{Error, Result};
use crate::util::prng::Prng;

/// Per-tenant QoS tier. Lower [`QosClass::index`] = higher priority.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum QosClass {
    /// Time-critical physical-system tenants: placed first, shed last,
    /// admission-protected by the tightest SLO.
    Realtime,
    /// The default tier (all closed-loop tenants land here).
    #[default]
    Standard,
    /// Best-effort backfill: shed first, never admission-rejected (its
    /// SLO is unbounded — it absorbs overload via shedding instead).
    Batch,
}

/// All tiers in priority order (highest first).
pub const QOS_CLASSES: [QosClass; 3] = [QosClass::Realtime, QosClass::Standard, QosClass::Batch];

impl QosClass {
    /// Priority index: 0 = realtime, 1 = standard, 2 = batch.
    pub fn index(self) -> usize {
        match self {
            QosClass::Realtime => 0,
            QosClass::Standard => 1,
            QosClass::Batch => 2,
        }
    }

    /// Canonical long name (used in metrics sections and errors).
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Realtime => "realtime",
            QosClass::Standard => "standard",
            QosClass::Batch => "batch",
        }
    }

    /// Short name used in arrival-spec grammar (`@rt`, `@std`, `@batch`).
    pub fn short(self) -> &'static str {
        match self {
            QosClass::Realtime => "rt",
            QosClass::Standard => "std",
            QosClass::Batch => "batch",
        }
    }

    /// Parse either the long or the short tier name.
    pub fn from_name(s: &str) -> Result<QosClass> {
        match s {
            "rt" | "realtime" => Ok(QosClass::Realtime),
            "std" | "standard" => Ok(QosClass::Standard),
            "batch" => Ok(QosClass::Batch),
            other => Err(Error::config(format!(
                "unknown QoS tier {other:?} (want rt|std|batch)"
            ))),
        }
    }
}

/// Rate-modulation profile applied on top of the base Poisson rate.
#[derive(Clone, Copy, Debug, PartialEq)]
enum ModKind {
    /// Sinusoidal day/night swing: rate × `(1 + amp·sin(2π·tick/period))`.
    Diurnal { period: u64, amp: f64 },
    /// Flash crowd: rate × `factor` while `at <= tick < at + len`.
    Burst { at: u64, len: u64, factor: f64 },
}

/// One modulation profile, optionally scoped to a single tier (that is
/// how drifting mixes are constructed: burst only the realtime tier and
/// the observed shares move away from the spec's base mix).
#[derive(Clone, Copy, Debug, PartialEq)]
struct Modulation {
    kind: ModKind,
    tier: Option<QosClass>,
}

impl Modulation {
    fn factor_at(&self, tick: u64, tier: QosClass) -> f64 {
        if self.tier.is_some() && self.tier != Some(tier) {
            return 1.0;
        }
        match self.kind {
            ModKind::Diurnal { period, amp } => {
                let phase = 2.0 * std::f64::consts::PI * (tick % period) as f64 / period as f64;
                (1.0 + amp * phase.sin()).max(0.0)
            }
            ModKind::Burst { at, len, factor } => {
                if tick >= at && tick < at + len {
                    factor
                } else {
                    1.0
                }
            }
        }
    }

    fn spec(&self) -> String {
        let tier = match self.tier {
            Some(t) => format!("@{}", t.short()),
            None => String::new(),
        };
        match self.kind {
            ModKind::Diurnal { period, amp } => format!("diurnal:{period}*{amp}{tier}"),
            ModKind::Burst { at, len, factor } => format!("burst:{at}+{len}*{factor}{tier}"),
        }
    }
}

/// A deterministic open-loop arrival process over logical ticks.
///
/// Grammar (comma-separated `key:value` components, mirroring
/// [`super::faults::FaultPlan::parse`]):
///
/// | component | meaning |
/// |---|---|
/// | `poisson:R` | mean window arrivals per tick across all tiers (required) |
/// | `tenants:N` | synthetic tenant count (default 6) |
/// | `mix:A/B/C` | integer tier weights realtime/standard/batch (default 1/4/1) |
/// | `ticks:T` | logical-clock horizon (default 256) |
/// | `seed:S` | PRNG seed for the Poisson draws (default 1) |
/// | `diurnal:P*A[@tier]` | sinusoidal swing, period `P` ticks, amplitude `A` |
/// | `burst:T0+L*F[@tier]` | rate ×`F` during `[T0, T0+L)` |
///
/// `@tier` is `rt`, `std`, or `batch`; omitted means the profile applies
/// to every tier. Multiple `diurnal`/`burst` components compose
/// multiplicatively.
///
/// ```
/// use merinda::coordinator::traffic::{ArrivalSpec, QosClass};
/// let spec = ArrivalSpec::parse("poisson:2.5,tenants:12,mix:1/2/1,ticks:64,seed:9,burst:20+10*4@rt")?;
/// let plan = spec.plan();
/// // Pure function of the spec: replaying is bit-identical.
/// assert_eq!(plan, ArrivalSpec::parse(&spec.spec())?.plan());
/// // Tenants cycle the mix pattern: tenant 0 is realtime under 1/2/1.
/// assert_eq!(spec.tier_of(0), QosClass::Realtime);
/// # Ok::<(), merinda::util::error::Error>(())
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalSpec {
    /// Mean arrivals per tick summed over all tiers.
    pub rate: f64,
    /// Number of synthetic tenants multiplexed over the case-study
    /// systems (tenant `i` streams scenario `i mod 6`).
    pub tenants: usize,
    /// Tier weights `[realtime, standard, batch]`.
    pub mix: [u32; 3],
    /// Logical-clock horizon.
    pub ticks: u64,
    /// Seed for the Poisson and tenant-assignment draws.
    pub seed: u64,
    mods: Vec<Modulation>,
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        ArrivalSpec {
            rate: 1.0,
            tenants: 6,
            mix: [1, 4, 1],
            ticks: 256,
            seed: 1,
            mods: Vec::new(),
        }
    }
}

impl ArrivalSpec {
    /// Parse a spec string (see the type-level grammar table).
    pub fn parse(spec: &str) -> Result<ArrivalSpec> {
        let mut out = ArrivalSpec {
            mods: Vec::new(),
            ..ArrivalSpec::default()
        };
        let mut saw_rate = false;
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (key, val) = tok
                .split_once(':')
                .ok_or_else(|| Error::config(format!("arrival component {tok:?}: want key:value")))?;
            match key {
                "poisson" => {
                    out.rate = val
                        .parse::<f64>()
                        .ok()
                        .filter(|r| *r > 0.0 && r.is_finite())
                        .ok_or_else(|| {
                            Error::config(format!("poisson rate {val:?}: want a positive number"))
                        })?;
                    saw_rate = true;
                }
                "tenants" => {
                    out.tenants = val
                        .parse::<usize>()
                        .ok()
                        .filter(|n| *n >= 1)
                        .ok_or_else(|| Error::config(format!("tenants {val:?}: want >= 1")))?;
                }
                "ticks" => {
                    out.ticks = val
                        .parse::<u64>()
                        .ok()
                        .filter(|n| *n >= 1)
                        .ok_or_else(|| Error::config(format!("ticks {val:?}: want >= 1")))?;
                }
                "seed" => {
                    out.seed = val
                        .parse::<u64>()
                        .map_err(|_| Error::config(format!("seed {val:?}: want u64")))?;
                }
                "mix" => {
                    let parts: Vec<&str> = val.split('/').collect();
                    if parts.len() != 3 {
                        return Err(Error::config(format!("mix {val:?}: want A/B/C")));
                    }
                    let mut mix = [0u32; 3];
                    for (slot, p) in mix.iter_mut().zip(&parts) {
                        *slot = p
                            .parse::<u32>()
                            .map_err(|_| Error::config(format!("mix weight {p:?}: want u32")))?;
                    }
                    if mix.iter().sum::<u32>() == 0 {
                        return Err(Error::config("mix 0/0/0: at least one weight must be > 0"));
                    }
                    out.mix = mix;
                }
                "diurnal" => {
                    let (body, tier) = split_tier(val)?;
                    let (p, a) = body.split_once('*').ok_or_else(|| {
                        Error::config(format!("diurnal {val:?}: want P*A[@tier]"))
                    })?;
                    let period = p
                        .parse::<u64>()
                        .ok()
                        .filter(|p| *p >= 2)
                        .ok_or_else(|| Error::config(format!("diurnal period {p:?}: want >= 2")))?;
                    let amp = a
                        .parse::<f64>()
                        .ok()
                        .filter(|a| *a >= 0.0 && a.is_finite())
                        .ok_or_else(|| Error::config(format!("diurnal amp {a:?}: want >= 0")))?;
                    out.mods.push(Modulation {
                        kind: ModKind::Diurnal { period, amp },
                        tier,
                    });
                }
                "burst" => {
                    let (body, tier) = split_tier(val)?;
                    let parsed = body.split_once('+').and_then(|(t0, rest)| {
                        let (l, f) = rest.split_once('*')?;
                        Some((t0.parse::<u64>().ok()?, l.parse::<u64>().ok()?, f.parse::<f64>().ok()?))
                    });
                    let (at, len, factor) = parsed.ok_or_else(|| {
                        Error::config(format!("burst {val:?}: want T0+L*F[@tier]"))
                    })?;
                    if len == 0 || factor < 0.0 || !factor.is_finite() {
                        return Err(Error::config(format!(
                            "burst {val:?}: want L >= 1 and F >= 0"
                        )));
                    }
                    out.mods.push(Modulation {
                        kind: ModKind::Burst { at, len, factor },
                        tier,
                    });
                }
                other => {
                    return Err(Error::config(format!(
                        "unknown arrival component {other:?} \
                         (want poisson|tenants|mix|ticks|seed|diurnal|burst)"
                    )));
                }
            }
        }
        if !saw_rate {
            return Err(Error::config("arrival spec needs a poisson:R component"));
        }
        Ok(out)
    }

    /// Canonical spec string; `parse(spec()).plan() == plan()` round-trips.
    pub fn spec(&self) -> String {
        let mut s = format!(
            "poisson:{},tenants:{},mix:{}/{}/{},ticks:{},seed:{}",
            self.rate, self.tenants, self.mix[0], self.mix[1], self.mix[2], self.ticks, self.seed
        );
        for m in &self.mods {
            s.push(',');
            s.push_str(&m.spec());
        }
        s
    }

    /// Draw a random-but-replayable spec (soak fuzzing): every field is a
    /// pure function of `seed`, and every drawn value survives the
    /// `spec()`/`parse()` round trip exactly (rates and amplitudes are
    /// quarter steps, which print and re-parse losslessly).
    pub fn seeded(seed: u64) -> ArrivalSpec {
        let mut rng = Prng::new(seed ^ 0x5eed_0a11_4117_0015);
        let mut spec = ArrivalSpec {
            rate: (2 + rng.below(9)) as f64 * 0.5,
            tenants: 4 + rng.below(12),
            mix: [
                1 + rng.below(3) as u32,
                1 + rng.below(4) as u32,
                1 + rng.below(3) as u32,
            ],
            ticks: 64 + 32 * rng.below(6) as u64,
            seed,
            mods: Vec::new(),
        };
        for _ in 0..rng.below(3) {
            let tier = match rng.below(4) {
                0 => Some(QosClass::Realtime),
                1 => Some(QosClass::Standard),
                2 => Some(QosClass::Batch),
                _ => None,
            };
            let kind = if rng.bernoulli(0.5) {
                ModKind::Diurnal {
                    period: 32 + 16 * rng.below(6) as u64,
                    amp: 0.25 * (1 + rng.below(3)) as f64,
                }
            } else {
                ModKind::Burst {
                    at: rng.below((spec.ticks / 2) as usize) as u64,
                    len: 8 + 8 * rng.below(5) as u64,
                    factor: (2 + rng.below(4)) as f64,
                }
            };
            spec.mods.push(Modulation { kind, tier });
        }
        spec
    }

    /// Tier of tenant `i`: the `mix` weights expand into a repeating
    /// pattern (`1/4/1` ⇒ rt, std, std, std, std, batch, rt, …).
    pub fn tier_of(&self, tenant: usize) -> QosClass {
        let wsum: u32 = self.mix.iter().sum();
        let pos = (tenant as u64 % wsum as u64) as u32;
        if pos < self.mix[0] {
            QosClass::Realtime
        } else if pos < self.mix[0] + self.mix[1] {
            QosClass::Standard
        } else {
            QosClass::Batch
        }
    }

    /// Base (unmodulated) share of the total rate each tier receives.
    pub fn base_shares(&self) -> [f64; 3] {
        let wsum: u32 = self.mix.iter().sum();
        let mut shares = [0.0; 3];
        for (s, w) in shares.iter_mut().zip(self.mix) {
            *s = w as f64 / wsum as f64;
        }
        shares
    }

    /// Mean arrivals per tick for `tier` at logical time `tick`.
    pub fn rate_at(&self, tick: u64, tier: QosClass) -> f64 {
        let base = self.rate * self.base_shares()[tier.index()];
        self.mods
            .iter()
            .fold(base, |r, m| r * m.factor_at(tick, tier))
    }

    /// Materialize the deterministic schedule. Pure function of the spec:
    /// no wall clock, no shared state, no hash-order dependence.
    pub fn plan(&self) -> ArrivalPlan {
        let tenant_tiers: Vec<QosClass> = (0..self.tenants).map(|i| self.tier_of(i)).collect();
        let mut members: [Vec<u32>; 3] = Default::default();
        for (i, t) in tenant_tiers.iter().enumerate() {
            members[t.index()].push(i as u32);
        }
        let mut rng = Prng::new(self.seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0x0a11_4117);
        let mut arrivals = Vec::new();
        let mut offered_per_tier = [0u64; 3];
        for tick in 0..self.ticks {
            for tier in QOS_CLASSES {
                let pool = &members[tier.index()];
                if pool.is_empty() {
                    continue;
                }
                // Cap λ so a pathological spec cannot hang the draw loop.
                let lam = self.rate_at(tick, tier).min(64.0);
                if lam <= 0.0 {
                    continue;
                }
                for _ in 0..poisson(&mut rng, lam) {
                    let tenant = pool[rng.below(pool.len())];
                    arrivals.push(Arrival { tick, tenant });
                    offered_per_tier[tier.index()] += 1;
                }
            }
        }
        ArrivalPlan {
            ticks: self.ticks,
            arrivals,
            tenant_tiers,
            offered_per_tier,
            base_shares: self.base_shares(),
        }
    }
}

/// Strip an optional `@tier` suffix off a modulation body.
fn split_tier(val: &str) -> Result<(&str, Option<QosClass>)> {
    match val.split_once('@') {
        Some((body, tier)) => Ok((body, Some(QosClass::from_name(tier)?))),
        None => Ok((val, None)),
    }
}

/// Knuth's Poisson sampler: multiply uniforms until the product drops
/// below `e^{-λ}`. Fine for the modest per-tick rates the soak uses.
fn poisson(rng: &mut Prng, lambda: f64) -> u64 {
    let limit = (-lambda).exp();
    let mut k = 0u64;
    let mut p = 1.0;
    loop {
        p *= rng.uniform();
        if p <= limit {
            return k;
        }
        k += 1;
    }
}

/// One scheduled window arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Arrival {
    /// Logical tick the arrival fires on.
    pub tick: u64,
    /// Target tenant (its tier is `tenant_tiers[tenant]`).
    pub tenant: u32,
}

/// A fully materialized arrival schedule (bit-identical per spec).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalPlan {
    /// Logical-clock horizon copied from the spec.
    pub ticks: u64,
    /// Arrivals in firing order (non-decreasing `tick`).
    pub arrivals: Vec<Arrival>,
    /// Tier assignment per tenant id.
    pub tenant_tiers: Vec<QosClass>,
    /// Total offered load per tier over the horizon.
    pub offered_per_tier: [u64; 3],
    /// The spec's unmodulated tier shares (drift-detector reference).
    pub base_shares: [f64; 3],
}

impl ArrivalPlan {
    /// Per-tick offered counts per tier (what the drift detector sees).
    pub fn tier_counts_by_tick(&self) -> Vec<[u64; 3]> {
        let mut counts = vec![[0u64; 3]; self.ticks as usize];
        for a in &self.arrivals {
            let tier = self.tenant_tiers[a.tenant as usize];
            counts[a.tick as usize][tier.index()] += 1;
        }
        counts
    }
}

/// Drift-detector knobs.
#[derive(Clone, Copy, Debug)]
pub struct DriftConfig {
    /// Sliding window of ticks the observed mix is estimated over.
    pub window: usize,
    /// L1-share distance (halved) above which a drift episode begins.
    pub threshold: f64,
    /// Hysteresis: the episode ends (re-arming the trigger) only once
    /// drift falls below `threshold * exit_frac`.
    pub exit_frac: f64,
    /// Minimum arrivals in the window before shares are trusted.
    pub min_arrivals: u64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            window: 32,
            threshold: 0.2,
            exit_frac: 0.5,
            min_arrivals: 24,
        }
    }
}

/// Fired by [`DriftDetector::observe`] on the rising edge of a drift
/// episode.
#[derive(Clone, Copy, Debug)]
pub struct DriftTrigger {
    /// Drift magnitude at the trigger: `0.5 · Σ|observed − reference|`.
    pub drift: f64,
    /// Observed per-tier shares over the sliding window.
    pub observed: [f64; 3],
}

/// Latched traffic-mix drift detector.
///
/// The reference mix is *fixed* at the spec's base shares, so a burst
/// that shifts the mix fires exactly once (latched) and the trigger
/// re-arms only after the observed mix returns near the reference —
/// one drift episode, one retune.
#[derive(Clone, Debug)]
pub struct DriftDetector {
    cfg: DriftConfig,
    reference: [f64; 3],
    history: VecDeque<[u64; 3]>,
    in_drift: bool,
    last_drift: f64,
    fires: u64,
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig, reference: [f64; 3]) -> DriftDetector {
        DriftDetector {
            cfg,
            reference,
            history: VecDeque::new(),
            in_drift: false,
            last_drift: 0.0,
            fires: 0,
        }
    }

    /// Feed one tick's per-tier arrival counts; `Some` on the rising
    /// edge of a new drift episode.
    pub fn observe(&mut self, counts: [u64; 3]) -> Option<DriftTrigger> {
        self.history.push_back(counts);
        while self.history.len() > self.cfg.window {
            self.history.pop_front();
        }
        let mut sums = [0u64; 3];
        for c in &self.history {
            for (s, v) in sums.iter_mut().zip(c) {
                *s += v;
            }
        }
        let total: u64 = sums.iter().sum();
        if total < self.cfg.min_arrivals {
            return None;
        }
        let mut drift = 0.0;
        let mut observed = [0.0; 3];
        for i in 0..3 {
            observed[i] = sums[i] as f64 / total as f64;
            drift += (observed[i] - self.reference[i]).abs();
        }
        drift *= 0.5;
        self.last_drift = drift;
        if !self.in_drift && drift > self.cfg.threshold {
            self.in_drift = true;
            self.fires += 1;
            return Some(DriftTrigger { drift, observed });
        }
        if self.in_drift && drift < self.cfg.threshold * self.cfg.exit_frac {
            self.in_drift = false;
        }
        None
    }

    /// Drift magnitude at the most recent trusted observation.
    pub fn last_drift(&self) -> f64 {
        self.last_drift
    }

    /// Whether a drift episode is currently latched.
    pub fn in_drift(&self) -> bool {
        self.in_drift
    }

    /// Rising edges seen so far (== retunes requested).
    pub fn fires(&self) -> u64 {
        self.fires
    }
}

/// Per-tier p99 SLO targets in milliseconds (`None` = unbounded).
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Indexed by [`QosClass::index`].
    pub p99_ms: [Option<f64>; 3],
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            p99_ms: [Some(500.0), Some(2000.0), None],
        }
    }
}

impl SloPolicy {
    pub fn slo_ms(&self, tier: QosClass) -> Option<f64> {
        self.p99_ms[tier.index()]
    }
}

/// SLO-protecting admission controller.
///
/// Projected p99 for an arriving window is a queueing estimate: the
/// windows already queued at the same or higher priority plus the
/// in-flight set all drain ahead of it through `slots` placement slots,
/// each taking the observed mean service latency, so
/// `projected = (ahead / slots + 1) · svc_ms`. If that breaches the
/// tier's SLO the window is rejected with [`Error::Admission`] before it
/// enters any queue. Batch has no SLO and is never rejected (it absorbs
/// overload through shed ordering instead).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionController {
    pub slo: SloPolicy,
}

impl AdmissionController {
    /// Check one arrival; `Ok(projected_ms)` admits it.
    pub fn check(&self, tier: QosClass, ahead: usize, slots: usize, svc_ms: f64) -> Result<f64> {
        let projected = (ahead as f64 / slots.max(1) as f64 + 1.0) * svc_ms;
        match self.slo.slo_ms(tier) {
            Some(slo) if projected > slo => Err(Error::admission(tier.name(), projected, slo)),
            _ => Ok(projected),
        }
    }
}

/// The window payload ring for one tenant: pre-sliced `(start, Y, U)`
/// windows cycled as arrivals fire (open-loop load is unbounded; the
/// underlying sample stream is not).
pub struct TenantTraffic {
    /// `(window start sample, Y slice, U slice)` in plan order.
    pub windows: Vec<(usize, Vec<f32>, Vec<f32>)>,
}

/// Knobs for [`run_open_loop`].
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopConfig {
    /// Global queued-window budget enforced after every tick via
    /// [`StreamCoordinator::shed_to_budget`] (batch sheds first).
    pub backlog_budget: usize,
    /// Per-tier SLO targets driving admission.
    pub slo: SloPolicy,
    /// Drift-detector knobs for online retuning.
    pub drift: DriftConfig,
    /// Service-latency estimate (ms) used by admission before any
    /// completion has been observed.
    pub svc_ms_hint: f64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            backlog_budget: 512,
            slo: SloPolicy::default(),
            drift: DriftConfig::default(),
            svc_ms_hint: 5.0,
        }
    }
}

/// One online-retune event (drift episode rising edge).
#[derive(Clone, Copy, Debug)]
pub struct RetuneEvent {
    /// Logical tick the drift episode was detected on.
    pub tick: u64,
    /// Drift magnitude at the trigger.
    pub drift: f64,
    /// Observed per-tier shares at the trigger.
    pub observed: [f64; 3],
    /// Whether the retune callback installed a fresh model set.
    pub models_refreshed: bool,
}

/// Per-tier traffic counters accumulated by the driver.
#[derive(Clone, Copy, Debug, Default)]
pub struct TierTraffic {
    /// Arrivals the plan fired for this tier.
    pub offered: u64,
    /// Arrivals the admission controller let through.
    pub admitted: u64,
    /// Arrivals rejected with [`Error::Admission`].
    pub rejected: u64,
    /// Windows shed by the backlog-budget sweep (a subset of the
    /// coordinator's total shed count for the tier).
    pub shed_budget: u64,
}

/// What [`run_open_loop`] hands back.
#[derive(Clone, Debug, Default)]
pub struct TrafficReport {
    /// Ticks driven.
    pub ticks: u64,
    /// Indexed by [`QosClass::index`].
    pub per_tier: [TierTraffic; 3],
    /// Online-retune events in firing order.
    pub retunes: Vec<RetuneEvent>,
    /// Largest drift magnitude observed over the run.
    pub max_drift: f64,
}

impl TrafficReport {
    /// `offered == admitted + rejected` for every tier.
    pub fn admission_closes(&self) -> bool {
        self.per_tier
            .iter()
            .all(|t| t.offered == t.admitted + t.rejected)
    }
}

/// Drive a [`StreamCoordinator`] open-loop through an [`ArrivalPlan`].
///
/// Each logical tick: fire the tick's arrivals (admission-checked, then
/// offered to the coordinator regardless of completion rate), pump and
/// poll the fleet, shed the global backlog down to budget (batch before
/// standard before realtime), and feed the drift detector. On a drift
/// episode's rising edge `retune` is invoked; if it returns a fresh
/// model set the coordinator's placement cost models are swapped
/// mid-stream. Finishes with a full drain, so every admitted window is
/// completed, shed, or failed when this returns.
pub fn run_open_loop<F>(
    coord: &mut StreamCoordinator,
    plan: &ArrivalPlan,
    traffic: &[TenantTraffic],
    cfg: &OpenLoopConfig,
    mut retune: F,
) -> Result<TrafficReport>
where
    F: FnMut(&RetuneEvent) -> Option<Vec<InstanceModel>>,
{
    if traffic.len() != plan.tenant_tiers.len() {
        return Err(Error::config(format!(
            "traffic rings for {} tenants but plan has {}",
            traffic.len(),
            plan.tenant_tiers.len()
        )));
    }
    for (t, ring) in traffic.iter().enumerate() {
        if ring.windows.is_empty() {
            return Err(Error::config(format!("tenant {t} has an empty window ring")));
        }
        coord.set_qos(t as u32, plan.tenant_tiers[t]);
    }
    let metrics: Arc<Metrics> = coord.metrics();
    let admission = AdmissionController { slo: cfg.slo };
    let mut detector = DriftDetector::new(cfg.drift, plan.base_shares);
    let mut next_ring = vec![0usize; traffic.len()];
    let mut report = TrafficReport {
        ticks: plan.ticks,
        ..TrafficReport::default()
    };
    let mut arr_idx = 0usize;
    for tick in 0..plan.ticks {
        // One latency estimate per tick, shared by every admission check
        // in it (snapshotting per arrival would be quadratic in load).
        let snap = metrics.snapshot();
        let svc_ms = if snap.latency.count > 0 {
            snap.latency.mean_ms
        } else {
            cfg.svc_ms_hint
        };
        let slots = coord.placement_slots();
        let mut tick_counts = [0u64; 3];
        while arr_idx < plan.arrivals.len() && plan.arrivals[arr_idx].tick == tick {
            let a = plan.arrivals[arr_idx];
            arr_idx += 1;
            let tier = plan.tenant_tiers[a.tenant as usize];
            let ti = tier.index();
            tick_counts[ti] += 1;
            report.per_tier[ti].offered += 1;
            metrics.on_tier_offered(tier);
            let ahead = coord.queued_at_or_above(tier) + coord.in_flight();
            match admission.check(tier, ahead, slots, svc_ms) {
                Ok(_) => {
                    report.per_tier[ti].admitted += 1;
                    metrics.on_tier_admitted(tier);
                    let ring = &traffic[a.tenant as usize].windows;
                    let (start, y, u) = &ring[next_ring[a.tenant as usize] % ring.len()];
                    next_ring[a.tenant as usize] += 1;
                    coord.offer_window(a.tenant, *start, y.clone(), u.clone())?;
                }
                Err(e) if e.is_admission() => {
                    report.per_tier[ti].rejected += 1;
                    metrics.on_tier_rejected(tier);
                }
                Err(e) => return Err(e),
            }
        }
        coord.pump();
        coord.poll();
        let shed = coord.shed_to_budget(cfg.backlog_budget);
        for (acc, s) in report.per_tier.iter_mut().zip(shed) {
            acc.shed_budget += s;
        }
        if let Some(trigger) = detector.observe(tick_counts) {
            let mut ev = RetuneEvent {
                tick,
                drift: trigger.drift,
                observed: trigger.observed,
                models_refreshed: false,
            };
            if let Some(models) = retune(&ev) {
                coord.retarget_models(models)?;
                ev.models_refreshed = true;
            }
            report.retunes.push(ev);
        }
        report.max_drift = report.max_drift.max(detector.last_drift());
    }
    coord.drain();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_through_spec() {
        let s = "poisson:2.5,tenants:12,mix:1/2/1,ticks:64,seed:9,\
                 diurnal:32*0.5,burst:20+10*4@rt";
        let spec = ArrivalSpec::parse(s).unwrap();
        assert_eq!(spec.rate, 2.5);
        assert_eq!(spec.tenants, 12);
        assert_eq!(spec.mix, [1, 2, 1]);
        let again = ArrivalSpec::parse(&spec.spec()).unwrap();
        assert_eq!(spec, again, "spec() must re-parse to the same spec");
        assert_eq!(spec.plan(), again.plan());
    }

    #[test]
    fn parse_rejects_malformed_components() {
        for bad in [
            "tenants:4",              // missing required poisson rate
            "poisson:0",              // rate must be positive
            "poisson:2,mix:0/0/0",    // all-zero mix
            "poisson:2,mix:1/2",      // mix needs 3 weights
            "poisson:2,burst:5*3",    // burst grammar is T0+L*F
            "poisson:2,burst:5+0*3",  // zero-length burst
            "poisson:2,diurnal:1*.5", // period >= 2
            "poisson:2,burst:5+4*3@gold", // unknown tier
            "poisson:2,warp:9",       // unknown component
        ] {
            assert!(ArrivalSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn tier_assignment_cycles_the_mix() {
        let spec = ArrivalSpec::parse("poisson:1,tenants:8,mix:1/2/1").unwrap();
        let tiers: Vec<QosClass> = (0..8).map(|i| spec.tier_of(i)).collect();
        assert_eq!(
            tiers,
            [
                QosClass::Realtime,
                QosClass::Standard,
                QosClass::Standard,
                QosClass::Batch,
                QosClass::Realtime,
                QosClass::Standard,
                QosClass::Standard,
                QosClass::Batch,
            ]
        );
    }

    #[test]
    fn burst_modulation_is_tier_scoped_and_windowed() {
        let spec = ArrivalSpec::parse("poisson:3,mix:1/1/1,burst:10+5*4@rt").unwrap();
        let base = 1.0; // 3 split evenly across three tiers
        assert!((spec.rate_at(9, QosClass::Realtime) - base).abs() < 1e-12);
        assert!((spec.rate_at(10, QosClass::Realtime) - 4.0 * base).abs() < 1e-12);
        assert!((spec.rate_at(14, QosClass::Realtime) - 4.0 * base).abs() < 1e-12);
        assert!((spec.rate_at(15, QosClass::Realtime) - base).abs() < 1e-12);
        assert!((spec.rate_at(12, QosClass::Batch) - base).abs() < 1e-12);
    }

    #[test]
    fn diurnal_modulation_never_goes_negative() {
        let spec = ArrivalSpec::parse("poisson:2,diurnal:24*1").unwrap();
        for tick in 0..96 {
            for tier in QOS_CLASSES {
                assert!(spec.rate_at(tick, tier) >= 0.0);
            }
        }
    }

    #[test]
    fn plan_is_pure_and_seed_sensitive() {
        let spec = ArrivalSpec::parse("poisson:2,tenants:6,ticks:64,seed:5").unwrap();
        assert_eq!(spec.plan(), spec.plan(), "same spec ⇒ bit-identical plan");
        let other = ArrivalSpec::parse("poisson:2,tenants:6,ticks:64,seed:6").unwrap();
        assert_ne!(spec.plan().arrivals, other.plan().arrivals);
    }

    #[test]
    fn plan_accounting_is_internally_consistent() {
        let spec = ArrivalSpec::seeded(42);
        let plan = spec.plan();
        assert_eq!(plan.tenant_tiers.len(), spec.tenants);
        let offered: u64 = plan.offered_per_tier.iter().sum();
        assert_eq!(offered, plan.arrivals.len() as u64);
        let by_tick: u64 = plan
            .tier_counts_by_tick()
            .iter()
            .map(|c| c.iter().sum::<u64>())
            .sum();
        assert_eq!(by_tick, offered);
        // Ticks are non-decreasing (the open-loop driver walks linearly).
        assert!(plan.arrivals.windows(2).all(|w| w[0].tick <= w[1].tick));
    }

    #[test]
    fn seeded_specs_round_trip_losslessly() {
        for seed in 0..64 {
            let spec = ArrivalSpec::seeded(seed);
            let again = ArrivalSpec::parse(&spec.spec()).unwrap();
            assert_eq!(spec, again, "seed {seed}: spec string must round-trip");
        }
    }

    #[test]
    fn drift_detector_latches_per_episode() {
        let cfg = DriftConfig {
            window: 8,
            threshold: 0.2,
            exit_frac: 0.5,
            min_arrivals: 8,
        };
        let mut det = DriftDetector::new(cfg, [0.25, 0.5, 0.25]);
        let balanced = [2u64, 4, 2];
        let skewed = [8u64, 1, 1];
        for _ in 0..8 {
            assert!(det.observe(balanced).is_none());
        }
        // Episode 1: skew fires exactly once even while skew persists.
        let mut fires = 0;
        for _ in 0..12 {
            if det.observe(skewed).is_some() {
                fires += 1;
            }
        }
        assert_eq!(fires, 1, "latched: one fire per episode");
        assert!(det.in_drift());
        // Recovery: balanced traffic re-arms the trigger...
        for _ in 0..16 {
            assert!(det.observe(balanced).is_none());
        }
        assert!(!det.in_drift());
        // ...and a second episode fires exactly once more.
        let mut fires2 = 0;
        for _ in 0..12 {
            if det.observe(skewed).is_some() {
                fires2 += 1;
            }
        }
        assert_eq!(fires2, 1);
        assert_eq!(det.fires(), 2);
    }

    #[test]
    fn drift_detector_ignores_sparse_windows() {
        let cfg = DriftConfig {
            window: 4,
            threshold: 0.1,
            exit_frac: 0.5,
            min_arrivals: 100,
        };
        let mut det = DriftDetector::new(cfg, [0.33, 0.34, 0.33]);
        // Wildly skewed but far below min_arrivals: never trusted.
        for _ in 0..32 {
            assert!(det.observe([3, 0, 0]).is_none());
        }
        assert_eq!(det.fires(), 0);
    }

    #[test]
    fn admission_rejects_only_past_slo() {
        let ctl = AdmissionController {
            slo: SloPolicy {
                p99_ms: [Some(100.0), Some(1000.0), None],
            },
        };
        // 10 ahead over 2 slots at 30ms each: projected (5+1)*30 = 180ms.
        let err = ctl.check(QosClass::Realtime, 10, 2, 30.0).unwrap_err();
        assert!(err.is_admission());
        assert!(err.to_string().contains("realtime"));
        // Same backlog is fine for the looser standard SLO.
        assert!(ctl.check(QosClass::Standard, 10, 2, 30.0).is_ok());
        // Batch has no SLO: admitted under arbitrary backlog.
        assert!(ctl.check(QosClass::Batch, 1_000_000, 1, 30.0).is_ok());
        // Zero slots must not divide by zero.
        assert!(ctl.check(QosClass::Realtime, 0, 0, 30.0).is_ok());
    }

    #[test]
    fn qos_names_round_trip() {
        for q in QOS_CLASSES {
            assert_eq!(QosClass::from_name(q.name()).unwrap(), q);
            assert_eq!(QosClass::from_name(q.short()).unwrap(), q);
        }
        assert!(QosClass::from_name("gold").is_err());
        assert_eq!(QosClass::default(), QosClass::Standard);
        assert!(QosClass::Realtime.index() < QosClass::Batch.index());
    }
}

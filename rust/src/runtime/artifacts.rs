//! Artifact manifest parsing.
//!
//! `artifacts/manifest.json` is written by `python/compile/aot.py` and
//! records, for every lowered entry point, the argument order, shapes and
//! output arity. The Rust side validates every call against this before
//! touching PJRT, so shape bugs surface as typed errors instead of XLA
//! aborts.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::{Error, Result};

/// Model dimensions baked into the artifacts (must match `model.py`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelDims {
    pub xdim: usize,
    pub udim: usize,
    pub plib: usize,
    pub hid: usize,
    pub dense: usize,
    pub batch: usize,
    pub seq: usize,
    pub ltc_unfold: usize,
}

/// One argument of an entry point.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub outputs: usize,
    pub args: Vec<ArgSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dims: ModelDims,
    pub entries: Vec<EntrySpec>,
    pub dir: PathBuf,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| Error::Artifact(format!("manifest missing numeric key {key:?}")))
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        Manifest::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(Error::Artifact)?;
        let d = j
            .get("dims")
            .ok_or_else(|| Error::Artifact("manifest missing dims".into()))?;
        let dims = ModelDims {
            xdim: req_usize(d, "xdim")?,
            udim: req_usize(d, "udim")?,
            plib: req_usize(d, "plib")?,
            hid: req_usize(d, "hid")?,
            dense: req_usize(d, "dense")?,
            batch: req_usize(d, "batch")?,
            seq: req_usize(d, "seq")?,
            ltc_unfold: req_usize(d, "ltc_unfold")?,
        };
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Artifact("manifest missing entries".into()))?
        {
            let name = e
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| Error::Artifact("entry missing name".into()))?
                .to_string();
            let file = e
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| Error::Artifact("entry missing file".into()))?;
            let outputs = req_usize(e, "outputs")?;
            let mut args = Vec::new();
            for a in e
                .get("args")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| Error::Artifact("entry missing args".into()))?
            {
                let aname = a
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("<anon>")
                    .to_string();
                let shape: Vec<usize> = a
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| Error::Artifact("arg missing shape".into()))?
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect();
                args.push(ArgSpec { name: aname, shape });
            }
            entries.push(EntrySpec {
                name,
                file: dir.join(file),
                outputs,
                args,
            });
        }
        Ok(Manifest { dims, entries, dir })
    }

    /// Find an entry by name.
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Artifact(format!("no artifact entry {name:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "dims": {"xdim":3,"udim":1,"plib":15,"hid":32,"dense":48,"batch":8,"seq":64,"ltc_unfold":6},
      "entries": [
        {"name":"gru_cell","file":"gru_cell.hlo.txt","outputs":1,
         "args":[{"name":"x","shape":[8,4],"dtype":"f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.dims.hid, 32);
        assert_eq!(m.entries.len(), 1);
        let e = m.entry("gru_cell").unwrap();
        assert_eq!(e.args[0].shape, vec![8, 4]);
        assert_eq!(e.args[0].elements(), 32);
        assert!(e.file.ends_with("gru_cell.hlo.txt"));
    }

    #[test]
    fn missing_entry_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn scalar_arg_has_one_element() {
        let a = ArgSpec {
            name: "dt".into(),
            shape: vec![],
        };
        assert_eq!(a.elements(), 1);
    }
}

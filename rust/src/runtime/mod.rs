//! PJRT runtime: load AOT artifacts and execute them from the Rust hot path.
//!
//! `make artifacts` (build time, Python) lowers every L2 entry point to
//! `artifacts/<name>.hlo.txt` + `artifacts/manifest.json`. At startup the
//! coordinator constructs one [`Runtime`], which compiles each module once
//! on the PJRT CPU client; per-request execution is then pure Rust + XLA —
//! Python is never on the request path.

mod artifacts;
mod client;

pub use artifacts::{ArgSpec, EntrySpec, Manifest, ModelDims};
pub use client::{Executable, Runtime};

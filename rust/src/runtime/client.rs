//! PJRT CPU client wrapper: compile-once, execute-many.
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Entry points are lowered with
//! `return_tuple=True`, so results are unpacked from a single tuple
//! literal.

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::{Error, Result};

use super::artifacts::{EntrySpec, Manifest};

/// A compiled entry point plus its manifest spec.
pub struct Executable {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 buffers in manifest argument order.
    ///
    /// Each `args[i]` must have exactly `spec.args[i].elements()` values;
    /// shapes are imposed via literal reshape. Returns the flattened f32
    /// contents of each tuple element.
    pub fn run_f32(&self, args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.spec.args.len() {
            return Err(Error::Shape {
                expected: format!("{} args", self.spec.args.len()),
                got: format!("{} args", args.len()),
            });
        }
        let mut literals = Vec::with_capacity(args.len());
        for (a, spec) in args.iter().zip(&self.spec.args) {
            if a.len() != spec.elements() {
                return Err(Error::Shape {
                    expected: format!("{} elems for {}", spec.elements(), spec.name),
                    got: format!("{} elems", a.len()),
                });
            }
            let lit = xla::Literal::vec1(a);
            let lit = if spec.shape.is_empty() {
                // Scalars: reshape to rank-0.
                lit.reshape(&[])?
            } else {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        if tuple.len() != self.spec.outputs {
            return Err(Error::Shape {
                expected: format!("{} outputs", self.spec.outputs),
                got: format!("{} outputs", tuple.len()),
            });
        }
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Compile-once registry of all artifact entry points.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: std::sync::Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest (no compilation yet;
    /// entries compile lazily on first use and are then cached).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// Platform string (always "cpu" in this environment).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling if needed) an executable by entry name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = spec.file.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let exec = Arc::new(Executable { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Eagerly compile a list of entries (startup warm-up).
    pub fn warm_up(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }
}

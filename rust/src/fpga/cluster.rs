//! Multi-FPGA "tower" scale-out model (the paper's §8 future work).
//!
//! The conclusion proposes scale-out on multi-FPGA clusters to assess
//! throughput and latency at larger problem sizes. This module models a
//! tower of `n` boards fed by one host NIC: requests are sharded
//! round-robin (data parallel) or the GRU hidden dimension is split
//! across boards (model parallel, all-gather each step). The interconnect
//! is a simple store-and-forward Ethernet/Aurora model.

use super::fixedpoint::FixedFormat;
use super::gru_accel::{AccelReport, GruAccel, GruAccelConfig};
use super::pipeline::PipelineTiming;
use super::resources::{Device, Resources};

/// How work is split across boards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sharding {
    /// Each board serves whole requests (round-robin).
    DataParallel,
    /// Hidden state split across boards; per-step all-gather.
    ModelParallel,
}

/// Interconnect between boards (and to the host).
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Sustained payload bandwidth, bytes per second.
    pub bandwidth_bps: f64,
    /// One-way latency, seconds.
    pub latency_s: f64,
}

impl Link {
    /// 10 GbE host link (PYNQ clusters typically aggregate through one).
    pub fn ten_gbe() -> Link {
        Link {
            bandwidth_bps: 10e9 / 8.0,
            latency_s: 8e-6,
        }
    }

    /// Board-to-board Aurora-style serial link.
    pub fn aurora() -> Link {
        Link {
            bandwidth_bps: 25e9 / 8.0,
            latency_s: 1e-6,
        }
    }

    /// Seconds to move `bytes` point-to-point.
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// This link under a degradation fault: bandwidth divided and
    /// latency multiplied by `factor` (≥ 1.0 — e.g. a flapping SFP or a
    /// saturated switch port). The fault layer models a degraded
    /// instance by inflating its placement transfer cost by the same
    /// factor, so the two views agree.
    pub fn degraded(&self, factor: f64) -> Link {
        let f = factor.max(1.0);
        Link {
            bandwidth_bps: self.bandwidth_bps / f,
            latency_s: self.latency_s * f,
        }
    }
}

/// A tower of identical boards running the GRU accelerator.
#[derive(Clone, Debug)]
pub struct Tower {
    pub boards: usize,
    pub cfg: GruAccelConfig,
    pub sharding: Sharding,
    pub host_link: Link,
    pub mesh_link: Link,
    pub device: Device,
}

/// Scale-out evaluation result.
#[derive(Clone, Debug)]
pub struct TowerReport {
    pub boards: usize,
    pub sharding: Sharding,
    /// Sustained GRU steps per second across the tower.
    pub throughput_steps_per_s: f64,
    /// Latency for one step (including communication), seconds.
    pub step_latency_s: f64,
    /// Speedup over one board.
    pub speedup: f64,
    /// Parallel efficiency (speedup / boards).
    pub efficiency: f64,
    /// Aggregate power (W).
    pub power_w: f64,
    pub per_board: AccelReport,
}

impl Tower {
    pub fn new(boards: usize, cfg: GruAccelConfig, sharding: Sharding) -> Tower {
        assert!(boards >= 1);
        Tower {
            boards,
            cfg,
            sharding,
            host_link: Link::ten_gbe(),
            mesh_link: Link::aurora(),
            device: Device::pynq_z2(),
        }
    }

    /// Bytes per request crossing the host link (input window + theta).
    fn io_bytes(&self) -> u64 {
        let wb = (self.cfg.act_fmt.word_bits as u64).div_ceil(8);
        ((self.cfg.input + self.cfg.hidden) as u64) * wb
    }

    pub fn report(&self) -> TowerReport {
        let single = GruAccel::new(self.cfg.clone()).report();
        let step_s = single.interval as f64 * self.device.period_ns() * 1e-9;
        let single_tput = 1.0 / step_s;

        let (throughput, latency) = match self.sharding {
            Sharding::DataParallel => {
                // Boards work independently; the shared host NIC caps
                // aggregate ingest.
                let compute_tput = self.boards as f64 * single_tput;
                let nic_tput = self.host_link.bandwidth_bps / self.io_bytes() as f64;
                (
                    compute_tput.min(nic_tput),
                    step_s + self.host_link.transfer_s(self.io_bytes()),
                )
            }
            Sharding::ModelParallel => {
                // Hidden split: per-board compute shrinks ~1/n, but every
                // step all-gathers the hidden state around the ring.
                let shard_step = step_s / self.boards as f64;
                let wb = (self.cfg.act_fmt.word_bits as u64).div_ceil(8);
                let shard_bytes = (self.cfg.hidden as u64 * wb) / self.boards as u64;
                let allgather =
                    (self.boards - 1) as f64 * self.mesh_link.transfer_s(shard_bytes.max(1));
                let lat = shard_step + allgather;
                (1.0 / lat, lat + self.host_link.transfer_s(self.io_bytes()))
            }
        };

        let speedup = throughput / single_tput;
        TowerReport {
            boards: self.boards,
            sharding: self.sharding,
            throughput_steps_per_s: throughput,
            step_latency_s: latency,
            speedup,
            efficiency: speedup / self.boards as f64,
            power_w: single.power_w * self.boards as f64 + 6.0, // + switch
            per_board: single,
        }
    }
}

/// One concrete accelerator card in a *heterogeneous* fleet.
///
/// [`Tower`] models scale-out over identical boards; `BoardSpec` is the
/// heterogeneous counterpart the resource-aware placement layer
/// (`coordinator::placement`) schedules onto: each board carries its own
/// device capacity, accelerator configuration and host link, so two
/// boards in one fleet can differ in clock, fabric budget, DATAFLOW
/// concurrency and transfer cost.
#[derive(Clone, Debug)]
pub struct BoardSpec {
    /// Human-readable instance name (appears in soak reports).
    pub name: String,
    /// Fabric capacity + clock.
    pub device: Device,
    /// The accelerator design instantiated on this board.
    pub cfg: GruAccelConfig,
    /// Host-to-board link windows travel over.
    pub link: Link,
}

impl BoardSpec {
    pub fn new(
        name: impl Into<String>,
        device: Device,
        cfg: GruAccelConfig,
        link: Link,
    ) -> BoardSpec {
        BoardSpec {
            name: name.into(),
            device,
            cfg,
            link,
        }
    }

    /// The same physical board (name, fabric capacity, host link) running
    /// a different accelerator design at a different PL clock — how the
    /// design-space tuner (`fpga::tuner`) re-deploys a board at its
    /// chosen operating point.
    pub fn retargeted(&self, cfg: GruAccelConfig, clock_mhz: f64) -> BoardSpec {
        BoardSpec {
            name: self.name.clone(),
            device: self.device.with_clock(clock_mhz),
            cfg,
            link: self.link,
        }
    }

    /// The assembled accelerator on this board's device.
    pub fn accel(&self) -> GruAccel {
        let mut a = GruAccel::new(self.cfg.clone());
        a.device = self.device;
        a
    }

    /// Structural report (cycles, interval, resources, power) of this
    /// board's design.
    pub fn report(&self) -> AccelReport {
        self.accel().report()
    }

    /// Fabric consumed by this board's design.
    pub fn resources(&self) -> Resources {
        self.report().resources
    }

    /// Does the design fit this board's device?
    pub fn fits(&self) -> bool {
        self.device.fits(&self.resources())
    }

    /// Cycle-model timing for a `seq`-step recovery window streamed
    /// through the board's stage pipeline. DATAFLOW boards overlap
    /// stages; non-DATAFLOW boards execute them back to back.
    pub fn window_timing(&self, seq: u64) -> PipelineTiming {
        let p = self.accel().stage_pipeline();
        if self.cfg.dataflow {
            p.analyze(seq)
        } else {
            p.analyze_sequential(seq)
        }
    }

    /// Wall-clock seconds for one window at this board's clock.
    pub fn window_seconds(&self, seq: u64) -> f64 {
        self.device.cycles_to_seconds(self.window_timing(seq).total_cycles)
    }

    /// Steady-state seconds between window completions when windows
    /// stream back to back (interval-bound, not fill-bound).
    pub fn window_service_seconds(&self, seq: u64) -> f64 {
        self.device.cycles_to_seconds(self.window_timing(seq).interval * seq)
    }

    /// Seconds to move `bytes` of window payload over this board's link.
    pub fn transfer_seconds(&self, bytes: u64) -> f64 {
        self.link.transfer_s(bytes)
    }
}

/// Window payload crossing the host link: quantized `[y | u]` samples
/// in, Θ coefficients back. Shared by the placement cost model
/// (`coordinator::placement`) and the tuner's BRAM double-buffering
/// headroom constraint (`fpga::tuner`), so the two can never disagree
/// about what one in-flight window costs.
pub fn window_payload_bytes(
    act_fmt: &FixedFormat,
    window: usize,
    xdim: usize,
    udim: usize,
    theta_len: usize,
) -> u64 {
    let wb = (act_fmt.word_bits as u64).div_ceil(8);
    ((window * (xdim + udim) + theta_len) as u64) * wb
}

/// The canonical heterogeneous 3-board fleet used by `merinda soak
/// --fleet 3` and the placement tests: a DATAFLOW PYNQ, a sequential
/// (pre-optimization) PYNQ, and a faster-clocked UltraScale+ board on a
/// low-latency link. `input`/`hidden` are the serving model dims.
pub fn heterogeneous_fleet(input: usize, hidden: usize) -> Vec<BoardSpec> {
    let fmt = FixedFormat::q8_8();
    let dataflow = GruAccelConfig::serving(input, hidden, fmt, fmt);
    let sequential = GruAccelConfig {
        dataflow: false,
        ddr_spill: true,
        ..dataflow.clone()
    };
    vec![
        BoardSpec::new("pynq-dataflow", Device::pynq_z2(), dataflow.clone(), Link::ten_gbe()),
        BoardSpec::new("pynq-sequential", Device::pynq_z2(), sequential, Link::ten_gbe()),
        BoardSpec::new("zu7ev-dataflow", Device::zu7ev(), dataflow, Link::aurora()),
    ]
}

/// Sweep tower sizes for a sharding strategy.
pub fn scaling_sweep(
    cfg: &GruAccelConfig,
    sharding: Sharding,
    sizes: &[usize],
) -> Vec<TowerReport> {
    sizes
        .iter()
        .map(|&n| Tower::new(n, cfg.clone(), sharding).report())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GruAccelConfig {
        GruAccelConfig::concurrent()
    }

    #[test]
    fn one_board_matches_single_accel() {
        let t = Tower::new(1, cfg(), Sharding::DataParallel).report();
        assert!((t.speedup - 1.0).abs() < 0.01);
        assert!((t.efficiency - 1.0).abs() < 0.01);
    }

    #[test]
    fn data_parallel_scales_until_nic_bound() {
        let reports = scaling_sweep(&cfg(), Sharding::DataParallel, &[1, 2, 4, 8, 16, 64]);
        // Throughput is non-decreasing in boards.
        for w in reports.windows(2) {
            assert!(w[1].throughput_steps_per_s >= w[0].throughput_steps_per_s * 0.999);
        }
        // Efficiency eventually decays (shared NIC).
        let last = reports.last().unwrap();
        assert!(
            last.efficiency < 1.0,
            "NIC should bound large towers: eff={}",
            last.efficiency
        );
    }

    #[test]
    fn model_parallel_latency_hits_communication_wall() {
        // For this tiny hidden state, all-gather latency swamps the
        // compute shrink — the classic small-model scale-out lesson.
        let r2 = Tower::new(2, cfg(), Sharding::ModelParallel).report();
        let r16 = Tower::new(16, cfg(), Sharding::ModelParallel).report();
        assert!(r16.step_latency_s > r2.step_latency_s * 0.9);
        assert!(r16.efficiency < 0.5);
    }

    #[test]
    fn data_parallel_beats_model_parallel_for_small_models() {
        let d = Tower::new(8, cfg(), Sharding::DataParallel).report();
        let m = Tower::new(8, cfg(), Sharding::ModelParallel).report();
        assert!(d.throughput_steps_per_s > m.throughput_steps_per_s);
    }

    #[test]
    fn power_scales_with_boards() {
        let r1 = Tower::new(1, cfg(), Sharding::DataParallel).report();
        let r4 = Tower::new(4, cfg(), Sharding::DataParallel).report();
        assert!(r4.power_w > 3.5 * r1.per_board.power_w);
    }

    #[test]
    fn link_transfer_time() {
        let l = Link::ten_gbe();
        // 1.25 GB/s → 1 MB ≈ 0.8 ms + 8 µs latency.
        let t = l.transfer_s(1 << 20);
        assert!(t > 8e-4 && t < 1e-3, "t={t}");
    }

    #[test]
    fn degraded_link_costs_the_degradation_factor_more() {
        let l = Link::ten_gbe();
        let d = l.degraded(4.0);
        let bytes = 1u64 << 20;
        let ratio = d.transfer_s(bytes) / l.transfer_s(bytes);
        assert!((ratio - 4.0).abs() < 1e-9, "ratio={ratio}");
        // Factors below 1 clamp to nominal: degradation never speeds up.
        let same = l.degraded(0.5);
        assert!((same.transfer_s(bytes) - l.transfer_s(bytes)).abs() < 1e-15);
    }

    #[test]
    fn heterogeneous_fleet_is_genuinely_heterogeneous() {
        let fleet = heterogeneous_fleet(4, 32);
        assert_eq!(fleet.len(), 3);
        let names: std::collections::BTreeSet<&str> =
            fleet.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(names.len(), 3, "board names must be distinct");
        // Every canonical board's design must fit its device — the
        // placement layer treats a non-fitting board as unusable.
        for b in &fleet {
            assert!(b.fits(), "{}: {} on {}", b.name, b.resources(), b.device.name);
        }
        // The DATAFLOW PYNQ beats the sequential PYNQ per window; the
        // higher-clocked ZU7EV beats both in wall-clock.
        let w = |i: usize| fleet[i].window_seconds(64);
        assert!(w(0) < w(1), "dataflow {} vs sequential {}", w(0), w(1));
        assert!(w(2) < w(0), "zu7ev {} vs pynq {}", w(2), w(0));
    }

    #[test]
    fn retargeted_board_keeps_identity_changes_design() {
        let base = heterogeneous_fleet(4, 32).remove(0);
        let mut cfg = base.cfg.clone();
        cfg.unroll = 64;
        cfg.banks = 32;
        let re = base.retargeted(cfg, 150.0);
        assert_eq!(re.name, base.name);
        assert_eq!(re.device.capacity.lut, base.device.capacity.lut);
        assert!((re.device.clock_mhz - 150.0).abs() < 1e-12);
        assert_eq!(re.cfg.unroll, 64);
        // A faster design at a slower clock still reports coherently.
        assert!(re.window_timing(64).total_cycles > 0);
    }

    #[test]
    fn payload_bytes_count_io_and_theta() {
        let fmt = FixedFormat::q8_8();
        // 64 × (3+1) samples + 45 coefficients at 2 bytes each.
        assert_eq!(window_payload_bytes(&fmt, 64, 3, 1, 45), (64 * 4 + 45) * 2);
        // 12-bit words still occupy 2 bytes on the link.
        let q48 = FixedFormat::q4_8();
        assert_eq!(window_payload_bytes(&q48, 64, 3, 1, 45), (64 * 4 + 45) * 2);
    }

    #[test]
    fn board_window_timing_matches_accel_models() {
        let fleet = heterogeneous_fleet(4, 32);
        let df = &fleet[0];
        let seq = &fleet[1];
        let p_df = df.accel().stage_pipeline();
        assert_eq!(df.window_timing(64), p_df.analyze(64));
        let p_seq = seq.accel().stage_pipeline();
        assert_eq!(seq.window_timing(64), p_seq.analyze_sequential(64));
        // Steady-state service time never exceeds the fill-included
        // window latency for DATAFLOW boards.
        assert!(df.window_service_seconds(64) <= df.window_seconds(64) + 1e-12);
    }
}

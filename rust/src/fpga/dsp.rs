//! DSP48 slice model: pipelined fused multiply–accumulate lanes.
//!
//! A DSP48E1/E2 provides `P = A×B + C` with dedicated pipeline registers,
//! sustaining II = 1 at several hundred MHz (§5.2.1). Linear GRU work
//! (matvecs, bias adds, blending) maps onto arrays of these lanes; bias
//! adds are absorbed in the post-adder.

use super::resources::Resources;

/// One DSP48 MAC lane.
#[derive(Clone, Copy, Debug)]
pub struct DspLane {
    /// Pipeline depth in cycles (MREG + PREG + input regs).
    pub latency: u32,
}

impl Default for DspLane {
    fn default() -> Self {
        // 4-stage: AREG/BREG, MREG, PREG (+ output) — typical full-pipe DSP48.
        DspLane { latency: 4 }
    }
}

/// An array of MAC lanes executing a dense linear operation.
#[derive(Clone, Debug)]
pub struct DspMacArray {
    pub lanes: u32,
    pub lane: DspLane,
}

impl DspMacArray {
    pub fn new(lanes: u32) -> DspMacArray {
        DspMacArray {
            lanes: lanes.max(1),
            lane: DspLane::default(),
        }
    }

    /// Cycles to compute `macs` multiply–accumulates when memory can supply
    /// `memory_ii` iterations-worth of operands (II from the BRAM model).
    ///
    /// Each cycle the array retires `lanes` MACs if fed; the effective
    /// launch rate is one iteration per `memory_ii` cycles. Total =
    /// pipeline fill + steady issue.
    pub fn cycles(&self, macs: u64, memory_ii: u32) -> u64 {
        if macs == 0 {
            return 0;
        }
        let iters = macs.div_ceil(self.lanes as u64);
        self.lane.latency as u64 + iters * memory_ii as u64 - 1
    }

    /// Cycles at perfect II=1 feeding.
    pub fn cycles_fed(&self, macs: u64) -> u64 {
        self.cycles(macs, 1)
    }

    /// Resource bundle: one DSP slice per lane, plus accumulation /
    /// control fabric.
    pub fn resources(&self) -> Resources {
        Resources {
            lut: 40 * self.lanes as u64,
            ff: 60 * self.lanes as u64,
            dsp: self.lanes as u64,
            bram18: 0,
        }
    }
}

/// Elementwise DSP stage (e.g. the final interpolation, Eq. 15: two
/// multiplies + one add per element → 2 DSPs per parallel element lane).
#[derive(Clone, Debug)]
pub struct DspElementwise {
    /// Parallel element lanes.
    pub lanes: u32,
    /// DSPs consumed per lane.
    pub dsp_per_lane: u32,
    pub latency: u32,
}

impl DspElementwise {
    pub fn new(lanes: u32, dsp_per_lane: u32) -> DspElementwise {
        DspElementwise {
            lanes: lanes.max(1),
            dsp_per_lane,
            latency: 4,
        }
    }

    /// Cycles to process `elems` elements.
    pub fn cycles(&self, elems: u64, memory_ii: u32) -> u64 {
        if elems == 0 {
            return 0;
        }
        let iters = elems.div_ceil(self.lanes as u64);
        self.latency as u64 + iters * memory_ii as u64 - 1
    }

    pub fn resources(&self) -> Resources {
        Resources {
            lut: 25 * self.lanes as u64,
            ff: 40 * self.lanes as u64,
            dsp: (self.lanes * self.dsp_per_lane) as u64,
            bram18: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_cycles_scale_with_lanes() {
        let a1 = DspMacArray::new(1);
        let a4 = DspMacArray::new(4);
        // 960 MACs: 1 lane → 960 iters; 4 lanes → 240 iters.
        assert_eq!(a1.cycles_fed(960), 4 + 960 - 1);
        assert_eq!(a4.cycles_fed(960), 4 + 240 - 1);
    }

    #[test]
    fn memory_stall_doubles_cycles() {
        let a = DspMacArray::new(4);
        // II=2 (unbanked memory): issue every other cycle.
        assert_eq!(a.cycles(960, 2), 4 + 480 - 1);
    }

    #[test]
    fn zero_work_is_free() {
        assert_eq!(DspMacArray::new(8).cycles_fed(0), 0);
        assert_eq!(DspElementwise::new(4, 2).cycles(0, 1), 0);
    }

    #[test]
    fn resources_one_dsp_per_lane() {
        assert_eq!(DspMacArray::new(16).resources().dsp, 16);
        assert_eq!(DspElementwise::new(4, 2).resources().dsp, 8);
    }

    #[test]
    fn elementwise_cycles() {
        let e = DspElementwise::new(4, 2);
        // 16 elements on 4 lanes: 4 iterations + fill.
        assert_eq!(e.cycles(16, 1), 4 + 4 - 1);
    }
}

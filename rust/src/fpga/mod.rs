//! Cycle-level FPGA dataflow simulator — the paper's hardware substrate.
//!
//! The paper evaluates MERINDA on a PYNQ-Z2 via Vitis HLS; this module
//! reproduces that study structurally (DESIGN.md §2): BRAM banking and the
//! II law (`bram`), DSP48 MAC lanes (`dsp`), LUT activation tables and
//! fabric arithmetic (`lut`), fixed-point numerics (`fixedpoint`), the
//! DATAFLOW stage pipeline (`pipeline`), an HLS-style scheduler (`hls`),
//! DDR/AXI transfers (`interconnect`), the calibrated power model
//! (`power`), and device capacities (`resources`). Accelerators are not
//! hand-assembled on top of those primitives any more: `graph` is a
//! dataflow-graph IR (ops + edges + per-op resource/latency annotations)
//! whose lowering pass compiles any well-formed graph through the cycle,
//! fit and power models — the GRU and LTC accelerators behind Tables 7–8
//! / Fig. 8 (`gru_accel`, `ltc_accel`) are graph instances, and the
//! SINDy library + dense-head family (`sindy_accel`) is described by its
//! graph alone. `cluster` scales out: identical-board towers plus the
//! heterogeneous [`BoardSpec`](cluster::BoardSpec) fleet the
//! resource-aware placement layer (`coordinator::placement`) schedules
//! onto. `tuner` closes the loop: it sweeps the design space (tiling ×
//! format × adder mix × clock) per board — or per graph family via
//! [`tune_graph`](tuner::tune_graph) — scores candidates with the
//! cycle/resource/power models, and hands the chosen
//! [`TunedConfig`](tuner::TunedConfig) to placement — the models stop
//! describing designs and start picking them. `partition` goes past the
//! single device entirely: it cuts one graph along its FIFO edges into
//! per-board subgraphs joined by explicit link hops, so a design too big
//! for any one device still streams across the fleet
//! ([`PartitionedPlan`](partition::PartitionedPlan)).

pub mod bram;
pub mod cluster;
pub mod dsp;
pub mod fixedpoint;
pub mod graph;
pub mod gru_accel;
pub mod hls;
pub mod interconnect;
pub mod lut;
pub mod ltc_accel;
pub mod partition;
pub mod pipeline;
pub mod power;
pub mod resources;
pub mod sindy_accel;
pub mod tuner;

// The stage-map vocabulary, shared by every four-op family.
pub use graph::{all_stage_maps, default_stage_maps, stage_map_name, StageMap};

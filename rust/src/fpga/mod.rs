//! Cycle-level FPGA dataflow simulator — the paper's hardware substrate.
//!
//! The paper evaluates MERINDA on a PYNQ-Z2 via Vitis HLS; this module
//! reproduces that study structurally (DESIGN.md §2): BRAM banking and the
//! II law (`bram`), DSP48 MAC lanes (`dsp`), LUT activation tables and
//! fabric arithmetic (`lut`), fixed-point numerics (`fixedpoint`), the
//! DATAFLOW stage pipeline (`pipeline`), an HLS-style scheduler (`hls`),
//! DDR/AXI transfers (`interconnect`), the calibrated power model
//! (`power`), device capacities (`resources`), and the assembled GRU and
//! LTC accelerators (`gru_accel`, `ltc_accel`) behind Tables 7–8 / Fig. 8.
//! `cluster` scales out: identical-board towers plus the heterogeneous
//! [`BoardSpec`](cluster::BoardSpec) fleet the resource-aware placement
//! layer (`coordinator::placement`) schedules onto. `tuner` closes the
//! loop: it sweeps the design space (tiling × format × adder mix ×
//! clock) per board, scores candidates with the cycle/resource/power
//! models, and hands the chosen [`TunedConfig`](tuner::TunedConfig) to
//! placement — the models stop describing designs and start picking
//! them.

pub mod bram;
pub mod cluster;
pub mod dsp;
pub mod fixedpoint;
pub mod gru_accel;
pub mod hls;
pub mod interconnect;
pub mod lut;
pub mod ltc_accel;
pub mod pipeline;
pub mod power;
pub mod resources;
pub mod tuner;

//! DATAFLOW pipeline model: concurrent stages linked by FIFOs.
//!
//! §5.2.3: under the HLS `DATAFLOW` pragma each stage becomes its own
//! hardware process; once the pipeline fills, every stage works on a
//! different time step concurrently. Steady-state spacing between outputs
//! (the paper's *Interval*) is the maximum per-stage II (plus any
//! arbitration); latency-to-first-result is the sum of stage depths.
//!
//! Two evaluators are provided and cross-checked in tests:
//! * [`Pipeline::analyze`] — closed-form cycles/interval.
//! * [`Pipeline::simulate`] — cycle-accurate token simulation through
//!   bounded FIFOs (captures backpressure from undersized FIFOs, which the
//!   analytic model assumes away).

/// One DATAFLOW stage.
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: String,
    /// Steady-state initiation interval (cycles between accepted inputs).
    pub ii: u32,
    /// Latency from accepting an input to emitting its output.
    pub depth: u32,
}

impl Stage {
    pub fn new(name: impl Into<String>, ii: u32, depth: u32) -> Stage {
        Stage {
            name: name.into(),
            ii: ii.max(1),
            depth: depth.max(1),
        }
    }
}

/// Result of evaluating a pipeline over a workload of `items`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineTiming {
    /// Total cycles from first input to last output.
    pub total_cycles: u64,
    /// Steady-state output spacing.
    pub interval: u64,
    /// Cycles until the first output (pipeline fill).
    pub fill_latency: u64,
}

/// A linear DATAFLOW pipeline (the GRU graph in Fig. 6 is linear).
#[derive(Clone, Debug, Default)]
pub struct Pipeline {
    pub stages: Vec<Stage>,
    /// FIFO capacity between stage i and i+1 (len = stages-1). `None`
    /// means unbounded (analytic assumption).
    pub fifo_depths: Vec<Option<u32>>,
}

impl Pipeline {
    pub fn new(stages: Vec<Stage>) -> Pipeline {
        let n = stages.len().saturating_sub(1);
        Pipeline {
            stages,
            fifo_depths: vec![None; n],
        }
    }

    pub fn with_fifos(mut self, depths: Vec<Option<u32>>) -> Pipeline {
        assert_eq!(depths.len(), self.stages.len().saturating_sub(1));
        self.fifo_depths = depths;
        self
    }

    /// Closed-form timing, assuming adequately sized FIFOs:
    /// interval = max II; fill = Σ depth; total = fill + (items-1)·interval.
    pub fn analyze(&self, items: u64) -> PipelineTiming {
        assert!(!self.stages.is_empty());
        let interval = self.stages.iter().map(|s| s.ii as u64).max().unwrap();
        let fill: u64 = self.stages.iter().map(|s| s.depth as u64).sum();
        let total = if items == 0 {
            0
        } else {
            fill + (items - 1) * interval
        };
        PipelineTiming {
            total_cycles: total,
            interval,
            fill_latency: fill,
        }
    }

    /// Sequential (no DATAFLOW) execution: stages do not overlap, so each
    /// item takes Σ(depth + (1-1)·ii) ... i.e. per-item latency is the sum
    /// of stage service times and interval equals that sum.
    pub fn analyze_sequential(&self, items: u64) -> PipelineTiming {
        let per_item: u64 = self
            .stages
            .iter()
            .map(|s| s.depth as u64 + s.ii as u64 - 1)
            .sum();
        PipelineTiming {
            total_cycles: per_item * items,
            interval: per_item,
            fill_latency: per_item,
        }
    }

    /// Cycle-accurate token simulation with bounded FIFOs.
    ///
    /// Each stage accepts a new token every `ii` cycles if its input FIFO
    /// has a token and its output FIFO has space; a token emerges `depth`
    /// cycles after acceptance. Returns exact timing (and equals
    /// `analyze` when FIFOs are deep enough — property-tested).
    pub fn simulate(&self, items: u64) -> PipelineTiming {
        let n = self.stages.len();
        assert!(n > 0);
        if items == 0 {
            return PipelineTiming {
                total_cycles: 0,
                interval: 0,
                fill_latency: 0,
            };
        }
        // occupancy of FIFO i (between stage i-1 and i); fifo 0 is the
        // unbounded input queue.
        let mut fifo: Vec<u64> = vec![0; n + 1];
        fifo[0] = items;
        let caps: Vec<u64> = std::iter::once(u64::MAX)
            .chain(
                self.fifo_depths
                    .iter()
                    .map(|d| d.map(|v| v as u64).unwrap_or(u64::MAX)),
            )
            .chain(std::iter::once(u64::MAX))
            .collect(); // caps[i] = capacity of fifo i, output unbounded

        // in-flight tokens per stage: (finish_cycle) min-queue.
        let mut inflight: Vec<std::collections::VecDeque<u64>> =
            vec![std::collections::VecDeque::new(); n];
        let mut next_accept: Vec<u64> = vec![0; n];
        let mut first_out: Option<u64> = None;
        let mut last_out = 0u64;
        let mut produced = 0u64;
        let mut cycle = 0u64;
        // Safety bound: generous upper bound on runtime.
        let bound = self
            .stages
            .iter()
            .map(|s| (s.ii as u64 + s.depth as u64) * (items + n as u64))
            .sum::<u64>()
            + 1_000;

        while produced < items && cycle < bound {
            // Retire completions (upstream-first so a token can't traverse
            // two stages in one cycle).
            for i in 0..n {
                while let Some(&f) = inflight[i].front() {
                    if f <= cycle && fifo[i + 1] < caps[i + 1] {
                        inflight[i].pop_front();
                        fifo[i + 1] += 1;
                        if i == n - 1 {
                            produced += 1;
                            last_out = cycle;
                            first_out.get_or_insert(cycle);
                        }
                    } else {
                        break;
                    }
                }
            }
            // Accept new tokens.
            for i in 0..n {
                let s = &self.stages[i];
                if cycle >= next_accept[i] && fifo[i] > 0 {
                    // Bounded in-flight: stage holds at most depth/ii tokens.
                    let max_inflight = (s.depth as u64).div_ceil(s.ii as u64).max(1);
                    if (inflight[i].len() as u64) < max_inflight + 1 {
                        fifo[i] -= 1;
                        inflight[i].push_back(cycle + s.depth as u64);
                        next_accept[i] = cycle + s.ii as u64;
                    }
                }
            }
            cycle += 1;
        }
        let fill = first_out.map(|c| c + 1).unwrap_or(0);
        let total = last_out + 1;
        let interval = if items > 1 {
            (total - fill) / (items - 1).max(1) + u64::from((total - fill) % (items - 1) != 0)
        } else {
            self.stages.iter().map(|s| s.ii as u64).max().unwrap()
        };
        PipelineTiming {
            total_cycles: total,
            interval,
            fill_latency: fill,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gru_like() -> Pipeline {
        Pipeline::new(vec![
            Stage::new("affine", 4, 32),
            Stage::new("sigmoid", 1, 2),
            Stage::new("candidate", 4, 24),
            Stage::new("interp", 1, 4),
        ])
    }

    #[test]
    fn interval_is_max_ii() {
        let t = gru_like().analyze(100);
        assert_eq!(t.interval, 4);
        assert_eq!(t.fill_latency, 62);
        assert_eq!(t.total_cycles, 62 + 99 * 4);
    }

    #[test]
    fn sequential_is_sum() {
        let t = gru_like().analyze_sequential(10);
        // (4-1+32)+(1-1+2)+(4-1+24)+(1-1+4) = 35+2+27+4 = 68
        assert_eq!(t.interval, 68);
        assert_eq!(t.total_cycles, 680);
    }

    #[test]
    fn dataflow_beats_sequential() {
        let p = gru_like();
        assert!(p.analyze(50).total_cycles < p.analyze_sequential(50).total_cycles);
    }

    #[test]
    fn simulation_matches_analysis_with_deep_fifos() {
        let p = gru_like();
        for items in [1u64, 2, 7, 32] {
            let a = p.analyze(items);
            let s = p.simulate(items);
            // Fill latency in the event model includes accept alignment;
            // allow a small constant skew but identical steady interval.
            assert!(
                (s.total_cycles as i64 - a.total_cycles as i64).abs() <= 8,
                "items={items}: sim={s:?} ana={a:?}"
            );
        }
    }

    #[test]
    fn undersized_fifo_creates_backpressure() {
        // Slow consumer, tiny FIFO: producer stalls; total ≈ consumer-bound
        // either way, but fill of downstream changes. Compare against a
        // deep-FIFO run to ensure the bounded one is never faster.
        let fast_then_slow = Pipeline::new(vec![
            Stage::new("prod", 1, 1),
            Stage::new("cons", 8, 8),
        ]);
        let deep = fast_then_slow.clone().with_fifos(vec![Some(1024)]);
        let tiny = fast_then_slow.with_fifos(vec![Some(1)]);
        let d = deep.simulate(64);
        let t = tiny.simulate(64);
        assert!(t.total_cycles >= d.total_cycles);
        // Consumer II bounds throughput in both cases.
        assert!(d.total_cycles >= 8 * 63);
    }

    #[test]
    fn single_item_interval_is_max_ii() {
        let p = gru_like();
        assert_eq!(p.simulate(1).interval, 4);
    }

    #[test]
    fn zero_items() {
        assert_eq!(gru_like().analyze(0).total_cycles, 0);
        assert_eq!(gru_like().simulate(0).total_cycles, 0);
    }
}

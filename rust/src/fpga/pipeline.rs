//! DATAFLOW pipeline model: concurrent stages linked by FIFOs.
//!
//! §5.2.3: under the HLS `DATAFLOW` pragma each stage becomes its own
//! hardware process; once the pipeline fills, every stage works on a
//! different time step concurrently. Steady-state spacing between outputs
//! (the paper's *Interval*) is the maximum per-stage II (plus any
//! arbitration); latency-to-first-result is the sum of stage depths.
//!
//! Two evaluators are provided and cross-checked in tests:
//! * [`Pipeline::analyze`] — closed-form cycles/interval.
//! * [`Pipeline::simulate`] — cycle-accurate token simulation through
//!   bounded FIFOs (captures backpressure from undersized FIFOs, which the
//!   analytic model assumes away).

/// One DATAFLOW stage.
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: String,
    /// Steady-state initiation interval (cycles between accepted inputs).
    pub ii: u32,
    /// Latency from accepting an input to emitting its output.
    pub depth: u32,
}

impl Stage {
    pub fn new(name: impl Into<String>, ii: u32, depth: u32) -> Stage {
        Stage {
            name: name.into(),
            ii: ii.max(1),
            depth: depth.max(1),
        }
    }
}

/// Result of evaluating a pipeline over a workload of `items`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineTiming {
    /// Total cycles from first input to last output.
    pub total_cycles: u64,
    /// Steady-state output spacing.
    pub interval: u64,
    /// Cycles until the first output (pipeline fill).
    pub fill_latency: u64,
}

/// A linear DATAFLOW pipeline (the GRU graph in Fig. 6 is linear).
///
/// # Example
///
/// ```
/// use merinda::fpga::pipeline::{Pipeline, Stage};
///
/// let p = Pipeline::new(vec![
///     Stage::new("affine", 4, 32),
///     Stage::new("interp", 1, 4),
/// ]);
/// let t = p.analyze(100);
/// assert_eq!(t.interval, 4); // slowest stage II bounds throughput
/// assert_eq!(t.fill_latency, 36); // sum of stage depths
/// assert_eq!(t.total_cycles, 36 + 99 * 4);
/// // The cycle-accurate simulation agrees with the closed form.
/// assert_eq!(p.simulate(100), t);
/// ```
#[derive(Clone, Debug, Default)]
pub struct Pipeline {
    pub stages: Vec<Stage>,
    /// FIFO capacity between stage i and i+1 (len = stages-1). `None`
    /// means unbounded (analytic assumption).
    pub fifo_depths: Vec<Option<u32>>,
}

impl Pipeline {
    pub fn new(stages: Vec<Stage>) -> Pipeline {
        let n = stages.len().saturating_sub(1);
        Pipeline {
            stages,
            fifo_depths: vec![None; n],
        }
    }

    pub fn with_fifos(mut self, depths: Vec<Option<u32>>) -> Pipeline {
        assert_eq!(depths.len(), self.stages.len().saturating_sub(1));
        self.fifo_depths = depths;
        self
    }

    /// Closed-form timing, assuming adequately sized FIFOs:
    /// interval = max II; fill = Σ depth; total = fill + (items-1)·interval.
    pub fn analyze(&self, items: u64) -> PipelineTiming {
        assert!(!self.stages.is_empty());
        let interval = self.stages.iter().map(|s| s.ii as u64).max().unwrap();
        let fill: u64 = self.stages.iter().map(|s| s.depth as u64).sum();
        let total = if items == 0 {
            0
        } else {
            fill + (items - 1) * interval
        };
        PipelineTiming {
            total_cycles: total,
            interval,
            fill_latency: fill,
        }
    }

    /// Sequential (no DATAFLOW) execution: stages do not overlap, so each
    /// item takes Σ(depth + (1-1)·ii) ... i.e. per-item latency is the sum
    /// of stage service times and interval equals that sum.
    pub fn analyze_sequential(&self, items: u64) -> PipelineTiming {
        let per_item: u64 = self
            .stages
            .iter()
            .map(|s| s.depth as u64 + s.ii as u64 - 1)
            .sum();
        PipelineTiming {
            total_cycles: per_item * items,
            interval: per_item,
            fill_latency: per_item,
        }
    }

    /// Cycle-accurate token simulation with bounded FIFOs.
    ///
    /// Event-driven max-plus recursion: stage `i` accepts token `k` once
    /// (a) the token has left stage `i-1`, (b) `ii` cycles have elapsed
    /// since the stage's previous accept, and (c) one of the stage's
    /// `⌈depth/ii⌉` internal pipeline slots is free; the token is ready
    /// `depth` cycles after acceptance and leaves as soon as the
    /// downstream FIFO has space (same-cycle handoff: a slot freed by the
    /// consumer's accept can be refilled in that cycle). With FIFOs deep
    /// enough to never backpressure, the recursion collapses to the
    /// closed form, so `simulate` equals [`Pipeline::analyze`] **exactly**
    /// — unit- and property-tested. Undersized FIFOs stall producers and
    /// only ever increase cycle counts.
    pub fn simulate(&self, items: u64) -> PipelineTiming {
        let n = self.stages.len();
        assert!(n > 0);
        if items == 0 {
            return PipelineTiming {
                total_cycles: 0,
                interval: 0,
                fill_latency: 0,
            };
        }
        let m = items as usize;
        // start[k*n + i]: cycle stage i accepts token k;
        // fin[k*n + i]:   cycle token k enters the FIFO after stage i.
        let mut start = vec![0u64; m * n];
        let mut fin = vec![0u64; m * n];
        for k in 0..m {
            for i in 0..n {
                let st = &self.stages[i];
                let (ii, depth) = (st.ii as u64, st.depth as u64);
                let mut t = if i > 0 { fin[k * n + i - 1] } else { 0 };
                if k > 0 {
                    t = t.max(start[(k - 1) * n + i] + ii);
                }
                let slots = depth.div_ceil(ii).max(1) as usize;
                if k >= slots {
                    // All internal slots busy until an older token leaves.
                    t = t.max(fin[(k - slots) * n + i]);
                }
                let mut f = t + depth;
                if k > 0 {
                    // FIFO ordering: token k cannot overtake token k-1.
                    f = f.max(fin[(k - 1) * n + i]);
                }
                if i + 1 < n {
                    if let Some(cap) = self.fifo_depths[i] {
                        let cap = (cap as usize).max(1);
                        if k >= cap {
                            // Space frees when the consumer accepts the
                            // token `cap` places ahead.
                            f = f.max(start[(k - cap) * n + i + 1]);
                        }
                    }
                }
                start[k * n + i] = t;
                fin[k * n + i] = f;
            }
        }
        let total = fin[(m - 1) * n + n - 1];
        let fill = fin[n - 1];
        let interval = if items > 1 {
            let span = total - fill;
            span / (items - 1) + u64::from(span % (items - 1) != 0)
        } else {
            self.stages.iter().map(|s| s.ii as u64).max().unwrap()
        };
        PipelineTiming {
            total_cycles: total,
            interval,
            fill_latency: fill,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gru_like() -> Pipeline {
        Pipeline::new(vec![
            Stage::new("affine", 4, 32),
            Stage::new("sigmoid", 1, 2),
            Stage::new("candidate", 4, 24),
            Stage::new("interp", 1, 4),
        ])
    }

    #[test]
    fn interval_is_max_ii() {
        let t = gru_like().analyze(100);
        assert_eq!(t.interval, 4);
        assert_eq!(t.fill_latency, 62);
        assert_eq!(t.total_cycles, 62 + 99 * 4);
    }

    #[test]
    fn sequential_is_sum() {
        let t = gru_like().analyze_sequential(10);
        // (4-1+32)+(1-1+2)+(4-1+24)+(1-1+4) = 35+2+27+4 = 68
        assert_eq!(t.interval, 68);
        assert_eq!(t.total_cycles, 680);
    }

    #[test]
    fn dataflow_beats_sequential() {
        let p = gru_like();
        assert!(p.analyze(50).total_cycles < p.analyze_sequential(50).total_cycles);
    }

    #[test]
    fn simulation_matches_analysis_with_deep_fifos() {
        let p = gru_like();
        for items in [1u64, 2, 7, 32] {
            assert_eq!(p.simulate(items), p.analyze(items), "items={items}");
        }
        // Explicit deep (but bounded) FIFOs behave like unbounded ones.
        let deep = gru_like().with_fifos(vec![Some(1024); 3]);
        for items in [1u64, 2, 7, 32] {
            assert_eq!(deep.simulate(items), deep.analyze(items), "items={items}");
        }
    }

    #[test]
    fn undersized_fifo_creates_backpressure() {
        // Slow consumer, tiny FIFO: producer stalls; total ≈ consumer-bound
        // either way, but fill of downstream changes. Compare against a
        // deep-FIFO run to ensure the bounded one is never faster.
        let fast_then_slow = Pipeline::new(vec![
            Stage::new("prod", 1, 1),
            Stage::new("cons", 8, 8),
        ]);
        let deep = fast_then_slow.clone().with_fifos(vec![Some(1024)]);
        let tiny = fast_then_slow.with_fifos(vec![Some(1)]);
        let d = deep.simulate(64);
        let t = tiny.simulate(64);
        assert!(t.total_cycles >= d.total_cycles);
        // Consumer II bounds throughput in both cases.
        assert!(d.total_cycles >= 8 * 63);
    }

    #[test]
    fn single_item_interval_is_max_ii() {
        let p = gru_like();
        assert_eq!(p.simulate(1).interval, 4);
    }

    #[test]
    fn zero_items() {
        assert_eq!(gru_like().analyze(0).total_cycles, 0);
        assert_eq!(gru_like().simulate(0).total_cycles, 0);
    }
}

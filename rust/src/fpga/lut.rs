//! LUT-fabric functional units: activation tables and carry-chain logic.
//!
//! §5.2.2: sigmoid/tanh are implemented as lookup / piecewise-linear tables
//! in distributed LUT RAM, returning a value in one cycle without touching
//! DSPs. This module provides (a) a *functional* table implementation used
//! by the fixed-point datapath (so accuracy under table quantization is
//! measurable), and (b) *cost models* for mapping arithmetic onto LUT
//! fabric instead of DSPs — the `sN = L` configurations of Table 7.

use super::resources::Resources;

/// Activation function selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Sigmoid,
    Tanh,
}

impl Activation {
    pub fn exact(&self, x: f64) -> f64 {
        match self {
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Activation::Tanh => x.tanh(),
        }
    }
}

/// A piecewise-linear activation table stored in LUT RAM.
///
/// `entries` breakpoints uniformly span `[-range, range]`; outside the
/// range the function saturates to its asymptote. With linear
/// interpolation between breakpoints the error for sigmoid/tanh at 64
/// entries over ±8 is already below 1e-3 — consistent with the paper's
/// "minimal accuracy loss" claim for LUT activations.
#[derive(Clone, Debug)]
pub struct ActivationTable {
    pub func: Activation,
    pub entries: usize,
    pub range: f64,
    table: Vec<f64>,
    /// f32 copy of the table + precomputed index scale for the hot path
    /// (EXPERIMENTS.md §Perf: the functional datapath emulation calls this
    /// per element per step).
    table_f32: Vec<f32>,
    inv_step_f32: f32,
    /// One-cycle lookup (paper: "constant time (one cycle)").
    pub latency: u32,
    /// Linear interpolation between breakpoints (vs staircase).
    pub interpolate: bool,
}

impl ActivationTable {
    pub fn new(func: Activation, entries: usize, range: f64, interpolate: bool) -> Self {
        assert!(entries >= 2);
        let table: Vec<f64> = (0..entries)
            .map(|i| {
                let x = -range + 2.0 * range * i as f64 / (entries - 1) as f64;
                func.exact(x)
            })
            .collect();
        let table_f32: Vec<f32> = table.iter().map(|&v| v as f32).collect();
        let inv_step_f32 = ((entries - 1) as f64 / (2.0 * range)) as f32;
        ActivationTable {
            func,
            entries,
            range,
            table,
            table_f32,
            inv_step_f32,
            latency: 1,
            interpolate,
        }
    }

    /// f32 hot-path evaluation (identical math to `eval`, single-precision
    /// index arithmetic; bounded by the same table error).
    #[inline]
    pub fn eval_f32(&self, x: f32) -> f32 {
        let r = self.range as f32;
        if x <= -r {
            return self.table_f32[0];
        }
        if x >= r {
            return self.table_f32[self.entries - 1];
        }
        let pos = (x + r) * self.inv_step_f32;
        let idx = pos as usize; // x > -r so pos >= 0
        if !self.interpolate || idx + 1 >= self.entries {
            return self.table_f32[idx.min(self.entries - 1)];
        }
        let frac = pos - idx as f32;
        self.table_f32[idx] * (1.0 - frac) + self.table_f32[idx + 1] * frac
    }

    /// Paper-style default: 256-entry interpolated table over ±8.
    pub fn default_for(func: Activation) -> Self {
        ActivationTable::new(func, 256, 8.0, true)
    }

    /// Evaluate through the table (the hardware datapath).
    pub fn eval(&self, x: f64) -> f64 {
        if x <= -self.range {
            return self.table[0];
        }
        if x >= self.range {
            return self.table[self.entries - 1];
        }
        let pos = (x + self.range) / (2.0 * self.range) * (self.entries - 1) as f64;
        let idx = pos.floor() as usize;
        if !self.interpolate || idx + 1 >= self.entries {
            return self.table[idx.min(self.entries - 1)];
        }
        let frac = pos - idx as f64;
        self.table[idx] * (1.0 - frac) + self.table[idx + 1] * frac
    }

    /// Maximum absolute error vs the exact function, sampled densely.
    pub fn max_error(&self) -> f64 {
        let mut worst: f64 = 0.0;
        let samples = 4 * self.entries;
        for i in 0..=samples {
            let x = -self.range + 2.0 * self.range * i as f64 / samples as f64;
            worst = worst.max((self.eval(x) - self.func.exact(x)).abs());
        }
        worst
    }

    /// LUT cost: table bits in distributed RAM (64 bits per LUT as RAM64)
    /// plus interpolation adder/multiplier if enabled.
    pub fn resources(&self, word_bits: u32) -> Resources {
        let table_bits = self.entries as u64 * word_bits as u64;
        let lutram = table_bits.div_ceil(64);
        let interp = if self.interpolate {
            // One small multiplier (frac × delta) + adder in fabric.
            lut_mult_cost(word_bits.min(12)) + word_bits as u64
        } else {
            0
        };
        Resources {
            lut: lutram + interp + 16,
            ff: word_bits as u64 * 2,
            dsp: 0,
            bram18: 0,
        }
    }
}

/// LUT cost of a W×W-bit array multiplier in fabric (no DSP): roughly
/// W²·1.1 LUTs for a carry-save array — the price of `sN = L` mappings in
/// Table 7 (DSP count drops, LUT count balloons).
pub fn lut_mult_cost(word_bits: u32) -> u64 {
    let w = word_bits as u64;
    (w * w).max(1) + w / 2
}

/// LUT cost of a W-bit carry-chain adder (§1: "carry-chain adders").
pub fn lut_add_cost(word_bits: u32) -> u64 {
    word_bits as u64
}

/// A MAC lane built from LUT fabric instead of a DSP slice: same function,
/// ~2× the latency (carry chains are slower than hard DSP pipes), zero DSP.
#[derive(Clone, Debug)]
pub struct LutMacArray {
    pub lanes: u32,
    pub word_bits: u32,
    pub latency: u32,
}

impl LutMacArray {
    pub fn new(lanes: u32, word_bits: u32) -> LutMacArray {
        LutMacArray {
            lanes: lanes.max(1),
            word_bits,
            latency: 6, // array multiplier + carry chain, pipelined deeper
        }
    }

    /// Cycles to retire `macs` multiply–accumulates at the given memory II.
    /// Throughput matches the DSP array (II=1 capable once pipelined); the
    /// cost is fabric area and a longer fill.
    pub fn cycles(&self, macs: u64, memory_ii: u32) -> u64 {
        if macs == 0 {
            return 0;
        }
        let iters = macs.div_ceil(self.lanes as u64);
        self.latency as u64 + iters * memory_ii as u64 - 1
    }

    pub fn resources(&self) -> Resources {
        let per_lane = lut_mult_cost(self.word_bits) + 2 * lut_add_cost(self.word_bits);
        Resources {
            lut: per_lane * self.lanes as u64 + 30,
            ff: (self.word_bits as u64 * 4) * self.lanes as u64,
            dsp: 0,
            bram18: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_table_accuracy() {
        let t = ActivationTable::default_for(Activation::Sigmoid);
        assert!(t.max_error() < 1e-3, "err={}", t.max_error());
    }

    #[test]
    fn tanh_table_accuracy() {
        let t = ActivationTable::default_for(Activation::Tanh);
        assert!(t.max_error() < 2e-3, "err={}", t.max_error());
    }

    #[test]
    fn more_entries_monotonically_better() {
        let small = ActivationTable::new(Activation::Sigmoid, 32, 8.0, true);
        let big = ActivationTable::new(Activation::Sigmoid, 512, 8.0, true);
        assert!(big.max_error() < small.max_error());
    }

    #[test]
    fn interpolation_beats_staircase() {
        let stair = ActivationTable::new(Activation::Tanh, 128, 8.0, false);
        let interp = ActivationTable::new(Activation::Tanh, 128, 8.0, true);
        assert!(interp.max_error() < stair.max_error());
    }

    #[test]
    fn saturates_outside_range() {
        let t = ActivationTable::default_for(Activation::Sigmoid);
        assert!((t.eval(100.0) - 1.0).abs() < 1e-3);
        assert!(t.eval(-100.0).abs() < 1e-3);
    }

    #[test]
    fn lut_mac_uses_no_dsp_but_many_luts() {
        let lut = LutMacArray::new(4, 16);
        let r = lut.resources();
        assert_eq!(r.dsp, 0);
        assert!(r.lut > 1000, "lut={}", r.lut);
    }

    #[test]
    fn lut_and_dsp_macs_same_steady_throughput() {
        use super::super::dsp::DspMacArray;
        let l = LutMacArray::new(4, 16);
        let d = DspMacArray::new(4);
        let big = 100_000;
        let dl = l.cycles(big, 1) as f64;
        let dd = d.cycles_fed(big) as f64;
        assert!((dl - dd).abs() / dd < 0.01);
    }

    #[test]
    fn activation_exact_values() {
        assert!((Activation::Sigmoid.exact(0.0) - 0.5).abs() < 1e-12);
        assert!(Activation::Tanh.exact(0.0).abs() < 1e-12);
    }
}

//! Dataflow-graph IR for accelerator lowering: one graph description,
//! every model family.
//!
//! Before this module, every accelerator was hand-described:
//! `gru_accel` and `ltc_accel` each built their own stage schedule and
//! report arithmetic, and adding a model family meant re-deriving
//! stages, BRAM tiling and adder-mix choices by hand. Here the
//! description is lifted into a small IR — [`Op`] nodes (matvec,
//! elementwise, nonlinearity, reduction) carrying exactly the
//! annotations the HLS scheduler consumes (trip count, UNROLL lanes,
//! MAC/elementwise/activation counts, [`Binding`] to DSP or LUT fabric,
//! BRAM tile footprints via [`BankedArray`]), [`Edge`]s between them
//! (element volume, DATAFLOW FIFO depth, DDR spill round trips) and
//! explicit [`Transfer`] records for DDR/BRAM movement the compute
//! graph itself does not express — and [`lower`] compiles any
//! well-formed graph through the existing cycle model
//! ([`schedule`](super::hls::schedule) per op, then the streaming or
//! iterative interval law), [`Device::fits`], and the calibrated
//! [`power`](super::power) model.
//!
//! The GRU and LTC accelerators are graph *instances* now
//! (`GruAccel::graph` / `LtcAccel::graph`); their lowered schedules are
//! asserted cycle-exact against the original hand-built ones across the
//! whole tuner search space (`rust/tests/graph.rs`), and new families —
//! the SINDy library + dense-head accelerator in
//! [`sindy_accel`](super::sindy_accel) — need zero scheduling code.
//!
//! # Example
//!
//! ```
//! use merinda::fpga::graph::{lower, Graph, Op, Target};
//! use merinda::fpga::bram::BankedArray;
//! use merinda::fpga::fixedpoint::FixedFormat;
//!
//! // Two-stage streaming accelerator: a matvec feeding an elementwise op.
//! let fmt = FixedFormat::q8_8();
//! let mut g = Graph::new("demo", fmt, fmt).streaming(true, false).with_io_elems(20);
//! let mv = g.push_op(
//!     Op::matvec("mv", 256)
//!         .unrolled(8)
//!         .with_array(BankedArray::new("w", 256, 16), 1, 0),
//! );
//! let ew = g.push_op(Op::elementwise("scale", 16, 2).unrolled(4));
//! g.connect(mv, ew, 16, 1);
//!
//! let low = lower(&g, &Target::default()).unwrap();
//! assert_eq!(low.stages.len(), 2);
//! assert!(low.cycles > 0 && low.interval <= low.cycles);
//! assert!(low.fits);
//! ```

use super::bram::{BankedArray, BramFifo};
use super::fixedpoint::FixedFormat;
use super::hls::{schedule, ArrayAccess, Binding, LoopNest, ScheduledLoop};
use super::interconnect::DdrModel;
use super::pipeline::{Pipeline, PipelineTiming, Stage};
use super::power::{Activity, PowerModel};
use super::resources::{Device, Resources};
use crate::util::error::{Error, Result};

/// Stage-to-fabric mapping, Table 7's configuration axis. Four-slot by
/// convention (the paper's four-stage designs); graphs with a different
/// op count index it positionally and ignore the tail.
pub type StageMap = [Binding; 4];

/// Short config name like `s1D_s2L_s3L_s4D`.
pub fn stage_map_name(m: &StageMap) -> String {
    format!(
        "s1{}_s2{}_s3{}_s4{}",
        m[0].letter(),
        m[1].letter(),
        m[2].letter(),
        m[3].letter()
    )
}

/// All 16 stage mappings in Table 7's row order.
pub fn all_stage_maps() -> Vec<StageMap> {
    let b = [Binding::Dsp, Binding::Lut];
    let mut out = Vec::with_capacity(16);
    for s1 in b {
        for s2 in b {
            for s3 in b {
                for s4 in b {
                    out.push([s1, s2, s3, s4]);
                }
            }
        }
    }
    out
}

/// The adder-mix axis the tuner sweeps by default: all-DSP, the paper's
/// concurrent D/L/L/D mix, and all LUT-fabric (carry-chain) arithmetic.
pub fn default_stage_maps() -> Vec<StageMap> {
    let d = Binding::Dsp;
    let l = Binding::Lut;
    vec![[d, d, d, d], [d, l, l, d], [l, l, l, l]]
}

/// What kind of work an op performs — decides which annotations
/// [`Graph::validate`] requires it to carry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Dense multiply–accumulate (matvec / GEMM tile): `macs_per_iter > 0`.
    MatVec,
    /// Pointwise arithmetic (adds, muls, divides): `elementwise_per_iter > 0`.
    Elementwise,
    /// Activation-table lookups (sigmoid/tanh/ReLU in LUT RAM):
    /// `activations_per_iter > 0`.
    Nonlinearity,
    /// Accumulating reduction (sum/argmax tree): MAC or elementwise work.
    Reduction,
}

/// One compute node: the per-op resource/latency annotations the HLS
/// scheduler consumes. [`Op::loop_nest`] reconstructs the exact
/// [`LoopNest`] the hand-built accelerators used to build inline, so
/// lowering a graph schedules precisely what the original code did.
#[derive(Clone, Debug)]
pub struct Op {
    pub name: String,
    pub kind: OpKind,
    /// Trip count of the innermost loop before unrolling.
    pub trip: u64,
    /// UNROLL factor (parallel lanes).
    pub unroll: u32,
    /// MAC operations per original iteration.
    pub macs_per_iter: u32,
    /// Non-MAC elementwise ops per original iteration.
    pub elementwise_per_iter: u32,
    /// Activation-table lookups per original iteration.
    pub activations_per_iter: u32,
    /// DSP or LUT fabric for the arithmetic.
    pub binding: Binding,
    /// BRAM tiles the op touches, with per-iteration read/write counts
    /// (these drive the II law).
    pub arrays: Vec<ArrayAccess>,
    /// Fixed-point word width (drives LUT fabric cost).
    pub word_bits: u32,
}

impl Op {
    fn with_kind(name: impl Into<String>, kind: OpKind, trip: u64) -> Op {
        Op {
            name: name.into(),
            kind,
            trip,
            unroll: 1,
            macs_per_iter: 0,
            elementwise_per_iter: 0,
            activations_per_iter: 0,
            binding: Binding::Dsp,
            arrays: Vec::new(),
            word_bits: 16,
        }
    }

    /// A dense MAC op (one MAC per iteration by default).
    pub fn matvec(name: impl Into<String>, trip: u64) -> Op {
        let mut op = Op::with_kind(name, OpKind::MatVec, trip);
        op.macs_per_iter = 1;
        op
    }

    /// A pointwise op performing `per_iter` elementwise operations per
    /// iteration.
    pub fn elementwise(name: impl Into<String>, trip: u64, per_iter: u32) -> Op {
        let mut op = Op::with_kind(name, OpKind::Elementwise, trip);
        op.elementwise_per_iter = per_iter;
        op
    }

    /// An activation-lookup op (one table lookup per iteration by default).
    pub fn nonlinearity(name: impl Into<String>, trip: u64) -> Op {
        let mut op = Op::with_kind(name, OpKind::Nonlinearity, trip);
        op.activations_per_iter = 1;
        op
    }

    /// An accumulating reduction (one MAC per iteration by default).
    pub fn reduction(name: impl Into<String>, trip: u64) -> Op {
        let mut op = Op::with_kind(name, OpKind::Reduction, trip);
        op.macs_per_iter = 1;
        op
    }

    pub fn unrolled(mut self, u: u32) -> Op {
        self.unroll = u.max(1);
        self
    }

    pub fn macs(mut self, m: u32) -> Op {
        self.macs_per_iter = m;
        self
    }

    pub fn elementwise_ops(mut self, e: u32) -> Op {
        self.elementwise_per_iter = e;
        self
    }

    pub fn activations(mut self, a: u32) -> Op {
        self.activations_per_iter = a;
        self
    }

    pub fn bound(mut self, b: Binding) -> Op {
        self.binding = b;
        self
    }

    pub fn with_array(mut self, array: BankedArray, reads: u32, writes: u32) -> Op {
        self.arrays.push(ArrayAccess {
            array,
            reads_per_iter: reads,
            writes_per_iter: writes,
        });
        self
    }

    /// The exact [`LoopNest`] this op schedules as.
    pub fn loop_nest(&self) -> LoopNest {
        LoopNest {
            name: self.name.clone(),
            trip: self.trip,
            unroll: self.unroll,
            macs_per_iter: self.macs_per_iter,
            elementwise_per_iter: self.elementwise_per_iter,
            activations_per_iter: self.activations_per_iter,
            arrays: self.arrays.clone(),
            binding: self.binding,
            word_bits: self.word_bits,
        }
    }
}

/// A producer→consumer dependency between two ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Edge {
    /// Producing op (index into `Graph::ops`).
    pub from: usize,
    /// Consuming op.
    pub to: usize,
    /// Elements carried per item.
    pub elems: u64,
    /// DDR round trips when the graph spills intermediates
    /// (`ddr_spill`): each trip moves `elems` activation words out to
    /// DDR (and a trip of 2 covers out-and-back). Zero for values that
    /// stay in registers.
    pub round_trips: u64,
    /// DATAFLOW FIFO depth override in elements (`None` → the graph's
    /// default `fifo_depth`).
    pub fifo_depth: Option<u32>,
}

/// How items flow through the graph — decides the interval law lowering
/// applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Feed-forward pipeline (the GRU shape): ops overlap under
    /// DATAFLOW, intermediates ride FIFOs or spill to DDR.
    Streaming,
    /// Iterative solver (the LTC shape): every op runs sequentially
    /// `iterations` times per item with a host-sync round trip per
    /// iteration; nothing overlaps across iterations.
    Iterative {
        iterations: u32,
        host_sync_cycles: u64,
    },
}

/// Explicit DDR traffic per item (streaming) or per iteration
/// (iterative) that the op/edge structure does not already imply —
/// the IR's "DDR/BRAM transfer" vocabulary. Element counts are scaled
/// by the graph's activation word width at lowering time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transfer {
    /// `transactions` scattered DMA transactions of `elems_each`
    /// activation words (uncoalesced round trips — each pays the full
    /// DDR latency).
    Scattered { transactions: u64, elems_each: u64 },
    /// One coalesced burst of `elems` activation words.
    Burst { elems: u64 },
}

/// A dataflow-graph accelerator description. Build with
/// [`Graph::new`] + [`Graph::push_op`] + [`Graph::connect`], then
/// compile with [`lower`].
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    /// Fixed-point activation format (FIFO widths, DDR word size).
    pub act_fmt: FixedFormat,
    /// Fixed-point weight format (BRAM tile widths).
    pub weight_fmt: FixedFormat,
    /// DATAFLOW on/off (op overlap; streaming profile only).
    pub dataflow: bool,
    /// Spill edge intermediates to DDR (pre-optimization baseline
    /// behaviour; off when DATAFLOW FIFOs carry them).
    pub ddr_spill: bool,
    /// Default inter-op FIFO depth in elements.
    pub fifo_depth: u32,
    /// Input + output activation words crossing DDR per item.
    pub io_elems: u64,
    pub profile: Profile,
    pub ops: Vec<Op>,
    pub edges: Vec<Edge>,
    /// Extra DDR traffic (per iteration under [`Profile::Iterative`]).
    pub transfers: Vec<Transfer>,
}

impl Graph {
    pub fn new(name: impl Into<String>, act_fmt: FixedFormat, weight_fmt: FixedFormat) -> Graph {
        Graph {
            name: name.into(),
            act_fmt,
            weight_fmt,
            dataflow: false,
            ddr_spill: false,
            fifo_depth: 256,
            io_elems: 0,
            profile: Profile::Streaming,
            ops: Vec::new(),
            edges: Vec::new(),
            transfers: Vec::new(),
        }
    }

    /// Streaming profile with the DATAFLOW / DDR-spill axes set.
    pub fn streaming(mut self, dataflow: bool, ddr_spill: bool) -> Graph {
        self.profile = Profile::Streaming;
        self.dataflow = dataflow;
        self.ddr_spill = ddr_spill;
        self
    }

    /// Iterative-solver profile: ops run sequentially `iterations` times
    /// per item, paying `host_sync_cycles` of PS-side control per
    /// iteration.
    pub fn iterative(mut self, iterations: u32, host_sync_cycles: u64) -> Graph {
        self.profile = Profile::Iterative {
            iterations,
            host_sync_cycles,
        };
        self.dataflow = false;
        self.ddr_spill = false;
        self
    }

    pub fn with_fifo_depth(mut self, depth: u32) -> Graph {
        self.fifo_depth = depth;
        self
    }

    pub fn with_io_elems(mut self, elems: u64) -> Graph {
        self.io_elems = elems;
        self
    }

    /// Append an op, returning its index for [`Graph::connect`].
    pub fn push_op(&mut self, op: Op) -> usize {
        self.ops.push(op);
        self.ops.len() - 1
    }

    /// Connect producer `from` to consumer `to` with `elems` elements
    /// per item and `round_trips` DDR round trips when spilled.
    pub fn connect(&mut self, from: usize, to: usize, elems: u64, round_trips: u64) {
        self.edges.push(Edge {
            from,
            to,
            elems,
            round_trips,
            fifo_depth: None,
        });
    }

    /// Record explicit DDR traffic (see [`Transfer`]).
    pub fn transfer(&mut self, t: Transfer) {
        self.transfers.push(t);
    }

    /// Well-formedness: at least one op, positive trip counts,
    /// kind-consistent annotations, in-range edges, acyclicity, and a
    /// positive iteration count for iterative profiles. Every failure is
    /// a typed [`Error::Config`] naming the offending node.
    pub fn validate(&self) -> Result<()> {
        if self.ops.is_empty() {
            return Err(Error::config(format!("graph {:?} has no ops", self.name)));
        }
        for op in &self.ops {
            if op.trip == 0 {
                return Err(Error::config(format!(
                    "graph {:?}: op {:?} has a zero trip count",
                    self.name, op.name
                )));
            }
            let complete = match op.kind {
                OpKind::MatVec => op.macs_per_iter > 0,
                OpKind::Elementwise => op.elementwise_per_iter > 0,
                OpKind::Nonlinearity => op.activations_per_iter > 0,
                OpKind::Reduction => op.macs_per_iter > 0 || op.elementwise_per_iter > 0,
            };
            if !complete {
                return Err(Error::config(format!(
                    "graph {:?}: {:?} op {:?} is missing its {} annotation",
                    self.name,
                    op.kind,
                    op.name,
                    match op.kind {
                        OpKind::MatVec => "MAC-count",
                        OpKind::Elementwise => "elementwise-count",
                        OpKind::Nonlinearity => "activation-count",
                        OpKind::Reduction => "MAC- or elementwise-count",
                    }
                )));
            }
        }
        let n = self.ops.len();
        for e in &self.edges {
            if e.from >= n || e.to >= n {
                return Err(Error::config(format!(
                    "graph {:?}: edge {}→{} references a missing op (have {n})",
                    self.name, e.from, e.to
                )));
            }
        }
        // Kahn-style elimination; anything left has a cycle through it.
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            indeg[e.to] += 1;
        }
        let mut done = vec![false; n];
        let mut visited = 0;
        let mut progressed = true;
        while progressed {
            progressed = false;
            for i in 0..n {
                if done[i] || indeg[i] != 0 {
                    continue;
                }
                done[i] = true;
                visited += 1;
                progressed = true;
                for e in &self.edges {
                    if e.from == i {
                        indeg[e.to] -= 1;
                    }
                }
            }
        }
        if visited < n {
            let stuck: Vec<&str> = self
                .ops
                .iter()
                .zip(&done)
                .filter(|(_, d)| !**d)
                .map(|(op, _)| op.name.as_str())
                .collect();
            return Err(Error::config(format!(
                "graph {:?} has a dependency cycle through {:?}",
                self.name, stuck
            )));
        }
        if let Profile::Iterative { iterations, .. } = self.profile {
            if iterations == 0 {
                return Err(Error::config(format!(
                    "graph {:?}: iterative profile needs iterations >= 1",
                    self.name
                )));
            }
        }
        Ok(())
    }
}

/// The hardware a graph lowers onto: a device plus the shared DDR and
/// power calibrations. [`Target::default`] is the PYNQ-Z2 with the
/// models every hand-built accelerator used.
#[derive(Clone, Copy, Debug)]
pub struct Target {
    pub device: Device,
    pub ddr: DdrModel,
    pub power: PowerModel,
}

impl Default for Target {
    fn default() -> Self {
        Target::for_device(Device::pynq_z2())
    }
}

impl Target {
    pub fn for_device(device: Device) -> Target {
        Target {
            device,
            ddr: DdrModel::default(),
            power: PowerModel::default(),
        }
    }
}

/// A compiled graph: per-op schedules plus the whole-design cycle,
/// resource, power and fit verdicts — everything the tuner, the
/// placement cost model and the report tables consume.
#[derive(Clone, Debug)]
pub struct LoweredGraph {
    pub name: String,
    /// One scheduled loop per op, in op order.
    pub stages: Vec<ScheduledLoop>,
    /// End-to-end latency for one item.
    pub cycles: u64,
    /// Steady-state spacing between outputs.
    pub interval: u64,
    pub resources: Resources,
    pub power_w: f64,
    pub energy_per_output_j: f64,
    /// Worst achieved initiation interval across ops.
    pub worst_stage_ii: u32,
    /// Design fits the target device.
    pub fits: bool,
    /// DDR cycles charged per item (streaming) or per iteration sweep
    /// (iterative).
    pub ddr_cycles_per_item: u64,
    pub dataflow: bool,
    pub profile: Profile,
    pub act_fmt: FixedFormat,
    /// Timing-closure derate for this design (multiple of the base
    /// clock it can close at) — see [`graph_clock_scale`].
    pub clock_scale: f64,
}

impl LoweredGraph {
    /// The scheduled ops as a stage pipeline, one item per graph
    /// invocation: each stage's service time is both its per-item
    /// initiation interval and its latency.
    pub fn stage_pipeline(&self) -> Pipeline {
        let stages: Vec<Stage> = self
            .stages
            .iter()
            .map(|s| Stage::new(s.name.clone(), s.cycles as u32, s.cycles as u32))
            .collect();
        Pipeline::new(stages)
    }

    /// Cycle-model timing for a `seq`-item window: DATAFLOW graphs
    /// overlap items through the stage pipeline, sequential streaming
    /// graphs drain it per item, and iterative graphs pay the full
    /// interval (compute + DDR + host sync) every item.
    pub fn window_timing(&self, seq: u64) -> PipelineTiming {
        match self.profile {
            Profile::Streaming => {
                let p = self.stage_pipeline();
                if self.dataflow {
                    p.analyze(seq)
                } else {
                    p.analyze_sequential(seq)
                }
            }
            Profile::Iterative { .. } => PipelineTiming {
                total_cycles: seq * self.interval,
                interval: self.interval,
                fill_latency: self.interval,
            },
        }
    }

    /// Report-style window cycles: fill then steady state for streaming
    /// graphs, `seq · interval` for iterative ones.
    pub fn window_cycles(&self, seq: u64) -> u64 {
        if seq == 0 {
            return 0;
        }
        match self.profile {
            Profile::Streaming => self.cycles + (seq - 1) * self.interval,
            Profile::Iterative { .. } => seq * self.interval,
        }
    }
}

/// Highest clock, as a multiple of the target's base clock, a graph can
/// close timing at in this model: carry-chain multipliers on any MAC op
/// cap the clock at base rate, ≥64-lane unroll does the same, and the
/// widest designs (96 lanes or 4-wide BRAM reshape) derate below it.
/// On GRU graphs this agrees exactly with
/// [`tuner::max_clock_scale`](super::tuner::max_clock_scale).
pub fn graph_clock_scale(g: &Graph) -> f64 {
    let lut_macs = g
        .ops
        .iter()
        .any(|o| o.macs_per_iter > 0 && o.binding == Binding::Lut);
    let max_unroll = g.ops.iter().map(|o| o.unroll).max().unwrap_or(1);
    let max_reshape = g
        .ops
        .iter()
        .flat_map(|o| o.arrays.iter())
        .map(|a| a.array.reshape)
        .max()
        .unwrap_or(1);
    let mut scale: f64 = 1.15;
    if lut_macs || max_unroll >= 64 {
        scale = 1.0;
    }
    if max_unroll >= 96 || max_reshape >= 4 {
        scale = 0.9;
    }
    scale
}

/// Compile a graph onto a target: validate, schedule every op through
/// the HLS scheduler, then apply the profile's interval law, charge the
/// DDR traffic, sum resources (FIFOs under DATAFLOW, the DMA/AXI
/// overhead every design pays) and price power/energy.
///
/// # Example
///
/// ```
/// use merinda::fpga::graph::{lower, Target};
/// use merinda::fpga::gru_accel::{GruAccel, GruAccelConfig};
///
/// // Lowering the GRU graph reproduces the hand-built report exactly.
/// let accel = GruAccel::new(GruAccelConfig::concurrent());
/// let low = lower(&accel.graph(), &Target::default()).unwrap();
/// let report = accel.report();
/// assert_eq!(low.cycles, report.cycles);
/// assert_eq!(low.interval, report.interval);
/// assert_eq!(low.resources, report.resources);
/// ```
pub fn lower(g: &Graph, t: &Target) -> Result<LoweredGraph> {
    g.validate()?;
    let stages: Vec<ScheduledLoop> = g.ops.iter().map(|op| schedule(&op.loop_nest())).collect();
    match g.profile {
        Profile::Streaming => lower_streaming(g, t, stages),
        Profile::Iterative {
            iterations,
            host_sync_cycles,
        } => lower_iterative(g, t, stages, iterations, host_sync_cycles),
    }
}

/// Streaming interval law — the GRU report arithmetic, generalized to
/// N ops: DATAFLOW overlaps ops (interval = slowest op + exposed DDR),
/// sequential graphs sum services; spilled edges turn into scattered
/// DMA transactions, FIFO-carried edges into BRAM FIFOs.
fn lower_streaming(g: &Graph, t: &Target, stages: Vec<ScheduledLoop>) -> Result<LoweredGraph> {
    let services: Vec<u64> = stages.iter().map(|s| s.cycles).collect();
    let sum_service: u64 = services.iter().sum();
    let max_service: u64 = *services.iter().max().expect("validated: >=1 op");

    // Per-item DDR traffic: I/O always; spilled edge intermediates too.
    let wb = (g.act_fmt.word_bits as u64).div_ceil(8);
    let io_bytes = g.io_elems * wb;
    let spill_bytes: u64 = g.edges.iter().map(|e| e.elems * e.round_trips * wb).sum();
    let extra_bytes: u64 = g
        .transfers
        .iter()
        .map(|tr| match *tr {
            Transfer::Scattered {
                transactions,
                elems_each,
            } => transactions * elems_each * wb,
            Transfer::Burst { elems } => elems * wb,
        })
        .sum();
    let ddr_bytes = if g.ddr_spill {
        io_bytes + spill_bytes + extra_bytes
    } else {
        io_bytes + extra_bytes
    };

    let n_ops = stages.len() as u64;
    let ddr_cycles = if g.ddr_spill {
        // Scattered small transactions between ops.
        t.ddr.scattered_cycles(n_ops, ddr_bytes / n_ops)
    } else {
        // Streaming: amortized burst, overlapped with compute under
        // DATAFLOW; only the non-overlapped remainder shows up.
        let burst = t.ddr.burst_cycles(ddr_bytes);
        if g.dataflow {
            burst.saturating_sub(max_service).min(burst / 4)
        } else {
            burst
        }
    };

    let (cycles, interval) = if g.dataflow {
        let fifo_skew = 2 * (stages.len() as u64 - 1); // FIFO handshakes
        (
            sum_service + fifo_skew + ddr_cycles,
            max_service + ddr_cycles,
        )
    } else {
        let per_item = sum_service + ddr_cycles;
        (per_item, per_item)
    };

    // Resources: ops + FIFOs (dataflow) + DMA engine + AXI.
    let mut res = Resources::ZERO;
    for s in &stages {
        res += s.resources;
    }
    if g.dataflow {
        for e in &g.edges {
            let depth = e.fifo_depth.unwrap_or(g.fifo_depth) as u64;
            let name = format!("fifo_{}_{}", e.from, e.to);
            res += BramFifo::for_format(name, depth, g.act_fmt).resources();
        }
    }
    // DMA + AXI crossbar + control.
    res += Resources::new(1_800, 2_400, 0, 2);

    // Activity: a stalled pipeline (II>1 or sequential ops) toggles
    // compute less but hammers DDR more.
    let worst_ii = stages.iter().map(|s| s.ii).max().expect("validated: >=1 op");
    let busy = if g.dataflow {
        max_service as f64 / interval.max(1) as f64
    } else {
        // Each op active only its share of the item time.
        sum_service as f64 / (stages.len() as f64 * interval.max(1) as f64)
    };
    let act = Activity {
        dsp: busy / worst_ii as f64,
        lut: 0.35 + 0.25 * busy,
        bram: (0.4 + 0.5 * busy).min(1.0),
        ddr: (ddr_cycles as f64 / interval.max(1) as f64).min(1.0)
            + if g.ddr_spill { 0.55 } else { 0.15 },
    };
    let act = Activity {
        ddr: act.ddr.min(1.0),
        ..act
    };

    let power_w = t.power.watts(&res, &act);
    let energy = t
        .power
        .energy_per_output_j(&res, &act, interval, t.device.clock_mhz);

    Ok(LoweredGraph {
        name: g.name.clone(),
        cycles,
        interval,
        resources: res,
        power_w,
        energy_per_output_j: energy,
        worst_stage_ii: worst_ii,
        fits: t.device.fits(&res),
        ddr_cycles_per_item: ddr_cycles,
        dataflow: g.dataflow,
        profile: g.profile,
        act_fmt: g.act_fmt,
        clock_scale: graph_clock_scale(g),
        stages,
    })
}

/// Iterative interval law — the LTC report arithmetic, generalized: all
/// ops run back-to-back `iterations` times per item, each iteration
/// paying the graph's [`Transfer`] traffic plus the host-sync round
/// trip. Nothing overlaps.
fn lower_iterative(
    g: &Graph,
    t: &Target,
    stages: Vec<ScheduledLoop>,
    iterations: u32,
    host_sync_cycles: u64,
) -> Result<LoweredGraph> {
    let sweep_cycles: u64 = stages.iter().map(|s| s.cycles).sum();
    let mut sweep_res = Resources::ZERO;
    for s in &stages {
        sweep_res += s.resources;
    }
    let cycles = sweep_cycles * iterations as u64;

    let wb = (g.act_fmt.word_bits as u64).div_ceil(8);
    let mut ddr_per_iter = 0u64;
    for tr in &g.transfers {
        ddr_per_iter += match *tr {
            Transfer::Scattered {
                transactions,
                elems_each,
            } => t.ddr.scattered_cycles(transactions, elems_each * wb),
            Transfer::Burst { elems } => t.ddr.burst_cycles(elems * wb),
        };
    }
    let interval = cycles + iterations as u64 * (ddr_per_iter + host_sync_cycles);

    // The same engine is reused across iterations; add the solver
    // sequencing FSM + buffers and the DMA/AXI overhead.
    let mut res = sweep_res;
    res += Resources::new(9_000, 18_000, 4, 2);
    res += Resources::new(1_800, 2_400, 0, 2);

    let worst_ii = stages.iter().map(|s| s.ii).max().expect("validated: >=1 op");
    let busy = cycles as f64 / interval.max(1) as f64;
    let act = Activity {
        dsp: 0.75 * busy,
        lut: 0.35 + 0.3 * busy,
        bram: 0.5,
        ddr: (1.0 - busy).clamp(0.3, 1.0),
    };
    let power_w = t.power.watts(&res, &act);
    let energy = t
        .power
        .energy_per_output_j(&res, &act, interval, t.device.clock_mhz);

    Ok(LoweredGraph {
        name: g.name.clone(),
        cycles,
        interval,
        resources: res,
        power_w,
        energy_per_output_j: energy,
        worst_stage_ii: worst_ii,
        fits: t.device.fits(&res),
        ddr_cycles_per_item: ddr_per_iter,
        dataflow: false,
        profile: g.profile,
        act_fmt: g.act_fmt,
        clock_scale: graph_clock_scale(g),
        stages,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::gru_accel::{GruAccel, GruAccelConfig};
    use crate::fpga::tuner::max_clock_scale;

    fn tiny(dataflow: bool) -> Graph {
        let fmt = FixedFormat::q8_8();
        let mut g = Graph::new("tiny", fmt, fmt)
            .streaming(dataflow, false)
            .with_io_elems(8);
        let a = g.push_op(
            Op::matvec("mv", 256)
                .unrolled(8)
                .with_array(BankedArray::new("w", 256, 16), 1, 0),
        );
        let b = g.push_op(Op::elementwise("ew", 16, 2).unrolled(4));
        g.connect(a, b, 16, 1);
        g
    }

    #[test]
    fn valid_graph_lowers() {
        let low = lower(&tiny(true), &Target::default()).unwrap();
        assert_eq!(low.stages.len(), 2);
        assert!(low.cycles > 0);
        assert!(low.interval <= low.cycles);
        assert!(low.fits);
        assert!(low.power_w > 0.0 && low.energy_per_output_j > 0.0);
    }

    #[test]
    fn empty_graph_rejected() {
        let fmt = FixedFormat::q8_8();
        let g = Graph::new("empty", fmt, fmt);
        assert!(matches!(g.validate(), Err(Error::Config(_))));
    }

    #[test]
    fn zero_trip_rejected() {
        let fmt = FixedFormat::q8_8();
        let mut g = Graph::new("zt", fmt, fmt);
        g.push_op(Op::matvec("mv", 0));
        let err = g.validate().unwrap_err();
        assert!(format!("{err:?}").contains("zero trip"));
    }

    #[test]
    fn annotation_completeness_enforced() {
        let mut g = tiny(true);
        g.ops[0].macs_per_iter = 0; // MatVec op without MACs
        assert!(matches!(g.validate(), Err(Error::Config(_))));
        let mut g = tiny(true);
        g.ops[1].elementwise_per_iter = 0;
        assert!(matches!(g.validate(), Err(Error::Config(_))));
    }

    #[test]
    fn cycle_rejected() {
        let mut g = tiny(true);
        g.connect(1, 0, 16, 1); // back edge: 0→1→0
        let err = g.validate().unwrap_err();
        assert!(format!("{err:?}").contains("cycle"));
    }

    #[test]
    fn self_loop_rejected() {
        let mut g = tiny(true);
        g.connect(0, 0, 4, 1);
        assert!(matches!(g.validate(), Err(Error::Config(_))));
    }

    #[test]
    fn out_of_range_edge_rejected() {
        let mut g = tiny(true);
        g.connect(0, 9, 4, 1);
        let err = g.validate().unwrap_err();
        assert!(format!("{err:?}").contains("missing op"));
    }

    #[test]
    fn iterative_zero_iterations_rejected() {
        let fmt = FixedFormat::q8_8();
        let mut g = Graph::new("it", fmt, fmt).iterative(0, 100);
        g.push_op(Op::matvec("mv", 64));
        assert!(matches!(g.validate(), Err(Error::Config(_))));
    }

    #[test]
    fn dataflow_adds_one_fifo_per_edge() {
        let df = lower(&tiny(true), &Target::default()).unwrap();
        let seq = lower(&tiny(false), &Target::default()).unwrap();
        // One edge → one BRAM FIFO (256 × 16 bits < one BRAM18).
        assert_eq!(df.resources.bram18, seq.resources.bram18 + 1);
    }

    #[test]
    fn sixteen_stage_maps_in_table7_order() {
        let maps = all_stage_maps();
        assert_eq!(maps.len(), 16);
        assert_eq!(stage_map_name(&maps[0]), "s1D_s2D_s3D_s4D");
        assert_eq!(stage_map_name(&maps[15]), "s1L_s2L_s3L_s4L");
        assert_eq!(default_stage_maps().len(), 3);
    }

    #[test]
    fn clock_scale_matches_gru_timing_model() {
        // graph_clock_scale on a GRU graph must agree with the tuner's
        // config-level closure model for every shipped config and the
        // derate-triggering corners.
        let mut cases = vec![
            GruAccelConfig::gru_baseline(),
            GruAccelConfig::concurrent(),
            GruAccelConfig::bram_optimal(),
            GruAccelConfig::concurrent().with_stage_map([Binding::Lut; 4]),
        ];
        let mut wide = GruAccelConfig::base();
        wide.unroll = 64;
        cases.push(wide);
        for cfg in cases {
            let g = GruAccel::new(cfg.clone()).graph();
            assert_eq!(
                graph_clock_scale(&g),
                max_clock_scale(&cfg),
                "{}",
                stage_map_name(&cfg.stage_map)
            );
        }
    }

    #[test]
    fn window_timing_profiles() {
        let df = lower(&tiny(true), &Target::default()).unwrap();
        let services: Vec<u64> = df.stages.iter().map(|s| s.cycles).collect();
        let t = df.window_timing(100);
        assert_eq!(t.interval, *services.iter().max().unwrap());
        assert_eq!(df.window_cycles(0), 0);
        assert_eq!(df.window_cycles(5), df.cycles + 4 * df.interval);
    }
}

//! On-chip BRAM model: dual-port banks, partitioning, reshaping, and
//! per-cycle port arbitration.
//!
//! This is the heart of the paper's low-level contribution (§5.3): a true
//! dual-port BRAM supplies 2 accesses/cycle, so a loop needing R reads per
//! iteration stalls to II ≥ ⌈R/2⌉ unless the array is split into B banks
//! (`ARRAY_PARTITION`), giving 2B ports and II ≥ ⌈R/(2B)⌉. `ARRAY_RESHAPE`
//! instead widens the word so one access fetches `factor` elements.

use super::fixedpoint::FixedFormat;
use super::resources::Resources;

/// How an array is split across banks (HLS `ARRAY_PARTITION` modes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    /// Single bank (no pragma).
    None,
    /// `cyclic factor=B`: element i lives in bank i mod B.
    Cyclic(u32),
    /// `block factor=B`: element i lives in bank i / ceil(N/B).
    Block(u32),
}

impl Partition {
    pub fn banks(&self) -> u32 {
        match self {
            Partition::None => 1,
            Partition::Cyclic(b) | Partition::Block(b) => (*b).max(1),
        }
    }
}

/// A banked on-chip array.
#[derive(Clone, Debug)]
pub struct BankedArray {
    pub name: String,
    /// Total logical elements.
    pub elements: u64,
    /// Element width in bits (fixed-point word width).
    pub elem_bits: u32,
    pub partition: Partition,
    /// `ARRAY_RESHAPE factor`: elements packed per physical word.
    pub reshape: u32,
    /// Ports per bank (BRAM is true dual-port).
    pub ports_per_bank: u32,
}

impl BankedArray {
    pub fn new(name: impl Into<String>, elements: u64, elem_bits: u32) -> BankedArray {
        BankedArray {
            name: name.into(),
            elements,
            elem_bits,
            partition: Partition::None,
            reshape: 1,
            ports_per_bank: 2,
        }
    }

    /// Apply `ARRAY_PARTITION`.
    pub fn partitioned(mut self, p: Partition) -> BankedArray {
        self.partition = p;
        self
    }

    /// Apply `ARRAY_RESHAPE factor=r` (wide-word packing).
    pub fn reshaped(mut self, r: u32) -> BankedArray {
        self.reshape = r.max(1);
        self
    }

    pub fn banks(&self) -> u32 {
        self.partition.banks()
    }

    /// Element accesses deliverable per cycle: ports × words/access.
    pub fn accesses_per_cycle(&self) -> u32 {
        self.banks() * self.ports_per_bank * self.reshape
    }

    /// Initiation interval needed to supply `reads` element reads per loop
    /// iteration — the paper's II ≥ ⌈R / 2B⌉ law (extended by reshape).
    pub fn ii_for_reads(&self, reads: u32) -> u32 {
        if reads == 0 {
            return 1;
        }
        reads.div_ceil(self.accesses_per_cycle()).max(1)
    }

    /// Which bank serves logical element `i`?
    pub fn bank_of(&self, i: u64) -> u32 {
        let b = self.banks() as u64;
        match self.partition {
            Partition::None => 0,
            Partition::Cyclic(_) => (i / self.reshape as u64 % b) as u32,
            Partition::Block(_) => {
                let per = self.elements.div_ceil(b);
                ((i / per).min(b - 1)) as u32
            }
        }
    }

    /// Cycle-accurate arbitration: given one iteration's element indices,
    /// how many cycles until all are served? Each bank serves
    /// `ports_per_bank` *word* accesses per cycle; a word covers `reshape`
    /// consecutive elements, so indices in the same word coalesce.
    pub fn cycles_for_accesses(&self, indices: &[u64]) -> u32 {
        if indices.is_empty() {
            return 0;
        }
        let banks = self.banks() as usize;
        let mut words_per_bank: Vec<std::collections::BTreeSet<u64>> =
            vec![std::collections::BTreeSet::new(); banks];
        for &i in indices {
            let word = i / self.reshape as u64;
            let bank = self.bank_of(i) as usize;
            words_per_bank[bank].insert(word);
        }
        words_per_bank
            .iter()
            .map(|w| (w.len() as u32).div_ceil(self.ports_per_bank))
            .max()
            .unwrap_or(0)
    }

    /// BRAM18 blocks consumed: each bank independently needs
    /// ⌈bits_per_bank / 18 Kb⌉ blocks (and at least one).
    pub fn bram18_blocks(&self) -> u64 {
        let banks = self.banks() as u64;
        let elems_per_bank = self.elements.div_ceil(banks);
        let bits_per_bank = elems_per_bank * self.elem_bits as u64;
        banks * bits_per_bank.div_ceil(18 * 1024).max(1)
    }

    /// Resource bundle (BRAM plus address/decode LUT overhead per bank).
    pub fn resources(&self) -> Resources {
        let banks = self.banks() as u64;
        Resources {
            lut: 12 * banks + 4 * (self.reshape as u64 - 1) * banks,
            ff: 8 * banks,
            dsp: 0,
            bram18: self.bram18_blocks(),
        }
    }
}

/// A BRAM-backed FIFO between DATAFLOW stages (`STREAM ... impl=bram`).
#[derive(Clone, Debug)]
pub struct BramFifo {
    pub name: String,
    pub depth: u64,
    pub elem_bits: u32,
}

impl BramFifo {
    pub fn new(name: impl Into<String>, depth: u64, elem_bits: u32) -> BramFifo {
        BramFifo {
            name: name.into(),
            depth,
            elem_bits,
        }
    }

    /// FIFO whose element width is a fixed-point format's word width —
    /// the common case for the DATAFLOW stream channels between stages.
    pub fn for_format(name: impl Into<String>, depth: u64, fmt: FixedFormat) -> BramFifo {
        BramFifo::new(name, depth, fmt.word_bits)
    }

    pub fn resources(&self) -> Resources {
        let bits = self.depth * self.elem_bits as u64;
        Resources {
            lut: 24,
            ff: 16,
            dsp: 0,
            bram18: bits.div_ceil(18 * 1024).max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ii_law_single_bank() {
        // Paper §5.3.1: R=4, B=1 → II ≥ ⌈4/2⌉ = 2.
        let a = BankedArray::new("w", 1024, 16);
        assert_eq!(a.ii_for_reads(4), 2);
        assert_eq!(a.ii_for_reads(2), 1);
        assert_eq!(a.ii_for_reads(8), 4);
    }

    #[test]
    fn ii_law_banked() {
        // Paper §5.3.1: R=4, B=2 → II = 1; R=8 needs B=4 wait no: 2B=8 ≥ 8.
        let a2 = BankedArray::new("w", 1024, 16).partitioned(Partition::Cyclic(2));
        assert_eq!(a2.ii_for_reads(4), 1);
        let a4 = BankedArray::new("w", 1024, 16).partitioned(Partition::Cyclic(4));
        assert_eq!(a4.ii_for_reads(8), 1);
    }

    #[test]
    fn reshape_multiplies_bandwidth() {
        let a = BankedArray::new("w", 1024, 16).reshaped(4);
        // One dual-port bank of 4-wide words: 8 elements/cycle.
        assert_eq!(a.accesses_per_cycle(), 8);
        assert_eq!(a.ii_for_reads(8), 1);
    }

    #[test]
    fn cyclic_bank_mapping() {
        let a = BankedArray::new("w", 16, 16).partitioned(Partition::Cyclic(4));
        assert_eq!(a.bank_of(0), 0);
        assert_eq!(a.bank_of(1), 1);
        assert_eq!(a.bank_of(5), 1);
        assert_eq!(a.bank_of(7), 3);
    }

    #[test]
    fn block_bank_mapping() {
        let a = BankedArray::new("w", 16, 16).partitioned(Partition::Block(4));
        assert_eq!(a.bank_of(0), 0);
        assert_eq!(a.bank_of(3), 0);
        assert_eq!(a.bank_of(4), 1);
        assert_eq!(a.bank_of(15), 3);
    }

    #[test]
    fn arbitration_matches_ii_law_for_cyclic_unrolled_lanes() {
        // 4 unrolled lanes read consecutive elements each cycle. With
        // cyclic(4) each lane hits its own bank → 1 cycle.
        let a = BankedArray::new("w", 64, 16).partitioned(Partition::Cyclic(4));
        assert_eq!(a.cycles_for_accesses(&[0, 1, 2, 3]), 1);
        // With block(4) partitioning those 4 indices are in one bank → 2.
        let b = BankedArray::new("w", 64, 16).partitioned(Partition::Block(4));
        assert_eq!(b.cycles_for_accesses(&[0, 1, 2, 3]), 2);
    }

    #[test]
    fn coalesced_wide_words() {
        let a = BankedArray::new("w", 64, 16).reshaped(4);
        // Elements 0..4 live in one word → a single port access.
        assert_eq!(a.cycles_for_accesses(&[0, 1, 2, 3]), 1);
        assert_eq!(a.cycles_for_accesses(&[0, 4, 8, 12]), 2); // 4 words, 2 ports
    }

    #[test]
    fn bram_block_accounting() {
        // 1024 × 16-bit = 16 Kb → fits one BRAM18.
        let a = BankedArray::new("w", 1024, 16);
        assert_eq!(a.bram18_blocks(), 1);
        // Banking 4-way forces 4 physical blocks even if underfilled.
        let b = BankedArray::new("w", 1024, 16).partitioned(Partition::Cyclic(4));
        assert_eq!(b.bram18_blocks(), 4);
    }

    #[test]
    fn fifo_resources() {
        let f = BramFifo::new("r_pre", 256, 16);
        assert_eq!(f.resources().bram18, 1);
    }

    #[test]
    fn fifo_for_format_uses_word_width() {
        let f = BramFifo::for_format("z_pre", 256, FixedFormat::q8_8());
        assert_eq!(f.elem_bits, 16);
        assert_eq!(f.resources().bram18, 1);
    }
}

//! FPGA resource accounting and device capacity model.
//!
//! Resources are the four currencies of the paper's evaluation (Tables 7/8):
//! LUTs, flip-flops, DSP48 slices and BRAM18 blocks. The device model is
//! the PYNQ-Z2's Zynq-7020 fabric (§6.2). Note the paper's BRAM-optimal
//! design (276 k LUTs) exceeds the 7020 — those rows are HLS synthesis
//! estimates, and our simulator reports the same kind of estimate plus an
//! explicit `fits()` check.

use std::fmt;
use std::ops::{Add, AddAssign};

/// Bytes of storage in one BRAM18 block (18 Kb).
pub const BRAM18_BYTES: u64 = 18 * 1024 / 8;

/// A bundle of fabric resources.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    /// 18 Kb BRAM blocks (a BRAM36 counts as two).
    pub bram18: u64,
}

impl Resources {
    pub const ZERO: Resources = Resources {
        lut: 0,
        ff: 0,
        dsp: 0,
        bram18: 0,
    };

    pub fn new(lut: u64, ff: u64, dsp: u64, bram18: u64) -> Resources {
        Resources {
            lut,
            ff,
            dsp,
            bram18,
        }
    }

    /// Scale all fields by an integer factor (unrolling replicas).
    pub fn scaled(&self, k: u64) -> Resources {
        Resources {
            lut: self.lut * k,
            ff: self.ff * k,
            dsp: self.dsp * k,
            bram18: self.bram18 * k,
        }
    }

    /// Component-wise max (for mutually exclusive resource phases).
    pub fn max(&self, other: &Resources) -> Resources {
        Resources {
            lut: self.lut.max(other.lut),
            ff: self.ff.max(other.ff),
            dsp: self.dsp.max(other.dsp),
            bram18: self.bram18.max(other.bram18),
        }
    }

    /// Component-wise saturating subtraction (free capacity after a
    /// design is placed; an overflowing class reads as zero headroom).
    pub fn saturating_sub(&self, other: &Resources) -> Resources {
        Resources {
            lut: self.lut.saturating_sub(other.lut),
            ff: self.ff.saturating_sub(other.ff),
            dsp: self.dsp.saturating_sub(other.dsp),
            bram18: self.bram18.saturating_sub(other.bram18),
        }
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            dsp: self.dsp + o.dsp,
            bram18: self.bram18 + o.bram18,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        *self = *self + o;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "LUT={} FF={} DSP={} BRAM18={}",
            self.lut, self.ff, self.dsp, self.bram18
        )
    }
}

/// An FPGA device's capacity.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub capacity: Resources,
    /// Default PL clock in MHz (paper drives 150–200 MHz).
    pub clock_mhz: f64,
}

impl Device {
    /// PYNQ-Z2 / Zynq XC7Z020: 53 200 LUTs, 106 400 FFs, 220 DSP48E1,
    /// 140 BRAM36 (= 280 BRAM18).
    pub fn pynq_z2() -> Device {
        Device {
            name: "PYNQ-Z2 (Zynq-7020)",
            capacity: Resources::new(53_200, 106_400, 220, 280),
            clock_mhz: 173.0, // paper Table 5 FPGA frequency for MR
        }
    }

    /// A larger Ultrascale+ part for headroom studies (ZU7EV-class).
    pub fn zu7ev() -> Device {
        Device {
            name: "Zynq UltraScale+ ZU7EV",
            capacity: Resources::new(230_400, 460_800, 1_728, 624),
            clock_mhz: 300.0,
        }
    }

    /// The same device retargeted to a different PL clock (the tuner's
    /// clock axis; capacity is unchanged).
    pub fn with_clock(self, clock_mhz: f64) -> Device {
        Device { clock_mhz, ..self }
    }

    /// Fabric left over once a design consuming `used` is placed.
    pub fn free(&self, used: &Resources) -> Resources {
        self.capacity.saturating_sub(used)
    }

    /// How many `payload_bytes`-sized windows the BRAM left after `used`
    /// can hold *double-buffered* (the streaming concurrency currency —
    /// 0 means no headroom at all). Callers decide how to clamp: the
    /// placement layer admits at least one window per fitting board,
    /// while the tuner treats 0 as an infeasible design point.
    pub fn double_buffer_windows(&self, used: &Resources, payload_bytes: u64) -> usize {
        let free_bytes = self.free(used).bram18 * BRAM18_BYTES;
        (free_bytes / (2 * payload_bytes).max(1)) as usize
    }

    /// Does a design fit this device?
    pub fn fits(&self, used: &Resources) -> bool {
        used.lut <= self.capacity.lut
            && used.ff <= self.capacity.ff
            && used.dsp <= self.capacity.dsp
            && used.bram18 <= self.capacity.bram18
    }

    /// Peak utilization fraction across resource classes (>1 = overflow).
    pub fn utilization(&self, used: &Resources) -> f64 {
        let frac = |u: u64, c: u64| u as f64 / c as f64;
        frac(used.lut, self.capacity.lut)
            .max(frac(used.ff, self.capacity.ff))
            .max(frac(used.dsp, self.capacity.dsp))
            .max(frac(used.bram18, self.capacity.bram18))
    }

    /// Clock period in nanoseconds.
    pub fn period_ns(&self) -> f64 {
        1_000.0 / self.clock_mhz
    }

    /// Convert a cycle count to seconds at this device's clock.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 * self.period_ns() * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_scale() {
        let a = Resources::new(10, 20, 3, 1);
        let b = Resources::new(5, 5, 1, 0);
        let c = a + b;
        assert_eq!(c, Resources::new(15, 25, 4, 1));
        assert_eq!(a.scaled(2), Resources::new(20, 40, 6, 2));
    }

    #[test]
    fn pynq_fits_small_design() {
        let d = Device::pynq_z2();
        assert!(d.fits(&Resources::new(10_000, 15_000, 44, 14)));
        // The paper's BRAM-optimal row must NOT fit (276 047 LUTs).
        assert!(!d.fits(&Resources::new(276_047, 130_106, 524, 36)));
    }

    #[test]
    fn utilization_peaks_on_binding_resource() {
        let d = Device::pynq_z2();
        let u = d.utilization(&Resources::new(0, 0, 220, 0));
        assert!((u - 1.0).abs() < 1e-12);
        assert!(d.utilization(&Resources::new(0, 0, 440, 0)) > 1.0);
    }

    #[test]
    fn cycle_timing() {
        let d = Device::pynq_z2();
        let s = d.cycles_to_seconds(173_000_000);
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn component_max() {
        let a = Resources::new(10, 0, 5, 0);
        let b = Resources::new(3, 7, 1, 2);
        assert_eq!(a.max(&b), Resources::new(10, 7, 5, 2));
    }

    #[test]
    fn saturating_sub_never_underflows() {
        let a = Resources::new(10, 5, 3, 2);
        let b = Resources::new(4, 9, 3, 1);
        assert_eq!(a.saturating_sub(&b), Resources::new(6, 0, 0, 1));
    }

    #[test]
    fn with_clock_keeps_capacity() {
        let d = Device::pynq_z2().with_clock(100.0);
        assert!((d.clock_mhz - 100.0).abs() < 1e-12);
        assert_eq!(d.capacity.lut, Device::pynq_z2().capacity.lut);
    }

    #[test]
    fn double_buffer_windows_counts_free_bram() {
        let d = Device::pynq_z2();
        // 278 free BRAM18 after a 2-block design; 1 KiB payloads need
        // 2 KiB double-buffered each.
        let used = Resources::new(0, 0, 0, 2);
        let free_bytes = 278 * BRAM18_BYTES;
        assert_eq!(
            d.double_buffer_windows(&used, 1024),
            (free_bytes / 2048) as usize
        );
        // A design eating all BRAM leaves no headroom.
        assert_eq!(d.double_buffer_windows(&Resources::new(0, 0, 0, 280), 1024), 0);
        // Zero payload never divides by zero.
        assert!(d.double_buffer_windows(&used, 0) > 0);
    }
}

//! Activity-based power and energy model.
//!
//! Calibrated once against the paper's Table 8 operating points (see
//! DESIGN.md §7) and then held fixed for every other experiment. The
//! structure is the usual FPGA decomposition:
//!
//! `P = P_static+PS + e_dsp·DSPs·α_dsp + e_lut·LUTs·α_lut
//!      + e_bram·BRAMs·α_bram + P_ddr·u_ddr`
//!
//! where the α are activity factors derived from the schedule (a stalled
//! pipeline toggles less) and `u_ddr` is DDR bus utilization.

use super::resources::Resources;

/// Per-resource activity factors for a running design.
#[derive(Clone, Copy, Debug)]
pub struct Activity {
    /// Fraction of cycles each DSP does useful work (1.0 at II=1).
    pub dsp: f64,
    /// LUT toggle activity (0..1).
    pub lut: f64,
    /// BRAM port utilization (0..1).
    pub bram: f64,
    /// DDR bus utilization (0..1).
    pub ddr: f64,
}

impl Activity {
    pub fn idle() -> Activity {
        Activity {
            dsp: 0.0,
            lut: 0.0,
            bram: 0.0,
            ddr: 0.0,
        }
    }
}

/// Calibrated power model (PYNQ-Z2 class device at ~173 MHz).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// PL static + PS (ARM cores, DDR controller idle) watts.
    pub base_w: f64,
    /// Watts per fully-active DSP slice.
    pub w_per_dsp: f64,
    /// Watts per fully-toggling LUT.
    pub w_per_lut: f64,
    /// Watts per BRAM18 with both ports active.
    pub w_per_bram18: f64,
    /// Watts of a fully-utilized DDR interface.
    pub ddr_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Calibration: see DESIGN.md §7 / EXPERIMENTS.md Table 8 notes.
        PowerModel {
            base_w: 1.70,
            w_per_dsp: 1.2e-3,
            w_per_lut: 6.0e-6,
            w_per_bram18: 12.0e-3,
            ddr_w: 2.9,
        }
    }
}

impl PowerModel {
    /// Total watts for a design with the given resources and activity.
    pub fn watts(&self, res: &Resources, act: &Activity) -> f64 {
        self.base_w
            + self.w_per_dsp * res.dsp as f64 * act.dsp
            + self.w_per_lut * res.lut as f64 * act.lut
            + self.w_per_bram18 * res.bram18 as f64 * act.bram
            + self.ddr_w * act.ddr
    }

    /// Energy per output item in joules: P × interval × clock period.
    pub fn energy_per_output_j(
        &self,
        res: &Resources,
        act: &Activity,
        interval_cycles: u64,
        clock_mhz: f64,
    ) -> f64 {
        let p = self.watts(res, act);
        energy_j(p, interval_cycles, clock_mhz)
    }
}

/// Joules consumed running at `watts` for `cycles` at `clock_mhz` — the
/// per-window energy the design-space tuner (`fpga::tuner`) scores
/// candidates with (a whole recovery window rather than one output).
pub fn energy_j(watts: f64, cycles: u64, clock_mhz: f64) -> f64 {
    watts * cycles as f64 / (clock_mhz * 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full() -> Activity {
        Activity {
            dsp: 1.0,
            lut: 1.0,
            bram: 1.0,
            ddr: 1.0,
        }
    }

    #[test]
    fn idle_design_draws_base_power() {
        let m = PowerModel::default();
        let r = Resources::new(20_000, 30_000, 100, 10);
        assert!((m.watts(&r, &Activity::idle()) - m.base_w).abs() < 1e-12);
    }

    #[test]
    fn power_monotone_in_resources() {
        let m = PowerModel::default();
        let small = Resources::new(10_000, 0, 50, 5);
        let big = Resources::new(100_000, 0, 500, 20);
        assert!(m.watts(&big, &full()) > m.watts(&small, &full()));
    }

    #[test]
    fn energy_j_is_watts_times_seconds() {
        // 2 W for 173e6 cycles at 173 MHz = 1 s = 2 J.
        assert!((energy_j(2.0, 173_000_000, 173.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn energy_proportional_to_interval() {
        let m = PowerModel::default();
        let r = Resources::new(20_000, 0, 168, 10);
        let a = full();
        let e1 = m.energy_per_output_j(&r, &a, 100, 173.0);
        let e2 = m.energy_per_output_j(&r, &a, 200, 173.0);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_band_concurrent_gru() {
        // Concurrent GRU (Table 8): 19480 LUT, 168 DSP, 10 BRAM, on-chip
        // streaming (low DDR). Paper: 3.013 W. Model must land within 20%.
        let m = PowerModel::default();
        let r = Resources::new(19_480, 17_150, 168, 10);
        let a = Activity {
            dsp: 0.9,
            lut: 0.5,
            bram: 0.8,
            ddr: 0.25,
        };
        let w = m.watts(&r, &a);
        assert!((w - 3.013).abs() / 3.013 < 0.2, "w={w}");
    }

    #[test]
    fn calibration_band_ltc() {
        // LTC (Table 8): 27368 LUT, 49 DSP, 5 BRAM, DDR-thrashing solver.
        // Paper: 5.11 W.
        let m = PowerModel::default();
        let r = Resources::new(27_368, 39_281, 49, 5);
        let a = Activity {
            dsp: 0.6,
            lut: 0.6,
            bram: 0.7,
            ddr: 1.0,
        };
        let w = m.watts(&r, &a);
        assert!((w - 5.11).abs() / 5.11 < 0.2, "w={w}");
    }
}

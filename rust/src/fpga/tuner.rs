//! Hardware design-space autotuner: the search the paper runs by hand.
//!
//! MERINDA's headline numbers come from co-design — BRAM tiling, the
//! fixed-point format sweet spot, DSP-vs-carry-chain adder mixes and the
//! achievable clock are chosen *per board* (§5, Tables 7–8; the
//! follow-up edge paper frames the same search under explicit resource
//! budgets). This module automates that search: [`tune_board`] sweeps
//! tile size (UNROLL × banking × reshape) × fixed-point format preset ×
//! adder mix (DSP slices vs LUT-fabric/carry-chain, the Table 7 axis) ×
//! PL clock over one [`BoardSpec`], scores every candidate with the
//! existing models — the [`Pipeline`](super::pipeline::Pipeline) cycle
//! model for window time, [`Device::fits`](super::resources::Device) for
//! the fabric budget, the calibrated [`power`](super::power) model for
//! watts — and returns the feasible Pareto front plus one
//! [`TunedConfig`]: the fastest design that fits the device *with BRAM
//! double-buffering headroom* for at least one in-flight window.
//!
//! Three so-far-descriptive models (resources, power, cycles) become
//! optimization inputs here: `coordinator::placement` derives fleet cost
//! models from tuner output (`InstanceSpec::from_tuned`), `merinda tune`
//! emits the gated `BENCH_tune.json`, and `merinda soak --tuned` runs
//! the streaming fleet at the tuned operating points.
//!
//! The search is not GRU-specific: [`tune_graph`] runs the same sweep
//! over *any* accelerator family expressed in the
//! [`graph`](super::graph) IR — a closure maps each [`DesignPoint`]
//! (tile × format × adder mix × DATAFLOW) to a graph, lowering scores
//! it, and the selection/Pareto machinery is shared. [`tune_board`] is
//! the GRU-family instance of that search, kept as the `BoardSpec`-level
//! entry point the CLI and placement consume.
//!
//! # Example
//!
//! ```
//! use merinda::fpga::cluster::heterogeneous_fleet;
//! use merinda::fpga::tuner::{tune_fleet, TunerOptions};
//!
//! let fleet = heterogeneous_fleet(4, 32);
//! let outcomes = tune_fleet(&fleet, &TunerOptions::default());
//! // Every canonical board gets a fitting, never-slower configuration.
//! for out in outcomes.into_iter().map(Result::unwrap) {
//!     assert!(out.chosen.window_cycles <= out.default_window_cycles);
//! }
//! ```

use std::cmp::Ordering;

use super::cluster::{window_payload_bytes, BoardSpec};
use super::fixedpoint::FixedFormat;
use super::graph::{lower, Graph, LoweredGraph, StageMap, Target};
use super::gru_accel::GruAccelConfig;
use super::hls::Binding;
use super::power::energy_j;
use super::resources::Resources;
use crate::util::error::{Error, Result};

/// One tiling preset: MAC lanes per stage plus the BRAM banking /
/// reshaping that feeds them (the II law decides whether the lanes
/// actually stream at full rate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    /// UNROLL factor (parallel MAC lanes per matvec stage).
    pub unroll: u32,
    /// ARRAY_PARTITION factor on the weight arrays.
    pub banks: u32,
    /// ARRAY_RESHAPE factor (wide words).
    pub reshape: u32,
}

impl Tile {
    pub fn new(unroll: u32, banks: u32, reshape: u32) -> Tile {
        Tile {
            unroll,
            banks,
            reshape,
        }
    }
}

/// A named activation/weight fixed-point pairing (mirrors the serving
/// presets of `coordinator::FixedPointConfig`, which lives a layer up).
#[derive(Clone, Copy, Debug)]
pub struct FormatPreset {
    pub name: &'static str,
    pub act: FixedFormat,
    pub weight: FixedFormat,
}

fn preset(name: &'static str, act: FixedFormat, weight: FixedFormat) -> FormatPreset {
    FormatPreset { name, act, weight }
}

/// The three serving format presets: `q8.8`, `q4.8`, `8bit`.
pub fn default_formats() -> Vec<FormatPreset> {
    vec![
        preset("q8.8", FixedFormat::q8_8(), FixedFormat::q8_8()),
        preset("q4.8", FixedFormat::q4_8(), FixedFormat::q4_8()),
        preset("8bit", FixedFormat::new(8, 4), FixedFormat::new(8, 4)),
    ]
}

/// Tiling ladder from the paper's sweep: baseline through BRAM-optimal.
pub fn default_tiles() -> Vec<Tile> {
    vec![
        Tile::new(8, 2, 1),
        Tile::new(16, 4, 1),
        Tile::new(32, 8, 1),
        Tile::new(32, 16, 1),
        Tile::new(64, 32, 1),
        Tile::new(96, 32, 4),
    ]
}

// The adder-mix axis lives with the rest of the stage-map vocabulary in
// the graph IR; re-exported here so existing tuner imports keep working.
pub use super::graph::default_stage_maps;

/// Highest clock, as a multiple of the board's base clock, a design can
/// close timing at in this model: carry-chain multipliers on the matvec
/// stages (s1/s3 bound to LUT fabric) cap the clock at base rate, wide
/// unroll fanout does the same, and the widest tiles (96 lanes or 4-wide
/// reshape) derate below it.
pub fn max_clock_scale(cfg: &GruAccelConfig) -> f64 {
    let lut_macs = cfg.stage_map[0] == Binding::Lut || cfg.stage_map[2] == Binding::Lut;
    let mut scale: f64 = 1.15;
    if lut_macs || cfg.unroll >= 64 {
        scale = 1.0;
    }
    if cfg.unroll >= 96 || cfg.reshape >= 4 {
        scale = 0.9;
    }
    scale
}

/// Search-space and constraint knobs for [`tune_board`].
#[derive(Clone, Debug)]
pub struct TunerOptions {
    /// Recovery window length in GRU steps (the serving window).
    pub window: usize,
    /// Per-sample state rows crossing the link (payload model).
    pub xdim: usize,
    /// Per-sample input rows crossing the link.
    pub udim: usize,
    /// Θ coefficients returned per window.
    pub theta_len: usize,
    /// Tiling candidates (UNROLL × banks × reshape).
    pub tiles: Vec<Tile>,
    /// Fixed-point format presets to sweep.
    pub formats: Vec<FormatPreset>,
    /// Stage-to-fabric adder mixes to sweep.
    pub stage_maps: Vec<StageMap>,
    /// Clock candidates as multiples of the board's base clock.
    pub clock_scales: Vec<f64>,
    /// Also evaluate every point with DATAFLOW off (DDR-spill baseline).
    pub sweep_dataflow: bool,
    /// Fidelity floor: formats with fewer fractional bits are rejected
    /// (the paper's "preserving fidelity" bar sits at 8 — Q8.8).
    pub min_frac_bits: u32,
    /// Optional power budget in watts (the edge-constrained search of
    /// the follow-up paper); `None` leaves power as a score only.
    pub max_power_w: Option<f64>,
}

impl Default for TunerOptions {
    fn default() -> Self {
        TunerOptions {
            // Canonical serving window and payload dims (64-step windows
            // of 3 state + 1 input rows, 45 Θ coefficients).
            window: 64,
            xdim: 3,
            udim: 1,
            theta_len: 45,
            tiles: default_tiles(),
            formats: default_formats(),
            stage_maps: default_stage_maps(),
            clock_scales: vec![0.85, 1.0, 1.15],
            sweep_dataflow: true,
            min_frac_bits: 8,
            max_power_w: None,
        }
    }
}

/// One evaluated design point: the configuration, its modeled window
/// timing/power at the candidate clock, and every feasibility verdict
/// separately (so infeasible points are explainable, not just absent).
#[derive(Clone, Debug)]
pub struct TuneCandidate {
    /// The accelerator configuration evaluated.
    pub cfg: GruAccelConfig,
    /// PL clock this point runs at (MHz).
    pub clock_mhz: f64,
    /// Cycle-model cycles for one recovery window.
    pub window_cycles: u64,
    /// Steady-state cycles between window outputs.
    pub interval: u64,
    /// `window_cycles` at `clock_mhz`, in seconds — the speed score.
    pub window_s: f64,
    /// Modeled power draw (W) — the second Pareto axis.
    pub power_w: f64,
    /// Energy for one full window (J).
    pub energy_per_window_j: f64,
    /// Fabric the design consumes.
    pub resources: Resources,
    /// Design fits the board's device capacity.
    pub fits: bool,
    /// Free BRAM can double-buffer at least one window payload.
    pub headroom_ok: bool,
    /// `clock_mhz` is within the design's timing-closure model.
    pub clock_ok: bool,
    /// Formats meet the fidelity floor (`min_frac_bits`).
    pub fidelity_ok: bool,
    /// Within the optional power budget.
    pub power_ok: bool,
    /// Concurrent windows the free BRAM double-buffers (capped at 512).
    pub max_outstanding: usize,
    /// Format preset name (`q8.8`, `q4.8`, `8bit`, `custom`).
    pub format: &'static str,
}

impl TuneCandidate {
    /// All feasibility verdicts at once — the Pareto/selection filter.
    pub fn feasible(&self) -> bool {
        self.fits && self.headroom_ok && self.clock_ok && self.fidelity_ok && self.power_ok
    }
}

/// The tuner's pick for one board: the fastest feasible design point,
/// never slower (in cycles) than the board's shipped configuration, as a
/// ready-to-deploy [`BoardSpec`].
///
/// # Example
///
/// ```
/// use merinda::coordinator::placement::InstanceSpec;
/// use merinda::fpga::cluster::heterogeneous_fleet;
/// use merinda::fpga::tuner::{tune_board, TunerOptions};
///
/// let board = heterogeneous_fleet(4, 32).remove(0);
/// let tuned = tune_board(&board, &TunerOptions::default()).unwrap().chosen;
/// // Feed the tuned operating point straight into fleet placement:
/// let model = InstanceSpec::from_tuned(&tuned).model(64, 3, 1, 45);
/// assert!(model.fits && model.max_outstanding >= 1);
/// assert_eq!(model.window_cycles, tuned.window_cycles);
/// ```
#[derive(Clone, Debug)]
pub struct TunedConfig {
    /// The board retargeted to the chosen design and clock — hand this
    /// to `coordinator::placement::InstanceSpec` (or use
    /// `InstanceSpec::from_tuned`) to derive the fleet cost model.
    pub board: BoardSpec,
    /// Chosen PL clock (MHz).
    pub clock_mhz: f64,
    /// Window length the search was scored at.
    pub window: usize,
    /// Modeled cycles per window at the chosen design.
    pub window_cycles: u64,
    /// Seconds per window at the chosen clock.
    pub window_s: f64,
    /// Modeled power draw (W).
    pub power_w: f64,
    /// Energy per window (J).
    pub energy_per_window_j: f64,
    /// Fabric consumed.
    pub resources: Resources,
    /// BRAM double-buffering concurrency budget (≥ 1 by construction).
    pub max_outstanding: usize,
    /// Format preset name.
    pub format: &'static str,
    /// Cycles per window of the board's shipped configuration.
    pub default_window_cycles: u64,
}

impl TunedConfig {
    /// Cycle-count speedup over the board's shipped configuration
    /// (≥ 1.0 whenever the shipped design was itself feasible).
    pub fn speedup_vs_default(&self) -> f64 {
        if self.window_cycles == 0 {
            return 1.0;
        }
        self.default_window_cycles as f64 / self.window_cycles as f64
    }
}

/// Everything [`tune_board`] learned about one board.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// Board the search ran over.
    pub board_name: String,
    /// Design points evaluated (grid + the shipped configuration).
    pub evaluated: usize,
    /// How many of them were feasible.
    pub feasible: usize,
    /// Whether the shipped configuration itself was feasible (when it
    /// is, `chosen` is constrained to never regress its cycle count).
    pub default_feasible: bool,
    /// Cycles per window of the shipped configuration.
    pub default_window_cycles: u64,
    /// Seconds per window of the shipped configuration at base clock.
    pub default_window_s: f64,
    /// Power draw of the shipped configuration (W).
    pub default_power_w: f64,
    /// The selected operating point.
    pub chosen: TunedConfig,
    pareto: Vec<TuneCandidate>,
}

impl TuneOutcome {
    /// The feasible Pareto front over (window seconds, watts), fastest
    /// first: along the iteration window time never decreases and power
    /// strictly decreases — every step slower must buy power back.
    ///
    /// # Example
    ///
    /// ```
    /// use merinda::fpga::cluster::heterogeneous_fleet;
    /// use merinda::fpga::tuner::{tune_board, TunerOptions};
    ///
    /// let board = heterogeneous_fleet(4, 32).remove(2);
    /// let out = tune_board(&board, &TunerOptions::default()).unwrap();
    /// let front: Vec<_> = out.pareto().collect();
    /// assert!(!front.is_empty());
    /// for pair in front.windows(2) {
    ///     assert!(pair[0].window_s <= pair[1].window_s);
    ///     assert!(pair[0].power_w > pair[1].power_w);
    /// }
    /// ```
    pub fn pareto(&self) -> std::slice::Iter<'_, TuneCandidate> {
        self.pareto.iter()
    }
}

/// Match a format pair back to its preset name for reporting.
fn format_label(act: FixedFormat, weight: FixedFormat) -> &'static str {
    for p in default_formats() {
        if act == p.act && weight == p.weight {
            return p.name;
        }
    }
    "custom"
}

/// Score one configuration on one board, emitting one candidate per
/// clock. The schedule, resources, cycle counts, power and budgets are
/// clock-independent, so the expensive evaluation runs once per design
/// and only the seconds/energy/closure verdicts vary per clock. Timing
/// comes from [`BoardSpec::window_timing`] — the exact helper the
/// placement cost model uses — so tuner scores and fleet cost models
/// can never diverge.
fn evaluate(
    board: &BoardSpec,
    cfg: GruAccelConfig,
    clocks: &[f64],
    opts: &TunerOptions,
    format: &'static str,
    out: &mut Vec<TuneCandidate>,
) {
    // The board running this design (at base clock — cycles and fabric
    // are clock-independent; per-clock values are derived below).
    let design = board.retargeted(cfg, board.device.clock_mhz);
    let report = design.report();
    let timing = design.window_timing(opts.window as u64);
    let payload = window_payload_bytes(
        &design.cfg.act_fmt,
        opts.window,
        opts.xdim,
        opts.udim,
        opts.theta_len,
    );
    let budget = board.device.double_buffer_windows(&report.resources, payload);
    let fidelity_ok = design.cfg.act_fmt.frac_bits >= opts.min_frac_bits
        && design.cfg.weight_fmt.frac_bits >= opts.min_frac_bits;
    let power_ok = match opts.max_power_w {
        Some(cap) => report.power_w <= cap,
        None => true,
    };
    let max_clock = board.device.clock_mhz * max_clock_scale(&design.cfg);
    for &clock_mhz in clocks {
        let device = board.device.with_clock(clock_mhz);
        out.push(TuneCandidate {
            cfg: design.cfg.clone(),
            clock_mhz,
            window_cycles: timing.total_cycles,
            interval: timing.interval,
            window_s: device.cycles_to_seconds(timing.total_cycles),
            power_w: report.power_w,
            energy_per_window_j: energy_j(report.power_w, timing.total_cycles, clock_mhz),
            resources: report.resources,
            fits: board.device.fits(&report.resources),
            headroom_ok: budget >= 1,
            clock_ok: clock_mhz <= max_clock + 1e-9,
            fidelity_ok,
            power_ok,
            max_outstanding: budget.min(512),
            format,
        });
    }
}

/// Total order over possibly-NaN scores (NaN compares equal).
fn cmp_f64(a: f64, b: f64) -> Ordering {
    a.partial_cmp(&b).unwrap_or(Ordering::Equal)
}

/// Speed-then-power ordering over `(window_s, power_w)` keys (ties
/// resolve toward lower power) — shared by the board-level and
/// graph-level searches.
fn cmp_speed_power_key(a: (f64, f64), b: (f64, f64)) -> Ordering {
    cmp_f64(a.0, b.0).then(cmp_f64(a.1, b.1))
}

/// Speed-then-power ordering (ties resolve toward lower power).
fn cmp_speed_power(a: &TuneCandidate, b: &TuneCandidate) -> Ordering {
    cmp_speed_power_key((a.window_s, a.power_w), (b.window_s, b.power_w))
}

/// Why a search came up empty: every constraint rejection counted
/// separately, so the `Error::config` a dry search returns names the
/// binding constraint instead of a silent absence.
///
/// Shared with the partitioned sweep (`fpga::partition::best_partition`),
/// which must pass fit and timing closure as *separate* verdicts: a
/// split candidate that fits the fabric but cannot close timing at a
/// member board's clock is a `clock_fail`, never an `unfit` — collapsing
/// the two would misreport a clock-derated split as not fitting.
#[derive(Default)]
pub(crate) struct FeasibilityTally {
    evaluated: usize,
    unfit: usize,
    no_headroom: usize,
    clock_fail: usize,
    low_fidelity: usize,
    over_power: usize,
}

impl FeasibilityTally {
    pub(crate) fn add(
        &mut self,
        fits: bool,
        headroom: bool,
        clock: bool,
        fidelity: bool,
        power: bool,
    ) {
        self.evaluated += 1;
        self.unfit += usize::from(!fits);
        self.no_headroom += usize::from(!headroom);
        self.clock_fail += usize::from(!clock);
        self.low_fidelity += usize::from(!fidelity);
        self.over_power += usize::from(!power);
    }

    pub(crate) fn error(&self, name: &str) -> Error {
        Error::config(format!(
            "no feasible design point for {name}: {} candidates evaluated \
             ({} over the fabric budget, {} without BRAM double-buffer headroom, \
             {} failing timing closure, {} below the fidelity floor, \
             {} over the power budget)",
            self.evaluated,
            self.unfit,
            self.no_headroom,
            self.clock_fail,
            self.low_fidelity,
            self.over_power
        ))
    }
}

/// Exhaustively sweep the design space for one board and pick its
/// operating point. Fails with a typed [`Error::Config`] — naming the
/// binding constraint — only when no design point satisfies every
/// constraint (fit, BRAM double-buffer headroom, timing closure,
/// fidelity floor, optional power budget).
///
/// The board's shipped configuration is always evaluated as a candidate;
/// whenever it is feasible, the chosen config is additionally
/// constrained to `window_cycles ≤` the shipped design's, so tuning can
/// only speed a board up in the machine-independent cycle currency that
/// placement and CI gate on.
///
/// # Example
///
/// ```
/// use merinda::fpga::cluster::heterogeneous_fleet;
/// use merinda::fpga::tuner::{tune_board, TunerOptions};
///
/// // The sequential PYNQ ships without DATAFLOW; the tuner finds the
/// // overlapped design — a strict cycle-count win.
/// let board = heterogeneous_fleet(4, 32).remove(1);
/// let out = tune_board(&board, &TunerOptions::default()).unwrap();
/// assert!(out.chosen.board.cfg.dataflow);
/// assert!(out.chosen.speedup_vs_default() > 1.0);
/// ```
pub fn tune_board(board: &BoardSpec, opts: &TunerOptions) -> Result<TuneOutcome> {
    assert!(opts.window > 0, "tuner needs a non-empty window");
    let default_timing = board.window_timing(opts.window as u64);
    let default_report = board.report();

    // Candidate 0 is always the shipped configuration at base clock.
    let mut candidates = Vec::new();
    let shipped_label = format_label(board.cfg.act_fmt, board.cfg.weight_fmt);
    let base_clock = [board.device.clock_mhz];
    evaluate(
        board,
        board.cfg.clone(),
        &base_clock,
        opts,
        shipped_label,
        &mut candidates,
    );
    let mut clocks = Vec::with_capacity(opts.clock_scales.len());
    for &s in &opts.clock_scales {
        clocks.push(board.device.clock_mhz * s);
    }
    let dataflow_axis: &[bool] = if opts.sweep_dataflow {
        &[true, false]
    } else {
        &[true]
    };
    for tile in &opts.tiles {
        for fmtp in &opts.formats {
            for map in &opts.stage_maps {
                for &dataflow in dataflow_axis {
                    let mut cfg = board.cfg.clone();
                    cfg.unroll = tile.unroll;
                    cfg.banks = tile.banks;
                    cfg.reshape = tile.reshape;
                    cfg.dataflow = dataflow;
                    cfg.ddr_spill = !dataflow;
                    cfg.stage_map = *map;
                    cfg.act_fmt = fmtp.act;
                    cfg.weight_fmt = fmtp.weight;
                    evaluate(board, cfg, &clocks, opts, fmtp.name, &mut candidates);
                }
            }
        }
    }

    let default_feasible = candidates[0].feasible();

    // Selection: fastest feasible point, no cycle regression vs the
    // shipped design (when that design is itself feasible).
    let mut tally = FeasibilityTally::default();
    let mut chosen: Option<usize> = None;
    for (i, c) in candidates.iter().enumerate() {
        tally.add(c.fits, c.headroom_ok, c.clock_ok, c.fidelity_ok, c.power_ok);
        if !c.feasible() {
            continue;
        }
        if default_feasible && c.window_cycles > default_timing.total_cycles {
            continue;
        }
        let better = match chosen {
            None => true,
            Some(j) => cmp_speed_power(c, &candidates[j]) == Ordering::Less,
        };
        if better {
            chosen = Some(i);
        }
    }
    let chosen = match chosen {
        Some(i) => i,
        None => return Err(tally.error(&board.name)),
    };

    // Pareto front over (window_s, power_w) among all feasible points.
    let mut order: Vec<usize> = Vec::new();
    for (i, c) in candidates.iter().enumerate() {
        if c.feasible() {
            order.push(i);
        }
    }
    let feasible = order.len();
    order.sort_by(|&a, &b| cmp_speed_power(&candidates[a], &candidates[b]));
    let mut pareto: Vec<TuneCandidate> = Vec::new();
    let mut best_power = f64::INFINITY;
    for i in order {
        let c = &candidates[i];
        if c.power_w < best_power {
            best_power = c.power_w;
            pareto.push(c.clone());
        }
    }

    let c = &candidates[chosen];
    let tuned = TunedConfig {
        board: board.retargeted(c.cfg.clone(), c.clock_mhz),
        clock_mhz: c.clock_mhz,
        window: opts.window,
        window_cycles: c.window_cycles,
        window_s: c.window_s,
        power_w: c.power_w,
        energy_per_window_j: c.energy_per_window_j,
        resources: c.resources,
        max_outstanding: c.max_outstanding,
        format: c.format,
        default_window_cycles: default_timing.total_cycles,
    };
    Ok(TuneOutcome {
        board_name: board.name.clone(),
        evaluated: candidates.len(),
        feasible,
        default_feasible,
        default_window_cycles: default_timing.total_cycles,
        default_window_s: board.window_seconds(opts.window as u64),
        default_power_w: default_report.power_w,
        chosen: tuned,
        pareto,
    })
}

/// Tune every board of a fleet independently (board order preserved; an
/// `Err` marks a board with no feasible design point, naming the
/// binding constraint).
pub fn tune_fleet(boards: &[BoardSpec], opts: &TunerOptions) -> Vec<Result<TuneOutcome>> {
    boards.iter().map(|b| tune_board(b, opts)).collect()
}

/// Re-tune an entire serving roster, all-or-nothing.
///
/// The online-retune path (`coordinator::traffic` reacting to traffic-mix
/// drift) swaps the live placement cost models mid-stream, so a partial
/// roster is worse than no retune at all: if *any* board has no feasible
/// design point the whole retune is abandoned (the stream keeps its
/// current models) and the binding constraint is reported. Board order is
/// preserved so outcomes line up index-for-index with the fleet.
pub fn retune_roster(boards: &[BoardSpec], opts: &TunerOptions) -> Result<Vec<TuneOutcome>> {
    if boards.is_empty() {
        return Err(Error::config("retune_roster: empty board roster"));
    }
    boards.iter().map(|b| tune_board(b, opts)).collect()
}

/// One point on the shared design axes every family sweep walks:
/// everything a graph builder needs to materialize one candidate
/// design. The GRU family maps it onto `GruAccelConfig`
/// (tile → unroll/banks/reshape, `dataflow` → DATAFLOW vs DDR-spill);
/// other families interpret the same axes for their own structure.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    /// Tiling (UNROLL lanes × BRAM banking × reshape).
    pub tile: Tile,
    /// Stage-to-fabric adder mix.
    pub stage_map: StageMap,
    /// Fixed-point activation format.
    pub act_fmt: FixedFormat,
    /// Fixed-point weight format.
    pub weight_fmt: FixedFormat,
    /// DATAFLOW on (FIFO-carried edges) vs off (DDR-spill baseline).
    pub dataflow: bool,
}

/// One evaluated graph design point — the graph-family analogue of
/// [`TuneCandidate`], carrying the [`DesignPoint`] instead of a
/// `GruAccelConfig` and otherwise the same scores and per-constraint
/// feasibility verdicts.
#[derive(Clone, Debug)]
pub struct GraphTuneCandidate {
    /// The design point the graph was built from.
    pub point: DesignPoint,
    /// PL clock this point runs at (MHz).
    pub clock_mhz: f64,
    /// Cycle-model cycles for one recovery window.
    pub window_cycles: u64,
    /// Steady-state cycles between window outputs.
    pub interval: u64,
    /// `window_cycles` at `clock_mhz`, in seconds — the speed score.
    pub window_s: f64,
    /// Modeled power draw (W) — the second Pareto axis.
    pub power_w: f64,
    /// Energy for one full window (J).
    pub energy_per_window_j: f64,
    /// Fabric the design consumes.
    pub resources: Resources,
    /// Design fits the target device.
    pub fits: bool,
    /// Free BRAM can double-buffer at least one window payload.
    pub headroom_ok: bool,
    /// `clock_mhz` is within the design's timing-closure model.
    pub clock_ok: bool,
    /// Formats meet the fidelity floor (`min_frac_bits`).
    pub fidelity_ok: bool,
    /// Within the optional power budget.
    pub power_ok: bool,
    /// Concurrent windows the free BRAM double-buffers (capped at 512).
    pub max_outstanding: usize,
    /// Format preset name (`q8.8`, `q4.8`, `8bit`, `custom`).
    pub format: &'static str,
}

impl GraphTuneCandidate {
    /// All feasibility verdicts at once — the Pareto/selection filter.
    pub fn feasible(&self) -> bool {
        self.fits && self.headroom_ok && self.clock_ok && self.fidelity_ok && self.power_ok
    }
}

/// Everything [`tune_graph`] learned about one accelerator family.
#[derive(Clone, Debug)]
pub struct GraphTuneOutcome {
    /// Family name the search ran over.
    pub family: String,
    /// Design points evaluated (grid + the family default).
    pub evaluated: usize,
    /// How many of them were feasible.
    pub feasible: usize,
    /// Cycles per window of the family's default design point.
    pub default_window_cycles: u64,
    /// The selected operating point.
    pub chosen: GraphTuneCandidate,
    /// The chosen design compiled — hand this to
    /// `coordinator::placement::GraphInstanceSpec` to derive a fleet
    /// cost model for the family.
    pub chosen_lowered: LoweredGraph,
    pareto: Vec<GraphTuneCandidate>,
}

impl GraphTuneOutcome {
    /// The feasible Pareto front over (window seconds, watts), fastest
    /// first — same antichain contract as [`TuneOutcome::pareto`].
    pub fn pareto(&self) -> std::slice::Iter<'_, GraphTuneCandidate> {
        self.pareto.iter()
    }
}

/// Score one lowered graph, emitting one candidate per clock — the
/// graph-family analogue of [`evaluate`]. Timing comes from
/// [`LoweredGraph::window_timing`], the same cycle law the placement
/// cost model uses, and the timing-closure ceiling from the lowered
/// graph's own `clock_scale` annotation.
fn evaluate_graph_point(
    point: &DesignPoint,
    low: &LoweredGraph,
    clocks: &[f64],
    target: &Target,
    opts: &TunerOptions,
    format: &'static str,
    out: &mut Vec<GraphTuneCandidate>,
) {
    let timing = low.window_timing(opts.window as u64);
    let payload = window_payload_bytes(
        &low.act_fmt,
        opts.window,
        opts.xdim,
        opts.udim,
        opts.theta_len,
    );
    let budget = target.device.double_buffer_windows(&low.resources, payload);
    let fidelity_ok = point.act_fmt.frac_bits >= opts.min_frac_bits
        && point.weight_fmt.frac_bits >= opts.min_frac_bits;
    let power_ok = match opts.max_power_w {
        Some(cap) => low.power_w <= cap,
        None => true,
    };
    let max_clock = target.device.clock_mhz * low.clock_scale;
    for &clock_mhz in clocks {
        let device = target.device.with_clock(clock_mhz);
        out.push(GraphTuneCandidate {
            point: point.clone(),
            clock_mhz,
            window_cycles: timing.total_cycles,
            interval: timing.interval,
            window_s: device.cycles_to_seconds(timing.total_cycles),
            power_w: low.power_w,
            energy_per_window_j: energy_j(low.power_w, timing.total_cycles, clock_mhz),
            resources: low.resources,
            fits: low.fits,
            headroom_ok: budget >= 1,
            clock_ok: clock_mhz <= max_clock + 1e-9,
            fidelity_ok,
            power_ok,
            max_outstanding: budget.min(512),
            format,
        });
    }
}

/// Exhaustively sweep the shared design axes for one accelerator
/// *family* — any closure from [`DesignPoint`] to a graph — and pick
/// its operating point. Same contract as [`tune_board`]: the family's
/// `default_point` is always evaluated at base clock, the chosen point
/// never regresses its cycle count when the default is feasible, and a
/// dry search fails with the typed [`Error::Config`] naming the binding
/// constraint.
///
/// # Example
///
/// ```
/// use merinda::fpga::graph::Target;
/// use merinda::fpga::sindy_accel::SindyAccelConfig;
/// use merinda::fpga::tuner::{tune_graph, TunerOptions};
///
/// // Tune the SINDy library + dense-head family — no hand-written
/// // schedule anywhere, the graph builder is the whole description.
/// let cfg = SindyAccelConfig::concurrent();
/// let out = tune_graph(
///     "sindy_head",
///     &cfg.family(),
///     &cfg.design_point(),
///     &Target::default(),
///     &TunerOptions::default(),
/// )
/// .unwrap();
/// assert!(out.chosen.feasible());
/// assert!(out.chosen.window_cycles <= out.default_window_cycles);
/// ```
pub fn tune_graph(
    family: &str,
    build: &dyn Fn(&DesignPoint) -> Graph,
    default_point: &DesignPoint,
    target: &Target,
    opts: &TunerOptions,
) -> Result<GraphTuneOutcome> {
    assert!(opts.window > 0, "tuner needs a non-empty window");

    // Candidate 0 is always the family's default point at base clock.
    let mut candidates = Vec::new();
    let base_clock = [target.device.clock_mhz];
    let default_low = lower(&build(default_point), target)?;
    let shipped_label = format_label(default_point.act_fmt, default_point.weight_fmt);
    evaluate_graph_point(
        default_point,
        &default_low,
        &base_clock,
        target,
        opts,
        shipped_label,
        &mut candidates,
    );
    let default_window_cycles = candidates[0].window_cycles;

    let mut clocks = Vec::with_capacity(opts.clock_scales.len());
    for &s in &opts.clock_scales {
        clocks.push(target.device.clock_mhz * s);
    }
    let dataflow_axis: &[bool] = if opts.sweep_dataflow {
        &[true, false]
    } else {
        &[true]
    };
    for tile in &opts.tiles {
        for fmtp in &opts.formats {
            for map in &opts.stage_maps {
                for &dataflow in dataflow_axis {
                    let point = DesignPoint {
                        tile: *tile,
                        stage_map: *map,
                        act_fmt: fmtp.act,
                        weight_fmt: fmtp.weight,
                        dataflow,
                    };
                    let low = lower(&build(&point), target)?;
                    evaluate_graph_point(
                        &point,
                        &low,
                        &clocks,
                        target,
                        opts,
                        fmtp.name,
                        &mut candidates,
                    );
                }
            }
        }
    }

    let default_feasible = candidates[0].feasible();
    let mut tally = FeasibilityTally::default();
    let mut chosen: Option<usize> = None;
    for (i, c) in candidates.iter().enumerate() {
        tally.add(c.fits, c.headroom_ok, c.clock_ok, c.fidelity_ok, c.power_ok);
        if !c.feasible() {
            continue;
        }
        if default_feasible && c.window_cycles > default_window_cycles {
            continue;
        }
        let better = match chosen {
            None => true,
            Some(j) => {
                let prev = &candidates[j];
                cmp_speed_power_key((c.window_s, c.power_w), (prev.window_s, prev.power_w))
                    == Ordering::Less
            }
        };
        if better {
            chosen = Some(i);
        }
    }
    let chosen = match chosen {
        Some(i) => i,
        None => return Err(tally.error(family)),
    };

    // Pareto front over (window_s, power_w) among all feasible points.
    let mut order: Vec<usize> = (0..candidates.len())
        .filter(|&i| candidates[i].feasible())
        .collect();
    let feasible = order.len();
    order.sort_by(|&a, &b| {
        let (x, y) = (&candidates[a], &candidates[b]);
        cmp_speed_power_key((x.window_s, x.power_w), (y.window_s, y.power_w))
    });
    let mut pareto: Vec<GraphTuneCandidate> = Vec::new();
    let mut best_power = f64::INFINITY;
    for i in order {
        let c = &candidates[i];
        if c.power_w < best_power {
            best_power = c.power_w;
            pareto.push(c.clone());
        }
    }

    let c = candidates[chosen].clone();
    let chosen_lowered = lower(&build(&c.point), target)?;
    Ok(GraphTuneOutcome {
        family: family.to_string(),
        evaluated: candidates.len(),
        feasible,
        default_window_cycles,
        chosen: c,
        chosen_lowered,
        pareto,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::cluster::heterogeneous_fleet;
    use crate::fpga::resources::BRAM18_BYTES;

    fn outcomes() -> Vec<TuneOutcome> {
        tune_fleet(&heterogeneous_fleet(4, 32), &TunerOptions::default())
            .into_iter()
            .map(|o| o.expect("every canonical board must tune"))
            .collect()
    }

    #[test]
    fn retune_roster_is_all_or_nothing() {
        let fleet = heterogeneous_fleet(4, 32);
        let outs = retune_roster(&fleet, &TunerOptions::default())
            .expect("canonical fleet must retune wholesale");
        assert_eq!(outs.len(), fleet.len(), "order-preserving, one per board");
        for (board, out) in fleet.iter().zip(&outs) {
            assert_eq!(out.board_name, board.name);
            assert!(out.chosen.window_s > 0.0);
        }
        assert!(retune_roster(&[], &TunerOptions::default()).is_err());
    }

    #[test]
    fn every_canonical_board_gets_a_fitting_config() {
        let outs = outcomes();
        assert_eq!(outs.len(), 3);
        for out in &outs {
            let t = &out.chosen;
            assert!(t.board.fits(), "{}: tuned design must fit", out.board_name);
            assert!(t.max_outstanding >= 1, "{}", out.board_name);
            assert!(t.window_cycles > 0 && t.window_s > 0.0);
            assert!(out.feasible >= 1 && out.feasible <= out.evaluated);
        }
    }

    #[test]
    fn tuned_has_bram_double_buffer_headroom() {
        for out in outcomes() {
            let t = &out.chosen;
            let payload = window_payload_bytes(&t.board.cfg.act_fmt, t.window, 3, 1, 45);
            let free = t.board.device.free(&t.resources).bram18 * BRAM18_BYTES;
            assert!(
                free >= 2 * payload,
                "{}: free {free} B cannot double-buffer {payload} B",
                out.board_name
            );
        }
    }

    #[test]
    fn tuned_never_regresses_default_cycles() {
        let outs = outcomes();
        let mut strict = 0;
        for out in &outs {
            assert!(out.default_feasible, "{}", out.board_name);
            assert!(
                out.chosen.window_cycles <= out.default_window_cycles,
                "{}: tuned {} vs default {}",
                out.board_name,
                out.chosen.window_cycles,
                out.default_window_cycles
            );
            assert!(out.chosen.speedup_vs_default() >= 1.0);
            if out.chosen.window_cycles < out.default_window_cycles {
                strict += 1;
            }
        }
        assert!(strict >= 1, "tuning must strictly beat at least one default");
    }

    #[test]
    fn sequential_board_gains_dataflow() {
        // heterogeneous_fleet board 1 ships with DATAFLOW off — by far
        // the largest win in the space.
        let board = heterogeneous_fleet(4, 32).remove(1);
        assert!(!board.cfg.dataflow);
        let out = tune_board(&board, &TunerOptions::default()).unwrap();
        assert!(out.chosen.board.cfg.dataflow);
        assert!(out.chosen.speedup_vs_default() > 2.0);
    }

    #[test]
    fn pareto_front_is_an_antichain_fastest_first() {
        for out in outcomes() {
            let front: Vec<&TuneCandidate> = out.pareto().collect();
            assert!(!front.is_empty());
            for pair in front.windows(2) {
                assert!(pair[0].window_s <= pair[1].window_s);
                assert!(pair[0].power_w > pair[1].power_w);
            }
            for c in &front {
                assert!(c.feasible());
            }
        }
    }

    #[test]
    fn fidelity_floor_rejects_narrow_formats() {
        for out in outcomes() {
            assert!(out.chosen.board.cfg.act_fmt.frac_bits >= 8, "{}", out.board_name);
            assert_ne!(out.chosen.format, "8bit");
        }
    }

    #[test]
    fn impossible_power_budget_yields_config_error() {
        // 1 W is below the 1.7 W static floor of the power model; the
        // error must say the power budget was the binding constraint.
        let opts = TunerOptions {
            max_power_w: Some(1.0),
            ..TunerOptions::default()
        };
        let board = heterogeneous_fleet(4, 32).remove(0);
        let err = tune_board(&board, &opts).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("no feasible design point"), "{msg}");
        assert!(msg.contains("power budget"), "{msg}");
    }

    #[test]
    fn loose_power_budget_caps_chosen_power() {
        let board = heterogeneous_fleet(4, 32).remove(0);
        let unbounded = tune_board(&board, &TunerOptions::default()).unwrap();
        let cap = unbounded.chosen.power_w - 1e-6;
        let opts = TunerOptions {
            max_power_w: Some(cap),
            ..TunerOptions::default()
        };
        if let Ok(bounded) = tune_board(&board, &opts) {
            assert!(bounded.chosen.power_w <= cap);
        }
    }

    #[test]
    fn clock_scale_model_derates_carry_chains_and_wide_tiles() {
        let base = GruAccelConfig::concurrent();
        // Concurrent map has LUT-bound s2 but DSP-bound matvecs at
        // unroll 32: full overclock headroom is denied only by s3.
        let all_dsp = GruAccelConfig {
            stage_map: [Binding::Dsp; 4],
            ..base.clone()
        };
        assert!((max_clock_scale(&all_dsp) - 1.15).abs() < 1e-12);
        assert!((max_clock_scale(&base) - 1.0).abs() < 1e-12);
        let wide = GruAccelConfig {
            unroll: 96,
            ..all_dsp
        };
        assert!((max_clock_scale(&wide) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn tuned_clock_within_timing_closure() {
        for out in outcomes() {
            let cfg = &out.chosen.board.cfg;
            let base = heterogeneous_fleet(4, 32)
                .into_iter()
                .find(|b| b.name == out.board_name)
                .unwrap();
            let max = base.device.clock_mhz * max_clock_scale(cfg);
            assert!(out.chosen.clock_mhz <= max + 1e-9, "{}", out.board_name);
        }
    }

    #[test]
    fn format_labels_round_trip() {
        let q88 = FixedFormat::q8_8();
        let q48 = FixedFormat::q4_8();
        let i8f = FixedFormat::new(8, 4);
        assert_eq!(format_label(q88, q88), "q8.8");
        assert_eq!(format_label(q48, q48), "q4.8");
        assert_eq!(format_label(i8f, i8f), "8bit");
        assert_eq!(format_label(FixedFormat::new(16, 12), q88), "custom");
    }
}

//! LTC (ODE) accelerator baseline — Table 8 row 1.
//!
//! The liquid-time-constant cell advances its state with an iterative
//! fused ODE solver: `LTC_UNFOLD` sequential sub-steps per time step, each
//! a full matvec + sigmoid + elementwise divide, with a true data
//! dependency between sub-steps (§1, Fig. 1 left). Nothing overlaps: the
//! solver cannot be pipelined across sub-steps, and because the
//! coefficients adapt online the next item's solve cannot be prefetched —
//! each sub-step round-trips state through the memory subsystem. This is
//! exactly the behaviour the MERINDA GRU block removes.

use super::bram::BankedArray;
use super::fixedpoint::FixedFormat;
use super::graph::{lower, Graph, Op, Target, Transfer};
use super::hls::Binding;
use super::interconnect::DdrModel;
use super::lut::{Activation, ActivationTable};
use super::power::PowerModel;
use super::resources::{Device, Resources};
use crate::mr::ltc::LtcParams;

/// LTC accelerator configuration.
#[derive(Clone, Debug)]
pub struct LtcAccelConfig {
    pub input: usize,
    pub hidden: usize,
    /// ODE solver sub-steps per time step (paper: 6).
    pub solver_steps: u32,
    /// MAC lanes.
    pub unroll: u32,
    pub act_fmt: FixedFormat,
    pub weight_fmt: FixedFormat,
}

impl LtcAccelConfig {
    pub fn base() -> LtcAccelConfig {
        LtcAccelConfig {
            input: 4,
            hidden: 16,
            solver_steps: 6,
            unroll: 8,
            act_fmt: FixedFormat::new(16, 8),
            weight_fmt: FixedFormat::new(16, 8),
        }
    }
}

/// Structural evaluation result (same shape as the GRU report).
#[derive(Clone, Debug)]
pub struct LtcReport {
    pub cycles: u64,
    pub interval: u64,
    pub resources: Resources,
    pub power_w: f64,
    pub energy_per_output_j: f64,
}

impl LtcReport {
    /// Cycles to process a `seq`-step window: the iterative solver cannot
    /// overlap time steps, so every step pays the full interval (compute
    /// plus the per-sub-step DDR round trips and PS sync).
    pub fn window_cycles(&self, seq: u64) -> u64 {
        seq * self.interval
    }
}

pub struct LtcAccel {
    pub cfg: LtcAccelConfig,
    pub ddr: DdrModel,
    pub power: PowerModel,
    pub device: Device,
}

impl LtcAccel {
    pub fn new(cfg: LtcAccelConfig) -> LtcAccel {
        LtcAccel {
            cfg,
            ddr: DdrModel::default(),
            power: PowerModel::default(),
            device: Device::pynq_z2(),
        }
    }

    /// One solver sub-step scheduled by hand: f = σ(Wx + Uh + b), then the
    /// fused update h ← (h + dt·f∘A) / (1 + dt·(1/τ + f)). Retained as the
    /// equivalence oracle for the graph lowering
    /// (`graph_lowering_matches_hand_built_substeps`).
    #[cfg(test)]
    fn substep_cycles(&self) -> (u64, Resources) {
        use super::hls::{schedule, LoopNest};
        let c = &self.cfg;
        let h = c.hidden as u64;
        let macs = (c.input * c.hidden + c.hidden * c.hidden) as u64;
        let w = BankedArray::new("ltc_w", macs, c.weight_fmt.word_bits);
        let s_mac = schedule(
            &LoopNest::new("ltc_affine", macs)
                .unrolled(c.unroll)
                .macs(1)
                .bound(Binding::Dsp)
                .with_array(w, 1, 0),
        );
        // Sigmoid lookups + fused update: 1 div ≈ 8 elementwise ops (no
        // hard divider; iterative reciprocal on DSP).
        let s_upd = schedule(
            &LoopNest::new("ltc_update", h)
                .unrolled(c.unroll.min(c.hidden as u32))
                .activations(1)
                .elementwise(10)
                .bound(Binding::Dsp)
                .with_array(
                    BankedArray::new("ltc_state", h, c.act_fmt.word_bits),
                    3,
                    1,
                ),
        );
        (
            s_mac.cycles + s_upd.cycles,
            s_mac.resources + s_upd.resources,
        )
    }

    /// The iterative solver as a dataflow graph: one matvec op feeding the
    /// fused-update op, run `solver_steps` times per item under the
    /// [`Profile::Iterative`](super::graph::Profile) law, with the
    /// per-sub-step costs the feed-forward GRU design simply does not
    /// have —
    ///  (a) state out + state in as scattered DMA transactions and the
    ///      adaptive-coefficient reload as a burst (online coefficients
    ///      defeat prefetch/caching);
    ///  (b) a PS-side solver-control round trip — the adaptive step
    ///      size/convergence check runs on the ARM core, an AXI-Lite
    ///      poll + interrupt costing ~5 µs ≈ 865 cycles at 173 MHz.
    /// This is the paper's §1 complaint ("iterative dependencies,
    /// kernel-launch overheads, high data-movement latency") in cycles.
    pub fn graph(&self) -> Graph {
        let c = &self.cfg;
        let h = c.hidden as u64;
        let macs = (c.input * c.hidden + c.hidden * c.hidden) as u64;
        let mut g =
            Graph::new("ltc_solver", c.act_fmt, c.weight_fmt).iterative(c.solver_steps, 865);
        let mac = g.push_op(
            Op::matvec("ltc_affine", macs)
                .unrolled(c.unroll)
                .bound(Binding::Dsp)
                .with_array(BankedArray::new("ltc_w", macs, c.weight_fmt.word_bits), 1, 0),
        );
        // Sigmoid lookups + fused update: 1 div ≈ 8 elementwise ops (no
        // hard divider; iterative reciprocal on DSP).
        let upd = g.push_op(
            Op::nonlinearity("ltc_update", h)
                .unrolled(c.unroll.min(c.hidden as u32))
                .elementwise_ops(10)
                .bound(Binding::Dsp)
                .with_array(BankedArray::new("ltc_state", h, c.act_fmt.word_bits), 3, 1),
        );
        g.connect(mac, upd, h, 1);
        g.transfer(Transfer::Scattered {
            transactions: 2,
            elems_each: h,
        });
        g.transfer(Transfer::Burst {
            elems: (c.input + c.hidden) as u64 * c.hidden as u64,
        });
        g
    }

    /// Structural report, derived by lowering [`LtcAccel::graph`] through
    /// the shared graph compiler.
    pub fn report(&self) -> LtcReport {
        let target = Target {
            device: self.device,
            ddr: self.ddr,
            power: self.power,
        };
        let low = lower(&self.graph(), &target).expect("LTC graph is well-formed by construction");
        LtcReport {
            cycles: low.cycles,
            interval: low.interval,
            resources: low.resources,
            power_w: low.power_w,
            energy_per_output_j: low.energy_per_output_j,
        }
    }

    /// Functional fixed-point LTC forward (one sequence), mirroring the
    /// modeled datapath — used for the accuracy columns.
    pub fn forward_fixed(&self, params: &LtcParams, xs: &[f32], seq: usize, dt: f32) -> Vec<f32> {
        let c = &self.cfg;
        let (i_sz, hid) = (c.input, c.hidden);
        let af = c.act_fmt;
        let wf = c.weight_fmt;
        let sig = ActivationTable::default_for(Activation::Sigmoid);

        let qwf: Vec<f32> = params.wf.iter().map(|&v| wf.quantize_f32(v)).collect();
        let quf: Vec<f32> = params.uf.iter().map(|&v| wf.quantize_f32(v)).collect();
        let qbf: Vec<f32> = params.bf.iter().map(|&v| wf.quantize_f32(v)).collect();

        let mut h = vec![0.0f32; hid];
        for t in 0..seq {
            let x = &xs[t * i_sz..(t + 1) * i_sz];
            for _ in 0..c.solver_steps {
                let mut pre = qbf.clone();
                for (ii, &xv) in x.iter().enumerate() {
                    for j in 0..hid {
                        pre[j] += xv * qwf[ii * hid + j];
                    }
                }
                for (hi, &hv) in h.iter().enumerate() {
                    for j in 0..hid {
                        pre[j] += hv * quf[hi * hid + j];
                    }
                }
                for j in 0..hid {
                    let f = af.quantize_f32(sig.eval(af.quantize_f32(pre[j]) as f64) as f32);
                    let num = h[j] + dt * f * params.a[j];
                    let den = 1.0 + dt * (1.0 / params.tau[j] + f);
                    h[j] = af.quantize_f32(num / den);
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::gru_accel::{GruAccel, GruAccelConfig};
    use crate::mr::ltc::LtcCell;
    use crate::util::Prng;

    #[test]
    fn ltc_much_slower_than_any_gru_config() {
        let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
        let gru = GruAccel::new(GruAccelConfig::gru_baseline()).report();
        // Paper: LTC interval 12014 vs GRU 271 (~44×); we require ≫.
        assert!(
            ltc.interval > 5 * gru.interval,
            "ltc={} gru={}",
            ltc.interval,
            gru.interval
        );
        assert!(ltc.cycles > gru.cycles);
    }

    #[test]
    fn solver_steps_scale_latency_linearly() {
        let mut c3 = LtcAccelConfig::base();
        c3.solver_steps = 3;
        let mut c6 = LtcAccelConfig::base();
        c6.solver_steps = 6;
        let r3 = LtcAccel::new(c3).report();
        let r6 = LtcAccel::new(c6).report();
        assert_eq!(r6.cycles, 2 * r3.cycles);
    }

    #[test]
    fn ltc_energy_dwarfs_gru_energy() {
        let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
        let gru = GruAccel::new(GruAccelConfig::concurrent()).report();
        // Paper: GRU configs are ~98-99% lower energy/output than LTC.
        assert!(ltc.energy_per_output_j > 10.0 * gru.energy_per_output_j);
    }

    #[test]
    fn ltc_window_at_least_4x_dataflow_gru_window() {
        // The paper's §6 headline trend: the dataflow GRU needs ≥ 4×
        // (they report 6.3×+) fewer cycles than the sequential LTC on a
        // streaming window. `BENCH_cycles.json` records the exact ratio.
        let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
        let gru = GruAccel::new(GruAccelConfig::concurrent()).report();
        let ratio = ltc.window_cycles(64) as f64 / gru.window_cycles(64) as f64;
        assert!(ratio >= 4.0, "ltc/gru window cycle ratio {ratio}");
        assert_eq!(ltc.window_cycles(64), 64 * ltc.interval);
    }

    #[test]
    fn graph_lowering_matches_hand_built_substeps() {
        // The graph instance must reproduce the hand-built sub-step
        // schedule exactly: same per-sweep cycles and resources, and the
        // same solver-steps × sub-step latency law.
        for unroll in [4, 8, 32] {
            let mut cfg = LtcAccelConfig::base();
            cfg.unroll = unroll;
            let accel = LtcAccel::new(cfg);
            let (sub_cycles, sub_res) = accel.substep_cycles();
            let low = lower(&accel.graph(), &Target::default()).unwrap();
            let sweep: u64 = low.stages.iter().map(|s| s.cycles).sum();
            let sweep_res = low
                .stages
                .iter()
                .fold(Resources::ZERO, |a, s| a + s.resources);
            assert_eq!(sweep, sub_cycles, "unroll {unroll}");
            assert_eq!(sweep_res, sub_res, "unroll {unroll}");
            assert_eq!(low.cycles, sub_cycles * accel.cfg.solver_steps as u64);
            let r = accel.report();
            assert_eq!((r.cycles, r.interval), (low.cycles, low.interval));
        }
    }

    #[test]
    fn fixed_forward_tracks_f32_ltc() {
        let mut rng = Prng::new(5);
        let cfg = LtcAccelConfig::base();
        let params = LtcParams::random(cfg.input, cfg.hidden, &mut rng, 0.3);
        let accel = LtcAccel::new(cfg.clone());
        let xs = rng.normal_vec_f32(24 * cfg.input, 0.8);
        let fixed = accel.forward_fixed(&params, &xs, 24, 0.1);
        let float = LtcCell::new(params, cfg.solver_steps as usize).run(&xs, 24, 0.1);
        let err: f32 = fixed
            .iter()
            .zip(&float)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.15, "LTC fixed-point drift {err}");
    }
}

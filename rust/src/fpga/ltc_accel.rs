//! LTC (ODE) accelerator baseline — Table 8 row 1.
//!
//! The liquid-time-constant cell advances its state with an iterative
//! fused ODE solver: `LTC_UNFOLD` sequential sub-steps per time step, each
//! a full matvec + sigmoid + elementwise divide, with a true data
//! dependency between sub-steps (§1, Fig. 1 left). Nothing overlaps: the
//! solver cannot be pipelined across sub-steps, and because the
//! coefficients adapt online the next item's solve cannot be prefetched —
//! each sub-step round-trips state through the memory subsystem. This is
//! exactly the behaviour the MERINDA GRU block removes.

use super::bram::BankedArray;
use super::fixedpoint::FixedFormat;
use super::hls::{schedule, Binding, LoopNest};
use super::interconnect::DdrModel;
use super::lut::{Activation, ActivationTable};
use super::power::{Activity, PowerModel};
use super::resources::{Device, Resources};
use crate::mr::ltc::LtcParams;

/// LTC accelerator configuration.
#[derive(Clone, Debug)]
pub struct LtcAccelConfig {
    pub input: usize,
    pub hidden: usize,
    /// ODE solver sub-steps per time step (paper: 6).
    pub solver_steps: u32,
    /// MAC lanes.
    pub unroll: u32,
    pub act_fmt: FixedFormat,
    pub weight_fmt: FixedFormat,
}

impl LtcAccelConfig {
    pub fn base() -> LtcAccelConfig {
        LtcAccelConfig {
            input: 4,
            hidden: 16,
            solver_steps: 6,
            unroll: 8,
            act_fmt: FixedFormat::new(16, 8),
            weight_fmt: FixedFormat::new(16, 8),
        }
    }
}

/// Structural evaluation result (same shape as the GRU report).
#[derive(Clone, Debug)]
pub struct LtcReport {
    pub cycles: u64,
    pub interval: u64,
    pub resources: Resources,
    pub power_w: f64,
    pub energy_per_output_j: f64,
}

impl LtcReport {
    /// Cycles to process a `seq`-step window: the iterative solver cannot
    /// overlap time steps, so every step pays the full interval (compute
    /// plus the per-sub-step DDR round trips and PS sync).
    pub fn window_cycles(&self, seq: u64) -> u64 {
        seq * self.interval
    }
}

pub struct LtcAccel {
    pub cfg: LtcAccelConfig,
    pub ddr: DdrModel,
    pub power: PowerModel,
    pub device: Device,
}

impl LtcAccel {
    pub fn new(cfg: LtcAccelConfig) -> LtcAccel {
        LtcAccel {
            cfg,
            ddr: DdrModel::default(),
            power: PowerModel::default(),
            device: Device::pynq_z2(),
        }
    }

    /// One solver sub-step: f = σ(Wx + Uh + b), then the fused update
    /// h ← (h + dt·f∘A) / (1 + dt·(1/τ + f)).
    fn substep_cycles(&self) -> (u64, Resources) {
        let c = &self.cfg;
        let h = c.hidden as u64;
        let macs = (c.input * c.hidden + c.hidden * c.hidden) as u64;
        let w = BankedArray::new("ltc_w", macs, c.weight_fmt.word_bits);
        let s_mac = schedule(
            &LoopNest::new("ltc_affine", macs)
                .unrolled(c.unroll)
                .macs(1)
                .bound(Binding::Dsp)
                .with_array(w, 1, 0),
        );
        // Sigmoid lookups + fused update: 1 div ≈ 8 elementwise ops (no
        // hard divider; iterative reciprocal on DSP).
        let s_upd = schedule(
            &LoopNest::new("ltc_update", h)
                .unrolled(c.unroll.min(c.hidden as u32))
                .activations(1)
                .elementwise(10)
                .bound(Binding::Dsp)
                .with_array(
                    BankedArray::new("ltc_state", h, c.act_fmt.word_bits),
                    3,
                    1,
                ),
        );
        (
            s_mac.cycles + s_upd.cycles,
            s_mac.resources + s_upd.resources,
        )
    }

    pub fn report(&self) -> LtcReport {
        let c = &self.cfg;
        let (sub_cycles, sub_res) = self.substep_cycles();

        // Sequential sub-steps; latency = solver_steps × substep.
        let cycles = sub_cycles * c.solver_steps as u64;

        // Interval: no cross-item overlap, plus per-sub-step costs that the
        // feed-forward GRU design simply does not have:
        //  (a) state out + state in + adaptive-coefficient reload as three
        //      scattered DMA transactions (online coefficients defeat
        //      prefetch/caching);
        //  (b) a PS-side solver-control round trip — the adaptive step
        //      size/convergence check runs on the ARM core, an AXI-Lite
        //      poll + interrupt costing ~5 µs ≈ 865 cycles at 173 MHz.
        // This is the paper's §1 complaint ("iterative dependencies,
        // kernel-launch overheads, high data-movement latency") in cycles.
        let wb = (c.act_fmt.word_bits as u64).div_ceil(8);
        let state_bytes = (c.hidden as u64) * wb;
        let coef_bytes = ((c.input + c.hidden) as u64 * c.hidden as u64) * wb;
        let ddr_per_substep = self.ddr.scattered_cycles(2, state_bytes)
            + self.ddr.burst_cycles(coef_bytes);
        let ps_sync = 865u64;
        let interval = cycles + c.solver_steps as u64 * (ddr_per_substep + ps_sync);

        // Resources shared across sub-steps (same engine reused) + solver
        // sequencing control.
        let mut res = sub_res;
        res += Resources::new(9_000, 18_000, 4, 2); // solver FSM + buffers
        res += Resources::new(1_800, 2_400, 0, 2); // DMA + AXI

        let busy = cycles as f64 / interval.max(1) as f64;
        let act = Activity {
            dsp: 0.75 * busy,
            lut: 0.35 + 0.3 * busy,
            bram: 0.5,
            ddr: (1.0 - busy).clamp(0.3, 1.0),
        };
        let power_w = self.power.watts(&res, &act);
        let energy = self
            .power
            .energy_per_output_j(&res, &act, interval, self.device.clock_mhz);
        LtcReport {
            cycles,
            interval,
            resources: res,
            power_w,
            energy_per_output_j: energy,
        }
    }

    /// Functional fixed-point LTC forward (one sequence), mirroring the
    /// modeled datapath — used for the accuracy columns.
    pub fn forward_fixed(&self, params: &LtcParams, xs: &[f32], seq: usize, dt: f32) -> Vec<f32> {
        let c = &self.cfg;
        let (i_sz, hid) = (c.input, c.hidden);
        let af = c.act_fmt;
        let wf = c.weight_fmt;
        let sig = ActivationTable::default_for(Activation::Sigmoid);

        let qwf: Vec<f32> = params.wf.iter().map(|&v| wf.quantize_f32(v)).collect();
        let quf: Vec<f32> = params.uf.iter().map(|&v| wf.quantize_f32(v)).collect();
        let qbf: Vec<f32> = params.bf.iter().map(|&v| wf.quantize_f32(v)).collect();

        let mut h = vec![0.0f32; hid];
        for t in 0..seq {
            let x = &xs[t * i_sz..(t + 1) * i_sz];
            for _ in 0..c.solver_steps {
                let mut pre = qbf.clone();
                for (ii, &xv) in x.iter().enumerate() {
                    for j in 0..hid {
                        pre[j] += xv * qwf[ii * hid + j];
                    }
                }
                for (hi, &hv) in h.iter().enumerate() {
                    for j in 0..hid {
                        pre[j] += hv * quf[hi * hid + j];
                    }
                }
                for j in 0..hid {
                    let f = af.quantize_f32(sig.eval(af.quantize_f32(pre[j]) as f64) as f32);
                    let num = h[j] + dt * f * params.a[j];
                    let den = 1.0 + dt * (1.0 / params.tau[j] + f);
                    h[j] = af.quantize_f32(num / den);
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::gru_accel::{GruAccel, GruAccelConfig};
    use crate::mr::ltc::LtcCell;
    use crate::util::Prng;

    #[test]
    fn ltc_much_slower_than_any_gru_config() {
        let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
        let gru = GruAccel::new(GruAccelConfig::gru_baseline()).report();
        // Paper: LTC interval 12014 vs GRU 271 (~44×); we require ≫.
        assert!(
            ltc.interval > 5 * gru.interval,
            "ltc={} gru={}",
            ltc.interval,
            gru.interval
        );
        assert!(ltc.cycles > gru.cycles);
    }

    #[test]
    fn solver_steps_scale_latency_linearly() {
        let mut c3 = LtcAccelConfig::base();
        c3.solver_steps = 3;
        let mut c6 = LtcAccelConfig::base();
        c6.solver_steps = 6;
        let r3 = LtcAccel::new(c3).report();
        let r6 = LtcAccel::new(c6).report();
        assert_eq!(r6.cycles, 2 * r3.cycles);
    }

    #[test]
    fn ltc_energy_dwarfs_gru_energy() {
        let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
        let gru = GruAccel::new(GruAccelConfig::concurrent()).report();
        // Paper: GRU configs are ~98-99% lower energy/output than LTC.
        assert!(ltc.energy_per_output_j > 10.0 * gru.energy_per_output_j);
    }

    #[test]
    fn ltc_window_at_least_4x_dataflow_gru_window() {
        // The paper's §6 headline trend: the dataflow GRU needs ≥ 4×
        // (they report 6.3×+) fewer cycles than the sequential LTC on a
        // streaming window. `BENCH_cycles.json` records the exact ratio.
        let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
        let gru = GruAccel::new(GruAccelConfig::concurrent()).report();
        let ratio = ltc.window_cycles(64) as f64 / gru.window_cycles(64) as f64;
        assert!(ratio >= 4.0, "ltc/gru window cycle ratio {ratio}");
        assert_eq!(ltc.window_cycles(64), 64 * ltc.interval);
    }

    #[test]
    fn fixed_forward_tracks_f32_ltc() {
        let mut rng = Prng::new(5);
        let cfg = LtcAccelConfig::base();
        let params = LtcParams::random(cfg.input, cfg.hidden, &mut rng, 0.3);
        let accel = LtcAccel::new(cfg.clone());
        let xs = rng.normal_vec_f32(24 * cfg.input, 0.8);
        let fixed = accel.forward_fixed(&params, &xs, 24, 0.1);
        let float = LtcCell::new(params, cfg.solver_steps as usize).run(&xs, 24, 0.1);
        let err: f32 = fixed
            .iter()
            .zip(&float)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.15, "LTC fixed-point drift {err}");
    }
}

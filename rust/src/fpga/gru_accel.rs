//! The MERINDA GRU accelerator model (paper §5, Fig. 5–6, Tables 7–8).
//!
//! Assembles the four-stage GRU forward pipeline from the HLS scheduler
//! primitives and evaluates it two ways:
//!
//! * **Structurally** — [`GruAccel::report`] derives cycles, interval,
//!   resources, power and energy from the schedule (what Tables 7/8 show).
//! * **Functionally** — [`GruAccel::forward_fixed`] executes the same
//!   datapath numerically in fixed-point with LUT activation tables, so
//!   quantization accuracy is measurable against the f32 reference
//!   (`mr::gru::GruCell`).
//!
//! Timing definitions used throughout this repo (the paper's own Table 8
//! mixes several; see EXPERIMENTS.md notes):
//! * `cycles`   — end-to-end latency for one GRU step (pipeline fill).
//! * `interval` — steady-state spacing between outputs on a long stream.

use super::bram::{BankedArray, Partition};
use super::fixedpoint::FixedFormat;
use super::graph::{lower, Graph, LoweredGraph, Op, Target};
use super::hls::{schedule, Binding, LoopNest, ScheduledLoop};
use super::interconnect::DdrModel;
use super::lut::{Activation, ActivationTable};
use super::pipeline::Pipeline;
use super::power::PowerModel;
use super::resources::{Device, Resources};
use crate::mr::gru::GruParams;
use crate::mr::linalg;

// The stage-map vocabulary lives in the graph IR now; re-exported here
// so existing `fpga::gru_accel::{...}` imports keep working.
pub use super::graph::{all_stage_maps, stage_map_name, StageMap};

/// GRU accelerator configuration.
#[derive(Clone, Debug)]
pub struct GruAccelConfig {
    /// Input vector width fed per time step.
    pub input: usize,
    /// Hidden units.
    pub hidden: usize,
    /// UNROLL factor: parallel MAC lanes per matvec stage.
    pub unroll: u32,
    /// ARRAY_PARTITION factor on the weight arrays.
    pub banks: u32,
    /// ARRAY_RESHAPE factor (wide words).
    pub reshape: u32,
    /// DATAFLOW on/off (stage overlap).
    pub dataflow: bool,
    /// Spill intermediates to DDR between stages (pre-optimization
    /// baseline behaviour; off when DATAFLOW FIFOs are used).
    pub ddr_spill: bool,
    /// Per-stage fabric binding.
    pub stage_map: StageMap,
    /// Fixed-point activation format.
    pub act_fmt: FixedFormat,
    /// Fixed-point weight format.
    pub weight_fmt: FixedFormat,
    /// Inter-stage FIFO depth (elements).
    pub fifo_depth: u32,
}

impl GruAccelConfig {
    /// Paper-scale accelerator dims (their HLS design; distinct from the
    /// L2 training model size).
    pub fn base() -> GruAccelConfig {
        GruAccelConfig {
            input: 4,
            hidden: 16,
            unroll: 8,
            banks: 1,
            reshape: 1,
            dataflow: false,
            ddr_spill: true,
            stage_map: [Binding::Dsp; 4],
            act_fmt: FixedFormat::new(16, 8),
            weight_fmt: FixedFormat::new(16, 8),
            fifo_depth: 256,
        }
    }

    /// Table 8 row 2: conventional GRU forward, no concurrency.
    pub fn gru_baseline() -> GruAccelConfig {
        GruAccelConfig::base()
    }

    /// Table 8 row 3: + DATAFLOW concurrency (on-chip FIFOs, banked ×4).
    pub fn concurrent() -> GruAccelConfig {
        GruAccelConfig {
            unroll: 32,
            banks: 8,
            dataflow: true,
            ddr_spill: false,
            stage_map: [Binding::Dsp, Binding::Lut, Binding::Lut, Binding::Dsp],
            ..GruAccelConfig::base()
        }
    }

    /// Table 8 row 4: + aggressive BRAM banking and wider unroll.
    pub fn bram_optimal() -> GruAccelConfig {
        GruAccelConfig {
            unroll: 96,
            banks: 32,
            reshape: 4,
            dataflow: true,
            ddr_spill: false,
            stage_map: [Binding::Dsp; 4],
            ..GruAccelConfig::base()
        }
    }

    /// With a different stage map (Table 7 sweep).
    pub fn with_stage_map(mut self, m: StageMap) -> GruAccelConfig {
        self.stage_map = m;
        self
    }

    /// The concurrent (DATAFLOW) configuration at arbitrary model dims
    /// with explicit numeric formats — the cycle model behind the
    /// quantized serving backend (`coordinator::FixedPointBackend`).
    pub fn serving(
        input: usize,
        hidden: usize,
        act_fmt: FixedFormat,
        weight_fmt: FixedFormat,
    ) -> GruAccelConfig {
        GruAccelConfig {
            input,
            hidden,
            act_fmt,
            weight_fmt,
            ..GruAccelConfig::concurrent()
        }
    }

    /// MACs in stage 1 (gate affines: W·x for 3 gates + U·h for r,z).
    pub fn stage1_macs(&self) -> u64 {
        (self.input * 3 * self.hidden + self.hidden * 2 * self.hidden) as u64
    }

    /// MACs in stage 3 (candidate recurrent term (r∘h)·Un).
    pub fn stage3_macs(&self) -> u64 {
        (self.hidden * self.hidden) as u64
    }
}

/// Structural evaluation of one configuration.
#[derive(Clone, Debug)]
pub struct AccelReport {
    pub name: String,
    /// End-to-end latency for one GRU step.
    pub cycles: u64,
    /// Steady-state output spacing.
    pub interval: u64,
    pub resources: Resources,
    pub power_w: f64,
    /// Energy per produced hidden-state vector (J).
    pub energy_per_output_j: f64,
    /// Achieved II of the binding stage.
    pub worst_stage_ii: u32,
    pub fits_pynq: bool,
}

impl AccelReport {
    /// Cycles to stream a `seq`-step window: the first step pays the
    /// pipeline fill (`cycles`), subsequent steps the steady-state
    /// `interval`. For non-DATAFLOW configurations `cycles == interval`,
    /// so this reduces to `seq · interval`.
    pub fn window_cycles(&self, seq: u64) -> u64 {
        if seq == 0 {
            0
        } else {
            self.cycles + (seq - 1) * self.interval
        }
    }
}

/// The assembled accelerator.
///
/// # Example
///
/// ```
/// use merinda::fpga::gru_accel::{GruAccel, GruAccelConfig};
///
/// let base = GruAccel::new(GruAccelConfig::gru_baseline()).report();
/// let conc = GruAccel::new(GruAccelConfig::concurrent()).report();
/// // DATAFLOW stage overlap shortens the steady-state interval...
/// assert!(conc.interval < base.interval);
/// // ...and the concurrent design still fits the PYNQ-Z2 fabric.
/// assert!(conc.fits_pynq);
/// ```
pub struct GruAccel {
    pub cfg: GruAccelConfig,
    pub ddr: DdrModel,
    pub power: PowerModel,
    pub device: Device,
}

impl GruAccel {
    pub fn new(cfg: GruAccelConfig) -> GruAccel {
        GruAccel {
            cfg,
            ddr: DdrModel::default(),
            power: PowerModel::default(),
            device: Device::pynq_z2(),
        }
    }

    fn weight_array(&self, name: &str, elements: u64) -> BankedArray {
        let mut a = BankedArray::new(name, elements, self.cfg.weight_fmt.word_bits);
        if self.cfg.banks > 1 {
            a = a.partitioned(Partition::Cyclic(self.cfg.banks));
        }
        if self.cfg.reshape > 1 {
            a = a.reshaped(self.cfg.reshape);
        }
        a
    }

    /// Schedule the four stages of Fig. 6.
    pub fn stages(&self) -> Vec<ScheduledLoop> {
        let c = &self.cfg;
        let h = c.hidden as u64;

        // Stage 1: gate affines. One weight read per MAC lane per cycle.
        let w_elems = (c.input * 3 * c.hidden + c.hidden * 2 * c.hidden) as u64;
        let s1 = schedule(
            &LoopNest::new("s1_gate_affine", c.stage1_macs())
                .unrolled(c.unroll)
                .macs(1)
                .bound(c.stage_map[0])
                .with_array(self.weight_array("gate_weights", w_elems), 1, 0),
        );

        // Stage 2: sigmoid(r), sigmoid(z) lookups + reset modulation r∘h.
        // Under DATAFLOW the pre-activations arrive through STREAM FIFOs
        // (1 pop/cycle/lane, no BRAM port contention — §5.3.2); without it
        // they sit in a shared BRAM buffer and compete for ports.
        let act_lanes = c.unroll.min(2 * c.hidden as u32);
        let mut s2_loop = LoopNest::new("s2_sigmoid", 2 * h)
            .unrolled(act_lanes)
            .activations(1)
            .elementwise(1)
            .bound(c.stage_map[1]);
        if !c.dataflow {
            s2_loop = s2_loop.with_array(self.weight_array("h_prev", h).reshaped(c.reshape), 1, 0);
        }
        let s2 = schedule(&s2_loop);

        // Stage 3: candidate (r∘h)·Un + tanh.
        let s3 = schedule(
            &LoopNest::new("s3_candidate", c.stage3_macs())
                .unrolled(c.unroll)
                .macs(1)
                .activations(1)
                .bound(c.stage_map[2])
                .with_array(self.weight_array("Un", h * h), 1, 0),
        );

        // Stage 4: interpolation h' = (1−z)∘n + z∘h (2 mul + 1 add each).
        // Same FIFO-vs-buffer distinction as stage 2.
        let mut s4_loop = LoopNest::new("s4_interp", h)
            .unrolled(c.unroll.min(c.hidden as u32))
            .elementwise(3)
            .bound(c.stage_map[3]);
        if !c.dataflow {
            s4_loop = s4_loop.with_array(self.weight_array("z_gate", h), 2, 1);
        }
        let s4 = schedule(&s4_loop);

        vec![s1, s2, s3, s4]
    }

    /// The four-stage pipeline of Fig. 6 as a dataflow graph: the same
    /// ops, arrays and annotations [`GruAccel::stages`] schedules by
    /// hand, expressed in the IR so [`lower`] (and through it the tuner
    /// and placement) can compile it. `rust/tests/graph.rs` asserts the
    /// lowered schedule cycle-exact against `stages()` across the whole
    /// tuner search space.
    pub fn graph(&self) -> Graph {
        let c = &self.cfg;
        let h = c.hidden as u64;
        let mut g = Graph::new(stage_map_name(&c.stage_map), c.act_fmt, c.weight_fmt)
            .streaming(c.dataflow, c.ddr_spill)
            .with_fifo_depth(c.fifo_depth)
            .with_io_elems((c.input + c.hidden) as u64);

        // Stage 1: gate affines. One weight read per MAC lane per cycle.
        let w_elems = (c.input * 3 * c.hidden + c.hidden * 2 * c.hidden) as u64;
        let s1 = g.push_op(
            Op::matvec("s1_gate_affine", c.stage1_macs())
                .unrolled(c.unroll)
                .bound(c.stage_map[0])
                .with_array(self.weight_array("gate_weights", w_elems), 1, 0),
        );

        // Stage 2: sigmoid(r), sigmoid(z) lookups + reset modulation r∘h.
        let act_lanes = c.unroll.min(2 * c.hidden as u32);
        let mut s2_op = Op::nonlinearity("s2_sigmoid", 2 * h)
            .unrolled(act_lanes)
            .elementwise_ops(1)
            .bound(c.stage_map[1]);
        if !c.dataflow {
            s2_op = s2_op.with_array(self.weight_array("h_prev", h).reshaped(c.reshape), 1, 0);
        }
        let s2 = g.push_op(s2_op);

        // Stage 3: candidate (r∘h)·Un + tanh.
        let s3 = g.push_op(
            Op::matvec("s3_candidate", c.stage3_macs())
                .unrolled(c.unroll)
                .activations(1)
                .bound(c.stage_map[2])
                .with_array(self.weight_array("Un", h * h), 1, 0),
        );

        // Stage 4: interpolation h' = (1−z)∘n + z∘h.
        let mut s4_op = Op::elementwise("s4_interp", h, 3)
            .unrolled(c.unroll.min(c.hidden as u32))
            .bound(c.stage_map[3]);
        if !c.dataflow {
            s4_op = s4_op.with_array(self.weight_array("z_gate", h), 2, 1);
        }
        let s4 = g.push_op(s4_op);

        // Edge volumes carry the DDR-spill accounting: 3H gate
        // pre-activations out + back (r_pre/z_pre/h_pre), then the r/z/n
        // intermediates one way.
        g.connect(s1, s2, 3 * h, 2);
        g.connect(s2, s3, 2 * h, 1);
        g.connect(s3, s4, h, 1);
        g
    }

    fn target(&self) -> Target {
        Target {
            device: self.device,
            ddr: self.ddr,
            power: self.power,
        }
    }

    /// The graph compiled for this accelerator's target.
    fn lowered(&self) -> LoweredGraph {
        lower(&self.graph(), &self.target()).expect("GRU graph is well-formed by construction")
    }

    /// The four scheduled stages as a DATAFLOW stage pipeline, one item
    /// per GRU step: each stage's service time (its internal loop drain)
    /// is both its per-item initiation interval and its latency. Shared
    /// by the quantized serving backend's cycle report and the `cycles`
    /// bench so the two can never diverge.
    pub fn stage_pipeline(&self) -> Pipeline {
        self.lowered().stage_pipeline()
    }

    /// Structural report for this configuration, derived by lowering
    /// [`GruAccel::graph`] through the shared graph compiler.
    pub fn report(&self) -> AccelReport {
        let low = self.lowered();
        AccelReport {
            name: low.name,
            cycles: low.cycles,
            interval: low.interval,
            resources: low.resources,
            power_w: low.power_w,
            energy_per_output_j: low.energy_per_output_j,
            worst_stage_ii: low.worst_stage_ii,
            fits_pynq: low.fits,
        }
    }

    /// Structural report for one *training* step (paper §6.2: forward and
    /// backpropagation both run on the fabric).
    ///
    /// BPTT reverses the same dataflow with roughly 2× the forward MAC
    /// volume (∂h→gate deltas reuse Uᵀ/Wᵀ; weight-gradient accumulation
    /// adds an outer-product pass), plus a weight-update sweep. No stage
    /// overlap exists across the forward/backward boundary (the backward
    /// pass needs the cached activations of the whole window), so training
    /// interval ≈ fwd interval + bwd interval + update.
    pub fn training_report(&self) -> AccelReport {
        let fwd = self.report();
        let c = &self.cfg;
        // Backward MAC volume ≈ 2× forward (delta backprop + weight grads).
        let bwd_macs = 2 * (c.stage1_macs() + c.stage3_macs());
        let lanes = c.unroll.max(1) as u64;
        let mem_ii = fwd.worst_stage_ii as u64;
        let bwd_cycles = 6 + bwd_macs.div_ceil(lanes) * mem_ii;
        // Weight update: one read-modify-write per parameter through the
        // banked ports.
        let params = (c.input * 3 * c.hidden + c.hidden * 3 * c.hidden) as u64;
        let ports = (2 * c.banks * c.reshape).max(2) as u64;
        let upd_cycles = params.div_ceil(ports);
        let interval = fwd.interval + bwd_cycles + upd_cycles;
        let cycles = fwd.cycles + bwd_cycles + upd_cycles;
        // Backward reuses the forward MAC lanes (time-multiplexed), adds
        // gradient accumulators (FF-heavy) and the cached-activation BRAM.
        let mut res = fwd.resources;
        res += Resources::new(2_400, 9_000, 0, 4);
        let power_w = fwd.power_w * 1.12; // higher sustained activity
        let energy = power_w * interval as f64 / (self.device.clock_mhz * 1e6);
        AccelReport {
            name: format!("{}_train", fwd.name),
            cycles,
            interval,
            resources: res,
            power_w,
            energy_per_output_j: energy,
            worst_stage_ii: fwd.worst_stage_ii,
            fits_pynq: self.device.fits(&res),
        }
    }

    /// Functional fixed-point forward pass through the modeled datapath.
    ///
    /// Quantizes weights/activations to the configured formats and
    /// evaluates sigmoid/tanh through the LUT tables — the numbers a real
    /// bitstream would produce. `xs` is (K, input) row-major.
    pub fn forward_fixed(&self, params: &GruParams, xs: &[f32], seq: usize) -> Vec<f32> {
        let c = &self.cfg;
        assert_eq!(params.input, c.input);
        assert_eq!(params.hidden, c.hidden);
        let (i_sz, hid) = (c.input, c.hidden);
        let th = 3 * hid;
        let wf = c.weight_fmt;
        let af = c.act_fmt;
        let sig = ActivationTable::default_for(Activation::Sigmoid);
        let tanh = ActivationTable::default_for(Activation::Tanh);

        // Quantize weights once (they live in BRAM).
        let qw: Vec<f32> = params.w.iter().map(|&v| wf.quantize_f32(v)).collect();
        let qu: Vec<f32> = params.u.iter().map(|&v| wf.quantize_f32(v)).collect();
        let qb: Vec<f32> = params.b.iter().map(|&v| wf.quantize_f32(v)).collect();

        // Scratch buffers reused across time steps (§Perf: the original
        // per-step allocations dominated this emulation loop).
        let mut h = vec![0.0f32; hid];
        let mut x = vec![0.0f32; i_sz];
        let mut gx = vec![0.0f32; th];
        let mut gh = vec![0.0f32; 2 * hid];
        let mut r = vec![0.0f32; hid];
        let mut z = vec![0.0f32; hid];
        let mut cand = vec![0.0f32; hid];
        let mut n = vec![0.0f32; hid];
        for t in 0..seq {
            for (xd, &xv) in x.iter_mut().zip(&xs[t * i_sz..(t + 1) * i_sz]) {
                *xd = af.quantize_f32(xv);
            }

            // Stage 1: gate affines with quantized accumulate (shared
            // linalg kernels; same ascending-k order as the f32 reference).
            gx.copy_from_slice(&qb);
            linalg::matvec_acc(i_sz, th, &x, &qw, th, &mut gx);
            gh.fill(0.0);
            linalg::matvec_acc(hid, 2 * hid, &h, &qu, th, &mut gh);
            for v in gx.iter_mut() {
                *v = af.quantize_f32(*v);
            }
            for v in gh.iter_mut() {
                *v = af.quantize_f32(*v);
            }

            // Stage 2: LUT sigmoids + reset modulation.
            for j in 0..hid {
                r[j] = af.quantize_f32(sig.eval_f32(gx[j] + gh[j]));
                z[j] = af.quantize_f32(sig.eval_f32(gx[hid + j] + gh[hid + j]));
            }

            // Stage 3: candidate.
            cand.fill(0.0);
            for hi in 0..hid {
                let rh = af.quantize_f32(r[hi] * h[hi]);
                if rh != 0.0 {
                    linalg::axpy(&mut cand, rh, &qu[hi * th + 2 * hid..(hi + 1) * th]);
                }
            }
            for j in 0..hid {
                n[j] = af.quantize_f32(tanh.eval_f32(gx[2 * hid + j] + af.quantize_f32(cand[j])));
            }

            // Stage 4: interpolation.
            for j in 0..hid {
                h[j] = af.quantize_f32((1.0 - z[j]) * n[j] + z[j] * h[j]);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mr::gru::GruCell;
    use crate::util::Prng;

    #[test]
    fn dataflow_improves_interval() {
        let base = GruAccel::new(GruAccelConfig::gru_baseline()).report();
        let conc = GruAccel::new(GruAccelConfig::concurrent()).report();
        assert!(
            conc.interval < base.interval,
            "conc={} base={}",
            conc.interval,
            base.interval
        );
        assert!(conc.cycles < base.cycles);
    }

    #[test]
    fn banking_improves_interval_further() {
        let conc = GruAccel::new(GruAccelConfig::concurrent()).report();
        let bank = GruAccel::new(GruAccelConfig::bram_optimal()).report();
        assert!(bank.interval < conc.interval);
        // ...at a steep resource cost (paper: DSP ×3, LUT ×14 vs concurrent).
        assert!(bank.resources.dsp > 2 * conc.resources.dsp);
    }

    #[test]
    fn concurrent_fits_pynq_banked_overflows() {
        let conc = GruAccel::new(GruAccelConfig::concurrent()).report();
        assert!(conc.fits_pynq, "{:?}", conc.resources);
        let bank = GruAccel::new(GruAccelConfig::bram_optimal()).report();
        // Paper's BRAM-optimal row exceeds the 7020 too (276 k LUTs).
        assert!(!bank.fits_pynq || bank.resources.dsp > 220);
    }

    #[test]
    fn stage_map_lut_heavy_reduces_dsp() {
        let all_d = GruAccel::new(
            GruAccelConfig::concurrent().with_stage_map([Binding::Dsp; 4]),
        )
        .report();
        let all_l = GruAccel::new(
            GruAccelConfig::concurrent().with_stage_map([Binding::Lut; 4]),
        )
        .report();
        assert!(all_l.resources.dsp < all_d.resources.dsp / 2);
        assert!(all_l.resources.lut > all_d.resources.lut);
    }

    #[test]
    fn fixed_point_forward_tracks_f32() {
        let mut rng = Prng::new(77);
        let cfg = GruAccelConfig::concurrent();
        let params = GruParams::random(cfg.input, cfg.hidden, &mut rng, 0.3);
        let accel = GruAccel::new(cfg);
        let seq = 32;
        let xs = rng.normal_vec_f32(seq * accel.cfg.input, 0.8);

        let fixed = accel.forward_fixed(&params, &xs, seq);
        let float = GruCell::new(params).run(&xs, seq);
        let err: f32 = fixed
            .iter()
            .zip(&float)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        // Q8.8 activations: per-step error ~2^-8, accumulated over 32 steps
        // stays well under 0.1 (paper: "preserving fidelity").
        assert!(err < 0.1, "fixed-point drift {err}");
    }

    #[test]
    fn narrower_format_is_less_accurate() {
        let mut rng = Prng::new(3);
        let mut cfg_hi = GruAccelConfig::concurrent();
        cfg_hi.act_fmt = FixedFormat::new(16, 12);
        let mut cfg_lo = GruAccelConfig::concurrent();
        cfg_lo.act_fmt = FixedFormat::new(8, 4);
        let params = GruParams::random(cfg_hi.input, cfg_hi.hidden, &mut rng, 0.3);
        let xs = rng.normal_vec_f32(16 * cfg_hi.input, 0.8);
        let float = GruCell::new(params.clone()).run(&xs, 16);
        let err = |cfg: GruAccelConfig| -> f32 {
            GruAccel::new(cfg)
                .forward_fixed(&params, &xs, 16)
                .iter()
                .zip(&float)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max)
        };
        assert!(err(cfg_lo) > err(cfg_hi));
    }

    #[test]
    fn sixteen_stage_maps_enumerated() {
        let maps = all_stage_maps();
        assert_eq!(maps.len(), 16);
        assert_eq!(stage_map_name(&maps[0]), "s1D_s2D_s3D_s4D");
        assert_eq!(stage_map_name(&maps[15]), "s1L_s2L_s3L_s4L");
    }

    #[test]
    fn training_costs_roughly_three_forwards() {
        // Paper intuition: fwd + bwd(≈2×) + update. The training interval
        // must be 2.5–6× the inference interval across configs.
        for cfg in [
            GruAccelConfig::gru_baseline(),
            GruAccelConfig::concurrent(),
            GruAccelConfig::bram_optimal(),
        ] {
            let a = GruAccel::new(cfg);
            let f = a.report();
            let t = a.training_report();
            let ratio = t.interval as f64 / f.interval as f64;
            assert!(
                (1.5..8.0).contains(&ratio),
                "{}: train/infer interval ratio {ratio}",
                f.name
            );
            assert!(t.resources.ff > f.resources.ff);
            assert!(t.power_w > f.power_w);
        }
    }

    #[test]
    fn window_cycles_fill_plus_steady_state() {
        let conc = GruAccel::new(GruAccelConfig::concurrent()).report();
        assert_eq!(conc.window_cycles(0), 0);
        assert_eq!(conc.window_cycles(1), conc.cycles);
        assert_eq!(conc.window_cycles(64), conc.cycles + 63 * conc.interval);
        // Sequential configs: cycles == interval, so the window is linear.
        let base = GruAccel::new(GruAccelConfig::gru_baseline()).report();
        assert_eq!(base.cycles, base.interval);
        assert_eq!(base.window_cycles(64), 64 * base.interval);
    }

    #[test]
    fn stage_pipeline_matches_scheduled_services() {
        let a = GruAccel::new(GruAccelConfig::concurrent());
        let p = a.stage_pipeline();
        let services: Vec<u64> = a.stages().iter().map(|s| s.cycles).collect();
        assert_eq!(p.analyze(1).fill_latency, services.iter().sum::<u64>());
        assert_eq!(p.analyze(100).interval, *services.iter().max().unwrap());
        assert_eq!(p.simulate(17), p.analyze(17));
    }

    #[test]
    fn serving_config_scales_with_hidden_size() {
        let fmt = FixedFormat::q8_8();
        let small = GruAccel::new(GruAccelConfig::serving(4, 16, fmt, fmt)).report();
        let big = GruAccel::new(GruAccelConfig::serving(4, 32, fmt, fmt)).report();
        assert!(big.interval > small.interval);
        assert!(big.cycles > small.cycles);
    }

    #[test]
    fn report_is_deterministic() {
        let a = GruAccel::new(GruAccelConfig::concurrent()).report();
        let b = GruAccel::new(GruAccelConfig::concurrent()).report();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.interval, b.interval);
        assert_eq!(a.resources, b.resources);
    }
}

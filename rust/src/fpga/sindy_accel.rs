//! SINDy library-evaluation + dense-head accelerator — the first model
//! family described *only* as a graph.
//!
//! The datapath streams one `[x | u]` sample per item through four ops:
//! incremental polynomial-library evaluation (each monomial is one
//! multiply on top of a lower-degree monomial — `mr::library`'s chain),
//! the dense head's first GEMM layer, the ReLU, and the second GEMM
//! layer producing the Θ coefficient estimates (`mr::dense`). Unlike
//! `gru_accel` and `ltc_accel` there is **no hand-built stage schedule
//! anywhere in this module**: [`SindyAccelConfig::graph`] is the whole
//! hardware description, and cycle counts, resources, power, tuning and
//! placement all come from [`lower`](super::graph::lower),
//! [`tune_graph`](super::tuner::tune_graph) and
//! `coordinator::placement::GraphInstanceSpec` — the payoff the graph
//! IR exists for.
//!
//! # Example
//!
//! ```
//! use merinda::fpga::graph::{lower, Target};
//! use merinda::fpga::sindy_accel::SindyAccelConfig;
//!
//! let low = lower(&SindyAccelConfig::concurrent().graph(), &Target::default()).unwrap();
//! assert_eq!(low.stages.len(), 4);
//! assert!(low.fits && low.interval <= low.cycles);
//! ```

use super::bram::{BankedArray, Partition};
use super::fixedpoint::FixedFormat;
use super::graph::{stage_map_name, Graph, Op, StageMap};
use super::hls::Binding;
use super::tuner::{DesignPoint, Tile};
use crate::mr::library::library_size;

/// SINDy-head accelerator configuration: model dims plus the same
/// design axes the tuner sweeps for every family.
#[derive(Clone, Debug)]
pub struct SindyAccelConfig {
    /// State rows per sample.
    pub xdim: usize,
    /// Input rows per sample.
    pub udim: usize,
    /// Polynomial library order.
    pub order: u32,
    /// Dense-head hidden units.
    pub hidden: usize,
    /// Θ coefficients produced per sample (`xdim ×` library terms).
    pub output: usize,
    /// UNROLL factor: parallel lanes per GEMM op.
    pub unroll: u32,
    /// ARRAY_PARTITION factor on the weight arrays.
    pub banks: u32,
    /// ARRAY_RESHAPE factor (wide words).
    pub reshape: u32,
    /// DATAFLOW on/off (op overlap).
    pub dataflow: bool,
    /// Spill intermediates to DDR between ops.
    pub ddr_spill: bool,
    /// Per-op fabric binding.
    pub stage_map: StageMap,
    /// Fixed-point activation format.
    pub act_fmt: FixedFormat,
    /// Fixed-point weight format.
    pub weight_fmt: FixedFormat,
    /// Inter-op FIFO depth (elements).
    pub fifo_depth: u32,
}

impl SindyAccelConfig {
    /// Canonical serving dims (3 states + 1 input, order-2 library → 15
    /// terms, 45 Θ coefficients), sequential DDR-spill baseline.
    pub fn base() -> SindyAccelConfig {
        SindyAccelConfig {
            xdim: 3,
            udim: 1,
            order: 2,
            hidden: 16,
            output: 45,
            unroll: 8,
            banks: 1,
            reshape: 1,
            dataflow: false,
            ddr_spill: true,
            stage_map: [Binding::Dsp; 4],
            act_fmt: FixedFormat::new(16, 8),
            weight_fmt: FixedFormat::new(16, 8),
            fifo_depth: 256,
        }
    }

    /// The DATAFLOW operating point: overlapped ops, FIFO-carried
    /// intermediates, the library op on LUT fabric (it is all single
    /// multiplies — no MAC chains to derate the clock).
    pub fn concurrent() -> SindyAccelConfig {
        SindyAccelConfig {
            unroll: 32,
            banks: 8,
            dataflow: true,
            ddr_spill: false,
            stage_map: [Binding::Lut, Binding::Dsp, Binding::Lut, Binding::Dsp],
            ..SindyAccelConfig::base()
        }
    }

    /// Monomials in the candidate library: C(order + xdim + udim, xdim + udim).
    pub fn library_terms(&self) -> u64 {
        library_size(self.xdim + self.udim, self.order) as u64
    }

    /// Dense-head MAC volume per sample — by construction equal to
    /// `mr::dense::DenseHead::macs()` for an unpruned head of the same
    /// dims (asserted in this module's tests).
    pub fn head_macs(&self) -> u64 {
        let p = self.library_terms();
        p * self.hidden as u64 + self.hidden as u64 * self.output as u64
    }

    /// This configuration's position on the shared tuner axes.
    pub fn design_point(&self) -> DesignPoint {
        DesignPoint {
            tile: Tile::new(self.unroll, self.banks, self.reshape),
            stage_map: self.stage_map,
            act_fmt: self.act_fmt,
            weight_fmt: self.weight_fmt,
            dataflow: self.dataflow,
        }
    }

    /// The same model dims at another design point (the tuner's
    /// candidate-mutation rule: tile → unroll/banks/reshape, DATAFLOW
    /// vs DDR-spill, adder mix, formats).
    pub fn at_point(&self, p: &DesignPoint) -> SindyAccelConfig {
        SindyAccelConfig {
            unroll: p.tile.unroll,
            banks: p.tile.banks,
            reshape: p.tile.reshape,
            dataflow: p.dataflow,
            ddr_spill: !p.dataflow,
            stage_map: p.stage_map,
            act_fmt: p.act_fmt,
            weight_fmt: p.weight_fmt,
            ..self.clone()
        }
    }

    /// The family closure [`tune_graph`](super::tuner::tune_graph)
    /// sweeps: design point in, graph out.
    pub fn family(&self) -> impl Fn(&DesignPoint) -> Graph + '_ {
        |p: &DesignPoint| self.at_point(p).graph()
    }

    fn weight_array(&self, name: &str, elements: u64) -> BankedArray {
        let mut a = BankedArray::new(name, elements, self.weight_fmt.word_bits);
        if self.banks > 1 {
            a = a.partitioned(Partition::Cyclic(self.banks));
        }
        if self.reshape > 1 {
            a = a.reshaped(self.reshape);
        }
        a
    }

    /// The whole hardware description: four ops, three edges, nothing
    /// scheduled by hand.
    pub fn graph(&self) -> Graph {
        let p = self.library_terms();
        let h = self.hidden as u64;
        let o = self.output as u64;
        let mut g = Graph::new(
            format!("sindy_{}", stage_map_name(&self.stage_map)),
            self.act_fmt,
            self.weight_fmt,
        )
        .streaming(self.dataflow, self.ddr_spill)
        .with_fifo_depth(self.fifo_depth)
        .with_io_elems((self.xdim + self.udim) as u64 + o);

        // Op 1: incremental library evaluation — one multiply per
        // monomial on top of an already-computed lower-degree monomial.
        // Without DATAFLOW the φ vector sits in a shared BRAM buffer and
        // the read-modify-write traffic competes for its ports.
        let mut s1_op = Op::elementwise("s1_library", p, 1)
            .unrolled(self.unroll.min(p as u32))
            .bound(self.stage_map[0]);
        if !self.dataflow {
            s1_op = s1_op.with_array(BankedArray::new("phi", p, self.act_fmt.word_bits), 1, 1);
        }
        let s1 = g.push_op(s1_op);

        // Op 2: dense-head layer 1 (φ → hidden GEMM).
        let s2 = g.push_op(
            Op::matvec("s2_head_l1", p * h)
                .unrolled(self.unroll)
                .bound(self.stage_map[1])
                .with_array(self.weight_array("w1", p * h), 1, 0),
        );

        // Op 3: ReLU through the activation tables.
        let s3 = g.push_op(
            Op::nonlinearity("s3_relu", h)
                .unrolled(self.unroll.min(self.hidden as u32))
                .bound(self.stage_map[2]),
        );

        // Op 4: dense-head layer 2 (hidden → Θ GEMM).
        let s4 = g.push_op(
            Op::matvec("s4_head_l2", h * o)
                .unrolled(self.unroll)
                .bound(self.stage_map[3])
                .with_array(self.weight_array("w2", h * o), 1, 0),
        );

        // φ out + back when spilled; hidden activations one way each.
        g.connect(s1, s2, p, 2);
        g.connect(s2, s3, h, 1);
        g.connect(s3, s4, h, 1);
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::graph::{lower, Target};
    use crate::mr::dense::DenseHead;
    use crate::util::Prng;

    #[test]
    fn library_terms_match_mr_library() {
        let cfg = SindyAccelConfig::base();
        assert_eq!(cfg.library_terms(), 15); // C(2+4, 4)
        assert_eq!(cfg.output as u64, cfg.xdim as u64 * cfg.library_terms());
    }

    #[test]
    fn head_macs_match_dense_head_cost_model() {
        let cfg = SindyAccelConfig::base();
        let mut rng = Prng::new(11);
        let head = DenseHead::random(
            cfg.library_terms() as usize,
            cfg.hidden,
            cfg.output,
            &mut rng,
        );
        assert_eq!(cfg.head_macs(), head.macs());
    }

    #[test]
    fn graph_is_well_formed_and_concurrent_fits_pynq() {
        for cfg in [SindyAccelConfig::base(), SindyAccelConfig::concurrent()] {
            let g = cfg.graph();
            g.validate().unwrap();
            let low = lower(&g, &Target::default()).unwrap();
            assert_eq!(low.stages.len(), 4);
            assert!(low.cycles > 0 && low.interval > 0);
        }
        let conc = lower(&SindyAccelConfig::concurrent().graph(), &Target::default()).unwrap();
        assert!(conc.fits, "{:?}", conc.resources);
    }

    #[test]
    fn dataflow_beats_ddr_spill_baseline() {
        let t = Target::default();
        let base = lower(&SindyAccelConfig::base().graph(), &t).unwrap();
        let conc = lower(&SindyAccelConfig::concurrent().graph(), &t).unwrap();
        assert!(
            conc.interval < base.interval,
            "conc={} base={}",
            conc.interval,
            base.interval
        );
    }

    #[test]
    fn design_point_round_trips() {
        let cfg = SindyAccelConfig::concurrent();
        let p = cfg.design_point();
        let back = cfg.at_point(&p);
        assert_eq!(back.unroll, cfg.unroll);
        assert_eq!(back.banks, cfg.banks);
        assert_eq!(back.dataflow, cfg.dataflow);
        assert_eq!(back.stage_map, cfg.stage_map);
    }
}

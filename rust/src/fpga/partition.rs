//! Multi-board graph partitioning: split one accelerator design across
//! the fleet.
//!
//! `coordinator::placement` is whole-window-to-one-board: a design whose
//! tiles exceed one device's BRAM is simply infeasible, no matter how
//! many boards sit idle. This module cuts a validated
//! [`Graph`](super::graph::Graph) into per-board subgraphs **along its
//! FIFO edges** — every cut edge becomes an explicit board-to-board
//! [`Link`](super::cluster::Link) transfer ([`LinkHop`]) with
//! serialization *and* latency modeled separately — lowers each subgraph
//! through the unchanged [`lower`] path on its own
//! [`Target`](super::graph::Target), and composes a [`PartitionedPlan`]
//! whose end-to-end window timing is the max-plus composition of the
//! member stage pipelines plus the link hops:
//!
//! ```text
//! fill     = Σ part fill + Σ hop (latency + serialization)
//! interval = max(max part interval, max hop serialization)
//! window   = fill + (seq − 1) · interval
//! ```
//!
//! Links are double-buffered (one buffer drains to the wire while the
//! next item fills), so hop *latency* is paid once in the fill and
//! steady-state throughput is bounded by the slowest board or the
//! busiest wire — never the sum of the boards. A zero-cut partition runs
//! the whole graph through the same code path, which is why
//! `rust/tests/partition.rs` can hold the composition cycle-exact
//! against whole-graph lowering.
//!
//! [`best_partition`] sweeps every contiguous cut assignment (the
//! whole-graph candidate included), tallying fit and timing-closure
//! rejections separately through the tuner's feasibility ledger, and
//! [`PartitionedInstanceSpec`](crate::coordinator::placement::PartitionedInstanceSpec)
//! turns the winning plan into a fleet cost model so split and
//! whole-window plans rank against each other per tenant.
//!
//! # Example
//!
//! ```
//! use merinda::fpga::gru_accel::GruAccelConfig;
//! use merinda::fpga::partition::{best_partition, pynq_rack};
//!
//! // A GRU too big for one PYNQ-Z2 streams once split across two.
//! let fmt = merinda::fpga::fixedpoint::FixedFormat::q8_8();
//! let g = GruAccelConfig::serving(4, 384, fmt, fmt).graph();
//! let out = best_partition(&g, &pynq_rack(2), 64).unwrap();
//! assert!(out.plan.n_parts() > 1 && out.plan.feasible());
//! ```

use super::cluster::Link;
use super::fixedpoint::FixedFormat;
use super::graph::{lower, Edge, Graph, LoweredGraph, Profile, Target};
use super::pipeline::PipelineTiming;
use super::resources::{Device, Resources};
use super::tuner::FeasibilityTally;
use crate::util::error::{Error, Result};

/// One board position a partition part can be assigned to.
#[derive(Clone, Debug)]
pub struct BoardSlot {
    pub name: String,
    /// Device + DDR + power calibrations the part lowers onto.
    pub target: Target,
    /// The link *into* this slot: the host ingest link for slot 0, the
    /// board-to-board link carrying its cut traffic otherwise.
    pub link: Link,
    /// The device's stock clock — timing closure of a part is judged
    /// against `base_clock_mhz × clock_scale`, so a derated slot
    /// ([`BoardSlot::derated`]) remembers what it derated from.
    pub base_clock_mhz: f64,
}

impl BoardSlot {
    pub fn new(name: impl Into<String>, device: Device, link: Link) -> BoardSlot {
        BoardSlot {
            name: name.into(),
            target: Target::for_device(device),
            link,
            base_clock_mhz: device.clock_mhz,
        }
    }

    /// The same slot with the PL clock scaled to `scale ×` the stock
    /// clock (capacity unchanged) — how a wide design that cannot close
    /// timing at stock rate still gets a feasible home.
    pub fn derated(mut self, scale: f64) -> BoardSlot {
        let mhz = self.base_clock_mhz * scale;
        self.target.device = self.target.device.with_clock(mhz);
        self
    }
}

/// A rack of `n` identical PYNQ-Z2 slots, every link 10 GbE: the host
/// feeds the head board and cut traffic hops board to board.
pub fn pynq_rack(n: usize) -> Vec<BoardSlot> {
    (0..n)
        .map(|i| BoardSlot::new(format!("pynq-{i}"), Device::pynq_z2(), Link::ten_gbe()))
        .collect()
}

/// Fabric one link endpoint costs a board: MAC/PHY control plus the
/// double-buffered link FIFO pair. Charged per hop endpoint on top of
/// the part's lowered resources.
pub fn link_endpoint_overhead() -> Resources {
    Resources::new(2_400, 3_200, 0, 4)
}

/// A cut edge turned into an explicit board-to-board transfer.
#[derive(Clone, Copy, Debug)]
pub struct LinkHop {
    pub from_part: usize,
    pub to_part: usize,
    /// Producing / consuming op as indices into the *original* graph.
    pub from_op: usize,
    pub to_op: usize,
    /// Elements the original edge carried per item.
    pub elems: u64,
    /// The original edge's DDR round trips — preserved for conservation
    /// accounting only: over the link the value crosses exactly once
    /// (the link FIFO replaces the DDR spill bounce).
    pub round_trips: u64,
    /// Wire bytes per item (`elems ×` activation word bytes).
    pub bytes_per_item: u64,
    /// The link into the consuming part's slot.
    pub link: Link,
}

impl LinkHop {
    /// Wire occupancy per item — the hop's contribution to the
    /// steady-state interval (the buffer drains while the next fills).
    pub fn serialize_s(&self) -> f64 {
        self.bytes_per_item as f64 / self.link.bandwidth_bps
    }

    /// Full one-item traversal (latency + serialization) — paid once in
    /// the pipeline fill.
    pub fn hop_s(&self) -> f64 {
        self.link.transfer_s(self.bytes_per_item)
    }
}

/// One board's share of a partitioned design.
#[derive(Clone, Debug)]
pub struct PartPlan {
    /// Slot name this part is assigned to.
    pub board: String,
    pub device: Device,
    /// Stock clock the slot derated from (equals `device.clock_mhz`
    /// when not derated).
    pub base_clock_mhz: f64,
    /// This part's ops as indices into the original graph.
    pub ops: Vec<usize>,
    /// The subgraph itself (inspectable by tests and reports).
    pub graph: Graph,
    pub lowered: LoweredGraph,
    /// Link endpoint fabric charged on top of the lowered resources.
    pub link_overhead: Resources,
}

impl PartPlan {
    /// Fabric this part consumes: the lowered design plus its link
    /// endpoints.
    pub fn resources(&self) -> Resources {
        self.lowered.resources + self.link_overhead
    }

    /// Part (including link endpoints) fits its device.
    pub fn fits(&self) -> bool {
        self.device.fits(&self.resources())
    }

    /// Part closes timing at the slot's clock: the slot may run at most
    /// `base_clock × clock_scale` for this subgraph's derate class.
    pub fn clock_ok(&self) -> bool {
        self.device.clock_mhz <= self.base_clock_mhz * self.lowered.clock_scale + 1e-9
    }
}

/// Plan-level timing in seconds (members may run at different clocks,
/// so seconds is the only shared currency; [`PartitionedPlan::window_timing`]
/// re-quotes it in cycles at the reference clock).
#[derive(Clone, Copy, Debug)]
pub struct PlanTiming {
    /// First input to last output for the whole window.
    pub total_s: f64,
    /// Steady-state spacing between window items.
    pub interval_s: f64,
    /// First input to first output (part fills + link hops).
    pub fill_s: f64,
}

/// A design split across boards: per-part lowered subgraphs plus the
/// cut-edge link hops, composed into end-to-end window timing.
#[derive(Clone, Debug)]
pub struct PartitionedPlan {
    /// The original graph's name.
    pub name: String,
    /// Activation format (link payload word width).
    pub act_fmt: FixedFormat,
    pub parts: Vec<PartPlan>,
    pub hops: Vec<LinkHop>,
}

impl PartitionedPlan {
    pub fn n_parts(&self) -> usize {
        self.parts.len()
    }

    /// Every part (with its link endpoints) fits its device.
    pub fn fits(&self) -> bool {
        self.parts.iter().all(|p| p.fits())
    }

    /// Every part closes timing at its slot's clock.
    pub fn clock_ok(&self) -> bool {
        self.parts.iter().all(|p| p.clock_ok())
    }

    /// Deployable: fits everywhere and closes timing everywhere.
    pub fn feasible(&self) -> bool {
        self.fits() && self.clock_ok()
    }

    /// Total fabric across all member boards (link endpoints included).
    pub fn resources(&self) -> Resources {
        let mut r = Resources::ZERO;
        for p in &self.parts {
            r += p.resources();
        }
        r
    }

    /// The slowest member's clock — the plan's common cycle currency.
    pub fn reference_clock_mhz(&self) -> f64 {
        self.parts
            .iter()
            .map(|p| p.device.clock_mhz)
            .fold(f64::INFINITY, f64::min)
    }

    fn ref_cycles(&self, seconds: f64) -> u64 {
        (seconds * self.reference_clock_mhz() * 1e6).round() as u64
    }

    /// Pipeline-view steady-state interval: the slowest member's stage
    /// interval or the busiest hop's serialization, whichever binds.
    fn pipeline_interval_s(&self) -> f64 {
        let mut iv = 0f64;
        for p in &self.parts {
            iv = iv.max(p.device.cycles_to_seconds(p.lowered.window_timing(1).interval));
        }
        for h in &self.hops {
            iv = iv.max(h.serialize_s());
        }
        iv
    }

    /// Pipeline-view fill: member fills plus full hop traversals.
    fn pipeline_fill_s(&self) -> f64 {
        let parts: f64 = self
            .parts
            .iter()
            .map(|p| p.device.cycles_to_seconds(p.lowered.window_timing(1).fill_latency))
            .sum();
        let hops: f64 = self.hops.iter().map(LinkHop::hop_s).sum();
        parts + hops
    }

    /// Max-plus window timing in seconds — the composition law the
    /// module docs state, over [`LoweredGraph::window_timing`]'s
    /// pipeline view of each part.
    pub fn window_timing_s(&self, seq: u64) -> PlanTiming {
        let interval_s = self.pipeline_interval_s();
        let fill_s = self.pipeline_fill_s();
        let total_s = if seq == 0 {
            0.0
        } else {
            fill_s + (seq - 1) as f64 * interval_s
        };
        PlanTiming {
            total_s,
            interval_s,
            fill_s,
        }
    }

    /// [`window_timing_s`](PartitionedPlan::window_timing_s) re-quoted
    /// in cycles at the reference clock — drop-in for
    /// [`LoweredGraph::window_timing`] in the placement cost model (and
    /// exactly equal to it for a single-part plan).
    pub fn window_timing(&self, seq: u64) -> PipelineTiming {
        let t = self.window_timing_s(seq);
        PipelineTiming {
            total_cycles: self.ref_cycles(t.total_s),
            interval: self.ref_cycles(t.interval_s),
            fill_latency: self.ref_cycles(t.fill_s),
        }
    }

    /// Report-view steady-state interval in seconds (the lowered
    /// `interval` law, DDR cycles included), against the busiest wire.
    pub fn interval_s(&self) -> f64 {
        let mut iv = 0f64;
        for p in &self.parts {
            iv = iv.max(p.device.cycles_to_seconds(p.lowered.interval));
        }
        for h in &self.hops {
            iv = iv.max(h.serialize_s());
        }
        iv
    }

    /// Report-view fill in seconds: member one-item latencies plus full
    /// hop traversals.
    pub fn fill_s(&self) -> f64 {
        let parts: f64 = self
            .parts
            .iter()
            .map(|p| p.device.cycles_to_seconds(p.lowered.cycles))
            .sum();
        let hops: f64 = self.hops.iter().map(LinkHop::hop_s).sum();
        parts + hops
    }

    /// Report-style window seconds: fill then steady state — the
    /// partitioned counterpart of [`LoweredGraph::window_cycles`] at
    /// each member's own clock.
    pub fn window_s(&self, seq: u64) -> f64 {
        if seq == 0 {
            return 0.0;
        }
        self.fill_s() + (seq - 1) as f64 * self.interval_s()
    }

    /// [`window_s`](PartitionedPlan::window_s) in reference-clock cycles
    /// (exactly [`LoweredGraph::window_cycles`] for a single-part plan).
    pub fn window_cycles(&self, seq: u64) -> u64 {
        self.ref_cycles(self.window_s(seq))
    }

    /// Report-view interval in reference-clock cycles.
    pub fn interval_cycles(&self) -> u64 {
        self.ref_cycles(self.interval_s())
    }

    /// Index of the member bounding steady-state throughput (ties break
    /// toward the earlier part).
    pub fn slowest_part(&self) -> usize {
        let mut best = 0usize;
        let mut best_s = f64::NEG_INFINITY;
        for (i, p) in self.parts.iter().enumerate() {
            let s = p.device.cycles_to_seconds(p.lowered.interval);
            if s > best_s {
                best_s = s;
                best = i;
            }
        }
        best
    }
}

/// Deterministic topological order over a validated graph's ops (Kahn,
/// lowest ready index first).
fn topo_order(g: &Graph) -> Vec<usize> {
    let n = g.ops.len();
    let mut indeg = vec![0usize; n];
    for e in &g.edges {
        indeg[e.to] += 1;
    }
    let mut done = vec![false; n];
    let mut order = Vec::with_capacity(n);
    while order.len() < n {
        let i = (0..n)
            .find(|&i| !done[i] && indeg[i] == 0)
            .expect("validated graphs are acyclic");
        done[i] = true;
        order.push(i);
        for e in &g.edges {
            if e.from == i {
                indeg[e.to] -= 1;
            }
        }
    }
    order
}

/// Cut a validated graph into `cuts.len() + 1` contiguous parts of its
/// topological order and assign them to `slots` in order.
///
/// `cuts` are boundary positions in `1..n_ops`, strictly increasing: cut
/// `c` places the first `c` topo-ordered ops before the boundary. Every
/// inter-part edge then points from a lower part to a higher one by
/// construction (cut acyclicity), and becomes a [`LinkHop`] on the
/// consuming slot's link. Part 0 keeps the graph's host I/O
/// (`io_elems`) and explicit [`Transfer`](super::graph::Transfer)s —
/// the head board owns the DMA channel; downstream parts receive
/// everything over cut links.
///
/// Returns the composed plan whether or not it is feasible (callers
/// check [`PartitionedPlan::fits`] / [`PartitionedPlan::clock_ok`]);
/// errors are structural only: invalid graph, iterative profile (every
/// iteration host-syncs, so a split would serialize on the link),
/// malformed cuts, or a slot-count mismatch.
pub fn partition(g: &Graph, cuts: &[usize], slots: &[BoardSlot]) -> Result<PartitionedPlan> {
    g.validate()?;
    if let Profile::Iterative { .. } = g.profile {
        return Err(Error::config(format!(
            "graph {:?} is iterative: it host-syncs every iteration, so a multi-board split \
             would serialize on the link; partition streaming graphs only",
            g.name
        )));
    }
    let n = g.ops.len();
    if slots.len() != cuts.len() + 1 {
        return Err(Error::config(format!(
            "graph {:?}: {} cut(s) make {} part(s) but {} board slot(s) were given",
            g.name,
            cuts.len(),
            cuts.len() + 1,
            slots.len()
        )));
    }
    let mut prev = 0usize;
    for &c in cuts {
        if c <= prev || c >= n {
            return Err(Error::config(format!(
                "graph {:?}: cut positions must be strictly increasing within 1..{n} \
                 (got {cuts:?})",
                g.name
            )));
        }
        prev = c;
    }

    // Assign each op to its part by topological position.
    let order = topo_order(g);
    let mut part_of = vec![0usize; n];
    {
        let mut bounds: Vec<usize> = cuts.to_vec();
        bounds.push(n);
        let mut lo = 0usize;
        for (j, &hi) in bounds.iter().enumerate() {
            for &oi in &order[lo..hi] {
                part_of[oi] = j;
            }
            lo = hi;
        }
    }

    // Cut edges become link hops on the consuming slot's link.
    let wb = (g.act_fmt.word_bits as u64).div_ceil(8);
    let mut hops = Vec::new();
    for e in &g.edges {
        let (fp, tp) = (part_of[e.from], part_of[e.to]);
        if fp == tp {
            continue;
        }
        debug_assert!(fp < tp, "contiguous topo cuts only cut forward");
        hops.push(LinkHop {
            from_part: fp,
            to_part: tp,
            from_op: e.from,
            to_op: e.to,
            elems: e.elems,
            round_trips: e.round_trips,
            bytes_per_item: e.elems * wb,
            link: slots[tp].link,
        });
    }

    // Build and lower each part's subgraph (ops keep their original
    // relative order, so a zero-cut partition reproduces the graph
    // verbatim and lowers cycle-identically).
    let n_parts = cuts.len() + 1;
    let mut new_index = vec![usize::MAX; n];
    let mut parts = Vec::with_capacity(n_parts);
    for (j, slot) in slots.iter().enumerate() {
        let member_ops: Vec<usize> = (0..n).filter(|&i| part_of[i] == j).collect();
        let mut sg = Graph::new(format!("{}.p{j}", g.name), g.act_fmt, g.weight_fmt)
            .streaming(g.dataflow, g.ddr_spill)
            .with_fifo_depth(g.fifo_depth);
        if j == 0 {
            sg = sg.with_io_elems(g.io_elems);
            for &t in &g.transfers {
                sg.transfer(t);
            }
        }
        for (k, &oi) in member_ops.iter().enumerate() {
            new_index[oi] = k;
            sg.push_op(g.ops[oi].clone());
        }
        for e in &g.edges {
            if part_of[e.from] == j && part_of[e.to] == j {
                sg.edges.push(Edge {
                    from: new_index[e.from],
                    to: new_index[e.to],
                    ..*e
                });
            }
        }
        let lowered = lower(&sg, &slot.target)?;
        let endpoints = hops
            .iter()
            .filter(|h| h.from_part == j || h.to_part == j)
            .count() as u64;
        parts.push(PartPlan {
            board: slot.name.clone(),
            device: slot.target.device,
            base_clock_mhz: slot.base_clock_mhz,
            ops: member_ops,
            graph: sg,
            lowered,
            link_overhead: link_endpoint_overhead().scaled(endpoints),
        });
    }

    Ok(PartitionedPlan {
        name: g.name.clone(),
        act_fmt: g.act_fmt,
        parts,
        hops,
    })
}

/// What [`best_partition`] found: the winning plan plus sweep counters
/// for benches and CI.
#[derive(Clone, Debug)]
pub struct PartitionOutcome {
    pub plan: PartitionedPlan,
    /// Cut assignments evaluated (the whole-graph candidate included).
    pub evaluated: usize,
    /// Of those, how many were deployable.
    pub feasible: usize,
}

/// All strictly increasing `(k-1)`-subsets of `1..n`: the cut boundary
/// sets splitting `n` topo-ordered ops into `k` non-empty parts.
fn cut_sets(n: usize, k: usize) -> Vec<Vec<usize>> {
    fn rec(start: usize, n: usize, left: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if left == 0 {
            out.push(cur.clone());
            return;
        }
        for c in start..n {
            cur.push(c);
            rec(c + 1, n, left - 1, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(1, n, k - 1, &mut Vec::with_capacity(k.saturating_sub(1)), &mut out);
    out
}

/// Sweep every contiguous cut assignment of `g` onto a prefix of
/// `slots` — from the whole graph on one board up to
/// `min(slots, n_ops)` parts — and pick the plan with the smallest
/// modeled [`window_s`](PartitionedPlan::window_s) for a `window`-item
/// window. Because the whole-graph candidate is in the space and a
/// replacement must be *strictly* faster, the chosen plan never models
/// more time than the whole-window plan whenever that plan is feasible.
///
/// Rejections are tallied through the tuner's feasibility ledger with
/// fit and timing closure as **separate verdicts**: a split that fits
/// the fabric but cannot close timing at a member's clock is reported
/// as `failing timing closure`, never as `over the fabric budget`. A
/// dry sweep returns the ledger as a typed [`Error::Config`] naming the
/// binding constraint.
pub fn best_partition(g: &Graph, slots: &[BoardSlot], window: u64) -> Result<PartitionOutcome> {
    g.validate()?;
    if slots.is_empty() {
        return Err(Error::config(format!(
            "graph {:?}: cannot partition onto an empty slot roster",
            g.name
        )));
    }
    let n = g.ops.len();
    let mut tally = FeasibilityTally::default();
    let mut evaluated = 0usize;
    let mut feasible = 0usize;
    let mut best: Option<PartitionedPlan> = None;
    let mut best_s = f64::INFINITY;
    for k in 1..=slots.len().min(n) {
        for cuts in cut_sets(n, k) {
            let plan = partition(g, &cuts, &slots[..k])?;
            evaluated += 1;
            let fits = plan.fits();
            let clock = plan.clock_ok();
            tally.add(fits, true, clock, true, true);
            if !(fits && clock) {
                continue;
            }
            feasible += 1;
            let s = plan.window_s(window);
            if s < best_s {
                best_s = s;
                best = Some(plan);
            }
        }
    }
    match best {
        Some(plan) => Ok(PartitionOutcome {
            plan,
            evaluated,
            feasible,
        }),
        None => Err(tally.error(&g.name)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::fixedpoint::FixedFormat;
    use crate::fpga::graph::Op;

    fn chain(n: usize) -> Graph {
        let fmt = FixedFormat::q8_8();
        let mut g = Graph::new("chain", fmt, fmt)
            .streaming(true, false)
            .with_io_elems(8);
        let mut prev = None;
        for i in 0..n {
            let id = g.push_op(Op::elementwise(format!("e{i}"), 64, 1).unrolled(4));
            if let Some(p) = prev {
                g.connect(p, id, 16, 1);
            }
            prev = Some(id);
        }
        g
    }

    #[test]
    fn cut_sets_enumerate_compositions() {
        assert_eq!(cut_sets(4, 1), vec![Vec::<usize>::new()]);
        assert_eq!(cut_sets(4, 2).len(), 3); // C(3,1)
        assert_eq!(cut_sets(4, 3).len(), 3); // C(3,2)
        assert_eq!(cut_sets(4, 4), vec![vec![1, 2, 3]]);
    }

    #[test]
    fn slot_count_must_match_cuts() {
        let g = chain(3);
        let err = partition(&g, &[1], &pynq_rack(3)).unwrap_err();
        assert!(format!("{err:?}").contains("board slot"));
    }

    #[test]
    fn cuts_must_be_strictly_increasing_and_in_range() {
        let g = chain(3);
        for cuts in [vec![0], vec![3], vec![2, 2], vec![2, 1]] {
            let slots = pynq_rack(cuts.len() + 1);
            let err = partition(&g, &cuts, &slots).unwrap_err();
            assert!(format!("{err:?}").contains("strictly increasing"), "{cuts:?}");
        }
    }

    #[test]
    fn iterative_graphs_are_rejected() {
        let fmt = FixedFormat::q8_8();
        let mut g = Graph::new("iter", fmt, fmt).iterative(5, 100);
        g.push_op(Op::matvec("mv", 64));
        let err = partition(&g, &[], &pynq_rack(1)).unwrap_err();
        assert!(format!("{err:?}").contains("iterative"));
    }

    #[test]
    fn two_part_chain_has_one_hop_and_io_on_head() {
        let g = chain(4);
        let plan = partition(&g, &[2], &pynq_rack(2)).unwrap();
        assert_eq!(plan.n_parts(), 2);
        assert_eq!(plan.hops.len(), 1);
        assert_eq!(plan.parts[0].graph.io_elems, g.io_elems);
        assert_eq!(plan.parts[1].graph.io_elems, 0);
        // Both endpoints pay the link fabric.
        assert_eq!(plan.parts[0].link_overhead, link_endpoint_overhead());
        assert_eq!(plan.parts[1].link_overhead, link_endpoint_overhead());
        // Steady state is bounded below by the slowest member.
        let slowest = plan.slowest_part();
        let member_iv = plan.parts[slowest]
            .device
            .cycles_to_seconds(plan.parts[slowest].lowered.interval);
        assert!(plan.interval_s() >= member_iv - 1e-15);
    }

    #[test]
    fn empty_roster_is_a_config_error() {
        let g = chain(2);
        assert!(best_partition(&g, &[], 64).is_err());
    }
}

//! AXI/DMA interconnect and DDR model.
//!
//! §5.1: a Memory Reader/Writer engine streams inputs/parameters from
//! off-chip DDR into BRAM over AXI master ports. Designs that keep
//! intermediates on-chip (DATAFLOW + FIFOs) touch DDR only at the stream
//! boundaries; the baseline and the iterative LTC design bounce
//! intermediate state through DDR, which is where their latency and power
//! go.

/// DDR + AXI DMA timing/energy model.
#[derive(Clone, Copy, Debug)]
pub struct DdrModel {
    /// Sustained bytes per PL cycle once a burst is streaming
    /// (128-bit AXI at matched clock = 16 B/cycle).
    pub bytes_per_cycle: f64,
    /// Fixed latency per DMA transaction (descriptor setup + DDR access).
    pub burst_latency_cycles: u64,
    /// Energy per byte moved (pJ) — DDR3 on PYNQ ≈ 70 pJ/B end to end.
    pub pj_per_byte: f64,
}

impl Default for DdrModel {
    fn default() -> Self {
        DdrModel {
            bytes_per_cycle: 16.0,
            burst_latency_cycles: 150,
            pj_per_byte: 70.0,
        }
    }
}

impl DdrModel {
    /// Cycles for one DMA burst of `bytes`.
    pub fn burst_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.burst_latency_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Cycles for `n` separate small transactions (no coalescing) — the
    /// penalty pattern of iterative designs that reload per sub-step.
    pub fn scattered_cycles(&self, n: u64, bytes_each: u64) -> u64 {
        n * self.burst_cycles(bytes_each)
    }

    /// Energy in joules for moving `bytes`.
    pub fn energy_j(&self, bytes: u64) -> f64 {
        bytes as f64 * self.pj_per_byte * 1e-12
    }
}

/// DRAM footprint estimator for an MR workload (Table 4/5 DRAM column).
#[derive(Clone, Copy, Debug)]
pub struct DramFootprint {
    /// Model parameters resident in DDR (bytes).
    pub params_bytes: u64,
    /// Training/serving trace buffers.
    pub trace_bytes: u64,
    /// Host-side runtime overhead (allocator, descriptors, bitstream...).
    pub runtime_bytes: u64,
}

impl DramFootprint {
    pub fn total_bytes(&self) -> u64 {
        self.params_bytes + self.trace_bytes + self.runtime_bytes
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bytes() as f64 / (1024.0 * 1024.0)
    }

    /// FPGA-side footprint for a workload: params + double-buffered traces
    /// + a lean bare-metal runtime (no framework heap).
    pub fn fpga(params: u64, trace: u64) -> DramFootprint {
        DramFootprint {
            params_bytes: params,
            trace_bytes: 2 * trace,
            runtime_bytes: 64 << 20, // PYNQ Linux + XRT-lite ≈ 64 MB
        }
    }

    /// GPU-side footprint: framework (TF/Keras per the paper) dominates.
    pub fn gpu(params: u64, trace: u64) -> DramFootprint {
        DramFootprint {
            params_bytes: 4 * params, // fp32 master + optimizer copies
            trace_bytes: 8 * trace,   // pipeline prefetch + staging
            runtime_bytes: 2_300 << 20, // CUDA context + TF runtime
        }
    }

    /// Mobile-GPU (Jetson) footprint: shared LPDDR, smaller runtime.
    pub fn mobile_gpu(params: u64, trace: u64) -> DramFootprint {
        DramFootprint {
            params_bytes: 4 * params,
            trace_bytes: 4 * trace,
            runtime_bytes: 900 << 20,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_amortizes_latency() {
        let d = DdrModel::default();
        let one_big = d.burst_cycles(16 * 1024);
        let many_small = d.scattered_cycles(1024, 16);
        assert!(many_small > 10 * one_big);
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        assert_eq!(DdrModel::default().burst_cycles(0), 0);
    }

    #[test]
    fn energy_scales_linearly() {
        let d = DdrModel::default();
        assert!((d.energy_j(2_000_000) - 2.0 * d.energy_j(1_000_000)).abs() < 1e-15);
    }

    #[test]
    fn fpga_footprint_much_smaller_than_gpu() {
        let params = 2 << 20;
        let trace = 4 << 20;
        let f = DramFootprint::fpga(params, trace);
        let g = DramFootprint::gpu(params, trace);
        assert!(g.total_mb() > 10.0 * f.total_mb());
        // Paper Table 5: FPGA MR footprint ≈ 72 MB.
        assert!(f.total_mb() > 30.0 && f.total_mb() < 200.0);
    }
}

//! HLS-style scheduler: from loop nest + pragmas to II, cycles, resources.
//!
//! Models what Vitis HLS does with the paper's directives (§5.3.2):
//! `UNROLL factor=U` replicates the loop body into U lanes;
//! `ARRAY_PARTITION`/`ARRAY_RESHAPE` provision memory ports; `PIPELINE`
//! gives II = max(1, ⌈R/(ports)⌉) per array; `BIND_OP` selects DSP or LUT
//! fabric for the arithmetic. The output of scheduling one loop is a
//! [`ScheduledLoop`] whose `(ii, cycles, resources)` feed the
//! [`Pipeline`](super::pipeline::Pipeline) stage graph.

use super::bram::BankedArray;
use super::dsp::DspMacArray;
use super::lut::{lut_add_cost, ActivationTable, LutMacArray};
use super::resources::Resources;

/// Which fabric executes a stage's arithmetic (Table 7's D/L axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Binding {
    Dsp,
    Lut,
}

impl Binding {
    pub fn letter(&self) -> char {
        match self {
            Binding::Dsp => 'D',
            Binding::Lut => 'L',
        }
    }
}

/// One array accessed by a loop, with per-iteration read/write counts.
#[derive(Clone, Debug)]
pub struct ArrayAccess {
    pub array: BankedArray,
    /// Element reads per (unrolled) loop iteration.
    pub reads_per_iter: u32,
    /// Element writes per iteration.
    pub writes_per_iter: u32,
}

/// A pipelined, possibly unrolled loop to schedule.
#[derive(Clone, Debug)]
pub struct LoopNest {
    pub name: String,
    /// Trip count of the innermost loop before unrolling.
    pub trip: u64,
    /// UNROLL factor (parallel lanes).
    pub unroll: u32,
    /// MAC operations per original iteration.
    pub macs_per_iter: u32,
    /// Non-MAC elementwise ops per original iteration (adds, muls, divs).
    pub elementwise_per_iter: u32,
    /// Activation-table lookups per original iteration.
    pub activations_per_iter: u32,
    pub arrays: Vec<ArrayAccess>,
    pub binding: Binding,
    /// Fixed-point word width (drives LUT fabric cost).
    pub word_bits: u32,
}

impl LoopNest {
    pub fn new(name: impl Into<String>, trip: u64) -> LoopNest {
        LoopNest {
            name: name.into(),
            trip,
            unroll: 1,
            macs_per_iter: 0,
            elementwise_per_iter: 0,
            activations_per_iter: 0,
            arrays: Vec::new(),
            binding: Binding::Dsp,
            word_bits: 16,
        }
    }

    pub fn unrolled(mut self, u: u32) -> LoopNest {
        self.unroll = u.max(1);
        self
    }

    pub fn macs(mut self, m: u32) -> LoopNest {
        self.macs_per_iter = m;
        self
    }

    pub fn elementwise(mut self, e: u32) -> LoopNest {
        self.elementwise_per_iter = e;
        self
    }

    pub fn activations(mut self, a: u32) -> LoopNest {
        self.activations_per_iter = a;
        self
    }

    pub fn bound(mut self, b: Binding) -> LoopNest {
        self.binding = b;
        self
    }

    pub fn with_array(mut self, array: BankedArray, reads: u32, writes: u32) -> LoopNest {
        self.arrays.push(ArrayAccess {
            array,
            reads_per_iter: reads,
            writes_per_iter: writes,
        });
        self
    }
}

/// Scheduling result for one loop.
#[derive(Clone, Debug)]
pub struct ScheduledLoop {
    pub name: String,
    /// Achieved initiation interval (cycles between unrolled iterations).
    pub ii: u32,
    /// Pipeline depth (fill latency) in cycles.
    pub depth: u32,
    /// Total cycles to drain the whole loop once.
    pub cycles: u64,
    pub resources: Resources,
    /// The array that bound the II (None if compute-bound at II=1).
    pub bottleneck: Option<String>,
}

/// Schedule a loop nest under the paper's II law.
pub fn schedule(l: &LoopNest) -> ScheduledLoop {
    let lanes = l.unroll;
    // Memory-constrained II: each array must supply reads+writes for all
    // unrolled lanes every launch (paper: II >= ceil(R / 2B)).
    let mut ii = 1u32;
    let mut bottleneck = None;
    for a in &l.arrays {
        let per_launch = (a.reads_per_iter + a.writes_per_iter) * lanes;
        let this = a.array.ii_for_reads(per_launch);
        if this > ii {
            ii = this;
            bottleneck = Some(a.array.name.clone());
        }
    }

    let iters = l.trip.div_ceil(lanes as u64);
    let total_macs = l.trip * l.macs_per_iter as u64;
    let total_elem = l.trip * l.elementwise_per_iter as u64;

    // Compute unit + latency model per binding.
    let (depth, mut res) = match l.binding {
        Binding::Dsp => {
            let mac = DspMacArray::new(lanes * l.macs_per_iter.max(1));
            let mut r = Resources::ZERO;
            if l.macs_per_iter > 0 {
                r += DspMacArray::new(lanes * l.macs_per_iter).resources();
            }
            if l.elementwise_per_iter > 0 {
                r += super::dsp::DspElementwise::new(lanes, l.elementwise_per_iter).resources();
            }
            (mac.lane.latency + 1, r)
        }
        Binding::Lut => {
            let mut r = Resources::ZERO;
            if l.macs_per_iter > 0 {
                r += LutMacArray::new(lanes * l.macs_per_iter, l.word_bits).resources();
            }
            if l.elementwise_per_iter > 0 {
                r += Resources {
                    lut: (lut_add_cost(l.word_bits) * 3) * (lanes as u64),
                    ff: (l.word_bits as u64 * 2) * lanes as u64,
                    dsp: 0,
                    bram18: 0,
                };
            }
            (7, r)
        }
    };

    // Activation tables are LUT-resident regardless of the MAC binding
    // (the paper never burns DSPs on sigmoid/tanh).
    if l.activations_per_iter > 0 {
        let t = ActivationTable::default_for(super::lut::Activation::Sigmoid);
        res += t.resources(l.word_bits).scaled(lanes as u64);
    }

    // Array storage + loop control overhead.
    for a in &l.arrays {
        res += a.array.resources();
    }
    res += Resources {
        lut: 50 + 8 * lanes as u64,
        ff: 70 + 10 * lanes as u64,
        dsp: 0,
        bram18: 0,
    };

    let cycles = depth as u64 + iters.saturating_sub(1) * ii as u64 + (ii as u64 - 1)
        + (total_macs + total_elem) / (total_macs + total_elem).max(1); // +1 if any work

    ScheduledLoop {
        name: l.name.clone(),
        ii,
        depth,
        cycles,
        resources: res,
        bottleneck,
    }
}

#[cfg(test)]
mod tests {
    use super::super::bram::Partition;
    use super::*;

    fn weight_array(banks: u32) -> BankedArray {
        let a = BankedArray::new("params.Wr", 1024, 16);
        if banks > 1 {
            a.partitioned(Partition::Cyclic(banks))
        } else {
            a
        }
    }

    #[test]
    fn paper_example_unroll4_unbanked_stalls() {
        // §5.3.1: UNROLL=4, one weight read per lane per cycle, B=1 → II=2.
        let l = LoopNest::new("gate", 256)
            .unrolled(4)
            .macs(1)
            .with_array(weight_array(1), 1, 0);
        let s = schedule(&l);
        assert_eq!(s.ii, 2);
        assert_eq!(s.bottleneck.as_deref(), Some("params.Wr"));
    }

    #[test]
    fn paper_example_unroll4_banked_full_throughput() {
        // §5.3.1: B=2 → 4 ports ≥ 4 reads → II=1.
        let l = LoopNest::new("gate", 256)
            .unrolled(4)
            .macs(1)
            .with_array(weight_array(2), 1, 0);
        assert_eq!(schedule(&l).ii, 1);
    }

    #[test]
    fn paper_example_r8_needs_b4() {
        // §5.3.1: 4 lanes × 2 matrices = 8 reads → B=4 for II=1.
        let both = |banks| {
            LoopNest::new("gate", 256)
                .unrolled(4)
                .macs(2)
                .with_array(weight_array(banks), 2, 0)
        };
        assert_eq!(schedule(&both(2)).ii, 2);
        assert_eq!(schedule(&both(4)).ii, 1);
    }

    #[test]
    fn banking_cuts_cycles() {
        let mk = |banks| {
            schedule(
                &LoopNest::new("gate", 960)
                    .unrolled(4)
                    .macs(1)
                    .with_array(weight_array(banks), 1, 0),
            )
        };
        let un = mk(1);
        let banked = mk(4);
        assert!(banked.cycles < un.cycles);
        assert!(un.cycles as f64 / banked.cycles as f64 > 1.8);
    }

    #[test]
    fn lut_binding_swaps_dsp_for_lut() {
        let base = LoopNest::new("gate", 256)
            .unrolled(4)
            .macs(1)
            .with_array(weight_array(2), 1, 0);
        let d = schedule(&base.clone().bound(Binding::Dsp));
        let l = schedule(&base.bound(Binding::Lut));
        assert!(d.resources.dsp > 0);
        assert_eq!(l.resources.dsp, 0);
        assert!(l.resources.lut > d.resources.lut);
        // Same steady-state II either way.
        assert_eq!(d.ii, l.ii);
    }

    #[test]
    fn unroll_scales_resources_linearly_ish() {
        let mk = |u| {
            schedule(
                &LoopNest::new("gate", 1024)
                    .unrolled(u)
                    .macs(1)
                    .with_array(weight_array(u), 1, 0),
            )
        };
        let u2 = mk(2);
        let u8 = mk(8);
        assert!(u8.resources.dsp >= 4 * u2.resources.dsp - 2);
        assert!(u8.cycles < u2.cycles);
    }
}

//! `ap_fixed`-style fixed-point arithmetic model.
//!
//! The paper's accelerator uses 8–16 bit activations and 12–16 bit
//! weights/accumulators (§5, §6.4). This module models Vitis HLS
//! `ap_fixed<W, I>` with round-half-away-from-zero and saturation — the
//! same policy as the L1 `fixedpoint.py` Pallas kernel, pinned bit-equal by
//! `rust/tests/integration.rs` and property-tested in
//! `rust/tests/proptests.rs`.

/// A fixed-point format: `word_bits` total (incl. sign), `frac_bits`
/// fractional. Integer bits = word − frac (sign included).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FixedFormat {
    pub word_bits: u32,
    pub frac_bits: u32,
}

impl FixedFormat {
    pub fn new(word_bits: u32, frac_bits: u32) -> FixedFormat {
        assert!(word_bits >= 2 && word_bits <= 32, "word_bits {word_bits}");
        assert!(frac_bits < word_bits, "frac {frac_bits} >= word {word_bits}");
        FixedFormat {
            word_bits,
            frac_bits,
        }
    }

    /// The paper's activation format sweet spot (Q8.8).
    pub fn q8_8() -> FixedFormat {
        FixedFormat::new(16, 8)
    }

    /// The paper's weight format (12-bit word, 8 frac).
    pub fn q4_8() -> FixedFormat {
        FixedFormat::new(12, 8)
    }

    /// Scale factor 2^frac.
    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// Largest representable value.
    #[inline]
    pub fn max_value(&self) -> f64 {
        (((1i64 << (self.word_bits - 1)) - 1) as f64) / self.scale()
    }

    /// Smallest (most negative) representable value.
    #[inline]
    pub fn min_value(&self) -> f64 {
        (-(1i64 << (self.word_bits - 1)) as f64) / self.scale()
    }

    /// Quantization step (LSB weight).
    #[inline]
    pub fn resolution(&self) -> f64 {
        1.0 / self.scale()
    }

    /// Quantize to the raw integer code (saturating).
    #[inline]
    pub fn to_raw(&self, x: f64) -> i64 {
        let scaled = x * self.scale();
        // round half away from zero, like the HLS AP_RND mode we model
        let r = scaled.signum() * (scaled.abs() + 0.5).floor();
        let lo = -(1i64 << (self.word_bits - 1));
        let hi = (1i64 << (self.word_bits - 1)) - 1;
        (r as i64).clamp(lo, hi)
    }

    /// Dequantize a raw code.
    #[inline]
    pub fn from_raw(&self, raw: i64) -> f64 {
        raw as f64 / self.scale()
    }

    /// Round-trip quantization f64 → f64.
    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        self.from_raw(self.to_raw(x))
    }

    /// Round-trip quantization in f32 (bit-matched to the Pallas kernel,
    /// which computes in f32).
    #[inline]
    pub fn quantize_f32(&self, x: f32) -> f32 {
        let scale = self.scale() as f32;
        let scaled = x * scale;
        let r = scaled.signum() * (scaled.abs() + 0.5).floor();
        let lo = -((1i64 << (self.word_bits - 1)) as f32);
        let hi = ((1i64 << (self.word_bits - 1)) - 1) as f32;
        r.clamp(lo, hi) / scale
    }

    /// Quantize a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.quantize_f32(*x);
        }
    }

    /// Pass each value through the saturating [`Fixed`] representation in
    /// this format (round + clamp on the raw integer code) — models an
    /// accumulator writeback with `AP_SAT`. Semantically this is
    /// [`FixedFormat::quantize`] per element; it differs from
    /// [`FixedFormat::quantize_slice`] in rounding through the f64/i64
    /// raw path, which wide (≥24 frac bit) accumulator formats need —
    /// `quantize_f32` would lose LSBs to f32 mantissa rounding.
    pub fn saturate_slice(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = Fixed::from_f64(*x as f64, *self).to_f64() as f32;
        }
    }

    /// Accumulator format used by the quantized serving datapath: a
    /// 32-bit word keeping the fractional bits of both operand formats
    /// combined, capped so at least 8 integer bits (±128 range) remain
    /// for the accumulated sum before saturation — the DSP48 wide
    /// post-adder with `AP_SAT` on writeback.
    pub fn accumulator_for(act: FixedFormat, weight: FixedFormat) -> FixedFormat {
        FixedFormat::new(32, (act.frac_bits + weight.frac_bits).min(24))
    }
}

/// Operand/accumulator format pair threading a quantized datapath through
/// the batched kernels (`mr::linalg::gru_forward_batch_fixed`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DatapathFormats {
    /// Activation/state format: values are re-quantized to this at every
    /// stage boundary.
    pub act: FixedFormat,
    /// Saturating accumulator format for pre-activation sums.
    pub acc: FixedFormat,
}

impl DatapathFormats {
    /// Datapath for the given activation and weight storage formats, with
    /// the accumulator derived via [`FixedFormat::accumulator_for`].
    pub fn for_ops(act: FixedFormat, weight: FixedFormat) -> DatapathFormats {
        DatapathFormats {
            act,
            acc: FixedFormat::accumulator_for(act, weight),
        }
    }
}

/// A fixed-point number with its format (for accumulator modeling).
#[derive(Clone, Copy, Debug)]
pub struct Fixed {
    pub raw: i64,
    pub fmt: FixedFormat,
}

impl Fixed {
    pub fn from_f64(x: f64, fmt: FixedFormat) -> Fixed {
        Fixed {
            raw: fmt.to_raw(x),
            fmt,
        }
    }

    pub fn to_f64(&self) -> f64 {
        self.fmt.from_raw(self.raw)
    }

    /// Saturating add in the shared format.
    pub fn add(&self, other: &Fixed) -> Fixed {
        assert_eq!(self.fmt, other.fmt);
        let lo = -(1i64 << (self.fmt.word_bits - 1));
        let hi = (1i64 << (self.fmt.word_bits - 1)) - 1;
        Fixed {
            raw: (self.raw + other.raw).clamp(lo, hi),
            fmt: self.fmt,
        }
    }

    /// Multiply: product has 2×frac bits; rescale back with rounding, then
    /// saturate — models the DSP48 post-multiply truncation path.
    pub fn mul(&self, other: &Fixed) -> Fixed {
        assert_eq!(self.fmt, other.fmt);
        let prod = self.raw as i128 * other.raw as i128;
        let shift = self.fmt.frac_bits;
        // Rounding half is 2^(shift-1) — except 0 when shift == 0: the
        // product is already at the target scale, nothing to round (and
        // `shift - 1` would underflow u32).
        let half = if shift == 0 { 0 } else { 1i128 << (shift - 1) };
        let rounded = if prod >= 0 {
            (prod + half) >> shift
        } else {
            -((-prod + half) >> shift)
        };
        let lo = -(1i128 << (self.fmt.word_bits - 1));
        let hi = (1i128 << (self.fmt.word_bits - 1)) - 1;
        Fixed {
            raw: rounded.clamp(lo, hi) as i64,
            fmt: self.fmt,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_lsb() {
        let fmt = FixedFormat::q8_8();
        for i in -1000..1000 {
            let x = i as f64 * 0.013;
            if x.abs() < fmt.max_value() {
                let q = fmt.quantize(x);
                assert!(
                    (q - x).abs() <= fmt.resolution() / 2.0 + 1e-12,
                    "x={x} q={q}"
                );
            }
        }
    }

    #[test]
    fn saturates_at_bounds() {
        let fmt = FixedFormat::new(8, 4); // range [-8, 7.9375]
        assert_eq!(fmt.quantize(100.0), fmt.max_value());
        assert_eq!(fmt.quantize(-100.0), fmt.min_value());
        assert!((fmt.max_value() - 7.9375).abs() < 1e-12);
        assert!((fmt.min_value() + 8.0).abs() < 1e-12);
    }

    #[test]
    fn round_half_away_from_zero() {
        let fmt = FixedFormat::new(16, 1); // steps of 0.5
        assert_eq!(fmt.quantize(0.25), 0.5); // halfway rounds away
        assert_eq!(fmt.quantize(-0.25), -0.5);
        assert_eq!(fmt.quantize(0.24), 0.0);
    }

    #[test]
    fn f32_and_f64_paths_agree() {
        let fmt = FixedFormat::q8_8();
        for i in -500..500 {
            let x = i as f32 * 0.037;
            let a = fmt.quantize_f32(x);
            let b = fmt.quantize(x as f64) as f32;
            assert_eq!(a, b, "x={x}");
        }
    }

    #[test]
    fn fixed_mul_matches_float_approximately() {
        let fmt = FixedFormat::new(16, 8);
        let a = Fixed::from_f64(1.5, fmt);
        let b = Fixed::from_f64(-2.25, fmt);
        let c = a.mul(&b);
        assert!((c.to_f64() + 3.375).abs() <= fmt.resolution());
    }

    #[test]
    fn fixed_add_saturates() {
        let fmt = FixedFormat::new(8, 0); // integers in [-128, 127]
        let a = Fixed::from_f64(100.0, fmt);
        let b = Fixed::from_f64(100.0, fmt);
        assert_eq!(a.add(&b).to_f64(), 127.0);
    }

    #[test]
    fn mul_with_zero_frac_bits_is_exact_integer_product() {
        // Regression: `shift - 1` underflowed u32 when frac_bits == 0.
        let fmt = FixedFormat::new(8, 0); // integers in [-128, 127]
        let a = Fixed::from_f64(7.0, fmt);
        let b = Fixed::from_f64(-9.0, fmt);
        assert_eq!(a.mul(&b).to_f64(), -63.0);
        // Out-of-range products saturate instead of wrapping.
        let big = Fixed::from_f64(100.0, fmt);
        assert_eq!(big.mul(&big).to_f64(), fmt.max_value());
        let neg = Fixed::from_f64(-100.0, fmt);
        assert_eq!(big.mul(&neg).to_f64(), fmt.min_value());
    }

    #[test]
    fn saturate_slice_rounds_and_clamps() {
        let fmt = FixedFormat::new(8, 4); // range [-8, 7.9375], step 1/16
        let mut xs = vec![0.26f32, 100.0, -100.0];
        fmt.saturate_slice(&mut xs);
        assert!((xs[0] - 0.25).abs() < 1e-6);
        assert_eq!(xs[1], fmt.max_value() as f32);
        assert_eq!(xs[2], fmt.min_value() as f32);
    }

    #[test]
    fn accumulator_format_is_wide_and_bounded() {
        let acc = FixedFormat::accumulator_for(FixedFormat::q8_8(), FixedFormat::q8_8());
        assert_eq!((acc.word_bits, acc.frac_bits), (32, 16));
        // Very fine operand formats cap the accumulator's fractional bits
        // so at least 8 integer bits remain.
        let fine = FixedFormat::new(30, 20);
        let acc = FixedFormat::accumulator_for(fine, fine);
        assert_eq!((acc.word_bits, acc.frac_bits), (32, 24));
        let dp = DatapathFormats::for_ops(fine, fine);
        assert_eq!(dp.acc, acc);
        assert_eq!(dp.act, fine);
    }

    #[test]
    fn quantize_slice_in_place() {
        let fmt = FixedFormat::new(12, 4);
        let mut xs = vec![0.1f32, 0.2, -0.33];
        fmt.quantize_slice(&mut xs);
        for x in &xs {
            let scaled = *x * 16.0;
            assert!((scaled - scaled.round()).abs() < 1e-6);
        }
    }
}

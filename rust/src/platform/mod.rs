//! Cross-platform cost models (paper Table 5, §6.2).
//!
//! The paper measures four MR workloads on an RTX 6000 workstation, a
//! Jetson Orin Nano, and the PYNQ-Z2. We have none of that hardware, so
//! the GPU platforms are *calibrated analytic models* (DESIGN.md §2):
//! runtime decomposes into per-step kernel-launch overhead (the paper's
//! §1 complaint about many small kernels) plus compute/bandwidth time;
//! power interpolates base→peak with utilization; DRAM comes from the
//! footprint model. The FPGA column is produced by the cycle simulator,
//! not this file. Constants are pinned to the paper's Table 5 operating
//! points and then reused unchanged for every workload.

use crate::fpga::interconnect::DramFootprint;

/// A platform's cost model.
#[derive(Clone, Copy, Debug)]
pub struct PlatformModel {
    pub name: &'static str,
    /// Reported clock (paper's Freq column), MHz.
    pub freq_mhz: f64,
    /// Idle/base power draw attributable to the job (W).
    pub base_power_w: f64,
    /// Peak board power under full load (W).
    pub peak_power_w: f64,
    /// Per-kernel launch + scheduling overhead (µs).
    pub launch_overhead_us: f64,
    /// Sustained f32 throughput on small tensors (GFLOP/s) — far below
    /// peak because MR kernels are tiny (SM under-utilization at B≈1).
    pub small_kernel_gflops: f64,
    /// Achieved utilization fraction for this workload class.
    pub utilization: f64,
}

impl PlatformModel {
    /// RTX 6000 workstation (TensorFlow 2.10 per the paper).
    pub fn gpu() -> PlatformModel {
        PlatformModel {
            name: "GPU (RTX 6000)",
            freq_mhz: 1410.0,
            base_power_w: 28.0,
            peak_power_w: 300.0,
            launch_overhead_us: 9.0,
            small_kernel_gflops: 55.0,
            utilization: 0.16,
        }
    }

    /// Jetson Orin Nano.
    pub fn mobile_gpu() -> PlatformModel {
        PlatformModel {
            name: "Mobile GPU (Orin Nano)",
            freq_mhz: 306.0,
            base_power_w: 4.0,
            peak_power_w: 14.0,
            launch_overhead_us: 14.0,
            small_kernel_gflops: 18.0,
            utilization: 0.22,
        }
    }

    /// Estimated wall time for a training run (seconds).
    ///
    /// `kernels_per_step`: distinct device kernels per optimizer step
    /// (iterative solvers multiply this — the paper's core GPU complaint).
    pub fn runtime_s(&self, steps: u64, kernels_per_step: u64, flops_per_step: f64) -> f64 {
        let launch = steps as f64 * kernels_per_step as f64 * self.launch_overhead_us * 1e-6;
        let compute = steps as f64 * flops_per_step / (self.small_kernel_gflops * 1e9);
        launch + compute
    }

    /// Average power during the run (W).
    pub fn power_w(&self) -> f64 {
        self.base_power_w + self.utilization * (self.peak_power_w - self.base_power_w)
    }

    /// Energy for a run (J).
    pub fn energy_j(&self, runtime_s: f64) -> f64 {
        self.power_w() * runtime_s
    }
}

/// Static workload characterization (counts extracted from the L2 model
/// dims; see `workloads()` below).
#[derive(Clone, Copy, Debug)]
pub struct WorkloadModel {
    pub name: &'static str,
    /// Device kernels per training step on a framework runtime.
    pub kernels_per_step: u64,
    /// FLOPs per training step.
    pub flops_per_step: f64,
    /// Parameter bytes.
    pub param_bytes: u64,
    /// Trace/working-set bytes.
    pub trace_bytes: u64,
}

/// The paper's four Table 5 workloads, characterized for the canonical
/// AID configuration (batch 8, seq 64, hid 32; LTC unfold 6).
pub fn workloads() -> [WorkloadModel; 4] {
    let seq = 64u64;
    let hid = 32u64;
    let batch = 8u64;
    // GRU fwd+bwd FLOPs per step: ~2 × 3 matvecs × (io·3H + H·3H) × seq × batch × 3 (fwd+2bwd).
    let gru_flops = (batch * seq * (4 * 3 * hid + hid * 3 * hid) * 2 * 3) as f64;
    let rk4_flops = (batch * seq * 4 * 15 * 3 * 2 * 3) as f64;
    [
        WorkloadModel {
            // LTC: every solver sub-step is its own kernel chain.
            name: "LTC",
            kernels_per_step: 6 * seq * 14,
            flops_per_step: gru_flops * 2.2,
            param_bytes: 4 * (4 * hid + hid * hid + 3 * hid),
            trace_bytes: 4 * 200 * 4 * 14,
        },
        WorkloadModel {
            // SINDY: small library regressions, few kernels, tiny FLOPs.
            name: "SINDY",
            kernels_per_step: 40,
            flops_per_step: 2.0e6,
            param_bytes: 4 * 45,
            trace_bytes: 4 * 200 * 4 * 14,
        },
        WorkloadModel {
            // PINN+SR: NN forward + autodiff + regression per step.
            name: "PINN+SR",
            kernels_per_step: seq * 8,
            flops_per_step: gru_flops * 1.4 + rk4_flops,
            param_bytes: 4 * (hid * hid * 4),
            trace_bytes: 4 * 200 * 4 * 14,
        },
        WorkloadModel {
            // MR (MERINDA): one fused GRU scan + RK4 loss per step.
            name: "MR",
            kernels_per_step: seq * 6,
            flops_per_step: gru_flops + rk4_flops,
            param_bytes: 4 * (4 * 3 * hid + hid * 3 * hid + 3 * hid + hid * 48 + 48 * 45 + 45),
            trace_bytes: 4 * 200 * 4 * 14,
        },
    ]
}

/// One Table 5 row for a (workload, platform) pair.
#[derive(Clone, Debug)]
pub struct PlatformRow {
    pub workload: &'static str,
    pub platform: &'static str,
    pub runtime_s: f64,
    pub power_w: f64,
    pub dram_mb: f64,
    pub freq_mhz: f64,
}

/// Evaluate a GPU-class platform on a workload (training run of `steps`).
pub fn evaluate(p: &PlatformModel, w: &WorkloadModel, steps: u64) -> PlatformRow {
    let runtime = p.runtime_s(steps, w.kernels_per_step, w.flops_per_step);
    let dram = if p.freq_mhz > 1000.0 {
        DramFootprint::gpu(w.param_bytes, w.trace_bytes)
    } else {
        DramFootprint::mobile_gpu(w.param_bytes, w.trace_bytes)
    };
    PlatformRow {
        workload: w.name,
        platform: p.name,
        runtime_s: runtime,
        power_w: p.power_w(),
        dram_mb: dram.total_mb(),
        freq_mhz: p.freq_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_overhead_dominates_ltc_on_gpu() {
        // The paper's premise: iterative small kernels are launch-bound.
        let gpu = PlatformModel::gpu();
        let w = workloads();
        let ltc = &w[0];
        let launch = ltc.kernels_per_step as f64 * gpu.launch_overhead_us * 1e-6;
        let compute = ltc.flops_per_step / (gpu.small_kernel_gflops * 1e9);
        assert!(launch > 5.0 * compute, "launch={launch} compute={compute}");
    }

    #[test]
    fn mr_faster_than_ltc_everywhere() {
        for p in [PlatformModel::gpu(), PlatformModel::mobile_gpu()] {
            let w = workloads();
            let ltc = evaluate(&p, &w[0], 500);
            let mr = evaluate(&p, &w[3], 500);
            assert!(
                mr.runtime_s < ltc.runtime_s,
                "{}: mr={} ltc={}",
                p.name,
                mr.runtime_s,
                ltc.runtime_s
            );
        }
    }

    #[test]
    fn gpu_dram_in_gigabytes_mobile_smaller() {
        let w = workloads();
        let g = evaluate(&PlatformModel::gpu(), &w[3], 500);
        let m = evaluate(&PlatformModel::mobile_gpu(), &w[3], 500);
        // Paper: GPU MR 6.1 GB, mobile 2.3 GB.
        assert!(g.dram_mb > 2000.0, "gpu dram {}", g.dram_mb);
        assert!(m.dram_mb < g.dram_mb);
    }

    #[test]
    fn gpu_power_band_matches_paper() {
        // Paper Table 5 GPU power: 64–72 W across workloads.
        let p = PlatformModel::gpu().power_w();
        assert!((40.0..110.0).contains(&p), "p={p}");
    }

    #[test]
    fn mobile_gpu_power_single_digit() {
        let p = PlatformModel::mobile_gpu().power_w();
        assert!((4.0..10.0).contains(&p), "p={p}");
    }

    #[test]
    fn frequencies_match_paper_column() {
        assert_eq!(PlatformModel::gpu().freq_mhz, 1410.0);
        assert_eq!(PlatformModel::mobile_gpu().freq_mhz, 306.0);
    }
}

//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Subcommand dispatch lives in `main.rs`; this module only tokenizes.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order (subcommand is `positional[0]`).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

/// Option keys that take a value; anything else starting with `--` is a flag.
pub fn parse(argv: &[String], value_keys: &[&str]) -> Args {
    let mut args = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(rest) = a.strip_prefix("--") {
            if let Some((k, v)) = rest.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if value_keys.contains(&rest) && i + 1 < argv.len() {
                args.options.insert(rest.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                args.flags.push(rest.to_string());
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    args
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(
            &sv(&["train", "--steps", "100", "--verbose", "--lr=0.01", "extra"]),
            &["steps"],
        );
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.flag("verbose"));
        assert!((a.get_f64("lr", 0.0) - 0.01).abs() < 1e-12);
        assert_eq!(a.positional[1], "extra");
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&sv(&["x"]), &[]);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("m", "d"), "d");
    }

    #[test]
    fn value_key_without_value_is_flag() {
        let a = parse(&sv(&["--steps"]), &["steps"]);
        assert!(a.flag("steps"));
    }
}

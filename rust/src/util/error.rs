//! Unified error type for the merinda crate.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error enum.
///
/// Kept deliberately small: most subsystems are infallible simulators; the
/// fallible surfaces are artifact I/O, PJRT execution, and shape/config
/// validation.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (artifact files, trace dumps, reports).
    Io(std::io::Error),
    /// PJRT / XLA failure (compile, transfer, execute).
    Xla(String),
    /// A shape or dimension mismatch between host data and an artifact.
    Shape { expected: String, got: String },
    /// Invalid configuration (CLI flags, accelerator configs, bank factors).
    Config(String),
    /// A numeric failure (divergence, NaN loss, singular matrix).
    Numeric(String),
    /// Artifact missing or malformed.
    Artifact(String),
    /// The serving layer is saturated: a bounded queue refused the item.
    ///
    /// Unlike [`Error::Config`], this is a *transient* condition — the
    /// caller may retry later or shed the work. The streaming coordinator
    /// keys its shed-vs-hold decision on this variant, so overload must
    /// never be reported as a generic config/string error.
    Overloaded {
        /// Queue occupancy observed at rejection time.
        depth: usize,
    },
    /// A serving instance died: its service was shut down or killed, a
    /// worker panicked (poisoned lock), or a response channel dropped
    /// mid-request.
    ///
    /// This is a *fleet-recoverable* fault, not a coordinator abort: the
    /// health state machine marks the instance down and the stranded
    /// windows fail over to healthy siblings. It must never be folded
    /// into [`Error::Config`] — retrying a dead instance is pointless,
    /// but retrying the *work* elsewhere is exactly the right move.
    ServiceDown {
        /// What died (queue closed, lock poisoned, channel dropped).
        reason: String,
    },
    /// A recovered result failed its fidelity check (non-finite or
    /// out-of-bound coefficients — the signature of fixed-point bit-flip
    /// corruption). The window is retried; the corrupt Θ is discarded.
    Corrupted {
        /// What the fidelity check saw.
        detail: String,
    },
    /// The admission controller refused new work: accepting the window
    /// would push the projected p99 latency of its QoS tier past the
    /// tier's SLO.
    ///
    /// Unlike [`Error::Overloaded`] (a bounded queue is *full* right
    /// now), admission rejection is a *policy* decision made before the
    /// work enters any queue — the caller should down-tier, retry after
    /// backlog drains, or drop the request. The open-loop traffic driver
    /// keys its per-tier rejected counters on this variant.
    Admission {
        /// QoS tier whose SLO would have been breached.
        tier: String,
        /// Projected p99 latency had the window been admitted (ms).
        projected_ms: f64,
        /// The tier's SLO target (ms).
        slo_ms: f64,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Shape { expected, got } => {
                write!(f, "shape mismatch: expected {expected}, got {got}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Overloaded { depth } => {
                write!(f, "overloaded: queue full at depth {depth} (backpressure)")
            }
            Error::ServiceDown { reason } => {
                write!(f, "service down: {reason} (fail over)")
            }
            Error::Corrupted { detail } => {
                write!(f, "corrupted result: {detail} (retry)")
            }
            Error::Admission {
                tier,
                projected_ms,
                slo_ms,
            } => {
                write!(
                    f,
                    "admission rejected: {tier} tier projected p99 {projected_ms:.1}ms \
                     exceeds SLO {slo_ms:.1}ms"
                )
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper for config validation failures.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Helper for numeric failures.
    pub fn numeric(msg: impl Into<String>) -> Self {
        Error::Numeric(msg.into())
    }

    /// True when the error is transient backpressure (retry or shed),
    /// as opposed to a permanent failure.
    pub fn is_overload(&self) -> bool {
        matches!(self, Error::Overloaded { .. })
    }

    /// Helper for instance-death faults.
    pub fn service_down(reason: impl Into<String>) -> Self {
        Error::ServiceDown {
            reason: reason.into(),
        }
    }

    /// Helper for fidelity-check failures.
    pub fn corrupted(detail: impl Into<String>) -> Self {
        Error::Corrupted {
            detail: detail.into(),
        }
    }

    /// True when the error means the serving instance is gone and the
    /// work should be re-placed on a healthy sibling.
    pub fn is_service_down(&self) -> bool {
        matches!(self, Error::ServiceDown { .. })
    }

    /// True when the error is a detected-corruption fault (retryable).
    pub fn is_corrupted(&self) -> bool {
        matches!(self, Error::Corrupted { .. })
    }

    /// Helper for admission-control rejections.
    pub fn admission(tier: impl Into<String>, projected_ms: f64, slo_ms: f64) -> Self {
        Error::Admission {
            tier: tier.into(),
            projected_ms,
            slo_ms,
        }
    }

    /// True when the error is an SLO-protecting admission rejection (the
    /// work never entered a queue; the caller may down-tier or drop it).
    pub fn is_admission(&self) -> bool {
        matches!(self, Error::Admission { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_io() {
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "x"));
        assert!(e.to_string().contains("io error"));
    }

    #[test]
    fn display_shape() {
        let e = Error::Shape {
            expected: "[2,2]".into(),
            got: "[3]".into(),
        };
        assert!(e.to_string().contains("expected [2,2]"));
    }

    #[test]
    fn config_helper() {
        assert!(Error::config("bad").to_string().contains("config"));
    }

    #[test]
    fn overload_is_typed_and_transient() {
        let e = Error::Overloaded { depth: 7 };
        assert!(e.is_overload());
        assert!(e.to_string().contains("depth 7"));
        assert!(!Error::config("full").is_overload());
    }

    #[test]
    fn service_down_is_typed_and_recoverable() {
        let e = Error::service_down("queue closed");
        assert!(e.is_service_down());
        assert!(!e.is_overload());
        assert!(e.to_string().contains("queue closed"));
        assert!(!Error::config("shut down").is_service_down());
    }

    #[test]
    fn admission_is_typed_and_policy_level() {
        let e = Error::admission("realtime", 812.5, 500.0);
        assert!(e.is_admission());
        assert!(!e.is_overload(), "admission is policy, not backpressure");
        let s = e.to_string();
        assert!(s.contains("realtime"));
        assert!(s.contains("812.5"));
        assert!(s.contains("500.0"));
        assert!(!Error::config("slo").is_admission());
    }

    #[test]
    fn corrupted_is_typed_and_retryable() {
        let e = Error::corrupted("theta[2] = NaN");
        assert!(e.is_corrupted());
        assert!(!e.is_service_down());
        assert!(e.to_string().contains("NaN"));
    }
}

//! Unified error type for the merinda crate.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error enum.
///
/// Kept deliberately small: most subsystems are infallible simulators; the
/// fallible surfaces are artifact I/O, PJRT execution, and shape/config
/// validation.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (artifact files, trace dumps, reports).
    Io(std::io::Error),
    /// PJRT / XLA failure (compile, transfer, execute).
    Xla(String),
    /// A shape or dimension mismatch between host data and an artifact.
    Shape { expected: String, got: String },
    /// Invalid configuration (CLI flags, accelerator configs, bank factors).
    Config(String),
    /// A numeric failure (divergence, NaN loss, singular matrix).
    Numeric(String),
    /// Artifact missing or malformed.
    Artifact(String),
    /// The serving layer is saturated: a bounded queue refused the item.
    ///
    /// Unlike [`Error::Config`], this is a *transient* condition — the
    /// caller may retry later or shed the work. The streaming coordinator
    /// keys its shed-vs-hold decision on this variant, so overload must
    /// never be reported as a generic config/string error.
    Overloaded {
        /// Queue occupancy observed at rejection time.
        depth: usize,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Shape { expected, got } => {
                write!(f, "shape mismatch: expected {expected}, got {got}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Overloaded { depth } => {
                write!(f, "overloaded: queue full at depth {depth} (backpressure)")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper for config validation failures.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Helper for numeric failures.
    pub fn numeric(msg: impl Into<String>) -> Self {
        Error::Numeric(msg.into())
    }

    /// True when the error is transient backpressure (retry or shed),
    /// as opposed to a permanent failure.
    pub fn is_overload(&self) -> bool {
        matches!(self, Error::Overloaded { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_io() {
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "x"));
        assert!(e.to_string().contains("io error"));
    }

    #[test]
    fn display_shape() {
        let e = Error::Shape {
            expected: "[2,2]".into(),
            got: "[3]".into(),
        };
        assert!(e.to_string().contains("expected [2,2]"));
    }

    #[test]
    fn config_helper() {
        assert!(Error::config("bad").to_string().contains("config"));
    }

    #[test]
    fn overload_is_typed_and_transient() {
        let e = Error::Overloaded { depth: 7 };
        assert!(e.is_overload());
        assert!(e.to_string().contains("depth 7"));
        assert!(!Error::config("full").is_overload());
    }
}

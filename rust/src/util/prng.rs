//! Deterministic pseudo-random number generation.
//!
//! A small xoshiro256++ implementation (public-domain algorithm by Blackman
//! & Vigna) seeded via SplitMix64. Every stochastic component in the crate
//! (weight init, synthetic noise, workload generators, property tests) draws
//! from this so runs are reproducible from a single `u64` seed.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.uniform_in(lo as f64, hi as f64) as f32
    }

    /// Standard normal via Box–Muller (one value per call; simple and fine
    /// for our volumes).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free reduction is overkill here; modulo bias
        // is negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Random boolean with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fill a slice with N(0, std) f32 values (weight init).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f64) {
        for v in out.iter_mut() {
            *v = (self.normal() * std) as f32;
        }
    }

    /// Vector of N(0, std) f32 values.
    pub fn normal_vec_f32(&mut self, n: usize, std: f64) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal_f32(&mut v, std);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Prng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut a = Prng::new(11);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}

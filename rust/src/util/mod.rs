//! In-tree utility layer.
//!
//! The offline build environment only vendors the `xla` crate's dependency
//! closure, so the conveniences a networked project would pull from
//! crates.io (CLI parser, PRNG, JSON writer, bench harness, property-test
//! runner) are implemented here instead.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prng;
pub mod stats;

pub use error::{Error, Result};
pub use prng::Prng;

//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a plain `fn main()` that uses [`Bench`] to
//! time closures with warm-up, repetition, and simple statistics, printing
//! rows in the same format as the paper's tables. `cargo bench` runs them.

use std::hint::black_box as bb;
use std::time::Instant;

use super::json::Json;
use super::stats;

/// One timed measurement series.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_s() * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s() * 1e6
    }

    pub fn std_ms(&self) -> f64 {
        stats::std_dev(&self.samples) * 1e3
    }

    pub fn median_ms(&self) -> f64 {
        stats::median(&self.samples) * 1e3
    }

    /// Machine-readable row: name + µs statistics + sample count.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("mean_us", Json::num(self.mean_us())),
            ("median_us", Json::num(self.median_ms() * 1e3)),
            ("std_us", Json::num(self.std_ms() * 1e3)),
            ("iters", Json::num(self.samples.len() as f64)),
        ])
    }
}

/// Simple timing harness with warm-up.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bench {
            warmup_iters,
            iters,
        }
    }

    /// Time `f`, returning per-iteration samples. The closure's return value
    /// is passed through `black_box` so work is not optimized away.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup_iters {
            bb(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            bb(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            samples,
        }
    }
}

/// Read a `usize` workload knob from the environment (`MERINDA_*`
/// variables used by the CI smoke steps to shrink bench/soak workloads),
/// falling back to `default` when unset or unparsable.
pub fn env_usize(name: &str, default: usize) -> usize {
    parse_usize_knob(std::env::var(name).ok().as_deref(), default)
}

/// The pure parsing half of [`env_usize`] (unit-testable without
/// mutating the process environment, which is racy under the threaded
/// test harness).
fn parse_usize_knob(value: Option<&str>, default: usize) -> usize {
    value.and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Resolve a tracked bench artifact path at the repository root (one
/// level above the crate manifest): cargo runs benches with the package
/// directory as CWD, but the `BENCH_*.json` trajectory files are tracked
/// at the repo root.
pub fn artifact_path(file: &str) -> std::path::PathBuf {
    let manifest = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".to_string());
    let dir = std::path::Path::new(&manifest);
    dir.parent().unwrap_or(dir).join(file)
}

/// Machine-readable bench report: measurement rows plus named
/// baseline-vs-optimized speedups, written as `BENCH_<name>.json` so the
/// perf trajectory is tracked across PRs. Deterministic (non-wall-clock)
/// results attach as named top-level sections.
pub struct BenchJson {
    bench: String,
    rows: Vec<Json>,
    speedups: Vec<(String, Json)>,
    sections: Vec<(String, Json)>,
}

impl BenchJson {
    pub fn new(bench: &str) -> BenchJson {
        BenchJson {
            bench: bench.to_string(),
            rows: Vec::new(),
            speedups: Vec::new(),
            sections: Vec::new(),
        }
    }

    /// Attach a named top-level section (e.g. deterministic cycle-model
    /// results that are not timings). Keys must not collide with the
    /// built-in `bench` / `rows` / `speedups` keys or an earlier section
    /// (the serializer would silently last-wins otherwise).
    pub fn section(&mut self, key: &str, value: Json) {
        assert!(
            !matches!(key, "bench" | "rows" | "speedups"),
            "section key {key:?} collides with a built-in report key"
        );
        assert!(
            self.sections.iter().all(|(k, _)| k != key),
            "duplicate section key {key:?}"
        );
        self.sections.push((key.to_string(), value));
    }

    /// Record one measurement row.
    pub fn record(&mut self, m: &Measurement) {
        self.rows.push(m.to_json());
    }

    /// Record a baseline-vs-optimized pair under `key`; returns the
    /// mean-time speedup (baseline / optimized).
    pub fn record_speedup(
        &mut self,
        key: &str,
        baseline: &Measurement,
        optimized: &Measurement,
    ) -> f64 {
        let speedup = baseline.mean_s() / optimized.mean_s().max(1e-12);
        self.speedups.push((
            key.to_string(),
            Json::obj(vec![
                ("baseline", Json::str(baseline.name.clone())),
                ("baseline_mean_us", Json::num(baseline.mean_us())),
                ("optimized", Json::str(optimized.name.clone())),
                ("optimized_mean_us", Json::num(optimized.mean_us())),
                ("speedup", Json::num(speedup)),
            ]),
        ));
        speedup
    }

    pub fn to_json(&self) -> Json {
        let mut map = std::collections::BTreeMap::new();
        map.insert("bench".to_string(), Json::str(self.bench.clone()));
        map.insert("rows".to_string(), Json::Arr(self.rows.clone()));
        map.insert(
            "speedups".to_string(),
            Json::Obj(
                self.speedups
                    .iter()
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect(),
            ),
        );
        for (k, v) in &self.sections {
            map.insert(k.clone(), v.clone());
        }
        Json::Obj(map)
    }

    /// Write the report as pretty-printed JSON.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
    }
}

/// Render a plain-text table with aligned columns (paper-table style).
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    let line = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        s.push('\n');
        s
    };
    let hdr: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&line(&hdr, &widths));
    let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row, &widths));
    }
    out
}

/// Format helper: fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let b = Bench::new(1, 5);
        let m = b.run("noop", || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean_s() >= 0.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let t = render_table(
            "T",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("== T =="));
        assert!(t.contains("333"));
        assert_eq!(t.matches('|').count(), 9);
    }

    #[test]
    fn fmt_decimals() {
        assert_eq!(f(1.23456, 2), "1.23");
    }

    #[test]
    fn env_knob_defaults_and_parses() {
        // Read-only env probe plus the pure parser; no set_var (racy
        // against concurrent getenv in the threaded test harness).
        assert_eq!(env_usize("MERINDA_TEST_KNOB_UNSET", 7), 7);
        assert_eq!(parse_usize_knob(Some("12"), 7), 12);
        assert_eq!(parse_usize_knob(Some("not-a-number"), 7), 7);
        assert_eq!(parse_usize_knob(Some(""), 7), 7);
        assert_eq!(parse_usize_knob(None, 7), 7);
    }

    #[test]
    fn measurement_json_roundtrips() {
        let m = Measurement {
            name: "row".into(),
            samples: vec![1e-6, 2e-6, 3e-6],
        };
        let j = m.to_json();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "row");
        assert_eq!(back.get("iters").unwrap().as_usize().unwrap(), 3);
        assert!((back.get("mean_us").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bench_json_records_speedups() {
        let base = Measurement {
            name: "slow".into(),
            samples: vec![4e-3; 5],
        };
        let opt = Measurement {
            name: "fast".into(),
            samples: vec![1e-3; 5],
        };
        let mut r = BenchJson::new("unit");
        r.record(&base);
        r.record(&opt);
        let s = r.record_speedup("kernel", &base, &opt);
        assert!((s - 4.0).abs() < 1e-9);
        let j = Json::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "unit");
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
        let sp = j.get("speedups").unwrap().get("kernel").unwrap();
        assert!((sp.get("speedup").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn bench_json_sections_appear_at_top_level() {
        let mut r = BenchJson::new("cycles");
        r.section(
            "ratios",
            Json::obj(vec![("dataflow_vs_sequential_ltc", Json::num(6.3))]),
        );
        let j = Json::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "cycles");
        let ratio = j
            .get("ratios")
            .unwrap()
            .get("dataflow_vs_sequential_ltc")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((ratio - 6.3).abs() < 1e-12);
    }

    #[test]
    fn artifact_path_points_at_repo_root() {
        // Under cargo, CARGO_MANIFEST_DIR is the `rust/` package dir; the
        // artifact must land one level up.
        let p = artifact_path("BENCH_test.json");
        assert!(p.ends_with("BENCH_test.json"));
        if let Ok(manifest) = std::env::var("CARGO_MANIFEST_DIR") {
            let root = std::path::Path::new(&manifest).parent().unwrap();
            assert_eq!(p.parent().unwrap(), root);
        }
    }

    #[test]
    fn bench_json_writes_file() {
        let mut r = BenchJson::new("filetest");
        r.record(&Measurement {
            name: "x".into(),
            samples: vec![1e-6],
        });
        let path = std::env::temp_dir().join("merinda_bench_json_test.json");
        r.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}

//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a plain `fn main()` that uses [`Bench`] to
//! time closures with warm-up, repetition, and simple statistics, printing
//! rows in the same format as the paper's tables. `cargo bench` runs them.

use std::hint::black_box as bb;
use std::time::Instant;

use super::stats;

/// One timed measurement series.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_s() * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s() * 1e6
    }

    pub fn std_ms(&self) -> f64 {
        stats::std_dev(&self.samples) * 1e3
    }

    pub fn median_ms(&self) -> f64 {
        stats::median(&self.samples) * 1e3
    }
}

/// Simple timing harness with warm-up.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bench {
            warmup_iters,
            iters,
        }
    }

    /// Time `f`, returning per-iteration samples. The closure's return value
    /// is passed through `black_box` so work is not optimized away.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup_iters {
            bb(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            bb(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            samples,
        }
    }
}

/// Render a plain-text table with aligned columns (paper-table style).
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    let line = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        s.push('\n');
        s
    };
    let hdr: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&line(&hdr, &widths));
    let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row, &widths));
    }
    out
}

/// Format helper: fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let b = Bench::new(1, 5);
        let m = b.run("noop", || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean_s() >= 0.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let t = render_table(
            "T",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("== T =="));
        assert!(t.contains("333"));
        assert_eq!(t.matches('|').count(), 9);
    }

    #[test]
    fn fmt_decimals() {
        assert_eq!(f(1.23456, 2), "1.23");
    }
}

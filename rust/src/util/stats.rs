//! Small statistics helpers shared by benches and reports.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// p-th percentile (0..=100), nearest-rank.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        / a.len() as f64
}

/// Root mean squared error.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    mse(a, b).sqrt()
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// f32 convenience wrappers.
pub fn mse_f32(a: &[f32], b: &[f32]) -> f64 {
    let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
    mse(&af, &bf)
}

pub fn max_abs_diff_f32(a: &[f32], b: &[f32]) -> f64 {
    let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
    max_abs_diff(&af, &bf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
    }

    #[test]
    fn mse_zero_for_equal() {
        let xs = [1.0, -2.0, 3.5];
        assert_eq!(mse(&xs, &xs), 0.0);
    }

    #[test]
    fn max_abs() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }
}

//! Minimal JSON reader/writer.
//!
//! The artifact manifest (`artifacts/manifest.json`) and report dumps are
//! JSON; with no `serde_json` available offline we implement the small
//! subset we need: objects, arrays, strings, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Integer array helper (shapes).
    pub fn usize_arr(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    item.write(out, depth + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    Json::Str(k.clone()).write(out, depth + 1, false);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or("eof in \\u")? as char;
                            code = code * 16 + d.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|e| e.to_string())?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
                None => return Err("eof in string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("gru_step")),
            ("shape", Json::usize_arr(&[16, 32])),
            ("ok", Json::Bool(true)),
            ("pi", Json::num(3.25)),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_pretty_output() {
        let j = Json::obj(vec![("k", Json::arr(vec![Json::num(1.0)]))]);
        let back = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn parses_negative_exponent() {
        let j = Json::parse("-1.5e-3").unwrap();
        assert!((j.as_f64().unwrap() + 0.0015).abs() < 1e-12);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""éµ""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "éµ");
    }
}

//! Parse-or-execute experiments runner: one registry entry per paper
//! table/figure, regenerated from committed JSON logs under
//! `experiments/` at the repo root.
//!
//! The discipline follows the NSDI figure-script shape: each artifact is
//! backed by a per-experiment log; a run *parses* the log when it is
//! present and fresh and *executes* the generator only when the log is
//! missing, stale (schema-version mismatch) or explicitly forced. Two
//! consecutive `merinda experiments` runs therefore converge: the first
//! may execute missing entries and write their logs, the second
//! regenerates every table/figure purely by parsing. Every run emits the
//! aggregated `BENCH_experiments.json`, gated in CI by
//! `ci/check_bench_experiments.py`. See EXPERIMENTS.md §Paper results
//! for the table→command reproduction index.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::runtime::Runtime;
use crate::util::bench::{artifact_path, env_usize, BenchJson};
use crate::util::json::Json;
use crate::util::{Error, Result};

use super::experiments as exp;
use super::Table;

/// Log-format version. Bumping it invalidates every committed log: the
/// next run re-executes all entries (the "stale" half of parse-or-execute).
pub const SCHEMA_VERSION: u64 = 1;

/// How [`Runner::run_one`] resolves a log-vs-generator decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Parse the log when present and fresh; execute (and write the log)
    /// otherwise. The default, and what `--execute` names explicitly.
    ParseOrExecute,
    /// Never execute: a missing or stale log is an error. This is how CI
    /// asserts that a second run performs zero executions.
    ParseOnly,
    /// Always execute and rewrite the log, ignoring any committed state.
    Force,
}

/// Where a regenerated record came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// Read back from the committed per-experiment log.
    Parsed,
    /// Freshly executed by the generator (log rewritten).
    Executed,
}

impl fmt::Display for Source {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Source::Parsed => write!(f, "parsed"),
            Source::Executed => write!(f, "executed"),
        }
    }
}

/// One our-value / paper-value pair with a declared tolerance band on
/// the `ours / paper` ratio.
///
/// Gated comparisons are enforced by `ci/check_bench_experiments.py`;
/// informational ones (wall-clock-derived, or where the simulator is
/// documented to diverge from the paper's silicon) are emitted for the
/// trajectory but never fail the gate.
///
/// ```
/// use merinda::report::runner::Comparison;
/// let c = Comparison::gated("cycles", 1212.0, 1201.0, 0.5, 2.0);
/// assert!((c.ratio() - 1.00916).abs() < 1e-3);
/// assert!(c.within_band());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct Comparison {
    /// Metric name, unique within one experiment.
    pub metric: String,
    /// Our measured / modeled value.
    pub ours: f64,
    /// The paper's reported value (must be > 0).
    pub paper: f64,
    /// Declared `(lo, hi)` band on `ours / paper`; `(0, 0)` and unused
    /// when not gated.
    pub band: (f64, f64),
    /// Whether the CI gate enforces the band.
    pub gated: bool,
}

impl Comparison {
    /// A gated comparison: CI fails if `ours / paper` leaves `[lo, hi]`.
    pub fn gated(metric: impl Into<String>, ours: f64, paper: f64, lo: f64, hi: f64) -> Comparison {
        assert!(paper > 0.0, "paper value must be positive");
        assert!(lo <= hi, "band lo must not exceed hi");
        Comparison {
            metric: metric.into(),
            ours,
            paper,
            band: (lo, hi),
            gated: true,
        }
    }

    /// An informational comparison: recorded for the trajectory, never
    /// enforced.
    pub fn informational(metric: impl Into<String>, ours: f64, paper: f64) -> Comparison {
        assert!(paper > 0.0, "paper value must be positive");
        Comparison {
            metric: metric.into(),
            ours,
            paper,
            band: (0.0, 0.0),
            gated: false,
        }
    }

    /// `ours / paper`.
    pub fn ratio(&self) -> f64 {
        self.ours / self.paper
    }

    /// Gated band check; informational comparisons always pass.
    pub fn within_band(&self) -> bool {
        !self.gated || (self.ratio() >= self.band.0 && self.ratio() <= self.band.1)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("metric", Json::str(self.metric.clone())),
            ("ours", Json::num(self.ours)),
            ("paper", Json::num(self.paper)),
            ("ratio", Json::num(self.ratio())),
            ("band_lo", Json::num(self.band.0)),
            ("band_hi", Json::num(self.band.1)),
            ("gated", Json::Bool(self.gated)),
            ("within_band", Json::Bool(self.within_band())),
        ])
    }

    fn from_json(j: &Json) -> Result<Comparison> {
        let field = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::config(format!("comparison missing numeric {k:?}")))
        };
        let metric = j
            .get("metric")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::config("comparison missing metric"))?
            .to_string();
        let gated = matches!(j.get("gated"), Some(Json::Bool(true)));
        Ok(Comparison {
            metric,
            ours: field("ours")?,
            paper: field("paper")?,
            band: (field("band_lo")?, field("band_hi")?),
            gated,
        })
    }
}

/// The structured result of one regenerated paper table/figure: the
/// rendered table (title/headers/rows), the our-vs-paper comparisons,
/// an optional ASCII chart (Fig. 8), and free-form provenance notes.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentRecord {
    /// Registry id (`table1` … `table8`, `fig8`, `cycles`).
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub comparisons: Vec<Comparison>,
    /// ASCII chart body (Fig. 8's power/energy bars).
    pub chart: Option<String>,
    /// Provenance: fallbacks taken, workload knobs, calibration caveats.
    pub notes: Vec<String>,
}

impl ExperimentRecord {
    /// Build a record from a rendered [`Table`].
    pub fn from_table(id: &str, t: &Table) -> ExperimentRecord {
        ExperimentRecord {
            id: id.to_string(),
            title: t.title.clone(),
            headers: t.headers.clone(),
            rows: t.rows.clone(),
            comparisons: Vec::new(),
            chart: None,
            notes: Vec::new(),
        }
    }

    /// The record's table view (what benches and the CLI print).
    pub fn table(&self) -> Table {
        Table {
            title: self.title.clone(),
            headers: self.headers.clone(),
            rows: self.rows.clone(),
        }
    }

    /// All gated comparisons sit inside their declared bands.
    pub fn gated_ok(&self) -> bool {
        self.comparisons.iter().all(Comparison::within_band)
    }

    /// Serialize as the per-experiment log body (includes the schema
    /// version that staleness detection keys on).
    pub fn to_json(&self) -> Json {
        let strs = |xs: &[String]| Json::Arr(xs.iter().map(Json::str).collect());
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("title", Json::str(self.title.clone())),
            ("headers", strs(&self.headers)),
            (
                "rows",
                Json::Arr(self.rows.iter().map(|r| strs(r)).collect()),
            ),
            (
                "comparisons",
                Json::Arr(self.comparisons.iter().map(Comparison::to_json).collect()),
            ),
            (
                "chart",
                match &self.chart {
                    Some(c) => Json::str(c.clone()),
                    None => Json::Null,
                },
            ),
            ("notes", strs(&self.notes)),
        ])
    }

    /// Parse a log body; rejects schema-version mismatches (the caller
    /// treats that as "stale → re-execute").
    pub fn from_json(j: &Json) -> Result<ExperimentRecord> {
        let version = j
            .get("schema_version")
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::config("log missing schema_version"))? as u64;
        if version != SCHEMA_VERSION {
            return Err(Error::config(format!(
                "log schema_version {version} != {SCHEMA_VERSION}"
            )));
        }
        let text = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| Error::config(format!("log missing {k:?}")))
        };
        let str_arr = |v: &Json| -> Result<Vec<String>> {
            v.as_arr()
                .ok_or_else(|| Error::config("expected a string array"))?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::config("expected a string"))
                })
                .collect()
        };
        let headers = str_arr(
            j.get("headers")
                .ok_or_else(|| Error::config("log missing headers"))?,
        )?;
        let rows = j
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::config("log missing rows"))?
            .iter()
            .map(&str_arr)
            .collect::<Result<Vec<_>>>()?;
        let comparisons = j
            .get("comparisons")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::config("log missing comparisons"))?
            .iter()
            .map(Comparison::from_json)
            .collect::<Result<Vec<_>>>()?;
        let chart = match j.get("chart") {
            Some(Json::Str(c)) => Some(c.clone()),
            _ => None,
        };
        let notes = match j.get("notes") {
            Some(v) => str_arr(v)?,
            None => Vec::new(),
        };
        Ok(ExperimentRecord {
            id: text("id")?,
            title: text("title")?,
            headers,
            rows,
            comparisons,
            chart,
            notes,
        })
    }
}

/// Workload knobs the executing generators consume.
#[derive(Clone, Debug)]
pub struct ExecCtx {
    /// PJRT artifact directory probed by the Table 6 entry; when absent
    /// the entry falls back to the native MERINDA polish.
    pub artifact_dir: String,
    /// Samples per system for the Table 6 recovery comparison
    /// (`MERINDA_EXP_SAMPLES` shrinks it in CI).
    pub table6_samples: usize,
    /// Seed for the stochastic generators.
    pub seed: u64,
}

impl Default for ExecCtx {
    fn default() -> Self {
        ExecCtx {
            artifact_dir: "artifacts".to_string(),
            table6_samples: env_usize("MERINDA_EXP_SAMPLES", 1200),
            seed: 23,
        }
    }
}

/// One registry entry: a paper artifact and its generator.
pub struct Entry {
    /// Registry id and log-file stem.
    pub id: &'static str,
    /// The paper artifact this entry reproduces.
    pub anchor: &'static str,
    execute: fn(&ExecCtx) -> Result<ExperimentRecord>,
}

fn run_table1(_: &ExecCtx) -> Result<ExperimentRecord> {
    Ok(exp::table1_record())
}

fn run_table2(_: &ExecCtx) -> Result<ExperimentRecord> {
    Ok(exp::table2_record())
}

fn run_table3(_: &ExecCtx) -> Result<ExperimentRecord> {
    Ok(exp::table3_record())
}

fn run_table4(_: &ExecCtx) -> Result<ExperimentRecord> {
    exp::table4_record()
}

fn run_table5(_: &ExecCtx) -> Result<ExperimentRecord> {
    exp::table5_record()
}

fn run_table6(ctx: &ExecCtx) -> Result<ExperimentRecord> {
    let opts = exp::Table6Opts {
        samples: ctx.table6_samples,
        seed: ctx.seed,
        ..Default::default()
    };
    match Runtime::new(&ctx.artifact_dir) {
        Ok(rt) => exp::table6_record(&rt, opts),
        Err(_) => exp::table6_native_record(opts),
    }
}

fn run_table7(_: &ExecCtx) -> Result<ExperimentRecord> {
    Ok(exp::table7_record())
}

fn run_table8(_: &ExecCtx) -> Result<ExperimentRecord> {
    Ok(exp::table8_record())
}

fn run_fig8(_: &ExecCtx) -> Result<ExperimentRecord> {
    Ok(exp::fig8_record())
}

fn run_cycles(_: &ExecCtx) -> Result<ExperimentRecord> {
    exp::cycles_record()
}

static ENTRIES: [Entry; 10] = [
    Entry {
        id: "table1",
        anchor: "Table 1 (forward-pass split)",
        execute: run_table1,
    },
    Entry {
        id: "table2",
        anchor: "Table 2 (ODE-step breakdown)",
        execute: run_table2,
    },
    Entry {
        id: "table3",
        anchor: "Table 3 (case-study systems)",
        execute: run_table3,
    },
    Entry {
        id: "table4",
        anchor: "Table 4 (SINDy MR time/energy/DRAM)",
        execute: run_table4,
    },
    Entry {
        id: "table5",
        anchor: "Table 5 (cross-platform comparison)",
        execute: run_table5,
    },
    Entry {
        id: "table6",
        anchor: "Table 6 (recovery accuracy)",
        execute: run_table6,
    },
    Entry {
        id: "table7",
        anchor: "Table 7 (stage-mapping sweep)",
        execute: run_table7,
    },
    Entry {
        id: "table8",
        anchor: "Table 8 (accelerator configs)",
        execute: run_table8,
    },
    Entry {
        id: "fig8",
        anchor: "Fig. 8 (power/energy bars)",
        execute: run_fig8,
    },
    Entry {
        id: "cycles",
        anchor: "§6 headline cycle ratios",
        execute: run_cycles,
    },
];

/// One regenerated experiment with its provenance.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub record: ExperimentRecord,
    pub source: Source,
}

/// The parse-or-execute runner over a log directory.
///
/// ```
/// use merinda::report::runner::{Mode, Runner, Source};
/// let dir = std::env::temp_dir().join("merinda-doc-runner");
/// let runner = Runner::new(&dir);
/// // Force one execution, then the committed log alone must suffice.
/// let first = runner.run_one("table8", Mode::Force).unwrap();
/// let second = runner.run_one("table8", Mode::ParseOnly).unwrap();
/// assert_eq!(first.source, Source::Executed);
/// assert_eq!(second.source, Source::Parsed);
/// assert_eq!(first.record.rows, second.record.rows);
/// ```
pub struct Runner {
    log_dir: PathBuf,
    ctx: ExecCtx,
}

impl Runner {
    /// A runner over `log_dir` with the default [`ExecCtx`].
    pub fn new(log_dir: impl AsRef<Path>) -> Runner {
        Runner {
            log_dir: log_dir.as_ref().to_path_buf(),
            ctx: ExecCtx::default(),
        }
    }

    /// A runner with explicit workload knobs.
    pub fn with_ctx(log_dir: impl AsRef<Path>, ctx: ExecCtx) -> Runner {
        Runner {
            log_dir: log_dir.as_ref().to_path_buf(),
            ctx,
        }
    }

    /// The canonical runner: logs live in `experiments/` at the repo root
    /// (one level above the crate manifest, like the `BENCH_*.json`
    /// artifacts).
    pub fn at_repo_root() -> Runner {
        Runner::new(artifact_path("experiments"))
    }

    pub fn log_dir(&self) -> &Path {
        &self.log_dir
    }

    /// All registry ids, in paper order.
    pub fn ids() -> Vec<&'static str> {
        ENTRIES.iter().map(|e| e.id).collect()
    }

    /// The full registry (id + paper anchor), for index rendering.
    pub fn entries() -> &'static [Entry] {
        &ENTRIES
    }

    /// Look up a registry entry by id.
    pub fn entry(id: &str) -> Result<&'static Entry> {
        ENTRIES.iter().find(|e| e.id == id).ok_or_else(|| {
            Error::config(format!(
                "unknown experiment {id:?}; valid ids: {}",
                Runner::ids().join(", ")
            ))
        })
    }

    /// The per-experiment log path (`<log_dir>/<id>.json`).
    pub fn log_path(&self, id: &str) -> PathBuf {
        self.log_dir.join(format!("{id}.json"))
    }

    /// Read back a fresh log, or `None` when it is missing, unparsable,
    /// stale (schema-version mismatch) or recorded under another id.
    pub fn load(&self, id: &str) -> Option<ExperimentRecord> {
        let text = std::fs::read_to_string(self.log_path(id)).ok()?;
        let json = Json::parse(&text).ok()?;
        let rec = ExperimentRecord::from_json(&json).ok()?;
        if rec.id == id {
            Some(rec)
        } else {
            None
        }
    }

    /// Parse-or-execute one experiment (see [`Mode`]). Executions write
    /// the log back so the next run parses.
    pub fn run_one(&self, id: &str, mode: Mode) -> Result<RunOutcome> {
        let entry = Runner::entry(id)?;
        if mode != Mode::Force {
            if let Some(record) = self.load(id) {
                return Ok(RunOutcome {
                    record,
                    source: Source::Parsed,
                });
            }
            if mode == Mode::ParseOnly {
                return Err(Error::config(format!(
                    "no fresh log for {id} at {}; run `merinda experiments` \
                     (or --force) to regenerate it",
                    self.log_path(id).display()
                )));
            }
        }
        let record = (entry.execute)(&self.ctx)?;
        std::fs::create_dir_all(&self.log_dir)?;
        std::fs::write(self.log_path(id), record.to_json().to_pretty())?;
        Ok(RunOutcome {
            record,
            source: Source::Executed,
        })
    }

    /// Run a set of experiments in registry order.
    pub fn run(&self, ids: &[&str], mode: Mode) -> Result<Vec<RunOutcome>> {
        ids.iter().map(|id| self.run_one(id, mode)).collect()
    }

    /// Aggregate outcomes into the `BENCH_experiments.json` report:
    /// one `experiments.<id>` section per record (with its `source`) and
    /// a `summary` envelope the CI gate cross-checks.
    pub fn bench_report(outcomes: &[RunOutcome]) -> BenchJson {
        let mut experiments = std::collections::BTreeMap::new();
        let mut executed = 0usize;
        let mut comparisons = 0usize;
        let mut gated = 0usize;
        let mut gated_within = 0usize;
        for out in outcomes {
            if out.source == Source::Executed {
                executed += 1;
            }
            comparisons += out.record.comparisons.len();
            for c in &out.record.comparisons {
                if c.gated {
                    gated += 1;
                    if c.within_band() {
                        gated_within += 1;
                    }
                }
            }
            let mut obj = match out.record.to_json() {
                Json::Obj(o) => o,
                _ => unreachable!("record json is an object"),
            };
            obj.insert("source".to_string(), Json::str(out.source.to_string()));
            experiments.insert(out.record.id.clone(), Json::Obj(obj));
        }
        let mut report = BenchJson::new("experiments");
        report.section("experiments", Json::Obj(experiments));
        report.section(
            "summary",
            Json::obj(vec![
                ("experiments", Json::num(outcomes.len() as f64)),
                ("executed", Json::num(executed as f64)),
                ("parsed", Json::num((outcomes.len() - executed) as f64)),
                ("comparisons", Json::num(comparisons as f64)),
                ("gated_comparisons", Json::num(gated as f64)),
                ("gated_within_band", Json::num(gated_within as f64)),
                ("all_within_band", Json::Bool(gated == gated_within)),
            ]),
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record() -> ExperimentRecord {
        ExperimentRecord {
            id: "table9".to_string(),
            title: "Table 9: unit".to_string(),
            headers: vec!["a".into(), "b".into()],
            rows: vec![vec!["1".into(), "2".into()]],
            comparisons: vec![
                Comparison::gated("x", 2.0, 1.0, 0.5, 3.0),
                Comparison::informational("y", 10.0, 1.0),
            ],
            chart: Some("##".to_string()),
            notes: vec!["unit fixture".to_string()],
        }
    }

    #[test]
    fn record_json_round_trips() {
        let rec = sample_record();
        let back = ExperimentRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(rec, back);
    }

    #[test]
    fn stale_schema_version_is_rejected() {
        let mut obj = match sample_record().to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        obj.insert("schema_version".to_string(), Json::num(999.0));
        assert!(ExperimentRecord::from_json(&Json::Obj(obj)).is_err());
    }

    #[test]
    fn comparison_band_semantics() {
        let inside = Comparison::gated("m", 190.0, 107.0, 0.5, 2.0);
        assert!(inside.within_band());
        let outside = Comparison::gated("m", 1000.0, 107.0, 0.5, 2.0);
        assert!(!outside.within_band());
        // Informational comparisons never fail the gate.
        let info = Comparison::informational("m", 1000.0, 107.0);
        assert!(info.within_band());
    }

    #[test]
    fn registry_ids_are_distinct_and_complete() {
        let ids = Runner::ids();
        // Joined comparison pins count, order and distinctness at once.
        assert_eq!(
            ids.join(","),
            "table1,table2,table3,table4,table5,table6,table7,table8,fig8,cycles"
        );
        assert!(Runner::entry("table99").is_err());
    }

    #[test]
    fn bench_report_summary_is_consistent() {
        let outcomes = vec![
            RunOutcome {
                record: sample_record(),
                source: Source::Executed,
            },
            RunOutcome {
                record: ExperimentRecord {
                    id: "table10".to_string(),
                    ..sample_record()
                },
                source: Source::Parsed,
            },
        ];
        let j = Json::parse(&Runner::bench_report(&outcomes).to_json().to_pretty()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "experiments");
        let s = j.get("summary").unwrap();
        assert_eq!(s.get("experiments").unwrap().as_usize().unwrap(), 2);
        assert_eq!(s.get("executed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(s.get("parsed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(s.get("comparisons").unwrap().as_usize().unwrap(), 4);
        assert_eq!(s.get("gated_comparisons").unwrap().as_usize().unwrap(), 2);
        assert_eq!(s.get("gated_within_band").unwrap().as_usize().unwrap(), 2);
        assert_eq!(s.get("all_within_band").unwrap(), &Json::Bool(true));
        let exps = j.get("experiments").unwrap();
        assert_eq!(
            exps.get("table9").unwrap().get("source").unwrap().as_str(),
            Some("executed")
        );
        assert_eq!(
            exps.get("table10").unwrap().get("source").unwrap().as_str(),
            Some("parsed")
        );
    }
}

//! Experiment generators: one function per paper table/figure.
//!
//! Shared by the CLI (`merinda table N`) and the bench harness
//! (`cargo bench`). Each returns a [`Table`] (or chart string) whose rows
//! contain our measured values with the paper's values alongside, so the
//! reproduction "shape" is auditable at a glance. See DESIGN.md §5 for the
//! experiment index and EXPERIMENTS.md for recorded runs.

use crate::fpga::gru_accel::{all_stage_maps, stage_map_name, GruAccel, GruAccelConfig};
use crate::fpga::interconnect::DramFootprint;
use crate::fpga::ltc_accel::{LtcAccel, LtcAccelConfig};
use crate::fpga::resources::Device;
use crate::mr::ltc::{LtcCell, LtcParams};
use crate::mr::recover::{self, MerindaOpts};
use crate::mr::train::TrainOpts;
use crate::platform::{evaluate, workloads, PlatformModel};
use crate::runtime::Runtime;
use crate::systems::{table6_systems, Aid, Apc, AvLateral, CaseStudy};
use crate::util::{Prng, Result};

use super::{bar_chart, fmt, sci, Table};

/// Table 1: overall forward pass split (sensory vs ODE solver).
pub fn table1() -> Table {
    let mut rng = Prng::new(11);
    let cell = LtcCell::new(LtcParams::random(4, 16, &mut rng, 0.3), 6);
    let xs = rng.normal_vec_f32(64 * 4, 1.0);
    // Warm up, then measure.
    let _ = cell.profile(&xs, 64, 0.1);
    let p = cell.profile(&xs, 64, 0.1);
    let total = p.sensory_s + p.solver_s;
    let ms = |s: f64| fmt(s * 1e3, 6);
    let pct = |s: f64| fmt(100.0 * s / total, 1);

    let mut t = Table::new(
        "Table 1: Overall Forward Pass (LTC, 64 steps x 6 solver sub-steps)",
        &["Operation", "Time (ms)", "Share (%)", "Paper share"],
    );
    t.row(vec![
        "Sensory Processing".into(),
        ms(p.sensory_s),
        pct(p.sensory_s),
        "12.3%".into(),
    ]);
    t.row(vec![
        "ODE Solver (6 steps)".into(),
        ms(p.solver_s),
        pct(p.solver_s),
        "87.7%".into(),
    ]);
    t.row(vec![
        "Total Forward Pass".into(),
        ms(total),
        "100.0".into(),
        "100.0%".into(),
    ]);
    t
}

/// Table 2: per-ODE-step component breakdown.
pub fn table2() -> Table {
    let mut rng = Prng::new(13);
    let cell = LtcCell::new(LtcParams::random(4, 16, &mut rng, 0.3), 6);
    let xs = rng.normal_vec_f32(256 * 4, 1.0);
    let _ = cell.profile(&xs, 256, 0.1);
    let p = cell.profile(&xs, 256, 0.1);
    let per_step = |s: f64| s / p.steps as f64;
    let step_total = per_step(
        p.recurrent_sigmoid_s
            + p.weight_activation_s
            + p.reversal_activation_s
            + p.sum_ops_s
            + p.euler_update_s,
    );
    let ms = |s: f64| fmt(per_step(s) * 1e3, 6);
    let pct = |s: f64| fmt(100.0 * per_step(s) / step_total, 1);

    let mut t = Table::new(
        "Table 2: ODE Step Breakdown (per solver sub-step)",
        &["Operation", "Time (ms)", "Share (%)", "Paper share"],
    );
    for (name, secs, paper) in [
        ("Recurrent Sigmoid", p.recurrent_sigmoid_s, "46.7%"),
        ("Weight Activation", p.weight_activation_s, "2.4%"),
        ("Reversal Activation", p.reversal_activation_s, "2.5%"),
        ("Sum Operations", p.sum_ops_s, "34.4%"),
        ("Euler Update", p.euler_update_s, "14.0%"),
    ] {
        t.row(vec![name.into(), ms(secs), pct(secs), paper.into()]);
    }
    t.row(vec![
        "Single ODE Step Total".into(),
        fmt(step_total * 1e3, 6),
        "100.0".into(),
        "100.0%".into(),
    ]);
    t
}

/// Table 4: SINDy-MR on AID / Autonomous Car / APC through the FPGA model.
pub fn table4() -> Result<Table> {
    let device = Device::pynq_z2();
    let mut t = Table::new(
        "Table 4: FPGA execution time, energy, DRAM (SINDy MR per system)",
        &[
            "System",
            "Time (s)",
            "Energy (J)",
            "DRAM (MB)",
            "Paper (s / J / MB)",
        ],
    );
    let mut rng = Prng::new(17);
    let systems: Vec<(Box<dyn CaseStudy>, usize, f64, &str)> = vec![
        (Box::new(Aid::default()), 200, 5.0, "56.63 / 107.88 / 192.36"),
        (
            Box::new(AvLateral::default()),
            2000,
            0.01,
            "21.23 / 40.44 / 213.00",
        ),
        (Box::new(Apc::default()), 2000, 0.05, "20.74 / 39.43 / 289.18"),
    ];
    for (sys, samples, dt, paper) in systems {
        let tr = sys.generate(samples, dt, &mut rng);
        // Host-measured SINDy wall time (the algorithm itself)...
        let t0 = std::time::Instant::now();
        let rec = recover::recover_sindy(&tr)?;
        let host_s = t0.elapsed().as_secs_f64();
        let _ = rec;
        // ...scaled onto the PYNQ's ARM A9 (≈120× slower than this host
        // for dense f64 loops — calibrated once, DESIGN.md §7), plus the
        // library-evaluation offload modeled on the fabric.
        let arm_scale = 120.0;
        let fpga_s = host_s * arm_scale;
        let accel = GruAccel::new(GruAccelConfig::gru_baseline());
        let rep = accel.report();
        let power = rep.power_w;
        let energy = power * fpga_s * 0.45; // duty-cycled fabric
        let params = 4 * 45u64;
        let trace_bytes = (samples * (sys.xdim() + sys.udim()) * 8) as u64;
        let dram = DramFootprint::fpga(params, trace_bytes).total_mb()
            + (samples as f64 * 0.12); // regression workspace
        t.row(vec![
            sys.name().into(),
            fmt(fpga_s, 2),
            fmt(energy, 2),
            fmt(dram, 2),
            paper.into(),
        ]);
    }
    let _ = device;
    Ok(t)
}

/// Table 5: workloads × platforms on the AID dataset.
pub fn table5(rt: Option<&Runtime>) -> Result<Table> {
    let mut t = Table::new(
        "Table 5: Cross-platform comparison, AID workload",
        &[
            "Workload",
            "Platform",
            "Runtime (s)",
            "Power (W)",
            "DRAM (MB)",
            "Freq (MHz)",
        ],
    );
    let steps = 500u64;
    let dev = Device::pynq_z2();
    for w in workloads() {
        // GPU + mobile GPU from the calibrated platform models.
        for p in [PlatformModel::gpu(), PlatformModel::mobile_gpu()] {
            let row = evaluate(&p, &w, steps);
            t.row(vec![
                w.name.into(),
                row.platform.into(),
                fmt(row.runtime_s, 2),
                fmt(row.power_w, 2),
                fmt(row.dram_mb, 0),
                fmt(row.freq_mhz, 0),
            ]);
        }
        // FPGA column from the cycle simulator.
        let (cycles_per_step, power_w) = match w.name {
            "LTC" => {
                let r = LtcAccel::new(LtcAccelConfig::base()).report();
                (r.interval * 64, r.power_w)
            }
            "SINDY" => {
                let r = GruAccel::new(GruAccelConfig::gru_baseline()).report();
                (r.interval * 8, r.power_w * 0.95)
            }
            "PINN+SR" => {
                let r = GruAccel::new(GruAccelConfig::gru_baseline()).report();
                (r.interval * 48, r.power_w)
            }
            _ => {
                let r = GruAccel::new(GruAccelConfig::concurrent()).report();
                (r.interval * 64, r.power_w + 1.4) // + DMA/PS load
            }
        };
        let runtime_s = dev.cycles_to_seconds(cycles_per_step * steps);
        let params = w.param_bytes;
        let dram = DramFootprint::fpga(params, w.trace_bytes).total_mb();
        t.row(vec![
            w.name.into(),
            "FPGA (PYNQ-Z2)".into(),
            fmt(runtime_s, 2),
            fmt(power_w, 2),
            fmt(dram, 0),
            fmt(dev.clock_mhz, 0),
        ]);
    }
    let _ = rt;
    Ok(t)
}

/// Table 6 options (training budget for MERINDA).
#[derive(Clone, Copy, Debug)]
pub struct Table6Opts {
    pub samples: usize,
    pub merinda_steps: usize,
    pub seed: u64,
}

impl Default for Table6Opts {
    fn default() -> Self {
        Table6Opts {
            samples: 1200,
            merinda_steps: 120,
            seed: 23,
        }
    }
}

/// Table 6: reconstruction MSE, EMILY vs PINN+SR vs MERINDA, 4 systems.
pub fn table6(rt: &Runtime, opts: Table6Opts) -> Result<Table> {
    let mut t = Table::new(
        "Table 6: Recovery accuracy (trajectory reconstruction MSE)",
        &[
            "Application",
            "EMILY",
            "PINN+SR",
            "MERINDA",
            "Paper (EMILY/PINN+SR/MERINDA)",
        ],
    );
    let papers = [
        "0.03 / 0.05 / 0.03",
        "1.7 / 2.11 / 1.68",
        "4.2 / 6.9 / 5.1",
        "14.3 / 12.1 / 15.1",
    ];
    let mut rng = Prng::new(opts.seed);
    for (sys, paper) in table6_systems().iter().zip(papers) {
        // Per-system dt tuned for identifiability.
        let dt = match sys.name() {
            "Chaotic Lorenz" => 0.004,
            "F8 Cruiser" => 0.01,
            _ => 0.01,
        };
        let tr = sys
            .generate(opts.samples, dt, &mut rng)
            .with_noise(0.002, &mut rng);
        let e = recover::recover_emily(&tr)?;
        let p = recover::recover_pinn_sr(&tr)?;
        let m = recover::recover_merinda(
            rt,
            &tr,
            MerindaOpts {
                train: TrainOpts {
                    steps: opts.merinda_steps,
                    dt: dt as f32 * 10.0, // normalized-time step
                    seed: opts.seed,
                    ..Default::default()
                },
                ..Default::default()
            },
        )?;
        t.row(vec![
            sys.name().into(),
            sci(e.recon_mse),
            sci(p.recon_mse),
            sci(m.recon_mse),
            paper.into(),
        ]);
    }
    Ok(t)
}

/// Table 7: the 16-way stage-mapping sweep.
pub fn table7() -> Table {
    let mut t = Table::new(
        "Table 7: Stage-wise compute mapping (D=DSP, L=LUT/carry)",
        &["Config", "Cycles", "LUT", "FF", "DSP", "BRAM", "fits 7020"],
    );
    for m in all_stage_maps() {
        let cfg = GruAccelConfig::concurrent().with_stage_map(m);
        let r = GruAccel::new(cfg).report();
        t.row(vec![
            stage_map_name(&m),
            r.cycles.to_string(),
            r.resources.lut.to_string(),
            r.resources.ff.to_string(),
            r.resources.dsp.to_string(),
            r.resources.bram18.to_string(),
            if r.fits_pynq { "yes" } else { "NO" }.into(),
        ]);
    }
    t
}

/// The four Table 8 configurations with their paper rows.
pub fn table8_rows() -> Vec<(String, u64, u64, crate::fpga::resources::Resources, f64, f64)> {
    let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
    let mut rows = vec![(
        "LTC".to_string(),
        ltc.cycles,
        ltc.interval,
        ltc.resources,
        ltc.power_w,
        ltc.energy_per_output_j,
    )];
    for (name, cfg) in [
        ("GRU Baseline", GruAccelConfig::gru_baseline()),
        ("Concurrent GRU", GruAccelConfig::concurrent()),
        ("BRAM optimal GRU", GruAccelConfig::bram_optimal()),
    ] {
        let r = GruAccel::new(cfg).report();
        rows.push((
            name.to_string(),
            r.cycles,
            r.interval,
            r.resources,
            r.power_w,
            r.energy_per_output_j,
        ));
    }
    rows
}

/// Table 8: cycles/resources/interval/power across the four configs.
pub fn table8() -> Table {
    let mut t = Table::new(
        "Table 8: Accelerator configurations",
        &[
            "Configuration",
            "Cycles",
            "Interval",
            "LUT",
            "FF",
            "DSP",
            "BRAM",
            "Power (W)",
            "Paper (cyc/intv/W)",
        ],
    );
    let paper = [
        "1201 / 12014 / 5.11",
        "1045 / 271 / 4.736",
        "380 / 145 / 3.013",
        "190 / 107 / 4.15",
    ];
    for ((name, cycles, interval, res, power, _e), p) in table8_rows().into_iter().zip(paper) {
        t.row(vec![
            name,
            cycles.to_string(),
            interval.to_string(),
            res.lut.to_string(),
            res.ff.to_string(),
            res.dsp.to_string(),
            res.bram18.to_string(),
            fmt(power, 3),
            p.into(),
        ]);
    }
    t
}

/// Fig. 8: power (linear) and energy (log) across the four configs.
pub fn fig8() -> String {
    let rows = table8_rows();
    let power: Vec<(String, f64)> = rows.iter().map(|r| (r.0.clone(), r.4)).collect();
    let energy: Vec<(String, f64)> = rows.iter().map(|r| (r.0.clone(), r.5)).collect();
    let mut out = String::new();
    out.push_str(&bar_chart("Fig 8a: Power (W, linear)", &power, 40, false));
    out.push_str(&bar_chart(
        "Fig 8b: Energy per output (J, log scale)",
        &energy,
        40,
        true,
    ));
    out
}

/// Sanity metric reused by tests: MERINDA-vs-paper Table 8 speedup shape.
pub fn table8_speedups() -> (f64, f64, f64) {
    let rows = table8_rows();
    let ltc = rows[0].2 as f64;
    let base = rows[1].2 as f64;
    let conc = rows[2].2 as f64;
    let bank = rows[3].2 as f64;
    (ltc / base, base / conc, conc / bank)
}

/// End-to-end AID demo metric for EXPERIMENTS.md: final loss after a
/// PJRT training run.
pub fn aid_train_demo(rt: &Runtime, steps: usize, seed: u64) -> Result<crate::mr::train::TrainReport> {
    use crate::mr::train::PjrtTrainer;
    let mut rng = Prng::new(seed);
    let tr = Aid::default().generate(200, 5.0, &mut rng);
    let (y, u) = tr.padded_f32(3, 1);
    let scale: f32 = y.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let y: Vec<f32> = y.iter().map(|v| v / scale).collect();
    let mut trainer = PjrtTrainer::new(rt, seed)?;
    trainer.train(
        &y,
        &u,
        TrainOpts {
            steps,
            seed,
            ..Default::default()
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_solver_dominates() {
        let t = table1();
        // Row 1 is the solver; its share column must exceed 60%.
        let share: f64 = t.rows[1][2].parse().unwrap();
        assert!(share > 60.0, "solver share {share}");
    }

    #[test]
    fn table2_sigmoid_and_sums_lead() {
        let t = table2();
        let get = |i: usize| -> f64 { t.rows[i][2].parse().unwrap() };
        let sigmoid = get(0);
        let sums = get(3);
        let weight = get(1);
        let reversal = get(2);
        assert!(sigmoid > weight && sigmoid > reversal);
        assert!(sigmoid + sums > 50.0, "sigmoid+sums = {}", sigmoid + sums);
    }

    #[test]
    fn table7_best_config_is_mixed_mapping() {
        let t = table7();
        // The minimum-cycle config should not be one of the all-LUT rows
        // (paper: s1D_s2L_s3L_s4D wins).
        let best = t
            .rows
            .iter()
            .min_by_key(|r| r[1].parse::<u64>().unwrap())
            .unwrap();
        assert!(best[0].starts_with("s1D"), "best={}", best[0]);
    }

    #[test]
    fn table8_speedup_shape() {
        let (s1, s2, s3) = table8_speedups();
        // Paper: 44.3x (LTC→GRU), 1.87x (→DATAFLOW), 1.36x (→banking).
        assert!(s1 > 3.0, "LTC→GRU {s1}");
        assert!(s2 > 1.2, "GRU→DATAFLOW {s2}");
        assert!(s3 > 1.05, "DATAFLOW→banking {s3}");
    }

    #[test]
    fn fig8_chart_renders() {
        let s = fig8();
        assert!(s.contains("Fig 8a") && s.contains("Fig 8b"));
        assert!(s.contains("LTC"));
    }

    #[test]
    fn table4_generates_three_rows() {
        let t = table4().unwrap();
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn table5_has_twelve_rows() {
        let t = table5(None).unwrap();
        assert_eq!(t.rows.len(), 12); // 4 workloads × 3 platforms
    }
}

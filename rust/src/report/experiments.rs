//! Experiment generators: one function per paper table/figure.
//!
//! Shared by the CLI (`merinda table N`, `merinda experiments`) and the
//! bench harness (`cargo bench`). Each `tableN()` returns a [`Table`]
//! (or chart string) whose rows contain our measured values with the
//! paper's values alongside, so the reproduction "shape" is auditable at
//! a glance; each `tableN_record()` additionally emits the structured
//! our-value/paper-value comparisons that feed the parse-or-execute
//! runner ([`super::runner`]) and the CI-gated `BENCH_experiments.json`.
//! See EXPERIMENTS.md §Paper results for the table→command reproduction
//! index and recorded runs.

use crate::fpga::gru_accel::{all_stage_maps, stage_map_name, GruAccel, GruAccelConfig};
use crate::fpga::interconnect::DramFootprint;
use crate::fpga::ltc_accel::{LtcAccel, LtcAccelConfig};
use crate::fpga::resources::Device;
use crate::mr::library::PolyLibrary;
use crate::mr::ltc::{LtcCell, LtcParams};
use crate::mr::recover::{self, MerindaOpts, Recovery};
use crate::mr::train::TrainOpts;
use crate::platform::{evaluate, workloads, PlatformModel};
use crate::runtime::Runtime;
use crate::systems::{table6_systems, Aid, Apc, AvLateral, CaseStudy, Trace};
use crate::util::bench::env_usize;
use crate::util::{Error, Prng, Result};

use super::runner::{Comparison, ExperimentRecord};
use super::{bar_chart, fmt, sci, Table};

/// Parse a numeric table cell (the generators format every measured cell
/// with [`fmt`]/[`sci`], both of which `f64::from_str` accepts).
fn cell(t: &Table, row: usize, col: usize) -> f64 {
    t.rows[row][col]
        .parse()
        .unwrap_or_else(|_| panic!("non-numeric cell [{row}][{col}]: {:?}", t.rows[row][col]))
}

/// Table 1: overall forward pass split (sensory vs ODE solver).
pub fn table1() -> Table {
    let mut rng = Prng::new(11);
    let cell = LtcCell::new(LtcParams::random(4, 16, &mut rng, 0.3), 6);
    let xs = rng.normal_vec_f32(64 * 4, 1.0);
    // Warm up, then measure.
    let _ = cell.profile(&xs, 64, 0.1);
    let p = cell.profile(&xs, 64, 0.1);
    let total = p.sensory_s + p.solver_s;
    let ms = |s: f64| fmt(s * 1e3, 6);
    let pct = |s: f64| fmt(100.0 * s / total, 1);

    let mut t = Table::new(
        "Table 1: Overall Forward Pass (LTC, 64 steps x 6 solver sub-steps)",
        &["Operation", "Time (ms)", "Share (%)", "Paper share"],
    );
    t.row(vec![
        "Sensory Processing".into(),
        ms(p.sensory_s),
        pct(p.sensory_s),
        "12.3%".into(),
    ]);
    t.row(vec![
        "ODE Solver (6 steps)".into(),
        ms(p.solver_s),
        pct(p.solver_s),
        "87.7%".into(),
    ]);
    t.row(vec![
        "Total Forward Pass".into(),
        ms(total),
        "100.0".into(),
        "100.0%".into(),
    ]);
    t
}

/// Structured Table 1 record: the solver-dominance share is gated (the
/// paper's structural claim), the sensory share is informational
/// (wall-clock measured on whatever host executes).
pub fn table1_record() -> ExperimentRecord {
    let t = table1();
    let sensory = cell(&t, 0, 2);
    let solver = cell(&t, 1, 2);
    let mut rec = ExperimentRecord::from_table("table1", &t);
    rec.comparisons = vec![
        // Paper: 87.7% solver. Gate: solver stays dominant (60..100%).
        Comparison::gated("solver_share_pct", solver, 87.7, 0.68, 1.14),
        Comparison::informational("sensory_share_pct", sensory, 12.3),
    ];
    rec.notes.push("shares are host wall-clock; only solver dominance is gated".to_string());
    rec
}

/// Table 2: per-ODE-step component breakdown.
pub fn table2() -> Table {
    let mut rng = Prng::new(13);
    let cell = LtcCell::new(LtcParams::random(4, 16, &mut rng, 0.3), 6);
    let xs = rng.normal_vec_f32(256 * 4, 1.0);
    let _ = cell.profile(&xs, 256, 0.1);
    let p = cell.profile(&xs, 256, 0.1);
    let per_step = |s: f64| s / p.steps as f64;
    let step_total = per_step(
        p.recurrent_sigmoid_s
            + p.weight_activation_s
            + p.reversal_activation_s
            + p.sum_ops_s
            + p.euler_update_s,
    );
    let ms = |s: f64| fmt(per_step(s) * 1e3, 6);
    let pct = |s: f64| fmt(100.0 * per_step(s) / step_total, 1);

    let mut t = Table::new(
        "Table 2: ODE Step Breakdown (per solver sub-step)",
        &["Operation", "Time (ms)", "Share (%)", "Paper share"],
    );
    for (name, secs, paper) in [
        ("Recurrent Sigmoid", p.recurrent_sigmoid_s, "46.7%"),
        ("Weight Activation", p.weight_activation_s, "2.4%"),
        ("Reversal Activation", p.reversal_activation_s, "2.5%"),
        ("Sum Operations", p.sum_ops_s, "34.4%"),
        ("Euler Update", p.euler_update_s, "14.0%"),
    ] {
        t.row(vec![name.into(), ms(secs), pct(secs), paper.into()]);
    }
    t.row(vec![
        "Single ODE Step Total".into(),
        fmt(step_total * 1e3, 6),
        "100.0".into(),
        "100.0%".into(),
    ]);
    t
}

/// Structured Table 2 record: per-component shares are informational
/// (host wall-clock); the structural claim — recurrent sigmoid + sum
/// operations dominate the ODE step — is gated.
pub fn table2_record() -> ExperimentRecord {
    let t = table2();
    let share = |row: usize| cell(&t, row, 2);
    let mut rec = ExperimentRecord::from_table("table2", &t);
    // Paper shares: 46.7 + 34.4 = 81.1% for sigmoid + sums.
    rec.comparisons = vec![
        Comparison::gated("sigmoid_plus_sums_share_pct", share(0) + share(3), 81.1, 0.62, 1.24),
        Comparison::informational("recurrent_sigmoid_share_pct", share(0), 46.7),
        Comparison::informational("weight_activation_share_pct", share(1), 2.4),
        Comparison::informational("reversal_activation_share_pct", share(2), 2.5),
        Comparison::informational("sum_operations_share_pct", share(3), 34.4),
        Comparison::informational("euler_update_share_pct", share(4), 14.0),
    ];
    rec.notes.push("shares are host wall-clock; only sigmoid+sums dominance is gated".to_string());
    rec
}

/// Table 3: the case-study system roster (paper §6.1) — dimensions,
/// polynomial-library size, and ground-truth sparsity per system.
pub fn table3() -> Table {
    table3_record().table()
}

/// Structured Table 3 record; the roster shape (7 systems, 4 of them in
/// the Table 6 accuracy comparison) is gated.
pub fn table3_record() -> ExperimentRecord {
    let mut roster: Vec<(Box<dyn CaseStudy>, &str)> = table6_systems()
        .into_iter()
        .map(|s| (s, "Table 6, soak"))
        .collect();
    roster.push((Box::new(Aid::default()), "Table 4/5, soak"));
    roster.push((Box::new(AvLateral::default()), "Table 4, soak"));
    roster.push((Box::new(Apc::default()), "Table 4"));
    let table6_count = 4usize;

    let mut t = Table::new(
        "Table 3: Case-study systems (dims, library, ground-truth sparsity)",
        &[
            "System",
            "xdim",
            "udim",
            "Library terms",
            "True nonzeros",
            "Appears in",
        ],
    );
    for (sys, appears) in &roster {
        let lib = PolyLibrary::new(sys.xdim(), sys.udim(), 2);
        let nonzeros = match sys.true_coeffs() {
            Some(c) => c.iter().filter(|v| **v != 0.0).count().to_string(),
            None => "-".to_string(),
        };
        t.row(vec![
            sys.name().into(),
            sys.xdim().to_string(),
            sys.udim().to_string(),
            lib.len().to_string(),
            nonzeros,
            (*appears).into(),
        ]);
    }
    let mut rec = ExperimentRecord::from_table("table3", &t);
    rec.comparisons = vec![
        Comparison::gated("systems", roster.len() as f64, 7.0, 1.0, 1.0),
        Comparison::gated("table6_systems", table6_count as f64, 4.0, 1.0, 1.0),
    ];
    rec.notes.push("roster characterization is fully deterministic (no measurement)".to_string());
    rec
}

/// Table 4: SINDy-MR on AID / Autonomous Car / APC through the FPGA model.
pub fn table4() -> Result<Table> {
    let device = Device::pynq_z2();
    let mut t = Table::new(
        "Table 4: FPGA execution time, energy, DRAM (SINDy MR per system)",
        &[
            "System",
            "Time (s)",
            "Energy (J)",
            "DRAM (MB)",
            "Paper (s / J / MB)",
        ],
    );
    let mut rng = Prng::new(17);
    let systems: Vec<(Box<dyn CaseStudy>, usize, f64, &str)> = vec![
        (Box::new(Aid::default()), 200, 5.0, "56.63 / 107.88 / 192.36"),
        (
            Box::new(AvLateral::default()),
            2000,
            0.01,
            "21.23 / 40.44 / 213.00",
        ),
        (Box::new(Apc::default()), 2000, 0.05, "20.74 / 39.43 / 289.18"),
    ];
    for (sys, samples, dt, paper) in systems {
        let tr = sys.generate(samples, dt, &mut rng);
        // Host-measured SINDy wall time (the algorithm itself)...
        let t0 = std::time::Instant::now();
        let rec = recover::recover_sindy(&tr)?;
        let host_s = t0.elapsed().as_secs_f64();
        let _ = rec;
        // ...scaled onto the PYNQ's ARM A9 (≈120× slower than this host
        // for dense f64 loops — calibrated once; see EXPERIMENTS.md
        // §Paper results), plus the library-evaluation offload modeled
        // on the fabric.
        let arm_scale = 120.0;
        let fpga_s = host_s * arm_scale;
        let accel = GruAccel::new(GruAccelConfig::gru_baseline());
        let rep = accel.report();
        let power = rep.power_w;
        let energy = power * fpga_s * 0.45; // duty-cycled fabric
        let params = 4 * 45u64;
        let trace_bytes = (samples * (sys.xdim() + sys.udim()) * 8) as u64;
        let dram = DramFootprint::fpga(params, trace_bytes).total_mb()
            + (samples as f64 * 0.12); // regression workspace
        t.row(vec![
            sys.name().into(),
            fmt(fpga_s, 2),
            fmt(energy, 2),
            fmt(dram, 2),
            paper.into(),
        ]);
    }
    let _ = device;
    Ok(t)
}

/// Structured Table 4 record: DRAM footprints are model-derived and
/// gated; time and energy pass through the host-dependent ARM scaling,
/// so they stay informational.
pub fn table4_record() -> Result<ExperimentRecord> {
    let t = table4()?;
    // Paper per-system (time s, energy J, DRAM MB), in row order.
    let paper = [
        ("aid", 56.63, 107.88, 192.36),
        ("av_lateral", 21.23, 40.44, 213.00),
        ("apc", 20.74, 39.43, 289.18),
    ];
    let mut rec = ExperimentRecord::from_table("table4", &t);
    for (row, (key, time, energy, dram)) in paper.iter().enumerate() {
        rec.comparisons.push(Comparison::informational(
            format!("{key}_time_s"),
            cell(&t, row, 1),
            *time,
        ));
        rec.comparisons.push(Comparison::informational(
            format!("{key}_energy_j"),
            cell(&t, row, 2),
            *energy,
        ));
        // The DRAM model (params + 2×trace + runtime + workspace) is
        // deterministic; its calibrated ratios sit in 0.45..1.45.
        rec.comparisons.push(Comparison::gated(
            format!("{key}_dram_mb"),
            cell(&t, row, 3),
            *dram,
            0.2,
            2.0,
        ));
    }
    rec.notes.push(
        "time/energy scaled by the calibrated ARM factor (120x), informational only".to_string(),
    );
    Ok(rec)
}

/// Table 5: workloads × platforms on the AID dataset.
pub fn table5() -> Result<Table> {
    let mut t = Table::new(
        "Table 5: Cross-platform comparison, AID workload",
        &[
            "Workload",
            "Platform",
            "Runtime (s)",
            "Power (W)",
            "DRAM (MB)",
            "Freq (MHz)",
        ],
    );
    let steps = 500u64;
    let dev = Device::pynq_z2();
    for w in workloads() {
        // GPU + mobile GPU from the calibrated platform models.
        for p in [PlatformModel::gpu(), PlatformModel::mobile_gpu()] {
            let row = evaluate(&p, &w, steps);
            t.row(vec![
                w.name.into(),
                row.platform.into(),
                fmt(row.runtime_s, 2),
                fmt(row.power_w, 2),
                fmt(row.dram_mb, 0),
                fmt(row.freq_mhz, 0),
            ]);
        }
        // FPGA column from the cycle simulator.
        let (cycles_per_step, power_w) = match w.name {
            "LTC" => {
                let r = LtcAccel::new(LtcAccelConfig::base()).report();
                (r.interval * 64, r.power_w)
            }
            "SINDY" => {
                let r = GruAccel::new(GruAccelConfig::gru_baseline()).report();
                (r.interval * 8, r.power_w * 0.95)
            }
            "PINN+SR" => {
                let r = GruAccel::new(GruAccelConfig::gru_baseline()).report();
                (r.interval * 48, r.power_w)
            }
            _ => {
                let r = GruAccel::new(GruAccelConfig::concurrent()).report();
                (r.interval * 64, r.power_w + 1.4) // + DMA/PS load
            }
        };
        let runtime_s = dev.cycles_to_seconds(cycles_per_step * steps);
        let params = w.param_bytes;
        let dram = DramFootprint::fpga(params, w.trace_bytes).total_mb();
        t.row(vec![
            w.name.into(),
            "FPGA (PYNQ-Z2)".into(),
            fmt(runtime_s, 2),
            fmt(power_w, 2),
            fmt(dram, 0),
            fmt(dev.clock_mhz, 0),
        ]);
    }
    Ok(t)
}

/// Structured Table 5 record: the table shape (4 workloads × 3
/// platforms) and the modeled PYNQ clock are gated; cross-platform cell
/// values are platform-model estimates without embedded paper cells, so
/// the FPGA-vs-GPU power advantage is recorded as the one structural
/// comparison.
pub fn table5_record() -> Result<ExperimentRecord> {
    let t = table5()?;
    let rows = t.rows.len() as f64;
    let clock = cell(&t, 2, 5); // first FPGA row
    let power_frac = cell(&t, 2, 3) / cell(&t, 0, 3).max(1e-9);
    let mut rec = ExperimentRecord::from_table("table5", &t);
    rec.comparisons = vec![
        Comparison::gated("rows", rows, 12.0, 1.0, 1.0),
        // Paper runs the PYNQ-Z2 fabric at 173 MHz.
        Comparison::gated("fpga_clock_mhz", clock, 173.0, 0.99, 1.01),
        // Structural claim: the FPGA draws a small fraction of GPU power.
        Comparison::gated("fpga_over_gpu_power", power_frac, 0.05, 0.1, 10.0),
    ];
    rec.notes.push(
        "platform cells are calibrated-model estimates; no per-cell paper values embedded"
            .to_string(),
    );
    Ok(rec)
}

/// Table 6 options (training budget for MERINDA).
#[derive(Clone, Copy, Debug)]
pub struct Table6Opts {
    pub samples: usize,
    pub merinda_steps: usize,
    pub seed: u64,
}

impl Default for Table6Opts {
    fn default() -> Self {
        Table6Opts {
            samples: 1200,
            merinda_steps: 120,
            seed: 23,
        }
    }
}

/// Table 6: reconstruction MSE, EMILY vs PINN+SR vs MERINDA, 4 systems.
pub fn table6(rt: &Runtime, opts: Table6Opts) -> Result<Table> {
    table6_record(rt, opts).map(|r| r.table())
}

/// Structured Table 6 record with MERINDA trained through the PJRT
/// artifacts (requires `make artifacts`).
pub fn table6_record(rt: &Runtime, opts: Table6Opts) -> Result<ExperimentRecord> {
    table6_record_impl(opts, "MERINDA trained via the PJRT AOT artifacts", |tr, mo| {
        recover::recover_merinda(rt, tr, mo)
    })
}

/// Structured Table 6 record on the native fallback
/// ([`recover::recover_merinda_native`]): the same sparsity-driven
/// masked-ridge polish, with STLSQ proposing the support instead of the
/// trained neural flow. Used by the experiments runner when no PJRT
/// artifacts are present (offline containers, CI).
pub fn table6_native_record(opts: Table6Opts) -> Result<ExperimentRecord> {
    table6_record_impl(
        opts,
        "no PJRT artifacts: MERINDA column uses the native STLSQ-support fallback",
        recover::recover_merinda_native,
    )
}

fn table6_record_impl<F>(opts: Table6Opts, note: &str, mut merinda: F) -> Result<ExperimentRecord>
where
    F: FnMut(&Trace, MerindaOpts) -> Result<Recovery>,
{
    let mut t = Table::new(
        "Table 6: Recovery accuracy (trajectory reconstruction MSE)",
        &[
            "Application",
            "EMILY",
            "PINN+SR",
            "MERINDA",
            "Paper (EMILY/PINN+SR/MERINDA)",
        ],
    );
    // Paper MSEs per system: (EMILY, PINN+SR, MERINDA).
    let papers = [
        ("lotka", 0.03, 0.05, 0.03),
        ("lorenz", 1.7, 2.11, 1.68),
        ("f8", 4.2, 6.9, 5.1),
        ("pathogen", 14.3, 12.1, 15.1),
    ];
    let mut comparisons = Vec::new();
    let mut rng = Prng::new(opts.seed);
    for (sys, (key, pe, pp, pm)) in table6_systems().iter().zip(papers) {
        // Per-system dt tuned for identifiability.
        let dt = match sys.name() {
            "Chaotic Lorenz" => 0.004,
            "F8 Cruiser" => 0.01,
            _ => 0.01,
        };
        let tr = sys
            .generate(opts.samples, dt, &mut rng)
            .with_noise(0.002, &mut rng);
        let e = recover::recover_emily(&tr)?;
        let p = recover::recover_pinn_sr(&tr)?;
        let m = merinda(
            &tr,
            MerindaOpts {
                train: TrainOpts {
                    steps: opts.merinda_steps,
                    dt: dt as f32 * 10.0, // normalized-time step
                    seed: opts.seed,
                    ..Default::default()
                },
                ..Default::default()
            },
        )?;
        t.row(vec![
            sys.name().into(),
            sci(e.recon_mse),
            sci(p.recon_mse),
            sci(m.recon_mse),
            format!("{pe} / {pp} / {pm}"),
        ]);
        // MSE magnitudes track trajectory scale and noise draw, so all
        // accuracy comparisons stay informational.
        comparisons.push(Comparison::informational(
            format!("{key}_emily_mse"),
            e.recon_mse,
            pe,
        ));
        comparisons.push(Comparison::informational(
            format!("{key}_pinn_sr_mse"),
            p.recon_mse,
            pp,
        ));
        comparisons.push(Comparison::informational(
            format!("{key}_merinda_mse"),
            m.recon_mse,
            pm,
        ));
    }
    let mut rec = ExperimentRecord::from_table("table6", &t);
    rec.comparisons = comparisons;
    rec.notes.push(note.to_string());
    rec.notes.push(format!("samples={} merinda_steps={}", opts.samples, opts.merinda_steps));
    Ok(rec)
}

/// Table 7: the 16-way stage-mapping sweep.
pub fn table7() -> Table {
    let mut t = Table::new(
        "Table 7: Stage-wise compute mapping (D=DSP, L=LUT/carry)",
        &["Config", "Cycles", "LUT", "FF", "DSP", "BRAM", "fits 7020"],
    );
    for m in all_stage_maps() {
        let cfg = GruAccelConfig::concurrent().with_stage_map(m);
        let r = GruAccel::new(cfg).report();
        t.row(vec![
            stage_map_name(&m),
            r.cycles.to_string(),
            r.resources.lut.to_string(),
            r.resources.ff.to_string(),
            r.resources.dsp.to_string(),
            r.resources.bram18.to_string(),
            if r.fits_pynq { "yes" } else { "NO" }.into(),
        ]);
    }
    t
}

/// Structured Table 7 record: the sweep shape and the
/// binding-moves-resources-not-throughput invariant are gated (all
/// cycle-model derived, machine-independent).
pub fn table7_record() -> ExperimentRecord {
    let t = table7();
    let cycles: Vec<f64> = (0..t.rows.len()).map(|r| cell(&t, r, 1)).collect();
    let best = cycles.iter().cloned().fold(f64::INFINITY, f64::min);
    let worst = cycles.iter().cloned().fold(0.0f64, f64::max);
    let mut rec = ExperimentRecord::from_table("table7", &t);
    rec.comparisons = vec![
        Comparison::gated("mappings", t.rows.len() as f64, 16.0, 1.0, 1.0),
        // Paper: DSP/LUT binding shifts resources, not cycles; the
        // sweep's cycle spread stays within 15% of flat.
        Comparison::gated("cycle_spread", worst / best.max(1.0), 1.0, 0.9, 1.15),
        Comparison::informational("best_cycles", best, 380.0),
    ];
    rec.notes.push(
        "full gate lives in ci/check_bench_table7.py over BENCH_table7.json".to_string(),
    );
    rec
}

/// The four Table 8 configurations with their paper rows.
pub fn table8_rows() -> Vec<(String, u64, u64, crate::fpga::resources::Resources, f64, f64)> {
    let ltc = LtcAccel::new(LtcAccelConfig::base()).report();
    let mut rows = vec![(
        "LTC".to_string(),
        ltc.cycles,
        ltc.interval,
        ltc.resources,
        ltc.power_w,
        ltc.energy_per_output_j,
    )];
    for (name, cfg) in [
        ("GRU Baseline", GruAccelConfig::gru_baseline()),
        ("Concurrent GRU", GruAccelConfig::concurrent()),
        ("BRAM optimal GRU", GruAccelConfig::bram_optimal()),
    ] {
        let r = GruAccel::new(cfg).report();
        rows.push((
            name.to_string(),
            r.cycles,
            r.interval,
            r.resources,
            r.power_w,
            r.energy_per_output_j,
        ));
    }
    rows
}

/// Table 8: cycles/resources/interval/power across the four configs.
pub fn table8() -> Table {
    let mut t = Table::new(
        "Table 8: Accelerator configurations",
        &[
            "Configuration",
            "Cycles",
            "Interval",
            "LUT",
            "FF",
            "DSP",
            "BRAM",
            "Power (W)",
            "Paper (cyc/intv/W)",
        ],
    );
    let paper = [
        "1201 / 12014 / 5.11",
        "1045 / 271 / 4.736",
        "380 / 145 / 3.013",
        "190 / 107 / 4.15",
    ];
    for ((name, cycles, interval, res, power, _e), p) in table8_rows().into_iter().zip(paper) {
        t.row(vec![
            name,
            cycles.to_string(),
            interval.to_string(),
            res.lut.to_string(),
            res.ff.to_string(),
            res.dsp.to_string(),
            res.bram18.to_string(),
            fmt(power, 3),
            p.into(),
        ]);
    }
    t
}

/// Fig. 8: power (linear) and energy (log) across the four configs.
pub fn fig8() -> String {
    let rows = table8_rows();
    let power: Vec<(String, f64)> = rows.iter().map(|r| (r.0.clone(), r.4)).collect();
    let energy: Vec<(String, f64)> = rows.iter().map(|r| (r.0.clone(), r.5)).collect();
    let mut out = String::new();
    out.push_str(&bar_chart("Fig 8a: Power (W, linear)", &power, 40, false));
    out.push_str(&bar_chart(
        "Fig 8b: Energy per output (J, log scale)",
        &energy,
        40,
        true,
    ));
    out
}

/// Structured Fig. 8 record: the power/energy table behind the bars plus
/// the rendered ASCII chart; modeled powers are informational.
pub fn fig8_record() -> ExperimentRecord {
    let rows = table8_rows();
    let mut t = Table::new(
        "Fig 8: Power and energy per output across configurations",
        &["Configuration", "Power (W)", "Energy/output (J)"],
    );
    let paper_power = [5.11, 4.736, 3.013, 4.15];
    let mut comparisons = vec![Comparison::gated("configs", rows.len() as f64, 4.0, 1.0, 1.0)];
    for ((name, _, _, _, power, energy), pw) in rows.iter().zip(paper_power) {
        t.row(vec![name.clone(), fmt(*power, 3), sci(*energy)]);
        let key = name.to_lowercase().replace(' ', "_");
        comparisons.push(Comparison::informational(format!("{key}_power_w"), *power, pw));
    }
    let mut rec = ExperimentRecord::from_table("fig8", &t);
    rec.comparisons = comparisons;
    rec.chart = Some(fig8());
    rec.notes.push("powers from the resource/power model, not board telemetry".to_string());
    rec
}

/// Structured record for the §6 headline cycle comparison (the
/// `BENCH_cycles.json` trajectory): dataflow vs sequential GRU vs
/// sequential LTC through the deterministic cycle model, with the exact
/// event simulation cross-checked against the closed form.
/// `MERINDA_BENCH_SEQ` overrides the window length (CI shrinks it).
pub fn cycles_record() -> Result<ExperimentRecord> {
    let seq: u64 = env_usize("MERINDA_BENCH_SEQ", 64) as u64;
    let df_accel = GruAccel::new(GruAccelConfig::concurrent());
    let df = df_accel.report();
    let sq = GruAccel::new(GruAccelConfig::gru_baseline()).report();
    let ltc = LtcAccel::new(LtcAccelConfig::base()).report();

    let pipe = df_accel.stage_pipeline();
    let analyzed = pipe.analyze(seq);
    let simulated = pipe.simulate(seq);
    if simulated != analyzed {
        return Err(Error::numeric(
            "event simulation drifted from the closed-form pipeline analysis",
        ));
    }

    let w_df = df.window_cycles(seq);
    let w_sq = sq.window_cycles(seq);
    let w_ltc = ltc.window_cycles(seq);

    let mut t = Table::new(
        "Cycle comparison: dataflow GRU vs sequential GRU vs sequential LTC",
        &["Design", "Cycles/step", "Interval", "Window cycles"],
    );
    for (name, r, w) in [
        ("GRU dataflow", &df, w_df),
        ("GRU sequential", &sq, w_sq),
        ("LTC sequential", &ltc, w_ltc),
    ] {
        t.row(vec![
            name.into(),
            r.cycles.to_string(),
            r.interval.to_string(),
            w.to_string(),
        ]);
    }
    let mut rec = ExperimentRecord::from_table("cycles", &t);
    rec.comparisons = vec![
        // Same silicon anchors as Table 8.
        Comparison::gated("ltc_interval", ltc.interval as f64, 12014.0, 0.5, 1.5),
        Comparison::gated("ltc_cycles", ltc.cycles as f64, 1201.0, 0.5, 2.0),
        // Paper headline: up to 6.3x fewer cycles per window; our model
        // lands far above it (ROADMAP trajectory note), so informational.
        Comparison::informational(
            "dataflow_vs_sequential_ltc",
            w_ltc as f64 / w_df as f64,
            6.3,
        ),
        Comparison::informational(
            "gru_dataflow_vs_gru_sequential",
            w_sq as f64 / w_df as f64,
            1.87,
        ),
    ];
    rec.notes.push(format!("window length seq={seq}"));
    rec.notes.push("event simulation verified equal to the closed form".to_string());
    Ok(rec)
}

/// Sanity metric reused by tests: MERINDA-vs-paper Table 8 speedup shape.
pub fn table8_speedups() -> (f64, f64, f64) {
    let rows = table8_rows();
    let ltc = rows[0].2 as f64;
    let base = rows[1].2 as f64;
    let conc = rows[2].2 as f64;
    let bank = rows[3].2 as f64;
    (ltc / base, base / conc, conc / bank)
}

/// Structured Table 8 record. Cycle-model numbers are deterministic:
/// the LTC and GRU-baseline cycle counts land near the paper's silicon,
/// so those are gated; intervals and the aggressive dataflow rows
/// diverge from silicon by design (documented in ROADMAP's trajectory
/// note) and stay informational, as do modeled powers.
pub fn table8_record() -> ExperimentRecord {
    let t = table8();
    let rows = table8_rows();
    // Paper per-config (cycles, interval, power W), in row order.
    let paper = [
        ("ltc", 1201.0, 12014.0, 5.11),
        ("gru_baseline", 1045.0, 271.0, 4.736),
        ("concurrent", 380.0, 145.0, 3.013),
        ("bram_optimal", 190.0, 107.0, 4.15),
    ];
    let mut rec = ExperimentRecord::from_table("table8", &t);
    rec.comparisons
        .push(Comparison::gated("configs", rows.len() as f64, 4.0, 1.0, 1.0));
    for ((_, cycles, interval, _, power, _), (key, pc, pi, pw)) in rows.iter().zip(paper) {
        let (c, i) = (*cycles as f64, *interval as f64);
        match key {
            "ltc" => {
                rec.comparisons
                    .push(Comparison::gated("ltc_cycles", c, pc, 0.5, 2.0));
                rec.comparisons
                    .push(Comparison::gated("ltc_interval", i, pi, 0.5, 1.5));
            }
            "gru_baseline" => {
                rec.comparisons
                    .push(Comparison::gated("gru_baseline_cycles", c, pc, 0.5, 2.0));
                rec.comparisons
                    .push(Comparison::informational("gru_baseline_interval", i, pi));
            }
            _ => {
                rec.comparisons
                    .push(Comparison::informational(format!("{key}_cycles"), c, pc));
                rec.comparisons
                    .push(Comparison::informational(format!("{key}_interval"), i, pi));
            }
        }
        rec.comparisons
            .push(Comparison::informational(format!("{key}_power_w"), *power, pw));
    }
    let (s1, s2, s3) = table8_speedups();
    rec.comparisons
        .push(Comparison::informational("speedup_ltc_to_gru", s1, 44.3));
    rec.comparisons
        .push(Comparison::informational("speedup_gru_to_dataflow", s2, 1.87));
    rec.comparisons
        .push(Comparison::informational("speedup_dataflow_to_banking", s3, 1.36));
    rec.notes.push(
        "dataflow rows beat the paper's silicon; ratios tracked informationally".to_string(),
    );
    rec
}

/// End-to-end AID demo metric for EXPERIMENTS.md: final loss after a
/// PJRT training run.
pub fn aid_train_demo(rt: &Runtime, steps: usize, seed: u64) -> Result<crate::mr::train::TrainReport> {
    use crate::mr::train::PjrtTrainer;
    let mut rng = Prng::new(seed);
    let tr = Aid::default().generate(200, 5.0, &mut rng);
    let (y, u) = tr.padded_f32(3, 1);
    let scale: f32 = y.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let y: Vec<f32> = y.iter().map(|v| v / scale).collect();
    let mut trainer = PjrtTrainer::new(rt, seed)?;
    trainer.train(
        &y,
        &u,
        TrainOpts {
            steps,
            seed,
            ..Default::default()
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_solver_dominates() {
        let t = table1();
        // Row 1 is the solver; its share column must exceed 60%.
        let share: f64 = t.rows[1][2].parse().unwrap();
        assert!(share > 60.0, "solver share {share}");
    }

    #[test]
    fn table2_sigmoid_and_sums_lead() {
        let t = table2();
        let get = |i: usize| -> f64 { t.rows[i][2].parse().unwrap() };
        let sigmoid = get(0);
        let sums = get(3);
        let weight = get(1);
        let reversal = get(2);
        assert!(sigmoid > weight && sigmoid > reversal);
        assert!(sigmoid + sums > 50.0, "sigmoid+sums = {}", sigmoid + sums);
    }

    #[test]
    fn table7_best_config_is_mixed_mapping() {
        let t = table7();
        // The minimum-cycle config should not be one of the all-LUT rows
        // (paper: s1D_s2L_s3L_s4D wins).
        let best = t
            .rows
            .iter()
            .min_by_key(|r| r[1].parse::<u64>().unwrap())
            .unwrap();
        assert!(best[0].starts_with("s1D"), "best={}", best[0]);
    }

    #[test]
    fn table8_speedup_shape() {
        let (s1, s2, s3) = table8_speedups();
        // Paper: 44.3x (LTC→GRU), 1.87x (→DATAFLOW), 1.36x (→banking).
        assert!(s1 > 3.0, "LTC→GRU {s1}");
        assert!(s2 > 1.2, "GRU→DATAFLOW {s2}");
        assert!(s3 > 1.05, "DATAFLOW→banking {s3}");
    }

    #[test]
    fn fig8_chart_renders() {
        let s = fig8();
        assert!(s.contains("Fig 8a") && s.contains("Fig 8b"));
        assert!(s.contains("LTC"));
    }

    #[test]
    fn table4_generates_three_rows() {
        let t = table4().unwrap();
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn table5_has_twelve_rows() {
        let t = table5().unwrap();
        assert_eq!(t.rows.len(), 12); // 4 workloads × 3 platforms
    }

    #[test]
    fn table3_roster_shape() {
        let t = table3();
        assert_eq!(t.rows.len(), 7);
        assert_eq!(t.headers[0], "System");
        // Every row's library size must be positive.
        for r in 0..t.rows.len() {
            assert!(cell(&t, r, 3) > 0.0);
        }
    }

    #[test]
    fn deterministic_records_pass_their_gates() {
        for rec in [table3_record(), table7_record(), table8_record(), fig8_record()] {
            assert!(rec.gated_ok(), "{}: gated comparison out of band", rec.id);
            assert!(!rec.comparisons.is_empty(), "{}: no comparisons", rec.id);
        }
        let cyc = cycles_record().unwrap();
        assert!(cyc.gated_ok(), "cycles: gated comparison out of band");
    }
}

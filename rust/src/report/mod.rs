//! Report rendering: paper-style tables, markdown emitters and ASCII
//! charts for the bench harness and EXPERIMENTS.md.

pub mod experiments;
pub mod runner;

use std::fmt::Write as _;

/// A generic experiment table with paper-vs-measured annotation support.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Plain-text rendering (bench stdout).
    pub fn to_text(&self) -> String {
        crate::util::bench::render_table(
            &self.title,
            &self.headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
            &self.rows,
        )
    }

    /// GitHub-markdown rendering (EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }
}

/// Format a float with `d` decimals.
pub fn fmt(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

/// Format in scientific notation.
pub fn sci(v: f64) -> String {
    format!("{v:.3e}")
}

/// A simple horizontal ASCII bar chart (Fig. 8 substitute): one row per
/// label, bar scaled to the max value; `log` plots log10 magnitudes.
pub fn bar_chart(title: &str, items: &[(String, f64)], width: usize, log: bool) -> String {
    let mut out = format!("\n== {title} ==\n");
    let tf = |v: f64| if log { v.max(1e-12).log10() } else { v };
    let vals: Vec<f64> = items.iter().map(|(_, v)| tf(*v)).collect();
    let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min).min(0.0);
    let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for ((label, raw), v) in items.iter().zip(&vals) {
        let filled = (((v - lo) / span) * width as f64).round() as usize;
        let _ = writeln!(
            out,
            "{label:<label_w$} | {} {}",
            "#".repeat(filled.min(width)),
            if log {
                format!("{raw:.3e}")
            } else {
                format!("{raw:.3}")
            }
        );
    }
    out
}

/// Ratio annotation helper: "ours 190 (paper 107, 1.78×)".
pub fn vs_paper(ours: f64, paper: f64, decimals: usize) -> String {
    if paper == 0.0 {
        return fmt(ours, decimals);
    }
    format!(
        "{} (paper {}, {:.2}x)",
        fmt(ours, decimals),
        fmt(paper, decimals),
        ours / paper
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_text_and_markdown() {
        let mut t = Table::new("Table X", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let text = t.to_text();
        assert!(text.contains("Table X"));
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn bar_chart_scales() {
        let c = bar_chart(
            "P",
            &[("x".into(), 1.0), ("y".into(), 2.0)],
            10,
            false,
        );
        let x_bars = c.lines().find(|l| l.starts_with('x')).unwrap().matches('#').count();
        let y_bars = c.lines().find(|l| l.starts_with('y')).unwrap().matches('#').count();
        assert!(y_bars > x_bars);
    }

    #[test]
    fn log_chart_compresses() {
        let c = bar_chart(
            "E",
            &[("a".into(), 1e-6), ("b".into(), 1e-2)],
            20,
            true,
        );
        assert!(c.contains("e-6") || c.contains("e-06"));
    }

    #[test]
    fn vs_paper_format() {
        let s = vs_paper(190.0, 107.0, 0);
        assert!(s.contains("190") && s.contains("107") && s.contains("1.78"));
    }
}

//! ODE solvers: fixed-step Euler/RK4 and adaptive RK45 (Dormand–Prince).
//!
//! RK4 mirrors the L2 `rk4_rollout` (the ODE-loss path); RK45 substitutes
//! for Matlab's `ODE45`, which the paper uses to generate ground-truth
//! trajectories for the simulation case studies (§6.1).

/// Right-hand side of an ODE: dy/dt = f(t, y, u).
pub trait Rhs {
    /// State dimension.
    fn dim(&self) -> usize;
    /// Evaluate into `out` (len = dim).
    fn eval(&self, t: f64, y: &[f64], u: &[f64], out: &mut [f64]);
}

/// Closure adapter for ad-hoc systems.
pub struct FnRhs<F: Fn(f64, &[f64], &[f64], &mut [f64])> {
    pub dim: usize,
    pub f: F,
}

impl<F: Fn(f64, &[f64], &[f64], &mut [f64])> Rhs for FnRhs<F> {
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, t: f64, y: &[f64], u: &[f64], out: &mut [f64]) {
        (self.f)(t, y, u, out)
    }
}

/// One forward-Euler step.
pub fn euler_step(rhs: &dyn Rhs, t: f64, y: &mut [f64], u: &[f64], dt: f64) {
    let n = rhs.dim();
    let mut k = vec![0.0; n];
    rhs.eval(t, y, u, &mut k);
    for i in 0..n {
        y[i] += dt * k[i];
    }
}

/// One classic RK4 step (matches `model.rk4_rollout` with ZOH input).
pub fn rk4_step(rhs: &dyn Rhs, t: f64, y: &mut [f64], u: &[f64], dt: f64) {
    let n = rhs.dim();
    let mut k1 = vec![0.0; n];
    let mut k2 = vec![0.0; n];
    let mut k3 = vec![0.0; n];
    let mut k4 = vec![0.0; n];
    let mut tmp = vec![0.0; n];

    rhs.eval(t, y, u, &mut k1);
    for i in 0..n {
        tmp[i] = y[i] + 0.5 * dt * k1[i];
    }
    rhs.eval(t + 0.5 * dt, &tmp, u, &mut k2);
    for i in 0..n {
        tmp[i] = y[i] + 0.5 * dt * k2[i];
    }
    rhs.eval(t + 0.5 * dt, &tmp, u, &mut k3);
    for i in 0..n {
        tmp[i] = y[i] + dt * k3[i];
    }
    rhs.eval(t + dt, &tmp, u, &mut k4);
    for i in 0..n {
        y[i] += dt / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
    }
}

/// Integrate with fixed-step RK4, sampling at every step.
///
/// `us` is (steps, udim) row-major (zero-order hold per step, may be empty
/// for autonomous systems). Returns (steps+1, n) including y0.
pub fn rk4_trajectory(
    rhs: &dyn Rhs,
    y0: &[f64],
    us: &[f64],
    udim: usize,
    dt: f64,
    steps: usize,
) -> Vec<f64> {
    let n = rhs.dim();
    let mut y = y0.to_vec();
    let mut out = Vec::with_capacity((steps + 1) * n);
    out.extend_from_slice(&y);
    let zero_u = vec![0.0; udim.max(1)];
    for s in 0..steps {
        let u = if udim > 0 && !us.is_empty() {
            &us[s * udim..(s + 1) * udim]
        } else {
            &zero_u[..]
        };
        rk4_step(rhs, s as f64 * dt, &mut y, u, dt);
        out.extend_from_slice(&y);
    }
    out
}

/// Adaptive RK45 (Dormand–Prince 5(4)) options.
#[derive(Clone, Copy, Debug)]
pub struct Rk45Opts {
    pub rtol: f64,
    pub atol: f64,
    pub h_init: f64,
    pub h_min: f64,
    pub h_max: f64,
    pub max_steps: usize,
}

impl Default for Rk45Opts {
    fn default() -> Self {
        Rk45Opts {
            rtol: 1e-6,
            atol: 1e-9,
            h_init: 1e-3,
            h_min: 1e-10,
            h_max: 1.0,
            max_steps: 2_000_000,
        }
    }
}

// Dormand–Prince coefficients.
const A: [[f64; 6]; 6] = [
    [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
    [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
    [
        19372.0 / 6561.0,
        -25360.0 / 2187.0,
        64448.0 / 6561.0,
        -212.0 / 729.0,
        0.0,
        0.0,
    ],
    [
        9017.0 / 3168.0,
        -355.0 / 33.0,
        46732.0 / 5247.0,
        49.0 / 176.0,
        -5103.0 / 18656.0,
        0.0,
    ],
    [
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
    ],
];
const B5: [f64; 7] = [
    35.0 / 384.0,
    0.0,
    500.0 / 1113.0,
    125.0 / 192.0,
    -2187.0 / 6784.0,
    11.0 / 84.0,
    0.0,
];
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];
const C: [f64; 6] = [1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];

/// Integrate from `t0` to `t1` sampling the solution at `samples` evenly
/// spaced times (ODE45 substitute). Input is held at zero (the simulation
/// case studies are autonomous or have U folded into the RHS).
///
/// Returns (samples, n) row-major, or an error description on failure.
pub fn rk45_sample(
    rhs: &dyn Rhs,
    y0: &[f64],
    t0: f64,
    t1: f64,
    samples: usize,
    opts: Rk45Opts,
) -> Result<Vec<f64>, String> {
    assert!(samples >= 2 && t1 > t0);
    let n = rhs.dim();
    let zero_u: Vec<f64> = vec![];
    let mut y = y0.to_vec();
    let mut t = t0;
    let mut h = opts.h_init;
    let mut out = Vec::with_capacity(samples * n);
    out.extend_from_slice(&y);
    let sample_dt = (t1 - t0) / (samples - 1) as f64;
    let mut next_sample = 1usize;

    let mut k = vec![vec![0.0; n]; 7];
    let mut tmp = vec![0.0; n];
    rhs.eval(t, &y, &zero_u, &mut k[0]);

    for _step in 0..opts.max_steps {
        if next_sample >= samples {
            return Ok(out);
        }
        // Don't overshoot the next sample point (dense output by step
        // splitting — simple and adequate at our tolerances).
        let t_target = t0 + next_sample as f64 * sample_dt;
        let h_eff = h.min(t_target - t).min(opts.h_max).max(opts.h_min);

        // Stage evaluations.
        for s in 0..6 {
            for i in 0..n {
                let mut acc = 0.0;
                for (j, kj) in k.iter().enumerate().take(s + 1) {
                    acc += A[s][j] * kj[i];
                }
                tmp[i] = y[i] + h_eff * acc;
            }
            rhs.eval(t + C[s] * h_eff, &tmp, &zero_u, &mut k[s + 1]);
        }

        // 5th and 4th order solutions + error estimate.
        let mut err: f64 = 0.0;
        let mut y5 = vec![0.0; n];
        for i in 0..n {
            let mut acc5 = 0.0;
            let mut acc4 = 0.0;
            for j in 0..7 {
                acc5 += B5[j] * k[j][i];
                acc4 += B4[j] * k[j][i];
            }
            y5[i] = y[i] + h_eff * acc5;
            let y4 = y[i] + h_eff * acc4;
            let sc = opts.atol + opts.rtol * y5[i].abs().max(y[i].abs());
            err += ((y5[i] - y4) / sc).powi(2);
        }
        err = (err / n as f64).sqrt();

        if err <= 1.0 || h_eff <= opts.h_min * 1.0001 {
            // Accept.
            t += h_eff;
            y = y5;
            k[0] = k[6].clone(); // FSAL
            if (t - t_target).abs() < 1e-12 {
                out.extend_from_slice(&y);
                next_sample += 1;
            }
            if !y.iter().all(|v| v.is_finite()) {
                return Err(format!("diverged at t={t}"));
            }
        } else {
            rhs.eval(t, &y, &zero_u, &mut k[0]);
        }
        // PI-style step adaptation.
        let fac = (0.9 * err.powf(-0.2)).clamp(0.2, 5.0);
        h = (h_eff * fac).clamp(opts.h_min, opts.h_max);
    }
    Err("max_steps exceeded".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp_decay() -> FnRhs<impl Fn(f64, &[f64], &[f64], &mut [f64])> {
        FnRhs {
            dim: 1,
            f: |_t, y: &[f64], _u: &[f64], out: &mut [f64]| out[0] = -y[0],
        }
    }

    #[test]
    fn rk4_exp_decay_accuracy() {
        let rhs = exp_decay();
        let mut y = vec![1.0];
        let dt = 0.01;
        for s in 0..100 {
            rk4_step(&rhs, s as f64 * dt, &mut y, &[], dt);
        }
        assert!((y[0] - (-1.0f64).exp()).abs() < 1e-8, "y={}", y[0]);
    }

    #[test]
    fn euler_less_accurate_than_rk4() {
        let rhs = exp_decay();
        let dt = 0.05;
        let mut ye = vec![1.0];
        let mut yr = vec![1.0];
        for s in 0..20 {
            euler_step(&rhs, s as f64 * dt, &mut ye, &[], dt);
            rk4_step(&rhs, s as f64 * dt, &mut yr, &[], dt);
        }
        let exact = (-1.0f64).exp();
        assert!((yr[0] - exact).abs() < (ye[0] - exact).abs());
    }

    #[test]
    fn rk45_matches_exact_harmonic_oscillator() {
        // y'' = -y → (y, v): energy-conserving circle.
        let rhs = FnRhs {
            dim: 2,
            f: |_t, y: &[f64], _u: &[f64], out: &mut [f64]| {
                out[0] = y[1];
                out[1] = -y[0];
            },
        };
        let sol = rk45_sample(&rhs, &[1.0, 0.0], 0.0, 10.0, 101, Rk45Opts::default()).unwrap();
        for (i, chunk) in sol.chunks(2).enumerate() {
            let t = i as f64 * 0.1;
            assert!((chunk[0] - t.cos()).abs() < 1e-4, "t={t} y={}", chunk[0]);
        }
    }

    #[test]
    fn rk45_reports_divergence() {
        // y' = y² from y0=1 blows up at t=1.
        let rhs = FnRhs {
            dim: 1,
            f: |_t, y: &[f64], _u: &[f64], out: &mut [f64]| out[0] = y[0] * y[0],
        };
        let r = rk45_sample(&rhs, &[1.0], 0.0, 2.0, 21, Rk45Opts::default());
        assert!(r.is_err());
    }

    #[test]
    fn trajectory_includes_initial_state() {
        let rhs = exp_decay();
        let traj = rk4_trajectory(&rhs, &[2.0], &[], 0, 0.1, 10);
        assert_eq!(traj.len(), 11);
        assert_eq!(traj[0], 2.0);
        assert!(traj[10] < traj[0]);
    }

    #[test]
    fn zoh_input_is_applied() {
        // y' = u: with u=1 for 5 steps then u=0, y ends at 5·dt.
        let rhs = FnRhs {
            dim: 1,
            f: |_t, _y: &[f64], u: &[f64], out: &mut [f64]| out[0] = u[0],
        };
        let us: Vec<f64> = (0..10).map(|i| if i < 5 { 1.0 } else { 0.0 }).collect();
        let traj = rk4_trajectory(&rhs, &[0.0], &us, 1, 0.1, 10);
        assert!((traj[10] - 0.5).abs() < 1e-12);
    }
}

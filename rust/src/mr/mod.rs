//! Model-recovery algorithm suite (native Rust).
//!
//! Everything the paper's MR pipeline needs on the FPGA/edge side:
//! the GRU and LTC cells (f32 and fixed-point), ODE solvers, the sparse
//! polynomial candidate library, ridge/STLSQ (SINDy) regression, dense
//! heads and the Adam trainer. The native implementations mirror the L2
//! jax definitions and are pinned against the lowered HLO by
//! `rust/tests/integration.rs`.

pub mod backprop;
pub mod dense;
pub mod gru;
pub mod library;
pub mod linalg;
pub mod loss;
pub mod recover;
pub mod ltc;
pub mod ode;
pub mod ridge;
pub mod sindy;
pub mod train;

//! Loss functions for model recovery.
//!
//! The paper's training objective (§4): ODE reconstruction MSE between the
//! observed trace Y and the RK4-integrated estimate Y_est, plus an L1
//! sparsity term on the coefficient estimates — mirrors `merinda_loss` in
//! the L2 model.

/// Mean squared error over two equal-length f32 slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// L1 (mean absolute) sparsity penalty.
pub fn l1_mean(theta: &[f32]) -> f64 {
    if theta.is_empty() {
        return 0.0;
    }
    theta.iter().map(|&v| (v as f64).abs()).sum::<f64>() / theta.len() as f64
}

/// The combined MERINDA objective.
pub fn ode_loss(y: &[f32], y_est: &[f32], theta: &[f32], lambda: f64) -> f64 {
    mse(y, y_est) + lambda * l1_mean(theta)
}

/// Parameter-recovery MSE (Table 6's metric): error between estimated and
/// ground-truth coefficient matrices, over the nonzero support of truth ∪
/// estimate so structural misses are penalized.
pub fn coefficient_mse(est: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(est.len(), truth.len());
    let mut se = 0.0;
    let mut n = 0usize;
    for (e, t) in est.iter().zip(truth) {
        if *e != 0.0 || *t != 0.0 {
            se += (e - t) * (e - t);
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        se / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, -1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn l1_mean_value() {
        assert!((l1_mean(&[1.0, -3.0]) - 2.0).abs() < 1e-12);
        assert_eq!(l1_mean(&[]), 0.0);
    }

    #[test]
    fn lambda_weights_sparsity() {
        let y = [1.0f32; 4];
        let t = [2.0f32; 8];
        let l0 = ode_loss(&y, &y, &t, 0.0);
        let l1 = ode_loss(&y, &y, &t, 0.5);
        assert_eq!(l0, 0.0);
        assert!((l1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn coefficient_mse_over_support() {
        // truth has 2 active terms; est misses one and adds a spurious one.
        let truth = [1.0, 0.0, -0.5, 0.0];
        let est = [0.9, 0.2, 0.0, 0.0];
        let m = coefficient_mse(&est, &truth);
        // support = {0, 1, 2}: errors 0.1², 0.2², 0.5².
        assert!((m - (0.01 + 0.04 + 0.25) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn coefficient_mse_all_zero() {
        assert_eq!(coefficient_mse(&[0.0; 3], &[0.0; 3]), 0.0);
    }
}

//! MERINDA training driver.
//!
//! Training runs entirely from Rust: the fused Adam train step
//! (`merinda_train_step`) was AOT-lowered from L2 and executes via PJRT;
//! this module owns parameter/optimizer state, batches windows out of
//! recorded traces, and loops. Python is never invoked.

use std::sync::Arc;

use crate::runtime::{Executable, ModelDims, Runtime};
use crate::util::{Error, Prng, Result};

/// The seven MERINDA parameter arrays, in manifest order.
pub const PARAM_NAMES: [&str; 7] = [
    "gru_w", "gru_u", "gru_b", "dense_w1", "dense_b1", "dense_w2", "dense_b2",
];

/// Parameter shapes for the canonical dims.
pub fn param_shapes(d: &ModelDims) -> Vec<(String, Vec<usize>)> {
    let io = d.xdim + d.udim;
    vec![
        ("gru_w".into(), vec![io, 3 * d.hid]),
        ("gru_u".into(), vec![d.hid, 3 * d.hid]),
        ("gru_b".into(), vec![3 * d.hid]),
        ("dense_w1".into(), vec![d.hid, d.dense]),
        ("dense_b1".into(), vec![d.dense]),
        ("dense_w2".into(), vec![d.dense, d.xdim * d.plib]),
        ("dense_b2".into(), vec![d.xdim * d.plib]),
    ]
}

/// MERINDA parameters + Adam state.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub dims: ModelDims,
    /// 7 parameter arrays.
    pub params: Vec<Vec<f32>>,
    /// Adam first moments.
    pub m: Vec<Vec<f32>>,
    /// Adam second moments.
    pub v: Vec<Vec<f32>>,
    /// Step counter (pre-increment, as the lowered step expects).
    pub step: f32,
}

impl TrainState {
    /// Glorot-ish init matching `model.init_params`.
    pub fn init(dims: &ModelDims, rng: &mut Prng) -> TrainState {
        let mut params = Vec::new();
        for (name, shape) in param_shapes(dims) {
            let n: usize = shape.iter().product();
            if name.contains('b') {
                params.push(vec![0.0f32; n]);
            } else {
                let std = 1.0 / (shape[0] as f64).sqrt();
                params.push(rng.normal_vec_f32(n, std));
            }
        }
        let m = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let v = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        TrainState {
            dims: dims.clone(),
            params,
            m,
            v,
            step: 0.0,
        }
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
}

/// One training batch of windows: y (B, K, X), u (B, K, U), flattened.
#[derive(Clone, Debug)]
pub struct Batch {
    pub y: Vec<f32>,
    pub u: Vec<f32>,
}

/// Cut random windows out of a trace to form a batch.
///
/// `trace_y`: (N, xdim) row-major; `trace_u`: (N, udim). Windows start at
/// uniform offsets; each batch row is a contiguous (seq, dim) slice.
pub fn sample_batch(
    dims: &ModelDims,
    trace_y: &[f32],
    trace_u: &[f32],
    rng: &mut Prng,
) -> Result<Batch> {
    let n = trace_y.len() / dims.xdim;
    if n < dims.seq {
        return Err(Error::config(format!(
            "trace too short: {n} < seq {}",
            dims.seq
        )));
    }
    let mut y = Vec::with_capacity(dims.batch * dims.seq * dims.xdim);
    let mut u = Vec::with_capacity(dims.batch * dims.seq * dims.udim);
    for _ in 0..dims.batch {
        let s0 = rng.below(n - dims.seq + 1);
        y.extend_from_slice(&trace_y[s0 * dims.xdim..(s0 + dims.seq) * dims.xdim]);
        u.extend_from_slice(&trace_u[s0 * dims.udim..(s0 + dims.seq) * dims.udim]);
    }
    Ok(Batch { y, u })
}

/// Hyperparameters for a training run.
#[derive(Clone, Copy, Debug)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    pub dt: f32,
    pub lambda: f32,
    pub seed: u64,
    /// Log the loss every `log_every` steps into the returned curve.
    pub log_every: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 200,
            lr: 3e-3,
            dt: 0.1,
            lambda: 1e-3,
            seed: 42,
            log_every: 10,
        }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub steps: usize,
    pub wall_s: f64,
}

/// PJRT-backed trainer: executes the fused train step artifact.
pub struct PjrtTrainer {
    pub state: TrainState,
    train_exe: Arc<Executable>,
    forward_exe: Arc<Executable>,
}

impl PjrtTrainer {
    pub fn new(rt: &Runtime, seed: u64) -> Result<PjrtTrainer> {
        let dims = rt.manifest.dims.clone();
        let mut rng = Prng::new(seed);
        Ok(PjrtTrainer {
            state: TrainState::init(&dims, &mut rng),
            train_exe: rt.load("merinda_train_step")?,
            forward_exe: rt.load("merinda_forward")?,
        })
    }

    /// One fused Adam step; returns the loss.
    pub fn train_step(&mut self, batch: &Batch, dt: f32, lr: f32, lambda: f32) -> Result<f32> {
        let s = &self.state;
        let step_in = [s.step];
        let dt_in = [dt];
        let lr_in = [lr];
        let lam_in = [lambda];
        let mut args: Vec<&[f32]> = Vec::with_capacity(27);
        for p in &s.params {
            args.push(p);
        }
        for m in &s.m {
            args.push(m);
        }
        for v in &s.v {
            args.push(v);
        }
        args.push(&step_in);
        args.push(&batch.y);
        args.push(&batch.u);
        args.push(&dt_in);
        args.push(&lr_in);
        args.push(&lam_in);

        let out = self.train_exe.run_f32(&args)?;
        debug_assert_eq!(out.len(), 23);
        let st = &mut self.state;
        for i in 0..7 {
            st.params[i] = out[i].clone();
            st.m[i] = out[7 + i].clone();
            st.v[i] = out[14 + i].clone();
        }
        st.step = out[21][0];
        let loss = out[22][0];
        if !loss.is_finite() {
            return Err(Error::numeric(format!("loss diverged: {loss}")));
        }
        Ok(loss)
    }

    /// Full training loop over a trace.
    pub fn train(
        &mut self,
        trace_y: &[f32],
        trace_u: &[f32],
        opts: TrainOpts,
    ) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let dims = self.state.dims.clone();
        let mut rng = Prng::new(opts.seed);
        let mut losses = Vec::new();
        let mut last = f32::NAN;
        for s in 0..opts.steps {
            let batch = sample_batch(&dims, trace_y, trace_u, &mut rng)?;
            last = self.train_step(&batch, opts.dt, opts.lr, opts.lambda)?;
            if s % opts.log_every.max(1) == 0 || s + 1 == opts.steps {
                losses.push((s, last));
            }
        }
        Ok(TrainReport {
            losses,
            final_loss: last,
            steps: opts.steps,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Inference: average the per-window Θ estimates over a batch →
    /// (xdim, plib) coefficient matrix.
    pub fn estimate_theta(&self, batch: &Batch) -> Result<Vec<f64>> {
        let s = &self.state;
        let mut args: Vec<&[f32]> = s.params.iter().map(|p| p.as_slice()).collect();
        args.push(&batch.y);
        args.push(&batch.u);
        let out = self.forward_exe.run_f32(&args)?;
        let d = &s.dims;
        let per = d.xdim * d.plib;
        let mut theta = vec![0.0f64; per];
        for b in 0..d.batch {
            for i in 0..per {
                theta[i] += out[0][b * per + i] as f64;
            }
        }
        for t in theta.iter_mut() {
            *t /= d.batch as f64;
        }
        Ok(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            xdim: 3,
            udim: 1,
            plib: 15,
            hid: 32,
            dense: 48,
            batch: 8,
            seq: 64,
            ltc_unfold: 6,
        }
    }

    #[test]
    fn init_shapes_consistent() {
        let d = dims();
        let st = TrainState::init(&d, &mut Prng::new(1));
        assert_eq!(st.params.len(), 7);
        assert_eq!(st.params[0].len(), 4 * 96);
        assert_eq!(st.params[6].len(), 45);
        assert_eq!(st.m.len(), 7);
        assert!(st.param_count() > 5000);
    }

    #[test]
    fn biases_start_zero() {
        let st = TrainState::init(&dims(), &mut Prng::new(2));
        assert!(st.params[2].iter().all(|&v| v == 0.0)); // gru_b
        assert!(st.params[4].iter().all(|&v| v == 0.0)); // dense_b1
    }

    #[test]
    fn sample_batch_shapes() {
        let d = dims();
        let n = 500;
        let trace_y = vec![0.5f32; n * d.xdim];
        let trace_u = vec![0.0f32; n * d.udim];
        let b = sample_batch(&d, &trace_y, &trace_u, &mut Prng::new(3)).unwrap();
        assert_eq!(b.y.len(), d.batch * d.seq * d.xdim);
        assert_eq!(b.u.len(), d.batch * d.seq * d.udim);
    }

    #[test]
    fn sample_batch_rejects_short_trace() {
        let d = dims();
        let trace_y = vec![0.0f32; 10 * d.xdim];
        let trace_u = vec![0.0f32; 10 * d.udim];
        assert!(sample_batch(&d, &trace_y, &trace_u, &mut Prng::new(4)).is_err());
    }

    #[test]
    fn windows_are_contiguous_slices() {
        let d = ModelDims {
            batch: 2,
            seq: 3,
            xdim: 1,
            udim: 1,
            ..dims()
        };
        // trace_y[i] = i so windows must be consecutive runs.
        let trace_y: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let trace_u = vec![0.0f32; 50];
        let b = sample_batch(&d, &trace_y, &trace_u, &mut Prng::new(5)).unwrap();
        for w in 0..2 {
            let win = &b.y[w * 3..(w + 1) * 3];
            assert_eq!(win[1] - win[0], 1.0);
            assert_eq!(win[2] - win[1], 1.0);
        }
    }
}

//! End-to-end recovery methods for the Table 6 comparison.
//!
//! * **SINDY** — STLSQ on finite-difference derivatives (the classic
//!   baseline, [12, 18]).
//! * **PINN+SR** — physics-informed recovery with sparse regression [20]:
//!   here, smoothed derivatives + a single thresholded regression pass
//!   (no shooting refinement), which is what gives it the larger errors
//!   the paper reports.
//! * **EMILY** — implicit-dynamics recovery [19]: STLSQ followed by
//!   shooting refinement (coordinate descent on the trajectory
//!   reconstruction loss), the strongest classical baseline.
//! * **MERINDA** — the paper's method: GRU+dense neural flow (trained via
//!   the AOT PJRT artifacts) proposes Θ; its support drives a masked ridge
//!   polish (the paper's "exploit inherent sparsity to prune the dense
//!   layer" + ridge step, §3.1/§4).

use crate::mr::library::PolyLibrary;
use crate::mr::ridge::{ridge_cg, ridge_masked, RidgeCgOpts};
use crate::mr::sindy::{self, finite_difference, reconstruction_mse, SindyOpts, SparseModel};
use crate::runtime::Runtime;
use crate::systems::Trace;
use crate::util::{Prng, Result};

use super::train::{PjrtTrainer, TrainOpts};

/// A recovery outcome: the sparse model + its reconstruction MSE on the
/// generating trace.
#[derive(Clone, Debug)]
pub struct Recovery {
    pub method: &'static str,
    pub model: SparseModel,
    pub recon_mse: f64,
    pub wall_s: f64,
}

fn eval(method: &'static str, model: SparseModel, tr: &Trace, t0: std::time::Instant) -> Recovery {
    let mse = reconstruction_mse(&model, &tr.xs, &tr.us, tr.samples(), tr.dt);
    Recovery {
        method,
        model,
        recon_mse: mse,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

/// Classic SINDy/STLSQ.
pub fn recover_sindy(tr: &Trace) -> Result<Recovery> {
    let t0 = std::time::Instant::now();
    let lib = PolyLibrary::new(tr.xdim, tr.udim, 2);
    let model = sindy::sindy(
        &tr.xs,
        &tr.us,
        tr.samples(),
        lib,
        tr.dt,
        SindyOpts::default(),
    )?;
    Ok(eval("SINDY", model, tr, t0))
}

/// Moving-average smoother (window must be odd).
fn smooth(xs: &[f64], samples: usize, dim: usize, window: usize) -> Vec<f64> {
    let half = window / 2;
    let mut out = vec![0.0; xs.len()];
    for d in 0..dim {
        for s in 0..samples {
            let lo = s.saturating_sub(half);
            let hi = (s + half + 1).min(samples);
            let sum: f64 = (lo..hi).map(|i| xs[i * dim + d]).sum();
            out[s * dim + d] = sum / (hi - lo) as f64;
        }
    }
    out
}

/// PINN+SR stand-in: smoothing + one-shot thresholded regression.
pub fn recover_pinn_sr(tr: &Trace) -> Result<Recovery> {
    let t0 = std::time::Instant::now();
    let lib = PolyLibrary::new(tr.xdim, tr.udim, 2);
    let n = tr.samples();
    let xs = smooth(&tr.xs, n, tr.xdim, 5);
    let model = sindy::sindy(
        &xs,
        &tr.us,
        n,
        lib,
        tr.dt,
        SindyOpts {
            threshold: 0.12, // single aggressive pass, no re-fit loop
            lambda: 1e-3,
            max_iters: 1,
        },
    )?;
    Ok(eval("PINN+SR", model, tr, t0))
}

/// Shooting refinement: coordinate descent on the reconstruction loss over
/// the current nonzero support. Small, deterministic, derivative-free.
fn shooting_refine(model: &mut SparseModel, tr: &Trace, sweeps: usize) {
    let p = model.library.len();
    let n = tr.samples().min(400); // refine on a prefix for speed
    let mut best = reconstruction_mse(model, &tr.xs, &tr.us, n, tr.dt);
    for _ in 0..sweeps {
        let mut improved = false;
        for i in 0..model.xdim * p {
            if model.coeffs[i] == 0.0 {
                continue;
            }
            let orig = model.coeffs[i];
            let scale = orig.abs().max(1e-3);
            for delta in [0.05 * scale, -0.05 * scale, 0.01 * scale, -0.01 * scale] {
                model.coeffs[i] = orig + delta;
                let mse = reconstruction_mse(model, &tr.xs, &tr.us, n, tr.dt);
                if mse < best {
                    best = mse;
                    improved = true;
                    break;
                }
                model.coeffs[i] = orig;
            }
        }
        if !improved {
            break;
        }
    }
}

/// EMILY stand-in: STLSQ + shooting refinement.
pub fn recover_emily(tr: &Trace) -> Result<Recovery> {
    let t0 = std::time::Instant::now();
    let lib = PolyLibrary::new(tr.xdim, tr.udim, 2);
    let mut model = sindy::sindy(
        &tr.xs,
        &tr.us,
        tr.samples(),
        lib,
        tr.dt,
        SindyOpts::default(),
    )?;
    shooting_refine(&mut model, tr, 4);
    Ok(eval("EMILY", model, tr, t0))
}

/// Options for the per-window iterative coefficient polish
/// ([`refine_window_theta`]).
#[derive(Clone, Copy, Debug)]
pub struct RefineOpts {
    /// Ridge regularizer on the window least squares.
    pub lambda: f64,
    /// Polynomial library order over `[x | u]` (2 matches the canonical
    /// serving library, so NN-proposed Θ seeds align term-for-term).
    pub order: u32,
    /// Conjugate-gradient stopping rule.
    pub cg: RidgeCgOpts,
}

impl Default for RefineOpts {
    fn default() -> Self {
        RefineOpts {
            lambda: 1e-3,
            order: 2,
            cg: RidgeCgOpts::default(),
        }
    }
}

/// Result of refining one window's coefficient estimate.
#[derive(Clone, Debug)]
pub struct RefineOutcome {
    /// Polished (xdim × plib) coefficients, row-major like the serving Θ.
    pub theta: Vec<f32>,
    /// Total CG iterations across the `xdim` state equations — the
    /// quantity warm-starting reduces.
    pub iters: u64,
    /// All equations reached the residual threshold.
    pub converged: bool,
    /// Worst per-equation final residual 2-norm.
    pub residual: f64,
}

/// Iteratively polish a window's Θ estimate against that window's own
/// data: least-squares fit of finite-difference derivatives onto the
/// polynomial library, solved per state equation by warm-startable
/// conjugate gradient ([`ridge_cg`]).
///
/// `y` is the (samples × xdim) window, `u` the (samples × udim) inputs
/// (both row-major, f32 as on the serving path), and `theta0` the
/// (xdim × plib) seed — the NN proposal for a cold start, or the
/// previous overlapping window's refined Θ for a warm start. Both seeds
/// converge to the same minimizer (the problem is strictly convex for
/// `lambda > 0`); only the iteration count differs, which is exactly
/// what `coordinator::stream`'s warm-start cache exploits and what
/// `merinda soak` reports as the cold-vs-warm ratio.
///
/// Derivatives use a unit sample spacing: the stream layer does not know
/// the generating `dt`, and a fixed spacing only rescales the recovered
/// coefficients uniformly — iteration counts and convergence are
/// unaffected.
pub fn refine_window_theta(
    y: &[f32],
    xdim: usize,
    u: &[f32],
    udim: usize,
    samples: usize,
    theta0: &[f32],
    opts: &RefineOpts,
) -> Result<RefineOutcome> {
    if samples < 3 {
        return Err(crate::util::Error::config(format!(
            "refinement needs >= 3 samples per window, got {samples}"
        )));
    }
    if y.len() != samples * xdim || u.len() != samples * udim {
        return Err(crate::util::Error::Shape {
            expected: format!("y {}x{xdim}, u {}x{udim}", samples, samples),
            got: format!("y len {}, u len {}", y.len(), u.len()),
        });
    }
    let lib = PolyLibrary::new(xdim, udim, opts.order);
    let p = lib.len();
    if theta0.len() != xdim * p {
        return Err(crate::util::Error::Shape {
            expected: format!("theta0 len {}", xdim * p),
            got: format!("{}", theta0.len()),
        });
    }
    let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let u64v: Vec<f64> = u.iter().map(|&v| v as f64).collect();
    let dx = finite_difference(&y64, samples, xdim, 1.0);
    let a = lib.design_matrix(&y64, &u64v, samples);

    let mut theta = vec![0.0f32; xdim * p];
    let mut iters = 0u64;
    let mut converged = true;
    let mut residual = 0.0f64;
    for d in 0..xdim {
        let b: Vec<f64> = (0..samples).map(|s| dx[s * xdim + d]).collect();
        let w0: Vec<f64> = theta0[d * p..(d + 1) * p]
            .iter()
            .map(|&v| v as f64)
            .collect();
        let sol = ridge_cg(&a, &b, samples, p, opts.lambda, &w0, &opts.cg);
        iters += sol.iters;
        converged &= sol.converged;
        residual = residual.max(sol.residual);
        for (dst, src) in theta[d * p..(d + 1) * p].iter_mut().zip(&sol.w) {
            *dst = *src as f32;
        }
    }
    Ok(RefineOutcome {
        theta,
        iters,
        converged,
        residual,
    })
}

/// Masked-ridge polish shared by the PJRT and native MERINDA paths:
/// STLSQ restricted to the proposed support — solve, threshold, re-fit
/// until the mask stabilizes (the paper's sparsity-pruned ridge step,
/// §3.1), on finite-difference derivatives of the *raw* trace.
fn masked_ridge_polish(
    tr: &Trace,
    lib: &PolyLibrary,
    support: &[bool],
    lambda: f64,
) -> Result<Vec<f64>> {
    let p = lib.len();
    let n = tr.samples();
    let dx = finite_difference(&tr.xs, n, tr.xdim, tr.dt);
    let theta_mat = lib.design_matrix(&tr.xs, &tr.us, n);
    let mut coeffs = vec![0.0f64; tr.xdim * p];
    for d in 0..tr.xdim {
        let y: Vec<f64> = (0..n).map(|s| dx[s * tr.xdim + d]).collect();
        let mut mask: Vec<bool> = support[d * p..(d + 1) * p].to_vec();
        let mut w = ridge_masked(&theta_mat, &y, n, p, lambda, &mask)?;
        for _ in 0..6 {
            let mut changed = false;
            for (i, m) in mask.iter_mut().enumerate() {
                if *m && w[i].abs() < 0.02 {
                    *m = false;
                    changed = true;
                }
            }
            w = ridge_masked(&theta_mat, &y, n, p, lambda, &mask)?;
            if !changed {
                break;
            }
        }
        coeffs[d * p..(d + 1) * p].copy_from_slice(&w);
    }
    Ok(coeffs)
}

/// MERINDA configuration.
#[derive(Clone, Copy, Debug)]
pub struct MerindaOpts {
    pub train: TrainOpts,
    /// Nonzero budget per state equation for the support selection.
    pub support_per_eq: usize,
    /// Ridge λ for the polish.
    pub lambda: f64,
}

impl Default for MerindaOpts {
    fn default() -> Self {
        MerindaOpts {
            train: TrainOpts::default(),
            support_per_eq: 8,
            lambda: 1e-6,
        }
    }
}

/// The MERINDA pipeline: neural-flow training (PJRT) → Θ estimate →
/// sparsity-driven support → masked ridge polish on the derivatives.
pub fn recover_merinda(rt: &Runtime, tr: &Trace, opts: MerindaOpts) -> Result<Recovery> {
    let t0 = std::time::Instant::now();
    let dims = rt.manifest.dims.clone();

    // Pad the trace to the canonical dims the artifacts use, and normalize
    // the padded trace into the GRU's sweet spot.
    let (y_pad, u_pad) = tr.padded_f32(dims.xdim, dims.udim);
    let scale: f32 = y_pad
        .iter()
        .fold(0.0f32, |m, v| m.max(v.abs()))
        .max(1e-6);
    let y_norm: Vec<f32> = y_pad.iter().map(|v| v / scale).collect();

    // Train the neural flow via the fused PJRT train step.
    let mut trainer = PjrtTrainer::new(rt, opts.train.seed)?;
    trainer.train(&y_norm, &u_pad, opts.train)?;

    // Estimate Θ on a batch of windows.
    let mut rng = Prng::new(opts.train.seed ^ 0x5eed);
    let batch = super::train::sample_batch(&dims, &y_norm, &u_pad, &mut rng)?;
    let theta_canon = trainer.estimate_theta(&batch)?;

    // Project the canonical (3, 15) estimate down to the system's own
    // library and use its largest-|coef| entries as the support.
    let lib = PolyLibrary::new(tr.xdim, tr.udim, 2);
    let canon_lib = PolyLibrary::new(dims.xdim, dims.udim, 2);
    let canon_names = canon_lib.names();
    let names = lib.names();
    let p = lib.len();
    let mut support = vec![false; tr.xdim * p];
    for d in 0..tr.xdim {
        let row = &theta_canon[d * dims.plib..(d + 1) * dims.plib];
        let mut scored: Vec<(usize, f64)> = names
            .iter()
            .enumerate()
            .filter_map(|(i, n)| {
                canon_names
                    .iter()
                    .position(|cn| cn == n)
                    .map(|ci| (i, row[ci].abs()))
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for &(i, _) in scored.iter().take(opts.support_per_eq) {
            support[d * p + i] = true;
        }
    }

    // Belt-and-braces: union the NN-proposed support with a plain STLSQ
    // pass so a mis-ranked term from an under-trained network cannot drop
    // a structurally necessary library entry (the final threshold-refit
    // loop below still prunes back to a sparse model).
    if let Ok(stlsq) = sindy::sindy(
        &tr.xs,
        &tr.us,
        tr.samples(),
        lib.clone(),
        tr.dt,
        SindyOpts::default(),
    ) {
        for (i, c) in stlsq.coeffs.iter().enumerate() {
            if *c != 0.0 {
                support[i] = true;
            }
        }
    }

    // The shared masked ridge polish (the paper's ridge step, §3.1).
    let coeffs = masked_ridge_polish(tr, &lib, &support, opts.lambda)?;
    let model = SparseModel {
        xdim: tr.xdim,
        coeffs,
        library: lib,
        iters: vec![opts.train.steps; tr.xdim],
    };
    Ok(eval("MERINDA", model, tr, t0))
}

/// MERINDA without the PJRT runtime: the same sparsity-driven masked
/// ridge polish, with the support proposed by a plain STLSQ pass instead
/// of the trained neural flow. This is the fallback the experiments
/// runner takes when no AOT artifacts are present (offline containers,
/// CI), so the Table 6 entry stays executable everywhere; records built
/// this way carry an explicit provenance note.
pub fn recover_merinda_native(tr: &Trace, opts: MerindaOpts) -> Result<Recovery> {
    let t0 = std::time::Instant::now();
    let lib = PolyLibrary::new(tr.xdim, tr.udim, 2);
    let p = lib.len();
    let stlsq = sindy::sindy(
        &tr.xs,
        &tr.us,
        tr.samples(),
        lib.clone(),
        tr.dt,
        SindyOpts::default(),
    )?;
    let mut support: Vec<bool> = stlsq.coeffs.iter().map(|c| *c != 0.0).collect();
    // An equation STLSQ zeroed out entirely still needs a search space
    // for the polish: open its full row and let the threshold-refit loop
    // prune it back.
    for d in 0..tr.xdim {
        let row = &mut support[d * p..(d + 1) * p];
        if !row.iter().any(|&m| m) {
            row.iter_mut().for_each(|m| *m = true);
        }
    }
    let coeffs = masked_ridge_polish(tr, &lib, &support, opts.lambda)?;
    let model = SparseModel {
        xdim: tr.xdim,
        coeffs,
        library: lib,
        iters: vec![0; tr.xdim],
    };
    Ok(eval("MERINDA (native)", model, tr, t0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::{CaseStudy, LotkaVolterra};

    fn lv_trace() -> Trace {
        LotkaVolterra::default().generate(1500, 0.01, &mut Prng::new(1))
    }

    #[test]
    fn sindy_and_emily_recover_lv() {
        let tr = lv_trace();
        let s = recover_sindy(&tr).unwrap();
        let e = recover_emily(&tr).unwrap();
        assert!(s.recon_mse < 1e-2, "sindy mse {}", s.recon_mse);
        // EMILY (refined) is at least as good as plain SINDy.
        assert!(e.recon_mse <= s.recon_mse * 1.01, "{} vs {}", e.recon_mse, s.recon_mse);
    }

    #[test]
    fn merinda_native_recovers_lv() {
        let tr = lv_trace();
        let m = recover_merinda_native(&tr, MerindaOpts::default()).unwrap();
        assert_eq!(m.method, "MERINDA (native)");
        assert!(m.recon_mse.is_finite());
        assert!(m.recon_mse < 1e-1, "native merinda mse {}", m.recon_mse);
    }

    #[test]
    fn pinn_sr_is_weaker_than_emily() {
        // With noise, the single-pass PINN+SR should lose to EMILY.
        let tr = lv_trace().with_noise(0.02, &mut Prng::new(3));
        let p = recover_pinn_sr(&tr).unwrap();
        let e = recover_emily(&tr).unwrap();
        assert!(
            e.recon_mse <= p.recon_mse * 1.5,
            "emily {} pinn {}",
            e.recon_mse,
            p.recon_mse
        );
    }

    #[test]
    fn smoothing_reduces_noise_variance() {
        let mut rng = Prng::new(5);
        let n = 200;
        let noisy: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let sm = smooth(&noisy, n, 1, 5);
        let var = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>() / v.len() as f64;
        assert!(var(&sm) < var(&noisy) * 0.5);
    }

    /// A smooth synthetic stream at the canonical padded serving dims.
    fn synthetic_stream(samples: usize) -> (Vec<f32>, Vec<f32>) {
        let mut y = Vec::with_capacity(samples * 3);
        let mut u = Vec::with_capacity(samples);
        for s in 0..samples {
            let t = s as f32 * 0.05;
            y.push((0.7 * t).sin());
            y.push(0.5 * (0.9 * t).cos());
            y.push(0.0); // padded state dim
            u.push(0.2 * (0.3 * t).sin());
        }
        (y, u)
    }

    #[test]
    fn refine_cold_and_warm_converge_to_same_theta() {
        let (y, u) = synthetic_stream(128);
        let w = 64usize;
        let opts = RefineOpts::default();
        let p = PolyLibrary::new(3, 1, 2).len();
        // Cold seed: an arbitrary NN-like proposal.
        let cold_seed: Vec<f32> = (0..3 * p).map(|i| 0.3 + 0.01 * i as f32).collect();
        let first = refine_window_theta(&y[..w * 3], 3, &u[..w], 1, w, &cold_seed, &opts).unwrap();
        assert!(first.converged, "residual {}", first.residual);

        // Second, overlapping window (stride 16): warm vs cold seeds.
        let s0 = 16usize;
        let y2 = &y[s0 * 3..(s0 + w) * 3];
        let u2 = &u[s0..s0 + w];
        let warm = refine_window_theta(y2, 3, u2, 1, w, &first.theta, &opts).unwrap();
        let cold = refine_window_theta(y2, 3, u2, 1, w, &cold_seed, &opts).unwrap();
        assert!(warm.converged && cold.converged);
        assert!(
            warm.iters < cold.iters,
            "warm {} vs cold {} iterations",
            warm.iters,
            cold.iters
        );
        // Agreement tolerance: each seed stops at residual ≤ rtol·‖c‖,
        // which bounds the per-seed coefficient error by rtol·‖c‖/λ.
        for (a, b) in warm.theta.iter().zip(&cold.theta) {
            assert!(
                (a - b).abs() < 1e-2,
                "warm and cold must reach the same Θ: {a} vs {b}"
            );
        }
    }

    #[test]
    fn refine_rejects_bad_shapes() {
        let (y, u) = synthetic_stream(64);
        let p = PolyLibrary::new(3, 1, 2).len();
        let seed = vec![0.0f32; 3 * p];
        assert!(refine_window_theta(&y, 3, &u, 1, 2, &seed, &RefineOpts::default()).is_err());
        assert!(
            refine_window_theta(&y[..9], 3, &u, 1, 64, &seed, &RefineOpts::default()).is_err()
        );
        assert!(
            refine_window_theta(&y, 3, &u, 1, 64, &seed[..5], &RefineOpts::default()).is_err()
        );
    }

    #[test]
    fn shooting_refine_never_hurts() {
        let tr = lv_trace();
        let lib = PolyLibrary::new(2, 0, 2);
        let mut model = sindy::sindy(
            &tr.xs,
            &tr.us,
            tr.samples(),
            lib,
            tr.dt,
            SindyOpts::default(),
        )
        .unwrap();
        // Perturb a coefficient, then refine back.
        model.coeffs[1] *= 1.2;
        let before = reconstruction_mse(&model, &tr.xs, &tr.us, tr.samples().min(400), tr.dt);
        shooting_refine(&mut model, &tr, 3);
        let after = reconstruction_mse(&model, &tr.xs, &tr.us, tr.samples().min(400), tr.dt);
        assert!(after <= before);
    }
}

//! Shared batched/tiled compute kernels for the MR hot path.
//!
//! Every hot loop in the native MR stack — the GRU forward (`mr::gru`),
//! BPTT (`mr::backprop`), the LTC solver (`mr::ltc`), the fixed-point
//! datapath emulation (`fpga::gru_accel`) and the native serving backend
//! (`coordinator::NativeBackend`) — bottoms out in the primitives here:
//!
//! * [`axpy`] / [`dot`] / [`matvec_acc`] — contiguous-slice kernels whose
//!   inner loops rustc autovectorizes (no index arithmetic, no bounds
//!   checks in the hot loop).
//! * [`gemm`] — a blocked row-major `C += A·B` with explicit leading
//!   dimensions and a fixed-width ([`LANES`]) accumulator micro-kernel, so
//!   the j-loop maps onto SIMD lanes while the k-loop stays in ascending
//!   order (bitwise-identical accumulation to the scalar axpy form).
//! * [`PackedGru`] — the transposed-packed GRU weight layout: `W (I, 3H)`
//!   stays as lowered, `U (H, 3H)` is split into contiguous `U_rz (H, 2H)`
//!   and `U_n (H, H)` blocks so the two recurrent matvecs/GEMMs stream
//!   dense rows instead of strided slices of the packed `3H` axis.
//! * [`gru_step_batch`] / [`gru_forward_batch`] — the batch-major GRU:
//!   B concurrent windows advance one time step as three GEMMs
//!   (`(B,I)·(I,3H)`, `(B,H)·(H,2H)`, `(B,H)·(H,H)`) instead of B scalar
//!   matvec chains. Tensors are batch-major row-major: `x (B, I)`,
//!   `h (B, H)`, sequences `(B, K, I)` flattened.
//!
//! Accumulation-order contract: [`axpy`], [`matvec_acc`] and [`gemm`]
//! add contributions in ascending-k order, matching the scalar reference
//! implementations, so forward paths built on them agree bitwise with the
//! scalar code (up to `±0.0` normalization). [`dot`] is exempt — its
//! 4-lane accumulators reassociate the sum, so paths using it (the
//! optimized BPTT backward) agree with the reference only to ~1e-6
//! relative tolerance. `rust/tests/batched_equivalence.rs` pins both.

use super::dense::DenseHead;
use super::gru::{sigmoid, GruParams};
use crate::fpga::fixedpoint::DatapathFormats;

/// SIMD-friendly accumulator width of the [`gemm`] micro-kernel.
pub const LANES: usize = 8;

/// `y += a · x` over equal-length slices.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// Dot product with 4 accumulator lanes (reassociates the sum; use only
/// where tolerance-level agreement with the scalar order is acceptable).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let av = &a[c * 4..c * 4 + 4];
        let bv = &b[c * 4..c * 4 + 4];
        for l in 0..4 {
            lanes[l] += av[l] * bv[l];
        }
    }
    let mut acc = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for i in chunks * 4..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// `y (n) += x (k) · B (k×n)` where `B` is row-major with leading
/// dimension `ldb` (so packed sub-blocks of wider matrices work too).
/// Row-streaming axpy form: ascending-k accumulation.
#[inline]
pub fn matvec_acc(k: usize, n: usize, x: &[f32], b: &[f32], ldb: usize, y: &mut [f32]) {
    debug_assert!(x.len() >= k);
    debug_assert!(y.len() >= n);
    debug_assert!(ldb >= n);
    for (l, &xv) in x.iter().take(k).enumerate() {
        axpy(&mut y[..n], xv, &b[l * ldb..l * ldb + n]);
    }
}

/// Blocked row-major GEMM: `C (m×n) += A (m×k) · B (k×n)` with leading
/// dimensions `lda`/`ldb`/`ldc`.
///
/// The micro-kernel holds a [`LANES`]-wide slice of the C row in a local
/// fixed-size accumulator array across the whole k sweep, so rustc keeps
/// it in vector registers; k stays ascending, preserving the scalar
/// accumulation order bitwise.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    debug_assert!(lda >= k && ldb >= n && ldc >= n);
    debug_assert!(a.len() >= m.saturating_sub(1) * lda + k || m == 0);
    debug_assert!(c.len() >= m.saturating_sub(1) * ldc + n || m == 0);
    for i in 0..m {
        let arow = &a[i * lda..i * lda + k];
        let crow = &mut c[i * ldc..i * ldc + n];
        let mut j = 0;
        while j + LANES <= n {
            let mut acc = [0.0f32; LANES];
            acc.copy_from_slice(&crow[j..j + LANES]);
            for (l, &av) in arow.iter().enumerate() {
                let brow = &b[l * ldb + j..l * ldb + j + LANES];
                for (accv, &bv) in acc.iter_mut().zip(brow) {
                    *accv += av * bv;
                }
            }
            crow[j..j + LANES].copy_from_slice(&acc);
            j += LANES;
        }
        if j < n {
            for (l, &av) in arow.iter().enumerate() {
                let brow = &b[l * ldb..l * ldb + n];
                for (cv, &bv) in crow[j..].iter_mut().zip(&brow[j..]) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// GRU weights in the transposed-packed serving layout.
///
/// `w`/`b` keep the lowered `(I, 3H)` / `(3H,)` packing (`[Wr | Wz | Wn]`);
/// the recurrent matrix is re-packed once into contiguous `u_rz (H, 2H)`
/// and `u_n (H, H)` blocks so the hot loops never stride across the packed
/// `3H` axis.
#[derive(Clone, Debug)]
pub struct PackedGru {
    pub input: usize,
    pub hidden: usize,
    /// (I, 3H) row-major input weights (as in [`GruParams`]).
    pub w: Vec<f32>,
    /// (3H,) biases.
    pub b: Vec<f32>,
    /// (H, 2H) row-major: the `[Ur | Uz]` columns of U, packed contiguous.
    pub u_rz: Vec<f32>,
    /// (H, H) row-major: the `Un` columns of U, packed contiguous.
    pub u_n: Vec<f32>,
}

impl PackedGru {
    pub fn new(p: &GruParams) -> PackedGru {
        let (i_sz, hid) = (p.input, p.hidden);
        let th = 3 * hid;
        let mut u_rz = vec![0.0f32; hid * 2 * hid];
        let mut u_n = vec![0.0f32; hid * hid];
        for hi in 0..hid {
            u_rz[hi * 2 * hid..(hi + 1) * 2 * hid]
                .copy_from_slice(&p.u[hi * th..hi * th + 2 * hid]);
            u_n[hi * hid..(hi + 1) * hid]
                .copy_from_slice(&p.u[hi * th + 2 * hid..(hi + 1) * th]);
        }
        PackedGru {
            input: i_sz,
            hidden: hid,
            w: p.w.clone(),
            b: p.b.clone(),
            u_rz,
            u_n,
        }
    }
}

/// Reusable batch-major scratch for [`gru_step_batch`].
#[derive(Clone, Debug)]
pub struct GruBatchScratch {
    /// (B, 3H) gate pre-activations `x·W + b`.
    gx: Vec<f32>,
    /// (B, 2H) recurrent pre-activations `h·U_rz`.
    gh: Vec<f32>,
    /// (B, H) update gate.
    z: Vec<f32>,
    /// (B, H) reset-modulated state `r ∘ h`.
    rh: Vec<f32>,
    /// (B, H) candidate recurrent term `(r∘h)·U_n`.
    cand: Vec<f32>,
}

impl GruBatchScratch {
    pub fn new(hidden: usize, batch: usize) -> GruBatchScratch {
        GruBatchScratch {
            gx: vec![0.0; batch * 3 * hidden],
            gh: vec![0.0; batch * 2 * hidden],
            z: vec![0.0; batch * hidden],
            rh: vec![0.0; batch * hidden],
            cand: vec![0.0; batch * hidden],
        }
    }
}

/// One batch-major GRU step: `x (B, I)`, `h (B, H)` → `out (B, H)`.
///
/// Identical math to [`crate::mr::gru::GruCell::step_into`] per row, but B
/// rows advance together through three GEMMs instead of B matvec chains.
pub fn gru_step_batch(
    p: &PackedGru,
    x: &[f32],
    h: &[f32],
    out: &mut [f32],
    batch: usize,
    s: &mut GruBatchScratch,
) {
    let (i_sz, hid) = (p.input, p.hidden);
    let th = 3 * hid;
    debug_assert_eq!(x.len(), batch * i_sz);
    debug_assert_eq!(h.len(), batch * hid);
    debug_assert_eq!(out.len(), batch * hid);
    debug_assert!(s.gx.len() >= batch * th);

    // gx = b (broadcast) + X · W over the packed 3H axis.
    for w in 0..batch {
        s.gx[w * th..(w + 1) * th].copy_from_slice(&p.b);
    }
    gemm(batch, i_sz, th, x, i_sz, &p.w, th, &mut s.gx, th);

    // gh = H · U_rz over the r/z columns.
    s.gh[..batch * 2 * hid].fill(0.0);
    gemm(batch, hid, 2 * hid, h, hid, &p.u_rz, 2 * hid, &mut s.gh, 2 * hid);

    // Gates + reset modulation.
    for w in 0..batch {
        let gx = &s.gx[w * th..(w + 1) * th];
        let gh = &s.gh[w * 2 * hid..(w + 1) * 2 * hid];
        let hrow = &h[w * hid..(w + 1) * hid];
        let zrow = &mut s.z[w * hid..(w + 1) * hid];
        let rhrow = &mut s.rh[w * hid..(w + 1) * hid];
        for j in 0..hid {
            let r = sigmoid(gx[j] + gh[j]);
            zrow[j] = sigmoid(gx[hid + j] + gh[hid + j]);
            rhrow[j] = r * hrow[j];
        }
    }

    // Candidate: cand = (r∘h) · U_n.
    s.cand[..batch * hid].fill(0.0);
    gemm(batch, hid, hid, &s.rh, hid, &p.u_n, hid, &mut s.cand, hid);

    // Interpolation: h' = (1−z)∘tanh(gx_n + cand) + z∘h.
    for w in 0..batch {
        let gx = &s.gx[w * th..(w + 1) * th];
        let cand = &s.cand[w * hid..(w + 1) * hid];
        let zrow = &s.z[w * hid..(w + 1) * hid];
        let hrow = &h[w * hid..(w + 1) * hid];
        let orow = &mut out[w * hid..(w + 1) * hid];
        for j in 0..hid {
            let n = (gx[2 * hid + j] + cand[j]).tanh();
            orow[j] = (1.0 - zrow[j]) * n + zrow[j] * hrow[j];
        }
    }
}

/// Batch-major GRU sequence forward: `xs (B, K, I)` flattened → final
/// hidden states `(B, H)`. Handles any B ≥ 1 (ragged final batches are the
/// caller padding to their service batch, or simply a smaller B here).
pub fn gru_forward_batch(p: &PackedGru, xs: &[f32], seq: usize, batch: usize) -> Vec<f32> {
    let (i_sz, hid) = (p.input, p.hidden);
    debug_assert_eq!(xs.len(), batch * seq * i_sz);
    let mut s = GruBatchScratch::new(hid, batch);
    let mut xt = vec![0.0f32; batch * i_sz];
    let mut h = vec![0.0f32; batch * hid];
    let mut next = vec![0.0f32; batch * hid];
    for t in 0..seq {
        // Gather the time-t rows of each window into a contiguous (B, I).
        for w in 0..batch {
            let src = (w * seq + t) * i_sz;
            xt[w * i_sz..(w + 1) * i_sz].copy_from_slice(&xs[src..src + i_sz]);
        }
        gru_step_batch(p, &xt, &h, &mut next, batch, &mut s);
        std::mem::swap(&mut h, &mut next);
    }
    h
}

/// One batch-major GRU step through the quantized datapath: the same
/// three GEMMs as [`gru_step_batch`], but every pre-activation sum passes
/// through the saturating accumulator format and every stage output is
/// re-quantized to the activation format — the batched counterpart of
/// `fpga::gru_accel::GruAccel::forward_fixed`, minus the LUT activation
/// tables (serving keeps exact sigmoid/tanh so Q8.8 stays within serving
/// tolerance of the f32 backend).
///
/// The caller is expected to hand in weights already quantized to the
/// weight storage format (see `coordinator::FixedPointBackend`) and
/// inputs quantized to `fmts.act`.
pub fn gru_step_batch_fixed(
    p: &PackedGru,
    x: &[f32],
    h: &[f32],
    out: &mut [f32],
    batch: usize,
    s: &mut GruBatchScratch,
    fmts: DatapathFormats,
) {
    let (i_sz, hid) = (p.input, p.hidden);
    let th = 3 * hid;
    let (act, acc) = (fmts.act, fmts.acc);
    debug_assert_eq!(x.len(), batch * i_sz);
    debug_assert_eq!(h.len(), batch * hid);
    debug_assert_eq!(out.len(), batch * hid);
    debug_assert!(s.gx.len() >= batch * th);

    // Stage 1: gate affines with saturating accumulate.
    for w in 0..batch {
        s.gx[w * th..(w + 1) * th].copy_from_slice(&p.b);
    }
    gemm(batch, i_sz, th, x, i_sz, &p.w, th, &mut s.gx, th);
    acc.saturate_slice(&mut s.gx[..batch * th]);
    act.quantize_slice(&mut s.gx[..batch * th]);

    s.gh[..batch * 2 * hid].fill(0.0);
    gemm(batch, hid, 2 * hid, h, hid, &p.u_rz, 2 * hid, &mut s.gh, 2 * hid);
    acc.saturate_slice(&mut s.gh[..batch * 2 * hid]);
    act.quantize_slice(&mut s.gh[..batch * 2 * hid]);

    // Stage 2: gates + reset modulation, quantized at each boundary.
    for w in 0..batch {
        let gx = &s.gx[w * th..(w + 1) * th];
        let gh = &s.gh[w * 2 * hid..(w + 1) * 2 * hid];
        let hrow = &h[w * hid..(w + 1) * hid];
        let zrow = &mut s.z[w * hid..(w + 1) * hid];
        let rhrow = &mut s.rh[w * hid..(w + 1) * hid];
        for j in 0..hid {
            let r = act.quantize_f32(sigmoid(gx[j] + gh[j]));
            zrow[j] = act.quantize_f32(sigmoid(gx[hid + j] + gh[hid + j]));
            rhrow[j] = act.quantize_f32(r * hrow[j]);
        }
    }

    // Stage 3: candidate recurrent term through the accumulator.
    s.cand[..batch * hid].fill(0.0);
    gemm(batch, hid, hid, &s.rh, hid, &p.u_n, hid, &mut s.cand, hid);
    acc.saturate_slice(&mut s.cand[..batch * hid]);

    // Stage 4: tanh + interpolation, quantized on writeback.
    for w in 0..batch {
        let gx = &s.gx[w * th..(w + 1) * th];
        let cand = &s.cand[w * hid..(w + 1) * hid];
        let zrow = &s.z[w * hid..(w + 1) * hid];
        let hrow = &h[w * hid..(w + 1) * hid];
        let orow = &mut out[w * hid..(w + 1) * hid];
        for j in 0..hid {
            let n = act.quantize_f32((gx[2 * hid + j] + act.quantize_f32(cand[j])).tanh());
            orow[j] = act.quantize_f32((1.0 - zrow[j]) * n + zrow[j] * hrow[j]);
        }
    }
}

/// Quantized batch-major GRU sequence forward: [`gru_forward_batch`] with
/// inputs re-quantized to the activation format each step and every stage
/// running through [`gru_step_batch_fixed`]. Returns final hidden states
/// `(B, H)`, already quantized to `fmts.act`.
pub fn gru_forward_batch_fixed(
    p: &PackedGru,
    xs: &[f32],
    seq: usize,
    batch: usize,
    fmts: DatapathFormats,
) -> Vec<f32> {
    let (i_sz, hid) = (p.input, p.hidden);
    debug_assert_eq!(xs.len(), batch * seq * i_sz);
    let mut s = GruBatchScratch::new(hid, batch);
    let mut xt = vec![0.0f32; batch * i_sz];
    let mut h = vec![0.0f32; batch * hid];
    let mut next = vec![0.0f32; batch * hid];
    for t in 0..seq {
        for w in 0..batch {
            let src = (w * seq + t) * i_sz;
            xt[w * i_sz..(w + 1) * i_sz].copy_from_slice(&xs[src..src + i_sz]);
        }
        fmts.act.quantize_slice(&mut xt);
        gru_step_batch_fixed(p, &xt, &h, &mut next, batch, &mut s, fmts);
        std::mem::swap(&mut h, &mut next);
    }
    h
}

/// Quantized batched dense head: [`dense_head_batch`] with the hidden
/// layer and outputs passed through the saturating accumulator and
/// re-quantized to the activation format. Weights are expected
/// pre-quantized by the caller; the pruning mask still forces exact
/// zeros.
pub fn dense_head_batch_fixed(
    head: &DenseHead,
    h: &[f32],
    batch: usize,
    fmts: DatapathFormats,
) -> Vec<f32> {
    let (i_sz, hid, out_sz) = (head.input, head.hidden, head.output);
    let (act, acc) = (fmts.act, fmts.acc);
    debug_assert_eq!(h.len(), batch * i_sz);
    let mut z = vec![0.0f32; batch * hid];
    for w in 0..batch {
        z[w * hid..(w + 1) * hid].copy_from_slice(&head.b1);
    }
    gemm(batch, i_sz, hid, h, i_sz, &head.w1, hid, &mut z, hid);
    acc.saturate_slice(&mut z);
    for v in z.iter_mut() {
        *v = v.max(0.0);
    }
    act.quantize_slice(&mut z);
    let mut out = vec![0.0f32; batch * out_sz];
    for w in 0..batch {
        out[w * out_sz..(w + 1) * out_sz].copy_from_slice(&head.b2);
    }
    gemm(batch, hid, out_sz, &z, hid, &head.w2, out_sz, &mut out, out_sz);
    acc.saturate_slice(&mut out);
    act.quantize_slice(&mut out);
    if let Some(mask) = &head.mask {
        for w in 0..batch {
            for (o, &keep) in out[w * out_sz..(w + 1) * out_sz].iter_mut().zip(mask) {
                if !keep {
                    *o = 0.0;
                }
            }
        }
    }
    out
}

/// Batched dense head: `h (B, H)` → `theta (B, O)` through the two-layer
/// ReLU MLP, matching [`DenseHead::forward`] per row (mask included).
pub fn dense_head_batch(head: &DenseHead, h: &[f32], batch: usize) -> Vec<f32> {
    let (i_sz, hid, out_sz) = (head.input, head.hidden, head.output);
    debug_assert_eq!(h.len(), batch * i_sz);
    let mut z = vec![0.0f32; batch * hid];
    for w in 0..batch {
        z[w * hid..(w + 1) * hid].copy_from_slice(&head.b1);
    }
    gemm(batch, i_sz, hid, h, i_sz, &head.w1, hid, &mut z, hid);
    for v in z.iter_mut() {
        *v = v.max(0.0);
    }
    let mut out = vec![0.0f32; batch * out_sz];
    for w in 0..batch {
        out[w * out_sz..(w + 1) * out_sz].copy_from_slice(&head.b2);
    }
    gemm(batch, hid, out_sz, &z, hid, &head.w2, out_sz, &mut out, out_sz);
    if let Some(mask) = &head.mask {
        for w in 0..batch {
            for (o, &keep) in out[w * out_sz..(w + 1) * out_sz].iter_mut().zip(mask) {
                if !keep {
                    *o = 0.0;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mr::gru::GruCell;
    use crate::util::Prng;

    fn naive_gemm(
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        c: &mut [f32],
        ldc: usize,
    ) {
        for i in 0..m {
            for l in 0..k {
                for j in 0..n {
                    c[i * ldc + j] += a[i * lda + l] * b[l * ldb + j];
                }
            }
        }
    }

    #[test]
    fn gemm_matches_naive_all_shapes() {
        let mut rng = Prng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (8, 4, 96), (2, 16, 9), (5, 3, 8)] {
            let a = rng.normal_vec_f32(m * k, 1.0);
            let b = rng.normal_vec_f32(k * n, 1.0);
            let mut c1 = rng.normal_vec_f32(m * n, 0.5);
            let mut c2 = c1.clone();
            gemm(m, k, n, &a, k, &b, n, &mut c1, n);
            naive_gemm(m, k, n, &a, k, &b, n, &mut c2, n);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-5, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_respects_leading_dimensions() {
        // Operate on a 2x2 sub-block of padded matrices.
        let a = vec![1.0, 2.0, 9.0, 3.0, 4.0, 9.0]; // (2,2) in lda=3
        let b = vec![1.0, 0.0, 9.0, 0.0, 1.0, 9.0]; // identity in ldb=3
        let mut c = vec![0.0; 8]; // (2,2) in ldc=4
        gemm(2, 2, 2, &a, 3, &b, 3, &mut c, 4);
        assert_eq!(&c[0..2], &[1.0, 2.0]);
        assert_eq!(&c[4..6], &[3.0, 4.0]);
    }

    #[test]
    fn dot_matches_scalar_sum() {
        let mut rng = Prng::new(2);
        for n in [0usize, 1, 3, 4, 7, 8, 33] {
            let a = rng.normal_vec_f32(n, 1.0);
            let b = rng.normal_vec_f32(n, 1.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn matvec_acc_equals_gemm_row() {
        let mut rng = Prng::new(3);
        let (k, n, ldb) = (6, 10, 12);
        let x = rng.normal_vec_f32(k, 1.0);
        let b = rng.normal_vec_f32(k * ldb, 1.0);
        let mut y1 = vec![0.5f32; n];
        let mut y2 = y1.clone();
        matvec_acc(k, n, &x, &b, ldb, &mut y1);
        gemm(1, k, n, &x, k, &b, ldb, &mut y2, n);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn packed_layout_preserves_weights() {
        let mut rng = Prng::new(4);
        let p = GruParams::random(3, 5, &mut rng, 0.5);
        let packed = PackedGru::new(&p);
        let th = 15;
        for hi in 0..5 {
            assert_eq!(&packed.u_rz[hi * 10..hi * 10 + 10], &p.u[hi * th..hi * th + 10]);
            assert_eq!(&packed.u_n[hi * 5..hi * 5 + 5], &p.u[hi * th + 10..hi * th + 15]);
        }
        assert_eq!(packed.w, p.w);
        assert_eq!(packed.b, p.b);
    }

    #[test]
    fn batched_step_matches_scalar_cell() {
        let mut rng = Prng::new(5);
        for &batch in &[1usize, 3, 8] {
            let params = GruParams::random(4, 16, &mut rng, 0.4);
            let cell = GruCell::new(params.clone());
            let packed = PackedGru::new(&params);
            let x = rng.normal_vec_f32(batch * 4, 1.0);
            let h = rng.normal_vec_f32(batch * 16, 0.5);
            let mut out = vec![0.0f32; batch * 16];
            let mut s = GruBatchScratch::new(16, batch);
            gru_step_batch(&packed, &x, &h, &mut out, batch, &mut s);
            for w in 0..batch {
                let want = cell.step(&x[w * 4..(w + 1) * 4], &h[w * 16..(w + 1) * 16]);
                for (a, b) in out[w * 16..(w + 1) * 16].iter().zip(&want) {
                    assert!((a - b).abs() < 1e-6, "batch {batch} window {w}");
                }
            }
        }
    }

    #[test]
    fn batched_forward_matches_scalar_run() {
        let mut rng = Prng::new(6);
        let params = GruParams::random(3, 12, &mut rng, 0.3);
        let cell = GruCell::new(params.clone());
        let packed = PackedGru::new(&params);
        let (batch, seq) = (5, 17);
        let xs = rng.normal_vec_f32(batch * seq * 3, 0.8);
        let h = gru_forward_batch(&packed, &xs, seq, batch);
        for w in 0..batch {
            let want = cell.run(&xs[w * seq * 3..(w + 1) * seq * 3], seq);
            for (a, b) in h[w * 12..(w + 1) * 12].iter().zip(&want) {
                assert!((a - b).abs() < 1e-6, "window {w}");
            }
        }
    }

    #[test]
    fn fixed_batch_forward_is_batch_invariant() {
        use crate::fpga::fixedpoint::FixedFormat;
        let mut rng = Prng::new(11);
        let params = GruParams::random(3, 10, &mut rng, 0.3);
        let packed = PackedGru::new(&params);
        let fmts = DatapathFormats::for_ops(FixedFormat::q8_8(), FixedFormat::q8_8());
        let (batch, seq) = (4usize, 9usize);
        let xs = rng.normal_vec_f32(batch * seq * 3, 0.8);
        let all = gru_forward_batch_fixed(&packed, &xs, seq, batch, fmts);
        for w in 0..batch {
            let one =
                gru_forward_batch_fixed(&packed, &xs[w * seq * 3..(w + 1) * seq * 3], seq, 1, fmts);
            assert_eq!(&all[w * 10..(w + 1) * 10], &one[..], "window {w}");
        }
    }

    #[test]
    fn fixed_forward_wide_format_tracks_float() {
        use crate::fpga::fixedpoint::FixedFormat;
        let mut rng = Prng::new(12);
        let params = GruParams::random(4, 12, &mut rng, 0.3);
        let packed = PackedGru::new(&params);
        let wide = FixedFormat::new(24, 16);
        let fmts = DatapathFormats::for_ops(wide, wide);
        let (batch, seq) = (3usize, 16usize);
        let xs = rng.normal_vec_f32(batch * seq * 4, 0.8);
        let fixed = gru_forward_batch_fixed(&packed, &xs, seq, batch, fmts);
        let float = gru_forward_batch(&packed, &xs, seq, batch);
        for (a, b) in fixed.iter().zip(&float) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn dense_head_batch_fixed_tracks_float_and_respects_mask() {
        use crate::fpga::fixedpoint::FixedFormat;
        let mut rng = Prng::new(13);
        let mut head = DenseHead::random(6, 10, 9, &mut rng);
        let batch = 3;
        let h = rng.normal_vec_f32(batch * 6, 0.5);
        let wide = FixedFormat::new(24, 16);
        let fmts = DatapathFormats::for_ops(wide, wide);
        let fixed = dense_head_batch_fixed(&head, &h, batch, fmts);
        let float = dense_head_batch(&head, &h, batch);
        for (a, b) in fixed.iter().zip(&float) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // Pruned outputs are exact zeros even after quantization.
        let calib = vec![head.forward(&h[0..6])];
        head.prune_to_top(&calib, 3);
        let q8 = DatapathFormats::for_ops(FixedFormat::q8_8(), FixedFormat::q8_8());
        let masked = dense_head_batch_fixed(&head, &h, batch, q8);
        let mask = head.mask.as_ref().unwrap();
        for w in 0..batch {
            for (o, &keep) in masked[w * 9..(w + 1) * 9].iter().zip(mask) {
                if !keep {
                    assert_eq!(*o, 0.0);
                }
            }
        }
    }

    #[test]
    fn dense_head_batch_matches_scalar_forward() {
        let mut rng = Prng::new(7);
        let mut head = DenseHead::random(6, 10, 9, &mut rng);
        let batch = 4;
        let h = rng.normal_vec_f32(batch * 6, 1.0);
        // Unmasked.
        let out = dense_head_batch(&head, &h, batch);
        for w in 0..batch {
            let want = head.forward(&h[w * 6..(w + 1) * 6]);
            for (a, b) in out[w * 9..(w + 1) * 9].iter().zip(&want) {
                assert!((a - b).abs() < 1e-6);
            }
        }
        // Masked.
        let calib = vec![head.forward(&h[0..6])];
        head.prune_to_top(&calib, 3);
        let out = dense_head_batch(&head, &h, batch);
        for w in 0..batch {
            let want = head.forward(&h[w * 6..(w + 1) * 6]);
            for (a, b) in out[w * 9..(w + 1) * 9].iter().zip(&want) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }
}

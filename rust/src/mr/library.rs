//! Polynomial candidate library Θ(X, U) for sparse model recovery.
//!
//! §3.1: an n-dimensional model with Mth-order nonlinearity draws from
//! C(M+n, n) candidate terms; a sparse model uses p ≪ that. This module
//! builds the design matrix for SINDy/ridge and mirrors the L2
//! `poly_library_ref` (order-2 over [states | inputs], leading 1).

/// A single library term: product of variables with exponents.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Term {
    /// exponents[i] = power of variable i (states then inputs).
    pub exponents: Vec<u32>,
}

impl Term {
    pub fn degree(&self) -> u32 {
        self.exponents.iter().sum()
    }

    /// Human-readable name like `x0*x1` or `1`.
    pub fn name(&self, xdim: usize) -> String {
        let mut parts = Vec::new();
        for (i, &e) in self.exponents.iter().enumerate() {
            let var = if i < xdim {
                format!("x{i}")
            } else {
                format!("u{}", i - xdim)
            };
            for _ in 0..e {
                parts.push(var.clone());
            }
        }
        if parts.is_empty() {
            "1".to_string()
        } else {
            parts.join("*")
        }
    }

    /// Evaluate on a concatenated [x | u] vector.
    pub fn eval(&self, v: &[f64]) -> f64 {
        let mut acc = 1.0;
        for (i, &e) in self.exponents.iter().enumerate() {
            for _ in 0..e {
                acc *= v[i];
            }
        }
        acc
    }
}

/// A polynomial library over `xdim` states and `udim` inputs up to `order`.
#[derive(Clone, Debug)]
pub struct PolyLibrary {
    pub xdim: usize,
    pub udim: usize,
    pub order: u32,
    pub terms: Vec<Term>,
    /// Incremental-evaluation chain: `chain[k] = (parent, var)` so that
    /// `value[k] = value[parent] * v[var]` — every monomial is one multiply
    /// on top of a lower-degree monomial already computed (graded order
    /// guarantees `parent < k`). `chain[0]` is unused (the constant 1).
    chain: Vec<(usize, usize)>,
}

/// Number of monomials in d variables up to degree M: C(M+d, d).
pub fn library_size(dims: usize, order: u32) -> usize {
    // Compute binomial(order + dims, dims) without overflow for our sizes.
    let mut num = 1u64;
    let mut den = 1u64;
    for i in 1..=dims as u64 {
        num *= order as u64 + i;
        den *= i;
    }
    (num / den) as usize
}

impl PolyLibrary {
    /// Build all monomials of total degree ≤ order, in graded-lex order
    /// matching `poly_library_ref` for order 2 (1, linear, quadratic).
    pub fn new(xdim: usize, udim: usize, order: u32) -> PolyLibrary {
        let dims = xdim + udim;
        let mut terms = Vec::new();
        // Degree 0.
        terms.push(Term {
            exponents: vec![0; dims],
        });
        // Degree 1..=order, graded: within a degree, enumerate monomials
        // v_i v_j v_k … with i ≤ j ≤ k — matching the ref kernel's i ≤ j
        // ordering at order 2.
        fn rec_exact(
            dims: usize,
            left: u32,
            start: usize,
            exps: &mut Vec<u32>,
            out: &mut Vec<Term>,
        ) {
            if left == 0 {
                out.push(Term {
                    exponents: exps.clone(),
                });
                return;
            }
            for v in start..dims {
                exps[v] += 1;
                rec_exact(dims, left - 1, v, exps, out);
                exps[v] -= 1;
            }
        }
        for deg in 1..=order {
            let mut exps = vec![0u32; dims];
            rec_exact(dims, deg, 0, &mut exps, &mut terms);
        }
        // Build the incremental chain: drop one power of the first active
        // variable; the remaining monomial has degree-1 less and therefore
        // appears earlier in the graded enumeration.
        let index: std::collections::HashMap<Vec<u32>, usize> = terms
            .iter()
            .enumerate()
            .map(|(i, t)| (t.exponents.clone(), i))
            .collect();
        let mut chain = vec![(0usize, 0usize); terms.len()];
        for (k, t) in terms.iter().enumerate().skip(1) {
            let var = t
                .exponents
                .iter()
                .position(|&e| e > 0)
                .expect("non-constant term has an active variable");
            let mut pe = t.exponents.clone();
            pe[var] -= 1;
            let parent = *index.get(&pe).expect("graded order provides the parent");
            debug_assert!(parent < k);
            chain[k] = (parent, var);
        }
        PolyLibrary {
            xdim,
            udim,
            order,
            terms,
            chain,
        }
    }

    pub fn len(&self) -> usize {
        self.terms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    /// Evaluate all terms for one sample (x, u) into `out`.
    pub fn eval_into(&self, x: &[f64], u: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.xdim);
        debug_assert_eq!(u.len(), self.udim);
        debug_assert_eq!(out.len(), self.terms.len());
        let mut v = Vec::with_capacity(self.xdim + self.udim);
        v.extend_from_slice(x);
        v.extend_from_slice(u);
        for (o, t) in out.iter_mut().zip(&self.terms) {
            *o = t.eval(&v);
        }
    }

    /// Evaluate all terms for one sample, allocating.
    pub fn eval(&self, x: &[f64], u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.terms.len()];
        self.eval_into(x, u, &mut out);
        out
    }

    /// Evaluate all terms for one concatenated `[x | u]` sample through the
    /// incremental chain: one multiply per monomial, reusing the
    /// lower-degree product already in the row (EXPERIMENTS.md §Perf).
    /// `Term::eval` walks every variable's exponent per term (~`dims`×
    /// the work) and is kept as the reference oracle.
    pub fn eval_chain_into(&self, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(v.len(), self.xdim + self.udim);
        debug_assert_eq!(out.len(), self.terms.len());
        out[0] = 1.0;
        for k in 1..self.terms.len() {
            let (parent, var) = self.chain[k];
            out[k] = out[parent] * v[var];
        }
    }

    /// Build the (samples, terms) design matrix from trajectories.
    /// `xs`: (samples, xdim), `us`: (samples, udim) row-major.
    ///
    /// Perf note (EXPERIMENTS.md §Perf): rows are filled through
    /// [`PolyLibrary::eval_chain_into`] — one multiply per term at any
    /// order — instead of the generic exponent-walk in `Term::eval`, which
    /// costs ~3× more in this hot loop (and more at higher orders).
    pub fn design_matrix(&self, xs: &[f64], us: &[f64], samples: usize) -> Vec<f64> {
        let p = self.terms.len();
        let mut m = vec![0.0; samples * p];
        let d = self.xdim + self.udim;
        let mut v = vec![0.0f64; d];
        for s in 0..samples {
            v[..self.xdim].copy_from_slice(&xs[s * self.xdim..(s + 1) * self.xdim]);
            if self.udim > 0 {
                v[self.xdim..].copy_from_slice(&us[s * self.udim..(s + 1) * self.udim]);
            }
            self.eval_chain_into(&v, &mut m[s * p..(s + 1) * p]);
        }
        m
    }

    /// Term names (for report printing).
    pub fn names(&self) -> Vec<String> {
        self.terms.iter().map(|t| t.name(self.xdim)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_sizes() {
        // Paper §3.1: C(M+n, n). Order 2, 4 vars → C(6,4)=15.
        assert_eq!(library_size(4, 2), 15);
        assert_eq!(library_size(3, 2), 10);
        assert_eq!(library_size(3, 3), 20);
    }

    #[test]
    fn library_matches_binomial_count() {
        for (x, u, m) in [(3, 1, 2), (2, 0, 2), (3, 0, 3), (2, 1, 3)] {
            let lib = PolyLibrary::new(x, u, m);
            assert_eq!(lib.len(), library_size(x + u, m), "x={x} u={u} m={m}");
        }
    }

    #[test]
    fn matches_l2_kernel_ordering_order2() {
        // poly_library_ref: [1, v1..v4, v_i v_j (i<=j)] for v=[x,u].
        let lib = PolyLibrary::new(3, 1, 2);
        let names = lib.names();
        assert_eq!(names[0], "1");
        assert_eq!(names[1], "x0");
        assert_eq!(names[4], "u0");
        assert_eq!(names[5], "x0*x0");
        assert_eq!(names[6], "x0*x1");
        assert_eq!(names[14], "u0*u0");
    }

    #[test]
    fn evaluation_correct() {
        let lib = PolyLibrary::new(2, 0, 2);
        // terms: 1, x0, x1, x0², x0x1, x1²
        let f = lib.eval(&[2.0, 3.0], &[]);
        assert_eq!(f, vec![1.0, 2.0, 3.0, 4.0, 6.0, 9.0]);
    }

    #[test]
    fn design_matrix_rows() {
        let lib = PolyLibrary::new(1, 1, 2);
        let xs = [1.0, 2.0];
        let us = [0.5, -1.0];
        let m = lib.design_matrix(&xs, &us, 2);
        let p = lib.len();
        assert_eq!(m.len(), 2 * p);
        assert_eq!(&m[0..p], lib.eval(&[1.0], &[0.5]).as_slice());
        assert_eq!(&m[p..2 * p], lib.eval(&[2.0], &[-1.0]).as_slice());
    }

    #[test]
    fn chain_is_well_formed() {
        for (x, u, m) in [(3, 1, 2), (2, 0, 3), (4, 1, 3), (1, 0, 5)] {
            let lib = PolyLibrary::new(x, u, m);
            for (k, t) in lib.terms.iter().enumerate().skip(1) {
                let (parent, var) = lib.chain[k];
                assert!(parent < k, "x={x} u={u} m={m} k={k}");
                assert!(t.exponents[var] > 0);
                let mut pe = t.exponents.clone();
                pe[var] -= 1;
                assert_eq!(lib.terms[parent].exponents, pe);
            }
        }
    }

    #[test]
    fn chain_eval_matches_term_eval_higher_orders() {
        for (x, u, m) in [(3, 1, 3), (2, 1, 4), (4, 0, 3)] {
            let lib = PolyLibrary::new(x, u, m);
            let d = x + u;
            let v: Vec<f64> = (0..d).map(|i| 0.3 + 0.7 * i as f64).collect();
            let mut fast = vec![0.0; lib.len()];
            lib.eval_chain_into(&v, &mut fast);
            for (k, t) in lib.terms.iter().enumerate() {
                let naive = t.eval(&v);
                assert!(
                    (fast[k] - naive).abs() <= 1e-12 * (1.0 + naive.abs()),
                    "x={x} u={u} m={m} term {k}: {} vs {naive}",
                    fast[k]
                );
            }
        }
    }

    #[test]
    fn term_names_and_degrees() {
        let lib = PolyLibrary::new(2, 1, 2);
        for t in &lib.terms {
            assert!(t.degree() <= 2);
        }
        assert!(lib.names().contains(&"x0*u0".to_string()));
    }
}

//! Ridge regression via normal equations + Cholesky.
//!
//! §3.1: "Ridge regression identifies matrix A". Used by the SINDy/STLSQ
//! baseline and the dense-head equation selection. Solves
//! `argmin ‖Xw − y‖² + λ‖w‖²` through `(XᵀX + λI) w = Xᵀy`.

use crate::util::{Error, Result};

/// Dense column-major symmetric positive-definite solve via Cholesky.
///
/// `a` is (n, n) row-major (symmetric), `b` is (n,). Returns x with
/// `a x = b`, or an error if the matrix is not SPD.
pub fn cholesky_solve(a: &[f64], b: &[f64], n: usize) -> Result<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // Factor A = L Lᵀ (in-place lower triangle).
    let mut l = a.to_vec();
    for j in 0..n {
        let mut diag = l[j * n + j];
        for k in 0..j {
            diag -= l[j * n + k] * l[j * n + k];
        }
        if diag <= 0.0 || !diag.is_finite() {
            return Err(Error::numeric(format!(
                "cholesky failed at pivot {j}: {diag}"
            )));
        }
        let d = diag.sqrt();
        l[j * n + j] = d;
        for i in (j + 1)..n {
            let mut v = l[i * n + j];
            for k in 0..j {
                v -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = v / d;
        }
    }
    // Solve L z = b.
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut v = b[i];
        for k in 0..i {
            v -= l[i * n + k] * z[k];
        }
        z[i] = v / l[i * n + i];
    }
    // Solve Lᵀ x = z.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut v = z[i];
        for k in (i + 1)..n {
            v -= l[k * n + i] * x[k];
        }
        x[i] = v / l[i * n + i];
    }
    Ok(x)
}

/// Ridge regression: `x` (rows, cols) row-major design matrix, `y` (rows,)
/// targets, `lambda ≥ 0`. Returns the (cols,) weight vector.
pub fn ridge(x: &[f64], y: &[f64], rows: usize, cols: usize, lambda: f64) -> Result<Vec<f64>> {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(y.len(), rows);
    // Normal equations: G = XᵀX + λI, c = Xᵀy.
    let mut g = vec![0.0; cols * cols];
    let mut c = vec![0.0; cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        for i in 0..cols {
            c[i] += row[i] * y[r];
            for j in i..cols {
                g[i * cols + j] += row[i] * row[j];
            }
        }
    }
    // Symmetrize + regularize.
    for i in 0..cols {
        for j in 0..i {
            g[i * cols + j] = g[j * cols + i];
        }
        g[i * cols + i] += lambda.max(1e-12);
    }
    cholesky_solve(&g, &c, cols)
}

/// Ridge with a support mask: only columns with `mask[i] = true`
/// participate; others get weight 0 (the STLSQ inner solve).
pub fn ridge_masked(
    x: &[f64],
    y: &[f64],
    rows: usize,
    cols: usize,
    lambda: f64,
    mask: &[bool],
) -> Result<Vec<f64>> {
    let active: Vec<usize> = (0..cols).filter(|&i| mask[i]).collect();
    if active.is_empty() {
        return Ok(vec![0.0; cols]);
    }
    let k = active.len();
    let mut xa = vec![0.0; rows * k];
    for r in 0..rows {
        for (ai, &c) in active.iter().enumerate() {
            xa[r * k + ai] = x[r * cols + c];
        }
    }
    let wa = ridge(&xa, y, rows, k, lambda)?;
    let mut w = vec![0.0; cols];
    for (ai, &c) in active.iter().enumerate() {
        w[c] = wa[ai];
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn cholesky_identity() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let x = cholesky_solve(&a, &[3.0, -2.0], 2).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] + 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_solve(&a, &[1.0, 1.0], 2).is_err());
    }

    #[test]
    fn ridge_recovers_exact_weights_lambda_zero() {
        let mut rng = Prng::new(4);
        let (rows, cols) = (200, 5);
        let w_true: Vec<f64> = (0..cols).map(|i| i as f64 - 2.0).collect();
        let mut x = vec![0.0; rows * cols];
        let mut y = vec![0.0; rows];
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = rng.normal();
            }
            y[r] = (0..cols).map(|c| x[r * cols + c] * w_true[c]).sum();
        }
        let w = ridge(&x, &y, rows, cols, 0.0).unwrap();
        for (a, b) in w.iter().zip(&w_true) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn lambda_shrinks_weights() {
        let mut rng = Prng::new(9);
        let (rows, cols) = (50, 3);
        let mut x = vec![0.0; rows * cols];
        let mut y = vec![0.0; rows];
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = rng.normal();
            }
            y[r] = 2.0 * x[r * cols] + rng.normal_with(0.0, 0.01);
        }
        let w0 = ridge(&x, &y, rows, cols, 1e-9).unwrap();
        let w1 = ridge(&x, &y, rows, cols, 100.0).unwrap();
        let n0: f64 = w0.iter().map(|v| v * v).sum();
        let n1: f64 = w1.iter().map(|v| v * v).sum();
        assert!(n1 < n0);
    }

    #[test]
    fn masked_ridge_zeroes_inactive() {
        let mut rng = Prng::new(11);
        let (rows, cols) = (60, 4);
        let mut x = vec![0.0; rows * cols];
        let mut y = vec![0.0; rows];
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = rng.normal();
            }
            y[r] = 1.5 * x[r * cols + 1];
        }
        let mask = [false, true, false, true];
        let w = ridge_masked(&x, &y, rows, cols, 1e-9, &mask).unwrap();
        assert_eq!(w[0], 0.0);
        assert_eq!(w[2], 0.0);
        assert!((w[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn all_masked_returns_zero() {
        let w = ridge_masked(&[1.0, 2.0], &[1.0], 1, 2, 0.1, &[false, false]).unwrap();
        assert_eq!(w, vec![0.0, 0.0]);
    }
}

//! Ridge regression via normal equations + Cholesky.
//!
//! §3.1: "Ridge regression identifies matrix A". Used by the SINDy/STLSQ
//! baseline and the dense-head equation selection. Solves
//! `argmin ‖Xw − y‖² + λ‖w‖²` through `(XᵀX + λI) w = Xᵀy`.

use crate::util::{Error, Result};

/// Dense column-major symmetric positive-definite solve via Cholesky.
///
/// `a` is (n, n) row-major (symmetric), `b` is (n,). Returns x with
/// `a x = b`, or an error if the matrix is not SPD.
pub fn cholesky_solve(a: &[f64], b: &[f64], n: usize) -> Result<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // Factor A = L Lᵀ (in-place lower triangle).
    let mut l = a.to_vec();
    for j in 0..n {
        let mut diag = l[j * n + j];
        for k in 0..j {
            diag -= l[j * n + k] * l[j * n + k];
        }
        if diag <= 0.0 || !diag.is_finite() {
            return Err(Error::numeric(format!(
                "cholesky failed at pivot {j}: {diag}"
            )));
        }
        let d = diag.sqrt();
        l[j * n + j] = d;
        for i in (j + 1)..n {
            let mut v = l[i * n + j];
            for k in 0..j {
                v -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = v / d;
        }
    }
    // Solve L z = b.
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut v = b[i];
        for k in 0..i {
            v -= l[i * n + k] * z[k];
        }
        z[i] = v / l[i * n + i];
    }
    // Solve Lᵀ x = z.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut v = z[i];
        for k in (i + 1)..n {
            v -= l[k * n + i] * x[k];
        }
        x[i] = v / l[i * n + i];
    }
    Ok(x)
}

/// Assemble the ridge normal equations `G = XᵀX + λI`, `c = Xᵀy` —
/// shared by the direct ([`ridge`]) and iterative ([`ridge_cg`]) solvers
/// so the two can never diverge in formulation.
pub fn normal_equations(
    x: &[f64],
    y: &[f64],
    rows: usize,
    cols: usize,
    lambda: f64,
) -> (Vec<f64>, Vec<f64>) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(y.len(), rows);
    let mut g = vec![0.0; cols * cols];
    let mut c = vec![0.0; cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        for i in 0..cols {
            c[i] += row[i] * y[r];
            for j in i..cols {
                g[i * cols + j] += row[i] * row[j];
            }
        }
    }
    // Symmetrize + regularize.
    for i in 0..cols {
        for j in 0..i {
            g[i * cols + j] = g[j * cols + i];
        }
        g[i * cols + i] += lambda.max(1e-12);
    }
    (g, c)
}

/// Ridge regression: `x` (rows, cols) row-major design matrix, `y` (rows,)
/// targets, `lambda ≥ 0`. Returns the (cols,) weight vector.
pub fn ridge(x: &[f64], y: &[f64], rows: usize, cols: usize, lambda: f64) -> Result<Vec<f64>> {
    let (g, c) = normal_equations(x, y, rows, cols, lambda);
    cholesky_solve(&g, &c, cols)
}

/// Stopping rule for [`ridge_cg`].
#[derive(Clone, Copy, Debug)]
pub struct RidgeCgOpts {
    /// Relative residual threshold: stop when `‖r‖₂ ≤ rtol·‖Xᵀy‖₂`.
    pub rtol: f64,
    /// Absolute residual floor (covers `y = 0` right-hand sides).
    pub atol: f64,
    /// Iteration cap per solve.
    pub max_iters: usize,
}

impl Default for RidgeCgOpts {
    fn default() -> Self {
        RidgeCgOpts {
            rtol: 1e-6,
            atol: 1e-10,
            max_iters: 60,
        }
    }
}

/// Result of a [`ridge_cg`] solve.
#[derive(Clone, Debug)]
pub struct CgSolve {
    /// The (cols,) weight vector.
    pub w: Vec<f64>,
    /// Conjugate-gradient iterations taken.
    pub iters: u64,
    /// Whether the residual threshold was reached within `max_iters`.
    pub converged: bool,
    /// Final residual 2-norm `‖Xᵀy − (XᵀX + λI)w‖₂`.
    pub residual: f64,
}

/// Ridge regression by conjugate gradient on the normal equations,
/// seeded from `w0` — the warm-startable counterpart of [`ridge`].
///
/// Solves `(XᵀX + λI) w = Xᵀy` (identical formulation to [`ridge`], so
/// the two agree to solver tolerance) but iteratively: the iteration
/// count scales with the distance from `w0` to the solution, which is
/// what makes warm-starting consecutive overlapping recovery windows
/// from the previous window's coefficients measurably cheaper than
/// cold-starting each one (`coordinator::stream` warm-start path).
pub fn ridge_cg(
    x: &[f64],
    y: &[f64],
    rows: usize,
    cols: usize,
    lambda: f64,
    w0: &[f64],
    opts: &RidgeCgOpts,
) -> CgSolve {
    debug_assert_eq!(w0.len(), cols);
    let (g, c) = normal_equations(x, y, rows, cols, lambda);

    let matvec = |v: &[f64], out: &mut [f64]| {
        for i in 0..cols {
            let mut acc = 0.0;
            for j in 0..cols {
                acc += g[i * cols + j] * v[j];
            }
            out[i] = acc;
        }
    };
    let dot = |a: &[f64], b: &[f64]| -> f64 { a.iter().zip(b).map(|(p, q)| p * q).sum() };

    let mut w = w0.to_vec();
    let mut gv = vec![0.0; cols];
    matvec(&w, &mut gv);
    let mut r: Vec<f64> = c.iter().zip(&gv).map(|(ci, gi)| ci - gi).collect();
    let target = (opts.rtol * dot(&c, &c).sqrt()).max(opts.atol);
    let mut rs = dot(&r, &r);
    if rs.sqrt() <= target {
        return CgSolve {
            w,
            iters: 0,
            converged: true,
            residual: rs.sqrt(),
        };
    }
    let mut d = r.clone();
    let mut iters = 0u64;
    for _ in 0..opts.max_iters {
        matvec(&d, &mut gv);
        let dgd = dot(&d, &gv);
        if dgd <= 0.0 || !dgd.is_finite() {
            // Numerically lost SPD-ness: stop with what we have.
            break;
        }
        let alpha = rs / dgd;
        for i in 0..cols {
            w[i] += alpha * d[i];
            r[i] -= alpha * gv[i];
        }
        iters += 1;
        let rs_new = dot(&r, &r);
        if rs_new.sqrt() <= target {
            return CgSolve {
                w,
                iters,
                converged: true,
                residual: rs_new.sqrt(),
            };
        }
        let beta = rs_new / rs;
        for i in 0..cols {
            d[i] = r[i] + beta * d[i];
        }
        rs = rs_new;
    }
    CgSolve {
        w,
        iters,
        converged: false,
        residual: rs.sqrt(),
    }
}

/// Ridge with a support mask: only columns with `mask[i] = true`
/// participate; others get weight 0 (the STLSQ inner solve).
pub fn ridge_masked(
    x: &[f64],
    y: &[f64],
    rows: usize,
    cols: usize,
    lambda: f64,
    mask: &[bool],
) -> Result<Vec<f64>> {
    let active: Vec<usize> = (0..cols).filter(|&i| mask[i]).collect();
    if active.is_empty() {
        return Ok(vec![0.0; cols]);
    }
    let k = active.len();
    let mut xa = vec![0.0; rows * k];
    for r in 0..rows {
        for (ai, &c) in active.iter().enumerate() {
            xa[r * k + ai] = x[r * cols + c];
        }
    }
    let wa = ridge(&xa, y, rows, k, lambda)?;
    let mut w = vec![0.0; cols];
    for (ai, &c) in active.iter().enumerate() {
        w[c] = wa[ai];
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn cholesky_identity() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let x = cholesky_solve(&a, &[3.0, -2.0], 2).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] + 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_solve(&a, &[1.0, 1.0], 2).is_err());
    }

    #[test]
    fn ridge_recovers_exact_weights_lambda_zero() {
        let mut rng = Prng::new(4);
        let (rows, cols) = (200, 5);
        let w_true: Vec<f64> = (0..cols).map(|i| i as f64 - 2.0).collect();
        let mut x = vec![0.0; rows * cols];
        let mut y = vec![0.0; rows];
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = rng.normal();
            }
            y[r] = (0..cols).map(|c| x[r * cols + c] * w_true[c]).sum();
        }
        let w = ridge(&x, &y, rows, cols, 0.0).unwrap();
        for (a, b) in w.iter().zip(&w_true) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn lambda_shrinks_weights() {
        let mut rng = Prng::new(9);
        let (rows, cols) = (50, 3);
        let mut x = vec![0.0; rows * cols];
        let mut y = vec![0.0; rows];
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = rng.normal();
            }
            y[r] = 2.0 * x[r * cols] + rng.normal_with(0.0, 0.01);
        }
        let w0 = ridge(&x, &y, rows, cols, 1e-9).unwrap();
        let w1 = ridge(&x, &y, rows, cols, 100.0).unwrap();
        let n0: f64 = w0.iter().map(|v| v * v).sum();
        let n1: f64 = w1.iter().map(|v| v * v).sum();
        assert!(n1 < n0);
    }

    #[test]
    fn masked_ridge_zeroes_inactive() {
        let mut rng = Prng::new(11);
        let (rows, cols) = (60, 4);
        let mut x = vec![0.0; rows * cols];
        let mut y = vec![0.0; rows];
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = rng.normal();
            }
            y[r] = 1.5 * x[r * cols + 1];
        }
        let mask = [false, true, false, true];
        let w = ridge_masked(&x, &y, rows, cols, 1e-9, &mask).unwrap();
        assert_eq!(w[0], 0.0);
        assert_eq!(w[2], 0.0);
        assert!((w[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn all_masked_returns_zero() {
        let w = ridge_masked(&[1.0, 2.0], &[1.0], 1, 2, 0.1, &[false, false]).unwrap();
        assert_eq!(w, vec![0.0, 0.0]);
    }

    /// Random well-posed problem the direct and iterative solvers agree on.
    fn random_problem(seed: u64, rows: usize, cols: usize) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut x = vec![0.0; rows * cols];
        let mut y = vec![0.0; rows];
        for r in 0..rows {
            for c in 0..cols {
                x[r * cols + c] = rng.normal();
            }
            y[r] = (0..cols)
                .map(|c| x[r * cols + c] * (c as f64 * 0.5 - 1.0))
                .sum::<f64>()
                + rng.normal_with(0.0, 0.01);
        }
        (x, y)
    }

    #[test]
    fn cg_matches_cholesky_solution() {
        for seed in [3u64, 17, 99] {
            let (rows, cols) = (80, 9);
            let (x, y) = random_problem(seed, rows, cols);
            let lambda = 1e-3;
            let direct = ridge(&x, &y, rows, cols, lambda).unwrap();
            let cg = ridge_cg(
                &x,
                &y,
                rows,
                cols,
                lambda,
                &vec![0.0; cols],
                &RidgeCgOpts::default(),
            );
            assert!(cg.converged, "seed {seed}: residual {}", cg.residual);
            for (a, b) in cg.w.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-6, "seed {seed}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn cg_from_exact_solution_takes_zero_iterations() {
        let (rows, cols) = (60, 6);
        let (x, y) = random_problem(7, rows, cols);
        let lambda = 1e-3;
        let w_star = ridge(&x, &y, rows, cols, lambda).unwrap();
        let cg = ridge_cg(&x, &y, rows, cols, lambda, &w_star, &RidgeCgOpts::default());
        assert!(cg.converged);
        assert_eq!(cg.iters, 0, "seeding at the solution must cost nothing");
    }

    #[test]
    fn cg_warm_seed_beats_cold_seed() {
        let (rows, cols) = (100, 12);
        let (x, y) = random_problem(21, rows, cols);
        let lambda = 1e-3;
        let w_star = ridge(&x, &y, rows, cols, lambda).unwrap();
        // Warm: a small perturbation of the solution (what the previous
        // overlapping window provides). Cold: an unrelated seed.
        let warm: Vec<f64> = w_star.iter().map(|v| v + 1e-4).collect();
        let cold = vec![3.0; cols];
        let opts = RidgeCgOpts::default();
        let rw = ridge_cg(&x, &y, rows, cols, lambda, &warm, &opts);
        let rc = ridge_cg(&x, &y, rows, cols, lambda, &cold, &opts);
        assert!(rw.converged && rc.converged);
        assert!(
            rw.iters < rc.iters,
            "warm {} vs cold {} iterations",
            rw.iters,
            rc.iters
        );
        for (a, b) in rw.w.iter().zip(&rc.w) {
            assert!((a - b).abs() < 1e-5, "seeds must converge to one solution");
        }
    }

    #[test]
    fn cg_zero_rhs_converges_to_zero() {
        let (rows, cols) = (40, 5);
        let (x, _) = random_problem(5, rows, cols);
        let y = vec![0.0; rows];
        let cg = ridge_cg(
            &x,
            &y,
            rows,
            cols,
            1e-3,
            &vec![2.0; cols],
            &RidgeCgOpts::default(),
        );
        assert!(cg.converged);
        for v in &cg.w {
            assert!(v.abs() < 1e-6, "zero rhs must shrink to zero: {v}");
        }
    }
}

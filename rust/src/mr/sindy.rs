//! SINDy: sparse identification of nonlinear dynamics via STLSQ.
//!
//! The paper's comparison baseline (Tables 4/5, [12, 18]). Given sampled
//! trajectories X(t) and inputs U(t), estimate derivatives numerically,
//! build the polynomial design matrix Θ(X, U), and run sequentially
//! thresholded least squares: ridge-solve, zero out coefficients below
//! the threshold, repeat on the surviving support until stable.

use super::library::PolyLibrary;
use super::ridge::ridge_masked;
use crate::util::Result;

/// STLSQ hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct SindyOpts {
    /// Hard threshold on coefficient magnitude.
    pub threshold: f64,
    /// Ridge regularization inside each solve.
    pub lambda: f64,
    /// Maximum STLSQ sweeps.
    pub max_iters: usize,
}

impl Default for SindyOpts {
    fn default() -> Self {
        SindyOpts {
            threshold: 0.05,
            lambda: 1e-6,
            max_iters: 20,
        }
    }
}

/// A recovered sparse model: coefficient matrix (xdim, terms) row-major.
#[derive(Clone, Debug)]
pub struct SparseModel {
    pub xdim: usize,
    pub coeffs: Vec<f64>,
    pub library: PolyLibrary,
    /// STLSQ iterations actually used per state equation.
    pub iters: Vec<usize>,
}

impl SparseModel {
    /// Evaluate dX/dt at (x, u).
    pub fn dyn_eval(&self, x: &[f64], u: &[f64], out: &mut [f64]) {
        let p = self.library.len();
        let feats = self.library.eval(x, u);
        for d in 0..self.xdim {
            let row = &self.coeffs[d * p..(d + 1) * p];
            out[d] = row.iter().zip(&feats).map(|(c, f)| c * f).sum();
        }
    }

    /// Number of nonzero coefficients.
    pub fn nnz(&self) -> usize {
        self.coeffs.iter().filter(|c| **c != 0.0).count()
    }

    /// Coefficient for a named term of a state equation (tests).
    pub fn coeff(&self, eq: usize, term_name: &str) -> f64 {
        let names = self.library.names();
        let idx = names
            .iter()
            .position(|n| n == term_name)
            .unwrap_or_else(|| panic!("no term {term_name}"));
        self.coeffs[eq * self.library.len() + idx]
    }
}

/// Central-difference derivative estimate along axis 0.
/// `xs`: (samples, dim) row-major → (samples, dim) with one-sided ends.
pub fn finite_difference(xs: &[f64], samples: usize, dim: usize, dt: f64) -> Vec<f64> {
    assert!(samples >= 3);
    let mut dx = vec![0.0; samples * dim];
    for d in 0..dim {
        dx[d] = (xs[dim + d] - xs[d]) / dt;
        for s in 1..samples - 1 {
            dx[s * dim + d] = (xs[(s + 1) * dim + d] - xs[(s - 1) * dim + d]) / (2.0 * dt);
        }
        dx[(samples - 1) * dim + d] =
            (xs[(samples - 1) * dim + d] - xs[(samples - 2) * dim + d]) / dt;
    }
    dx
}

/// Run SINDy/STLSQ on sampled data.
///
/// `xs`: (samples, xdim), `us`: (samples, udim) row-major, `dt` sample
/// spacing. Returns the recovered sparse model.
pub fn sindy(
    xs: &[f64],
    us: &[f64],
    samples: usize,
    library: PolyLibrary,
    dt: f64,
    opts: SindyOpts,
) -> Result<SparseModel> {
    let xdim = library.xdim;
    let p = library.len();
    let dx = finite_difference(xs, samples, xdim, dt);
    let theta = library.design_matrix(xs, us, samples);

    let mut coeffs = vec![0.0; xdim * p];
    let mut iters = vec![0usize; xdim];
    for d in 0..xdim {
        let y: Vec<f64> = (0..samples).map(|s| dx[s * xdim + d]).collect();
        let mut mask = vec![true; p];
        let mut w = ridge_masked(&theta, &y, samples, p, opts.lambda, &mask)?;
        for it in 0..opts.max_iters {
            iters[d] = it + 1;
            let mut changed = false;
            for (i, m) in mask.iter_mut().enumerate() {
                if *m && w[i].abs() < opts.threshold {
                    *m = false;
                    changed = true;
                }
            }
            w = ridge_masked(&theta, &y, samples, p, opts.lambda, &mask)?;
            if !changed {
                break;
            }
        }
        coeffs[d * p..(d + 1) * p].copy_from_slice(&w);
    }
    Ok(SparseModel {
        xdim,
        coeffs,
        library,
        iters,
    })
}

/// Reconstruction MSE of a recovered model against held-out data: integrate
/// from the first sample with RK4 and compare trajectories.
pub fn reconstruction_mse(
    model: &SparseModel,
    xs: &[f64],
    us: &[f64],
    samples: usize,
    dt: f64,
) -> f64 {
    use super::ode::{rk4_step, FnRhs};
    let xdim = model.xdim;
    let udim = model.library.udim;
    let rhs = FnRhs {
        dim: xdim,
        f: |_t, y: &[f64], u: &[f64], out: &mut [f64]| model.dyn_eval(y, u, out),
    };
    let mut y = xs[0..xdim].to_vec();
    let mut se = 0.0;
    let zero_u: Vec<f64> = vec![0.0; udim.max(1)];
    for s in 1..samples {
        let u = if udim > 0 {
            &us[(s - 1) * udim..s * udim]
        } else {
            &zero_u[..udim.max(0)]
        };
        rk4_step(&rhs, (s - 1) as f64 * dt, &mut y, u, dt);
        // Clamp to keep a bad model from poisoning the metric with inf.
        for v in y.iter_mut() {
            *v = v.clamp(-1e6, 1e6);
        }
        for d in 0..xdim {
            let e = y[d] - xs[s * xdim + d];
            se += e * e;
        }
    }
    se / ((samples - 1) * xdim) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mr::ode::{rk4_trajectory, FnRhs};

    /// Generate clean Lotka–Volterra data and recover it.
    fn lv_data(samples: usize, dt: f64) -> Vec<f64> {
        let rhs = FnRhs {
            dim: 2,
            f: |_t, y: &[f64], _u: &[f64], out: &mut [f64]| {
                out[0] = 1.0 * y[0] - 0.5 * y[0] * y[1];
                out[1] = -1.0 * y[1] + 0.25 * y[0] * y[1];
            },
        };
        rk4_trajectory(&rhs, &[2.0, 1.0], &[], 0, dt, samples - 1)
    }

    #[test]
    fn recovers_lotka_volterra_structure() {
        let dt = 0.01;
        let samples = 2000;
        let xs = lv_data(samples, dt);
        let lib = PolyLibrary::new(2, 0, 2);
        let model = sindy(&xs, &[], samples, lib, dt, SindyOpts::default()).unwrap();
        // True terms: dx0 = x0 − 0.5 x0x1, dx1 = −x1 + 0.25 x0x1.
        assert!((model.coeff(0, "x0") - 1.0).abs() < 0.05);
        assert!((model.coeff(0, "x0*x1") + 0.5).abs() < 0.05);
        assert!((model.coeff(1, "x1") + 1.0).abs() < 0.05);
        assert!((model.coeff(1, "x0*x1") - 0.25).abs() < 0.05);
        // Sparsity: exactly 4 nonzeros.
        assert_eq!(model.nnz(), 4, "coeffs: {:?}", model.coeffs);
    }

    #[test]
    fn reconstruction_error_small_for_good_model() {
        let dt = 0.01;
        let samples = 1500;
        let xs = lv_data(samples, dt);
        let lib = PolyLibrary::new(2, 0, 2);
        let model = sindy(&xs, &[], samples, lib, dt, SindyOpts::default()).unwrap();
        let mse = reconstruction_mse(&model, &xs, &[], samples, dt);
        assert!(mse < 1e-3, "mse={mse}");
    }

    #[test]
    fn finite_difference_on_linear_fn() {
        // x(t) = 3t → dx = 3 everywhere.
        let dt = 0.1;
        let xs: Vec<f64> = (0..10).map(|i| 3.0 * i as f64 * dt).collect();
        let dx = finite_difference(&xs, 10, 1, dt);
        for v in dx {
            assert!((v - 3.0).abs() < 1e-10);
        }
    }

    #[test]
    fn threshold_prunes_noise_terms() {
        let dt = 0.01;
        let samples = 1000;
        let xs = lv_data(samples, dt);
        let lib = PolyLibrary::new(2, 0, 2);
        let tight = sindy(
            &xs,
            &[],
            samples,
            lib.clone(),
            dt,
            SindyOpts {
                threshold: 0.05,
                ..Default::default()
            },
        )
        .unwrap();
        let loose = sindy(
            &xs,
            &[],
            samples,
            lib,
            dt,
            SindyOpts {
                threshold: 0.0,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(tight.nnz() <= loose.nnz());
    }

    #[test]
    fn iterations_recorded() {
        let dt = 0.01;
        let samples = 500;
        let xs = lv_data(samples, dt);
        let lib = PolyLibrary::new(2, 0, 2);
        let m = sindy(&xs, &[], samples, lib, dt, SindyOpts::default()).unwrap();
        assert!(m.iters.iter().all(|&i| i >= 1));
    }
}

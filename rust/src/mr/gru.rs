//! GRU cell and sequence model (native Rust, f32).
//!
//! Gate packing matches `python/compile/kernels/ref.py` exactly:
//! `w: (I, 3H)` packed `[Wr | Wz | Wn]`, `u: (H, 3H)` packed
//! `[Ur | Uz | Un]`, `b: (3H,)`. `rust/tests/integration.rs` pins this
//! implementation against the Pallas-kernel HLO so the FPGA simulator, the
//! L1 kernel and this code all compute the same function.

use crate::util::Prng;

use super::linalg;

/// Packed GRU parameters.
#[derive(Clone, Debug)]
pub struct GruParams {
    pub input: usize,
    pub hidden: usize,
    /// (I, 3H) row-major input weights.
    pub w: Vec<f32>,
    /// (H, 3H) row-major recurrent weights.
    pub u: Vec<f32>,
    /// (3H,) biases.
    pub b: Vec<f32>,
}

impl GruParams {
    /// Random N(0, std) init (matches the integration-test convention).
    pub fn random(input: usize, hidden: usize, rng: &mut Prng, std: f64) -> GruParams {
        GruParams {
            input,
            hidden,
            w: rng.normal_vec_f32(input * 3 * hidden, std),
            u: rng.normal_vec_f32(hidden * 3 * hidden, std),
            b: rng.normal_vec_f32(3 * hidden, std * 0.3),
        }
    }

    /// Zero-initialized parameters.
    pub fn zeros(input: usize, hidden: usize) -> GruParams {
        GruParams {
            input,
            hidden,
            w: vec![0.0; input * 3 * hidden],
            u: vec![0.0; hidden * 3 * hidden],
            b: vec![0.0; 3 * hidden],
        }
    }

    /// Total parameter count.
    pub fn len(&self) -> usize {
        self.w.len() + self.u.len() + self.b.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Reusable scratch buffers for [`GruCell::step_into`].
#[derive(Clone, Debug)]
pub struct GruScratch {
    gx: Vec<f32>,
    gh: Vec<f32>,
    r: Vec<f32>,
    z: Vec<f32>,
    cand: Vec<f32>,
}

impl GruScratch {
    pub fn new(hidden: usize) -> GruScratch {
        GruScratch {
            gx: vec![0.0; 3 * hidden],
            gh: vec![0.0; 2 * hidden],
            r: vec![0.0; hidden],
            z: vec![0.0; hidden],
            cand: vec![0.0; hidden],
        }
    }
}

/// A GRU cell: owns parameters, steps one sample at a time.
#[derive(Clone, Debug)]
pub struct GruCell {
    pub params: GruParams,
}

impl GruCell {
    pub fn new(params: GruParams) -> GruCell {
        GruCell { params }
    }

    /// One step: x (I,), h (H,) → h' (H,).
    ///
    /// Allocating wrapper around [`GruCell::step_into`].
    pub fn step(&self, x: &[f32], h: &[f32]) -> Vec<f32> {
        let mut scratch = GruScratch::new(self.params.hidden);
        let mut out = vec![0.0f32; self.params.hidden];
        self.step_into(x, h, &mut out, &mut scratch);
        out
    }

    /// One step into a caller-provided buffer with reused scratch
    /// (§Perf: the per-step allocations dominated `run` on long traces).
    ///
    /// r = σ(x·Wr + h·Ur + br); z = σ(x·Wz + h·Uz + bz);
    /// n = tanh(x·Wn + (r∘h)·Un + bn); h' = (1−z)∘n + z∘h.
    pub fn step_into(&self, x: &[f32], h: &[f32], out: &mut [f32], s: &mut GruScratch) {
        let p = &self.params;
        let (i_sz, hid) = (p.input, p.hidden);
        debug_assert_eq!(x.len(), i_sz);
        debug_assert_eq!(h.len(), hid);
        let th = 3 * hid;
        debug_assert_eq!(out.len(), hid);

        // gx = x W + b over the packed 3H axis.
        let gx = &mut s.gx;
        gx.copy_from_slice(&p.b);
        linalg::matvec_acc(i_sz, th, x, &p.w, th, gx);
        // gh = h U over the r/z columns only (first 2H of each packed row).
        let gh = &mut s.gh;
        gh.fill(0.0);
        linalg::matvec_acc(hid, 2 * hid, h, &p.u, th, gh);

        let (r, z) = (&mut s.r, &mut s.z);
        for j in 0..hid {
            r[j] = sigmoid(gx[j] + gh[j]);
            z[j] = sigmoid(gx[hid + j] + gh[hid + j]);
        }

        // candidate: n = tanh(gx_n + (r∘h) Un)
        let cand = &mut s.cand;
        cand.fill(0.0);
        for hi in 0..hid {
            let rh = r[hi] * h[hi];
            if rh != 0.0 {
                linalg::axpy(cand, rh, &p.u[hi * th + 2 * hid..(hi + 1) * th]);
            }
        }
        for j in 0..hid {
            let n = (gx[2 * hid + j] + cand[j]).tanh();
            out[j] = (1.0 - z[j]) * n + z[j] * h[j];
        }
    }

    /// Run a sequence: xs is (K, I) row-major; returns final hidden state.
    pub fn run(&self, xs: &[f32], seq: usize) -> Vec<f32> {
        let i_sz = self.params.input;
        let hid = self.params.hidden;
        debug_assert_eq!(xs.len(), seq * i_sz);
        let mut scratch = GruScratch::new(hid);
        let mut h = vec![0.0f32; hid];
        let mut next = vec![0.0f32; hid];
        for t in 0..seq {
            self.step_into(&xs[t * i_sz..(t + 1) * i_sz], &h, &mut next, &mut scratch);
            std::mem::swap(&mut h, &mut next);
        }
        h
    }

    /// Run a sequence returning every hidden state (K, H).
    ///
    /// Uses [`GruCell::step_into`] with one reused scratch like `run` does
    /// (§Perf: the old per-step `step` wrapper re-allocated the scratch
    /// buffers and an extra output vector on every time step).
    pub fn run_all(&self, xs: &[f32], seq: usize) -> Vec<Vec<f32>> {
        let i_sz = self.params.input;
        let hid = self.params.hidden;
        let mut scratch = GruScratch::new(hid);
        let mut h = vec![0.0f32; hid];
        let mut out = Vec::with_capacity(seq);
        for t in 0..seq {
            let mut next = vec![0.0f32; hid];
            self.step_into(&xs[t * i_sz..(t + 1) * i_sz], &h, &mut next, &mut scratch);
            h.copy_from_slice(&next);
            out.push(next);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(i: usize, h: usize, seed: u64) -> GruCell {
        let mut rng = Prng::new(seed);
        GruCell::new(GruParams::random(i, h, &mut rng, 0.3))
    }

    #[test]
    fn state_is_bounded() {
        // h' is a convex combination of tanh(·) ∈ (−1,1) and previous h, so
        // starting from 0 the state stays in (−1, 1) forever.
        let c = cell(4, 16, 42);
        let mut rng = Prng::new(7);
        let mut h = vec![0.0f32; 16];
        for _ in 0..200 {
            let x = rng.normal_vec_f32(4, 2.0);
            h = c.step(&x, &h);
            assert!(h.iter().all(|v| v.abs() < 1.0), "state escaped: {h:?}");
        }
    }

    #[test]
    fn zero_params_zero_input_fixed_point() {
        // With all-zero parameters: r=z=0.5, n=tanh(0)=0, so h'=0.5 h.
        let c = GruCell::new(GruParams::zeros(2, 4));
        let h = vec![1.0f32; 4];
        let out = c.step(&[0.0, 0.0], &h);
        for v in out {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn step_deterministic() {
        let c = cell(3, 8, 1);
        let x = vec![0.5f32, -0.2, 0.1];
        let h = vec![0.1f32; 8];
        assert_eq!(c.step(&x, &h), c.step(&x, &h));
    }

    #[test]
    fn run_matches_manual_stepping() {
        let c = cell(2, 6, 9);
        let mut rng = Prng::new(3);
        let xs = rng.normal_vec_f32(10 * 2, 1.0);
        let final_h = c.run(&xs, 10);
        let mut h = vec![0.0f32; 6];
        for t in 0..10 {
            h = c.step(&xs[t * 2..(t + 1) * 2], &h);
        }
        assert_eq!(final_h, h);
    }

    #[test]
    fn run_all_last_equals_run() {
        let c = cell(2, 6, 11);
        let mut rng = Prng::new(5);
        let xs = rng.normal_vec_f32(7 * 2, 1.0);
        let all = c.run_all(&xs, 7);
        assert_eq!(all.last().unwrap(), &c.run(&xs, 7));
    }

    #[test]
    fn reset_gate_controls_memory() {
        // Large negative r-bias forces r≈0: candidate ignores h entirely,
        // so two different initial states converge after one step when z≈0.
        let mut p = GruParams::zeros(1, 2);
        for j in 0..2 {
            p.b[j] = -50.0; // br → r≈0
            p.b[2 + j] = -50.0; // bz → z≈0
        }
        let c = GruCell::new(p);
        let a = c.step(&[0.3], &[0.9, -0.9]);
        let b = c.step(&[0.3], &[-0.5, 0.5]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}

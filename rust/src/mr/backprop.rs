//! Native BPTT for the GRU (the FPGA-side training path).
//!
//! Paper §6.2: "The GRU model was developed from scratch, with the forward
//! pass and backpropagation logic implemented in C++ using HLS". The
//! PJRT train step covers host training; this module is the native
//! backward pass the FPGA runs — backpropagation-through-time for the
//! packed-gate GRU plus a linear head, gradient-checked against finite
//! differences and used by `GruAccel::training_report` to cost the
//! backward dataflow.
//!
//! Two implementations live here (EXPERIMENTS.md §Perf):
//! * [`GruBptt::loss_and_grads`] — the optimized path: one reusable
//!   [`BpttScratch`] holds all per-step activations in flat seq-major
//!   buffers, weights stream through the [`linalg::PackedGru`] layout and
//!   every inner loop is a `linalg` slice kernel. No per-step allocation.
//! * [`GruBptt::loss_and_grads_reference`] — the original allocation-heavy
//!   per-step implementation, kept verbatim as the numerical oracle for
//!   `rust/tests/batched_equivalence.rs` and as the bench baseline in
//!   `benches/hotpath.rs`.

use crate::util::Prng;

use super::gru::{sigmoid, GruParams};
use super::linalg::{self, PackedGru};

/// Gradients w.r.t. the GRU parameters (same packing as `GruParams`).
#[derive(Clone, Debug)]
pub struct GruGrads {
    pub w: Vec<f32>,
    pub u: Vec<f32>,
    pub b: Vec<f32>,
}

impl GruGrads {
    pub fn zeros(p: &GruParams) -> GruGrads {
        GruGrads {
            w: vec![0.0; p.w.len()],
            u: vec![0.0; p.u.len()],
            b: vec![0.0; p.b.len()],
        }
    }

    /// Squared L2 norm over all gradient entries.
    pub fn norm_sq(&self) -> f64 {
        self.w
            .iter()
            .chain(&self.u)
            .chain(&self.b)
            .map(|&g| (g as f64) * (g as f64))
            .sum()
    }
}

/// Per-step cached activations for the reference backward pass.
struct StepCache {
    x: Vec<f32>,
    h_prev: Vec<f32>,
    r: Vec<f32>,
    z: Vec<f32>,
    n: Vec<f32>,
    /// pre-activation of the candidate gate (needed for tanh').
    rh: Vec<f32>,
}

/// Flat seq-major scratch for the optimized BPTT path; allocate once and
/// reuse across calls (`sgd_step` reuses it across the whole batch).
#[derive(Clone, Debug)]
pub struct BpttScratch {
    hidden: usize,
    seq_cap: usize,
    /// (seq+1, H) hidden states including h0 = 0.
    h: Vec<f32>,
    /// (seq, H) cached gate activations.
    r: Vec<f32>,
    z: Vec<f32>,
    n: Vec<f32>,
    rh: Vec<f32>,
    /// (3H) / (2H) / (H) per-step temporaries.
    gx: Vec<f32>,
    gh: Vec<f32>,
    cand: Vec<f32>,
    /// (H) backward temporaries.
    dh: Vec<f32>,
    dh_prev: Vec<f32>,
    dn: Vec<f32>,
    dz: Vec<f32>,
    dr: Vec<f32>,
    dan: Vec<f32>,
    dar: Vec<f32>,
    daz: Vec<f32>,
    drh: Vec<f32>,
}

impl BpttScratch {
    pub fn new(hidden: usize, seq: usize) -> BpttScratch {
        BpttScratch {
            hidden,
            seq_cap: seq,
            h: vec![0.0; (seq + 1) * hidden],
            r: vec![0.0; seq * hidden],
            z: vec![0.0; seq * hidden],
            n: vec![0.0; seq * hidden],
            rh: vec![0.0; seq * hidden],
            gx: vec![0.0; 3 * hidden],
            gh: vec![0.0; 2 * hidden],
            cand: vec![0.0; hidden],
            dh: vec![0.0; hidden],
            dh_prev: vec![0.0; hidden],
            dn: vec![0.0; hidden],
            dz: vec![0.0; hidden],
            dr: vec![0.0; hidden],
            dan: vec![0.0; hidden],
            dar: vec![0.0; hidden],
            daz: vec![0.0; hidden],
            drh: vec![0.0; hidden],
        }
    }

    fn ensure(&mut self, hidden: usize, seq: usize) {
        if self.hidden != hidden || self.seq_cap < seq {
            *self = BpttScratch::new(hidden, seq.max(self.seq_cap));
        }
    }
}

/// BPTT engine for one GRU cell + linear head `y = h_K · Wo + bo`.
pub struct GruBptt {
    pub params: GruParams,
    /// (H, O) output head.
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
    pub out_dim: usize,
}

impl GruBptt {
    pub fn new(params: GruParams, out_dim: usize, rng: &mut Prng) -> GruBptt {
        let h = params.hidden;
        GruBptt {
            params,
            wo: rng.normal_vec_f32(h * out_dim, 1.0 / (h as f64).sqrt()),
            bo: vec![0.0; out_dim],
            out_dim,
        }
    }

    /// Forward through the sequence, caching activations (reference path).
    fn forward_cached(&self, xs: &[f32], seq: usize) -> (Vec<f32>, Vec<StepCache>) {
        let p = &self.params;
        let (i_sz, hid) = (p.input, p.hidden);
        let th = 3 * hid;
        let mut h = vec![0.0f32; hid];
        let mut caches = Vec::with_capacity(seq);
        for t in 0..seq {
            let x = &xs[t * i_sz..(t + 1) * i_sz];
            let mut gx = p.b.clone();
            for (ii, &xv) in x.iter().enumerate() {
                for (g, &wv) in gx.iter_mut().zip(&p.w[ii * th..(ii + 1) * th]) {
                    *g += xv * wv;
                }
            }
            let mut gh = vec![0.0f32; 2 * hid];
            for (hi, &hv) in h.iter().enumerate() {
                for (g, &uv) in gh.iter_mut().zip(&p.u[hi * th..hi * th + 2 * hid]) {
                    *g += hv * uv;
                }
            }
            let mut r = vec![0.0f32; hid];
            let mut z = vec![0.0f32; hid];
            for j in 0..hid {
                r[j] = sigmoid(gx[j] + gh[j]);
                z[j] = sigmoid(gx[hid + j] + gh[hid + j]);
            }
            let rh: Vec<f32> = (0..hid).map(|j| r[j] * h[j]).collect();
            let mut cand = vec![0.0f32; hid];
            for hi in 0..hid {
                let v = rh[hi];
                if v != 0.0 {
                    for (c, &uv) in cand
                        .iter_mut()
                        .zip(&p.u[hi * th + 2 * hid..(hi + 1) * th])
                    {
                        *c += v * uv;
                    }
                }
            }
            let n: Vec<f32> = (0..hid).map(|j| (gx[2 * hid + j] + cand[j]).tanh()).collect();
            let h_prev = h.clone();
            for j in 0..hid {
                h[j] = (1.0 - z[j]) * n[j] + z[j] * h_prev[j];
            }
            caches.push(StepCache {
                x: x.to_vec(),
                h_prev,
                r,
                z,
                n,
                rh,
            });
        }
        (h, caches)
    }

    /// Head output for a final hidden state.
    pub fn head(&self, h: &[f32]) -> Vec<f32> {
        let mut y = self.bo.clone();
        for (j, &hv) in h.iter().enumerate() {
            for (o, &w) in y.iter_mut().zip(&self.wo[j * self.out_dim..(j + 1) * self.out_dim]) {
                *o += hv * w;
            }
        }
        y
    }

    /// MSE loss + full gradients via BPTT for one (xs, target) sequence.
    ///
    /// Optimized path: zero per-step allocation, packed weights, slice
    /// kernels. Returns (loss, param grads, head grads (wo, bo)).
    pub fn loss_and_grads(
        &self,
        xs: &[f32],
        seq: usize,
        target: &[f32],
    ) -> (f64, GruGrads, Vec<f32>, Vec<f32>) {
        let packed = PackedGru::new(&self.params);
        let mut scratch = BpttScratch::new(self.params.hidden, seq);
        let mut g = GruGrads::zeros(&self.params);
        let mut dwo = vec![0.0f32; self.wo.len()];
        let mut dbo = vec![0.0f32; self.bo.len()];
        let loss = self.accumulate_loss_and_grads(
            xs,
            seq,
            target,
            &packed,
            &mut scratch,
            &mut g,
            &mut dwo,
            &mut dbo,
        );
        (loss, g, dwo, dbo)
    }

    /// One (xs, target) BPTT pass that *adds* its gradients into the given
    /// accumulators; returns the sample loss. `sgd_step` calls this in a
    /// loop with one shared scratch so batch gradient accumulation costs
    /// no extra buffers at all.
    #[allow(clippy::too_many_arguments)]
    pub fn accumulate_loss_and_grads(
        &self,
        xs: &[f32],
        seq: usize,
        target: &[f32],
        packed: &PackedGru,
        s: &mut BpttScratch,
        g: &mut GruGrads,
        dwo: &mut [f32],
        dbo: &mut [f32],
    ) -> f64 {
        let p = &self.params;
        let (i_sz, hid, th, od) = (p.input, p.hidden, 3 * p.hidden, self.out_dim);
        debug_assert_eq!(xs.len(), seq * i_sz);
        debug_assert_eq!(target.len(), od);
        s.ensure(hid, seq);

        // ---- Forward, caching r/z/n/rh and every hidden state. ----
        s.h[..hid].fill(0.0);
        for t in 0..seq {
            let x = &xs[t * i_sz..(t + 1) * i_sz];
            let gx = &mut s.gx;
            gx.copy_from_slice(&packed.b);
            linalg::matvec_acc(i_sz, th, x, &packed.w, th, gx);
            let gh = &mut s.gh;
            gh.fill(0.0);
            linalg::matvec_acc(hid, 2 * hid, &s.h[t * hid..(t + 1) * hid], &packed.u_rz, 2 * hid, gh);
            for j in 0..hid {
                let r = sigmoid(gx[j] + gh[j]);
                s.r[t * hid + j] = r;
                s.z[t * hid + j] = sigmoid(gx[hid + j] + gh[hid + j]);
                s.rh[t * hid + j] = r * s.h[t * hid + j];
            }
            let cand = &mut s.cand;
            cand.fill(0.0);
            for hi in 0..hid {
                let v = s.rh[t * hid + hi];
                if v != 0.0 {
                    linalg::axpy(cand, v, &packed.u_n[hi * hid..(hi + 1) * hid]);
                }
            }
            for j in 0..hid {
                let n = (gx[2 * hid + j] + cand[j]).tanh();
                s.n[t * hid + j] = n;
                let z = s.z[t * hid + j];
                let hp = s.h[t * hid + j];
                s.h[(t + 1) * hid + j] = (1.0 - z) * n + z * hp;
            }
        }

        // ---- Loss + head gradients. ----
        let h_final = &s.h[seq * hid..(seq + 1) * hid];
        let y = self.head(h_final);
        let mut loss = 0.0f64;
        let mut dy = vec![0.0f32; od];
        for k in 0..od {
            let e = y[k] - target[k];
            loss += (e as f64) * (e as f64);
            dy[k] = 2.0 * e / od as f32;
        }
        loss /= od as f64;

        s.dh.fill(0.0);
        for j in 0..hid {
            for k in 0..od {
                dwo[j * od + k] += h_final[j] * dy[k];
                s.dh[j] += self.wo[j * od + k] * dy[k];
            }
        }
        for (b, &d) in dbo.iter_mut().zip(&dy) {
            *b += d;
        }

        // ---- BPTT. ----
        for t in (0..seq).rev() {
            let h_prev = &s.h[t * hid..(t + 1) * hid];
            let r_t = &s.r[t * hid..(t + 1) * hid];
            let z_t = &s.z[t * hid..(t + 1) * hid];
            let n_t = &s.n[t * hid..(t + 1) * hid];
            let rh_t = &s.rh[t * hid..(t + 1) * hid];

            // h = (1-z) n + z h_prev; n = tanh(an).
            for j in 0..hid {
                let dh = s.dh[j];
                s.dn[j] = dh * (1.0 - z_t[j]);
                s.dz[j] = dh * (h_prev[j] - n_t[j]);
                s.dh_prev[j] = dh * z_t[j];
                s.dan[j] = s.dn[j] * (1.0 - n_t[j] * n_t[j]);
            }
            // Candidate recurrent term: weight grads + drh.
            for hi in 0..hid {
                let rv = rh_t[hi];
                linalg::axpy(&mut g.u[hi * th + 2 * hid..(hi + 1) * th], rv, &s.dan);
                s.drh[hi] = linalg::dot(&packed.u_n[hi * hid..(hi + 1) * hid], &s.dan);
            }
            // rh = r ∘ h_prev; gate pre-activations.
            for j in 0..hid {
                s.dr[j] = s.drh[j] * h_prev[j];
                s.dh_prev[j] += s.drh[j] * r_t[j];
                s.dar[j] = s.dr[j] * r_t[j] * (1.0 - r_t[j]);
                s.daz[j] = s.dz[j] * z_t[j] * (1.0 - z_t[j]);
            }
            // Bias gradients.
            linalg::axpy(&mut g.b[..hid], 1.0, &s.dar);
            linalg::axpy(&mut g.b[hid..2 * hid], 1.0, &s.daz);
            linalg::axpy(&mut g.b[2 * hid..], 1.0, &s.dan);
            // Input weight gradients.
            let x = &xs[t * i_sz..(t + 1) * i_sz];
            for (ii, &xv) in x.iter().enumerate() {
                linalg::axpy(&mut g.w[ii * th..ii * th + hid], xv, &s.dar);
                linalg::axpy(&mut g.w[ii * th + hid..ii * th + 2 * hid], xv, &s.daz);
                linalg::axpy(&mut g.w[ii * th + 2 * hid..(ii + 1) * th], xv, &s.dan);
            }
            // Recurrent r/z weight gradients + dh_prev backflow.
            for hi in 0..hid {
                let hv = h_prev[hi];
                linalg::axpy(&mut g.u[hi * th..hi * th + hid], hv, &s.dar);
                linalg::axpy(&mut g.u[hi * th + hid..hi * th + 2 * hid], hv, &s.daz);
                let urow = &packed.u_rz[hi * 2 * hid..(hi + 1) * 2 * hid];
                s.dh_prev[hi] +=
                    linalg::dot(&urow[..hid], &s.dar) + linalg::dot(&urow[hid..], &s.daz);
            }
            std::mem::swap(&mut s.dh, &mut s.dh_prev);
        }
        loss
    }

    /// The original per-step allocating implementation, kept verbatim as
    /// the numerical oracle for equivalence tests and the bench baseline.
    pub fn loss_and_grads_reference(
        &self,
        xs: &[f32],
        seq: usize,
        target: &[f32],
    ) -> (f64, GruGrads, Vec<f32>, Vec<f32>) {
        let p = &self.params;
        let (i_sz, hid, th, od) = (p.input, p.hidden, 3 * p.hidden, self.out_dim);
        let (h_final, caches) = self.forward_cached(xs, seq);
        let y = self.head(&h_final);

        // Loss and dL/dy.
        let mut loss = 0.0f64;
        let mut dy = vec![0.0f32; od];
        for k in 0..od {
            let e = y[k] - target[k];
            loss += (e as f64) * (e as f64);
            dy[k] = 2.0 * e / od as f32;
        }
        loss /= od as f64;

        // Head grads + dL/dh_K.
        let mut dwo = vec![0.0f32; hid * od];
        let dbo = dy.clone();
        let mut dh = vec![0.0f32; hid];
        for j in 0..hid {
            for k in 0..od {
                dwo[j * od + k] = h_final[j] * dy[k];
                dh[j] += self.wo[j * od + k] * dy[k];
            }
        }

        // BPTT.
        let mut g = GruGrads::zeros(p);
        for t in (0..seq).rev() {
            let c = &caches[t];
            // h = (1-z) n + z h_prev
            let mut dn = vec![0.0f32; hid];
            let mut dz = vec![0.0f32; hid];
            let mut dh_prev = vec![0.0f32; hid];
            for j in 0..hid {
                dn[j] = dh[j] * (1.0 - c.z[j]);
                dz[j] = dh[j] * (c.h_prev[j] - c.n[j]);
                dh_prev[j] = dh[j] * c.z[j];
            }
            // n = tanh(an), an = gx_n + rh · Un
            let dan: Vec<f32> = (0..hid).map(|j| dn[j] * (1.0 - c.n[j] * c.n[j])).collect();
            // rh·Un term.
            let mut drh = vec![0.0f32; hid];
            for hi in 0..hid {
                let urow = &p.u[hi * th + 2 * hid..(hi + 1) * th];
                let mut acc = 0.0f32;
                for j in 0..hid {
                    g.u[hi * th + 2 * hid + j] += c.rh[hi] * dan[j];
                    acc += urow[j] * dan[j];
                }
                drh[hi] = acc;
            }
            // rh = r ∘ h_prev
            let mut dr = vec![0.0f32; hid];
            for j in 0..hid {
                dr[j] = drh[j] * c.h_prev[j];
                dh_prev[j] += drh[j] * c.r[j];
            }
            // Gate pre-activations: r = σ(ar), z = σ(az).
            let dar: Vec<f32> = (0..hid).map(|j| dr[j] * c.r[j] * (1.0 - c.r[j])).collect();
            let daz: Vec<f32> = (0..hid).map(|j| dz[j] * c.z[j] * (1.0 - c.z[j])).collect();
            // ar = gx_r + gh_r; az = gx_z + gh_z; an's gx part.
            for j in 0..hid {
                g.b[j] += dar[j];
                g.b[hid + j] += daz[j];
                g.b[2 * hid + j] += dan[j];
            }
            for (ii, &xv) in c.x.iter().enumerate() {
                for j in 0..hid {
                    g.w[ii * th + j] += xv * dar[j];
                    g.w[ii * th + hid + j] += xv * daz[j];
                    g.w[ii * th + 2 * hid + j] += xv * dan[j];
                }
            }
            for hi in 0..hid {
                let hv = c.h_prev[hi];
                let urow = &p.u[hi * th..hi * th + 2 * hid];
                let mut acc = 0.0f32;
                for j in 0..hid {
                    g.u[hi * th + j] += hv * dar[j];
                    g.u[hi * th + hid + j] += hv * daz[j];
                    acc += urow[j] * dar[j] + urow[hid + j] * daz[j];
                }
                dh_prev[hi] += acc;
            }
            dh = dh_prev;
            let _ = i_sz;
        }
        (loss, g, dwo, dbo)
    }

    /// One SGD step on a batch of (sequence, target) pairs; returns the
    /// mean loss before the update. Packs the weights and allocates the
    /// scratch once for the whole batch.
    pub fn sgd_step(&mut self, batch: &[(&[f32], &[f32])], seq: usize, lr: f32) -> f64 {
        let packed = PackedGru::new(&self.params);
        let mut scratch = BpttScratch::new(self.params.hidden, seq);
        let mut g_acc = GruGrads::zeros(&self.params);
        let mut dwo_acc = vec![0.0f32; self.wo.len()];
        let mut dbo_acc = vec![0.0f32; self.bo.len()];
        let mut loss_acc = 0.0f64;
        for (xs, target) in batch {
            loss_acc += self.accumulate_loss_and_grads(
                xs,
                seq,
                target,
                &packed,
                &mut scratch,
                &mut g_acc,
                &mut dwo_acc,
                &mut dbo_acc,
            );
        }
        let scale = lr / batch.len() as f32;
        for (w, g) in self.params.w.iter_mut().zip(&g_acc.w) {
            *w -= scale * g;
        }
        for (u, g) in self.params.u.iter_mut().zip(&g_acc.u) {
            *u -= scale * g;
        }
        for (b, g) in self.params.b.iter_mut().zip(&g_acc.b) {
            *b -= scale * g;
        }
        for (w, g) in self.wo.iter_mut().zip(&dwo_acc) {
            *w -= scale * g;
        }
        for (b, g) in self.bo.iter_mut().zip(&dbo_acc) {
            *b -= scale * g;
        }
        loss_acc / batch.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(seed: u64) -> (GruBptt, Vec<f32>, Vec<f32>) {
        let mut rng = Prng::new(seed);
        let params = GruParams::random(2, 6, &mut rng, 0.4);
        let net = GruBptt::new(params, 2, &mut rng);
        let xs = rng.normal_vec_f32(5 * 2, 0.8);
        let target = rng.normal_vec_f32(2, 0.5);
        (net, xs, target)
    }

    /// Central-difference gradient check on every parameter class.
    #[test]
    fn gradients_match_finite_differences() {
        let (net, xs, target) = setup(3);
        let (_, g, dwo, dbo) = net.loss_and_grads(&xs, 5, &target);
        let eps = 1e-3f32;
        let loss_with = |mutator: &dyn Fn(&mut GruBptt)| -> f64 {
            let mut n2 = GruBptt {
                params: net.params.clone(),
                wo: net.wo.clone(),
                bo: net.bo.clone(),
                out_dim: net.out_dim,
            };
            mutator(&mut n2);
            n2.loss_and_grads(&xs, 5, &target).0
        };
        // Sample a few indices from each tensor.
        for idx in [0usize, 7, 17, 30] {
            let plus = loss_with(&|n| n.params.w[idx] += eps);
            let minus = loss_with(&|n| n.params.w[idx] -= eps);
            let fd = (plus - minus) / (2.0 * eps as f64);
            assert!(
                (fd - g.w[idx] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "dW[{idx}]: fd={fd} bp={}",
                g.w[idx]
            );
        }
        for idx in [0usize, 19, 53, 101] {
            let plus = loss_with(&|n| n.params.u[idx] += eps);
            let minus = loss_with(&|n| n.params.u[idx] -= eps);
            let fd = (plus - minus) / (2.0 * eps as f64);
            assert!(
                (fd - g.u[idx] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "dU[{idx}]: fd={fd} bp={}",
                g.u[idx]
            );
        }
        for idx in [0usize, 6, 13] {
            let plus = loss_with(&|n| n.params.b[idx] += eps);
            let minus = loss_with(&|n| n.params.b[idx] -= eps);
            let fd = (plus - minus) / (2.0 * eps as f64);
            assert!(
                (fd - g.b[idx] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "db[{idx}]: fd={fd} bp={}",
                g.b[idx]
            );
        }
        for idx in [0usize, 5, 11] {
            let plus = loss_with(&|n| n.wo[idx] += eps);
            let minus = loss_with(&|n| n.wo[idx] -= eps);
            let fd = (plus - minus) / (2.0 * eps as f64);
            assert!(
                (fd - dwo[idx] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "dWo[{idx}]: fd={fd} bp={}",
                dwo[idx]
            );
        }
        let plus = loss_with(&|n| n.bo[1] += eps);
        let minus = loss_with(&|n| n.bo[1] -= eps);
        let fd = (plus - minus) / (2.0 * eps as f64);
        assert!((fd - dbo[1] as f64).abs() < 2e-3 * (1.0 + fd.abs()));
    }

    /// The optimized path must agree with the reference oracle.
    #[test]
    fn optimized_matches_reference() {
        let mut rng = Prng::new(21);
        let params = GruParams::random(3, 10, &mut rng, 0.4);
        let net = GruBptt::new(params, 3, &mut rng);
        let xs = rng.normal_vec_f32(12 * 3, 0.8);
        let target = rng.normal_vec_f32(3, 0.5);
        let (l_opt, g_opt, dwo_opt, dbo_opt) = net.loss_and_grads(&xs, 12, &target);
        let (l_ref, g_ref, dwo_ref, dbo_ref) = net.loss_and_grads_reference(&xs, 12, &target);
        assert!((l_opt - l_ref).abs() <= 1e-6 * (1.0 + l_ref.abs()));
        let close = |a: &[f32], b: &[f32], what: &str| {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-6 * (1.0 + y.abs()),
                    "{what}[{i}]: {x} vs {y}"
                );
            }
        };
        close(&g_opt.w, &g_ref.w, "dW");
        close(&g_opt.u, &g_ref.u, "dU");
        close(&g_opt.b, &g_ref.b, "db");
        close(&dwo_opt, &dwo_ref, "dWo");
        close(&dbo_opt, &dbo_ref, "dbo");
    }

    /// SGD on a learnable toy task: predict the mean of the inputs.
    #[test]
    fn sgd_learns_sequence_mean() {
        let mut rng = Prng::new(7);
        let params = GruParams::random(1, 8, &mut rng, 0.3);
        let mut net = GruBptt::new(params, 1, &mut rng);
        let seq = 6;
        // Fixed dataset.
        let data: Vec<(Vec<f32>, Vec<f32>)> = (0..16)
            .map(|_| {
                let xs = rng.normal_vec_f32(seq, 0.7);
                let mean = xs.iter().sum::<f32>() / seq as f32;
                (xs, vec![mean])
            })
            .collect();
        let batch: Vec<(&[f32], &[f32])> = data
            .iter()
            .map(|(x, t)| (x.as_slice(), t.as_slice()))
            .collect();
        let first = net.sgd_step(&batch, seq, 0.2);
        let mut last = first;
        for _ in 0..150 {
            last = net.sgd_step(&batch, seq, 0.2);
        }
        assert!(
            last < first * 0.2,
            "BPTT training failed: {first} -> {last}"
        );
    }

    #[test]
    fn grads_zero_for_zero_error() {
        // Target = prediction → loss 0 and all-zero gradients.
        let (net, xs, _) = setup(11);
        let (h, _) = net.forward_cached(&xs, 5);
        let y = net.head(&h);
        let (loss, g, dwo, dbo) = net.loss_and_grads(&xs, 5, &y);
        assert!(loss < 1e-12);
        assert!(g.norm_sq() < 1e-12);
        assert!(dwo.iter().all(|v| v.abs() < 1e-6));
        assert!(dbo.iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn longer_sequences_accumulate_gradient() {
        let (net, _, target) = setup(13);
        let mut rng = Prng::new(14);
        let xs = rng.normal_vec_f32(20 * 2, 0.8);
        let (_, g5, _, _) = net.loss_and_grads(&xs[..5 * 2], 5, &target);
        let (_, g20, _, _) = net.loss_and_grads(&xs, 20, &target);
        // Not a strict law, but with these scales BPTT over 20 steps
        // should not produce an identically-shaped gradient.
        assert_ne!(g5.w, g20.w);
    }
}

//! Liquid-time-constant cell (the paper's baseline workload).
//!
//! LTC networks (Hasani et al.) advance the hidden state with a fused
//! implicit-Euler solver: each time step runs `unfold` sequential solver
//! sub-steps of
//!
//! `h ← (h + dt · f(x,h) ∘ A) / (1 + dt · (1/τ + f(x,h)))`,
//!
//! `f = σ(Wx + Uh + b)`. The sub-step chain is the sequential dependency
//! MERINDA eliminates; Tables 1/2 profile exactly this loop.

use crate::util::Prng;

use super::gru::sigmoid;
use super::linalg;

/// LTC parameters (row-major matrices).
#[derive(Clone, Debug)]
pub struct LtcParams {
    pub input: usize,
    pub hidden: usize,
    /// (I, H) input weights.
    pub wf: Vec<f32>,
    /// (H, H) recurrent weights.
    pub uf: Vec<f32>,
    /// (H,) bias.
    pub bf: Vec<f32>,
    /// (H,) asymptote vector A.
    pub a: Vec<f32>,
    /// (H,) time constants τ (positive).
    pub tau: Vec<f32>,
}

impl LtcParams {
    pub fn random(input: usize, hidden: usize, rng: &mut Prng, std: f64) -> LtcParams {
        LtcParams {
            input,
            hidden,
            wf: rng.normal_vec_f32(input * hidden, std),
            uf: rng.normal_vec_f32(hidden * hidden, std),
            bf: rng.normal_vec_f32(hidden, std * 0.3),
            a: rng.normal_vec_f32(hidden, 1.0),
            tau: (0..hidden)
                .map(|_| 0.5 + rng.uniform_f32(0.0, 1.5))
                .collect(),
        }
    }
}

/// Timing breakdown of one forward pass (drives Tables 1/2).
#[derive(Clone, Copy, Debug, Default)]
pub struct LtcProfile {
    /// Seconds in input/sensory preprocessing.
    pub sensory_s: f64,
    /// Seconds in the ODE solver loop in total.
    pub solver_s: f64,
    /// Per-solver-step component seconds.
    pub recurrent_sigmoid_s: f64,
    pub weight_activation_s: f64,
    pub reversal_activation_s: f64,
    pub sum_ops_s: f64,
    pub euler_update_s: f64,
    pub steps: u64,
}

/// Reusable scratch for [`LtcCell::sub_step_into`].
#[derive(Clone, Debug)]
pub struct LtcScratch {
    pre: Vec<f32>,
}

impl LtcScratch {
    pub fn new(hidden: usize) -> LtcScratch {
        LtcScratch {
            pre: vec![0.0; hidden],
        }
    }
}

/// An LTC cell with a fixed solver unfolding depth.
#[derive(Clone, Debug)]
pub struct LtcCell {
    pub params: LtcParams,
    pub unfold: usize,
}

impl LtcCell {
    pub fn new(params: LtcParams, unfold: usize) -> LtcCell {
        LtcCell { params, unfold }
    }

    /// One time step (all solver sub-steps).
    pub fn step(&self, x: &[f32], h: &[f32], dt: f32) -> Vec<f32> {
        let hid = self.params.hidden;
        let mut s = LtcScratch::new(hid);
        let mut h = h.to_vec();
        let mut next = vec![0.0f32; hid];
        for _ in 0..self.unfold {
            self.sub_step_into(x, &h, dt, &mut next, &mut s);
            std::mem::swap(&mut h, &mut next);
        }
        h
    }

    /// One fused-solver sub-step (allocating wrapper).
    pub fn sub_step(&self, x: &[f32], h: &[f32], dt: f32) -> Vec<f32> {
        let mut s = LtcScratch::new(self.params.hidden);
        let mut out = vec![0.0f32; self.params.hidden];
        self.sub_step_into(x, h, dt, &mut out, &mut s);
        out
    }

    /// One fused-solver sub-step into a caller-provided buffer with reused
    /// scratch (§Perf: the per-sub-step allocations dominated `run` on
    /// long traces; matvecs go through the shared `linalg` kernels).
    pub fn sub_step_into(&self, x: &[f32], h: &[f32], dt: f32, out: &mut [f32], s: &mut LtcScratch) {
        let p = &self.params;
        let hid = p.hidden;
        debug_assert_eq!(h.len(), hid);
        debug_assert_eq!(out.len(), hid);
        let pre = &mut s.pre;
        pre.copy_from_slice(&p.bf);
        linalg::matvec_acc(x.len(), hid, x, &p.wf, hid, pre);
        linalg::matvec_acc(hid, hid, h, &p.uf, hid, pre);
        for j in 0..hid {
            let f = sigmoid(pre[j]);
            out[j] = (h[j] + dt * f * p.a[j]) / (1.0 + dt * (1.0 / p.tau[j] + f));
        }
    }

    /// Run a sequence (K, I) returning the final hidden state.
    pub fn run(&self, xs: &[f32], seq: usize, dt: f32) -> Vec<f32> {
        let hid = self.params.hidden;
        let i_sz = self.params.input;
        let mut s = LtcScratch::new(hid);
        let mut h = vec![0.0f32; hid];
        let mut next = vec![0.0f32; hid];
        for t in 0..seq {
            let x = &xs[t * i_sz..(t + 1) * i_sz];
            for _ in 0..self.unfold {
                self.sub_step_into(x, &h, dt, &mut next, &mut s);
                std::mem::swap(&mut h, &mut next);
            }
        }
        h
    }

    /// Instrumented forward pass: times each component for Tables 1/2.
    ///
    /// "Sensory processing" is the input affine (Wx); within a solver step
    /// we time the recurrent+sigmoid evaluation, the weighted/reversal
    /// activation products (f·A and 1/τ terms), the summations and the
    /// fused Euler update, matching the paper's row labels.
    pub fn profile(&self, xs: &[f32], seq: usize, dt: f32) -> LtcProfile {
        use std::time::Instant;
        let p = &self.params;
        let hid = p.hidden;
        let mut prof = LtcProfile::default();
        let mut h = vec![0.0f32; hid];

        for t in 0..seq {
            let x = &xs[t * p.input..(t + 1) * p.input];

            // Sensory processing: input affine, computed once per step.
            let t0 = Instant::now();
            let mut sensory = p.bf.clone();
            for (i, &xv) in x.iter().enumerate() {
                let row = &p.wf[i * hid..(i + 1) * hid];
                for (s, &w) in sensory.iter_mut().zip(row) {
                    *s += xv * w;
                }
            }
            prof.sensory_s += t0.elapsed().as_secs_f64();

            let solver0 = Instant::now();
            for _ in 0..self.unfold {
                // Recurrent + sigmoid.
                let t1 = Instant::now();
                let mut pre = sensory.clone();
                for (i, &hv) in h.iter().enumerate() {
                    let row = &p.uf[i * hid..(i + 1) * hid];
                    for (s, &u) in pre.iter_mut().zip(row) {
                        *s += hv * u;
                    }
                }
                let f: Vec<f32> = pre.iter().map(|&v| sigmoid(v)).collect();
                prof.recurrent_sigmoid_s += t1.elapsed().as_secs_f64();

                // Weight activation: f ∘ A.
                let t2 = Instant::now();
                let fa: Vec<f32> = f.iter().zip(&p.a).map(|(&fv, &av)| fv * av).collect();
                prof.weight_activation_s += t2.elapsed().as_secs_f64();

                // Reversal activation: 1/τ + f (the decay path).
                let t3 = Instant::now();
                let rev: Vec<f32> = f
                    .iter()
                    .zip(&p.tau)
                    .map(|(&fv, &tv)| 1.0 / tv + fv)
                    .collect();
                prof.reversal_activation_s += t3.elapsed().as_secs_f64();

                // Sum operations: numerator/denominator assembly.
                let t4 = Instant::now();
                let num: Vec<f32> = h.iter().zip(&fa).map(|(&hv, &w)| hv + dt * w).collect();
                let den: Vec<f32> = rev.iter().map(|&r| 1.0 + dt * r).collect();
                prof.sum_ops_s += t4.elapsed().as_secs_f64();

                // Euler update: the divide + state write.
                let t5 = Instant::now();
                for j in 0..hid {
                    h[j] = num[j] / den[j];
                }
                prof.euler_update_s += t5.elapsed().as_secs_f64();
                prof.steps += 1;
            }
            prof.solver_s += solver0.elapsed().as_secs_f64();
        }
        prof
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(seed: u64) -> LtcCell {
        let mut rng = Prng::new(seed);
        LtcCell::new(LtcParams::random(4, 16, &mut rng, 0.3), 6)
    }

    #[test]
    fn state_remains_finite() {
        let c = cell(1);
        let mut rng = Prng::new(2);
        let xs = rng.normal_vec_f32(100 * 4, 2.0);
        let h = c.run(&xs, 100, 0.1);
        assert!(h.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fused_solver_contracts_toward_asymptote() {
        // With f ≈ 1 (large positive bias) and A = const, h converges —
        // check a fixed point is reached.
        let mut p = LtcParams::random(1, 4, &mut Prng::new(3), 0.0);
        p.bf = vec![10.0; 4];
        p.a = vec![2.0; 4];
        p.tau = vec![1.0; 4];
        let c = LtcCell::new(p, 6);
        let mut h = vec![0.0f32; 4];
        for _ in 0..200 {
            h = c.step(&[0.0], &h, 0.1);
        }
        let h2 = c.step(&[0.0], &h, 0.1);
        for (a, b) in h.iter().zip(&h2) {
            assert!((a - b).abs() < 1e-4, "not converged: {a} vs {b}");
        }
    }

    #[test]
    fn unfold_matches_manual_substeps() {
        let c = cell(5);
        let x = vec![0.1f32, -0.2, 0.3, 0.0];
        let h0 = vec![0.05f32; 16];
        let stepped = c.step(&x, &h0, 0.1);
        let mut manual = h0;
        for _ in 0..6 {
            manual = c.sub_step(&x, &manual, 0.1);
        }
        assert_eq!(stepped, manual);
    }

    #[test]
    fn profile_solver_dominates() {
        // Paper Table 1: ODE solver ≈ 87.7% of forward-pass time. With 6
        // unfolded sub-steps each containing the recurrent matvec, the
        // solver share must dominate the single sensory affine.
        let c = cell(7);
        let mut rng = Prng::new(8);
        let xs = rng.normal_vec_f32(64 * 4, 1.0);
        let p = c.profile(&xs, 64, 0.1);
        let total = p.sensory_s + p.solver_s;
        assert!(p.solver_s / total > 0.6, "solver share {}", p.solver_s / total);
        assert_eq!(p.steps, 64 * 6);
    }

    #[test]
    fn profile_sigmoid_is_top_substep_cost() {
        // Paper Table 2: recurrent sigmoid 46.7% — the biggest component.
        let c = cell(9);
        let mut rng = Prng::new(10);
        let xs = rng.normal_vec_f32(128 * 4, 1.0);
        let p = c.profile(&xs, 128, 0.1);
        assert!(p.recurrent_sigmoid_s > p.weight_activation_s);
        assert!(p.recurrent_sigmoid_s > p.reversal_activation_s);
        assert!(p.recurrent_sigmoid_s > p.euler_update_s);
    }
}

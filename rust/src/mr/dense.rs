//! Dense head: the MLP that maps GRU hidden states to coefficient
//! estimates (paper §4), plus the sparsity-driven pruning MERINDA adds on
//! top of the neural-flow architecture ("further pruning the dense layer",
//! §3.1).

use crate::util::Prng;

/// A two-layer ReLU MLP head matching the L2 `_dense_head`.
#[derive(Clone, Debug)]
pub struct DenseHead {
    pub input: usize,
    pub hidden: usize,
    pub output: usize,
    /// (input, hidden) row-major.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// (hidden, output) row-major.
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    /// Optional output mask from structural pruning (None = dense).
    pub mask: Option<Vec<bool>>,
}

impl DenseHead {
    pub fn random(input: usize, hidden: usize, output: usize, rng: &mut Prng) -> DenseHead {
        let s1 = 1.0 / (input as f64).sqrt();
        let s2 = 1.0 / (hidden as f64).sqrt();
        DenseHead {
            input,
            hidden,
            output,
            w1: rng.normal_vec_f32(input * hidden, s1),
            b1: vec![0.0; hidden],
            w2: rng.normal_vec_f32(hidden * output, s2),
            b2: vec![0.0; output],
            mask: None,
        }
    }

    /// Forward: h (input,) → theta (output,). ReLU between layers; masked
    /// outputs are forced to exactly zero (pruned library terms).
    pub fn forward(&self, h: &[f32]) -> Vec<f32> {
        debug_assert_eq!(h.len(), self.input);
        let mut z = self.b1.clone();
        for (i, &hv) in h.iter().enumerate() {
            let row = &self.w1[i * self.hidden..(i + 1) * self.hidden];
            for (zv, &w) in z.iter_mut().zip(row) {
                *zv += hv * w;
            }
        }
        for v in z.iter_mut() {
            *v = v.max(0.0); // ReLU
        }
        let mut out = self.b2.clone();
        for (j, &zv) in z.iter().enumerate() {
            if zv != 0.0 {
                let row = &self.w2[j * self.output..(j + 1) * self.output];
                for (ov, &w) in out.iter_mut().zip(row) {
                    *ov += zv * w;
                }
            }
        }
        if let Some(mask) = &self.mask {
            for (o, &keep) in out.iter_mut().zip(mask) {
                if !keep {
                    *o = 0.0;
                }
            }
        }
        out
    }

    /// MERINDA's sparsity-exploiting pruning: keep only the `keep` largest
    /// |output| units measured over a calibration batch — the paper's
    /// "dropout rate of |Θ|" that leaves exactly the active terms.
    pub fn prune_to_top(&mut self, calib_outputs: &[Vec<f32>], keep: usize) {
        let mut mag = vec![0.0f64; self.output];
        for out in calib_outputs {
            for (m, &v) in mag.iter_mut().zip(out) {
                *m += (v as f64).abs();
            }
        }
        let mut idx: Vec<usize> = (0..self.output).collect();
        idx.sort_by(|&a, &b| mag[b].partial_cmp(&mag[a]).unwrap());
        let mut mask = vec![false; self.output];
        for &i in idx.iter().take(keep) {
            mask[i] = true;
        }
        self.mask = Some(mask);
    }

    /// Fraction of outputs pruned away.
    pub fn sparsity(&self) -> f64 {
        match &self.mask {
            None => 0.0,
            Some(m) => m.iter().filter(|&&k| !k).count() as f64 / m.len() as f64,
        }
    }

    /// Multiply–accumulate count for one forward pass (for the FPGA cost
    /// model): pruned outputs cost nothing.
    pub fn macs(&self) -> u64 {
        let active_out = match &self.mask {
            None => self.output,
            Some(m) => m.iter().filter(|&&k| k).count(),
        };
        (self.input * self.hidden + self.hidden * active_out) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head(seed: u64) -> DenseHead {
        DenseHead::random(8, 16, 10, &mut Prng::new(seed))
    }

    #[test]
    fn forward_shape_and_determinism() {
        let d = head(1);
        let h = vec![0.5f32; 8];
        let a = d.forward(&h);
        assert_eq!(a.len(), 10);
        assert_eq!(a, d.forward(&h));
    }

    #[test]
    fn relu_blocks_negative_path() {
        // With large negative b1, layer-1 output is all zero → out = b2.
        let mut d = head(2);
        d.b1 = vec![-1e6; d.hidden];
        let out = d.forward(&vec![0.1; 8]);
        assert_eq!(out, d.b2);
    }

    #[test]
    fn pruning_zeroes_small_outputs() {
        let mut d = head(3);
        let calib: Vec<Vec<f32>> = (0..4)
            .map(|i| d.forward(&vec![0.1 * (i as f32 + 1.0); 8]))
            .collect();
        d.prune_to_top(&calib, 4);
        assert!((d.sparsity() - 0.6).abs() < 1e-9);
        let out = d.forward(&vec![0.3; 8]);
        assert_eq!(out.iter().filter(|v| **v != 0.0).count() <= 4, true);
    }

    #[test]
    fn pruning_reduces_macs() {
        let mut d = head(4);
        let full = d.macs();
        let calib = vec![d.forward(&vec![0.2; 8])];
        d.prune_to_top(&calib, 3);
        assert!(d.macs() < full);
    }

    #[test]
    fn kept_outputs_unchanged_by_mask() {
        let mut d = head(5);
        let h = vec![0.25f32; 8];
        let dense_out = d.forward(&h);
        let calib = vec![dense_out.clone()];
        d.prune_to_top(&calib, 10); // keep all
        assert_eq!(d.forward(&h), dense_out);
    }
}

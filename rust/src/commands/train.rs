//! `merinda train --system S --steps N` — PJRT neural-flow training run.

use merinda::mr::train::{PjrtTrainer, TrainOpts};
use merinda::runtime::Runtime;
use merinda::util::cli::Args;
use merinda::util::{Prng, Result};

use super::recover::system_by_name;

pub fn run(args: &Args) -> Result<()> {
    let sys = system_by_name(&args.get_or("system", "aid"))?;
    let steps = args.get_usize("steps", 300);
    let samples = args.get_usize("samples", 1000);
    let dt = args.get_f64("dt", if sys.name() == "AID" { 5.0 } else { 0.01 });
    let seed = args.get_u64("seed", 42);
    let lr = args.get_f64("lr", 3e-3) as f32;

    let rt = Runtime::new(args.get_or("artifacts", "artifacts"))?;
    println!("platform={} system={} steps={steps}", rt.platform(), sys.name());

    let mut rng = Prng::new(seed);
    let tr = sys.generate(samples, dt, &mut rng);
    let dims = rt.manifest.dims.clone();
    let (y, u) = tr.padded_f32(dims.xdim, dims.udim);
    let scale: f32 = y.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let y: Vec<f32> = y.iter().map(|v| v / scale).collect();

    let mut trainer = PjrtTrainer::new(&rt, seed)?;
    println!("params: {}", trainer.state.param_count());
    let report = trainer.train(
        &y,
        &u,
        TrainOpts {
            steps,
            lr,
            seed,
            log_every: (steps / 20).max(1),
            ..Default::default()
        },
    )?;
    println!("\nloss curve:");
    for (s, l) in &report.losses {
        println!("  step {s:>5}  loss {l:.6}");
    }
    println!(
        "\nfinal loss {:.6} after {} steps in {:.1}s ({:.1} ms/step)",
        report.final_loss,
        report.steps,
        report.wall_s,
        1e3 * report.wall_s / report.steps as f64
    );
    Ok(())
}

//! `merinda experiments` — the parse-or-execute paper-results runner.
//!
//! Regenerates every paper table/figure from the per-experiment JSON
//! logs under `experiments/`, executing only entries whose logs are
//! missing or stale, then writes the aggregated CI-gated
//! `BENCH_experiments.json`. See EXPERIMENTS.md §Paper results for the
//! table→command index.
//!
//! Flags:
//!   --only <ids>    comma-separated registry ids (e.g. table4,fig8)
//!   --execute       parse-or-execute (the default, named explicitly)
//!   --parse-only    never execute; missing/stale logs are an error
//!   --force         re-execute everything, rewriting the logs
//!   --logdir <dir>  log directory (default: experiments/ at repo root)
//!   --out <file>    report path (default: BENCH_experiments.json)
//!   --artifacts <d> PJRT artifact dir probed by the table6 entry

use merinda::report::runner::{ExecCtx, Mode, Runner, Source};
use merinda::util::bench::artifact_path;
use merinda::util::cli::Args;
use merinda::util::{Error, Result};

pub fn run(args: &Args) -> Result<()> {
    let mode = match (args.flag("force"), args.flag("parse-only")) {
        (true, true) => {
            return Err(Error::config("--force and --parse-only are mutually exclusive"))
        }
        (true, false) => Mode::Force,
        (false, true) => Mode::ParseOnly,
        // --execute is the default mode's explicit name; accept it as a
        // no-op so invocations read naturally.
        (false, false) => Mode::ParseOrExecute,
    };

    let ctx = ExecCtx {
        artifact_dir: args.get_or("artifacts", "artifacts"),
        ..Default::default()
    };
    let log_dir = match args.get("logdir") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => artifact_path("experiments"),
    };
    let runner = Runner::with_ctx(&log_dir, ctx);

    let all_ids = Runner::ids();
    let selected: Vec<String> = match args.get("only") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => all_ids.iter().map(|s| s.to_string()).collect(),
    };
    let ids: Vec<&str> = selected.iter().map(String::as_str).collect();
    for id in &ids {
        Runner::entry(id)?; // fail fast on typos before any execution
    }

    println!(
        "experiments runner: {} entr{} | mode {:?} | logs {}",
        ids.len(),
        if ids.len() == 1 { "y" } else { "ies" },
        mode,
        runner.log_dir().display()
    );

    let outcomes = runner.run(&ids, mode)?;
    for out in &outcomes {
        let anchor = Runner::entry(&out.record.id)?.anchor;
        println!("\n[{}] {} — {}", out.source, out.record.id, anchor);
        println!("{}", out.record.table().to_text());
        if let Some(chart) = &out.record.chart {
            println!("{chart}");
        }
        for c in &out.record.comparisons {
            let gate = if !c.gated {
                "info     "
            } else if c.within_band() {
                "gate ok  "
            } else {
                "GATE FAIL"
            };
            println!(
                "  {gate} {:<34} ours {:>12.4}  paper {:>10.4}  ratio {:.3}",
                c.metric,
                c.ours,
                c.paper,
                c.ratio()
            );
        }
        for n in &out.record.notes {
            println!("  note: {n}");
        }
    }

    let executed = outcomes.iter().filter(|o| o.source == Source::Executed).count();
    println!(
        "\n{} regenerated: {} executed, {} parsed from committed logs",
        outcomes.len(),
        executed,
        outcomes.len() - executed
    );

    let report = Runner::bench_report(&outcomes);
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => artifact_path("BENCH_experiments.json"),
    };
    report.write(&out_path)?;
    println!("wrote {}", out_path.display());

    if outcomes.iter().any(|o| !o.record.gated_ok()) {
        return Err(Error::numeric(
            "one or more gated paper comparisons left their tolerance band",
        ));
    }
    Ok(())
}

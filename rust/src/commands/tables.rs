//! `merinda table <N>` and `merinda info`.

use merinda::report::experiments as exp;
use merinda::runtime::Runtime;
use merinda::util::cli::Args;
use merinda::util::{Error, Result};

fn artifact_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts")
}

pub fn info(args: &Args) -> Result<()> {
    let rt = Runtime::new(artifact_dir(args))?;
    println!("platform: {}", rt.platform());
    let d = &rt.manifest.dims;
    println!(
        "model dims: xdim={} udim={} plib={} hid={} dense={} batch={} seq={}",
        d.xdim, d.udim, d.plib, d.hid, d.dense, d.batch, d.seq
    );
    println!("artifact entries:");
    for e in &rt.manifest.entries {
        println!(
            "  {:<22} args={:<3} outputs={}",
            e.name,
            e.args.len(),
            e.outputs
        );
    }
    Ok(())
}

pub fn run(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .ok_or_else(|| Error::config("usage: merinda table <1|2|3|4|5|6|7|8|fig8|all>"))?
        .as_str();
    let print = |t: merinda::report::Table| {
        println!("{}", t.to_text());
    };
    match which {
        "1" => print(exp::table1()),
        "2" => print(exp::table2()),
        "3" => print(exp::table3()),
        "4" => print(exp::table4()?),
        "5" => print(exp::table5()?),
        "6" => {
            let rt = Runtime::new(artifact_dir(args))?;
            let opts = exp::Table6Opts {
                merinda_steps: args.get_usize("steps", 120),
                seed: args.get_u64("seed", 23),
                ..Default::default()
            };
            print(exp::table6(&rt, opts)?);
        }
        "7" => print(exp::table7()),
        "8" => print(exp::table8()),
        "fig8" => println!("{}", exp::fig8()),
        "all" => {
            print(exp::table1());
            print(exp::table2());
            print(exp::table3());
            print(exp::table4()?);
            print(exp::table5()?);
            print(exp::table7());
            print(exp::table8());
            println!("{}", exp::fig8());
            println!("(table 6 skipped in 'all' — run `merinda table 6` for the trained comparison)");
        }
        other => return Err(Error::config(format!("unknown table {other:?}"))),
    }
    Ok(())
}

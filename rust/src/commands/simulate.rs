//! `merinda simulate --config C` — FPGA accelerator structural report.

use merinda::fpga::gru_accel::{GruAccel, GruAccelConfig};
use merinda::fpga::ltc_accel::{LtcAccel, LtcAccelConfig};
use merinda::fpga::resources::Device;
use merinda::util::cli::Args;
use merinda::util::{Error, Result};

pub fn run(args: &Args) -> Result<()> {
    let config = args.get_or("config", "concurrent");
    let device = Device::pynq_z2();

    if config == "ltc" {
        let r = LtcAccel::new(LtcAccelConfig::base()).report();
        println!("LTC (ODE) accelerator:");
        println!("  cycles/item      {}", r.cycles);
        println!("  interval         {}", r.interval);
        println!("  resources        {}", r.resources);
        println!("  power            {:.3} W", r.power_w);
        println!("  energy/output    {:.3e} J", r.energy_per_output_j);
        println!(
            "  throughput       {:.0} items/s @ {} MHz",
            device.clock_mhz * 1e6 / r.interval as f64,
            device.clock_mhz
        );
        return Ok(());
    }

    let cfg = match config.as_str() {
        "baseline" => GruAccelConfig::gru_baseline(),
        "concurrent" => GruAccelConfig::concurrent(),
        "bram" | "bram-optimal" => GruAccelConfig::bram_optimal(),
        other => {
            return Err(Error::config(format!(
                "unknown config {other:?} (ltc|baseline|concurrent|bram)"
            )))
        }
    };
    let accel = GruAccel::new(cfg);
    let r = accel.report();
    println!("GRU accelerator [{config}]:");
    println!("  unroll={} banks={} dataflow={}", accel.cfg.unroll, accel.cfg.banks, accel.cfg.dataflow);
    println!("  stage map        {}", r.name);
    println!("  cycles/item      {}", r.cycles);
    println!("  interval         {} (worst stage II={})", r.interval, r.worst_stage_ii);
    println!("  resources        {}", r.resources);
    println!(
        "  fits PYNQ-Z2     {} (utilization {:.1}%)",
        r.fits_pynq,
        100.0 * device.utilization(&r.resources)
    );
    println!("  power            {:.3} W", r.power_w);
    println!("  energy/output    {:.3e} J", r.energy_per_output_j);
    println!(
        "  throughput       {:.0} items/s @ {} MHz",
        device.clock_mhz * 1e6 / r.interval as f64,
        device.clock_mhz
    );
    // Stage detail.
    println!("\n  per-stage schedule:");
    for s in accel.stages() {
        println!(
            "    {:<16} II={} depth={} cycles={} {}{}",
            s.name,
            s.ii,
            s.depth,
            s.cycles,
            s.resources,
            s.bottleneck
                .as_deref()
                .map(|b| format!("  [bound by {b}]"))
                .unwrap_or_default()
        );
    }
    Ok(())
}

//! `merinda partition` — multi-board graph partitioning report.
//!
//! Runs `fpga::partition::best_partition` over three representative
//! designs on a two-slot PYNQ-Z2 rack (10 GbE between boards): a serving
//! GRU that fits one board (the never-worse row — the sweep must keep
//! the whole-graph plan), an oversized GRU whose gate/candidate weight
//! tiles blow one board's BRAM, and an oversized SINDy head. For each
//! design the whole-graph single-board plan is computed through the
//! *same* `partition` code path (zero cuts), so the whole-vs-split
//! comparison is cycle-model-exact by construction. Writes
//! `BENCH_partition.json` at the repo root — deterministic and
//! machine-independent, gated in CI by `ci/check_bench_partition.py`
//! (every oversized design must become feasible split, end-to-end
//! cycles must dominate every member's, and designs that fit whole must
//! never choose a slower split).

use std::collections::BTreeMap;

use merinda::fpga::fixedpoint::FixedFormat;
use merinda::fpga::graph::Graph;
use merinda::fpga::gru_accel::GruAccelConfig;
use merinda::fpga::partition::{
    best_partition, partition, pynq_rack, BoardSlot, LinkHop, PartitionedPlan,
};
use merinda::fpga::sindy_accel::SindyAccelConfig;
use merinda::util::bench::{artifact_path, BenchJson};
use merinda::util::cli::Args;
use merinda::util::json::Json;
use merinda::util::{Error, Result};

/// The canonical partitioning workload: two identical PYNQ-Z2 slots.
const RACK_SLOTS: usize = 2;

fn hop_json(h: &LinkHop) -> Json {
    Json::obj(vec![
        ("from_part", Json::num(h.from_part as f64)),
        ("to_part", Json::num(h.to_part as f64)),
        ("from_op", Json::num(h.from_op as f64)),
        ("to_op", Json::num(h.to_op as f64)),
        ("elems", Json::num(h.elems as f64)),
        ("bytes_per_item", Json::num(h.bytes_per_item as f64)),
        ("serialize_s", Json::num(h.serialize_s())),
        ("latency_s", Json::num(h.link.latency_s)),
    ])
}

fn plan_json(plan: &PartitionedPlan, window: u64) -> Json {
    let parts: Vec<Json> = plan
        .parts
        .iter()
        .map(|p| {
            let r = p.resources();
            Json::obj(vec![
                ("board", Json::str(p.board.clone())),
                ("ops", Json::Arr(p.ops.iter().map(|&i| Json::num(i as f64)).collect())),
                ("window_cycles", Json::num(p.lowered.window_cycles(window) as f64)),
                ("interval_cycles", Json::num(p.lowered.interval as f64)),
                ("lut", Json::num(r.lut as f64)),
                ("ff", Json::num(r.ff as f64)),
                ("dsp", Json::num(r.dsp as f64)),
                ("bram18", Json::num(r.bram18 as f64)),
                ("fits", Json::Bool(p.fits())),
                ("clock_ok", Json::Bool(p.clock_ok())),
            ])
        })
        .collect();
    let hops: Vec<Json> = plan.hops.iter().map(hop_json).collect();
    Json::obj(vec![
        ("n_parts", Json::num(plan.n_parts() as f64)),
        ("feasible", Json::Bool(plan.feasible())),
        ("parts", Json::Arr(parts)),
        ("hops", Json::Arr(hops)),
        (
            "end_to_end",
            Json::obj(vec![
                ("window_cycles", Json::num(plan.window_cycles(window) as f64)),
                ("interval_cycles", Json::num(plan.interval_cycles() as f64)),
                ("fill_s", Json::num(plan.fill_s())),
                ("interval_s", Json::num(plan.interval_s())),
                ("window_s", Json::num(plan.window_s(window))),
                ("reference_clock_mhz", Json::num(plan.reference_clock_mhz())),
            ]),
        ),
    ])
}

/// One design's whole-vs-split row. The whole-graph plan goes through
/// `partition` with zero cuts (same code path, cycle-exact vs `lower`).
fn design_json(g: &Graph, slots: &[BoardSlot], window: u64) -> Result<(Json, bool, bool)> {
    let whole = partition(g, &[], &slots[..1])?;
    let out = best_partition(g, slots, window)?;
    let split_chosen = out.plan.n_parts() > 1;
    let chosen = if split_chosen { "split" } else { "whole" };
    let json = Json::obj(vec![
        (
            "whole",
            Json::obj(vec![
                ("fits", Json::Bool(whole.fits())),
                ("feasible", Json::Bool(whole.feasible())),
                ("window_cycles", Json::num(whole.window_cycles(window) as f64)),
                ("window_s", Json::num(whole.window_s(window))),
                ("bram18", Json::num(whole.resources().bram18 as f64)),
            ]),
        ),
        ("split", plan_json(&out.plan, window)),
        ("evaluated", Json::num(out.evaluated as f64)),
        ("feasible_candidates", Json::num(out.feasible as f64)),
        ("chosen", Json::str(chosen)),
        ("chosen_window_cycles", Json::num(out.plan.window_cycles(window) as f64)),
        ("chosen_window_s", Json::num(out.plan.window_s(window))),
    ]);
    Ok((json, whole.feasible(), out.plan.feasible()))
}

/// The three report designs: (key, validated graph).
fn report_designs() -> Vec<(&'static str, Graph)> {
    let fmt = FixedFormat::q8_8();
    let oversized_sindy = SindyAccelConfig {
        xdim: 10,
        udim: 2,
        order: 3,
        hidden: 256,
        output: 900,
        ..SindyAccelConfig::concurrent()
    };
    vec![
        // Fits one PYNQ-Z2 whole: the never-worse row.
        ("gru_serving", GruAccelConfig::serving(4, 32, fmt, fmt).graph()),
        // Gate/candidate weight tiles overflow one board's BRAM.
        ("gru_oversized", GruAccelConfig::serving(4, 384, fmt, fmt).graph()),
        // Wide library × wide head: w1/w2 tiles overflow one board.
        ("sindy_oversized", oversized_sindy.graph()),
    ]
}

pub fn run(args: &Args) -> Result<()> {
    let window = args.get_usize("window", 64);
    if window == 0 {
        return Err(Error::config("partition needs --window >= 1"));
    }
    let slots = pynq_rack(RACK_SLOTS);
    let designs = report_designs();
    println!(
        "partition: {} design(s), {RACK_SLOTS}-slot pynq_z2 rack, {window}-step windows",
        designs.len()
    );

    let mut designs_json = BTreeMap::new();
    let mut whole_feasible = 0usize;
    let mut split_feasible = 0usize;
    let mut rescued = 0usize;
    for (key, g) in &designs {
        let (json, whole_ok, split_ok) = design_json(g, &slots, window as u64)?;
        whole_feasible += usize::from(whole_ok);
        split_feasible += usize::from(split_ok);
        rescued += usize::from(!whole_ok && split_ok);
        let chosen = json.get("chosen").and_then(Json::as_str).unwrap_or("?");
        let cycles = json
            .get("chosen_window_cycles")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        println!(
            "  [{key:<16}] whole {} -> chose {chosen} at {cycles:.0} cycles/window",
            if whole_ok { "feasible" } else { "infeasible" }
        );
        designs_json.insert((*key).to_string(), json);
    }
    println!(
        "\nsummary: {whole_feasible}/{} feasible whole, {split_feasible} feasible after the \
         sweep, {rescued} rescued by splitting",
        designs.len()
    );

    let mut report = BenchJson::new("partition");
    report.section(
        "workload",
        Json::obj(vec![
            ("window", Json::num(window as f64)),
            ("slots", Json::num(RACK_SLOTS as f64)),
            ("board", Json::str("pynq_z2")),
            ("link", Json::str("10gbe")),
        ]),
    );
    report.section("designs", Json::Obj(designs_json));
    report.section(
        "summary",
        Json::obj(vec![
            ("designs", Json::num(designs.len() as f64)),
            ("whole_feasible", Json::num(whole_feasible as f64)),
            ("split_feasible", Json::num(split_feasible as f64)),
            ("rescued_by_split", Json::num(rescued as f64)),
        ]),
    );
    let path = artifact_path("BENCH_partition.json");
    report.write(&path)?;
    println!("wrote {}", path.display());
    Ok(())
}

//! `merinda tune` — design-space autotuner over the canonical fleet.
//!
//! Runs `fpga::tuner` on every board of the heterogeneous roster at the
//! serving dims: each board's tile size × fixed-point format × adder
//! mix × clock space is swept, candidates are scored with the cycle,
//! resource-fit and power models, and the chosen operating point (the
//! fastest design that fits with BRAM double-buffering headroom, never
//! slower in cycles than the shipped default) is reported per board
//! together with its Pareto front. Writes `BENCH_tune.json` at the repo
//! root — deterministic and machine-independent, gated in CI by
//! `ci/check_bench_tune.py` (schema, every board fits, tuned-vs-default
//! cycle ratio ≥ 1 everywhere and > 1 somewhere). `merinda soak
//! --fleet N --tuned` then runs the streaming fleet at these operating
//! points.

use std::collections::BTreeMap;

use merinda::coordinator::{NATIVE_HID, NATIVE_PLIB, NATIVE_SEQ, NATIVE_UDIM, NATIVE_XDIM};
use merinda::fpga::cluster::heterogeneous_fleet;
use merinda::fpga::gru_accel::stage_map_name;
use merinda::fpga::tuner::{tune_board, TuneOutcome, TunerOptions};
use merinda::util::bench::{artifact_path, BenchJson};
use merinda::util::cli::Args;
use merinda::util::json::Json;
use merinda::util::{Error, Result};

/// One board's entry in the `boards` section of `BENCH_tune.json`.
fn board_json(out: &TuneOutcome) -> Json {
    let t = &out.chosen;
    let cfg = &t.board.cfg;
    let pareto: Vec<Json> = out
        .pareto()
        .map(|c| {
            Json::obj(vec![
                ("window_cycles", Json::num(c.window_cycles as f64)),
                ("window_s", Json::num(c.window_s)),
                ("power_w", Json::num(c.power_w)),
                ("clock_mhz", Json::num(c.clock_mhz)),
                ("unroll", Json::num(c.cfg.unroll as f64)),
                ("banks", Json::num(c.cfg.banks as f64)),
                ("dataflow", Json::Bool(c.cfg.dataflow)),
                ("format", Json::str(c.format)),
            ])
        })
        .collect();
    Json::obj(vec![
        (
            "default",
            Json::obj(vec![
                ("window_cycles", Json::num(out.default_window_cycles as f64)),
                ("window_s", Json::num(out.default_window_s)),
                ("power_w", Json::num(out.default_power_w)),
            ]),
        ),
        (
            "tuned",
            Json::obj(vec![
                ("window_cycles", Json::num(t.window_cycles as f64)),
                ("window_s", Json::num(t.window_s)),
                ("power_w", Json::num(t.power_w)),
                ("energy_per_window_j", Json::num(t.energy_per_window_j)),
                ("clock_mhz", Json::num(t.clock_mhz)),
                ("unroll", Json::num(cfg.unroll as f64)),
                ("banks", Json::num(cfg.banks as f64)),
                ("reshape", Json::num(cfg.reshape as f64)),
                ("dataflow", Json::Bool(cfg.dataflow)),
                ("stage_map", Json::str(stage_map_name(&cfg.stage_map))),
                ("format", Json::str(t.format)),
                ("max_outstanding", Json::num(t.max_outstanding as f64)),
                ("fits", Json::Bool(t.board.fits())),
            ]),
        ),
        ("ratio_cycles", Json::num(t.speedup_vs_default())),
        ("pareto_size", Json::num(pareto.len() as f64)),
        ("evaluated", Json::num(out.evaluated as f64)),
        ("feasible", Json::num(out.feasible as f64)),
        ("pareto", Json::Arr(pareto)),
    ])
}

pub fn run(args: &Args) -> Result<()> {
    let window = args.get_usize("window", NATIVE_SEQ);
    if window == 0 {
        return Err(Error::config("tune needs --window >= 1"));
    }
    let input = NATIVE_XDIM + NATIVE_UDIM;
    let opts = TunerOptions {
        window,
        xdim: NATIVE_XDIM,
        udim: NATIVE_UDIM,
        theta_len: NATIVE_XDIM * NATIVE_PLIB,
        ..TunerOptions::default()
    };
    let roster = heterogeneous_fleet(input, NATIVE_HID);
    println!(
        "tune: {} board(s), {window}-step windows, serving dims {input}->{NATIVE_HID}",
        roster.len()
    );

    let mut outcomes = Vec::new();
    for board in &roster {
        // `tune_board` now explains infeasibility itself (per-candidate
        // rejection tally in the `Error::Config` message).
        outcomes.push(tune_board(board, &opts)?);
    }

    let mut boards_json = BTreeMap::new();
    let mut improved = 0usize;
    let mut min_ratio = f64::INFINITY;
    let mut max_ratio: f64 = 0.0;
    for out in &outcomes {
        let t = &out.chosen;
        let cfg = &t.board.cfg;
        let ratio = t.speedup_vs_default();
        if ratio > 1.0 {
            improved += 1;
        }
        min_ratio = min_ratio.min(ratio);
        max_ratio = max_ratio.max(ratio);
        println!(
            "  [{:<16}] default {:>7} -> tuned {:>6} cycles/window ({ratio:.2}x)  \
             u{}/b{}/r{} {} {} @ {:.1} MHz  {:.2} W  budget {}  pareto {}",
            out.board_name,
            out.default_window_cycles,
            t.window_cycles,
            cfg.unroll,
            cfg.banks,
            cfg.reshape,
            stage_map_name(&cfg.stage_map),
            t.format,
            t.clock_mhz,
            t.power_w,
            t.max_outstanding,
            out.pareto().len()
        );
        boards_json.insert(out.board_name.clone(), board_json(out));
    }
    let fitting = outcomes.iter().filter(|o| o.chosen.board.fits()).count();
    println!(
        "\nsummary: {fitting}/{} boards fit, {improved} improved, \
         cycle ratio {min_ratio:.2}x..{max_ratio:.2}x",
        outcomes.len()
    );

    let mut report = BenchJson::new("tune");
    report.section(
        "workload",
        Json::obj(vec![
            ("window", Json::num(window as f64)),
            ("input", Json::num(input as f64)),
            ("hidden", Json::num(NATIVE_HID as f64)),
            ("xdim", Json::num(NATIVE_XDIM as f64)),
            ("udim", Json::num(NATIVE_UDIM as f64)),
            ("theta_len", Json::num((NATIVE_XDIM * NATIVE_PLIB) as f64)),
            ("boards", Json::num(roster.len() as f64)),
        ]),
    );
    report.section("boards", Json::Obj(boards_json));
    report.section(
        "summary",
        Json::obj(vec![
            ("boards", Json::num(outcomes.len() as f64)),
            ("boards_fitting", Json::num(fitting as f64)),
            ("boards_improved", Json::num(improved as f64)),
            ("min_ratio_cycles", Json::num(min_ratio)),
            ("max_ratio_cycles", Json::num(max_ratio)),
        ]),
    );
    let path = artifact_path("BENCH_tune.json");
    report.write(&path)?;
    println!("wrote {}", path.display());
    Ok(())
}

//! `merinda recover --system S --method M` — one recovery end to end.

use merinda::mr::recover::{self, MerindaOpts};
use merinda::mr::train::TrainOpts;
use merinda::runtime::Runtime;
use merinda::systems::{Aid, Apc, AvLateral, CaseStudy, F8Crusader, Lorenz, LotkaVolterra, Pathogen};
use merinda::util::cli::Args;
use merinda::util::{Error, Prng, Result};

pub fn system_by_name(name: &str) -> Result<Box<dyn CaseStudy>> {
    Ok(match name {
        "lotka" | "lotka-volterra" => Box::new(LotkaVolterra::default()),
        "lorenz" => Box::new(Lorenz::default()),
        "f8" => Box::new(F8Crusader::default()),
        "pathogen" => Box::new(Pathogen::default()),
        "aid" => Box::new(Aid::default()),
        "av" => Box::new(AvLateral::default()),
        "apc" => Box::new(Apc::default()),
        other => {
            return Err(Error::config(format!(
                "unknown system {other:?} (lotka|lorenz|f8|pathogen|aid|av|apc)"
            )))
        }
    })
}

pub fn run(args: &Args) -> Result<()> {
    let sys = system_by_name(&args.get_or("system", "lotka"))?;
    let method = args.get_or("method", "sindy");
    let samples = args.get_usize("samples", 1500);
    let dt = args.get_f64("dt", if sys.name() == "AID" { 5.0 } else { 0.01 });
    let seed = args.get_u64("seed", 42);

    let mut rng = Prng::new(seed);
    let tr = sys.generate(samples, dt, &mut rng);
    println!(
        "system={} samples={} dt={} method={}",
        sys.name(),
        samples,
        dt,
        method
    );

    let rec = match method.as_str() {
        "sindy" => recover::recover_sindy(&tr)?,
        "emily" => recover::recover_emily(&tr)?,
        "pinn-sr" | "pinnsr" => recover::recover_pinn_sr(&tr)?,
        "merinda" => {
            let rt = Runtime::new(args.get_or("artifacts", "artifacts"))?;
            recover::recover_merinda(
                &rt,
                &tr,
                MerindaOpts {
                    train: TrainOpts {
                        steps: args.get_usize("steps", 150),
                        seed,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )?
        }
        other => return Err(Error::config(format!("unknown method {other:?}"))),
    };

    println!(
        "\nrecovered model ({} nonzero terms, {:.2}s):",
        rec.model.nnz(),
        rec.wall_s
    );
    let names = rec.model.library.names();
    let p = rec.model.library.len();
    for d in 0..rec.model.xdim {
        let terms: Vec<String> = (0..p)
            .filter(|&i| rec.model.coeffs[d * p + i] != 0.0)
            .map(|i| format!("{:+.4}·{}", rec.model.coeffs[d * p + i], names[i]))
            .collect();
        println!("  dx{d}/dt = {}", terms.join(" "));
    }
    println!("\nreconstruction MSE = {:.6e}", rec.recon_mse);
    if let Some(truth) = sys.true_coeffs() {
        let cmse = merinda::mr::loss::coefficient_mse(&rec.model.coeffs, &truth);
        println!("coefficient MSE    = {cmse:.6e}");
    }
    Ok(())
}

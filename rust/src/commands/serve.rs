//! `merinda serve --requests N` — streaming recovery service demo.

use std::time::Instant;

use merinda::coordinator::{PjrtBackend, RecoveryRequest, Service, ServiceConfig};
use merinda::systems::{Aid, CaseStudy};
use merinda::util::cli::Args;
use merinda::util::{Prng, Result};

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 64);
    let seed = args.get_u64("seed", 42);
    let dir = args.get_or("artifacts", "artifacts");

    // Pre-generate request windows from AID traces.
    let mut rng = Prng::new(seed);
    let tr = Aid::default().generate(400, 5.0, &mut rng);
    let (y, u) = tr.padded_f32(3, 1);
    let scale: f32 = y.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let y: Vec<f32> = y.iter().map(|v| v / scale).collect();

    let seq = 64;
    let (xd, ud) = (3, 1);
    let windows: Vec<RecoveryRequest> = (0..n)
        .map(|i| {
            let s0 = rng.below(400 - seq);
            RecoveryRequest {
                id: i as u64,
                y: y[s0 * xd..(s0 + seq) * xd].to_vec(),
                u: u[s0 * ud..(s0 + seq) * ud].to_vec(),
            }
        })
        .collect();

    println!("starting service (PJRT backend, artifacts={dir})...");
    let svc = Service::start(ServiceConfig::default(), move || {
        PjrtBackend::new(dir, None, seed).expect("backend init (run `make artifacts`)")
    });

    let t0 = Instant::now();
    let rxs: Vec<_> = windows
        .into_iter()
        .filter_map(|w| svc.submit(w).ok())
        .collect();
    let accepted = rxs.len();
    let mut done = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            done += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = svc.metrics.snapshot();
    println!("\nserved {done}/{accepted} requests in {wall:.3}s ({:.1} req/s)", done as f64 / wall);
    println!("batches executed     {}", s.batches);
    println!("mean batch occupancy {:.2} / 8", s.mean_batch_occupancy);
    println!(
        "latency mean/p50/p99 {:.2} / {:.2} / {:.2} ms",
        s.latency.mean_ms, s.latency.p50_ms, s.latency.p99_ms
    );
    Ok(())
}

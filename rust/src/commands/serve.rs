//! `merinda serve --requests N` — streaming recovery service demo.
//!
//! `--backend pjrt|native|fixed|auto` picks the executor: the PJRT
//! artifact path, the artifact-free native batched-GRU backend, the
//! quantized fixed-point backend (`--fmt q8.8|q4.8|8bit`, with an
//! accelerator cycle report), or (default) PJRT with automatic fallback
//! to native when artifacts are missing. `--workers N` shards the
//! executor across N backend-owning threads.

use std::time::Instant;

use merinda::coordinator::{
    FixedPointBackend, FixedPointConfig, NativeBackend, PjrtBackend, RecoveryRequest, Service,
    ServiceConfig,
};
use merinda::systems::{Aid, CaseStudy};
use merinda::util::cli::Args;
use merinda::util::{Prng, Result};

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 64);
    let seed = args.get_u64("seed", 42);
    let workers = args.get_usize("workers", 1);
    let dir = args.get_or("artifacts", "artifacts");
    let backend = args.get_or("backend", "auto");

    // Pre-generate request windows from AID traces.
    let mut rng = Prng::new(seed);
    let tr = Aid::default().generate(400, 5.0, &mut rng);
    let (y, u) = tr.padded_f32(3, 1);
    let scale: f32 = y.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let y: Vec<f32> = y.iter().map(|v| v / scale).collect();

    let seq = 64;
    let (xd, ud) = (3, 1);
    let windows: Vec<RecoveryRequest> = (0..n)
        .map(|i| {
            let s0 = rng.below(400 - seq);
            RecoveryRequest {
                id: i as u64,
                y: y[s0 * xd..(s0 + seq) * xd].to_vec(),
                u: u[s0 * ud..(s0 + seq) * ud].to_vec(),
            }
        })
        .collect();

    // Auto mode probes Runtime::new rather than just checking for
    // artifacts/: it must also detect a PJRT-less build (the stub `xla`
    // dependency), where the manifest loads fine but no client can be
    // created. Costs one throwaway client init at startup; compilation is
    // lazy, so no modules are compiled by the probe.
    let use_native = match backend.as_str() {
        "native" => true,
        "pjrt" | "fixed" => false,
        _ => merinda::runtime::Runtime::new(&dir).is_err(),
    };
    let cfg = ServiceConfig {
        workers,
        ..Default::default()
    };
    // Kept outside the factory so the shared cycle counters stay readable
    // after the workers take their clones.
    let mut fixed_probe: Option<FixedPointBackend> = None;
    let svc = if backend == "fixed" {
        let fmt = args.get_or("fmt", "q8.8");
        let fp = FixedPointConfig::from_name(&fmt)?;
        let be = FixedPointBackend::new(8, seed, fp);
        println!(
            "starting service (fixed-point backend {fmt}, {workers} worker(s), \
             act {}b/weight {}b)...",
            fp.act_fmt.word_bits, fp.weight_fmt.word_bits
        );
        fixed_probe = Some(be.clone());
        Service::start(cfg, move || be.clone())
    } else if use_native {
        println!("starting service (native backend, {workers} worker(s), no artifacts needed)...");
        Service::start(cfg, move || NativeBackend::new(8, seed))
    } else {
        println!("starting service (PJRT backend, {workers} worker(s), artifacts={dir})...");
        Service::start(cfg, move || {
            PjrtBackend::new(&dir, None, seed).expect("backend init (run `make artifacts`)")
        })
    };

    let t0 = Instant::now();
    let done = svc.recover_many(windows).len();
    let wall = t0.elapsed().as_secs_f64();

    let s = svc.metrics.snapshot();
    // Accepted = submits that cleared backpressure (rejects are counted
    // separately by the metrics sink).
    let accepted = s.submitted - s.rejected;
    println!("\nserved {done}/{accepted} requests in {wall:.3}s ({:.1} req/s)", done as f64 / wall);
    println!("batches executed     {}", s.batches);
    println!("mean batch occupancy {:.2} / 8", s.mean_batch_occupancy);
    println!(
        "latency mean/p50/p99 {:.2} / {:.2} / {:.2} ms",
        s.latency.mean_ms, s.latency.p50_ms, s.latency.p99_ms
    );
    if let Some(be) = &fixed_probe {
        let r = be.cycle_report();
        println!(
            "\nfixed-point cycle model ({} windows, {} batches served):",
            r.windows_served, r.batches
        );
        println!(
            "  per-step cycles/interval   {} / {} (incl. DDR remainder)",
            r.step_cycles, r.step_interval
        );
        println!(
            "  per-window stage cycles    {} dataflow vs {} sequential ({:.1}x overlap speedup)",
            r.window_cycles,
            r.window_cycles_sequential,
            r.dataflow_speedup()
        );
        println!("  modeled accelerator cycles {}", r.modeled_cycles);
    }
    Ok(())
}

//! `merinda soak` — continuous multi-tenant streaming recovery workload.
//!
//! Replays trajectories from the six `systems/*` case studies (lorenz,
//! lotka, f8, av, aid, pathogen) as concurrent tenant streams through
//! `coordinator::stream`: samples arrive round-robin across tenants,
//! windows are sliced/queued/shed per policy, and the coordinator
//! places each window onto a heterogeneous accelerator fleet
//! (`--fleet N`, default 3: DATAFLOW PYNQ, sequential PYNQ, ZU7EV) via
//! the resource-aware cost function in `coordinator::placement`. With
//! `--tuned`, each board first runs through the design-space autotuner
//! (`fpga::tuner`) and the fleet is scheduled at the tuned operating
//! points instead of the shipped defaults (never slower in modeled
//! cycles — enforced at startup).
//! Warm-start recovery is on by default (`--no-warm` disables): each
//! window's Θ is polished seeded from the previous overlapping window,
//! and the saved iterations are reported per scenario as the
//! cold-vs-warm ratio. Reports throughput, p50/p99 latency, queue
//! depth, shed counts and the per-instance placement breakdown, and
//! writes a deterministic `BENCH_stream.json` (window counts +
//! accelerator cycle model, so the gated values are
//! machine-independent).
//!
//! By default the run *verifies itself*: the same windows are replayed
//! through the one-shot `Service::recover_many` path on an identically
//! seeded backend and every recovered window must match bitwise
//! (`--no-verify` skips; warm-start refinement is reported alongside the
//! raw Θ, never in place of it, so the bitwise check is unaffected).
//! CI shrinks the workload via the `MERINDA_SOAK_TENANTS` /
//! `MERINDA_SOAK_SAMPLES` env knobs (the same pattern as
//! `MERINDA_BENCH_SEQ` for the cycles bench).
//!
//! `--chaos <plan>` (or `MERINDA_SOAK_CHAOS`) replays the same workload
//! under deterministic fault injection (`coordinator::faults`): the
//! plan grammar is `crash:I@N,stall:I@N+MSms,flip:I@K,link:I@N*F+D`
//! (or the literal `seeded` to derive a plan from `--seed`). A warm
//! standby instance on the same identically-seeded backend joins the
//! roster, masked until the fleet degrades. The run then *self-verifies
//! the fault accounting*: per tenant, completed + shed + failed must
//! equal emitted (no window lost), no `(tenant, seq_no)` may complete
//! twice, every fired crash must leave its instance `down`, and every
//! fired bit-flip must have been caught by the fidelity check. The
//! bitwise one-shot comparison still runs — surviving windows carry
//! uncorrupted Θ. `--deadline-ms` bounds window completion before
//! hedged failover (default 30000).
//!
//! `--open-loop --arrivals <spec>` switches from closed-loop sample
//! replay to the production traffic tier (`coordinator::traffic`): a
//! deterministic seeded arrival process (grammar
//! `poisson:R,tenants:N,mix:A/B/C,ticks:T,seed:S,diurnal:P*A[@tier],`
//! `burst:T0+L*F[@tier]`, or the literal `seeded`) fires windows on a
//! logical clock regardless of completion rate, tenants carry
//! realtime/standard/batch QoS tiers from the `mix`, an admission
//! controller rejects arrivals whose tier SLO projection is breached
//! (`--slo-rt-ms` / `--slo-std-ms`; batch is never rejected), the
//! backlog is shed to `--backlog` budget batch-first every tick, and a
//! traffic-mix drift past `--drift-threshold` re-derives the placement
//! cost models mid-stream through the tuner. Per-tier latency
//! percentiles, admission and retune accounting land in new
//! `BENCH_stream.json` sections (`traffic`/`qos`/`admission`/`retune`,
//! present in both modes) and the run self-verifies per-tier closure:
//! offered == admitted + rejected and admitted == completed + shed +
//! failed. The bitwise one-shot comparison covers every completed
//! window (arrivals cycle a pre-sliced window ring, so each result's
//! start sample reconstructs its exact request).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use merinda::coordinator::placement::refine_cycle_model;
use merinda::coordinator::stream::{decode_id, encode_id};
use merinda::coordinator::{
    run_open_loop, window_plan, ArrivalSpec, DriftConfig, FaultKind, FaultPlan,
    FaultToleranceConfig, FixedPointBackend, FixedPointConfig, InstanceModel, InstanceSpec,
    Metrics, NativeBackend, OpenLoopConfig, SloPolicy, TenantTraffic, TrafficReport, NATIVE_HID,
    NATIVE_PLIB, NATIVE_SEQ, NATIVE_UDIM, NATIVE_XDIM, RecoveredWindow, RecoveryRequest, Service,
    ServiceConfig, ShedPolicy, StreamConfig, StreamCoordinator, WarmStartConfig, WindowConfig,
    QOS_CLASSES,
};
use merinda::fpga::cluster::heterogeneous_fleet;
use merinda::fpga::gru_accel::{GruAccel, GruAccelConfig};
use merinda::fpga::tuner::{retune_roster, TunerOptions};
use merinda::systems::streaming_systems;
use merinda::util::bench::{artifact_path, env_usize};
use merinda::util::cli::Args;
use merinda::util::json::Json;
use merinda::util::{Error, Prng, Result};

/// Canonical padded per-sample dims the serving backends expect.
const XD: usize = NATIVE_XDIM;
const UD: usize = NATIVE_UDIM;

struct TenantStream {
    scenario: &'static str,
    y: Vec<f32>,
    u: Vec<f32>,
}

/// Generate one normalized, padded trajectory per tenant, cycling
/// through the six-scenario roster.
fn build_streams(tenants: usize, samples: usize, seed: u64) -> Vec<TenantStream> {
    let mut rng = Prng::new(seed);
    let roster = streaming_systems();
    (0..tenants)
        .map(|t| {
            let (sys, dt) = &roster[t % roster.len()];
            let tr = sys.generate(samples, *dt, &mut rng);
            let (y, u) = tr.padded_f32(XD, UD);
            let ys: f32 = y.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
            let us: f32 = u.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
            TenantStream {
                scenario: sys.name(),
                y: y.iter().map(|v| v / ys).collect(),
                u: u.iter().map(|v| v / us).collect(),
            }
        })
        .collect()
}

/// Which serving backend a soak run uses. `Fixed` carries the one
/// shared backend instance so the cycle counters of every service
/// clone aggregate into a single report.
enum BackendKind {
    Native,
    Fixed(FixedPointBackend),
}

impl BackendKind {
    fn from_name(backend: &str, fmt: &str, seed: u64) -> Result<BackendKind> {
        match backend {
            "native" => Ok(BackendKind::Native),
            "fixed" => Ok(BackendKind::Fixed(FixedPointBackend::new(
                8,
                seed,
                FixedPointConfig::from_name(fmt)?,
            ))),
            other => Err(Error::config(format!(
                "unknown soak backend {other:?} (expected native or fixed)"
            ))),
        }
    }

    /// Counter-sharing probe for the fixed backend's cycle report.
    fn probe(&self) -> Option<FixedPointBackend> {
        match self {
            BackendKind::Native => None,
            BackendKind::Fixed(be) => Some(be.clone()),
        }
    }

    /// Start one service of this kind, recording into `sink`.
    fn start(&self, cfg: ServiceConfig, seed: u64, sink: Arc<Metrics>) -> Service {
        match self {
            BackendKind::Native => {
                Service::start_with_metrics(cfg, move || NativeBackend::new(8, seed), sink)
            }
            BackendKind::Fixed(be) => {
                let b = be.clone();
                Service::start_with_metrics(cfg, move || b.clone(), sink)
            }
        }
    }
}

/// Start one service on the requested backend (the one-shot verify
/// path). Returns the service plus, for the fixed backend, a
/// counter-sharing probe for the cycle report.
fn make_service(
    backend: &str,
    fmt: &str,
    workers: usize,
    seed: u64,
    sink: Arc<Metrics>,
) -> Result<(Service, Option<FixedPointBackend>)> {
    let kind = BackendKind::from_name(backend, fmt, seed)?;
    let cfg = ServiceConfig {
        workers,
        ..Default::default()
    };
    let svc = kind.start(cfg, seed, sink);
    Ok((svc, kind.probe()))
}

/// Derive placement models for a `fleet`-sized heterogeneous fleet by
/// cycling the canonical board roster at the serving dims. With
/// `tuned`, every roster board is first retargeted to its design-space
/// operating point (`fpga::tuner::tune_board`) before the cost models
/// are derived; a tuned config that modeled *more* cycles per window
/// than the shipped default would be a tuner bug, so it hard-fails.
fn fleet_models(fleet: usize, window: usize, tuned: bool) -> Result<Vec<InstanceModel>> {
    let mut roster = heterogeneous_fleet(XD + UD, NATIVE_HID);
    // Small fleets use only the roster prefix — don't tune (or gate on)
    // boards that never serve.
    roster.truncate(fleet.max(1));
    if tuned {
        let opts = TunerOptions {
            window,
            xdim: XD,
            udim: UD,
            theta_len: NATIVE_XDIM * NATIVE_PLIB,
            ..TunerOptions::default()
        };
        // All-or-nothing roster retune: the same hook the online-retune
        // path uses mid-stream, so startup and drift-triggered retunes
        // derive their models identically.
        let outs = retune_roster(&roster, &opts)?;
        let mut tuned_boards = Vec::with_capacity(roster.len());
        for out in &outs {
            if out.chosen.window_cycles > out.default_window_cycles {
                return Err(Error::numeric(format!(
                    "tuned config regressed {}: {} > {} cycles/window",
                    out.board_name, out.chosen.window_cycles, out.default_window_cycles
                )));
            }
            println!(
                "  tuned [{:<16}] {} -> {} cycles/window ({:.2}x)",
                out.board_name,
                out.default_window_cycles,
                out.chosen.window_cycles,
                out.chosen.speedup_vs_default()
            );
            tuned_boards.push(out.chosen.board.clone());
        }
        roster = tuned_boards;
    }
    Ok((0..fleet)
        .map(|i| {
            let mut board = roster[i % roster.len()].clone();
            if fleet > roster.len() {
                board.name = format!("{}#{}", board.name, i / roster.len());
            }
            InstanceSpec::new(board).model(window, XD, UD, NATIVE_XDIM * NATIVE_PLIB)
        })
        .collect())
}

/// Start the heterogeneous serving fleet: every instance runs an
/// identically seeded backend (so placement never changes the math) and
/// records into one shared metrics sink. For the fixed backend, all
/// instances clone one backend so its cycle counters aggregate
/// fleet-wide.
fn make_fleet(
    backend: &str,
    fmt: &str,
    workers: usize,
    seed: u64,
    models: &[InstanceModel],
) -> Result<(Vec<(InstanceModel, Service)>, BackendKind, Arc<Metrics>)> {
    let kind = BackendKind::from_name(backend, fmt, seed)?;
    let sink = Arc::new(Metrics::new());
    let cfg = ServiceConfig {
        workers,
        ..Default::default()
    };
    let fleet = models
        .iter()
        .map(|m| (m.clone(), kind.start(cfg, seed, sink.clone())))
        .collect();
    Ok((fleet, kind, sink))
}

pub fn run(args: &Args) -> Result<()> {
    let tenants = args.get_usize("tenants", env_usize("MERINDA_SOAK_TENANTS", 6)).max(1);
    let samples = args.get_usize("samples", env_usize("MERINDA_SOAK_SAMPLES", 400));
    let window = args.get_usize("window", NATIVE_SEQ);
    let stride = args.get_usize("stride", 16);
    let workers = args.get_usize("workers", 2).max(1);
    let queue = args.get_usize("queue", 64);
    let shed = ShedPolicy::from_name(&args.get_or("shed", "oldest"))?;
    let seed = args.get_u64("seed", 42);
    let backend = args.get_or("backend", "native");
    let fmt = args.get_or("fmt", "q8.8");
    let verify = !args.flag("no-verify");
    let fleet_n = args.get_usize("fleet", env_usize("MERINDA_SOAK_FLEET", 3)).max(1);
    let warm = !args.flag("no-warm");
    let tuned = args.flag("tuned");
    let deadline_ms = args.get_u64("deadline-ms", 30_000).max(1);
    let chaos_spec: Option<String> = args
        .get("chaos")
        .map(str::to_string)
        .or_else(|| std::env::var("MERINDA_SOAK_CHAOS").ok().filter(|s| !s.is_empty()));
    let chaos = chaos_spec.is_some();
    let open_loop = args.flag("open-loop");
    let arrivals = args.get_or("arrivals", "seeded");
    let backlog = args.get_usize("backlog", 512);
    let slo_rt_ms = args.get_f64("slo-rt-ms", 500.0);
    let slo_std_ms = args.get_f64("slo-std-ms", 2000.0);
    let drift_threshold = args.get_f64("drift-threshold", 0.2);
    let arrival_spec = if open_loop {
        Some(match arrivals.as_str() {
            "seeded" => ArrivalSpec::seeded(seed),
            s => ArrivalSpec::parse(s)?,
        })
    } else {
        None
    };
    // Open-loop tenant population comes from the arrival spec (the QoS
    // mix assigns tiers by tenant index), overriding --tenants/env.
    let tenants = arrival_spec.as_ref().map_or(tenants, |s| s.tenants);

    if window != NATIVE_SEQ {
        return Err(Error::config(format!(
            "the canonical serving model recovers {NATIVE_SEQ}-sample windows; \
             got --window {window}"
        )));
    }

    let wcfg = WindowConfig { window, stride }.normalized();
    let streams = build_streams(tenants, samples, seed);
    let scenarios: BTreeSet<&str> = streams.iter().map(|s| s.scenario).collect();
    println!(
        "soak: {tenants} tenant stream(s) over {} scenario(s), {samples} samples each, \
         window {}/stride {}, backend {backend}, {fleet_n}-instance fleet{}, \
         {workers} worker(s)/instance, warm-start {}",
        scenarios.len(),
        wcfg.window,
        wcfg.stride,
        if tuned { " (tuned)" } else { "" },
        if warm { "on" } else { "off" }
    );

    let models = fleet_models(fleet_n, wcfg.window, tuned)?;
    let (fleet, kind, sink) = make_fleet(&backend, &fmt, workers, seed, &models)?;
    let probe = kind.probe();
    let scfg = StreamConfig {
        window: wcfg,
        tenant_queue: queue,
        shed,
        warm_start: WarmStartConfig {
            enabled: warm,
            ..WarmStartConfig::default()
        },
        faults: FaultToleranceConfig {
            deadline: Duration::from_millis(deadline_ms),
            ..FaultToleranceConfig::default()
        },
        ..Default::default()
    };
    let mut coord = StreamCoordinator::with_fleet(fleet, scfg, XD, UD)?;

    // Arm the chaos plan and a warm standby. The standby runs the same
    // identically-seeded backend kind as the fleet (so windows it
    // absorbs still verify bitwise against the one-shot path) and stays
    // masked out of placement until the fleet degrades.
    let plan_starts = window_plan(samples, wcfg.window, wcfg.stride);
    let fault_plan = match chaos_spec.as_deref() {
        None => FaultPlan::none(),
        Some("seeded") => {
            let horizon = (tenants * plan_starts.len()) as u64;
            FaultPlan::seeded(seed, fleet_n, horizon.max(4))
        }
        Some(spec) => FaultPlan::parse(spec, fleet_n)?,
    };
    if chaos {
        coord.inject_faults(fault_plan.clone())?;
        let standby_cfg = ServiceConfig {
            workers,
            ..Default::default()
        };
        let standby_svc = kind.start(standby_cfg, seed, sink.clone());
        let standby_model = InstanceModel::synthetic("host-standby", 1e-3, 64);
        coord.add_standby(standby_model, standby_svc);
        println!(
            "chaos: plan [{}], deadline {deadline_ms}ms, host standby armed",
            fault_plan.spec()
        );
    }

    let t0 = Instant::now();
    let traffic_report: Option<TrafficReport> = if let Some(spec) = &arrival_spec {
        // Open-loop: the arrival plan fires windows on a logical clock
        // regardless of completion rate. Each tenant cycles a
        // pre-sliced window ring over its own trajectory, so every
        // completed result still verifies bitwise against one-shot.
        if plan_starts.is_empty() {
            return Err(Error::config(format!(
                "open-loop needs at least one full window: {samples} samples < window {}",
                wcfg.window
            )));
        }
        let plan = spec.plan();
        println!(
            "open-loop: [{}] -> {} arrivals over {} ticks (rt/std/batch {}/{}/{}), \
             backlog budget {backlog}, SLO rt {slo_rt_ms}ms / std {slo_std_ms}ms / batch none",
            spec.spec(),
            plan.arrivals.len(),
            plan.ticks,
            plan.offered_per_tier[0],
            plan.offered_per_tier[1],
            plan.offered_per_tier[2]
        );
        let rings: Vec<TenantTraffic> = streams
            .iter()
            .map(|st| TenantTraffic {
                windows: plan_starts
                    .iter()
                    .map(|&s0| {
                        (
                            s0,
                            st.y[s0 * XD..(s0 + wcfg.window) * XD].to_vec(),
                            st.u[s0 * UD..(s0 + wcfg.window) * UD].to_vec(),
                        )
                    })
                    .collect(),
            })
            .collect();
        let olcfg = OpenLoopConfig {
            backlog_budget: backlog,
            slo: SloPolicy {
                p99_ms: [Some(slo_rt_ms), Some(slo_std_ms), None],
            },
            drift: DriftConfig {
                threshold: drift_threshold,
                ..DriftConfig::default()
            },
            ..OpenLoopConfig::default()
        };
        let rep = run_open_loop(&mut coord, &plan, &rings, &olcfg, |ev| {
            println!(
                "  retune @tick {}: drift {:.3} (rt/std/batch {:.2}/{:.2}/{:.2}) — \
                 re-deriving placement models from the tuner",
                ev.tick, ev.drift, ev.observed[0], ev.observed[1], ev.observed[2]
            );
            fleet_models(fleet_n, wcfg.window, true).ok()
        })?;
        Some(rep)
    } else {
        // Samples arrive interleaved round-robin across tenants — the
        // concurrent-stream shape, not tenant-after-tenant replay.
        for s in 0..samples {
            for (t, st) in streams.iter().enumerate() {
                coord.push(t as u32, &st.y[s * XD..(s + 1) * XD], &st.u[s * UD..(s + 1) * UD]);
            }
            coord.pump();
            coord.poll();
        }
        coord.flush_tails();
        coord.drain();
        None
    };
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let mut results = coord.take_results();
    results.sort_by_key(|r| (r.tenant, r.seq_no));
    let stats = coord.stats();
    let m = coord.metrics().snapshot();
    let completed = stats.windows_completed;

    println!(
        "\nstreamed {completed} windows ({} shed, {} failed) in {wall:.3}s ({:.1} windows/s)",
        stats.windows_shed,
        stats.windows_failed,
        completed as f64 / wall
    );
    println!(
        "latency mean/p50/p99     {:.2} / {:.2} / {:.2} ms",
        m.latency.mean_ms, m.latency.p50_ms, m.latency.p99_ms
    );
    println!(
        "queue depth (svc/tenant) {} / {}   in-flight max {}",
        m.queue_depth_max, stats.tenant_queue_max, stats.in_flight_max
    );
    println!(
        "batches {}  occupancy {:.2}/8  AIMD backoffs {} (final burst {})",
        m.batches, m.mean_batch_occupancy, stats.burst_backoffs, stats.burst_final
    );
    for pt in &stats.per_tenant {
        println!(
            "  tenant {:>2} [{:<16}] emitted {:>4}  completed {:>4}  shed {:>3}",
            pt.tenant,
            streams[pt.tenant as usize].scenario,
            pt.emitted,
            pt.completed,
            pt.shed
        );
    }
    println!("placement ({} instance(s)):", stats.per_instance.len());
    for (i, inst) in stats.per_instance.iter().enumerate() {
        println!(
            "  instance {:>2} [{:<16}] placed {:>4}  completed {:>4}  \
             outstanding max {:>3}  {:>7} cycles/window  health {:<10} failed-over {:>3}",
            i,
            inst.name,
            inst.placed,
            inst.completed,
            inst.outstanding_max,
            inst.window_cycles,
            inst.health,
            inst.failed_over
        );
    }

    // Open-loop traffic accounting: per-tier disposition table, retune
    // log, and the closure self-checks (admission: offered == admitted
    // + rejected; disposition: admitted == completed + shed + failed).
    if let Some(rep) = &traffic_report {
        println!(
            "traffic: {} tick(s), max drift {:.3}, {} retune(s)",
            rep.ticks,
            rep.max_drift,
            rep.retunes.len()
        );
        for (i, q) in QOS_CLASSES.iter().enumerate() {
            let tt = &rep.per_tier[i];
            let ts = &m.per_tier[i];
            println!(
                "  tier {:<8} offered {:>5}  admitted {:>5}  rejected {:>5}  \
                 completed {:>5}  shed {:>4}  failed {:>3}  \
                 p50/p99/p999 {:.1}/{:.1}/{:.1} ms",
                q.name(),
                tt.offered,
                tt.admitted,
                tt.rejected,
                ts.completed,
                ts.shed,
                ts.failed,
                ts.p50_ms,
                ts.p99_ms,
                ts.p999_ms
            );
        }
        for ev in &rep.retunes {
            println!(
                "  retuned @tick {:>4}: drift {:.3}, models {}",
                ev.tick,
                ev.drift,
                if ev.models_refreshed { "refreshed" } else { "kept" }
            );
        }
        if !rep.admission_closes() {
            return Err(Error::numeric(
                "open-loop admission accounting did not close \
                 (offered != admitted + rejected on some tier)",
            ));
        }
        for (i, q) in QOS_CLASSES.iter().enumerate() {
            let ts = &m.per_tier[i];
            if ts.admitted != ts.completed + ts.shed + ts.failed {
                return Err(Error::numeric(format!(
                    "tier {} lost windows: {} admitted != {} completed + {} shed + {} failed",
                    q.name(),
                    ts.admitted,
                    ts.completed,
                    ts.shed,
                    ts.failed
                )));
            }
        }
        println!(
            "open-loop self-check: admission + disposition accounting closed on all 3 tiers"
        );
    }

    // Fault accounting: always reported; self-verified under --chaos.
    let fstats = stats.faults;
    if chaos || fstats.injected_total() > 0 || fstats.failed_over > 0 {
        println!(
            "faults: injected {} (crash {} stall {} link {} flip {})  detected: \
             timeouts {} disconnects {} corruptions {} submit-down {}",
            fstats.injected_total(),
            fstats.injected_crash,
            fstats.injected_stall,
            fstats.injected_link,
            fstats.injected_flip,
            fstats.detected_timeouts,
            fstats.detected_disconnects,
            fstats.detected_corruptions,
            fstats.detected_submit_down
        );
        println!(
            "        failed over {}  retries {}  duplicates dropped {}  exhausted {}  \
             standby windows {}  degraded entries/exits {}/{}",
            fstats.failed_over,
            fstats.retries,
            fstats.duplicates_dropped,
            fstats.exhausted,
            fstats.standby_windows,
            fstats.degraded_entries,
            fstats.degraded_exits
        );
    }
    if chaos {
        // Chaos self-verification: the fault layer must account for
        // every window and every injected fault must be observable.
        for pt in &stats.per_tenant {
            if pt.completed + pt.shed + pt.failed != pt.emitted {
                return Err(Error::numeric(format!(
                    "tenant {} lost windows under chaos: {} completed + {} shed + {} failed \
                     != {} emitted",
                    pt.tenant, pt.completed, pt.shed, pt.failed, pt.emitted
                )));
            }
        }
        let mut seen = BTreeSet::new();
        for r in &results {
            if !seen.insert((r.tenant, r.seq_no)) {
                return Err(Error::numeric(format!(
                    "window (tenant {}, seq {}) completed twice under chaos",
                    r.tenant, r.seq_no
                )));
            }
        }
        let crash_events = fault_plan
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Crash))
            .count() as u64;
        if fstats.injected_crash == crash_events {
            // Every planned crash fired: each victim must be observably
            // down (a crash is permanent — no probe revives it).
            for ev in &fault_plan.events {
                if matches!(ev.kind, FaultKind::Crash)
                    && stats.per_instance[ev.instance].health != "down"
                {
                    return Err(Error::numeric(format!(
                        "instance {} was crashed but reports health {:?}",
                        ev.instance, stats.per_instance[ev.instance].health
                    )));
                }
            }
        }
        if fstats.detected_corruptions < fstats.injected_flip {
            return Err(Error::numeric(format!(
                "{} bit-flips injected but only {} corruptions caught by the fidelity check",
                fstats.injected_flip, fstats.detected_corruptions
            )));
        }
        println!(
            "chaos self-check: accounting closed for {} tenant(s), {} unique windows, \
             {} crash(es) observed down, {}/{} corruption(s) caught",
            stats.per_tenant.len(),
            results.len(),
            fstats.injected_crash,
            fstats.detected_corruptions,
            fstats.injected_flip
        );
    }

    // Warm-start accounting: per-scenario cold-vs-warm iteration totals
    // over the paired windows (every warm-seeded window also refined
    // from the cold seed on the same data).
    let mut per_scenario: BTreeMap<&str, (u64, u64, u64)> = BTreeMap::new();
    for pt in &stats.per_tenant {
        let e = per_scenario
            .entry(streams[pt.tenant as usize].scenario)
            .or_insert((0, 0, 0));
        e.0 += pt.refine_warm_iters;
        e.1 += pt.refine_cold_iters;
        e.2 += pt.refine_paired;
    }
    let scenarios_measured = per_scenario.values().filter(|v| v.2 > 0).count();
    let scenarios_warm_below = per_scenario.values().filter(|v| v.2 > 0 && v.0 < v.1).count();
    if warm {
        println!(
            "warm-start: {} paired windows, {} warm vs {} cold iterations \
             (warm strictly below cold on {}/{} scenarios)",
            stats.refine_paired,
            stats.refine_warm_iters,
            stats.refine_cold_iters,
            scenarios_warm_below,
            scenarios_measured
        );
        for (name, (w, c, p)) in &per_scenario {
            println!(
                "  scenario [{:<16}] warm {:>5}  cold {:>5}  over {:>3} windows",
                name, w, c, p
            );
        }
    }

    // Streaming-vs-one-shot equivalence: the same windows through
    // `recover_many` on an identically seeded backend must recover the
    // same coefficients bitwise (the pipeline adds routing, not math).
    let (verify_compared, verify_delta) = if verify {
        let (svc2, _) = make_service(&backend, &fmt, workers, seed, Arc::new(Metrics::new()))?;
        let mut reqs = Vec::new();
        if open_loop {
            // Open-loop arrivals cycle each tenant's window ring, so
            // the exact request set is reconstructed from the completed
            // results: every result carries its start sample.
            for r in &results {
                let st = &streams[r.tenant as usize];
                reqs.push(RecoveryRequest {
                    id: encode_id(r.tenant, r.seq_no),
                    y: st.y[r.start * XD..(r.start + wcfg.window) * XD].to_vec(),
                    u: st.u[r.start * UD..(r.start + wcfg.window) * UD].to_vec(),
                });
            }
        } else {
            for (t, st) in streams.iter().enumerate() {
                for (k, &s0) in plan_starts.iter().enumerate() {
                    reqs.push(RecoveryRequest {
                        id: encode_id(t as u32, k as u32),
                        y: st.y[s0 * XD..(s0 + wcfg.window) * XD].to_vec(),
                        u: st.u[s0 * UD..(s0 + wcfg.window) * UD].to_vec(),
                    });
                }
            }
        }
        // Chunked below the service queue depth: `recover_many` silently
        // drops backpressure rejections, which would under-compare.
        let planned = reqs.len();
        let mut oneshot = Vec::with_capacity(planned);
        while !reqs.is_empty() {
            let take = reqs.len().min(128);
            let chunk: Vec<RecoveryRequest> = reqs.drain(..take).collect();
            oneshot.extend(svc2.recover_many(chunk));
        }
        if oneshot.len() != planned {
            return Err(Error::numeric(format!(
                "one-shot verification lost windows: served {}/{planned}",
                oneshot.len()
            )));
        }
        let by_key: BTreeMap<(u32, u32), &RecoveredWindow> =
            results.iter().map(|r| ((r.tenant, r.seq_no), r)).collect();
        let mut compared = 0u64;
        let mut max_delta = 0.0f64;
        for resp in &oneshot {
            if let Some(r) = by_key.get(&decode_id(resp.id)) {
                compared += 1;
                for (a, b) in r.theta.iter().zip(&resp.theta) {
                    max_delta = max_delta.max((*a as f64 - *b as f64).abs());
                }
            }
        }
        println!("verify: {compared} windows vs one-shot, max |dtheta| = {max_delta:.3e}");
        if compared != results.len() as u64 {
            return Err(Error::numeric(format!(
                "verification covered {compared} of {} streamed windows",
                results.len()
            )));
        }
        if max_delta > 0.0 {
            return Err(Error::numeric(format!(
                "streaming and one-shot recovery disagree: max |dtheta| = {max_delta:.3e}"
            )));
        }
        (compared, max_delta)
    } else {
        (0, 0.0)
    };

    // Deterministic accelerator cycle model at the serving dims and the
    // active fixed-point formats: what sustained window throughput the
    // DATAFLOW pipeline provides if the completed windows stream
    // back-to-back. Machine-independent, so CI can gate on it.
    let fp_model = probe.as_ref().map(|p| p.config()).unwrap_or_else(FixedPointConfig::q8_8);
    let accel = GruAccel::new(GruAccelConfig::serving(
        XD + UD,
        NATIVE_HID,
        fp_model.act_fmt,
        fp_model.weight_fmt,
    ));
    let pipe = accel.stage_pipeline();
    let window_cycles = pipe.analyze(wcfg.window as u64).total_cycles;
    let streamed = pipe.analyze(completed * wcfg.window as u64);
    let wpm = if streamed.total_cycles > 0 {
        completed as f64 * 1e6 / streamed.total_cycles as f64
    } else {
        0.0
    };
    println!("cycle model: {window_cycles} cycles/window, {wpm:.1} windows/Mcycle sustained");
    if let Some(p) = &probe {
        let r = p.cycle_report();
        println!(
            "fixed-point counters: {} windows in {} batches, {} modeled cycles",
            r.windows_served, r.batches, r.modeled_cycles
        );
    }

    let min_done = stats.per_tenant.iter().map(|t| t.completed).min().unwrap_or(0);
    let max_done = stats.per_tenant.iter().map(|t| t.completed).max().unwrap_or(0);

    let mut report = merinda::util::bench::BenchJson::new("stream");
    report.section(
        "workload",
        Json::obj(vec![
            ("tenants", Json::num(tenants as f64)),
            ("samples_per_tenant", Json::num(samples as f64)),
            ("window", Json::num(wcfg.window as f64)),
            ("stride", Json::num(wcfg.stride as f64)),
            ("backend", Json::str(backend.clone())),
            ("workers", Json::num(workers as f64)),
            ("scenarios", Json::num(scenarios.len() as f64)),
            ("tuned", Json::Bool(tuned)),
        ]),
    );
    report.section(
        "totals",
        Json::obj(vec![
            ("windows_emitted", Json::num(stats.windows_emitted as f64)),
            ("windows_completed", Json::num(completed as f64)),
            ("windows_shed", Json::num(stats.windows_shed as f64)),
            ("windows_failed", Json::num(stats.windows_failed as f64)),
        ]),
    );
    report.section(
        "fairness",
        Json::obj(vec![
            ("min_tenant_completed", Json::num(min_done as f64)),
            ("max_tenant_completed", Json::num(max_done as f64)),
        ]),
    );
    report.section(
        "queue",
        Json::obj(vec![
            ("service_queue_depth_max", Json::num(m.queue_depth_max as f64)),
            ("tenant_queue_max", Json::num(stats.tenant_queue_max as f64)),
            ("in_flight_max", Json::num(stats.in_flight_max as f64)),
            ("burst_backoffs", Json::num(stats.burst_backoffs as f64)),
            ("burst_final", Json::num(stats.burst_final as f64)),
        ]),
    );
    report.section(
        "cycle_model",
        Json::obj(vec![
            ("window_cycles", Json::num(window_cycles as f64)),
            ("interval", Json::num(streamed.interval as f64)),
            ("modeled_cycles_streamed", Json::num(streamed.total_cycles as f64)),
            ("windows_per_mcycle", Json::num(wpm)),
        ]),
    );
    report.section(
        "verify",
        Json::obj(vec![
            ("checked", Json::Bool(verify)),
            ("compared", Json::num(verify_compared as f64)),
            ("max_abs_delta", Json::num(verify_delta)),
        ]),
    );
    report.section(
        "placement",
        Json::obj(vec![
            ("instances", Json::num(stats.per_instance.len() as f64)),
            (
                "instances_used",
                Json::num(
                    stats.per_instance.iter().filter(|i| i.placed > 0).count() as f64,
                ),
            ),
            (
                "per_instance",
                Json::Arr(
                    stats
                        .per_instance
                        .iter()
                        .map(|i| {
                            Json::obj(vec![
                                ("name", Json::str(i.name.clone())),
                                ("placed", Json::num(i.placed as f64)),
                                ("completed", Json::num(i.completed as f64)),
                                ("outstanding_max", Json::num(i.outstanding_max as f64)),
                                ("window_cycles", Json::num(i.window_cycles as f64)),
                                ("modeled_cycles", Json::num(i.modeled_cycles as f64)),
                                ("health", Json::str(i.health.clone())),
                                ("failed_over", Json::num(i.failed_over as f64)),
                                ("downs", Json::num(i.downs as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    // Warm-start: iteration and modeled-cycle ratios over the paired
    // windows. The cycle ratio charges each path its NN window plus its
    // refinement iterations on the serving accelerator's MAC lanes.
    let plib = NATIVE_PLIB;
    // The CG matvec retires on the same MAC lanes the serving
    // accelerator schedules (its UNROLL factor).
    let lanes = accel.cfg.unroll as u64;
    let warm_cycles = stats.refine_paired * window_cycles
        + refine_cycle_model(stats.refine_warm_iters, plib, lanes);
    let cold_cycles = stats.refine_paired * window_cycles
        + refine_cycle_model(stats.refine_cold_iters, plib, lanes);
    let iter_ratio = if stats.refine_cold_iters > 0 {
        stats.refine_warm_iters as f64 / stats.refine_cold_iters as f64
    } else {
        0.0
    };
    let cycle_ratio = if cold_cycles > 0 {
        warm_cycles as f64 / cold_cycles as f64
    } else {
        0.0
    };
    report.section(
        "warm_start",
        Json::obj(vec![
            ("enabled", Json::Bool(warm)),
            ("paired_windows", Json::num(stats.refine_paired as f64)),
            ("warm_iters", Json::num(stats.refine_warm_iters as f64)),
            ("cold_iters", Json::num(stats.refine_cold_iters as f64)),
            ("iter_ratio", Json::num(iter_ratio)),
            ("warm_cycles", Json::num(warm_cycles as f64)),
            ("cold_cycles", Json::num(cold_cycles as f64)),
            ("cycle_ratio", Json::num(cycle_ratio)),
            ("scenarios_measured", Json::num(scenarios_measured as f64)),
            ("scenarios_warm_below_cold", Json::num(scenarios_warm_below as f64)),
            (
                "per_scenario",
                Json::Obj(
                    per_scenario
                        .iter()
                        .map(|(name, (w, c, p))| {
                            (
                                name.to_string(),
                                Json::obj(vec![
                                    ("warm_iters", Json::num(*w as f64)),
                                    ("cold_iters", Json::num(*c as f64)),
                                    ("paired_windows", Json::num(*p as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    // Fault-layer accounting: always present (all-zero counters when no
    // chaos plan is armed and the fleet stayed healthy) so
    // `ci/check_bench_stream.py` can gate both modes.
    report.section(
        "faults",
        Json::obj(vec![
            ("chaos", Json::Bool(chaos)),
            ("plan", Json::str(fault_plan.spec())),
            ("deadline_ms", Json::num(deadline_ms as f64)),
            ("injected_crash", Json::num(fstats.injected_crash as f64)),
            ("injected_stall", Json::num(fstats.injected_stall as f64)),
            ("injected_link", Json::num(fstats.injected_link as f64)),
            ("injected_flip", Json::num(fstats.injected_flip as f64)),
            ("detected_timeouts", Json::num(fstats.detected_timeouts as f64)),
            ("detected_disconnects", Json::num(fstats.detected_disconnects as f64)),
            ("detected_corruptions", Json::num(fstats.detected_corruptions as f64)),
            ("detected_submit_down", Json::num(fstats.detected_submit_down as f64)),
            ("failed_over", Json::num(fstats.failed_over as f64)),
            ("retries", Json::num(fstats.retries as f64)),
            ("duplicates_dropped", Json::num(fstats.duplicates_dropped as f64)),
            ("exhausted", Json::num(fstats.exhausted as f64)),
            ("degraded_entries", Json::num(fstats.degraded_entries as f64)),
            ("degraded_exits", Json::num(fstats.degraded_exits as f64)),
            ("standby_windows", Json::num(fstats.standby_windows as f64)),
            ("instances_down", Json::num(fstats.instances_down as f64)),
            ("instances_recovered", Json::num(fstats.instances_recovered as f64)),
            (
                "recovery_rounds_total",
                Json::num(fstats.recovery_rounds_total as f64),
            ),
            (
                "accounting_closed",
                Json::Bool(
                    stats
                        .per_tenant
                        .iter()
                        .all(|t| t.completed + t.shed + t.failed == t.emitted),
                ),
            ),
        ]),
    );
    // Traffic / QoS / admission / retune sections: always present so
    // `ci/check_bench_stream.py` can gate both modes (closed-loop runs
    // carry `open_loop: false` with zeroed driver counters; the per-tier
    // QoS metrics are live in both modes).
    let rep_default = TrafficReport::default();
    let rep = traffic_report.as_ref().unwrap_or(&rep_default);
    let spec_str = arrival_spec.as_ref().map(|s| s.spec()).unwrap_or_default();
    let offered_total: u64 = rep.per_tier.iter().map(|t| t.offered).sum();
    let rejected_total: u64 = rep.per_tier.iter().map(|t| t.rejected).sum();
    let slos: [Option<f64>; 3] = if open_loop {
        [Some(slo_rt_ms), Some(slo_std_ms), None]
    } else {
        [None; 3]
    };
    report.section(
        "traffic",
        Json::obj(vec![
            ("open_loop", Json::Bool(open_loop)),
            ("spec", Json::str(spec_str)),
            ("ticks", Json::num(rep.ticks as f64)),
            ("offered_total", Json::num(offered_total as f64)),
            ("backlog_budget", Json::num(backlog as f64)),
            ("max_drift", Json::num(rep.max_drift)),
            (
                "per_tier",
                Json::Obj(
                    QOS_CLASSES
                        .iter()
                        .enumerate()
                        .map(|(i, q)| {
                            let t = &rep.per_tier[i];
                            (
                                q.name().to_string(),
                                Json::obj(vec![
                                    ("offered", Json::num(t.offered as f64)),
                                    ("admitted", Json::num(t.admitted as f64)),
                                    ("rejected", Json::num(t.rejected as f64)),
                                    ("shed_budget", Json::num(t.shed_budget as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    report.section(
        "qos",
        Json::Obj(
            QOS_CLASSES
                .iter()
                .enumerate()
                .map(|(i, q)| {
                    let ts = &m.per_tier[i];
                    let slo = slos[i];
                    (
                        q.name().to_string(),
                        Json::obj(vec![
                            ("offered", Json::num(ts.offered as f64)),
                            ("admitted", Json::num(ts.admitted as f64)),
                            ("rejected", Json::num(ts.rejected as f64)),
                            ("placed", Json::num(ts.placed as f64)),
                            ("completed", Json::num(ts.completed as f64)),
                            ("shed", Json::num(ts.shed as f64)),
                            ("failed", Json::num(ts.failed as f64)),
                            ("latency_count", Json::num(ts.latency_count as f64)),
                            ("p50_ms", Json::num(ts.p50_ms)),
                            ("p99_ms", Json::num(ts.p99_ms)),
                            ("p999_ms", Json::num(ts.p999_ms)),
                            ("max_ms", Json::num(ts.max_ms)),
                            (
                                "slo_ms",
                                match slo {
                                    Some(s) => Json::num(s),
                                    None => Json::Null,
                                },
                            ),
                            ("slo_met", Json::Bool(slo.map_or(true, |s| ts.p99_ms <= s))),
                        ]),
                    )
                })
                .collect(),
        ),
    );
    report.section(
        "admission",
        Json::obj(vec![
            ("enabled", Json::Bool(open_loop)),
            ("slo_realtime_ms", Json::num(slo_rt_ms)),
            ("slo_standard_ms", Json::num(slo_std_ms)),
            ("slo_batch_ms", Json::Null),
            ("rejected_total", Json::num(rejected_total as f64)),
            ("closes", Json::Bool(rep.admission_closes())),
        ]),
    );
    report.section(
        "retune",
        Json::obj(vec![
            ("enabled", Json::Bool(open_loop)),
            ("drift_threshold", Json::num(drift_threshold)),
            ("count", Json::num(rep.retunes.len() as f64)),
            ("max_drift", Json::num(rep.max_drift)),
            (
                "events",
                Json::Arr(
                    rep.retunes
                        .iter()
                        .map(|ev| {
                            Json::obj(vec![
                                ("tick", Json::num(ev.tick as f64)),
                                ("drift", Json::num(ev.drift)),
                                ("models_refreshed", Json::Bool(ev.models_refreshed)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    // Wall-clock numbers are informational only — machine-dependent, so
    // CI gates on the window counts and cycle model above instead.
    report.section(
        "wall",
        Json::obj(vec![
            ("seconds", Json::num(wall)),
            ("windows_per_s", Json::num(completed as f64 / wall)),
            ("latency_p50_ms", Json::num(m.latency.p50_ms)),
            ("latency_p99_ms", Json::num(m.latency.p99_ms)),
        ]),
    );
    let path = artifact_path("BENCH_stream.json");
    report.write(&path)?;
    println!("\nwrote {}", path.display());
    Ok(())
}

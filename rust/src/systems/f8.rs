//! F8 Crusader longitudinal flight dynamics (simulation case study).
//!
//! The Garrard–Jordan F8 model as used in SINDY-MPC [18]: angle of attack
//! x0, pitch angle x1, pitch rate x2, elevator input u. The dynamics are
//! *cubic*, so an order-2 library cannot represent them exactly — which is
//! why the paper's Table 6 reports larger errors for this system than for
//! the quadratic ones. `true_coeffs` therefore returns `None` and the
//! benchmark falls back to trajectory-reconstruction MSE.

use crate::mr::ode::{rk4_trajectory, FnRhs, Rhs};
use crate::util::Prng;

use super::{CaseStudy, Trace};

/// F8 Crusader with the standard literature coefficients.
#[derive(Clone, Debug)]
pub struct F8Crusader {
    pub y0: [f64; 3],
    /// Elevator doublet amplitude (rad).
    pub input_amp: f64,
}

impl Default for F8Crusader {
    fn default() -> Self {
        F8Crusader {
            y0: [0.1, 0.0, 0.0],
            input_amp: 0.05,
        }
    }
}

fn f8_rhs(y: &[f64], u: f64, out: &mut [f64]) {
    let (x0, x1, x2) = (y[0], y[1], y[2]);
    // Garrard & Jordan (1977) F8 longitudinal model.
    out[0] = -0.877 * x0 + x2 - 0.088 * x0 * x2 + 0.47 * x0 * x0 - 0.019 * x1 * x1
        - x0 * x0 * x2
        + 3.846 * x0 * x0 * x0
        - 0.215 * u
        + 0.28 * x0 * x0 * u
        + 0.47 * x0 * u * u
        + 0.63 * u * u * u;
    out[1] = x2;
    out[2] = -4.208 * x0 - 0.396 * x2 - 0.47 * x0 * x0 - 3.564 * x0 * x0 * x0 - 20.967 * u
        + 6.265 * x0 * x0 * u
        + 46.0 * x0 * u * u
        + 61.4 * u * u * u;
}

impl CaseStudy for F8Crusader {
    fn name(&self) -> &'static str {
        "F8 Cruiser"
    }

    fn xdim(&self) -> usize {
        3
    }

    fn udim(&self) -> usize {
        1
    }

    fn rhs(&self) -> Box<dyn Rhs + '_> {
        Box::new(FnRhs {
            dim: 3,
            f: move |_t, y: &[f64], u: &[f64], out: &mut [f64]| {
                f8_rhs(y, u.first().copied().unwrap_or(0.0), out)
            },
        })
    }

    fn true_coeffs(&self) -> Option<Vec<f64>> {
        None // cubic dynamics: not representable at order 2
    }

    fn generate(&self, samples: usize, dt: f64, _rng: &mut Prng) -> Trace {
        // Elevator doublet excitation (standard system-ID input).
        let us: Vec<f64> = (0..samples)
            .map(|s| {
                let t = s as f64 * dt;
                if t < 1.0 {
                    self.input_amp
                } else if t < 2.0 {
                    -self.input_amp
                } else {
                    0.0
                }
            })
            .collect();
        let rhs = self.rhs();
        let xs = rk4_trajectory(rhs.as_ref(), &self.y0, &us, 1, dt, samples - 1);
        Trace {
            xdim: 3,
            udim: 1,
            dt,
            xs: xs[..samples * 3].to_vec(),
            us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_period_mode_is_damped() {
        let mut rng = Prng::new(1);
        let tr = F8Crusader::default().generate(4000, 0.01, &mut rng);
        // After the doublet the AoA oscillation decays toward trim.
        let early = tr.xs[500 * 3].abs();
        let late = tr.xs[3900 * 3].abs();
        assert!(late < early.max(0.05), "early={early} late={late}");
        assert!(tr.xs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn elevator_input_excites_pitch_rate() {
        let mut rng = Prng::new(2);
        let with_u = F8Crusader::default().generate(300, 0.01, &mut rng);
        let without = F8Crusader {
            input_amp: 0.0,
            y0: [0.1, 0.0, 0.0],
        }
        .generate(300, 0.01, &mut rng);
        let q_with: f64 = (0..300).map(|s| with_u.xs[s * 3 + 2].abs()).sum();
        let q_without: f64 = (0..300).map(|s| without.xs[s * 3 + 2].abs()).sum();
        assert!(q_with > q_without);
    }

    #[test]
    fn cubic_system_has_no_order2_truth() {
        assert!(F8Crusader::default().true_coeffs().is_none());
    }
}
